"""llama4-maverick-400b-a17b [hf:meta-llama/Llama-4; unverified].

48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048, MoE 128 routed
experts top-1 + 1 shared expert on alternating layers (Llama-4 interleaved
MoE).  The modality frontend ("early fusion") is a stub per the assignment:
``input_specs`` provides token ids; patch embeddings would enter the same
embedding slot.
"""
import jax.numpy as jnp

from ..models.transformer import LMConfig
from .base import ArchSpec, lm_shapes, register

CONFIG = LMConfig(
    name="llama4-maverick-400b-a17b",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, d_head=128,
    d_ff=8192, vocab=202048,
    n_experts=128, top_k=1, n_shared=1, d_ff_expert=8192, moe_every=2,
    dtype=jnp.bfloat16, attn_chunk=1024, microbatches=8,
)

SPEC = register(ArchSpec(
    arch_id="llama4-maverick-400b-a17b", family="lm", cfg=CONFIG,
    shapes=lm_shapes(CONFIG),
    source="hf:meta-llama/Llama-4-Scout-17B-16E (scaled per assignment)",
))
