"""dcn-v2 [arXiv:2008.13535; paper].

13 dense + 26 sparse fields (Criteo layout), embed_dim=16, 3 cross layers,
deep tower 1024-1024-512, parallel combination.  Per-field vocab 2^20
(hashed), tables row-sharded.  ``retrieval_cand`` for a ranker = bulk
scoring of 10^6 candidate rows for one query context.
"""
import jax.numpy as jnp

from ..models.recsys.dcn_v2 import DCNConfig
from .base import SDS, ArchSpec, ShapeCell, register
from .recsys_shapes import BULK_B, P99_B, TRAIN_B, N_CAND_RETR

CONFIG = DCNConfig(
    name="dcn-v2", n_dense=13, n_sparse=26, vocab_per_field=1 << 20,
    embed_dim=16, n_cross_layers=3, mlp_dims=(1024, 1024, 512),
)


def _fwd(batch, with_labels):
    def make(cfg):
        d = {
            "dense_feats": SDS((batch, cfg.n_dense), jnp.float32),
            "sparse_ids": SDS((batch, cfg.n_sparse), jnp.int32),
        }
        if with_labels:
            d["labels"] = SDS((batch,), jnp.float32)
        return d
    return make


SPEC = register(ArchSpec(
    arch_id="dcn-v2", family="recsys", cfg=CONFIG,
    shapes={
        "train_batch": ShapeCell("train", _fwd(TRAIN_B, True),
                                 f"batch {TRAIN_B}"),
        "serve_p99": ShapeCell("serve", _fwd(P99_B, False), "online ranking"),
        "serve_bulk": ShapeCell("serve", _fwd(BULK_B, False),
                                "offline scoring"),
        "retrieval_cand": ShapeCell("serve", _fwd(N_CAND_RETR, False),
                                    "1M candidate rows for one query"),
    },
    source="arXiv:2008.13535",
))
