"""mind [arXiv:1904.08030; unverified].

embed_dim=64, 4 interest capsules, 3 routing iterations, multi-interest
label-aware attention.
"""
from ..models.recsys.mind import MINDConfig
from .base import ArchSpec, register
from .recsys_shapes import seq_shapes

CONFIG = MINDConfig(
    name="mind", n_items=1 << 20, embed_dim=64, n_interests=4,
    capsule_iters=3, seq_len=50,
)

SPEC = register(ArchSpec(
    arch_id="mind", family="recsys", cfg=CONFIG,
    shapes=seq_shapes(seq_len=50, target_per_pos=False),
    source="arXiv:1904.08030",
))
