"""deepseek-moe-16b [arXiv:2401.06066; hf].

28L d_model=2048 16H (kv=16) d_ff=1408 vocab=102400; 64 fine-grained
routed experts top-6 + 2 shared experts, every layer.
"""
import jax.numpy as jnp

from ..models.transformer import LMConfig
from .base import ArchSpec, lm_shapes, register

CONFIG = LMConfig(
    name="deepseek-moe-16b",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16, d_head=128,
    d_ff=1408, vocab=102400,
    n_experts=64, top_k=6, n_shared=2, d_ff_expert=1408, moe_every=1,
    dtype=jnp.bfloat16, attn_chunk=2048, microbatches=16,
)

SPEC = register(ArchSpec(
    arch_id="deepseek-moe-16b", family="lm", cfg=CONFIG,
    shapes=lm_shapes(CONFIG), source="arXiv:2401.06066",
))
