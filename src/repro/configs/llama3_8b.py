"""llama3-8b [arXiv:2407.21783; unverified].

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=128256.
"""
import jax.numpy as jnp

from ..models.transformer import LMConfig
from .base import ArchSpec, lm_shapes, register

CONFIG = LMConfig(
    name="llama3-8b",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_head=128,
    d_ff=14336, vocab=128256,
    dtype=jnp.bfloat16, attn_chunk=2048, microbatches=16,
)

SPEC = register(ArchSpec(
    arch_id="llama3-8b", family="lm", cfg=CONFIG,
    shapes=lm_shapes(CONFIG), source="arXiv:2407.21783",
))
