"""gat-cora [arXiv:1710.10903; paper].

2 layers, 8 hidden units x 8 attention heads, attn aggregator.  The four
assigned graph cells span full-batch small (Cora), sampled training
(Reddit-scale), full-batch large (ogbn-products) and batched molecules —
each with its own feature/class dims (taken from the public datasets).
"""
import jax.numpy as jnp

from ..models.gnn import GNNConfig
from .base import SDS, ArchSpec, ShapeCell, register

CONFIG = GNNConfig(name="gat-cora", n_layers=2, d_hidden=8, n_heads=8)

# per-cell graph dims: (n_nodes, n_edges, d_feat, n_classes).
# Node/edge counts are padded up to the next multiple of 32 (isolated dummy
# nodes / masked self-loop edges) so explicit input shardings divide the
# 512-device mesh — the standard production padding; true sizes in comments.
CELL_DIMS = {
    "full_graph_sm": (3072, 10752, 1433, 7),            # Cora 2708 / 10556
    "minibatch_lg": (232_965, 114_615_892, 602, 41),    # Reddit (sampled path)
    "ogb_products": (2_449_408, 61_859_328, 100, 47),   # products 2449029 / 61859140
    "molecule": (4096, 64 * 128, 16, 10),               # 128-graph union (30x128 nodes)
}

FANOUTS = (15, 10)
BATCH_NODES = 1024


def _full_graph(n, e, f, c):
    def make(cfg):
        return {
            "feats": SDS((n, f), jnp.float32),
            "src": SDS((e,), jnp.int32),
            "dst": SDS((e,), jnp.int32),
            "labels": SDS((n,), jnp.int32),
            "mask": SDS((n,), jnp.bool_),
        }
    return make


def _minibatch(f, c):
    # union subgraph: 1024 seeds, fanout 15 then 10 (fixed shapes)
    n_tot = BATCH_NODES * (1 + FANOUTS[0] + FANOUTS[0] * FANOUTS[1])
    e_tot = BATCH_NODES * (FANOUTS[0] + FANOUTS[0] * FANOUTS[1])

    def make(cfg):
        return {
            "feats": SDS((n_tot, f), jnp.float32),
            "src": SDS((e_tot,), jnp.int32),
            "dst": SDS((e_tot,), jnp.int32),
            "labels": SDS((n_tot,), jnp.int32),
            "mask": SDS((n_tot,), jnp.bool_),   # true on the seed block
        }
    return make


def _shapes():
    out = {}
    for cell, (n, e, f, c) in CELL_DIMS.items():
        ov = (("d_feat", f), ("n_classes", c))
        if cell == "ogb_products":
            # §Perf: the 2.45M-node gather is this cell's bottleneck
            ov += (("quantized_gather", True),)
        if cell == "minibatch_lg":
            out[cell] = ShapeCell("train", _minibatch(f, c),
                                  "sampled blocks 1024 @ fanout 15-10", ov)
        else:
            out[cell] = ShapeCell("train", _full_graph(n, e, f, c),
                                  f"full batch {n} nodes / {e} edges", ov)
    return out


SPEC = register(ArchSpec(
    arch_id="gat-cora", family="gnn", cfg=CONFIG, shapes=_shapes(),
    source="arXiv:1710.10903",
))
