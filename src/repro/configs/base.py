"""Architecture registry: ArchSpec + per-cell input specs.

Every assigned architecture registers an ``ArchSpec`` with its exact
published configuration and its own shape set.  A *cell* = (arch, shape)
names one dry-run/roofline unit; ``input_specs`` builds the
ShapeDtypeStruct stand-ins the launcher lowers against (no allocation).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

SDS = jax.ShapeDtypeStruct


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    kind: str                      # "train" | "serve" | "decode"
    make_inputs: Callable[[Any], dict]  # cfg -> {name: ShapeDtypeStruct}
    note: str = ""
    cfg_overrides: tuple = ()      # (("d_feat", 100), ...) applied per cell


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    arch_id: str
    family: str                    # "lm" | "gnn" | "recsys" | "bandit"
    cfg: Any
    shapes: dict[str, ShapeCell]
    source: str = ""

    def cell_cfg(self, shape: str):
        ov = dict(self.shapes[shape].cfg_overrides)
        return dataclasses.replace(self.cfg, **ov) if ov else self.cfg

    def input_specs(self, shape: str) -> dict:
        return self.shapes[shape].make_inputs(self.cell_cfg(shape))


REGISTRY: dict[str, ArchSpec] = {}


def register(spec: ArchSpec) -> ArchSpec:
    REGISTRY[spec.arch_id] = spec
    return spec


def get(arch_id: str) -> ArchSpec:
    return REGISTRY[arch_id]


def all_cells() -> list[tuple[str, str]]:
    return [(a, s) for a, spec in REGISTRY.items() for s in spec.shapes]


# ---- shared LM shape-set builder ------------------------------------------------

def lm_shapes(cfg) -> dict[str, ShapeCell]:
    def train_4k(c):
        return {
            "tokens": SDS((256, 4096), jnp.int32),
            "labels": SDS((256, 4096), jnp.int32),
        }

    def prefill_32k(c):
        return {"tokens": SDS((32, 32768), jnp.int32)}

    def _decode(batch, s_max):
        def make(c):
            cache_shape = (c.n_blocks, c.block_layers, batch, c.n_kv_heads,
                           s_max, c.d_head)
            return {
                "token": SDS((batch,), jnp.int32),
                "k_cache": SDS(cache_shape, c.dtype),
                "v_cache": SDS(cache_shape, c.dtype),
                "pos": SDS((), jnp.int32),
            }
        return make

    return {
        "train_4k": ShapeCell("train", train_4k, "seq 4096, global batch 256"),
        "prefill_32k": ShapeCell("serve", prefill_32k,
                                 "inference prefill, 32 x 32768"),
        "decode_32k": ShapeCell("decode", _decode(128, 32768),
                                "one token vs 32k KV cache, batch 128"),
        # Decode against a 500k cache is LINEAR in cache length (one query
        # token) so full-attention archs run it; the sub-quadratic caveat
        # applies to 500k *prefill*, which is not attempted (DESIGN.md §5).
        "long_500k": ShapeCell("decode", _decode(1, 524288),
                               "one token vs 524288 KV cache, batch 1"),
    }
