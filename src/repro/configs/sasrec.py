"""sasrec [arXiv:1808.09781; paper].

embed_dim=50 n_blocks=2 n_heads=1 seq_len=50, causal self-attention over
the item history, next-item objective.  Catalog scaled to 2^20 items to
exercise the production sharded-embedding path (paper datasets are small;
the shape set assigns 10^6-candidate retrieval).
"""
from ..models.recsys.seqrec import SeqRecConfig
from .base import ArchSpec, register
from .recsys_shapes import seq_shapes

CONFIG = SeqRecConfig(
    name="sasrec", n_items=1 << 20, embed_dim=50, n_blocks=2, n_heads=1,
    seq_len=50, causal=True,
)

SPEC = register(ArchSpec(
    arch_id="sasrec", family="recsys", cfg=CONFIG,
    shapes=seq_shapes(seq_len=50, target_per_pos=True),
    source="arXiv:1808.09781",
))
