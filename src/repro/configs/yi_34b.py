"""yi-34b [arXiv:2403.04652; hf].

60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000 (llama arch).
"""
import jax.numpy as jnp

from ..models.transformer import LMConfig
from .base import ArchSpec, lm_shapes, register

CONFIG = LMConfig(
    name="yi-34b",
    n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8, d_head=128,
    d_ff=20480, vocab=64000,
    dtype=jnp.bfloat16, attn_chunk=2048, microbatches=32,
)

SPEC = register(ArchSpec(
    arch_id="yi-34b", family="lm", cfg=CONFIG,
    shapes=lm_shapes(CONFIG), source="arXiv:2403.04652",
))
