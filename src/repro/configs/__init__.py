"""Architecture configs: import registers every assigned arch + the paper's own."""
from . import (  # noqa: F401
    bert4rec,
    dcn_v2,
    deepseek_moe_16b,
    distclub_paper,
    gat_cora,
    llama3_8b,
    llama4_maverick_400b_a17b,
    mind,
    qwen3_4b,
    sasrec,
    yi_34b,
)
from .base import REGISTRY, ArchSpec, ShapeCell, all_cells, get  # noqa: F401
