"""Shared recsys shape-set builders (train_batch / serve_p99 / serve_bulk /
retrieval_cand) for the sequence-style recommenders (sasrec, bert4rec, mind).

Candidate-set size for the serve cells is 1000 (industry-standard final
ranking slate); retrieval scores one query against 10^6 candidates as a
single batched dot per the assignment ("batched-dot, not a loop").
"""
from __future__ import annotations

import jax.numpy as jnp

from .base import SDS, ShapeCell

TRAIN_B = 65_536
P99_B = 512
BULK_B = 262_144
N_CAND_SERVE = 1000
N_CAND_RETR = 1_048_576   # 2^20: 10^6 rounded up to divide 512-way meshes


def seq_shapes(seq_len: int, target_per_pos: bool) -> dict[str, ShapeCell]:
    """target_per_pos: SASRec/BERT4Rec predict per position; MIND one target."""

    def train(cfg):
        d = {
            "hist": SDS((TRAIN_B, seq_len), jnp.int32),
            "key": SDS((2,), jnp.uint32),
        }
        if target_per_pos:
            d["targets"] = SDS((TRAIN_B, seq_len), jnp.int32)
        else:
            d["targets"] = SDS((TRAIN_B,), jnp.int32)
        return d

    def serve(batch):
        def make(cfg):
            return {
                "hist": SDS((batch, seq_len), jnp.int32),
                "cand": SDS((batch, N_CAND_SERVE), jnp.int32),
            }
        return make

    def retrieval(cfg):
        return {
            "hist": SDS((1, seq_len), jnp.int32),
            "cand": SDS((N_CAND_RETR,), jnp.int32),
        }

    return {
        "train_batch": ShapeCell("train", train, f"batch {TRAIN_B}"),
        "serve_p99": ShapeCell("serve", serve(P99_B),
                               f"online, {P99_B} x {N_CAND_SERVE} candidates"),
        "serve_bulk": ShapeCell("serve", serve(BULK_B),
                                f"offline, {BULK_B} x {N_CAND_SERVE} candidates"),
        "retrieval_cand": ShapeCell("serve", retrieval,
                                    f"1 query x {N_CAND_RETR} candidates"),
    }
