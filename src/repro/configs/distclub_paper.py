"""The paper's own configuration: DistCLUB on the synthetic stress set
(20k users, d=25 features, 20 candidates/interaction; paper Table 1/2).

One dry-run cell: a full 4-stage epoch on the production mesh with users
sharded over every axis.  Hyper-parameters follow paper Table 2 with the
round budgets scaled to the batched-round formulation (sigma rounds per
user per stage; DESIGN.md §2).
"""
import jax.numpy as jnp

from ..core.types import BanditHyper
from .base import SDS, ArchSpec, ShapeCell, register

N_USERS = 20_480          # paper: 20,000; rounded to divide 512-way meshes
D_FEAT = 25

CONFIG = BanditHyper(
    alpha=0.03, beta=2.0, gamma=1.6, sigma=16, n_candidates=20,
    max_rounds=32,
)


def _epoch(cfg):
    # the unified engine state (distclub_shard.ShardedDistCLUB): env tables
    # and cluster snapshots are no longer carried — the environment lives
    # in the EnvOps closure and the snapshots are stage-2 transients.
    n, d = N_USERS, D_FEAT
    return {
        "Minv": SDS((n, d, d), jnp.float32),
        "b": SDS((n, d), jnp.float32),
        "occ": SDS((n,), jnp.int32),
        # bit-packed adjacency rows (32x below the dense bool graph)
        "adj": SDS((n, (n + 31) // 32), jnp.uint32),
        "labels": SDS((n,), jnp.int32),
        "u_rounds": SDS((n,), jnp.int32),
        "c_rounds": SDS((n,), jnp.int32),
        "comm_bytes": SDS((), jnp.float32),
        "key": SDS((2,), jnp.uint32),
    }


SPEC = register(ArchSpec(
    arch_id="distclub-paper", family="bandit", cfg=CONFIG,
    shapes={
        "online_20k": ShapeCell(
            "bandit_epoch", _epoch,
            "paper synthetic: 20480 users x d=25, full 4-stage epoch"),
    },
    source="this paper (Mahadik et al. 2020), Tables 1-2",
))
