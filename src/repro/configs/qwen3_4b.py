"""qwen3-4b [hf:Qwen/Qwen3-4B; hf].

36L d_model=2560 32H (GQA kv=8, head_dim=128 decoupled from d_model)
d_ff=9728 vocab=151936, qk-norm on.
"""
import jax.numpy as jnp

from ..models.transformer import LMConfig
from .base import ArchSpec, lm_shapes, register

CONFIG = LMConfig(
    name="qwen3-4b",
    n_layers=36, d_model=2560, n_heads=32, n_kv_heads=8, d_head=128,
    d_ff=9728, vocab=151936, qk_norm=True,
    dtype=jnp.bfloat16, attn_chunk=2048, microbatches=8,
)

SPEC = register(ArchSpec(
    arch_id="qwen3-4b", family="lm", cfg=CONFIG,
    shapes=lm_shapes(CONFIG), source="hf:Qwen/Qwen3-4B",
))
