"""bert4rec [arXiv:1904.06690; paper].

embed_dim=64 n_blocks=2 n_heads=2 seq_len=200, bidirectional encoder,
cloze (masked-item) objective.  Encoder-only: no decode-style cells exist
in the recsys shape set, so no skip is triggered (DESIGN.md §5).
"""
from ..models.recsys.seqrec import SeqRecConfig
from .base import ArchSpec, register
from .recsys_shapes import seq_shapes

CONFIG = SeqRecConfig(
    name="bert4rec", n_items=1 << 20, embed_dim=64, n_blocks=2, n_heads=2,
    seq_len=200, causal=False,
)

SPEC = register(ArchSpec(
    arch_id="bert4rec", family="recsys", cfg=CONFIG,
    shapes=seq_shapes(seq_len=200, target_per_pos=True),
    source="arXiv:1904.06690",
))
