"""One stage engine: the shard-count-agnostic DistCLUB runtime.

``runtime.stages`` holds the paper's four stage bodies written exactly
once; ``runtime.collectives`` holds the tiny communication protocol they
are written against.  ``repro.core.distclub`` runs the engine with the
null collectives (single host), ``repro.distributed.distclub_shard`` binds
the same stage functions to ``lax`` collectives inside ``shard_map``, and
both DCCB drivers route their interaction loop through the same shared
round scan.

Deliberately no eager submodule imports here: ``runtime.stages`` imports
``repro.core`` modules while ``repro.core.distclub`` imports
``runtime.stages`` back (call-time only), so the package init must stay
inert for either import order to work.
"""
