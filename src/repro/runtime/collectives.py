"""The communication protocol the stage engine is written against.

The four DistCLUB stages need exactly four communication primitives:

  axis_index()    which user-shard am I?        (stage 1/3: PRNG + env slice)
  all_gather(x)   tiled gather over the user axis (stage 2: v/occ for edge
                  pruning, label hops during connected components)
  psum(x)         all-reduce (stage 2: the paper's treeReduce of cluster
                  aggregates; epoch end: metrics)
  n_shards        static shard count (layout checks, comm models)

Two implementations, both hashable NamedTuples so drivers can thread them
through ``jax.jit`` as static arguments:

  ``NullCollectives``  every primitive is the identity — the engine run on
                       one host IS the single-host driver.  ``axis_index``
                       returns the Python int 0, so downstream offsets
                       (``row0 = axis_index() * n_local``) stay
                       compile-time constants.
  ``LaxCollectives``   binds the primitives to named mesh axes; only valid
                       inside ``shard_map`` (or another axis-binding
                       context) over those axes.

Everything else about distribution (which arrays are sharded, what the
local row offset is) is derived from array shapes plus ``axis_index`` —
the stage bodies in ``runtime.stages`` never mention a mesh.
"""
from __future__ import annotations

from typing import NamedTuple

import jax


class NullCollectives(NamedTuple):
    """Single-host: one shard, every collective is the identity."""

    @property
    def n_shards(self) -> int:
        return 1

    def axis_index(self):
        return 0                      # Python int: offsets stay static

    def all_gather(self, x):
        return x

    def psum(self, x):
        return x


class LaxCollectives(NamedTuple):
    """``lax`` collectives bound to mesh axes (use inside ``shard_map``)."""

    axes: tuple[str, ...]
    shards: int                       # product of the axes' mesh sizes

    @property
    def n_shards(self) -> int:
        return self.shards

    def axis_index(self):
        return jax.lax.axis_index(self.axes)

    def all_gather(self, x):
        return jax.lax.all_gather(x, self.axes, tiled=True)

    def psum(self, x):
        return jax.lax.psum(x, self.axes)


def lax_collectives(mesh, axes: tuple[str, ...]) -> LaxCollectives:
    """Collectives over ``axes`` of ``mesh`` (users = the flattened axes)."""
    shards = 1
    for a in axes:
        shards *= mesh.shape[a]
    return LaxCollectives(axes=tuple(axes), shards=shards)
