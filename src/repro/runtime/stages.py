"""The four DistCLUB stages (paper Listing 3), written exactly once.

Every function here operates on a LOCAL user slice ``[n_local, ...]`` and a
``Collectives`` implementation (``runtime.collectives``):

  stage 1  ``personalized_rounds``  — zero communication
  stage 2  ``stage2_refresh``       — THE communicating stage: all-gather
                                      for edge pruning, label hops for
                                      connected components, psum for the
                                      cluster aggregates (the treeReduce)
  stage 3  ``cluster_rounds``       — zero communication (stats frozen)
  stage 4  ``stage4_rebalance``     — zero communication

``repro.core.distclub`` runs these with ``NullCollectives`` (n_local = n,
row0 = 0) and ``repro.distributed.distclub_shard`` binds them to ``lax``
collectives inside ``shard_map``; the single-host/sharded parity test is
structural, not aspirational — there is one stage body to diverge from.

Shard-awareness of the environment: the stages call
``ops.contexts_fn(key, occ, row0)`` / ``ops.rewards_fn(key, occ, contexts,
choice, row0)`` where ``row0`` is the global id of the slice's first user.
Environments fold their PRNG **per global user id** (``repro.core.env_ops``)
so the draws for user ``u`` are identical no matter how the user axis is
sharded — metrics then agree across shardings up to fp contraction order
(psum vs flat sums in stage 2 and in the metric reductions).

Lazy-snapshot semantics (one source of truth): the per-user cluster
snapshots (Mcinv[label], bc[label], and the cluster mean-occ) are taken at
stage 2 and frozen for the whole epoch — stage 3's beta heuristic AND
stage 4's rebalancing both read the stage-2 snapshot.  The single-host
driver historically fed stage 4 a stage-3-updated ``seen`` counter while
the sharded driver used the stage-2 snapshot; unifying on the snapshot
(this module) fixed that divergence — see
``tests/test_algorithms.py::test_stage4_uses_stage2_snapshot``.

The interaction loop (``interaction_rounds``) is also the inner loop of
both DCCB drivers (buffered updates are just a different ``update_fn``),
so all four bandit runtimes share one round protocol:
env draw -> score -> fused choose -> env reward -> update -> metrics.
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..core import linucb
from ..core.types import Metrics

# ---------------------------------------------------------------------------
# the shared interaction loop (stage 1, stage 3, DCCB inner loop)
# ---------------------------------------------------------------------------


def _metrics_of(realized, expected, best, rand, mask):
    m = mask.astype(realized.dtype)
    return Metrics(
        reward=jnp.sum(realized * m),
        regret=jnp.sum((best - expected) * m),
        rand_reward=jnp.sum(rand * m),
        interactions=jnp.sum(mask.astype(jnp.int32)),
    )


def interaction_rounds(be, ops, hyper, key, carry0, *, row0, n_steps,
                       occ_of, score_fn, update_fn, budget=None):
    """``n_steps`` lockstep interaction rounds over a local user slice.

    One scan step = one (masked) interaction for every local user:

      contexts = ops.contexts_fn(k, occ, row0)         # env draw
      w, Minv  = score_fn(carry)                       # stage-specific
      x, choice = be.choose(w, Minv, contexts, occ, alpha)   # fused engine
      rewards  = ops.rewards_fn(k, occ, contexts, choice, row0)
      carry    = update_fn(carry, step_idx, x, realized, mask)

    ``carry0`` is an arbitrary pytree (pad it to the backend block shape
    ONCE before calling — only the fresh per-step context tensor is padded
    inside the loop).  ``occ_of(carry)`` returns the occupancy array at the
    carry's width; ``score_fn(carry) -> (w, minv_eff)`` at the same width.
    ``budget`` (un-padded ``[n_local] i32`` or None) masks users whose
    budget is exhausted; None = every user live every step (DCCB).
    ``update_fn`` receives ``realized`` and ``mask`` at logical/carry width
    respectively and owns any padding of its own inputs.

    Returns ``(carry, metrics)`` with per-step ``Metrics`` rows
    ``[n_steps]`` (local sums — psum them at the epoch boundary).
    """
    budget_p = None if budget is None else be.pad_users(budget)

    def step(carry, inp):
        step_idx, k = inp
        k_ctx, k_rew = jax.random.split(k)
        occ = occ_of(carry)
        occ_log = be.unpad_users(occ)
        mask = (jnp.ones(occ.shape, bool) if budget_p is None
                else step_idx < budget_p)
        contexts = ops.contexts_fn(k_ctx, occ_log, row0)
        w, minv_eff = score_fn(carry)
        x, choice = be.choose(w, minv_eff, contexts, occ, hyper.alpha)
        realized, expected, best, rand = ops.rewards_fn(
            k_rew, occ_log, contexts, be.unpad_users(choice), row0
        )
        carry = update_fn(carry, step_idx, x, realized, mask)
        return carry, _metrics_of(
            realized, expected, best, rand, be.unpad_users(mask)
        )

    steps = jnp.arange(n_steps)
    keys = jax.random.split(key, n_steps)
    return jax.lax.scan(step, carry0, (steps, keys))


def _linucb_update(be):
    """The DistCLUB per-round update: M-free fused Sherman-Morrison."""

    def update(carry, step_idx, x, realized, mask):
        del step_idx
        Minv, b, occ = carry
        Minv, b = be.update_inv(Minv, b, x, be.pad_users(realized), mask)
        return (Minv, b, occ + mask.astype(jnp.int32))

    return update


def _bandit_rounds(be, ops, hyper, Minv, b, occ, budget, key, row0, score_fn):
    carry0 = (be.pad_gram(Minv), be.pad_vec(b), be.pad_users(occ))
    (Minv, b, occ), metrics = interaction_rounds(
        be, ops, hyper, key, carry0, row0=row0, n_steps=hyper.max_rounds,
        occ_of=lambda c: c[2], score_fn=score_fn,
        update_fn=_linucb_update(be), budget=budget,
    )
    return (be.unpad_gram(Minv), be.unpad_vec(b), be.unpad_users(occ),
            metrics)


def personalized_rounds(be, ops, hyper, Minv, b, occ, budget, key, row0):
    """Stage 1: user-based LinUCB rounds — embarrassingly parallel, the
    state is padded once per stage and the scan carries the padded state."""

    def score_own(carry):
        Minv_, b_, _ = carry
        return linucb.user_vector(Minv_, b_), Minv_

    return _bandit_rounds(be, ops, hyper, Minv, b, occ, budget, key, row0,
                          score_own)


def beta_gate(hyper, occ, umean_occ):
    """The paper's beta personalization heuristic: a user whose lifetime
    occupancy has reached ``beta`` times the cluster's mean occupancy
    scores with their OWN statistics instead of the cluster's.  Single
    definition shared by stage 3 and the serving layer's clustered
    policies."""
    return occ.astype(jnp.float32) >= hyper.beta * umean_occ


def mix_scores(use_own, v_own, v_clu, Minv_own, Minv_clu):
    """Per-user blend of personalized vs cluster scoring statistics:
    ``(w, minv_eff)`` for the fused choose.  Shared by stage 3 and the
    serving policies (``repro.serve``)."""
    w = jnp.where(use_own[:, None], v_own, v_clu)
    minv_eff = jnp.where(use_own[:, None, None], Minv_own, Minv_clu)
    return w, minv_eff


def cluster_rounds(be, ops, hyper, Minv, b, occ, budget, key, row0,
                   uMcinv, ubc, umean_occ):
    """Stage 3: cluster-based rounds with the beta personalization
    heuristic.  The per-user cluster snapshots (``uMcinv``/``ubc``/
    ``umean_occ``, from :func:`stage2_refresh`) are FROZEN for the whole
    stage (the paper's lazy semantics): they are padded and the cluster
    user-vector computed once, outside the scan."""
    uMcinv_p = be.pad_gram(uMcinv)
    ubc_p = be.pad_vec(ubc)
    v_clu = linucb.user_vector(uMcinv_p, ubc_p)
    umean_p = be.pad_users(umean_occ)

    def score_cluster(carry):
        Minv_, b_, occ_ = carry
        use_own = beta_gate(hyper, occ_, umean_p)
        v_own = linucb.user_vector(Minv_, b_)
        return mix_scores(use_own, v_own, v_clu, Minv_, uMcinv_p)

    return _bandit_rounds(be, ops, hyper, Minv, b, occ, budget, key, row0,
                          score_cluster)


# ---------------------------------------------------------------------------
# stage 2: the communication stage
# ---------------------------------------------------------------------------


def stage2_comm_bytes(n: int, d: int) -> int:
    """Modeled network bytes of one stage-2 refresh (paper Fig. 3, updated
    for the packed graph engine).  Single source of truth for both
    drivers, the tests and the paper benchmarks.

    Per refresh: each user ships (M, b) once into the tree reduction and
    the cluster stats return along the same tree (``2 n (d^2 + d)`` f32
    words); edge pruning all-gathers the user vectors and counts
    (``n (d + 1)`` words); and each pointer-doubling CC hop exchanges the
    n i32 labels — ``ceil(log2 n) + 1`` hops bound the doubling schedule.
    The adjacency itself NEVER crosses the network: it is row-sharded and
    bit-packed, n^2/8 bytes of node-local HBM (32x below the dense bool
    graph; see ``benchmarks/bench_graph.py`` for the HBM model).
    """
    hops = max(1, math.ceil(math.log2(max(n, 2))) + 1)
    return 4 * (2 * n * (d * d + d) + n * (d + 1) + hops * n)


def snapshot_mean_occ(seen, size, labels):
    """Cluster mean lifetime-occupancy snapshot, per user: stage 3's beta
    heuristic AND stage 4's rebalancing both read this stage-2 value."""
    return seen[labels].astype(jnp.float32) / jnp.maximum(size[labels], 1)


def connected_components(col, gb, adj, n, row0, n_local):
    """Min-label propagation over the packed local adjacency rows, with
    pointer doubling on the (replicated) labels.

    One hop = fused neighbour-min over each shard's packed rows
    (``gb.cc_hop``, n_local*n/8 bytes of HBM), a tiled all-gather of the
    fresh local labels (the stage's only traffic), then the comm-free
    shortcutting step ``min(l, l[l])`` that makes convergence O(log n)
    hops instead of O(diameter).  With null collectives this is exactly
    the single-host ``GraphBackend.cc`` hop sequence.
    """
    init = jnp.arange(n, dtype=jnp.int32)

    def cond(carry):
        _, changed, it = carry
        return changed & (it < n)

    def body(carry):
        labels, _, it = carry
        local = jax.lax.dynamic_slice_in_dim(labels, row0, n_local)
        new = col.all_gather(gb.cc_hop(adj, local, labels))
        new = jnp.minimum(new, new[new])
        return new, jnp.any(new != labels), it + 1

    labels, _, _ = jax.lax.while_loop(cond, body, (init, jnp.array(True), 0))
    return labels


class Stage2Refresh(NamedTuple):
    """Everything stage 2 produces, local-slice and replicated views both.

    The replicated tables (``Mc``/``bc``/``size``/``seen``, label-indexed,
    rows for non-label ids are garbage/identity and never read) exist so
    the single-host driver can expose its ``ClusterStats`` record (serving
    layer, checkpoints); the sharded epoch keeps only the per-user sharded
    snapshots and lets the tables die as transients — they dominated
    per-device HBM when carried (§Perf iteration 2).
    """

    adj: jnp.ndarray          # [n_local, words]  pruned packed rows
    labels: jnp.ndarray       # [n]               replicated
    Mc: jnp.ndarray           # [n, d, d]         replicated (transient)
    bc: jnp.ndarray           # [n, d]            replicated (transient)
    size: jnp.ndarray         # [n] i32           replicated (transient)
    seen: jnp.ndarray         # [n] i32           replicated (transient)
    uMcinv: jnp.ndarray       # [n_local, d, d]   per-user cluster snapshot
    ubc: jnp.ndarray          # [n_local, d]
    umean_occ: jnp.ndarray    # [n_local] f32     mean-occ snapshot
    n_clusters: jnp.ndarray   # [] i32
    comm_bytes: jnp.ndarray   # [] f32            modeled bytes this refresh


def stage2_refresh(col, gb, hyper, d, Minv, b, occ, adj) -> Stage2Refresh:
    """Network update + clustering + cluster statistics (the comm stage).

    The Gram matrix is NOT an input: ``M = inv(Minv)`` is recovered
    locally once per refresh (both runtimes carry only the inverse —
    dropping M cut the per-round state traffic by ~1/3).  The cluster
    aggregation is a local ``segment_sum`` followed by ``col.psum`` — the
    paper's treeReduce on the all-reduce tree.  ``seen`` is seeded so
    ``seen/size`` equals the cluster's mean lifetime occupancy (paper:
    "average interactions for users in the cluster") and is FROZEN until
    the next refresh.
    """
    n = gb.n_cols
    n_local = Minv.shape[0]
    row0 = col.axis_index() * n_local

    # serving sessions may carry Minv in a reduced Precision state dtype;
    # the solves/inversions here run in f32 (no-op upcast for f32 state)
    Minv = Minv.astype(jnp.float32)
    v_local = linucb.user_vector(Minv, b)                     # [n_local, d]
    v_all = col.all_gather(v_local)                           # [n, d]
    occ_all = col.all_gather(occ)                             # [n]
    adj = gb.prune_rows(adj, v_local, occ, v_all, occ_all, hyper.gamma)
    labels = connected_components(col, gb, adj, n, row0, n_local)
    local_labels = jax.lax.dynamic_slice_in_dim(labels, row0, n_local)

    eye = jnp.eye(d, dtype=jnp.float32)
    M = jnp.linalg.inv(Minv)
    Mc = col.psum(
        jax.ops.segment_sum(M - eye, local_labels, num_segments=n)
    ) + eye
    bc = col.psum(jax.ops.segment_sum(b, local_labels, num_segments=n))
    size = col.psum(jax.ops.segment_sum(
        jnp.ones_like(local_labels), local_labels, num_segments=n))
    seen = col.psum(jax.ops.segment_sum(occ, local_labels, num_segments=n))

    uMcinv = jnp.linalg.inv(Mc[local_labels])                 # [n_local,d,d]
    ubc = bc[local_labels]
    umean_occ = snapshot_mean_occ(seen, size, local_labels)
    n_clusters = jnp.sum(labels == jnp.arange(n, dtype=labels.dtype))
    return Stage2Refresh(
        adj=adj, labels=labels, Mc=Mc, bc=bc, size=size, seen=seen,
        uMcinv=uMcinv, ubc=ubc, umean_occ=umean_occ, n_clusters=n_clusters,
        comm_bytes=jnp.float32(stage2_comm_bytes(n, d)),
    )


# ---------------------------------------------------------------------------
# stage 4
# ---------------------------------------------------------------------------


def stage4_rebalance(hyper, occ, umean_occ, u_rounds, c_rounds):
    """Rebalance per-user budgets between personalized / cluster rounds.

    ``umean_occ`` is the STAGE-2 SNAPSHOT of the cluster mean occupancy
    (``Stage2Refresh.umean_occ``) — the same frozen value stage 3's beta
    heuristic reads.  Both runtimes use this definition; the single-host
    driver previously fed a stage-3-updated counter here (the fixed
    divergence).

    Invariant (load-bearing for ``data.datasets.epochs_for``): the shift
    ``delta`` conserves the per-user budget SUM ``u + c`` — but only until
    a clip engages.  Each budget is clipped to ``[0, max_rounds]`` (the
    static scan length), so a user can momentarily process fewer than
    ``u + c`` interactions per epoch; per-epoch interaction counts are
    therefore bounded by ``n * 2 * min(sigma, max_rounds)``, not fixed at
    ``n * 2 * sigma``.
    """
    delta = ((occ.astype(jnp.float32) - umean_occ) / 2.0).astype(jnp.int32)
    u_rounds = jnp.clip(u_rounds + delta, 0, hyper.max_rounds)
    c_rounds = jnp.clip(c_rounds - delta, 0, hyper.max_rounds)
    return u_rounds, c_rounds
