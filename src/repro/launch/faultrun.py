"""Fault-injection driver: the feedback loop under hostile delivery.

    PYTHONPATH=src python -m repro.launch.faultrun --policy distclub \
        --rounds 60 --delay 0.3 --loss 0.1 --dup 0.05

Runs the same seeded traffic twice — a clean control (no faults) and the
faulted run — through a buffer-enabled ``OnlineBandit`` session and
prints the degradation attributable to the faults.  ``--guard`` wraps
the session in ``serve.guardrails.Guarded`` (CTR floor vs the clean
run's rate) so a ``--flip``-corrupted run ends in an auto-rollback
instead of a poisoned session; guardrail events are printed.

``--scenario churn`` serves against a live double-buffered catalog
instead of caller slates and layers CHURN faults on top of the delivery
faults: sustained stage/publish cycles (``--churn-every/-add/-retire``),
swap stalls (``--swap-stall``), torn swaps (``--torn``), a hot-region
flash crowd (``--flash-crowd-at/-size``) and a mass retirement
(``--mass-retire-at``).  The control run is the same traffic with zero
churn; the report adds the quarantine (``stale``) accounting and the
published epoch count.  ``--guard`` then also tracks the catalog, so a
``--churn-ceiling`` breach rolls back the (state, catalog, epoch)
triple as one unit.
"""
from __future__ import annotations

import argparse
import tempfile

import jax

from ..core import env as bandit_env
from ..core.types import BanditHyper
from ..serve import OnlineBandit, faults, guardrails, make_catalog
from ..train.checkpoint import CheckpointManager


def make_session(args):
    hyper = BanditHyper(alpha=0.05, gamma=2.4, n_candidates=args.k)
    return OnlineBandit.create(
        args.users, args.d, hyper, policy=args.policy,
        refresh_every=args.users * 4,
        pending_capacity=args.capacity, pending_ttl=args.ttl)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scenario", default="feedback",
                    choices=["feedback", "churn"],
                    help="feedback: slate serving under delivery faults; "
                         "churn: catalog serving under live churn + "
                         "delivery faults")
    ap.add_argument("--policy", default="distclub",
                    choices=["distclub", "dccb", "club", "linucb"])
    ap.add_argument("--rounds", type=int, default=60)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--users", type=int, default=256)
    ap.add_argument("--d", type=int, default=16)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--capacity", type=int, default=512)
    ap.add_argument("--ttl", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--delay", type=float, default=0.0,
                    help="P(feedback delayed 1..max-delay rounds)")
    ap.add_argument("--max-delay", type=int, default=4)
    ap.add_argument("--loss", type=float, default=0.0)
    ap.add_argument("--dup", type=float, default=0.0)
    ap.add_argument("--flip", type=float, default=0.0,
                    help="P(delivered reward sign-flipped)")
    ap.add_argument("--flip-after", type=int, default=0)
    ap.add_argument("--stall-every", type=int, default=0)
    ap.add_argument("--stall-rounds", type=int, default=2)
    ap.add_argument("--guard", action="store_true",
                    help="wrap in guardrails (CTR floor + auto-rollback; "
                         "with --scenario churn the catalog rolls back "
                         "epoch-consistently too)")
    ap.add_argument("--ctr-floor", type=float, default=0.25)
    # -- churn scenario knobs --
    ap.add_argument("--items", type=int, default=512,
                    help="[churn] initial catalog items")
    ap.add_argument("--item-capacity", type=int, default=768,
                    help="[churn] catalog slot capacity")
    ap.add_argument("--k-short", type=int, default=32)
    ap.add_argument("--churn-every", type=int, default=4,
                    help="[churn] stage+publish cadence in rounds")
    ap.add_argument("--churn-add", type=int, default=16)
    ap.add_argument("--churn-retire", type=int, default=16)
    ap.add_argument("--swap-stall", type=int, default=0,
                    help="[churn] publishes land this many rounds late")
    ap.add_argument("--torn", type=float, default=0.0,
                    help="[churn] P(a publish is torn/partial)")
    ap.add_argument("--flash-crowd-at", type=int, default=-1)
    ap.add_argument("--flash-crowd-size", type=int, default=0)
    ap.add_argument("--mass-retire-at", type=int, default=-1)
    ap.add_argument("--churn-ceiling", type=float, default=0.5,
                    help="[churn, --guard] capacity fraction per publish")
    args = ap.parse_args()

    spec = faults.FaultSpec(
        seed=args.seed, p_delay=args.delay, max_delay=args.max_delay,
        p_loss=args.loss, p_dup=args.dup, p_flip=args.flip,
        flip_after=args.flip_after, stall_every=args.stall_every,
        stall_rounds=args.stall_rounds,
        churn_every=args.churn_every if args.scenario == "churn" else 0,
        churn_add=args.churn_add, churn_retire=args.churn_retire,
        swap_stall_rounds=args.swap_stall, p_torn=args.torn,
        flash_crowd_at=args.flash_crowd_at,
        flash_crowd_size=args.flash_crowd_size,
        mass_retire_at=args.mass_retire_at)

    if args.scenario == "churn":
        env, _ = bandit_env.make_catalog_env(
            jax.random.PRNGKey(1), n_users=args.users, d=args.d,
            n_clusters=max(2, args.users // 16), n_items=args.items,
            n_candidates=args.k)
        cat = make_catalog(bandit_env.catalog_embeddings(env),
                           capacity=args.item_capacity)
        _, clean = faults.run_faulted_catalog(
            make_session(args), env, args.rounds,
            faults.FaultSpec(seed=args.seed), catalog=cat,
            k_short=args.k_short, batch=args.batch, key=args.seed)
        session = make_session(args)
        if args.guard:
            cfg = guardrails.GuardrailConfig(
                ctr_floor=args.ctr_floor, churn_ceiling=args.churn_ceiling,
                warmup=2 * args.batch, ema=0.7, snapshot_every=8,
                cooldown=2)
            session = guardrails.Guarded.create(
                session, CheckpointManager(tempfile.mkdtemp(), keep=4),
                cfg, catalog=cat)
            session, rep = faults.run_faulted_catalog(
                session, env, args.rounds, spec, k_short=args.k_short,
                batch=args.batch, key=args.seed)
        else:
            session, rep = faults.run_faulted_catalog(
                session, env, args.rounds, spec, catalog=cat,
                k_short=args.k_short, batch=args.batch, key=args.seed)
    else:
        env, _ = bandit_env.make_synthetic_env(
            jax.random.PRNGKey(1), n_users=args.users, d=args.d,
            n_clusters=max(2, args.users // 16), n_candidates=args.k)
        _, clean = faults.run_faulted(make_session(args), env.theta,
                                      args.rounds, faults.FaultSpec(),
                                      batch=args.batch, key=args.seed)
        session = make_session(args)
        if args.guard:
            cfg = guardrails.GuardrailConfig(
                ctr_floor=args.ctr_floor, warmup=2 * args.batch,
                ema=0.7, snapshot_every=8, cooldown=2)
            session = guardrails.Guarded.create(
                session, CheckpointManager(tempfile.mkdtemp(), keep=4),
                cfg)
        session, rep = faults.run_faulted(session, env.theta, args.rounds,
                                          spec, batch=args.batch,
                                          key=args.seed)

    n = max(1, rep.interactions)
    print(f"[{args.policy}/{args.scenario}] {rep.rounds} rounds x "
          f"{args.batch} ({rep.interactions} decisions, {rep.delivered} "
          f"deliveries, {rep.tx_per_s:.0f} tx/s)")
    print(f"  clean  : reward {clean.reward:8.1f}  regret {clean.regret:8.1f}"
          f"  ({clean.reward / max(1, clean.interactions):.3f}/decision)")
    print(f"  faulted: reward {rep.reward:8.1f}  regret {rep.regret:8.1f}"
          f"  ({rep.reward / n:.3f}/decision)")
    print(f"  regret degradation: "
          f"{rep.regret / max(clean.regret, 1e-9):.2f}x clean")
    print(f"  pending: {rep.pending}")
    if args.scenario == "churn":
        print(f"  churn: {rep.publishes} epochs published, "
              f"+{rep.items_added}/-{rep.items_retired} items, "
              f"{rep.pending['stale']} feedback quarantined, "
              f"tx ratio {rep.tx_per_s / max(clean.tx_per_s, 1e-9):.2f}x "
              "clean")
    for e in rep.events:
        print(f"  guard event: {e}")


if __name__ == "__main__":
    main()
