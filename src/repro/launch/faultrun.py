"""Fault-injection driver: the feedback loop under hostile delivery.

    PYTHONPATH=src python -m repro.launch.faultrun --policy distclub \
        --rounds 60 --delay 0.3 --loss 0.1 --dup 0.05

Runs the same seeded traffic twice — a clean control (no faults) and the
faulted run — through a buffer-enabled ``OnlineBandit`` session and
prints the degradation attributable to the faults.  ``--guard`` wraps
the session in ``serve.guardrails.Guarded`` (CTR floor vs the clean
run's rate) so a ``--flip``-corrupted run ends in an auto-rollback
instead of a poisoned session; guardrail events are printed.
"""
from __future__ import annotations

import argparse
import tempfile

import jax

from ..core import env as bandit_env
from ..core.types import BanditHyper
from ..serve import OnlineBandit, faults, guardrails
from ..train.checkpoint import CheckpointManager


def make_session(args):
    hyper = BanditHyper(alpha=0.05, gamma=2.4, n_candidates=args.k)
    return OnlineBandit.create(
        args.users, args.d, hyper, policy=args.policy,
        refresh_every=args.users * 4,
        pending_capacity=args.capacity, pending_ttl=args.ttl)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--policy", default="distclub",
                    choices=["distclub", "dccb", "club", "linucb"])
    ap.add_argument("--rounds", type=int, default=60)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--users", type=int, default=256)
    ap.add_argument("--d", type=int, default=16)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--capacity", type=int, default=512)
    ap.add_argument("--ttl", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--delay", type=float, default=0.0,
                    help="P(feedback delayed 1..max-delay rounds)")
    ap.add_argument("--max-delay", type=int, default=4)
    ap.add_argument("--loss", type=float, default=0.0)
    ap.add_argument("--dup", type=float, default=0.0)
    ap.add_argument("--flip", type=float, default=0.0,
                    help="P(delivered reward sign-flipped)")
    ap.add_argument("--flip-after", type=int, default=0)
    ap.add_argument("--stall-every", type=int, default=0)
    ap.add_argument("--stall-rounds", type=int, default=2)
    ap.add_argument("--guard", action="store_true",
                    help="wrap in guardrails (CTR floor + auto-rollback)")
    ap.add_argument("--ctr-floor", type=float, default=0.25)
    args = ap.parse_args()

    env, _ = bandit_env.make_synthetic_env(
        jax.random.PRNGKey(1), n_users=args.users, d=args.d,
        n_clusters=max(2, args.users // 16), n_candidates=args.k)
    spec = faults.FaultSpec(
        seed=args.seed, p_delay=args.delay, max_delay=args.max_delay,
        p_loss=args.loss, p_dup=args.dup, p_flip=args.flip,
        flip_after=args.flip_after, stall_every=args.stall_every,
        stall_rounds=args.stall_rounds)

    _, clean = faults.run_faulted(make_session(args), env.theta,
                                  args.rounds, faults.FaultSpec(),
                                  batch=args.batch, key=args.seed)

    session = make_session(args)
    if args.guard:
        cfg = guardrails.GuardrailConfig(
            ctr_floor=args.ctr_floor, warmup=2 * args.batch,
            ema=0.7, snapshot_every=8, cooldown=2)
        session = guardrails.Guarded.create(
            session, CheckpointManager(tempfile.mkdtemp(), keep=4), cfg)
    session, rep = faults.run_faulted(session, env.theta, args.rounds,
                                      spec, batch=args.batch,
                                      key=args.seed)

    n = max(1, rep.interactions)
    print(f"[{args.policy}] {rep.rounds} rounds x {args.batch} "
          f"({rep.interactions} decisions, {rep.delivered} deliveries, "
          f"{rep.tx_per_s:.0f} tx/s)")
    print(f"  clean  : reward {clean.reward:8.1f}  regret {clean.regret:8.1f}"
          f"  ({clean.reward / max(1, clean.interactions):.3f}/decision)")
    print(f"  faulted: reward {rep.reward:8.1f}  regret {rep.regret:8.1f}"
          f"  ({rep.reward / n:.3f}/decision)")
    print(f"  regret degradation: "
          f"{rep.regret / max(clean.regret, 1e-9):.2f}x clean")
    print(f"  pending: {rep.pending}")
    for e in rep.events:
        print(f"  guard event: {e}")


if __name__ == "__main__":
    main()
