"""HBM fit report over the dry-run artifacts.

    PYTHONPATH=src python -m repro.launch.fitcheck [--budget-gib 16]

For each compiled cell: resident bytes (arguments) vs the per-chip HBM
budget, plus the XLA:CPU temp as an upper bound and the verdict.  Exits
non-zero if any cell's RESIDENT state exceeds the budget (temp is advisory
— see EXPERIMENTS.md on XLA:CPU inflation).
"""
from __future__ import annotations

import argparse
import json
import pathlib

RESULTS = pathlib.Path(__file__).resolve().parents[3] / "results" / "dryrun"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--budget-gib", type=float, default=16.0)
    ap.add_argument("--tag", default="pod1")
    args = ap.parse_args()

    budget = args.budget_gib * 2 ** 30
    rows = []
    for p in sorted(RESULTS.glob(f"*__{args.tag}.json")):
        r = json.loads(p.read_text())
        args_b = r["memory"]["argument_bytes"] or 0
        out_b = r["memory"]["output_bytes"] or 0
        alias_b = r["memory"]["alias_bytes"] or 0
        temp_b = r["memory"]["temp_bytes"] or 0
        resident = args_b + max(0, out_b - alias_b)   # donated buffers alias
        rows.append((r["arch"], r["shape"], resident, temp_b,
                     resident <= budget))

    print(f"{'arch':34s}{'shape':16s}{'resident GiB':>13s}"
          f"{'temp GiB (CPU)':>16s}  fit")
    bad = 0
    for arch, shape, res, temp, ok in rows:
        flag = "OK" if ok else "OVER"
        bad += 0 if ok else 1
        print(f"{arch:34s}{shape:16s}{res/2**30:13.2f}{temp/2**30:16.2f}  "
              f"{flag}")
    print(f"\n{len(rows) - bad}/{len(rows)} cells fit "
          f"{args.budget_gib:.0f} GiB resident budget")
    raise SystemExit(1 if bad else 0)


if __name__ == "__main__":
    main()
