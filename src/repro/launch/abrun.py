"""A/B experiment driver: policies head-to-head on live routed traffic.

    PYTHONPATH=src python -m repro.launch.abrun --rounds 120 \
        --arms distclub dccb linucb --selector

Runs an N-arm ``serve.experiments`` experiment — sticky uid-hash traffic
splitting over one seeded request stream (``faults.TrafficStream``, the
same keyed traffic the fault harness uses) — and prints the
``ExperimentReport``: per-arm reward/regret/matched ratios, the traffic
shares over time, and the sequential z-statistic for the leading pair.

``--selector`` turns on the Thompson-sampling meta-selector (Beta
posterior per arm; ``--buckets`` adds the cold_start/regular/power_user
context split) re-weighting fractions at epoch boundaries with a
minimum-exploration floor.  ``--guard`` wraps every arm in its own
guardrail monitors so a breaching arm is auto-disabled, its traffic
re-routed to the survivors.  ``--faults`` layers the seeded delivery
faults (delay/loss/dup/sign-flip) on top — every arm experiences the
identical fault stream.

``--env`` picks the environment: ``synthetic`` (fixed planted clusters),
``drift`` (preferences rotate as users accumulate interactions), or
``catalog`` (serving against a persistent item catalog via each arm's
``step_catalog`` — synchronous, no delivery faults).
"""
from __future__ import annotations

import argparse
import functools

import jax
import jax.numpy as jnp
import numpy as np

from ..core import env as bandit_env
from ..core.types import BanditHyper
from ..serve import OnlineBandit, experiments, faults, guardrails, make_catalog


def make_arm(policy: str, args, alpha: float = 0.05):
    hyper = BanditHyper(alpha=alpha, gamma=2.4, n_candidates=args.k)
    return OnlineBandit.create(
        args.users, args.d, hyper, policy=policy,
        refresh_every=args.users * 4,
        pending_capacity=args.capacity, pending_ttl=args.ttl)


def print_report(rep, names):
    print(f"[experiment] {rep.rounds} rounds, final split "
          + " ".join(f"{n}={f:.2f}{'' if e else ' (DISABLED)'}"
                     for n, f, e in zip(names, rep.fractions, rep.enabled)))
    for i, n in enumerate(names):
        den = max(1, rep.interactions[i])
        print(f"  {n:10s} reward {rep.reward[i]:8.1f} "
              f"({rep.reward[i] / den:.3f}/decision)  "
              f"regret {rep.regret[i]:8.1f}  "
              f"decisions {rep.interactions[i]:6d}  "
              f"matched {rep.matched_ratio[i]:.2f}")
    print(f"  leader: {rep.leader} vs {rep.runner_up}, "
          f"z = {rep.z_leading_pair:+.2f}  ({rep.tx_per_s:.0f} tx/s)")
    if len(rep.shares) > 1:
        print("  shares over time:")
        for step, fr in rep.shares:
            print(f"    step {step:5d}: "
                  + " ".join(f"{f:.2f}" for f in fr))
    for e in rep.events:
        print(f"  event: {e}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arms", nargs="+",
                    default=["distclub", "dccb", "linucb"],
                    help="one policy name per arm "
                         "(distclub/dccb/club/linucb; repeats allowed)")
    ap.add_argument("--env", default="synthetic",
                    choices=["synthetic", "drift", "catalog"])
    ap.add_argument("--rounds", type=int, default=120)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--users", type=int, default=256)
    ap.add_argument("--d", type=int, default=16)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--capacity", type=int, default=512)
    ap.add_argument("--ttl", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--salt", type=int, default=0,
                    help="sticky-assignment hash salt")
    ap.add_argument("--selector", action="store_true",
                    help="Thompson-sampling meta-selector over the arms")
    ap.add_argument("--epoch-rounds", type=int, default=25,
                    help="[selector] rounds between traffic re-weights")
    ap.add_argument("--floor", type=float, default=0.05,
                    help="[selector] per-arm minimum traffic fraction")
    ap.add_argument("--buckets", action="store_true",
                    help="[selector] cold_start/regular/power_user "
                         "context buckets")
    ap.add_argument("--guard", action="store_true",
                    help="per-arm guardrails: a breaching arm is "
                         "auto-disabled and its traffic re-routed")
    ap.add_argument("--ctr-floor", type=float, default=0.25)
    # -- delivery faults (synthetic/drift envs) --
    ap.add_argument("--faults", action="store_true",
                    help="inject the seeded delivery faults below")
    ap.add_argument("--delay", type=float, default=0.3)
    ap.add_argument("--max-delay", type=int, default=4)
    ap.add_argument("--loss", type=float, default=0.05)
    ap.add_argument("--dup", type=float, default=0.05)
    ap.add_argument("--flip", type=float, default=0.0)
    ap.add_argument("--flip-after", type=int, default=0)
    # -- catalog env knobs --
    ap.add_argument("--items", type=int, default=512)
    ap.add_argument("--k-short", type=int, default=32)
    args = ap.parse_args()

    A = len(args.arms)
    sessions = [make_arm(p, args) for p in args.arms]
    names = []
    for i, p in enumerate(args.arms):
        names.append(p if p not in names else f"{p}#{i}")
    selector = None
    if args.selector:
        selector = experiments.make_selector(
            A, floor=args.floor, epoch_rounds=args.epoch_rounds,
            bucket_edges=(3, 21) if args.buckets else ())
    guard_cfg = None
    if args.guard:
        guard_cfg = guardrails.GuardrailConfig(
            ctr_floor=args.ctr_floor, warmup=2 * args.batch, ema=0.7,
            cooldown=2)
    exp = experiments.create(sessions, names=names, salt=args.salt,
                             selector=selector, guard_cfg=guard_cfg)

    if args.env == "catalog":
        env, _ = bandit_env.make_catalog_env(
            jax.random.PRNGKey(1), n_users=args.users, d=args.d,
            n_clusters=max(2, args.users // 16), n_items=args.items,
            n_candidates=args.k)
        cat = make_catalog(bandit_env.catalog_embeddings(env))
        theta = jnp.asarray(env.theta)
        rfn = functools.partial(_catalog_rewards, theta)
        stream = faults.TrafficStream(args.seed, args.batch, args.users)
        for i in range(args.rounds):
            users, kr, _ = stream.catalog_batch(i)
            exp, items, _ = experiments.step_catalog(
                exp, kr, users, cat, rfn, k_short=args.k_short)
        rep = experiments.report(exp, rounds=args.rounds)
    else:
        spec = faults.FaultSpec(
            seed=args.seed, p_delay=args.delay, max_delay=args.max_delay,
            p_loss=args.loss, p_dup=args.dup, p_flip=args.flip,
            flip_after=args.flip_after) if args.faults \
            else faults.FaultSpec(seed=args.seed)
        if args.env == "drift":
            denv, _ = bandit_env.make_drift_env(
                jax.random.PRNGKey(1), n_users=args.users, d=args.d,
                n_clusters=max(2, args.users // 16),
                n_candidates=args.k, drift_period=max(4, args.rounds // 4))
            theta = (lambda counts:
                     bandit_env.drift_theta(denv, jnp.asarray(counts)))
        else:
            env, _ = bandit_env.make_synthetic_env(
                jax.random.PRNGKey(1), n_users=args.users, d=args.d,
                n_clusters=max(2, args.users // 16), n_candidates=args.k)
            theta = env.theta
        exp, rep = experiments.run_experiment(
            exp, theta, args.rounds, spec=spec, batch=args.batch,
            key=args.seed)

    print_report(rep, exp.names)


def _catalog_rewards(theta, key, uids, contexts, choice):
    return bandit_env.step_rewards(key, theta[uids], contexts, choice)


if __name__ == "__main__":
    main()
