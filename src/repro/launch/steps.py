"""Cell -> (step_fn, abstract args, shardings) builders for every family.

``build_cell(arch_id, shape, mesh)`` returns a ``CellBundle`` the dry-run
lowers and the train/serve drivers execute.  Everything is built
abstractly (``jax.eval_shape``) — no parameter allocation happens here, so
the 400B-parameter cells cost nothing until real training runs on real
hardware.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .. import configs
from ..core.types import BanditHyper
from ..distributed import decode_shard, distclub_shard, sharding
from ..models import gnn, transformer
from ..models.recsys import dcn_v2, mind, seqrec
from ..train import optimizer
from . import mesh as mesh_lib

SDS = jax.ShapeDtypeStruct


@dataclasses.dataclass
class CellBundle:
    arch_id: str
    shape: str
    kind: str
    step_fn: Callable            # positional args
    abstract_args: tuple         # ShapeDtypeStructs / pytrees thereof
    in_shardings: tuple
    out_shardings: Any           # None -> let GSPMD choose
    donate_argnums: tuple = ()
    note: str = ""
    prejit: bool = False         # step_fn is already jit'd with shardings


def _shard_tree(mesh: Mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def _abstract(fn, *args):
    return jax.eval_shape(fn, *args)


# --- LM family -------------------------------------------------------------------


def build_lm_cell(spec, shape: str, mesh: Mesh,
                  kv_quant: bool = False) -> CellBundle:
    cfg = spec.cell_cfg(shape)
    cell = spec.shapes[shape]
    inputs = spec.input_specs(shape)
    ba = mesh_lib.batch_axes(mesh)
    p_specs = transformer.lm_specs(cfg)
    params_abs = _abstract(partial(transformer.init_lm, cfg=cfg),
                           SDS((2,), jnp.uint32))

    if cell.kind == "train":
        # ZeRO: moments + grad accumulator fully sharded (params stay in
        # their TP/EP layout; "data" is added on a replicated dim).
        data_size = mesh.shape["data"]
        z_specs = sharding.zero_specs(p_specs, params_abs, data_size)
        use_adafactor = cfg.param_count() > 100e9

        if use_adafactor:
            opt_init = partial(optimizer.adafactor_init,
                               momentum_dtype=jnp.bfloat16)
            opt_update = optimizer.adafactor_update
        else:
            opt_init = partial(optimizer.adamw_init,
                               moment_dtype=jnp.float32)
            opt_update = partial(optimizer.adamw_update, lr=3e-4)
        opt_abs = _abstract(opt_init, params_abs)
        mb = cfg.microbatches
        B = inputs["tokens"].shape[0]
        assert B % mb == 0

        def step(params, opt, tokens, labels):
            # keep the *batch* dim data-sharded after the microbatch split
            # (otherwise GSPMD shards the microbatch axis and every
            # microbatch runs fully replicated)
            mb_sh = NamedSharding(mesh, P(None, ba, None))
            tb = jax.lax.with_sharding_constraint(
                tokens.reshape(mb, B // mb, -1), mb_sh)
            lb = jax.lax.with_sharding_constraint(
                labels.reshape(mb, B // mb, -1), mb_sh)

            def mb_body(g_acc, xs):
                t, l = xs
                loss, grads = jax.value_and_grad(transformer.lm_loss)(
                    params, cfg, t, l)
                g_acc = jax.tree.map(
                    lambda a, g: a + g.astype(a.dtype), g_acc, grads)
                g_acc = jax.lax.with_sharding_constraint(
                    g_acc, _shard_tree(mesh, z_specs))
                return g_acc, loss

            acc_dt = jnp.bfloat16 if use_adafactor else jnp.float32
            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, acc_dt), params)
            g0 = jax.lax.with_sharding_constraint(
                g0, _shard_tree(mesh, z_specs))
            g_acc, losses = jax.lax.scan(mb_body, g0, (tb, lb))
            g_avg = jax.tree.map(lambda g: g / mb, g_acc)
            params, opt = opt_update(g_avg, opt, params)
            return params, opt, jnp.mean(losses)

        p_sh = _shard_tree(mesh, p_specs)
        z_sh = _shard_tree(mesh, z_specs)

        # per-leaf moment shardings follow the ZeRO param layout where the
        # moment has the same rank, else replicate (adafactor factors)
        def opt_shardings(opt_tree):
            flat_p, _ = jax.tree.flatten(params_abs)
            flat_zs, _ = jax.tree.flatten(z_sh)
            by_shape = {}
            for p, s in zip(flat_p, flat_zs):
                by_shape.setdefault(p.shape, s)

            def pick(leaf):
                return by_shape.get(leaf.shape, NamedSharding(mesh, P()))

            return jax.tree.map(pick, opt_tree)

        opt_sh = opt_shardings(opt_abs)
        tok_sh = NamedSharding(mesh, P(ba, None))
        return CellBundle(
            spec.arch_id, shape, "train", step,
            (params_abs, opt_abs, inputs["tokens"], inputs["labels"]),
            (p_sh, opt_sh, tok_sh, tok_sh),
            None, donate_argnums=(0, 1), note=cell.note,
        )

    if cell.kind == "serve":            # prefill
        def step(params, tokens):
            return transformer.lm_prefill(params, cfg, tokens)

        # llama4-class: weights/16 exceed HBM -> keep the training (data-
        # sharded) layout for prefill; gathers amortize over 32k tokens.
        fshard = cfg.param_count() * 2 / mesh.shape["model"] > 8e9
        p_sh = _shard_tree(
            mesh, decode_shard.lm_specs_fshard(cfg) if fshard
            else decode_shard.decode_param_specs(cfg))
        tok_sh = NamedSharding(mesh, P(ba, None))
        cache_sh = NamedSharding(mesh, decode_shard.cache_spec(ba))
        out_sh = (NamedSharding(mesh, P(ba, "model")), (cache_sh, cache_sh))
        return CellBundle(
            spec.arch_id, shape, "serve", step,
            (params_abs, inputs["tokens"]), (p_sh, tok_sh), out_sh,
            note=cell.note,
        )

    # decode: shard_map flash-decoding + TP (already jit'd with shardings)
    batch = inputs["token"].shape[0]
    s_max = inputs["k_cache"].shape[4]
    step_jit, p_sh, cache_sh = decode_shard.build_decode_step(
        mesh, cfg, batch, s_max, kv_quant=kv_quant)
    if kv_quant:
        kq = jax.ShapeDtypeStruct(inputs["k_cache"].shape, jnp.int8)
        sc = jax.ShapeDtypeStruct(inputs["k_cache"].shape[:-1], jnp.float32)
        caches = (kq, kq, sc, sc)
    else:
        caches = (inputs["k_cache"], inputs["v_cache"])
    return CellBundle(
        spec.arch_id, shape, "decode", step_jit,
        (params_abs, inputs["token"], caches, inputs["pos"]),
        (), None, note=cell.note, prejit=True,
    )


# --- GNN family ------------------------------------------------------------------


def build_gnn_cell(spec, shape: str, mesh: Mesh) -> CellBundle:
    """GNN train step: explicit shard_map (GSPMD replicates scatters).

    Layout contract: nodes row-sharded over every mesh axis; edges
    partitioned by destination block (dst in the local node shard) — the
    production graph-partitioning layout, making segment reductions local.
    """
    from jax.experimental.shard_map import shard_map

    cfg = spec.cell_cfg(shape)
    cell = spec.shapes[shape]
    inputs = spec.input_specs(shape)
    axes = tuple(mesh.axis_names)
    params_abs = _abstract(partial(gnn.init_gat, cfg=cfg), SDS((2,), jnp.uint32))
    opt_abs = _abstract(optimizer.adamw_init, params_abs)

    def local_step(params, opt, feats, src, dst, labels, mask):
        def loss_fn(p):
            return gnn.gat_loss_local(p, cfg, feats, src, dst, labels, mask,
                                      axes)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        grads = jax.lax.pmean(grads, axes)
        params, opt = optimizer.adamw_update(grads, opt, params, lr=5e-3)
        return params, opt, loss

    n_spec = P(axes)
    sharded = shard_map(
        local_step, mesh=mesh,
        in_specs=(P(), P(), P(axes, None), n_spec, n_spec, n_spec, n_spec),
        out_specs=(P(), P(), P()),
        check_rep=False,
    )
    p_sh = _shard_tree(mesh, jax.tree.map(lambda _: P(), params_abs))
    opt_sh = optimizer.AdamWState(
        step=NamedSharding(mesh, P()), m=p_sh, v=p_sh)
    node_sh = NamedSharding(mesh, P(axes))
    node2_sh = NamedSharding(mesh, P(axes, None))
    return CellBundle(
        spec.arch_id, shape, "train", sharded,
        (params_abs, opt_abs, inputs["feats"], inputs["src"], inputs["dst"],
         inputs["labels"], inputs["mask"]),
        (p_sh, opt_sh, node2_sh, node_sh, node_sh, node_sh, node_sh),
        None, donate_argnums=(0, 1), note=cell.note,
    )


# --- recsys family -----------------------------------------------------------


def build_recsys_cell(spec, shape: str, mesh: Mesh) -> CellBundle:
    cfg = spec.cell_cfg(shape)
    cell = spec.shapes[shape]
    inputs = spec.input_specs(shape)
    ba = mesh_lib.batch_axes(mesh)
    arch = spec.arch_id

    if arch in ("sasrec", "bert4rec"):
        init, p_specs = seqrec.init_seqrec, seqrec.seqrec_specs(cfg)
        loss_fn = seqrec.sampled_softmax_loss
        serve_fn, retr_fn = seqrec.score_candidates, seqrec.retrieval_scores
    elif arch == "mind":
        init, p_specs = mind.init_mind, mind.mind_specs(cfg)
        loss_fn = mind.mind_loss
        serve_fn, retr_fn = mind.mind_serve, mind.mind_retrieval
    else:                               # dcn-v2
        init, p_specs = dcn_v2.init_dcn, dcn_v2.dcn_specs(cfg)
        loss_fn = dcn_v2.dcn_loss
        serve_fn = retr_fn = None

    params_abs = _abstract(partial(init, cfg=cfg), SDS((2,), jnp.uint32))
    p_sh = _shard_tree(mesh, p_specs)

    if cell.kind == "train":
        opt_abs = _abstract(optimizer.adagrad_init, params_abs)
        opt_sh = optimizer.AdagradState(accum=p_sh)

        if arch == "dcn-v2":
            def step(params, opt, dense_feats, sparse_ids, labels):
                loss, grads = jax.value_and_grad(loss_fn)(
                    params, cfg, dense_feats, sparse_ids, labels)
                params, opt = optimizer.adagrad_update(grads, opt, params)
                return params, opt, loss
            args = (params_abs, opt_abs, inputs["dense_feats"],
                    inputs["sparse_ids"], inputs["labels"])
            shardings = (p_sh, opt_sh,
                         NamedSharding(mesh, P(ba, None)),
                         NamedSharding(mesh, P(ba, None)),
                         NamedSharding(mesh, P(ba)))
        else:
            # §Perf: 65536-row batches through a 200-token tower peak at
            # multi-GiB attention transients; microbatch with f32 grad
            # accumulation (identical math; one optimizer step).
            B = inputs["hist"].shape[0]
            mb = 8 if B >= 65_536 else 1

            def step(params, opt, hist, targets, key):
                if mb == 1:
                    loss, grads = jax.value_and_grad(loss_fn)(
                        params, cfg, hist, targets, key)
                else:
                    hb = jax.lax.with_sharding_constraint(
                        hist.reshape(mb, B // mb, -1),
                        NamedSharding(mesh, P(None, ba, None)))
                    tb = targets.reshape((mb, B // mb) + targets.shape[1:])

                    def mb_body(acc, xs):
                        h, t = xs
                        l, g = jax.value_and_grad(loss_fn)(
                            params, cfg, h, t, key)
                        return jax.tree.map(
                            lambda a, gg: a + gg.astype(a.dtype), acc, g), l

                    g0 = jax.tree.map(
                        lambda p: jnp.zeros(p.shape, jnp.float32), params)
                    grads, losses = jax.lax.scan(mb_body, g0, (hb, tb))
                    grads = jax.tree.map(lambda g: g / mb, grads)
                    loss = jnp.mean(losses)
                params, opt = optimizer.adagrad_update(grads, opt, params)
                return params, opt, loss

            t_sh = (NamedSharding(mesh, P(ba, None))
                    if inputs["targets"].ndim == 2
                    else NamedSharding(mesh, P(ba)))
            args = (params_abs, opt_abs, inputs["hist"], inputs["targets"],
                    inputs["key"])
            shardings = (p_sh, opt_sh, NamedSharding(mesh, P(ba, None)), t_sh,
                         NamedSharding(mesh, P()))
        return CellBundle(spec.arch_id, shape, "train", step, args, shardings,
                          None, donate_argnums=(0, 1), note=cell.note)

    # serve cells
    if arch == "dcn-v2":
        def step(params, dense_feats, sparse_ids):
            return dcn_v2.dcn_fwd(params, cfg, dense_feats, sparse_ids)
        args = (params_abs, inputs["dense_feats"], inputs["sparse_ids"])
        shardings = (p_sh, NamedSharding(mesh, P(ba, None)),
                     NamedSharding(mesh, P(ba, None)))
    elif shape == "retrieval_cand":
        def step(params, hist, cand):
            return retr_fn(params, cfg, hist, cand)
        args = (params_abs, inputs["hist"], inputs["cand"])
        # one query replicated; the 10^6-candidate slab shards over
        # every axis (batched dot, per the assignment)
        shardings = (p_sh, NamedSharding(mesh, P(None, None)),
                     NamedSharding(mesh, P(tuple(mesh.axis_names))))
    else:
        # §Perf (serve_bulk): scoring 262144 users x 1000 candidates in one
        # shot peaks at [B, C, d] gathered-candidate tensors; chunking the
        # batch through lax.map bounds the transient at one chunk (the
        # request stream is embarrassingly parallel).
        B = inputs["hist"].shape[0]
        chunk = 16_384
        if B > chunk:
            n_chunks = B // chunk

            def step(params, hist, cand):
                hb = hist.reshape(n_chunks, chunk, -1)
                cb = cand.reshape(n_chunks, chunk, -1)
                hb = jax.lax.with_sharding_constraint(
                    hb, NamedSharding(mesh, P(None, ba, None)))
                cb = jax.lax.with_sharding_constraint(
                    cb, NamedSharding(mesh, P(None, ba, None)))
                out = jax.lax.map(
                    lambda xs: serve_fn(params, cfg, xs[0], xs[1]), (hb, cb))
                return out.reshape(B, -1)
        else:
            def step(params, hist, cand):
                return serve_fn(params, cfg, hist, cand)
        args = (params_abs, inputs["hist"], inputs["cand"])
        shardings = (p_sh, NamedSharding(mesh, P(ba, None)),
                     NamedSharding(mesh, P(ba, None)))
    return CellBundle(spec.arch_id, shape, "serve", step, args, shardings,
                      None, note=cell.note)


# --- bandit (the paper's own cell) ---------------------------------------------


def build_bandit_cell(spec, shape: str, mesh: Mesh) -> CellBundle:
    from ..configs import distclub_paper as dp

    hyper: BanditHyper = spec.cfg
    axes = tuple(mesh.axis_names)
    epoch = distclub_shard.build_epoch_fn(mesh, axes, dp.N_USERS, dp.D_FEAT,
                                          hyper)
    specs = distclub_shard.state_specs(axes)
    inputs = spec.input_specs(shape)
    state_abs = distclub_shard.ShardedDistCLUB(
        **{k: v for k, v in inputs.items() if k != "key"})
    state_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                            is_leaf=lambda x: isinstance(x, P))

    return CellBundle(
        spec.arch_id, shape, "bandit_epoch", epoch,
        (state_abs, inputs["key"]),
        (state_sh, NamedSharding(mesh, P())),
        None, donate_argnums=(0,), note=spec.shapes[shape].note,
    )


# --- dispatcher ------------------------------------------------------------------

_BUILDERS = {
    "lm": build_lm_cell,
    "gnn": build_gnn_cell,
    "recsys": build_recsys_cell,
    "bandit": build_bandit_cell,
}


def build_cell(arch_id: str, shape: str, mesh: Mesh,
               kv_quant: bool = False) -> CellBundle:
    spec = configs.get(arch_id)
    if spec.family == "lm":
        return build_lm_cell(spec, shape, mesh, kv_quant=kv_quant)
    return _BUILDERS[spec.family](spec, shape, mesh)
