"""Training driver: ``python -m repro.launch.train --arch <id> [options]``.

Runs real steps (reduced configs on CPU; assigned configs on a TPU mesh)
with checkpoint/resume — kill it mid-run and it continues from the last
atomic checkpoint.  The dry-run path (``--dryrun``) lowers/compiles only.

Examples:
    python -m repro.launch.train --arch qwen3-4b --reduce --steps 50
    python -m repro.launch.train --arch sasrec --reduce --steps 100
    python -m repro.launch.train --arch distclub-paper --reduce --steps 20
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from .. import configs
from ..core.types import BanditHyper
from ..train import optimizer
from ..train.checkpoint import CheckpointManager


def _reduced_cfg(spec):
    if spec.family == "lm":
        return dataclasses.replace(
            spec.cfg, n_layers=2 * spec.cfg.block_layers, d_model=128,
            n_heads=4, n_kv_heads=min(4, spec.cfg.n_kv_heads), d_head=32,
            d_ff=256, vocab=2048,
            n_experts=min(8, spec.cfg.n_experts),
            d_ff_expert=128 if spec.cfg.is_moe else 0,
            top_k=min(2, spec.cfg.top_k), dtype=jnp.float32,
            attn_chunk=128, microbatches=1)
    if spec.family == "recsys":
        return dataclasses.replace(spec.cfg, n_items=4096)
    if spec.family == "gnn":
        return dataclasses.replace(spec.cfg, d_feat=64, n_classes=7)
    return spec.cfg


def train_lm(spec, args):
    from ..models import transformer as tr

    cfg = _reduced_cfg(spec) if args.reduce else spec.cfg
    key = jax.random.PRNGKey(args.seed)
    params = tr.init_lm(key, cfg)
    opt = optimizer.adamw_init(params)
    mgr = CheckpointManager(args.ckpt_dir or f"/tmp/repro_train_{spec.arch_id}",
                            keep=2)

    restored, start = mgr.restore_latest(
        jax.eval_shape(lambda: (params, opt)))
    if restored is not None:
        params, opt = restored
        print(f"resumed from checkpoint step {start}")
    else:
        start = 0

    @jax.jit
    def step(params, opt, tokens):
        loss, grads = jax.value_and_grad(tr.lm_loss)(
            params, cfg, tokens[:, :-1], tokens[:, 1:])
        params, opt = optimizer.adamw_update(grads, opt, params, lr=3e-4)
        return params, opt, loss

    B, S = args.batch, args.seq
    # learnable synthetic stream: zipfian unigram (entropy << log V), so the
    # loss visibly falls from log(V) toward the unigram entropy
    data_logits = -1.5 * jnp.log(jnp.arange(1, cfg.vocab + 1, dtype=jnp.float32))
    for i in range(start, args.steps):
        k = jax.random.fold_in(key, i)
        tokens = jax.random.categorical(k, data_logits, shape=(B, S + 1))
        t0 = time.perf_counter()
        params, opt, loss = step(params, opt, tokens)
        loss = float(loss)
        if i % args.log_every == 0:
            print(f"step {i:5d}  loss {loss:.4f}  "
                  f"{time.perf_counter() - t0:.2f}s")
        if (i + 1) % args.ckpt_every == 0:
            mgr.save((params, opt), i + 1)
    print("done; final loss", loss)


def train_bandit(spec, args):
    from ..core import distclub, env, env_ops

    hyper: BanditHyper = spec.cfg
    n, d = (2048, 25) if args.reduce else (20480, 25)
    e, _ = env.make_synthetic_env(jax.random.PRNGKey(0), n, d, 50,
                                  hyper.n_candidates)
    ops = env_ops.synthetic_ops(e)
    state, metrics, nclu = distclub.run(
        ops, jax.random.PRNGKey(args.seed), hyper, n_epochs=args.steps, d=d)
    T = int(metrics.interactions.sum())
    print(f"{T} interactions, reward/random = "
          f"{float(metrics.reward.sum()) / float(metrics.rand_reward.sum()):.3f}, "
          f"clusters {nclu.tolist()[-5:]}")


def train_recsys(spec, args):
    from ..models.recsys import dcn_v2, mind, seqrec

    cfg = _reduced_cfg(spec) if args.reduce else spec.cfg
    key = jax.random.PRNGKey(args.seed)
    if spec.arch_id == "dcn-v2":
        params = dcn_v2.init_dcn(key, cfg)
        opt = optimizer.adagrad_init(params)

        @jax.jit
        def step(params, opt, k):
            dense = jax.random.normal(k, (args.batch, cfg.n_dense))
            sparse = jax.random.randint(k, (args.batch, cfg.n_sparse), 0,
                                        cfg.vocab_per_field)
            labels = jax.random.bernoulli(k, 0.3, (args.batch,)).astype(
                jnp.float32)
            loss, g = jax.value_and_grad(dcn_v2.dcn_loss)(
                params, cfg, dense, sparse, labels)
            params, opt = optimizer.adagrad_update(g, opt, params)
            return params, opt, loss
    else:
        init, loss_fn = ((mind.init_mind, mind.mind_loss)
                         if spec.arch_id == "mind"
                         else (seqrec.init_seqrec,
                               seqrec.sampled_softmax_loss))
        params = init(key, cfg)
        opt = optimizer.adagrad_init(params)

        @jax.jit
        def step(params, opt, k):
            hist = jax.random.randint(k, (args.batch, cfg.seq_len), 1,
                                      cfg.n_items)
            tgt = (hist if spec.arch_id != "mind"
                   else jax.random.randint(k, (args.batch,), 1, cfg.n_items))
            loss, g = jax.value_and_grad(loss_fn)(params, cfg, hist, tgt, k)
            params, opt = optimizer.adagrad_update(g, opt, params)
            return params, opt, loss

    for i in range(args.steps):
        params, opt, loss = step(params, opt, jax.random.fold_in(key, i))
        if i % args.log_every == 0:
            print(f"step {i:5d}  loss {float(loss):.4f}")
    print("done; final loss", float(loss))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--reduce", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--ckpt-dir")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--log-every", type=int, default=5)
    args = ap.parse_args()

    spec = configs.get(args.arch)
    if spec.family == "lm":
        train_lm(spec, args)
    elif spec.family == "bandit":
        train_bandit(spec, args)
    elif spec.family == "recsys":
        train_recsys(spec, args)
    else:
        raise SystemExit("use tests/benchmarks for the GNN training path")


if __name__ == "__main__":
    main()
