"""Production meshes + spec-resolution helpers.

``make_production_mesh`` is a FUNCTION (not module state) so importing
this module never touches jax device state — the dry-run entry point sets
XLA_FLAGS before any jax import and only then builds meshes.

Mesh shapes (TPU v5e pods):
    single pod : (16, 16)     axes ("data", "model")   = 256 chips
    multi pod  : (2, 16, 16)  axes ("pod", "data", "model") = 512 chips

Model-family sharding conventions (DESIGN.md §6): PartitionSpecs in the
model code name the logical axes "data" / "model"; batch-like dims shard
over ("pod", "data") on the multi-pod mesh via ``batch_axes``.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def batch_axes(mesh: Mesh):
    """Axes a batch/user dim shards over (pure DP across pods)."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def all_axes(mesh: Mesh):
    return tuple(mesh.axis_names)


def resolve(mesh: Mesh, spec: P) -> NamedSharding:
    """Map a logical PartitionSpec onto this mesh.

    Rule: the logical "data" entry becomes ("pod", "data") on a multi-pod
    mesh when it shards a *batch-like* dim; weight specs keep plain "data"
    (ZeRO sharding stays intra-pod: cross-pod is pure DP so gradients
    all-reduce over "pod" but weights are not gathered across pods).
    """
    return NamedSharding(mesh, spec)


def batch_spec(mesh: Mesh, rank: int, sharded_dim: int = 0) -> P:
    entries = [None] * rank
    entries[sharded_dim] = batch_axes(mesh)
    return P(*entries)


# TPU v5e roofline constants (per chip)
PEAK_FLOPS_BF16 = 197e12      # FLOP/s
HBM_BW = 819e9                # B/s
ICI_BW = 4.5e10               # B/s per link (~50 GB/s, 1 direction)
HBM_BYTES = 16 * 1024 ** 3    # 16 GiB
