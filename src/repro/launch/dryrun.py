"""Multi-pod dry-run: lower + compile every (arch x shape) on the
production meshes and record memory/cost/collective analysis.

MUST set the host-device override before any jax import (jax locks the
device count at first init) — hence the first two lines.

Usage:
    python -m repro.launch.dryrun --arch llama3-8b --shape train_4k
    python -m repro.launch.dryrun --all [--multi-pod] [--filter lm]
Results: results/dryrun/<arch>__<shape>__<pod|single>.json
"""
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

import argparse      # noqa: E402
import json          # noqa: E402
import pathlib       # noqa: E402
import re            # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402

RESULTS = pathlib.Path(__file__).resolve().parents[3] / "results" / "dryrun"

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s+(\S+?)\[([\d,]*)\][^ ]*\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\("
)
_TUPLE_COLL_RE = re.compile(
    r"=\s+\(([^)]*)\)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\("
)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def parse_collectives(hlo: str) -> dict:
    """Per-op-kind byte totals from the partitioned HLO (per device).

    Model: bytes moved per device ~ output size for gather/scatter/permute
    style ops, 2x for all-reduce (reduce + broadcast phases of a ring).
    """
    out = {}
    for line in hlo.splitlines():
        m = _COLL_RE.search(line) or _TUPLE_COLL_RE.search(line)
        if not m:
            continue
        if m.re is _COLL_RE:
            dtype, dims, kind = m.group(1), m.group(2), m.group(3)
            nbytes = _shape_bytes(dtype, dims)
        else:
            inner, kind = m.group(1), m.group(2)
            nbytes = sum(
                _shape_bytes(t, d) for t, d in _SHAPE_RE.findall(inner)
            )
        if kind == "all-reduce":
            nbytes *= 2
        out[kind] = out.get(kind, 0) + nbytes
        out["total"] = out.get("total", 0) + nbytes
    return out


def run_cell(arch: str, shape: str, multi_pod: bool,
             kv_quant: bool = False) -> dict:
    from .. import configs  # noqa: F401  (registers archs)
    from . import mesh as mesh_lib, steps

    from ..distributed import sharding as _sh

    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    with _sh.hint_mesh(mesh):
        bundle = steps.build_cell(arch, shape, mesh, kv_quant=kv_quant)
    if bundle.prejit:
        jitted = bundle.step_fn
    else:
        kwargs = {}
        if bundle.out_shardings is not None:
            kwargs["out_shardings"] = bundle.out_shardings
        jitted = jax.jit(
            bundle.step_fn, in_shardings=bundle.in_shardings,
            donate_argnums=bundle.donate_argnums, **kwargs,
        )
    with _sh.hint_mesh(mesh):
        lowered = jitted.lower(*bundle.abstract_args)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    colls = parse_collectives(compiled.as_text())

    rec = {
        "arch": arch,
        "shape": shape,
        "mesh": list(mesh.shape.values()),
        "axes": list(mesh.axis_names),
        "multi_pod": multi_pod,
        "kind": bundle.kind,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "flops_per_device": cost.get("flops", 0.0) if cost else None,
        "bytes_per_device": cost.get("bytes accessed", 0.0) if cost else None,
        "collective_bytes_per_device": colls,
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_bytes": getattr(
                mem, "generated_code_size_in_bytes", None),
            "alias_bytes": getattr(mem, "alias_size_in_bytes", None),
        },
    }
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--filter", default="",
                    help="substring filter on arch id")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--kv-quant", action="store_true",
                    help="int8 KV-cache variant for LM decode cells")
    args = ap.parse_args()

    from .. import configs

    if args.all:
        cells = [(a, s) for a, s in configs.all_cells()
                 if args.filter in a]
    else:
        cells = [(args.arch, args.shape)]

    RESULTS.mkdir(parents=True, exist_ok=True)
    tag = "pod2" if args.multi_pod else "pod1"
    if args.kv_quant:
        tag += "_kvq"
    failures = []
    for arch, shape in cells:
        out = RESULTS / f"{arch}__{shape}__{tag}.json"
        if out.exists() and not args.force:
            print(f"[skip] {arch} x {shape} ({tag}) — cached")
            continue
        print(f"[dryrun] {arch} x {shape} ({tag}) ...", flush=True)
        try:
            rec = run_cell(arch, shape, args.multi_pod,
                           kv_quant=args.kv_quant)
            out.write_text(json.dumps(rec, indent=1))
            mem = rec["memory"]
            print(
                f"  ok: compile {rec['compile_s']}s, "
                f"flops/dev {rec['flops_per_device']:.3g}, "
                f"args/dev {(mem['argument_bytes'] or 0)/2**30:.2f} GiB, "
                f"temp/dev {(mem['temp_bytes'] or 0)/2**30:.2f} GiB, "
                f"coll/dev {rec['collective_bytes_per_device'].get('total', 0)/2**20:.1f} MiB",
                flush=True,
            )
        except Exception as e:  # noqa: BLE001 — record and continue
            failures.append((arch, shape, repr(e)))
            print(f"  FAIL: {e}\n{traceback.format_exc()}", flush=True)
    if failures:
        print(f"\n{len(failures)} failures:")
        for a, s, e in failures:
            print(f"  {a} x {s}: {e[:200]}")
        raise SystemExit(1)
    print("\nall cells compiled.")


if __name__ == "__main__":
    main()
