"""Serving driver: ``python -m repro.launch.serve --arch <id>``.

Runs batched online recommendation with a policy-pluggable
``OnlineBandit`` session over a recsys model's embeddings (reduced scale
on CPU) — ``--policy {distclub,dccb,club,linucb}`` serves any of the four
bandits through the identical transaction — reporting reward vs the
random policy and throughput.  For LM archs it runs reduced-config decode
steps against a KV cache.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from .. import configs


def serve_recsys(spec, args):
    from .. import serve
    from ..core import env as bandit_env
    from ..core.types import BanditHyper
    from ..models.recsys import seqrec

    d, K = 32, 20
    cfg = seqrec.SeqRecConfig(n_items=4096, embed_dim=d, n_blocks=2,
                              n_heads=2, seq_len=16)
    model = seqrec.init_seqrec(jax.random.PRNGKey(0), cfg)
    world, _ = bandit_env.make_synthetic_env(
        jax.random.PRNGKey(1), n_users=args.users, d=d, n_clusters=8,
        n_candidates=K)
    hyper = BanditHyper(alpha=0.05, gamma=2.4, n_candidates=K)
    session = serve.OnlineBandit.create(
        args.users, d, hyper, policy=args.policy,
        refresh_every=args.users * 4)
    theta = world.theta

    def reward_fn(key, user_ids, contexts, choice):
        return bandit_env.step_rewards(key, theta[user_ids], contexts,
                                       choice)

    key = jax.random.PRNGKey(2)
    tot_r = tot_rand = 0.0
    t0 = time.perf_counter()
    for step in range(args.steps):
        k_u, k_c, k_s, key = jax.random.split(key, 4)
        users = jax.random.permutation(k_u, args.users)[:args.batch]
        cand = jax.random.randint(k_c, (args.batch, K), 0, cfg.n_items)
        ctx = serve.embed_candidates(model["item_embed"], cand)
        session, choice, m = serve.step(session, k_s, users, ctx, reward_fn)
        tot_r += float(m.reward)
        tot_rand += float(m.rand_reward)
    dt = time.perf_counter() - t0
    n = args.steps * args.batch
    print(f"[{args.policy}] {n} requests in {dt:.1f}s = {n / dt:.0f} req/s; "
          f"reward/random = {tot_r / tot_rand:.3f}")


def serve_lm(spec, args):
    from ..models import transformer as tr

    cfg = dataclasses.replace(
        spec.cfg, n_layers=2 * spec.cfg.block_layers, d_model=128, n_heads=4,
        n_kv_heads=min(4, spec.cfg.n_kv_heads), d_head=32, d_ff=256,
        vocab=2048, n_experts=min(8, spec.cfg.n_experts),
        d_ff_expert=128 if spec.cfg.is_moe else 0,
        top_k=min(2, spec.cfg.top_k), dtype=jnp.float32, attn_chunk=128)
    params = tr.init_lm(jax.random.PRNGKey(0), cfg)
    B, S = args.batch, 128
    prompt = jax.random.randint(jax.random.PRNGKey(1), (B, 16), 0, cfg.vocab)
    _, cache = tr.lm_prefill(params, cfg, prompt)
    kc = jnp.pad(cache[0], ((0, 0),) * 4 + ((0, S - 16), (0, 0)))
    vc = jnp.pad(cache[1], ((0, 0),) * 4 + ((0, S - 16), (0, 0)))

    decode = jax.jit(lambda p, t, c, pos: tr.lm_decode_step(p, cfg, t, c, pos))
    tok = prompt[:, -1]
    t0 = time.perf_counter()
    for pos in range(16, 16 + args.steps):
        logits, (kc, vc) = decode(params, tok, (kc, vc), jnp.int32(pos))
        tok = jnp.argmax(logits, -1)
    jax.block_until_ready(tok)
    dt = time.perf_counter() - t0
    print(f"decoded {args.steps} tokens x {B} seqs in {dt:.1f}s = "
          f"{args.steps * B / dt:.0f} tok/s (reduced config)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="sasrec")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--users", type=int, default=256)
    ap.add_argument("--policy", default="distclub",
                    choices=["distclub", "dccb", "club", "linucb"],
                    help="serving policy (recsys archs)")
    args = ap.parse_args()
    spec = configs.get(args.arch)
    if spec.family == "lm":
        serve_lm(spec, args)
    else:
        serve_recsys(spec, args)


if __name__ == "__main__":
    main()
