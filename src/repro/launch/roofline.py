"""Roofline analysis over the dry-run artifacts.

Three terms per (arch x shape x mesh), in seconds per step:

    compute    = FLOPs            / (chips x 197e12 bf16 FLOP/s)
    memory     = HBM bytes        / (chips x 819e9  B/s)
    collective = collective bytes / (chips x ~50e9  B/s ICI)

Numerator sources — and why there are two columns for each:
  * ``hlo_*``: ``compiled.cost_analysis()`` + collective ops parsed from the
    partitioned HLO.  CAVEAT (measured, see EXPERIMENTS.md): XLA's cost
    analysis counts a while/scan BODY ONCE, ignoring trip count, so scanned
    programs (layer stacks, microbatches, KV chunks) under-report; HLO text
    likewise shows in-loop collectives once.
  * ``ana_*``: analytic workload model (exact matmul/byte counts from the
    config — the numbers a roofline is normally built from).  These are the
    numbers the §Perf loop optimizes.

MODEL_FLOPS = 6 N D (dense train) / 6 N_active D (MoE) / 2 N D (forward
only) — the "useful compute" yardstick; ana_flops/MODEL_FLOPS shows
remat/attention/dispatch overhead.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib

from . import mesh as mesh_lib
from .. import configs

RESULTS = pathlib.Path(__file__).resolve().parents[3] / "results" / "dryrun"

PEAK = mesh_lib.PEAK_FLOPS_BF16
HBM = mesh_lib.HBM_BW
ICI = mesh_lib.ICI_BW


@dataclasses.dataclass
class Terms:
    arch: str
    shape: str
    mesh: str
    chips: int
    kind: str
    model_flops: float          # global, per step
    ana_flops: float            # global, per step
    ana_hbm_bytes: float        # global, per step
    ana_coll_bytes: float       # per device, per step
    hlo_flops: float            # per device (scan bodies once)
    hlo_bytes: float
    hlo_coll_bytes: float
    mem_args_gib: float
    mem_temp_gib: float

    @property
    def t_compute(self):
        return self.ana_flops / (self.chips * PEAK)

    @property
    def t_memory(self):
        return self.ana_hbm_bytes / (self.chips * HBM)

    @property
    def t_collective(self):
        return self.ana_coll_bytes / ICI

    @property
    def bottleneck(self):
        ts = {"compute": self.t_compute, "memory": self.t_memory,
              "collective": self.t_collective}
        return max(ts, key=ts.get)

    @property
    def roofline_fraction(self):
        """useful-compute time / bottleneck time (1.0 = at the roofline)."""
        t_useful = self.model_flops / (self.chips * PEAK)
        t_bound = max(self.t_compute, self.t_memory, self.t_collective)
        return t_useful / t_bound if t_bound else 0.0


# ---- analytic workload models ---------------------------------------------------


def _lm_flops_bytes(cfg, shape: str, chips: int, multi_pod: bool):
    """(model_flops, ana_flops, hbm_bytes, coll_bytes_per_dev) per step."""
    p_all = cfg.param_count()
    p_act = cfg.active_param_count()
    tp = 16
    dp = chips // tp

    def attn_flops(batch, s_q, s_kv):
        # scores + pv per layer
        return cfg.n_layers * batch * 2 * 2 * cfg.n_heads * s_q * s_kv * cfg.d_head

    if shape == "train_4k":
        tokens = 256 * 4096
        mm = 6 * p_act * tokens                     # fwd+bwd matmuls
        remat = 2 * p_act * tokens                  # recompute fwd once
        att = 3 * attn_flops(256, 4096, 4096) / 2   # causal halves scores
        model = 6 * p_act * tokens
        ana = mm + remat + att * (1 + 0.5)          # attn recomputed too
        # HBM: weights read per microbatch (gathered) + moments + acts
        w_bytes = 2 * p_all
        hbm = (cfg.microbatches * w_bytes            # fwd+bwd weight reads
               + 3 * w_bytes                         # grads + opt read/write
               + tokens * cfg.d_model * 2 * cfg.n_layers * 3)
        # collectives per device: ZeRO gather weights per mb + grad RS + TP
        coll = (cfg.microbatches * 2 * p_all / chips * 2    # w allgather
                + 2 * 2 * p_all / chips                     # grad reduce
                + tokens // dp * cfg.d_model * 2 * cfg.n_layers * 2 / 4)
        return model, ana, hbm, coll
    if shape == "prefill_32k":
        tokens = 32 * 32768
        model = 2 * p_act * tokens
        ana = model + attn_flops(32, 32768, 32768) / 2
        hbm = 2 * p_all + tokens * cfg.d_model * 2 * cfg.n_layers * 2
        coll = 2 * p_all / chips * 2 + tokens // dp * cfg.d_model * 2 * cfg.n_layers / 2
        return model, ana, hbm, coll
    # decode shapes
    batch, s = (128, 32768) if shape == "decode_32k" else (1, 524288)
    model = 2 * p_act * batch
    ana = model + attn_flops(batch, 1, s)
    cache = cfg.n_layers * 2 * batch * cfg.n_kv_heads * s * cfg.d_head * 2
    hbm = 2 * p_all + cache
    coll = (batch * cfg.d_model * 2 * cfg.n_layers * 3    # TP gathers/psum
            + batch * cfg.n_heads * cfg.d_head * 4 * 16   # flash merge
            ) / min(chips, 256)
    return model, ana, hbm, coll


def _gnn_flops_bytes(cfg, shape, chips, dims):
    n, e, f, c = dims
    h = cfg.n_heads * cfg.d_hidden
    # layer1: n*f*h matmul + edge ops; layer2: n*h*(heads*c)
    mm = 2 * n * f * h + 2 * n * h * cfg.n_heads * c
    edge = e * (cfg.n_heads * (2 * cfg.d_hidden + 6) + 2 * cfg.n_heads * cfg.d_hidden)
    model = mm + edge
    ana = 3 * model                                   # fwd+bwd
    hbm = 4 * (n * f + 2 * e + n * h) * 3
    # per-device all_gather output of node features, both layers' widths
    # (layer1 = n_heads*d_hidden, layer2 = n_heads*n_classes), plus the
    # (small, sharded-output) cotangent reduce-scatters.  Calibrated against
    # the parsed HLO: ogb_products 4127 MiB bf16 -> 1063 MiB int8.
    widths = h + cfg.n_heads * c
    fwd_bytes = 1.02 if getattr(cfg, "quantized_gather", False) else 2
    coll = n * widths * fwd_bytes + n * widths * 4 / chips * 4
    return model, ana, hbm, coll


def _recsys_flops_bytes(spec, cfg, shape, chips):
    arch = spec.arch_id
    from ..configs import recsys_shapes as rs

    if arch == "dcn-v2":
        d = cfg.d_interact
        per_row = 2 * (cfg.n_cross_layers * d * d
                       + 1024 * d + 1024 * 1024 + 1024 * 512 + (d + 512))
        emb_bytes_row = cfg.n_sparse * cfg.embed_dim * 4
        batch = {"train_batch": rs.TRAIN_B, "serve_p99": rs.P99_B,
                 "serve_bulk": rs.BULK_B,
                 "retrieval_cand": rs.N_CAND_RETR}[shape]
        mult = 3 if shape == "train_batch" else 1
        model = per_row * batch * mult
        hbm = batch * (emb_bytes_row + 13 * 4 + per_row and emb_bytes_row + 52) * mult
        hbm = batch * (emb_bytes_row + 52) * mult + 2 * 4 * (
            cfg.n_sparse * cfg.vocab_per_field * cfg.embed_dim) * (
            1 if shape == "train_batch" else 0) / 100   # sparse touch ~1%
        coll = batch * emb_bytes_row / chips * 2
        return model, model, hbm, coll
    # sequence models
    d = cfg.embed_dim
    L = cfg.seq_len
    blocks = getattr(cfg, "n_blocks", 2)
    per_user = blocks * (2 * L * (3 * d * d + d * d) + 2 * 2 * L * L * d
                         + 2 * L * 8 * d * d)
    if arch == "mind":
        per_user = cfg.capsule_iters * 2 * L * cfg.n_interests * d + 2 * L * d * d
    batch = {"train_batch": rs.TRAIN_B, "serve_p99": rs.P99_B,
             "serve_bulk": rs.BULK_B, "retrieval_cand": 1}[shape]
    cand = {"train_batch": 128, "serve_p99": rs.N_CAND_SERVE,
            "serve_bulk": rs.N_CAND_SERVE,
            "retrieval_cand": rs.N_CAND_RETR}[shape]
    score = 2 * batch * cand * d
    mult = 3 if shape == "train_batch" else 1
    model = (per_user * batch + score) * mult
    hbm = (batch * L * d * 4 * blocks * 3 + batch * cand * d * 4 / 8
           + score / 100) * mult
    coll = batch * cand * 4 / chips + batch * d * 4 / chips
    return model, model, hbm, coll


def _bandit_flops_bytes(hyper, chips):
    from ..configs import distclub_paper as dp

    n, d, K, R = dp.N_USERS, dp.D_FEAT, hyper.n_candidates, hyper.max_rounds
    per_i = 2 * K * d * d + 2 * K * d + 6 * d * d    # UCB + SM update
    inter = n * 2 * R * per_i
    stage2 = 2 * n * n * d + n * d ** 3              # prune + CC + inverses
    model = inter + stage2
    hbm = 2 * R * n * (3 * d * d * 4) + n * n * 1 + n * d * d * 4 * 4
    coll = (n * (d * d + d) * 4 * 2 + n * 4 * 10) / chips * 2
    return model, model, hbm, coll


def analyze(rec: dict) -> Terms:
    spec = configs.get(rec["arch"])
    shape = rec["shape"]
    chips = 1
    for s in rec["mesh"]:
        chips *= s
    cfg = spec.cell_cfg(shape)
    if spec.family == "lm":
        model, ana, hbm, coll = _lm_flops_bytes(cfg, shape, chips,
                                                rec["multi_pod"])
    elif spec.family == "gnn":
        from ..configs.gat_cora import CELL_DIMS
        model, ana, hbm, coll = _gnn_flops_bytes(cfg, shape, chips,
                                                 CELL_DIMS[shape])
    elif spec.family == "recsys":
        model, ana, hbm, coll = _recsys_flops_bytes(spec, cfg, shape, chips)
    else:
        model, ana, hbm, coll = _bandit_flops_bytes(cfg, chips)
    return Terms(
        arch=rec["arch"], shape=shape,
        mesh="x".join(str(s) for s in rec["mesh"]), chips=chips,
        kind=rec["kind"], model_flops=model, ana_flops=ana,
        ana_hbm_bytes=hbm, ana_coll_bytes=coll,
        hlo_flops=rec.get("flops_per_device") or 0.0,
        hlo_bytes=rec.get("bytes_per_device") or 0.0,
        hlo_coll_bytes=(rec.get("collective_bytes_per_device") or {}).get(
            "total", 0),
        mem_args_gib=(rec["memory"]["argument_bytes"] or 0) / 2 ** 30,
        mem_temp_gib=(rec["memory"]["temp_bytes"] or 0) / 2 ** 30,
    )


def load_all(tag: str = "pod1") -> list[Terms]:
    out = []
    for p in sorted(RESULTS.glob(f"*__{tag}.json")):
        out.append(analyze(json.loads(p.read_text())))
    return out


def table(terms: list[Terms]) -> str:
    hdr = ("| arch | shape | chips | compute s | memory s | coll s | "
           "bottleneck | MODEL_TF | useful/ana | roofline frac |")
    sep = "|" + "---|" * 10
    rows = [hdr, sep]
    for t in terms:
        rows.append(
            f"| {t.arch} | {t.shape} | {t.chips} | {t.t_compute:.2e} | "
            f"{t.t_memory:.2e} | {t.t_collective:.2e} | {t.bottleneck} | "
            f"{t.model_flops/1e12:.1f} | "
            f"{t.model_flops/max(t.ana_flops,1):.2f} | "
            f"{t.roofline_fraction:.2f} |")
    return "\n".join(rows)


if __name__ == "__main__":
    for tag in ("pod1", "pod2"):
        ts = load_all(tag)
        if ts:
            print(f"\n== mesh {ts[0].mesh} ==\n")
            print(table(ts))
