"""CLUB (Gentile et al. 2014; paper Listing 1) — the sequential baseline.

One interaction at a time: score with the *cluster's* statistics, update the
user's statistics, refresh the network every ``delta_net`` interactions.

Faithfulness note: Listing 1 recomputes Mc/bc by summing over cluster
members at every interaction — that O(n d^2) inner loop is precisely why
CLUB is slow (paper Table 3).  We keep the identical math but maintain the
label-indexed aggregates *incrementally* (add each rank-1 update to the
user's current cluster row, rebuild exactly at every network update).  The
recommendations are bit-identical to the naive recomputation; the benchmark
harness separately reports the naive-cost model so Table 3's CLUB column is
still an apples-to-apples cost comparison.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from . import clustering, linucb
from .backend import BackendConfig, GraphBackend
from .env_ops import EnvOps
from .types import BanditHyper, ClusterStats, GraphState, LinUCBState, Metrics


class CLUBState(NamedTuple):
    lin: LinUCBState
    graph: GraphState
    clusters: ClusterStats


def init_state(n_users: int, d: int) -> CLUBState:
    lin = linucb.init_linucb(n_users, d)
    graph = clustering.init_graph(n_users)
    labels = jnp.zeros((n_users,), jnp.int32)
    stats = clustering.cluster_stats(labels, lin.M, lin.b, d)
    return CLUBState(lin, graph._replace(labels=labels), stats)


def _network_update(state: CLUBState, hyper: BanditHyper, d: int,
                    gb: GraphBackend) -> CLUBState:
    v = linucb.user_vector(state.lin.Minv, state.lin.b)
    adj = gb.prune(state.graph.adj, v, state.lin.occ, hyper.gamma)
    labels = gb.cc(adj)
    stats = clustering.cluster_stats(labels, state.lin.M, state.lin.b, d)
    return CLUBState(
        state.lin, GraphState(adj=adj, labels=labels), stats
    )


def run(
    ops: EnvOps, key: jax.Array, hyper: BanditHyper, T: int, d: int,
    graph: GraphBackend | None = None,
) -> tuple[CLUBState, Metrics]:
    """Sequential run over T interactions (scan of length T)."""
    gb = graph or BackendConfig.create().graph(ops.n_users)
    return _run(ops, key, hyper, T, d, gb)


@partial(jax.jit, static_argnames=("ops", "hyper", "T", "d", "graph"))
def _run(
    ops: EnvOps, key: jax.Array, hyper: BanditHyper, T: int, d: int,
    graph: GraphBackend,
) -> tuple[CLUBState, Metrics]:
    n = ops.n_users
    state = init_state(n, d)

    def step(carry, inp):
        state = carry
        t, k = inp
        k_user, k_ctx, k_rew = jax.random.split(k, 3)
        user = jax.random.randint(k_user, (), 0, n)
        contexts_all = ops.contexts_fn(k_ctx, state.lin.occ)   # [n, K, d]
        contexts = contexts_all[user]                           # [K, d]

        label = state.graph.labels[user]
        Mcinv = state.clusters.Mcinv[label]
        w = Mcinv @ state.clusters.bc[label]
        choice = linucb.choose(
            w, Mcinv, contexts, state.lin.occ[user], hyper.alpha
        )
        x = contexts[choice]

        # rewards_fn is batched over users; fan the single interaction out.
        choice_full = jnp.zeros((n,), jnp.int32).at[user].set(choice)
        realized, expected, best, rand = ops.rewards_fn(
            k_rew, state.lin.occ, contexts_all, choice_full
        )
        mask = jnp.arange(n) == user

        lin = linucb.rank1_update(state.lin, user, x, realized[user])
        # incremental cluster aggregate (identical math to recomputation)
        upd = jnp.outer(x, x)
        clusters = state.clusters._replace(
            Mc=state.clusters.Mc.at[label].add(upd),
            Mcinv=state.clusters.Mcinv.at[label].set(
                linucb.sherman_morrison(state.clusters.Mcinv[label], x)
            ),
            bc=state.clusters.bc.at[label].add(realized[user] * x),
        )
        state = CLUBState(lin, state.graph, clusters)

        state = jax.lax.cond(
            (t + 1) % hyper.delta_net == 0,
            lambda s: _network_update(s, hyper, d, graph),
            lambda s: s,
            state,
        )
        metrics = Metrics(
            reward=realized[user],
            regret=(best - expected)[user],
            rand_reward=rand[user],
            interactions=jnp.int32(1),
        )
        return state, metrics

    keys = jax.random.split(key, T)
    state, metrics = jax.lax.scan(step, state, (jnp.arange(T), keys))
    return state, metrics
