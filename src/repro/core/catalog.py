"""The persistent item catalog the retrieval engine serves against.

A :class:`Catalog` is the item-side state the paper's user-side sharding
never had: a fixed-capacity table of item embeddings plus a liveness
mask.  Slots, not items, are the unit of storage — retiring an item just
clears its ``live`` bit (the retrieval kernels score it -inf), and adding
an item claims the lowest dead slot — so the array shapes (and therefore
every compiled transaction touching the catalog) are stable across the
add/retire churn of the drift scenario.

Sharding: the catalog shards over the mesh on the ITEM axis (axis 0 of
both arrays; ``specs``/``distributed.distclub_shard.named_shardings``).
Inside ``shard_map`` each device holds rows
``[axis_index * n_local, ...)`` and shortlists only those — the serving
layer merges per-shard shortlists, so cross-device traffic is
``O(B * K_short * shards)`` words instead of ``O(B * N_items)``.

Pure-functional like everything else: mutators return a new Catalog.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

try:  # PartitionSpec only needed for the sharded binding
    from jax.sharding import PartitionSpec as P
except ImportError:  # pragma: no cover
    P = None


class Catalog(NamedTuple):
    emb: jnp.ndarray    # [capacity, d] f32 embeddings (dead slots: zeros)
    live: jnp.ndarray   # [capacity] f32 liveness (1 = servable)

    @property
    def capacity(self) -> int:
        return self.live.shape[0]

    @property
    def d(self) -> int:
        return self.emb.shape[1]

    def n_live(self) -> jnp.ndarray:
        return jnp.sum(self.live).astype(jnp.int32)


def make_catalog(emb: jnp.ndarray, capacity: int | None = None) -> Catalog:
    """Catalog over ``emb [N, d]`` (all live), with ``capacity - N``
    spare dead slots for future ``add_items``."""
    N, d = emb.shape
    capacity = N if capacity is None else capacity
    if capacity < N:
        raise ValueError(f"capacity {capacity} < {N} items")
    full = jnp.zeros((capacity, d), jnp.float32).at[:N].set(emb)
    live = jnp.zeros((capacity,), jnp.float32).at[:N].set(1.0)
    return Catalog(emb=full, live=live)


def random_catalog(key: jax.Array, n_items: int, d: int,
                   capacity: int | None = None) -> Catalog:
    """Unit-norm random embeddings — benchmark/test construction."""
    e = jax.random.normal(key, (n_items, d))
    e = e / jnp.linalg.norm(e, axis=-1, keepdims=True)
    return make_catalog(e, capacity=capacity)


def retire_items(cat: Catalog, ids: jnp.ndarray
                 ) -> tuple[Catalog, jnp.ndarray]:
    """Clear the liveness bit of ``ids``; returns
    ``(catalog, n_retired)`` where ``n_retired`` counts slots that
    actually went live -> dead.  Negative ids (ragged-batch padding),
    out-of-range ids, duplicates, and already-dead slots are all
    well-defined no-ops — they simply don't count."""
    tgt = jnp.where(ids >= 0, ids, cat.capacity)
    new_live = cat.live.at[tgt].set(0.0, mode="drop")
    n_retired = jnp.sum(cat.live - new_live).astype(jnp.int32)
    return cat._replace(live=new_live), n_retired


def add_items(cat: Catalog, emb_new: jnp.ndarray
              ) -> tuple[Catalog, jnp.ndarray, jnp.ndarray]:
    """Place ``emb_new [m, d]`` into the lowest dead slots; returns
    ``(catalog, slot_ids [m], n_added)``.

    A PARTIAL FILL when fewer than ``m`` slots are free: the first
    ``n_added`` rows (in input order) claim the dead slots in ascending
    id order, the overflow is NOT placed and gets slot id -1 — live
    items are never silently overwritten.  Callers that must make room
    retire first and re-add the remainder."""
    m = emb_new.shape[0]
    # stable ascending sort of the 0/1 mask: dead slots first, id order
    order = jnp.argsort(cat.live, stable=True).astype(jnp.int32)
    n_free = (cat.capacity - jnp.sum(cat.live)).astype(jnp.int32)
    placed = jnp.arange(m, dtype=jnp.int32) < n_free
    slot = order[jnp.minimum(jnp.arange(m), cat.capacity - 1)]
    tgt = jnp.where(placed, slot, cat.capacity)   # overflow writes drop
    return cat._replace(
        emb=cat.emb.at[tgt].set(emb_new.astype(jnp.float32), mode="drop"),
        live=cat.live.at[tgt].set(1.0, mode="drop"),
    ), jnp.where(placed, slot, -1), jnp.sum(placed.astype(jnp.int32))


def specs(axes) -> Catalog:
    """PartitionSpecs for an item-axis sharding over mesh ``axes``."""
    return Catalog(emb=P(axes), live=P(axes))
