"""The persistent item catalog the retrieval engine serves against —
epoch-numbered and DOUBLE-BUFFERED for live churn.

A :class:`Catalog` holds TWO device-resident slot banks of item
embeddings plus liveness masks.  Exactly one bank — ``active`` — is the
serving truth; the other is the SHADOW staging area.  Mutators
(:func:`add_items` / :func:`retire_items`) stage into the shadow bank
only, so a flash crowd of arrivals or a mass retirement never perturbs
the bank in-flight transactions read; :func:`publish` then flips
``active`` and bumps ``epoch`` in ONE functional op — the whole swap is
a single atomic device update, with no host-side interleaving against
``serve.step_catalog``.

Slots, not items, are the unit of storage — retiring an item clears its
``live`` bit in the shadow bank (after publish the retrieval kernels
score it -inf), adding an item claims the lowest dead shadow slot — so
the array shapes (and therefore every compiled transaction touching the
catalog) are stable across churn.

Epoch accounting (the staleness contract ``serve.pending`` enforces):

  * ``epoch`` counts publishes.  Every pending decision records the
    epoch it was issued at.
  * ``born[bank, slot]`` is the epoch at which the slot's CURRENT
    resident item became servable — staged adds are stamped
    ``epoch + 1`` (the epoch their publish will create), so a slot that
    was retired and re-claimed by a different item is distinguishable
    from the item a stale decision chose.
  * in-flight decisions tolerate EXACTLY ONE stale epoch: feedback for a
    decision issued at epoch ``e`` folds while the published epoch is at
    most ``e + 1`` and its item is still live with ``born <= e``;
    anything older is quarantined (counted ``stale``, never folded).

Sharding: the catalog shards over the mesh on the ITEM axis (axis 1 of
the banked arrays; ``specs``/``distributed.distclub_shard
.named_shardings``).  Inside ``shard_map`` each device holds slot rows
``[axis_index * n_local, ...)`` of BOTH banks and shortlists only those
— the serving layer merges per-shard shortlists, so cross-device traffic
is ``O(B * K_short * shards)`` words instead of ``O(B * N_items)``.
``active``/``epoch`` are replicated scalars: the flip is atomic on every
shard at once.

Precision (`core.backend.Precision`): banks may store embeddings in bf16
or int8 instead of f32 — ``emb`` simply carries that dtype and a per-slot
f32 dequant ``scale`` rides along (1.0 except under int8, where
``dequantized = emb.astype(f32) * scale[:, None]``).  The initial
:func:`make_catalog` quantization shares one scale per ``scale_block``
contiguous slots (the tile granularity the retrieval kernels stream);
churn-added items get per-row scales — the group structure is a property
of the initial layout only, and every mutator/publish treats ``scale``
exactly like the other slot arrays, so scales survive double-buffered
publishes and slot reclaim bit-exactly.

Pure-functional like everything else: mutators return a new Catalog.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .backend import Precision, resolve_precision

try:  # PartitionSpec only needed for the sharded binding
    from jax.sharding import PartitionSpec as P
except ImportError:  # pragma: no cover
    P = None


class Bank(NamedTuple):
    """One bank's view — what the retrieval kernels actually consume."""

    emb: jnp.ndarray    # [capacity, d] embeddings (f32/bf16/int8 codes;
                        #   dead slots: zeros)
    live: jnp.ndarray   # [capacity] f32 liveness (1 = servable)
    born: jnp.ndarray   # [capacity] i32 epoch the resident item arrived
    scale: jnp.ndarray  # [capacity] f32 int8 dequant scale (1.0 otherwise)


class Catalog(NamedTuple):
    emb: jnp.ndarray    # [2, capacity, d] per-bank embeddings (bank dtype)
    live: jnp.ndarray   # [2, capacity] f32 per-bank liveness
    born: jnp.ndarray   # [2, capacity] i32 per-bank arrival epoch
    scale: jnp.ndarray  # [2, capacity] f32 per-bank dequant scales
    active: jnp.ndarray  # [] i32 which bank serves (0/1)
    epoch: jnp.ndarray   # [] i32 publish counter

    @property
    def capacity(self) -> int:
        return self.live.shape[1]

    @property
    def d(self) -> int:
        return self.emb.shape[2]

    @property
    def serving(self) -> Bank:
        """The active bank — the only state serving transactions read."""
        return Bank(emb=self.emb[self.active], live=self.live[self.active],
                    born=self.born[self.active],
                    scale=self.scale[self.active])

    @property
    def staged(self) -> Bank:
        """The shadow bank — where add/retire churn accumulates until
        the next :func:`publish`."""
        shadow = 1 - self.active
        return Bank(emb=self.emb[shadow], live=self.live[shadow],
                    born=self.born[shadow], scale=self.scale[shadow])

    def n_live(self) -> jnp.ndarray:
        """Servable item count of the ACTIVE bank (staged churn does not
        move this until it publishes)."""
        return jnp.sum(self.live[self.active]).astype(jnp.int32)


def _quantize_rows(emb: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    """f32 rows -> int8 codes under per-row ``scale`` (maxabs/127)."""
    q = jnp.round(jnp.clip(emb / scale[:, None], -127.0, 127.0))
    return q.astype(jnp.int8)


def dequantize(bank: Bank) -> jnp.ndarray:
    """The f32 embedding view the scoring math runs on.  For f32 banks
    this is the identity (bit-exact); bf16 upcasts; int8 applies the
    per-slot scale.  The dtype branch is trace-time."""
    e = bank.emb.astype(jnp.float32)
    if bank.emb.dtype == jnp.int8:
        e = e * bank.scale[:, None]
    return e


def make_catalog(emb: jnp.ndarray, capacity: int | None = None, *,
                 precision: Precision | str | None = None) -> Catalog:
    """Catalog over ``emb [N, d]`` (all live, born at epoch 0), with
    ``capacity - N`` spare dead slots for future ``add_items``.  Both
    banks start identical, active bank 0, epoch 0.

    ``precision`` picks the bank storage dtype (``catalog_dtype``); int8
    quantizes with one shared scale per ``scale_block`` contiguous slots
    (per-block maxabs/127, floored at 1e-8 so all-dead blocks stay
    finite).  Default (None) resolves ``REPRO_PRECISION`` -> f32."""
    prec = resolve_precision(precision)
    N, d = emb.shape
    capacity = N if capacity is None else capacity
    if capacity < N:
        raise ValueError(f"capacity {capacity} < {N} items")
    full32 = jnp.zeros((capacity, d), jnp.float32).at[:N].set(emb)
    dt = prec.jnp_catalog
    if dt == jnp.int8:
        sb = min(prec.scale_block, capacity)
        gid = jnp.arange(capacity, dtype=jnp.int32) // sb
        ngroups = (capacity + sb - 1) // sb
        rowmax = jnp.max(jnp.abs(full32), axis=1)
        gmax = jnp.zeros((ngroups,), jnp.float32).at[gid].max(rowmax)
        scale = jnp.maximum(gmax, 1e-8)[gid] / 127.0
        full = _quantize_rows(full32, scale)
    else:
        full = full32.astype(dt)
        scale = jnp.ones((capacity,), jnp.float32)
    live = jnp.zeros((capacity,), jnp.float32).at[:N].set(1.0)
    z = jnp.zeros((), jnp.int32)
    return Catalog(
        emb=jnp.stack([full, full]),
        live=jnp.stack([live, live]),
        born=jnp.zeros((2, capacity), jnp.int32),
        scale=jnp.stack([scale, scale]),
        active=z, epoch=z,
    )


def random_catalog(key: jax.Array, n_items: int, d: int,
                   capacity: int | None = None, *,
                   precision: Precision | str | None = None) -> Catalog:
    """Unit-norm random embeddings — benchmark/test construction."""
    e = jax.random.normal(key, (n_items, d))
    e = e / jnp.linalg.norm(e, axis=-1, keepdims=True)
    return make_catalog(e, capacity=capacity, precision=precision)


def _write_bank(cat: Catalog, bank, emb, live, born, scale) -> Catalog:
    return cat._replace(
        emb=cat.emb.at[bank].set(emb),
        live=cat.live.at[bank].set(live),
        born=cat.born.at[bank].set(born),
        scale=cat.scale.at[bank].set(scale),
    )


@jax.jit
def retire_items(cat: Catalog, ids: jnp.ndarray
                 ) -> tuple[Catalog, jnp.ndarray]:
    """STAGE the retirement of ``ids`` into the shadow bank; returns
    ``(catalog, n_retired)`` where ``n_retired`` counts shadow slots
    that actually went live -> dead.  Serving is untouched until
    :func:`publish`.  Negative ids (ragged-batch padding), out-of-range
    ids, duplicates, and already-dead slots are all well-defined no-ops
    — they simply don't count."""
    shadow = 1 - cat.active
    live_s = cat.live[shadow]
    tgt = jnp.where(ids >= 0, ids, cat.capacity)
    new_live = live_s.at[tgt].set(0.0, mode="drop")
    n_retired = jnp.sum(live_s - new_live).astype(jnp.int32)
    return cat._replace(live=cat.live.at[shadow].set(new_live)), n_retired


@jax.jit
def add_items(cat: Catalog, emb_new: jnp.ndarray
              ) -> tuple[Catalog, jnp.ndarray, jnp.ndarray]:
    """STAGE ``emb_new [m, d]`` into the lowest dead SHADOW slots;
    returns ``(catalog, slot_ids [m], n_added)``.  The staged items are
    stamped ``born = epoch + 1`` — the epoch the next :func:`publish`
    creates — and serve only from that publish on.

    A PARTIAL FILL when fewer than ``m`` shadow slots are free: the
    first ``n_added`` rows (in input order) claim the dead slots in
    ascending id order, the overflow is NOT placed and gets slot id -1 —
    live items are never silently overwritten.  Callers that must make
    room stage retirements first (same shadow bank, so a
    retire-then-add lands on the freed slots) and re-add the remainder.
    """
    m = emb_new.shape[0]
    shadow = 1 - cat.active
    emb_s, live_s, born_s, scale_s = (cat.emb[shadow], cat.live[shadow],
                                      cat.born[shadow], cat.scale[shadow])
    # stable ascending sort of the 0/1 mask: dead slots first, id order
    order = jnp.argsort(live_s, stable=True).astype(jnp.int32)
    n_free = (cat.capacity - jnp.sum(live_s)).astype(jnp.int32)
    placed = jnp.arange(m, dtype=jnp.int32) < n_free
    slot = order[jnp.minimum(jnp.arange(m), cat.capacity - 1)]
    tgt = jnp.where(placed, slot, cat.capacity)   # overflow writes drop
    emb32 = emb_new.astype(jnp.float32)
    if emb_s.dtype == jnp.int8:
        # churn-added items get per-row scales: the scale_block group
        # structure is a property of the initial layout only
        sc = jnp.maximum(jnp.max(jnp.abs(emb32), axis=1), 1e-8) / 127.0
        codes = _quantize_rows(emb32, sc)
    else:
        sc = jnp.ones((m,), jnp.float32)
        codes = emb32.astype(emb_s.dtype)
    cat = _write_bank(
        cat, shadow,
        emb_s.at[tgt].set(codes, mode="drop"),
        live_s.at[tgt].set(1.0, mode="drop"),
        born_s.at[tgt].set(cat.epoch + 1, mode="drop"),
        scale_s.at[tgt].set(sc, mode="drop"),
    )
    return cat, jnp.where(placed, slot, -1), jnp.sum(placed.astype(jnp.int32))


@jax.jit
def staged_churn(cat: Catalog) -> jnp.ndarray:
    """Number of slots whose staged state differs from the serving state
    — what the next :func:`publish` will change.  Feeds the guardrail
    churn-rate monitor."""
    a, s = cat.active, 1 - cat.active
    diff = ((cat.live[a] != cat.live[s])
            | (cat.born[a] != cat.born[s])
            | (cat.scale[a] != cat.scale[s])
            | jnp.any(cat.emb[a] != cat.emb[s], axis=-1))
    return jnp.sum(diff.astype(jnp.int32))


@jax.jit
def publish(cat: Catalog) -> Catalog:
    """Atomically flip the staged bank live: the shadow becomes the
    serving bank, ``epoch`` bumps by one, and the retiring bank is
    re-seeded as a copy of the newly published state (so the next round
    of staging starts from what is being served).  One functional op —
    under jit the swap is a single device update, never a torn
    host-side interleave."""
    new_active = 1 - cat.active
    emb_p, live_p, born_p, scale_p = (
        cat.emb[new_active], cat.live[new_active],
        cat.born[new_active], cat.scale[new_active])
    cat = _write_bank(cat, cat.active, emb_p, live_p, born_p, scale_p)
    return cat._replace(active=new_active, epoch=cat.epoch + 1)


@jax.jit
def torn_publish(cat: Catalog, keep_mask: jnp.ndarray) -> Catalog:
    """FAULT INJECTION ONLY — a publish where only ``keep_mask
    [capacity]`` slots' staged changes land (the rest flip back to their
    pre-churn state) before the atomic swap.  Models the torn/partial
    swap a non-double-buffered implementation risks; the epoch still
    bumps, so quarantine accounting stays well-defined while serving
    quality degrades.  Used by ``serve.faults`` and the churn tests."""
    shadow = 1 - cat.active
    keep = keep_mask.astype(bool)
    cat = _write_bank(
        cat, shadow,
        jnp.where(keep[:, None], cat.emb[shadow], cat.emb[cat.active]),
        jnp.where(keep, cat.live[shadow], cat.live[cat.active]),
        jnp.where(keep, cat.born[shadow], cat.born[cat.active]),
        jnp.where(keep, cat.scale[shadow], cat.scale[cat.active]),
    )
    return publish(cat)


def specs(axes) -> Catalog:
    """PartitionSpecs for an item-axis sharding over mesh ``axes`` —
    banks shard on their SLOT axis, the bank/flip scalars replicate."""
    return Catalog(emb=P(None, axes), live=P(None, axes),
                   born=P(None, axes), scale=P(None, axes),
                   active=P(), epoch=P())
