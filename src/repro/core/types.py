"""Core datatypes for the CLUB-family bandit algorithms.

Everything is a flat NamedTuple of arrays so states are pytrees that move
through jit / scan / shard_map without ceremony.  The user axis (``n``) is
the distribution axis: in the sharded runtime every array whose leading dim
is ``n`` is sharded over the flattened device mesh.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp


class BanditHyper(NamedTuple):
    """Hyper-parameters shared by CLUB / DCCB / DistCLUB (paper Table 2)."""

    alpha: float = 0.03        # UCB exploration coefficient
    beta: float = 2.0          # DistCLUB cluster-penalizing threshold
    gamma: float = 0.7         # edge-deletion threshold multiplier
    sigma: int = 16            # initial uRounds/cRounds split (paper: 2500)
    delta_net: int = 64        # CLUB network-update period (paper: 2000)
    buffer_size: int = 32      # DCCB buffer length (paper: 5000)
    n_candidates: int = 20     # |context set| presented per interaction
    max_rounds: int = 64       # static bound for uRounds/cRounds scan lengths


class LinUCBState(NamedTuple):
    """Per-user linear-bandit sufficient statistics.

    M    : [n, d, d]  Gram matrix  I + sum x x^T
    Minv : [n, d, d]  maintained inverse (Sherman-Morrison; exact)
    b    : [n, d]     reward-weighted context sum
    occ  : [n] i32    interaction counts
    """

    M: jnp.ndarray
    Minv: jnp.ndarray
    b: jnp.ndarray
    occ: jnp.ndarray


class GraphState(NamedTuple):
    """User-similarity graph + current clustering.

    adj      : [n, ceil(n/32)] uint32 — bit-packed rows, LSB-first (bit
               ``j % 32`` of word ``j // 32`` = edge (i, j); layout in
               ``repro.kernels.graph.ref``).  Row-sharded in the
               distributed runtime.  Edges are only ever pruned, so the
               packing is AND-monotone and 32x smaller than dense bool.
    labels   : [n] i32      cluster label = min user-id in the component
    """

    adj: jnp.ndarray
    labels: jnp.ndarray


class ClusterStats(NamedTuple):
    """Per-cluster aggregates, indexed by cluster label (a user id).

    Rows for ids that are not a current label are garbage and never read.
    """

    Mc: jnp.ndarray      # [n, d, d]
    Mcinv: jnp.ndarray   # [n, d, d]
    bc: jnp.ndarray      # [n, d]
    size: jnp.ndarray    # [n] i32   users per cluster
    seen: jnp.ndarray    # [n] i32   interactions since last stage-2


class DistCLUBState(NamedTuple):
    lin: LinUCBState
    graph: GraphState
    clusters: ClusterStats
    u_rounds: jnp.ndarray   # [n] i32 per-user stage-1 budget
    c_rounds: jnp.ndarray   # [n] i32 per-user stage-3 budget
    comm_bytes: jnp.ndarray  # [] f64-ish counter (f32) of bytes shipped


class Metrics(NamedTuple):
    """Streaming evaluation counters (one scalar slot per logical step)."""

    reward: jnp.ndarray      # realized reward (summed over the step's batch)
    regret: jnp.ndarray      # expected-best minus expected-chosen
    rand_reward: jnp.ndarray  # reward of a uniform-random policy (paper's RAN)
    interactions: jnp.ndarray  # number of (unmasked) interactions this step
