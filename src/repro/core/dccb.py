"""DCCB (Korda et al. 2016; paper Listing 2) — the buffered-gossip baseline.

Structure: repeat { L parallel interaction steps (filling every user's
length-L FIFO buffer) ; one peer-to-peer gossip round }.

Per interaction for user j:
    w = Mw[j]^-1 bw[j];  UCB(w, occ, contexts, Mw[j]^-1)
    push (x x^T, r x) into the buffers; pop the oldest entry into the
    *current* statistics (so current lags the newest information by L
    interactions — the paper's lazy-buffer semantics).

Gossip round (per user, with a random connected peer):
    compare *local* estimates (current + whole buffer);
    |w_i - w_peer| >= gamma (cb_i + cb_peer)  -> cut the edge, reset both;
    identical neighbourhoods                  -> average buffers + current.

Deviations (recorded per DESIGN.md §2):
  * Buffer entries are stored as full d x d matrices because DCCB's
    averaging step creates rank-2 mixtures; bench configs keep L modest and
    the Table-4 byte accounting uses the paper's analytic L (buffer floods
    are *counted*, not shipped, on this single-host simulation).
  * The gossip averaging applies to the receiving user only (the paper
    writes both endpoints from concurrent tasks; a pull-only update is the
    deterministic SPMD equivalent — every user is also a receiver in the
    same round, so information still spreads at the same hop rate).
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from . import clustering, linucb
from ..runtime import stages
from .backend import BackendConfig, InteractBackend
from .env_ops import EnvOps
from .types import BanditHyper


class DCCBState(NamedTuple):
    Mw: jnp.ndarray        # [n, d, d] current Gram (lagged)
    bw: jnp.ndarray        # [n, d]
    Mbuf: jnp.ndarray      # [n, L, d, d] FIFO of pending Gram updates
    bbuf: jnp.ndarray      # [n, L, d]
    occ: jnp.ndarray       # [n] i32
    adj: jnp.ndarray       # [n, n] bool
    slot: jnp.ndarray      # [] i32 ring-buffer cursor (global: users advance in lockstep)
    comm_bytes: jnp.ndarray  # [] f32


def init_state(n_users: int, d: int, L: int) -> DCCBState:
    eye = jnp.broadcast_to(jnp.eye(d, dtype=jnp.float32), (n_users, d, d))
    return DCCBState(
        Mw=eye,
        bw=jnp.zeros((n_users, d), jnp.float32),
        Mbuf=jnp.zeros((n_users, L, d, d), jnp.float32),
        bbuf=jnp.zeros((n_users, L, d), jnp.float32),
        occ=jnp.zeros((n_users,), jnp.int32),
        # DCCB's gossip cuts individual edges with per-(i, peer) scatter
        # updates, so it keeps the small dense graph (n is modest for the
        # baseline); the packed representation is DistCLUB/CLUB's.
        adj=clustering.dense_adj(n_users),
        slot=jnp.zeros((), jnp.int32),
        comm_bytes=jnp.zeros((), jnp.float32),
    )


def lagged_score(Mw: jnp.ndarray, bw: jnp.ndarray):
    """DCCB's scoring statistics from the lagged Gram: ``(w, Minv)``.

    The lagged ``Mw`` moves by buffer pops and gossip averaging (rank-2
    mixtures), so the inverse is recomputed batched rather than tracked by
    Sherman-Morrison.  Shared by the epoch driver's inner loop and the
    serving layer's dccb policy."""
    Minv = jnp.linalg.inv(Mw)
    return linucb.user_vector(Minv, bw), Minv


def buffered_push(s: DCCBState, x: jnp.ndarray, realized: jnp.ndarray,
                  mask: jnp.ndarray, L: int) -> DCCBState:
    """One masked buffered interaction for every user (the paper's
    lazy-buffer semantics): pop the oldest slot into the current
    statistics, push this round's update into the freed slot.

    Masked-off users are untouched — their pending slot entry stays
    buffered until their next active round pops it (push and pop share a
    slot, so no pending update is ever overwritten).  With an all-ones
    mask this is exactly the lockstep update of ``interaction_phase``;
    the serving layer calls it with the batch's per-user mask."""
    m = mask.astype(x.dtype)
    xm = x * m[:, None]
    upd_M = jnp.einsum("ni,nj->nij", xm, xm)
    upd_b = (realized * m)[:, None] * xm
    mM = m[:, None, None]
    old_M, old_b = s.Mbuf[:, s.slot], s.bbuf[:, s.slot]
    Mw = s.Mw + old_M * mM
    bw = s.bw + old_b * m[:, None]
    Mbuf = s.Mbuf.at[:, s.slot].set(jnp.where(mM > 0, upd_M, old_M))
    bbuf = s.bbuf.at[:, s.slot].set(jnp.where(m[:, None] > 0, upd_b, old_b))
    return s._replace(
        Mw=Mw, bw=bw, Mbuf=Mbuf, bbuf=bbuf,
        occ=s.occ + mask.astype(jnp.int32), slot=(s.slot + 1) % L,
    )


def interaction_phase(state: DCCBState, ops: EnvOps, key: jax.Array,
                      hyper: BanditHyper, L: int,
                      backend: InteractBackend | None = None):
    """L lockstep interaction steps; every user's buffer turns over once.

    Routes through the shared round protocol
    (``runtime.stages.interaction_rounds`` — the same loop the DistCLUB
    stages and both sharded runtimes run): DCCB supplies its own
    ``score_fn`` (the lagged Gram ``Mw`` is inverted batched each step —
    gossip averaging creates rank-2 mixtures Sherman-Morrison can't
    track) and ``update_fn`` (pop the oldest buffer slot into the current
    statistics, push the fresh update — the paper's lazy-buffer
    semantics).  No budget: every user is live every step.
    """
    n, d = state.bw.shape
    be = backend or BackendConfig.create().interact(n, d,
                                                     hyper.n_candidates)

    def score_lagged(carry):
        # Minv/w are derived fresh each step (Mw moves by buffer pops, not
        # rank-1 updates), so unlike the distclub stages there is no
        # carried state to pad once per stage — choose pads its per-step
        # inputs, which these already are.
        return lagged_score(carry.Mw, carry.bw)

    def update_buffered(carry, step_idx, x, realized, mask):
        del step_idx                  # lockstep: budget=None -> mask all-on
        return buffered_push(carry, x, realized, mask, L)

    return stages.interaction_rounds(
        be, ops, hyper, key, state, row0=0, n_steps=L,
        occ_of=lambda s: s.occ, score_fn=score_lagged,
        update_fn=update_buffered, budget=None,
    )


def gossip_round(state: DCCBState, key: jax.Array, hyper: BanditHyper,
                 L: int, d: int) -> DCCBState:
    """One peer-to-peer exchange per user (pull model)."""
    n = state.adj.shape[0]
    ids = jnp.arange(n)

    # local estimates include the whole buffer (paper's *_local copies)
    M_local = state.Mw + jnp.sum(state.Mbuf, axis=1)
    b_local = state.bw + jnp.sum(state.bbuf, axis=1)
    w = jnp.linalg.solve(M_local, b_local[..., None])[..., 0]   # [n, d]

    # choose a random connected peer (fall back to self when isolated ->
    # self-gossip is a no-op on both branches)
    logits = jnp.where(state.adj, 0.0, -jnp.inf)
    has_peer = jnp.any(state.adj, axis=1)
    peer = jnp.where(
        has_peer,
        jax.random.categorical(key, logits, axis=-1),
        ids,
    )

    dist = jnp.linalg.norm(w - w[peer], axis=-1)
    width = clustering.cb_width(state.occ)
    cut = (dist >= hyper.gamma * (width + width[peer])) & (peer != ids)

    # symmetric edge removal
    adj = state.adj
    adj = adj.at[ids, peer].set(jnp.where(cut, False, adj[ids, peer]))
    adj = adj.at[peer, ids].set(jnp.where(cut, False, adj[peer, ids]))

    # resets hit both endpoints of a cut edge
    reset = jnp.zeros((n,), bool).at[ids].max(cut).at[peer].max(cut)

    same_neigh = jnp.all(state.adj == state.adj[peer], axis=1) & ~cut & (
        peer != ids
    )

    def avg(a):
        return jnp.where(
            same_neigh.reshape((n,) + (1,) * (a.ndim - 1)),
            0.5 * (a + a[peer]),
            a,
        )

    eye = jnp.broadcast_to(jnp.eye(d, dtype=jnp.float32), (n, d, d))
    rs = lambda a, init: jnp.where(
        reset.reshape((n,) + (1,) * (a.ndim - 1)), init, a
    )

    Mw = rs(avg(state.Mw), eye)
    bw = rs(avg(state.bw), jnp.zeros_like(state.bw))
    Mbuf = rs(avg(state.Mbuf), jnp.zeros_like(state.Mbuf))
    bbuf = rs(avg(state.bbuf), jnp.zeros_like(state.bbuf))

    # paper Fig. 3 accounting: each exchange ships buffer + active objects
    nbytes = jnp.float32(n * (L + 1) * (d * d + d) * 4)
    return state._replace(
        Mw=Mw, bw=bw, Mbuf=Mbuf, bbuf=bbuf, adj=adj,
        comm_bytes=state.comm_bytes + nbytes,
    )


def run(ops: EnvOps, key: jax.Array, hyper: BanditHyper, n_epochs: int,
        d: int, L: int, backend: InteractBackend | None = None):
    """n_epochs x (L interaction steps + gossip).  Returns (state, metrics,
    cluster-count after each gossip round)."""
    if backend is None:
        backend = BackendConfig.create().interact(ops.n_users, d,
                                                  hyper.n_candidates)
    return _run(ops, key, hyper, n_epochs, d, L, backend)


@partial(jax.jit,
         static_argnames=("ops", "hyper", "n_epochs", "d", "L", "backend"))
def _run(ops: EnvOps, key: jax.Array, hyper: BanditHyper, n_epochs: int,
         d: int, L: int, backend: InteractBackend):
    state = init_state(ops.n_users, d, L)

    def epoch(state, k):
        k_int, k_gos = jax.random.split(k)
        state, metrics = interaction_phase(state, ops, k_int, hyper, L,
                                           backend)
        state = gossip_round(state, k_gos, hyper, L, d)
        n_clu = clustering.num_clusters(
            clustering.connected_components(state.adj)
        )
        return state, (metrics, n_clu)

    keys = jax.random.split(key, n_epochs)
    state, (metrics, n_clusters) = jax.lax.scan(epoch, state, keys)
    metrics = jax.tree.map(lambda x: x.reshape(-1), metrics)
    return state, metrics, n_clusters
