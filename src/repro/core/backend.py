"""Backend dispatch for the interaction, graph and retrieval engines.

The bandit hot loop is two operations per round — *choose* (UCB scores →
argmax → gather the chosen context) and *update* (rank-1 Sherman-Morrison
on the per-user statistics); stage 2 is two graph sweeps — *prune* (CLUB
edge deletion) and *CC hops* (min-label propagation); catalog serving adds
*shortlist* (streaming UCB top-K over the item catalog).  This module
selects between:

  ``reference``  the pure-jnp math in ``repro.core.linucb`` /
                 ``repro.kernels.graph.ref`` (CPU/GPU, and the numerical
                 oracle everywhere), and
  ``pallas``     the fused TPU kernels in ``repro.kernels.interact`` /
                 ``repro.kernels.rank1`` / ``repro.kernels.graph``
                 (``interpret=True`` off-TPU, so tier-1 still exercises
                 the kernel path).

Selection: explicit ``kind=`` argument > ``REPRO_BACKEND`` env var
("reference" | "pallas" | "auto") > "auto" (pallas iff running on TPU).

Precision: the engines additionally carry a :class:`Precision` policy —
which dtype the HBM-traffic-dominant state is STORED in (per-user ``Minv``
d^2 blocks, catalog embedding tiles), independent of the f32 the MXU/VPU
compute in.  Kernels upcast inside VMEM (``x.astype(f32)`` on a loaded
block; int8 catalog tiles additionally multiply a per-slot scale), so the
HBM stream shrinks 2x (bf16) / ~4x (int8) while every contraction still
accumulates in f32.  ``Precision.f32`` — the default — stores everything
in f32; every ``astype(float32)`` on an f32 array is a trace-time no-op,
so the f32 program is BIT-IDENTICAL to the pre-precision code.  Selection
mirrors the kind flag: explicit ``precision=`` argument > the
``REPRO_PRECISION`` env var ("f32" | "bf16" | "int8") > f32, resolved in
exactly one place (:func:`resolve_precision`).

Construction: one unified surface — ``BackendConfig(kind, precision)``
(build via :meth:`BackendConfig.create`, which resolves both flags) with
``.interact`` / ``.graph`` / ``.retrieval`` methods replacing the three
historical factories.  ``get_backend`` / ``get_graph_backend`` /
``get_retrieval_backend`` remain as thin deprecated wrappers for one PR.

Padding happens once per run, not once per call: the backend precomputes
the padded dims (users to the block multiple, d/K to sublane/lane
multiples) at construction, the drivers pad the scan-carried state a single
time per stage via ``pad_lin``/``pad_gram``/..., and every kernel entry
point short-circuits when handed pre-aligned arrays.  Only the per-step
context tensor (fresh every round) is padded inside the loop.  All padding
is exact: zero feature columns contribute nothing to scores or updates,
padded candidates are masked to -inf inside the choose kernel, and padded
users carry a zero budget so their mask is always off.

The backend is a NamedTuple of Python scalars — hashable, so drivers can
thread it through ``jax.jit`` as a static argument.
"""
from __future__ import annotations

import os
import warnings
from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..kernels import pad
from ..kernels.graph import ops as graph_ops
from ..kernels.interact import ops as interact_ops
from ..kernels.rank1 import ops as rank1_ops
from ..kernels.rank1.ref import rank1_update_inv_ref
from ..kernels.topk import ops as topk_ops
from ..kernels.topk.ref import tile_bounds, topk_ref, topk_ref_pruned
from . import clustering, linucb
from .types import LinUCBState

_ENV_FLAG = "REPRO_BACKEND"
_PRECISION_ENV_FLAG = "REPRO_PRECISION"

_DTYPES = {"f32": jnp.float32, "bf16": jnp.bfloat16, "int8": jnp.int8}
_STATE_DTYPES = ("f32", "bf16")             # Minv blocks (SPD: never int8)
_CATALOG_DTYPES = ("f32", "bf16", "int8")   # embedding tiles


class Precision(NamedTuple):
    """Storage-precision policy for the HBM-dominant state.

    A NamedTuple of Python scalars — hashable, so it rides inside the
    engine NamedTuples through ``jax.jit`` static arguments and the
    serving layer's lru-cached transactions compile once per policy.

    ``state_dtype``    per-user ``Minv`` d^2 blocks ("f32" | "bf16");
                       ``b``/``occ`` stay f32/i32 — they are O(d) per
                       user and exactness there keeps occ-style metrics
                       exact.
    ``catalog_dtype``  catalog embedding banks ("f32" | "bf16" | "int8";
                       int8 adds a per-slot f32 scale — see
                       ``core.catalog``).
    ``accum_dtype``    in-VMEM accumulation for the MXU contractions;
                       always "f32" today (kept explicit so the policy
                       records the numeric contract, not just storage).
    ``scale_block``    int8 scale granularity at initial quantization:
                       slots are grouped in blocks of this size sharing
                       one scale (churn-added rows get row-granular
                       scales; the stored array is per-slot either way).
    """

    state_dtype: str = "f32"
    catalog_dtype: str = "f32"
    accum_dtype: str = "f32"
    scale_block: int = 512

    @property
    def jnp_state(self):
        return _DTYPES[self.state_dtype]

    @property
    def jnp_catalog(self):
        return _DTYPES[self.catalog_dtype]

    @property
    def jnp_accum(self):
        return _DTYPES[self.accum_dtype]


# presets — the names the REPRO_PRECISION env flag accepts
Precision.f32 = Precision()
Precision.bf16 = Precision(state_dtype="bf16", catalog_dtype="bf16")
Precision.int8 = Precision(state_dtype="bf16", catalog_dtype="int8")
_PRECISION_PRESETS = {"f32": Precision.f32, "bf16": Precision.bf16,
                      "int8": Precision.int8}


def resolve_precision(precision=None) -> Precision:
    """THE one resolution point for the precision policy: explicit
    argument (a :class:`Precision` or a preset name) > ``REPRO_PRECISION``
    env var > f32.  Mirrors :func:`resolve_kind`."""
    if precision is None:
        precision = os.environ.get(_PRECISION_ENV_FLAG) or "f32"
    if isinstance(precision, str):
        if precision not in _PRECISION_PRESETS:
            raise ValueError(
                f"unknown precision {precision!r}; want "
                f"{'|'.join(_PRECISION_PRESETS)} or a Precision instance"
            )
        precision = _PRECISION_PRESETS[precision]
    if not isinstance(precision, Precision):
        raise TypeError(f"precision must be a Precision or preset name, "
                        f"got {type(precision).__name__}")
    if precision.state_dtype not in _STATE_DTYPES:
        raise ValueError(f"state_dtype {precision.state_dtype!r}; "
                         f"want {'|'.join(_STATE_DTYPES)}")
    if precision.catalog_dtype not in _CATALOG_DTYPES:
        raise ValueError(f"catalog_dtype {precision.catalog_dtype!r}; "
                         f"want {'|'.join(_CATALOG_DTYPES)}")
    if precision.accum_dtype != "f32":
        raise ValueError("accum_dtype must be 'f32' (MXU contractions "
                         "accumulate in f32)")
    if precision.scale_block < 1:
        raise ValueError(f"scale_block must be >= 1, "
                         f"got {precision.scale_block}")
    return precision


class InteractBackend(NamedTuple):
    """Fused-interaction engine for fixed (n, d, K) run shapes."""

    kind: str          # "reference" | "pallas"
    n: int             # logical users
    d: int             # logical feature dim
    K: int             # logical candidates per round
    n_pad: int         # users rounded to the block multiple
    d_pad: int         # d rounded to the sublane multiple
    K_pad: int         # K rounded to the lane multiple
    block_users: int
    interpret: bool    # run Pallas in interpret mode (CPU fallback)
    precision: Precision = Precision()   # storage policy for Minv state

    # ---- pad-once helpers (all trace-time no-ops when already padded, and
    # ---- identities for the reference backend) ------------------------------

    def pad_users(self, a: jnp.ndarray, fill=0) -> jnp.ndarray:
        """Pad the leading user axis n -> n_pad with ``fill``."""
        if a.shape[0] == self.n_pad:
            return a
        pad = [(0, self.n_pad - a.shape[0])] + [(0, 0)] * (a.ndim - 1)
        return jnp.pad(a, pad, constant_values=fill)

    def unpad_users(self, a: jnp.ndarray) -> jnp.ndarray:
        return a if a.shape[0] == self.n else a[: self.n]

    def pad_vec(self, a: jnp.ndarray) -> jnp.ndarray:
        """[n, d] -> [n_pad, d_pad] zero-padded."""
        if a.shape == (self.n_pad, self.d_pad):
            return a
        return jnp.pad(a, ((0, self.n_pad - a.shape[0]),
                           (0, self.d_pad - a.shape[1])))

    def unpad_vec(self, a: jnp.ndarray) -> jnp.ndarray:
        if a.shape == (self.n, self.d):
            return a
        return a[: self.n, : self.d]

    def pad_gram(self, a: jnp.ndarray) -> jnp.ndarray:
        """[n, d, d] -> [n_pad, d_pad, d_pad], identity on the padded diag
        (keeps padded Gram/inverse-Gram blocks well-conditioned; the real
        d x d block never mixes with the pad because padded x columns are
        zero)."""
        if a.shape == (self.n_pad, self.d_pad, self.d_pad):
            return a
        n, d = a.shape[0], a.shape[1]
        out = jnp.pad(a, ((0, self.n_pad - n), (0, self.d_pad - d),
                          (0, self.d_pad - d)))
        i = jnp.arange(d, self.d_pad)
        out = out.at[:, i, i].set(1.0)
        if n < self.n_pad:
            j = jnp.arange(d)
            out = out.at[n:, j, j].set(1.0)
        return out

    def unpad_gram(self, a: jnp.ndarray) -> jnp.ndarray:
        if a.shape == (self.n, self.d, self.d):
            return a
        return a[: self.n, : self.d, : self.d]

    def pad_ctx(self, a: jnp.ndarray) -> jnp.ndarray:
        """[n, K, d] -> [n_pad, K_pad, d_pad] zero-padded (per step)."""
        if a.shape == (self.n_pad, self.K_pad, self.d_pad):
            return a
        return jnp.pad(a, ((0, self.n_pad - a.shape[0]),
                           (0, self.K_pad - a.shape[1]),
                           (0, self.d_pad - a.shape[2])))

    def pad_lin(self, lin: LinUCBState) -> LinUCBState:
        if self.kind == "reference":
            return lin
        return LinUCBState(
            M=self.pad_gram(lin.M),
            Minv=self.pad_gram(lin.Minv),
            b=self.pad_vec(lin.b),
            occ=self.pad_users(lin.occ),
        )

    def unpad_lin(self, lin: LinUCBState) -> LinUCBState:
        if self.kind == "reference":
            return lin
        return LinUCBState(
            M=self.unpad_gram(lin.M),
            Minv=self.unpad_gram(lin.Minv),
            b=self.unpad_vec(lin.b),
            occ=self.unpad_users(lin.occ),
        )

    def with_users(self, n: int) -> "InteractBackend":
        """The same engine re-fit to a different leading (user/request)
        width — d, K and the dispatch decision are kept.  The serving
        layer uses this to derive a request-batch-width engine from the
        run-level one: the kind is resolved once per session, the width
        once per traced batch shape."""
        if n == self.n:
            return self
        if self.kind == "reference":
            return self._replace(n=n, n_pad=n)
        n_pad, d_pad, K_pad, bu = pad.padded_dims(n, self.d, self.K,
                                                  self.block_users)
        return self._replace(n=n, n_pad=n_pad, d_pad=d_pad, K_pad=K_pad,
                             block_users=bu)

    def with_candidates(self, K: int) -> "InteractBackend":
        """The same engine re-fit to a different slate width.  The
        catalog serving path uses this to run the final fused choose over
        a ``K_short`` shortlist with the session's run-level dispatch."""
        if K == self.K:
            return self
        if self.kind == "reference":
            return self._replace(K=K, K_pad=K)
        n_pad, d_pad, K_pad, bu = pad.padded_dims(self.n, self.d, K,
                                                  self.block_users)
        return self._replace(K=K, n_pad=n_pad, K_pad=K_pad, block_users=bu)

    # ---- the two hot-loop operations ---------------------------------------

    def choose(self, w, Minv, contexts, occ, alpha):
        """(x, choice) at the width of ``w`` (padded state in, padded out;
        logical-width inputs get logical-width outputs).

        Pallas kind: one kernel computes scores, argmax and the chosen-x
        gather in a single VMEM residency; the [n, K] score tensor never
        reaches HBM.  Reference kind: the seed linucb math.
        """
        if self.kind == "reference":
            # astype on an f32 array is a trace-time no-op — bf16 state
            # upcasts here so reference and pallas score the same f32 math
            choice = linucb.choose_batch(w, Minv.astype(jnp.float32),
                                         contexts, occ, alpha)
            x = jnp.take_along_axis(
                contexts, choice[:, None, None], axis=1
            )[:, 0]
            return x, choice
        choice, x = interact_ops.choose(
            self.pad_vec(w), self.pad_gram(Minv), self.pad_ctx(contexts),
            self.pad_users(occ), alpha,
            use_pallas=True, block_users=self.block_users,
            interpret=self.interpret, k_live=self.K,
        )
        return x[: w.shape[0], : w.shape[1]], choice[: w.shape[0]]

    def update_lin(self, lin: LinUCBState, x, r, mask) -> LinUCBState:
        """One masked interaction for every user: M, Minv, b in one pass."""
        if self.kind == "reference":
            return linucb.masked_batch_update(lin, x, r, mask)
        M, Minv, b = rank1_ops.rank1_update(
            lin.M, lin.Minv, lin.b, x, r, mask,
            use_pallas=True, block_users=self.block_users,
            interpret=self.interpret,
        )
        return LinUCBState(M, Minv, b, lin.occ + mask.astype(jnp.int32))

    def update_inv(self, Minv, b, x, r, mask):
        """M-free masked update (the sharded runtime carries no M)."""
        if self.kind == "reference":
            return rank1_update_inv_ref(Minv, b, x, r, mask)
        return rank1_ops.rank1_update_inv(
            Minv, b, x, r, mask,
            use_pallas=True, block_users=self.block_users,
            interpret=self.interpret,
        )


class GraphBackend(NamedTuple):
    """Stage-2 graph engine over the bit-packed adjacency.

    Operates on ``[n_rows, ceil(n_cols/32)]`` uint32 rows (layout:
    ``repro.kernels.graph.ref``).  ``n_rows == n_cols`` in the single-host
    drivers; the sharded runtime builds one backend per shard with
    ``n_rows = n_local`` and reuses the same kernels on its row shard.
    Like ``InteractBackend`` this is a NamedTuple of Python scalars, so it
    threads through ``jax.jit`` as a static argument.
    """

    kind: str          # "reference" | "pallas"
    n_rows: int        # adjacency rows held by this caller
    n_cols: int        # global user count (columns)
    block_i: int       # pallas row tile
    block_j: int       # pallas column tile (bits; /32 = words)
    row_block: int     # reference-path row blocking (lax.map tile)
    interpret: bool

    @property
    def words(self) -> int:
        """uint32 words per adjacency row."""
        return graph_ops.packed_words(self.n_cols)

    def init_adj(self, row_offset: int = 0) -> jnp.ndarray:
        """Fully-connected packed adjacency minus self edges."""
        return graph_ops.init_packed_adj(self.n_rows, self.n_cols,
                                         row_offset=row_offset)

    def pack(self, dense: jnp.ndarray) -> jnp.ndarray:
        return graph_ops.pack_bits(dense, self.words)

    def unpack(self, packed: jnp.ndarray) -> jnp.ndarray:
        return graph_ops.unpack_bits(packed, self.n_cols)

    def _opts(self):
        return dict(use_pallas=self.kind == "pallas", block_i=self.block_i,
                    block_j=self.block_j, interpret=self.interpret,
                    row_block=self.row_block)

    def prune_rows(self, adj, v_i, occ_i, v_j, occ_j, gamma):
        """AND the CLUB keep-mask into the packed rows.  The [n, n] f32
        distance matrix stays in VMEM (pallas) / a row slab (reference)."""
        cb_i = clustering.cb_width(occ_i)
        cb_j = clustering.cb_width(occ_j)
        return graph_ops.prune_packed(adj, v_i, cb_i, v_j, cb_j, gamma,
                                      **self._opts())

    def prune(self, adj, v, occ, gamma):
        """Square single-host prune (rows == columns)."""
        return self.prune_rows(adj, v, occ, v, occ, gamma)

    def cc_hop(self, adj, labels_self, labels_j):
        """One min-label hop over the packed rows (no pointer doubling)."""
        return graph_ops.cc_hop_packed(adj, labels_self, labels_j,
                                       **self._opts())

    def cc(self, adj) -> jnp.ndarray:
        """Connected components of the square packed graph: delegates to
        the engine's CC loop (``runtime.stages.connected_components``)
        with null collectives — ONE hop-sequence definition for CLUB, the
        single-host DistCLUB driver and the sharded runtime, identical to
        the dense ``clustering.connected_components`` oracle."""
        # call-time import: runtime.stages imports repro.core modules, so
        # a module-level import here would be order-sensitive.
        from ..runtime import collectives, stages
        return stages.connected_components(
            collectives.NullCollectives(), self, adj, self.n_cols,
            row0=0, n_local=self.n_rows,
        )


class RetrievalBackend(NamedTuple):
    """Catalog-scale retrieval engine: streaming UCB top-K shortlists.

    Scores a persistent ``[N_items, d]`` catalog for a batch of users
    with the same M-free statistics the fused choose reads
    (``theta . x + alpha sqrt(x' Minv x) sqrt(log1p(occ))``) and returns
    each user's ``K_short`` best (scores + item ids) WITHOUT ever
    materializing the ``[n, N_items]`` score matrix — the Pallas kernel
    keeps the running shortlist in revisited VMEM output blocks across
    item tiles, the jnp reference streams item tiles under ``lax.map`` /
    ``lax.scan``.  Like the other engines this is a NamedTuple of Python
    scalars, hashable and jit-static.

    The item-sharded runtime builds ONE backend and calls it per shard
    with that shard's catalog slice and ``row0_items = shard * n_local``;
    selection is by (score, id) value, so per-shard shortlists merged by
    the serving layer equal the single-host shortlist exactly (see
    ``kernels/topk/ref.py``).
    """

    kind: str          # "reference" | "pallas"
    d: int             # feature dim
    K_short: int       # shortlist length per user
    block_users: int   # pallas user block
    block_items: int   # pallas item tile
    row_block: int     # reference user-row blocking (lax.map tile)
    item_block: int    # reference item tile (lax.scan step)
    interpret: bool
    precision: Precision = Precision()   # storage policy (Minv + catalog)

    def shortlist(self, w, Minv, occ, items, live, alpha, row0_items=0,
                  scales=None):
        """(scores [n, K_short], ids [n, K_short] i32 GLOBAL item ids).

        ``row0_items`` is the global id of the catalog slice's first row
        (``axis_index * n_local`` on an item-sharded mesh).  Entries that
        hold no live item (underfull catalog / all-retired tile) keep
        score -inf and id -1.  ``items`` may be stored f32/bf16/int8 —
        int8 needs the per-slot ``scales [N]`` f32 array; the kernels
        dequantize tile-by-tile inside VMEM.
        """
        if self.kind == "reference":
            s, i = topk_ref(w, Minv, occ, items, live, alpha, self.K_short,
                            row_block=self.row_block,
                            item_block=self.item_block, scales=scales)
        else:
            s, i = topk_ops.topk(w, Minv, occ, items, live, alpha,
                                 self.K_short, use_pallas=True,
                                 block_users=self.block_users,
                                 block_items=self.block_items,
                                 interpret=self.interpret, scales=scales)
        i = jnp.where(jnp.isfinite(s), i + row0_items, -1)
        return s, i

    def shortlist_pruned(self, w, Minv, occ, items_sorted, live_sorted,
                         ids_sorted, tile_mu, tile_r, tile_xn, tile_n,
                         alpha, scales_sorted=None):
        """Cluster-pruned shortlist over a SORTED catalog slice
        (``core.itemclub`` builds the layout): computes the per-(user,
        tile) UCB upper bounds and streams only the tiles that can still
        beat each user block's running shortlist floor.

        Returns ``(scores [n, K_short], ids [n, K_short] i32 GLOBAL slot
        ids, tiles_skipped [] i32, tile_visits_total [] i32)`` with the
        shortlist BIT-EQUAL to :meth:`shortlist` over the unsorted slice
        — ``ids_sorted`` carries the original slot ids (already global
        on a sharded catalog: the cluster tables are replicated and each
        shard takes its position range), so tie-breaks match exactly.
        The caller is responsible for epoch freshness: these tables
        describe the bank they were built from, and a stale table's
        bounds are wrong — ``serve`` falls back to :meth:`shortlist`
        when ``clusters.epoch != catalog.epoch``."""
        tb = tile_bounds(w, Minv, occ, alpha, tile_mu, tile_r, tile_xn,
                         tile_n)
        if self.kind == "reference":
            s, i, skipped, total = topk_ref_pruned(
                w, Minv, occ, items_sorted, live_sorted, ids_sorted,
                alpha, self.K_short, tb, row_block=self.row_block,
                scales=scales_sorted)
        else:
            s, i, skipped, total = topk_ops.topk_pruned(
                w, Minv, occ, items_sorted, live_sorted, ids_sorted,
                alpha, self.K_short, tb, use_pallas=True,
                block_users=self.block_users, row_block=self.row_block,
                interpret=self.interpret, scales=scales_sorted)
        i = jnp.where(jnp.isfinite(s), i, -1)
        return s, i, skipped, total


def resolve_kind(kind: str | None = None) -> str:
    kind = kind or os.environ.get(_ENV_FLAG) or "auto"   # "" -> auto
    if kind == "auto":
        kind = "pallas" if jax.default_backend() == "tpu" else "reference"
    if kind not in ("reference", "pallas"):
        raise ValueError(
            f"unknown backend {kind!r}; want reference|pallas|auto"
        )
    return kind


class BackendConfig(NamedTuple):
    """THE backend-construction surface: one resolved (kind, precision)
    pair building every engine.  Replaces the three historical factories
    (``get_backend`` / ``get_graph_backend`` / ``get_retrieval_backend``),
    whose keyword surfaces had drifted apart; those names remain as thin
    deprecated wrappers for one PR.

        cfg = BackendConfig.create()              # env flags / auto
        be  = cfg.interact(n, d, K)
        gb  = cfg.graph(n_local, n_users)
        rb  = cfg.retrieval(d, K_short)

    Hashable (a NamedTuple of a str and a Precision), so it can ride
    through jit-static arguments like the engines themselves.
    """

    kind: str
    precision: Precision

    @classmethod
    def create(cls, kind: str | None = None,
               precision=None) -> "BackendConfig":
        """Resolve both selection flags — ``kind`` via
        :func:`resolve_kind` (``REPRO_BACKEND``), ``precision`` via
        :func:`resolve_precision` (``REPRO_PRECISION``)."""
        return cls(kind=resolve_kind(kind),
                   precision=resolve_precision(precision))

    def _interpret(self, interpret: bool | None) -> bool:
        if interpret is None:
            return jax.default_backend() != "tpu"
        return interpret

    def interact(self, n: int, d: int, K: int, *, block_users: int = 256,
                 interpret: bool | None = None) -> InteractBackend:
        """Fused-interaction engine for a run's (n, d, K); padded dims
        fixed here once."""
        if self.kind == "reference":
            n_pad, d_pad, K_pad, bu = n, d, K, block_users
        else:
            n_pad, d_pad, K_pad, bu = pad.padded_dims(n, d, K, block_users)
        return InteractBackend(
            kind=self.kind, n=n, d=d, K=K,
            n_pad=n_pad, d_pad=d_pad, K_pad=K_pad,
            block_users=bu, interpret=self._interpret(interpret),
            precision=self.precision,
        )

    def graph(self, n_rows: int, n_cols: int | None = None, *,
              block_i: int = 256, block_j: int = 4096,
              row_block: int = 256,
              interpret: bool | None = None) -> GraphBackend:
        """Stage-2 graph engine for a run's row/column extents.  The
        adjacency is bit-packed — there is nothing to store in reduced
        precision, so the graph engine ignores ``precision``."""
        return GraphBackend(
            kind=self.kind, n_rows=n_rows,
            n_cols=n_rows if n_cols is None else n_cols,
            block_i=block_i, block_j=block_j, row_block=row_block,
            interpret=self._interpret(interpret),
        )

    def retrieval(self, d: int, K_short: int, *, block_users: int = 128,
                  block_items: int = 512, row_block: int = 8,
                  item_block: int = 4096,
                  interpret: bool | None = None) -> RetrievalBackend:
        """Catalog-scale retrieval engine (streaming UCB top-K)."""
        return RetrievalBackend(
            kind=self.kind, d=d, K_short=K_short,
            block_users=block_users, block_items=block_items,
            row_block=row_block, item_block=item_block,
            interpret=self._interpret(interpret),
            precision=self.precision,
        )


# ---------------------------------------------------------------------------
# deprecated factory names — thin wrappers for one PR (the bandit_service
# playbook: keep the old names importable with a pointer, remove next PR)
# ---------------------------------------------------------------------------

_warned: set[str] = set()


def _deprecated(old: str, new: str) -> None:
    if old in _warned:      # once per process — tests stay quiet
        return
    _warned.add(old)
    warnings.warn(
        f"repro.core.backend.{old} is deprecated; build engines via "
        f"BackendConfig.create(kind, precision).{new} instead",
        DeprecationWarning, stacklevel=3,
    )


def get_backend(
    n: int,
    d: int,
    K: int,
    kind: str | None = None,
    *,
    block_users: int = 256,
    interpret: bool | None = None,
    precision=None,
) -> InteractBackend:
    """Deprecated — use ``BackendConfig.create(kind, precision).interact``."""
    _deprecated("get_backend", "interact(n, d, K)")
    return BackendConfig.create(kind, precision).interact(
        n, d, K, block_users=block_users, interpret=interpret)


def get_graph_backend(
    n_rows: int,
    n_cols: int | None = None,
    kind: str | None = None,
    *,
    block_i: int = 256,
    block_j: int = 4096,
    row_block: int = 256,
    interpret: bool | None = None,
) -> GraphBackend:
    """Deprecated — use ``BackendConfig.create(kind).graph``."""
    _deprecated("get_graph_backend", "graph(n_rows, n_cols)")
    return BackendConfig.create(kind).graph(
        n_rows, n_cols, block_i=block_i, block_j=block_j,
        row_block=row_block, interpret=interpret)


def get_retrieval_backend(
    d: int,
    K_short: int,
    kind: str | None = None,
    *,
    block_users: int = 128,
    block_items: int = 512,
    row_block: int = 8,
    item_block: int = 4096,
    interpret: bool | None = None,
    precision=None,
) -> RetrievalBackend:
    """Deprecated — use ``BackendConfig.create(kind, precision).retrieval``."""
    _deprecated("get_retrieval_backend", "retrieval(d, K_short)")
    return BackendConfig.create(kind, precision).retrieval(
        d, K_short, block_users=block_users, block_items=block_items,
        row_block=row_block, item_block=item_block, interpret=interpret)
