"""User-graph maintenance: edge pruning + connected components + aggregates.

The paper's Stage-2 ("updateNetwork + recomputeClusters") maps onto three
fully data-parallel pieces:

  1. prune_edges     — drop edge (i,j) when |v_i - v_j| exceeds the CLUB
                       confidence-width threshold (Gentile et al. 2014):
                       cb(occ) = sqrt((1 + log(1+occ)) / (1 + occ)).
  2. connected_components — iterative min-label propagation (the JAX-native
                       equivalent of Spark/GraphX connectedComponents): each
                       hop takes the min label over neighbours; a
                       ``lax.while_loop`` runs to fixed point.  At most n
                       hops; in practice O(graph diameter).
  3. cluster_stats   — per-cluster Gram/bias aggregation via segment_sum
                       keyed by label (the treeReduce of the paper; in the
                       sharded runtime this becomes a local segment_sum
                       followed by a mesh psum — the ICI all-reduce tree).

Labels live in user-id space (label = smallest user id in the component), so
all shapes stay static regardless of how many clusters exist.

Representation split: the DistCLUB / CLUB drivers carry the adjacency
**bit-packed** (``[n, ceil(n/32)] uint32``, see ``repro.kernels.graph``) and
run stage 2 through the ``GraphBackend`` engine — pruning only ever clears
bits, so packing is lossless and AND-monotone, and it cuts graph memory 32x
(the dense graph cannot even be allocated at the ROADMAP's million-user
scale).  The *dense* ``prune_edges`` / ``connected_components`` below are
kept as the numerical oracle for tests and for DCCB, whose gossip protocol
does per-edge scatter updates on a small dense matrix.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..kernels.graph import ops as graph_ops
from .types import ClusterStats, GraphState


def dense_adj(n_users: int) -> jnp.ndarray:
    """[n, n] bool fully-connected adjacency minus self edges (oracle/DCCB)."""
    return jnp.ones((n_users, n_users), bool) & ~jnp.eye(n_users, dtype=bool)


def init_graph(n_users: int) -> GraphState:
    """Packed fully-connected graph: [n, ceil(n/32)] uint32 rows."""
    adj = graph_ops.init_packed_adj(n_users, n_users)
    return GraphState(adj=adj, labels=jnp.zeros((n_users,), jnp.int32))


def cb_width(occ: jnp.ndarray, dtype=jnp.float32) -> jnp.ndarray:
    """CLUB's confidence-ball width around a user's estimate."""
    occf = occ.astype(dtype)
    return jnp.sqrt((1.0 + jnp.log1p(occf)) / (1.0 + occf))


def prune_edges(
    adj: jnp.ndarray,     # [n, n] bool
    v: jnp.ndarray,       # [n, d] current user vectors (Minv b)
    occ: jnp.ndarray,     # [n] i32
    gamma: float,
) -> jnp.ndarray:
    """Remove edges between users whose estimates diverged. Symmetric.

    Dense oracle: materializes the [n, n] distance matrix, so it is only
    used on small graphs (tests, DCCB).  Production paths go through
    ``GraphBackend.prune`` on the packed adjacency.
    """
    sq = jnp.sum(v * v, axis=-1)
    d2 = sq[:, None] + sq[None, :] - 2.0 * (v @ v.T)
    dist = jnp.sqrt(jnp.maximum(d2, 0.0))
    thresh = gamma * (cb_width(occ)[:, None] + cb_width(occ)[None, :])
    return adj & (dist < thresh)


def connected_components(adj: jnp.ndarray) -> jnp.ndarray:
    """Min-label propagation with pointer doubling.

    Each hop takes the min label over neighbours, then chases label->label
    links (``labels[labels]``) — the shortcutting step of classic
    pointer-jumping CC.  A label is always the id of some node in the same
    component with an equal-or-smaller id, so the jump preserves the
    min-label invariant while collapsing label chains geometrically: the
    ``while_loop`` converges in O(log n) hops instead of O(graph diameter).
    Returns [n] i32 labels (component min id).
    """
    n = adj.shape[0]
    init = jnp.arange(n, dtype=jnp.int32)
    big = jnp.int32(n)

    def hop(labels):
        # min over neighbours' labels (and own), then pointer-double
        neigh = jnp.where(adj, labels[None, :], big)
        l1 = jnp.minimum(labels, jnp.min(neigh, axis=1))
        return jnp.minimum(l1, l1[l1])

    def cond(carry):
        labels, changed, it = carry
        return changed & (it < n)

    def body(carry):
        labels, _, it = carry
        new = hop(labels)
        return new, jnp.any(new != labels), it + 1

    labels, _, _ = jax.lax.while_loop(cond, body, (init, jnp.array(True), 0))
    return labels


def cluster_stats(
    labels: jnp.ndarray,   # [n] i32
    M: jnp.ndarray,        # [n, d, d]
    b: jnp.ndarray,        # [n, d]
    d: int,
) -> ClusterStats:
    """Aggregate user statistics into label-indexed cluster statistics.

    Follows the paper: Mc = I + sum_u (Mu - I), bc = sum_u bu.  (Summing raw
    Mu would stack one identity per member; CLUB's estimator uses a single
    ridge term.)
    """
    n = labels.shape[0]
    eye = jnp.eye(d, dtype=M.dtype)
    Mc = jax.ops.segment_sum(M - eye, labels, num_segments=n) + eye
    bc = jax.ops.segment_sum(b, labels, num_segments=n)
    size = jax.ops.segment_sum(jnp.ones_like(labels), labels, num_segments=n)
    # one batched solve per stage-2 (not per interaction): cheap and exact.
    # Rows whose id is not a live label hold garbage; nothing reads them.
    Mcinv = jnp.linalg.inv(Mc)
    return ClusterStats(
        Mc=Mc,
        Mcinv=Mcinv,
        bc=bc,
        size=size,
        seen=jnp.zeros((n,), jnp.int32),
    )


def num_clusters(labels: jnp.ndarray) -> jnp.ndarray:
    """Number of distinct labels = number of users that are their own label."""
    n = labels.shape[0]
    return jnp.sum(labels == jnp.arange(n, dtype=labels.dtype))
