"""Paper core: CLUB-family contextual bandits (CLUB / DCCB / DistCLUB)."""
from . import club, clustering, dccb, distclub, env, env_ops, linucb, types
from .types import BanditHyper, DistCLUBState, LinUCBState, Metrics

__all__ = [
    "club", "clustering", "dccb", "distclub", "env", "env_ops", "linucb",
    "types", "BanditHyper", "DistCLUBState", "LinUCBState", "Metrics",
]
