"""Environment protocol shared by CLUB / DCCB / DistCLUB drivers.

An environment is two pure functions (closures over whatever tables the
environment needs), so the algorithm drivers stay agnostic between the
synthetic generator and logged-replay datasets:

  contexts_fn(key, occ)                     -> [n, K, d] candidate features
  rewards_fn(key, occ, contexts, choice)    -> (realized, expected, best, rand)

``occ`` is the per-user interaction count — replay environments use it as
the per-user queue cursor, preserving the paper's per-user ordering.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from . import env as synth_env


class EnvOps(NamedTuple):
    contexts_fn: Callable
    rewards_fn: Callable
    n_users: int
    d: int
    n_candidates: int


def synthetic_ops(env: synth_env.SyntheticEnv) -> EnvOps:
    n, d, K = env.n_users, env.d, env.n_candidates

    def contexts_fn(key, occ):
        del occ
        return synth_env.sample_contexts(key, (n,), K, d)

    def rewards_fn(key, occ, contexts, choice):
        del occ
        return synth_env.step_rewards(key, env.theta, contexts, choice)

    return EnvOps(contexts_fn, rewards_fn, n, d, K)


def replay_ops(
    item_feats: jnp.ndarray,     # [n_items, d]
    cand_ids: jnp.ndarray,       # [n_users, max_t, K] candidate item ids (pad=0)
    click_probs: jnp.ndarray,    # [n_users, max_t, K] logged CTR estimates
) -> EnvOps:
    """Logged-replay environment for the paper-dataset clones."""
    n, max_t, K = cand_ids.shape
    d = item_feats.shape[1]

    def contexts_fn(key, occ):
        del key
        t = jnp.minimum(occ, max_t - 1)                        # [n]
        ids = jnp.take_along_axis(cand_ids, t[:, None, None], axis=1)[:, 0]
        return item_feats[ids]                                  # [n, K, d]

    def rewards_fn(key, occ, contexts, choice):
        t = jnp.minimum(occ, max_t - 1)
        p_all = jnp.take_along_axis(click_probs, t[:, None, None], axis=1)[:, 0]
        p_choice = jnp.take_along_axis(p_all, choice[:, None], axis=1)[:, 0]
        best = jnp.max(p_all, axis=-1)
        rand = jnp.mean(p_all, axis=-1)
        u = jax.random.uniform(key, p_choice.shape)
        realized = (u < p_choice).astype(contexts.dtype)
        return realized, p_choice, best, rand

    return EnvOps(contexts_fn, rewards_fn, n, d, K)
