"""Shard-aware environment protocol shared by CLUB / DCCB / DistCLUB.

An environment is two pure functions (closures over whatever tables the
environment needs), so the algorithm drivers stay agnostic between the
synthetic generator, the non-stationary drift scenario, and logged-replay
datasets:

  contexts_fn(key, occ, row0=0)                  -> [n_local, K, d]
  rewards_fn(key, occ, contexts, choice, row0=0) -> (realized, expected,
                                                     best, rand)

``occ`` is the per-user interaction count for a LOCAL user slice (replay
environments use it as the per-user queue cursor, preserving the paper's
per-user ordering; the drift environment derives its phase from it) and
``row0`` is the global id of the slice's first user — the single-host
drivers pass ``row0=0`` with the full range, the sharded runtime passes
``axis_index * n_local`` inside ``shard_map``.  Environment tables are
closed over globally and sliced with ``dynamic_slice`` per call, so one
``EnvOps`` drives any sharding of the user axis.

Determinism under sharding (load-bearing for the parity tests): every
random draw is keyed per GLOBAL user id via ``fold_in(key, row0 + i)``, so
user ``u`` sees identical contexts and identical Bernoulli draws whether
the runtime is single-host or sharded 8 ways — runtimes diverge only by
fp contraction order in stage-2 aggregates and metric reductions.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from . import env as synth_env


class EnvOps(NamedTuple):
    contexts_fn: Callable
    rewards_fn: Callable
    n_users: int
    d: int
    n_candidates: int


def _user_keys(key, n_local: int, row0):
    """One PRNG key per user in the slice, keyed by GLOBAL user id."""
    ids = row0 + jnp.arange(n_local, dtype=jnp.int32)
    return jax.vmap(jax.random.fold_in, in_axes=(None, 0))(key, ids)


def _unit_contexts(key, n_local: int, K: int, d: int, row0):
    keys = _user_keys(key, n_local, row0)
    x = jax.vmap(lambda k: jax.random.normal(k, (K, d)))(keys)
    return x / jnp.linalg.norm(x, axis=-1, keepdims=True)


def _bernoulli_metrics(key, p_all, choice, dtype, row0):
    """(realized, expected, best, rand) from per-candidate click probs."""
    p_choice = jnp.take_along_axis(p_all, choice[:, None], axis=1)[:, 0]
    best = jnp.max(p_all, axis=-1)
    rand = jnp.mean(p_all, axis=-1)
    keys = _user_keys(key, p_all.shape[0], row0)
    u = jax.vmap(lambda k: jax.random.uniform(k, ()))(keys)
    realized = (u < p_choice).astype(dtype)
    return realized, p_choice, best, rand


def synthetic_ops(env: synth_env.SyntheticEnv) -> EnvOps:
    n, d, K = env.n_users, env.d, env.n_candidates
    theta = env.theta

    def contexts_fn(key, occ, row0=0):
        return _unit_contexts(key, occ.shape[0], K, d, row0)

    def rewards_fn(key, occ, contexts, choice, row0=0):
        th = jax.lax.dynamic_slice_in_dim(theta, row0, occ.shape[0])
        p_all = synth_env.expected_reward(th[:, None, :], contexts)
        return _bernoulli_metrics(key, p_all, choice, contexts.dtype, row0)

    return EnvOps(contexts_fn, rewards_fn, n, d, K)


def drift_ops(env: synth_env.DriftEnv) -> EnvOps:
    """Non-stationary scenario: contexts as the synthetic generator, click
    probabilities against the phase-dependent ``drift_theta`` — centroids
    re-draw every ``drift_period`` interactions per user."""
    n, d, K = env.n_users, env.d, env.n_candidates

    def contexts_fn(key, occ, row0=0):
        return _unit_contexts(key, occ.shape[0], K, d, row0)

    def rewards_fn(key, occ, contexts, choice, row0=0):
        th = synth_env.drift_theta(env, occ, row0)
        p_all = synth_env.expected_reward(th[:, None, :], contexts)
        return _bernoulli_metrics(key, p_all, choice, contexts.dtype, row0)

    return EnvOps(contexts_fn, rewards_fn, n, d, K)


def catalog_ops(env: synth_env.CatalogEnv) -> EnvOps:
    """Fixed-catalog scenario for the OFFLINE drivers: each round's slate
    is ``K`` items drawn (keyed per global user id) from the persistent
    catalog instead of fresh Gaussian contexts, at the per-user drift
    phase — so stage 1/3 learn against the same item population the
    retrieval engine serves, under any sharding.  (The serving-side
    two-stage path reads the catalog directly via
    ``serve.step_catalog``; this adapter is for ``distclub.run`` & co.)
    """
    n, d, K = env.n_users, env.d, env.n_candidates
    N = env.n_items
    theta = env.theta

    def _slate(key, occ, row0):
        keys = _user_keys(key, occ.shape[0], row0)
        ids = jax.vmap(lambda k: jax.random.randint(k, (K,), 0, N))(keys)
        phase = synth_env.catalog_phase(env, occ)                # [n_local]
        e = (env.region_centroids[phase[:, None], env.item_region[ids]]
             + env.item_noise[ids])
        return e / jnp.linalg.norm(e, axis=-1, keepdims=True)

    def contexts_fn(key, occ, row0=0):
        return _slate(key, occ, row0)

    def rewards_fn(key, occ, contexts, choice, row0=0):
        th = jax.lax.dynamic_slice_in_dim(theta, row0, occ.shape[0])
        p_all = synth_env.expected_reward(th[:, None, :], contexts)
        return _bernoulli_metrics(key, p_all, choice, contexts.dtype, row0)

    return EnvOps(contexts_fn, rewards_fn, n, d, K)


def replay_ops(
    item_feats: jnp.ndarray,     # [n_items, d]
    cand_ids: jnp.ndarray,       # [n_users, max_t, K] candidate item ids (pad=0)
    click_probs: jnp.ndarray,    # [n_users, max_t, K] logged CTR estimates
) -> EnvOps:
    """Logged-replay environment for the paper-dataset clones.  Each user
    consumes their queue of logged slates in order (``occ`` is the
    cursor); the tables are sliced per shard via ``row0``."""
    n, max_t, K = cand_ids.shape
    d = item_feats.shape[1]

    def contexts_fn(key, occ, row0=0):
        del key
        rows = jax.lax.dynamic_slice_in_dim(cand_ids, row0, occ.shape[0])
        t = jnp.minimum(occ, max_t - 1)                        # [n_local]
        ids = jnp.take_along_axis(rows, t[:, None, None], axis=1)[:, 0]
        return item_feats[ids]                                  # [n_local,K,d]

    def rewards_fn(key, occ, contexts, choice, row0=0):
        rows = jax.lax.dynamic_slice_in_dim(click_probs, row0, occ.shape[0])
        t = jnp.minimum(occ, max_t - 1)
        p_all = jnp.take_along_axis(rows, t[:, None, None], axis=1)[:, 0]
        return _bernoulli_metrics(key, p_all, choice, contexts.dtype, row0)

    return EnvOps(contexts_fn, rewards_fn, n, d, K)


def default_synthetic_ops(n_users: int, d: int, n_candidates: int,
                          seed: int = 0,
                          n_clusters: int | None = None) -> EnvOps:
    """Convenience constructor used by the sharded runtimes when no
    explicit environment is given: a planted clustered env with a mild
    cluster count so stage-2/3 have structure to find."""
    if n_clusters is None:
        n_clusters = max(2, n_users // 16)
    env, _ = synth_env.make_synthetic_env(
        jax.random.PRNGKey(seed), n_users=n_users, d=d,
        n_clusters=n_clusters, n_candidates=n_candidates,
        within_cluster_noise=0.05,
    )
    return synthetic_ops(env)
