"""Item-side CLUB clustering over the `Catalog` + tile-aligned UCB
bounds — the structure the cluster-pruned retrieval path serves from.

DistCLUB clusters USERS; `CatalogEnv` plants the mirrored structure on
the ITEM side (region centroids) that the streaming top-K engine never
exploited.  This module learns that structure online — CLUB-style, from
per-item reward statistics — and lays the catalog out so whole item
tiles can be skipped:

  1. `ItemStats` — per-slot serve counts + reward sums, folded
     duplicate-safely from served feedback (`observe_served`).  Items
     cluster on ``concat(normalize(emb), beta * rhat)``: embedding
     geometry plus the LEARNED mean reward, so two items of similar
     geometry but divergent realized reward separate (the CAB insight —
     the item side of the collaborative structure is learnable online).
  2. `build_clusters` — CLUB confidence pruning + connected components
     over a bounded ANCHOR set via the bit-packed adjacency + tiled
     edge-prune + fused CC-hop machinery of ``kernels/graph``
     (`GraphBackend`; a full graph over 2^18 items would need GiBs of
     adjacency — anchors keep stage-2-style cost while every item still
     gets a label by nearest-anchor assignment, chunked so the
     ``[capacity, A]`` distance matrix never materializes).  When
     ``capacity <= n_anchors`` every item IS an anchor and the
     clustering is the exact CLUB graph.
  3. Tile-aligned layout: a permutation ``perm`` (position -> slot id)
     sorts live slots by cluster label, dead slots last, and cached
     sorted copies of the serving bank plus per-tile summaries
     (centroid ``tile_mu``, radius ``tile_r``, max-norm ``tile_xn``,
     live count ``tile_n``) feed ``kernels.topk.ref.tile_bounds`` — a
     TRUE per-(user, tile) upper bound, so pruning is EXACT (shortlists
     bit-equal to unpruned; see ``kernels/topk/ref.py``).

Epoch contract (the churn-safety rule `serve` enforces): the cluster
state is stamped with the catalog epoch it was built from.  `publish`
is the only operation that mutates the serving bank and it always bumps
the epoch, so ``clusters.epoch == catalog.epoch`` iff the sorted copies
and tile tables still describe the serving truth — on mismatch the
pruned path FALLS BACK to unpruned scoring (never silently prunes with
stale bounds).  Rebuild lazily on the stage-2 cadence via
`refresh_clusters` (a no-op while the epoch still matches, unless
forced).

Sharding: the cluster tables are REPLICATED (`specs`).  Each item shard
takes its own position range of the sorted stream (`shard_slice`) —
because ``ids_sorted`` carries global slot ids and shortlist selection
is by (score, id) value, ANY partition of the position axis merges to
the identical shortlist, and the one-hot context assembly still
resolves slot ownership against the sharded bank.  ``capacity`` must be
divisible by ``tile_items * n_shards``.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

try:  # PartitionSpec only needed for the sharded binding
    from jax.sharding import PartitionSpec as P
except ImportError:  # pragma: no cover
    P = None

from .backend import BackendConfig
from .catalog import dequantize


class ItemStats(NamedTuple):
    """Per-slot learned reward statistics (slot-indexed, like the
    catalog banks: a retired-then-reclaimed slot should be reset via
    :func:`reset_new_slots` after the publish that re-seats it)."""

    occ: jnp.ndarray    # [capacity] i32 times the slot's item was served
    rsum: jnp.ndarray   # [capacity] f32 summed realized reward


class ItemClusters(NamedTuple):
    """Epoch-stamped item-cluster state + the tile-aligned sorted layout
    the pruned retrieval kernels stream."""

    epoch: jnp.ndarray        # [] i32 catalog epoch the tables describe
    labels: jnp.ndarray       # [capacity] i32 cluster label per slot
    perm: jnp.ndarray         # [capacity] i32 position -> slot id
    emb_sorted: jnp.ndarray   # [capacity, d] serving bank emb[perm]
    #                             (bank dtype: f32/bf16/int8 codes)
    live_sorted: jnp.ndarray  # [capacity] f32 serving bank live[perm]
    scale_sorted: jnp.ndarray  # [capacity] f32 serving bank scale[perm]
    tile_mu: jnp.ndarray      # [T, d] live-item centroid per tile
    tile_r: jnp.ndarray       # [T] max live |x - mu| per tile
    tile_xn: jnp.ndarray      # [T] max live |x| per tile
    tile_n: jnp.ndarray       # [T] i32 live items per tile
    n_clusters: jnp.ndarray   # [] i32 distinct anchor labels

    @property
    def capacity(self) -> int:
        return self.perm.shape[0]

    @property
    def tile_items(self) -> int:
        return self.perm.shape[0] // self.tile_mu.shape[0]


class RetrievalMetrics(NamedTuple):
    """Per-transaction pruned-retrieval telemetry (replicated scalars;
    psum-combined across item shards)."""

    tiles_skipped: jnp.ndarray   # [] i32 tile visits skipped
    tiles_total: jnp.ndarray     # [] i32 tile visits possible
    pruned_active: jnp.ndarray   # [] i32 1 = pruned path ran, 0 = stale
    #                                 cluster table, fell back to unpruned

    def skip_ratio(self) -> float:
        """Host-side tiles_skipped / tiles_total (0 when fallen back)."""
        return float(self.tiles_skipped) / max(1.0, float(self.tiles_total))


# ---------------------------------------------------------------------------
# learned per-item reward statistics
# ---------------------------------------------------------------------------


def init_stats(capacity: int) -> ItemStats:
    return ItemStats(occ=jnp.zeros((capacity,), jnp.int32),
                     rsum=jnp.zeros((capacity,), jnp.float32))


@jax.jit
def observe_served(stats: ItemStats, item_ids: jnp.ndarray,
                   rewards: jnp.ndarray,
                   valid: jnp.ndarray | None = None) -> ItemStats:
    """Fold one served batch: ``item_ids [B]`` global slot ids (< 0 =
    padding), ``rewards [B]`` realized rewards.  Scatter-add, so
    duplicate items in one batch fold exactly like sequential serves."""
    cap = stats.occ.shape[0]
    ok = (item_ids >= 0) & (item_ids < cap)
    if valid is not None:
        ok = ok & valid
    tgt = jnp.where(ok, item_ids, cap)          # out-of-range writes drop
    return ItemStats(
        occ=stats.occ.at[tgt].add(ok.astype(jnp.int32), mode="drop"),
        rsum=stats.rsum.at[tgt].add(
            jnp.where(ok, rewards.astype(jnp.float32), 0.0), mode="drop"),
    )


@jax.jit
def reset_new_slots(stats: ItemStats, catalog) -> ItemStats:
    """Zero the statistics of slots whose resident item arrived at the
    CURRENT epoch (``born == epoch``) — call after a `publish` so a
    reclaimed slot never inherits its previous occupant's rewards."""
    bank = catalog.serving
    fresh = bank.born == catalog.epoch
    return ItemStats(occ=jnp.where(fresh, 0, stats.occ),
                     rsum=jnp.where(fresh, 0.0, stats.rsum))


# ---------------------------------------------------------------------------
# CLUB clustering over anchors + nearest-anchor assignment
# ---------------------------------------------------------------------------


def _item_features(emb: jnp.ndarray, stats: ItemStats,
                   beta: float) -> jnp.ndarray:
    """[capacity, d + 1] — unit-normalized embedding ++ beta * learned
    mean reward (rhat = rsum / (1 + occ), the ridge-style estimate that
    is 0 for never-served items)."""
    nrm = jnp.maximum(jnp.linalg.norm(emb, axis=-1, keepdims=True), 1e-9)
    rhat = stats.rsum / (1.0 + stats.occ.astype(jnp.float32))
    return jnp.concatenate([emb / nrm, beta * rhat[:, None]], axis=1)


def _nearest_anchor(z: jnp.ndarray, z_a: jnp.ndarray,
                    chunk: int = 4096) -> jnp.ndarray:
    """argmin_a |z_i - z_a| per row, chunked so the [capacity, A]
    distance matrix never materializes.  Ties break on the smaller
    anchor index (argmin), so when every item is its own anchor the
    assignment is exactly the identity."""
    cap = z.shape[0]
    cb = min(chunk, cap)
    pad = (-cap) % cb
    zp = jnp.pad(z, ((0, pad), (0, 0)))
    a2 = jnp.sum(z_a * z_a, axis=1)

    def blk(zb):
        d2 = (jnp.sum(zb * zb, axis=1)[:, None]
              - 2.0 * (zb @ z_a.T) + a2[None])
        return jnp.argmin(d2, axis=1).astype(jnp.int32)

    out = jax.lax.map(blk, zp.reshape((cap + pad) // cb, cb, -1))
    return out.reshape(cap + pad)[:cap]


def build_clusters(catalog, stats: ItemStats | None = None, *,
                   tile_items: int = 512, n_anchors: int = 512,
                   gamma: float = 0.5, beta: float = 1.0,
                   kind: str | None = None,
                   interpret: bool | None = None) -> ItemClusters:
    """Cluster the SERVING bank and lay it out tile-aligned.

    CLUB pruning runs on a bounded anchor set (the first ``n_anchors``
    live slots in id order; every slot when ``capacity <= n_anchors``)
    through the packed-adjacency `GraphBackend` — edge (i, j) survives
    iff ``|z_i - z_j| < gamma (cb(occ_i) + cb(occ_j))``, components are
    fused CC hops — then every slot takes its nearest anchor's label.
    Dead slots sort AFTER every label so they pool in trailing tiles
    (bound -inf, skipped as soon as any shortlist floor exists).

    ``capacity % tile_items == 0`` is required (and on an S-way item
    shard, ``capacity % (tile_items * S) == 0`` so each shard's position
    range is whole tiles).  The result is stamped with the catalog's
    CURRENT epoch; any later `publish` invalidates it (see module
    docstring)."""
    bank = catalog.serving
    cap = catalog.capacity
    if cap % tile_items:
        raise ValueError(f"capacity {cap} % tile_items {tile_items} != 0")
    if stats is None:
        stats = init_stats(cap)

    # features, tile summaries and bounds all run on the DEQUANTIZED
    # stream — the exact f32 values the pruned kernels score — so the
    # bounds dominate what is actually scored (f32 banks: identity)
    emb_f = dequantize(bank)
    z = _item_features(emb_f, stats, beta)
    # live slots first (stable -> ascending id), like add_items' slot scan
    by_live = jnp.argsort(-bank.live, stable=True).astype(jnp.int32)
    A = min(n_anchors, cap)
    anchor_ids = by_live[:A]
    z_a = z[anchor_ids]

    gb = BackendConfig.create(kind).graph(A, A, interpret=interpret)
    adj = gb.init_adj()
    adj = gb.prune(adj, z_a, stats.occ[anchor_ids], gamma)
    anchor_labels = gb.cc(adj)                 # [A] i32 in [0, A)

    labels = anchor_labels[_nearest_anchor(z, z_a)]
    n_clusters = jnp.sum(
        (jnp.bincount(anchor_labels, length=A) > 0).astype(jnp.int32))

    # dead slots get label A (past every anchor label) so a stable sort
    # pushes them into the trailing tiles
    sort_key = jnp.where(bank.live > 0, labels, A)
    perm = jnp.argsort(sort_key, stable=True).astype(jnp.int32)
    emb_sorted = bank.emb[perm]          # stored dtype — kernels dequant
    live_sorted = bank.live[perm]
    scale_sorted = bank.scale[perm]

    T = cap // tile_items
    d = bank.emb.shape[1]
    et = emb_f[perm].reshape(T, tile_items, -1)
    lt = live_sorted.reshape(T, tile_items)
    cnt = jnp.sum(lt, axis=1)
    mu = (jnp.sum(et * lt[..., None], axis=1)
          / jnp.maximum(cnt, 1.0)[:, None])
    dist = jnp.linalg.norm(et - mu[:, None, :], axis=-1)
    tile_r = jnp.max(jnp.where(lt > 0, dist, 0.0), axis=1)
    tile_xn = jnp.max(
        jnp.where(lt > 0, jnp.linalg.norm(et, axis=-1), 0.0), axis=1)
    # quantized banks: widen radius/max-norm by the per-tile quantization
    # error bound so the bounds stay conservative even against re-rounded
    # dequant chains (f32: widening is exactly zero — bit-identical)
    if bank.emb.dtype == jnp.int8:
        st = scale_sorted.reshape(T, tile_items)
        qeps = jnp.sqrt(float(d)) * 0.5 * jnp.max(
            jnp.where(lt > 0, st, 0.0), axis=1)
    elif bank.emb.dtype == jnp.bfloat16:
        qeps = tile_xn * 2.0 ** -8        # bf16 has 8 mantissa bits
    else:
        qeps = jnp.zeros_like(tile_xn)
    tile_r = tile_r + qeps
    tile_xn = tile_xn + qeps

    return ItemClusters(
        epoch=jnp.asarray(catalog.epoch, jnp.int32),
        labels=labels.astype(jnp.int32), perm=perm,
        emb_sorted=emb_sorted, live_sorted=live_sorted,
        scale_sorted=scale_sorted.astype(jnp.float32),
        tile_mu=mu.astype(jnp.float32), tile_r=tile_r.astype(jnp.float32),
        tile_xn=tile_xn.astype(jnp.float32), tile_n=cnt.astype(jnp.int32),
        n_clusters=n_clusters,
    )


def is_fresh(clusters: ItemClusters, catalog) -> bool:
    """Host-side: do the tables still describe the serving bank?"""
    return int(clusters.epoch) == int(catalog.epoch)


def refresh_clusters(clusters: ItemClusters, catalog,
                     stats: ItemStats | None = None, *,
                     force: bool = False, **build_kw) -> ItemClusters:
    """Lazy rebuild: a no-op while the epoch still matches (pass
    ``force=True`` on the stage-2 cadence to fold fresh reward
    statistics into the clustering even without churn).  Keyword args
    forward to :func:`build_clusters`."""
    if not force and is_fresh(clusters, catalog):
        return clusters
    build_kw.setdefault("tile_items", clusters.tile_items)
    return build_clusters(catalog, stats, **build_kw)


# ---------------------------------------------------------------------------
# sharding
# ---------------------------------------------------------------------------


def specs() -> ItemClusters:
    """PartitionSpecs: the cluster tables REPLICATE (each item shard
    slices its own position range via :func:`shard_slice`)."""
    return ItemClusters(epoch=P(), labels=P(), perm=P(), emb_sorted=P(),
                        live_sorted=P(), scale_sorted=P(), tile_mu=P(),
                        tile_r=P(), tile_xn=P(), tile_n=P(),
                        n_clusters=P())


def shard_slice(clusters: ItemClusters, shard, n_local: int):
    """This shard's piece of the sorted stream: positions
    ``[shard * n_local, ...)`` and their whole tiles.  Returns
    ``(emb, live, ids, scale, tile_mu, tile_r, tile_xn, tile_n)`` —
    ``ids`` are the GLOBAL slot ids, so per-shard shortlists merge
    bit-equal to the single-host stream (selection is by value)."""
    tile = clusters.tile_items
    if n_local % tile:
        raise ValueError(
            f"shard slice {n_local} % tile_items {tile} != 0 — build "
            "clusters with tile_items dividing capacity // n_shards")
    T_local = n_local // tile
    row0 = shard * n_local
    t0 = shard * T_local
    sl = jax.lax.dynamic_slice_in_dim
    return (sl(clusters.emb_sorted, row0, n_local),
            sl(clusters.live_sorted, row0, n_local),
            sl(clusters.perm, row0, n_local),
            sl(clusters.scale_sorted, row0, n_local),
            sl(clusters.tile_mu, t0, T_local),
            sl(clusters.tile_r, t0, T_local),
            sl(clusters.tile_xn, t0, T_local),
            sl(clusters.tile_n, t0, T_local))
