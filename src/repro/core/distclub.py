"""DistCLUB (paper Listing 3): the four repeating stages, batched-SPMD style.

Stage 1  user-based LinUCB rounds        — all users advance in parallel, one
                                           interaction per scan step, masked by
                                           the per-user budget ``u_rounds``.
Stage 2  network update + clustering     — edge pruning, connected components,
                                           tree-reduced cluster statistics.
Stage 3  cluster-based UCB rounds        — as stage 1 but scoring uses the
                                           (frozen) cluster statistics, except
                                           for the paper's beta-heuristic users
                                           who keep personalized scoring.
Stage 4  budget rebalancing              — delta = (occ - cluster mean occ)/2
                                           shifts rounds between stages 1/3.

Parallelism note: the paper serializes interactions *within* a cluster in
stage 3 only because its Spark tasks mutate shared cluster objects.  Here the
cluster statistics are frozen between stage-2 refreshes (exactly the paper's
"lazy" semantics) and only per-user statistics mutate, so every user advances
in parallel without conflicts; cross-step ordering per user is preserved by
the scan.  The regret analysis in paper §4 covers this schedule — it is the
same lazy-update argument used to justify DCCB's buffering.

Execution backends: stages 1/3 run through the fused interaction engine
(``repro.core.backend``) — choose (scores+argmax+gather in one kernel) and
the fused rank-1 update.  The scan-carried LinUCB state is padded to the
kernel block shape ONCE per stage, not per step; only the fresh per-step
context tensor is padded inside the loop.  Stage-3 additionally hoists the
frozen per-user cluster snapshots (Mcinv[labels], bc[labels], the cluster
user vector AND the cluster mean-occ) out of the scan — they only change at
stage-2 refreshes (the paper's lazy semantics, matching the sharded
runtime), so gathering them per step was pure HBM traffic.

Stage 2 runs through the graph engine (``GraphBackend``): the adjacency is
bit-packed ``[n, ceil(n/32)] uint32``, pruning streams distance tiles
through VMEM (the ``[n, n]`` f32 matrix never exists), and each CC hop
reads ``n^2/8`` bytes of packed bits instead of ``n^2`` bool.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from . import clustering, linucb
from .backend import (GraphBackend, InteractBackend, get_backend,
                      get_graph_backend)
from .env_ops import EnvOps
from .types import BanditHyper, ClusterStats, DistCLUBState, Metrics


def init_state(n_users: int, d: int, hyper: BanditHyper) -> DistCLUBState:
    lin = linucb.init_linucb(n_users, d)
    graph = clustering.init_graph(n_users)
    labels = jnp.zeros((n_users,), jnp.int32)  # one big cluster initially
    stats = clustering.cluster_stats(labels, lin.M, lin.b, d)
    rounds = jnp.full((n_users,), hyper.sigma, jnp.int32)
    return DistCLUBState(
        lin=lin,
        graph=graph._replace(labels=labels),
        clusters=stats,
        u_rounds=rounds,
        c_rounds=rounds,
        comm_bytes=jnp.zeros((), jnp.float32),
    )


def _metrics_of(realized, expected, best, rand, mask):
    m = mask.astype(realized.dtype)
    return Metrics(
        reward=jnp.sum(realized * m),
        regret=jnp.sum((best - expected) * m),
        rand_reward=jnp.sum(rand * m),
        interactions=jnp.sum(mask.astype(jnp.int32)),
    )


def _default_backend(state: DistCLUBState, hyper: BanditHyper):
    n, d = state.lin.b.shape
    return get_backend(n, d, hyper.n_candidates)


def stage1(state: DistCLUBState, ops: EnvOps, key: jax.Array,
           hyper: BanditHyper, backend: InteractBackend | None = None):
    """User-based rounds: embarrassingly parallel across users."""
    be = backend or _default_backend(state, hyper)
    lin0 = be.pad_lin(state.lin)                  # pad once per stage
    budget = be.pad_users(state.u_rounds)         # padded users: budget 0

    def step(carry, inp):
        lin = carry
        step_idx, k = inp
        mask = step_idx < budget
        k_ctx, k_rew = jax.random.split(k)
        occ_log = be.unpad_users(lin.occ)
        contexts = ops.contexts_fn(k_ctx, occ_log)
        v = linucb.user_vector(lin.Minv, lin.b)
        x, choice = be.choose(v, lin.Minv, contexts, lin.occ, hyper.alpha)
        realized, expected, best, rand = ops.rewards_fn(
            k_rew, occ_log, contexts, be.unpad_users(choice)
        )
        lin = be.update_lin(lin, x, be.pad_users(realized), mask)
        return lin, _metrics_of(
            realized, expected, best, rand, be.unpad_users(mask)
        )

    steps = jnp.arange(hyper.max_rounds)
    keys = jax.random.split(key, hyper.max_rounds)
    lin, metrics = jax.lax.scan(step, lin0, (steps, keys))
    return state._replace(lin=be.unpad_lin(lin)), metrics


def stage2_comm_bytes(n: int, d: int) -> int:
    """Modeled network bytes of one stage-2 refresh (paper Fig. 3, updated
    for the packed graph engine).  Single source of truth for the driver,
    the tests and the paper benchmarks.

    Per refresh: each user ships (M, b) once into the tree reduction and
    the cluster stats return along the same tree (``2 n (d^2 + d)`` f32
    words); edge pruning all-gathers the user vectors and counts
    (``n (d + 1)`` words); and each pointer-doubling CC hop exchanges the
    n i32 labels — ``ceil(log2 n) + 1`` hops bound the doubling schedule.
    The adjacency itself NEVER crosses the network: it is row-sharded and
    bit-packed, n^2/8 bytes of node-local HBM (32x below the dense bool
    graph; see ``benchmarks/bench_graph.py`` for the HBM model).
    """
    hops = max(1, math.ceil(math.log2(max(n, 2))) + 1)
    return 4 * (2 * n * (d * d + d) + n * (d + 1) + hops * n)


def stage2(state: DistCLUBState, hyper: BanditHyper, d: int,
           graph: GraphBackend | None = None) -> DistCLUBState:
    """Network update, clustering, cluster statistics (the comm stage)."""
    gb = graph or get_graph_backend(state.graph.labels.shape[0])
    lin = state.lin
    v = linucb.user_vector(lin.Minv, lin.b)
    adj = gb.prune(state.graph.adj, v, lin.occ, hyper.gamma)
    labels = gb.cc(adj)
    stats = clustering.cluster_stats(labels, lin.M, lin.b, d)
    # seed 'seen' so that seen/size == mean lifetime occ of the cluster
    # (paper: "average interactions for users in the cluster").
    n = labels.shape[0]
    seen = jax.ops.segment_sum(lin.occ, labels, num_segments=n)
    stats = stats._replace(seen=seen)
    nbytes = jnp.float32(stage2_comm_bytes(n, d))
    return state._replace(
        graph=state.graph._replace(adj=adj, labels=labels),
        clusters=stats,
        comm_bytes=state.comm_bytes + nbytes,
    )


def stage3(state: DistCLUBState, ops: EnvOps, key: jax.Array,
           hyper: BanditHyper, backend: InteractBackend | None = None):
    """Cluster-based rounds with the beta personalization heuristic."""
    be = backend or _default_backend(state, hyper)
    labels = state.graph.labels
    stats = state.clusters
    n = labels.shape[0]

    # Frozen during the stage (the paper's lazy cluster statistics): hoist
    # the per-user snapshots, the cluster user-vector AND the cluster
    # mean-occ out of the scan.  The sharded runtime has always frozen the
    # mean-occ snapshot ("§Perf iteration 2"); the per-scan-step
    # segment_sum + seen[labels] gather here was the one place the
    # single-host driver diverged from that lazy schedule — and two O(n)
    # sweeps per step of pure HBM traffic.
    uMcinv = be.pad_gram(stats.Mcinv[labels])     # [n*, d*, d*]
    ubc = be.pad_vec(stats.bc[labels])            # [n*, d*]
    v_clu = linucb.user_vector(uMcinv, ubc)       # [n*, d*]
    usize = jnp.maximum(stats.size[labels], 1)    # [n]
    mean_occ = be.pad_users(
        stats.seen[labels].astype(jnp.float32) / usize
    )                                             # [n*] frozen snapshot

    lin0 = be.pad_lin(state.lin)
    budget = be.pad_users(state.c_rounds)

    def step(carry, inp):
        lin = carry
        step_idx, k = inp
        mask = step_idx < budget
        k_ctx, k_rew = jax.random.split(k)
        occ_log = be.unpad_users(lin.occ)
        contexts = ops.contexts_fn(k_ctx, occ_log)

        use_own = lin.occ.astype(jnp.float32) >= hyper.beta * mean_occ
        v_own = linucb.user_vector(lin.Minv, lin.b)
        theta = jnp.where(use_own[:, None], v_own, v_clu)
        minv_eff = jnp.where(use_own[:, None, None], lin.Minv, uMcinv)

        x, choice = be.choose(theta, minv_eff, contexts, lin.occ, hyper.alpha)
        realized, expected, best, rand = ops.rewards_fn(
            k_rew, occ_log, contexts, be.unpad_users(choice)
        )
        lin = be.update_lin(lin, x, be.pad_users(realized), mask)
        return lin, _metrics_of(
            realized, expected, best, rand, be.unpad_users(mask)
        )

    steps = jnp.arange(hyper.max_rounds)
    keys = jax.random.split(key, hyper.max_rounds)
    lin, metrics = jax.lax.scan(step, lin0, (steps, keys))
    # the seen-counter update folds into stage end: the per-user number of
    # stage-3 interactions is deterministic (sum over steps of
    # ``step_idx < budget`` = the clipped budget), so one segment_sum
    # replaces max_rounds of them.
    counts = jnp.clip(state.c_rounds, 0, hyper.max_rounds)
    seen = stats.seen + jax.ops.segment_sum(counts, labels, num_segments=n)
    return state._replace(
        lin=be.unpad_lin(lin), clusters=stats._replace(seen=seen)
    ), metrics


def stage4(state: DistCLUBState, hyper: BanditHyper) -> DistCLUBState:
    """Rebalance per-user budgets between personalized / cluster rounds."""
    labels = state.graph.labels
    stats = state.clusters
    size = jnp.maximum(stats.size[labels], 1)
    mean_occ = stats.seen[labels].astype(jnp.float32) / size
    delta = ((state.lin.occ.astype(jnp.float32) - mean_occ) / 2.0).astype(
        jnp.int32
    )
    u_rounds = jnp.clip(state.u_rounds + delta, 0, hyper.max_rounds)
    c_rounds = jnp.clip(state.c_rounds - delta, 0, hyper.max_rounds)
    return state._replace(u_rounds=u_rounds, c_rounds=c_rounds)


def run(
    ops: EnvOps,
    key: jax.Array,
    hyper: BanditHyper,
    n_epochs: int,
    d: int,
    backend: InteractBackend | None = None,
    graph: GraphBackend | None = None,
) -> tuple[DistCLUBState, Metrics, jnp.ndarray]:
    """Run ``n_epochs`` of the four-stage loop.

    ``backend`` selects the interaction engine and ``graph`` the stage-2
    graph engine (default: REPRO_BACKEND env flag, then pallas-iff-TPU;
    ``graph`` follows ``backend``'s kind when not given).  Returns (final
    state, per-scan-step metrics stacked over the whole run, cluster-count
    after each stage-2).
    """
    if backend is None:
        backend = get_backend(ops.n_users, d, hyper.n_candidates)
    if graph is None:
        graph = get_graph_backend(ops.n_users, kind=backend.kind,
                                  interpret=backend.interpret)
    return _run(ops, key, hyper, n_epochs, d, backend, graph)


@partial(jax.jit, static_argnames=("ops", "hyper", "n_epochs", "d", "backend",
                                   "graph"))
def _run(
    ops: EnvOps,
    key: jax.Array,
    hyper: BanditHyper,
    n_epochs: int,
    d: int,
    backend: InteractBackend,
    graph: GraphBackend,
) -> tuple[DistCLUBState, Metrics, jnp.ndarray]:
    state = init_state(ops.n_users, d, hyper)

    def epoch(state, k):
        k1, k3 = jax.random.split(k)
        state, m1 = stage1(state, ops, k1, hyper, backend)
        state = stage2(state, hyper, d, graph)
        n_clu = clustering.num_clusters(state.graph.labels)
        state, m3 = stage3(state, ops, k3, hyper, backend)
        state = stage4(state, hyper)
        metrics = jax.tree.map(
            lambda a, b: jnp.concatenate([a, b]), m1, m3
        )
        return state, (metrics, n_clu)

    keys = jax.random.split(key, n_epochs)
    state, (metrics, n_clusters) = jax.lax.scan(epoch, state, keys)
    metrics = jax.tree.map(lambda x: x.reshape(-1), metrics)
    return state, metrics, n_clusters
