"""DistCLUB single-host driver: the stage engine run with null collectives.

The four stage bodies (paper Listing 3) live ONCE in
``repro.runtime.stages`` — this module binds them to
``NullCollectives`` (one shard, every collective the identity, row0 = 0)
and adapts them to the public ``DistCLUBState`` record that the serving
layer, the checkpoint manager and the tests consume.  The sharded runtime
(``repro.distributed.distclub_shard``) binds the *same* stage functions to
``lax`` collectives inside ``shard_map``; the two drivers cannot drift
because there is no second stage body.

Stage 1  user-based LinUCB rounds        — all users advance in parallel,
                                           masked by ``u_rounds``.
Stage 2  network update + clustering     — edge pruning, connected
                                           components, tree-reduced
                                           cluster statistics.
Stage 3  cluster-based UCB rounds        — as stage 1 but scoring uses
                                           the FROZEN stage-2 cluster
                                           snapshots, except the paper's
                                           beta-heuristic users.
Stage 4  budget rebalancing              — delta = (occ - mean_occ)/2
                                           where ``mean_occ`` is the
                                           STAGE-2 snapshot (same value
                                           stage 3 reads) — unified with
                                           the sharded semantics.

State notes: the engine is M-free (the hot loop carries only ``Minv`` —
Sherman-Morrison + UCB never need the Gram itself).  ``lin.M`` is left
untouched by stages 1/3 (stage 2 recovers M from Minv internally before
the tree reduction); ``run`` refreshes it once after the epoch scan via
:func:`refresh_gram` for the consumers that want the Gram (serving layer
aggregates, checkpoints).  ``clusters.seen`` is the frozen
stage-2 snapshot — stage 3 no longer advances it (the old single-host
behavior that made stage 4 diverge from the sharded runtime).

Parallelism note: the paper serializes interactions *within* a cluster in
stage 3 only because its Spark tasks mutate shared cluster objects.  Here
the cluster statistics are frozen between stage-2 refreshes (exactly the
paper's "lazy" semantics) and only per-user statistics mutate, so every
user advances in parallel without conflicts; cross-step ordering per user
is preserved by the scan.  The regret analysis in paper §4 covers this
schedule — the same lazy-update argument used to justify DCCB's buffering.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from . import clustering, linucb
from ..runtime import stages
from ..runtime.collectives import NullCollectives
from .backend import BackendConfig, GraphBackend, InteractBackend
from .env_ops import EnvOps
from .types import (BanditHyper, ClusterStats, DistCLUBState, GraphState,
                    Metrics)

_NULL = NullCollectives()


def init_state(n_users: int, d: int, hyper: BanditHyper) -> DistCLUBState:
    lin = linucb.init_linucb(n_users, d)
    graph = clustering.init_graph(n_users)
    labels = jnp.zeros((n_users,), jnp.int32)  # one big cluster initially
    stats = clustering.cluster_stats(labels, lin.M, lin.b, d)
    rounds = jnp.full((n_users,), hyper.sigma, jnp.int32)
    return DistCLUBState(
        lin=lin,
        graph=graph._replace(labels=labels),
        clusters=stats,
        u_rounds=rounds,
        c_rounds=rounds,
        comm_bytes=jnp.zeros((), jnp.float32),
    )


def stage2_comm_bytes(n: int, d: int) -> int:
    """Modeled network bytes per stage-2 refresh — the single source of
    truth lives with the stage body (``runtime.stages``)."""
    return stages.stage2_comm_bytes(n, d)


def _default_backend(state: DistCLUBState, hyper: BanditHyper):
    n, d = state.lin.b.shape
    return BackendConfig.create().interact(n, d, hyper.n_candidates)


def _with_lin(state: DistCLUBState, Minv, b, occ) -> DistCLUBState:
    """Fold engine outputs back into the public record.

    ``lin.M`` is deliberately NOT touched here: nothing inside an epoch
    reads it (stage 2 recovers M from Minv itself), so recomputing it per
    stage would be two wasted n x d^3 batched inversions per epoch inside
    the scan.  Use :func:`refresh_gram` (``run`` does, once, after the
    epoch scan) when a coherent Gram is needed — serving aggregates,
    checkpoints."""
    lin = state.lin._replace(Minv=Minv, b=b, occ=occ)
    return state._replace(lin=lin)


def serving_snapshot(state: DistCLUBState):
    """Per-user cluster snapshots ``(uMcinv, ubc, umean_occ)`` gathered
    from the label-indexed stage-2 tables — the FROZEN values stage 3's
    beta heuristic reads, and what the serving layer (``repro.serve``)
    carries between refreshes."""
    labels = state.graph.labels
    stats = state.clusters
    return (stats.Mcinv[labels], stats.bc[labels],
            stages.snapshot_mean_occ(stats.seen, stats.size, labels))


def refresh_gram(state: DistCLUBState) -> DistCLUBState:
    """Recover ``lin.M = inv(lin.Minv)`` (exact up to the accumulated
    Sherman-Morrison fp error) for consumers of the Gram itself."""
    lin = state.lin._replace(M=jnp.linalg.inv(state.lin.Minv))
    return state._replace(lin=lin)


def stage1(state: DistCLUBState, ops: EnvOps, key: jax.Array,
           hyper: BanditHyper, backend: InteractBackend | None = None):
    """User-based rounds: embarrassingly parallel across users."""
    be = backend or _default_backend(state, hyper)
    Minv, b, occ, metrics = stages.personalized_rounds(
        be, ops, hyper, state.lin.Minv, state.lin.b, state.lin.occ,
        state.u_rounds, key, row0=0,
    )
    return _with_lin(state, Minv, b, occ), metrics


def stage2(state: DistCLUBState, hyper: BanditHyper, d: int,
           graph: GraphBackend | None = None) -> DistCLUBState:
    """Network update, clustering, cluster statistics (the comm stage)."""
    gb = graph or BackendConfig.create().graph(state.graph.labels.shape[0])
    res = stages.stage2_refresh(
        _NULL, gb, hyper, d,
        state.lin.Minv, state.lin.b, state.lin.occ, state.graph.adj,
    )
    stats = ClusterStats(
        Mc=res.Mc, Mcinv=jnp.linalg.inv(res.Mc), bc=res.bc,
        size=res.size, seen=res.seen,
    )
    return state._replace(
        graph=GraphState(adj=res.adj, labels=res.labels),
        clusters=stats,
        comm_bytes=state.comm_bytes + res.comm_bytes,
    )


def stage3(state: DistCLUBState, ops: EnvOps, key: jax.Array,
           hyper: BanditHyper, backend: InteractBackend | None = None):
    """Cluster-based rounds with the beta personalization heuristic.

    The per-user cluster snapshots are gathered from the stage-2 tables
    and stay FROZEN for the whole stage — including ``clusters.seen``,
    which this stage no longer advances (stage 4 reads the same stage-2
    snapshot in both runtimes)."""
    be = backend or _default_backend(state, hyper)
    uMcinv, ubc, umean_occ = serving_snapshot(state)
    Minv, b, occ, metrics = stages.cluster_rounds(
        be, ops, hyper, state.lin.Minv, state.lin.b, state.lin.occ,
        state.c_rounds, key, 0, uMcinv, ubc, umean_occ,
    )
    return _with_lin(state, Minv, b, occ), metrics


def stage4(state: DistCLUBState, hyper: BanditHyper) -> DistCLUBState:
    """Rebalance per-user budgets between personalized / cluster rounds
    (against the stage-2 mean-occ snapshot — see the engine docstring)."""
    umean_occ = stages.snapshot_mean_occ(
        state.clusters.seen, state.clusters.size, state.graph.labels)
    u_rounds, c_rounds = stages.stage4_rebalance(
        hyper, state.lin.occ, umean_occ, state.u_rounds, state.c_rounds)
    return state._replace(u_rounds=u_rounds, c_rounds=c_rounds)


def run(
    ops: EnvOps,
    key: jax.Array,
    hyper: BanditHyper,
    n_epochs: int,
    d: int,
    backend: InteractBackend | None = None,
    graph: GraphBackend | None = None,
) -> tuple[DistCLUBState, Metrics, jnp.ndarray]:
    """Run ``n_epochs`` of the four-stage loop.

    ``backend`` selects the interaction engine and ``graph`` the stage-2
    graph engine (default: REPRO_BACKEND env flag, then pallas-iff-TPU;
    ``graph`` follows ``backend``'s kind when not given).  Returns (final
    state, per-scan-step metrics stacked over the whole run, cluster-count
    after each stage-2).
    """
    if backend is None:
        backend = BackendConfig.create().interact(ops.n_users, d,
                                                  hyper.n_candidates)
    if graph is None:
        graph = BackendConfig(
            kind=backend.kind, precision=backend.precision,
        ).graph(ops.n_users, interpret=backend.interpret)
    return _run(ops, key, hyper, n_epochs, d, backend, graph)


@partial(jax.jit, static_argnames=("ops", "hyper", "n_epochs", "d", "backend",
                                   "graph"))
def _run(
    ops: EnvOps,
    key: jax.Array,
    hyper: BanditHyper,
    n_epochs: int,
    d: int,
    backend: InteractBackend,
    graph: GraphBackend,
) -> tuple[DistCLUBState, Metrics, jnp.ndarray]:
    state = init_state(ops.n_users, d, hyper)

    def epoch(state, k):
        k1, k3 = jax.random.split(k)
        state, m1 = stage1(state, ops, k1, hyper, backend)
        state = stage2(state, hyper, d, graph)
        n_clu = clustering.num_clusters(state.graph.labels)
        state, m3 = stage3(state, ops, k3, hyper, backend)
        state = stage4(state, hyper)
        metrics = jax.tree.map(
            lambda a, b: jnp.concatenate([a, b]), m1, m3
        )
        return state, (metrics, n_clu)

    keys = jax.random.split(key, n_epochs)
    state, (metrics, n_clusters) = jax.lax.scan(epoch, state, keys)
    metrics = jax.tree.map(lambda x: x.reshape(-1), metrics)
    return refresh_gram(state), metrics, n_clusters
