"""Vectorized linear-contextual-bandit primitives (the per-user math).

The paper's UCB rule (Listing 1) for a context set K = [k_1..k_K]:

    estimate_j = k_j . w
    bonus_j    = alpha * sqrt(k_j^T Minv k_j) * sqrt(log(1 + occ))
    choice     = argmax_j estimate_j + bonus_j

and the standard rank-1 statistics update

    M += x x^T ;  b += r * x.

We maintain Minv incrementally by Sherman-Morrison (exact for rank-1
updates) instead of re-inverting M — a beyond-paper optimization that turns
the per-interaction cost from O(d^3) to O(d^2).  ``tests/test_linucb.py``
checks it against explicit solves.

The batched versions below are the *reference* implementations; the Pallas
kernels in ``repro.kernels.ucb`` / ``repro.kernels.rank1`` implement the
same contracts for the TPU hot path and are validated against these.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .types import LinUCBState


def init_linucb(n_users: int, d: int, dtype=jnp.float32) -> LinUCBState:
    eye = jnp.broadcast_to(jnp.eye(d, dtype=dtype), (n_users, d, d))
    return LinUCBState(
        M=eye,
        Minv=eye,
        b=jnp.zeros((n_users, d), dtype),
        occ=jnp.zeros((n_users,), jnp.int32),
    )


def user_vector(Minv: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """v = Minv @ b.  Works for single ([d,d],[d]) or batched ([...,d,d],[...,d])."""
    return jnp.einsum("...ij,...j->...i", Minv, b)


def ucb_scores(
    w: jnp.ndarray,          # [d] preference estimate used for exploitation
    Minv: jnp.ndarray,       # [d, d] inverse Gram used for the bonus
    contexts: jnp.ndarray,   # [K, d] candidate item features
    occ: jnp.ndarray,        # [] i32 interaction count
    alpha: float,
) -> jnp.ndarray:
    """Paper's UCB(w, occ, context, Minv): returns [K] scores."""
    estimate = contexts @ w
    quad = jnp.einsum("kd,de,ke->k", contexts, Minv, contexts)
    bonus = alpha * jnp.sqrt(jnp.maximum(quad, 0.0)) * jnp.sqrt(
        jnp.log1p(occ.astype(contexts.dtype))
    )
    return estimate + bonus


def choose(w, Minv, contexts, occ, alpha) -> jnp.ndarray:
    """argmax over the candidate axis; returns [] i32 index."""
    return jnp.argmax(ucb_scores(w, Minv, contexts, occ, alpha))


# Batched (over users) versions ------------------------------------------------

ucb_scores_batch = jax.vmap(ucb_scores, in_axes=(0, 0, 0, 0, None))
choose_batch = jax.vmap(choose, in_axes=(0, 0, 0, 0, None))


def sherman_morrison(Minv: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """(M + x x^T)^-1 from M^-1, for [..., d, d] and [..., d]."""
    Mx = jnp.einsum("...ij,...j->...i", Minv, x)              # [..., d]
    denom = 1.0 + jnp.einsum("...i,...i->...", x, Mx)          # [...]
    outer = jnp.einsum("...i,...j->...ij", Mx, Mx)             # [..., d, d]
    return Minv - outer / denom[..., None, None]


def rank1_update(
    state: LinUCBState,
    user: jnp.ndarray,       # [] i32
    x: jnp.ndarray,          # [d]
    reward: jnp.ndarray,     # []
) -> LinUCBState:
    """Single-interaction update of one user's statistics (functional)."""
    M = state.M.at[user].add(jnp.outer(x, x))
    Minv = state.Minv.at[user].set(sherman_morrison(state.Minv[user], x))
    b = state.b.at[user].add(reward * x)
    occ = state.occ.at[user].add(1)
    return LinUCBState(M, Minv, b, occ)


def masked_batch_update(
    state: LinUCBState,
    x: jnp.ndarray,        # [n, d] one chosen context per user this step
    reward: jnp.ndarray,   # [n]
    mask: jnp.ndarray,     # [n] bool -- users actually active this step
) -> LinUCBState:
    """One interaction for every active user, in parallel.

    Distinct users never alias, so a full-width masked update is exact: it is
    the batched equivalent of the paper's per-user serialized processing
    (serialization across *steps*, parallelism across *users*).
    """
    m = mask.astype(x.dtype)
    xm = x * m[:, None]                       # zero context => identity update
    M = state.M + jnp.einsum("ni,nj->nij", xm, xm)
    Minv = sherman_morrison(state.Minv, xm)
    b = state.b + (reward * m)[:, None] * x
    occ = state.occ + mask.astype(jnp.int32)
    return LinUCBState(M, Minv, b, occ)
