"""Bandit environments.

Three kinds, all pure-functional and PRNG-driven so they compose with scan:

* ``SyntheticEnv`` — planted-cluster linear environment (the paper's
  "Synthetic" dataset and the standard CLUB evaluation protocol): each user
  has a hidden unit vector theta drawn from one of ``n_clusters`` centroids;
  a context set of ``K`` unit vectors is sampled per interaction; the click
  probability of item x for user u is  p = (1 + x . theta_u) / 2  and the
  realized reward is Bernoulli(p) (all paper datasets have 0/1 rewards).

* ``DriftEnv`` — the non-stationary variant of the above (the abstract's
  "content popularity can change rapidly"): the cluster centroids are
  re-drawn every ``drift_period`` interactions, so every user's preference
  vector jumps to a fresh phase table and the learner must re-converge.
  The phase is a pure function of the per-user interaction count, so the
  environment stays stateless and bit-identical under any sharding.

* ``ReplayEnv`` — a logged-interaction environment used by the paper-dataset
  clones in ``repro.data``: item features come from a fixed table and each
  user has a queue of logged candidate sets.  Per-user queues preserve the
  paper's per-user interaction ordering under batched rounds.

All are wrapped into the shard-aware ``EnvOps`` protocol by
``repro.core.env_ops``.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class SyntheticEnv(NamedTuple):
    theta: jnp.ndarray        # [n_users, d] hidden preference vectors
    n_candidates: int

    @property
    def n_users(self) -> int:
        return self.theta.shape[0]

    @property
    def d(self) -> int:
        return self.theta.shape[1]


def make_synthetic_env(
    key: jax.Array,
    n_users: int,
    d: int,
    n_clusters: int,
    n_candidates: int = 20,
    within_cluster_noise: float = 0.0,
) -> tuple[SyntheticEnv, jnp.ndarray]:
    """Planted clustered environment; returns (env, true_labels)."""
    k_cent, k_assign, k_noise = jax.random.split(key, 3)
    centroids = jax.random.normal(k_cent, (n_clusters, d))
    centroids /= jnp.linalg.norm(centroids, axis=-1, keepdims=True)
    labels = jax.random.randint(k_assign, (n_users,), 0, n_clusters)
    theta = centroids[labels]
    if within_cluster_noise > 0:
        theta = theta + within_cluster_noise * jax.random.normal(
            k_noise, theta.shape
        )
    theta /= jnp.linalg.norm(theta, axis=-1, keepdims=True)
    return SyntheticEnv(theta=theta, n_candidates=n_candidates), labels


class DriftEnv(NamedTuple):
    """Non-stationary planted-cluster environment (periodic centroid
    re-draws).  ``theta`` for user ``u`` at interaction count ``occ`` is

        normalize(centroids[min(occ // drift_period, P-1), label_u]
                  + noise_u)

    i.e. each user's hidden preference jumps to a freshly drawn centroid
    table every ``drift_period`` of *their own* interactions.  Keying the
    phase on the per-user count (not a global clock) keeps the environment
    a pure function of ``(occ, user)`` — the property every driver (scan,
    shard_map) relies on — while still modeling rapid popularity change.
    """

    centroids: jnp.ndarray    # [n_phases, n_clusters, d] unit rows
    labels: jnp.ndarray       # [n_users] i32 fixed cluster assignment
    noise: jnp.ndarray        # [n_users, d] per-user within-cluster offset
    drift_period: int
    n_candidates: int

    @property
    def n_users(self) -> int:
        return self.labels.shape[0]

    @property
    def d(self) -> int:
        return self.centroids.shape[-1]

    @property
    def n_phases(self) -> int:
        return self.centroids.shape[0]


def make_drift_env(
    key: jax.Array,
    n_users: int,
    d: int,
    n_clusters: int,
    n_candidates: int = 20,
    drift_period: int = 64,
    n_phases: int = 4,
    within_cluster_noise: float = 0.05,
) -> tuple[DriftEnv, jnp.ndarray]:
    """Planted clustered environment whose centroids re-draw every
    ``drift_period`` interactions; returns (env, true_labels)."""
    k_cent, k_assign, k_noise = jax.random.split(key, 3)
    centroids = jax.random.normal(k_cent, (n_phases, n_clusters, d))
    centroids /= jnp.linalg.norm(centroids, axis=-1, keepdims=True)
    labels = jax.random.randint(k_assign, (n_users,), 0, n_clusters)
    noise = within_cluster_noise * jax.random.normal(k_noise, (n_users, d))
    return DriftEnv(
        centroids=centroids, labels=labels, noise=noise,
        drift_period=drift_period, n_candidates=n_candidates,
    ), labels


def drift_theta(env: DriftEnv, occ: jnp.ndarray, row0=0) -> jnp.ndarray:
    """Current hidden preference vectors for the user slice
    ``[row0, row0 + occ.shape[0])`` at per-user interaction counts ``occ``."""
    n_local = occ.shape[0]
    labels = jax.lax.dynamic_slice_in_dim(env.labels, row0, n_local)
    noise = jax.lax.dynamic_slice_in_dim(env.noise, row0, n_local)
    phase = jnp.clip(occ // env.drift_period, 0, env.n_phases - 1)
    theta = env.centroids[phase, labels] + noise
    return theta / jnp.linalg.norm(theta, axis=-1, keepdims=True)


def sample_contexts(key: jax.Array, shape_prefix, K: int, d: int) -> jnp.ndarray:
    """Unit-norm candidate features: [*shape_prefix, K, d]."""
    x = jax.random.normal(key, (*shape_prefix, K, d))
    return x / jnp.linalg.norm(x, axis=-1, keepdims=True)


def expected_reward(theta_u: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """p(click) in [0,1]; broadcasts over leading axes of x."""
    return 0.5 * (1.0 + jnp.einsum("...d,...d->...", x, theta_u))


def step_rewards(
    key: jax.Array,
    theta_u: jnp.ndarray,     # [..., d]
    contexts: jnp.ndarray,    # [..., K, d]
    choice: jnp.ndarray,      # [...] i32
):
    """Realized Bernoulli reward for the chosen item + regret terms.

    Returns (reward [...], expected [...], best_expected [...], rand_reward [...]).
    ``rand_reward`` is the expected reward of the paper's RAN baseline
    (uniform-random choice) = mean over the candidate set.
    """
    p_all = expected_reward(theta_u[..., None, :], contexts)      # [..., K]
    p_choice = jnp.take_along_axis(p_all, choice[..., None], axis=-1)[..., 0]
    best = jnp.max(p_all, axis=-1)
    rand = jnp.mean(p_all, axis=-1)
    u = jax.random.uniform(key, p_choice.shape)
    realized = (u < p_choice).astype(contexts.dtype)
    return realized, p_choice, best, rand
