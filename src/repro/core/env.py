"""Bandit environments.

Three kinds, all pure-functional and PRNG-driven so they compose with scan:

* ``SyntheticEnv`` — planted-cluster linear environment (the paper's
  "Synthetic" dataset and the standard CLUB evaluation protocol): each user
  has a hidden unit vector theta drawn from one of ``n_clusters`` centroids;
  a context set of ``K`` unit vectors is sampled per interaction; the click
  probability of item x for user u is  p = (1 + x . theta_u) / 2  and the
  realized reward is Bernoulli(p) (all paper datasets have 0/1 rewards).

* ``DriftEnv`` — the non-stationary variant of the above (the abstract's
  "content popularity can change rapidly"): the cluster centroids are
  re-drawn every ``drift_period`` interactions, so every user's preference
  vector jumps to a fresh phase table and the learner must re-converge.
  The phase is a pure function of the per-user interaction count, so the
  environment stays stateless and bit-identical under any sharding.

* ``ReplayEnv`` — a logged-interaction environment used by the paper-dataset
  clones in ``repro.data``: item features come from a fixed table and each
  user has a queue of logged candidate sets.  Per-user queues preserve the
  paper's per-user interaction ordering under batched rounds.

* ``CatalogEnv`` — the item-side scale scenario: a FIXED catalog of
  ``n_items`` embeddings drawn from region centroids (the item-axis mirror
  of the planted user clusters), against which the retrieval engine serves
  its two-stage shortlist -> choose path.  Item drift mirrors ``DriftEnv``
  on the item side: the region centroids re-draw per phase, so "content
  popularity" moves while the user preferences stay put.

All are wrapped into the shard-aware ``EnvOps`` protocol by
``repro.core.env_ops``.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class SyntheticEnv(NamedTuple):
    theta: jnp.ndarray        # [n_users, d] hidden preference vectors
    n_candidates: int

    @property
    def n_users(self) -> int:
        return self.theta.shape[0]

    @property
    def d(self) -> int:
        return self.theta.shape[1]


def make_synthetic_env(
    key: jax.Array,
    n_users: int,
    d: int,
    n_clusters: int,
    n_candidates: int = 20,
    within_cluster_noise: float = 0.0,
) -> tuple[SyntheticEnv, jnp.ndarray]:
    """Planted clustered environment; returns (env, true_labels)."""
    k_cent, k_assign, k_noise = jax.random.split(key, 3)
    centroids = jax.random.normal(k_cent, (n_clusters, d))
    centroids /= jnp.linalg.norm(centroids, axis=-1, keepdims=True)
    labels = jax.random.randint(k_assign, (n_users,), 0, n_clusters)
    theta = centroids[labels]
    if within_cluster_noise > 0:
        theta = theta + within_cluster_noise * jax.random.normal(
            k_noise, theta.shape
        )
    theta /= jnp.linalg.norm(theta, axis=-1, keepdims=True)
    return SyntheticEnv(theta=theta, n_candidates=n_candidates), labels


class DriftEnv(NamedTuple):
    """Non-stationary planted-cluster environment (periodic centroid
    re-draws).  ``theta`` for user ``u`` at interaction count ``occ`` is

        normalize(centroids[min(occ // drift_period, P-1), label_u]
                  + noise_u)

    i.e. each user's hidden preference jumps to a freshly drawn centroid
    table every ``drift_period`` of *their own* interactions.  Keying the
    phase on the per-user count (not a global clock) keeps the environment
    a pure function of ``(occ, user)`` — the property every driver (scan,
    shard_map) relies on — while still modeling rapid popularity change.
    """

    centroids: jnp.ndarray    # [n_phases, n_clusters, d] unit rows
    labels: jnp.ndarray       # [n_users] i32 fixed cluster assignment
    noise: jnp.ndarray        # [n_users, d] per-user within-cluster offset
    drift_period: int
    n_candidates: int

    @property
    def n_users(self) -> int:
        return self.labels.shape[0]

    @property
    def d(self) -> int:
        return self.centroids.shape[-1]

    @property
    def n_phases(self) -> int:
        return self.centroids.shape[0]


def make_drift_env(
    key: jax.Array,
    n_users: int,
    d: int,
    n_clusters: int,
    n_candidates: int = 20,
    drift_period: int = 64,
    n_phases: int = 4,
    within_cluster_noise: float = 0.05,
) -> tuple[DriftEnv, jnp.ndarray]:
    """Planted clustered environment whose centroids re-draw every
    ``drift_period`` interactions; returns (env, true_labels)."""
    k_cent, k_assign, k_noise = jax.random.split(key, 3)
    centroids = jax.random.normal(k_cent, (n_phases, n_clusters, d))
    centroids /= jnp.linalg.norm(centroids, axis=-1, keepdims=True)
    labels = jax.random.randint(k_assign, (n_users,), 0, n_clusters)
    noise = within_cluster_noise * jax.random.normal(k_noise, (n_users, d))
    return DriftEnv(
        centroids=centroids, labels=labels, noise=noise,
        drift_period=drift_period, n_candidates=n_candidates,
    ), labels


def drift_theta(env: DriftEnv, occ: jnp.ndarray, row0=0) -> jnp.ndarray:
    """Current hidden preference vectors for the user slice
    ``[row0, row0 + occ.shape[0])`` at per-user interaction counts ``occ``."""
    n_local = occ.shape[0]
    labels = jax.lax.dynamic_slice_in_dim(env.labels, row0, n_local)
    noise = jax.lax.dynamic_slice_in_dim(env.noise, row0, n_local)
    phase = jnp.clip(occ // env.drift_period, 0, env.n_phases - 1)
    theta = env.centroids[phase, labels] + noise
    return theta / jnp.linalg.norm(theta, axis=-1, keepdims=True)


class CatalogEnv(NamedTuple):
    """Fixed-catalog environment (the retrieval engine's workload).

    Users keep the planted-cluster hidden preferences of ``SyntheticEnv``;
    items are persistent: item ``i`` lives in region ``item_region[i]``
    and its embedding at phase ``p`` is

        normalize(region_centroids[p, item_region[i]] + item_noise[i])

    With ``drift_period > 0`` a user at interaction count ``occ`` sees
    phase ``min(occ // drift_period, P-1)`` — centroid re-draw over
    catalog regions, the item-side mirror of ``DriftEnv`` (and like it, a
    pure function of ``(occ, user, item)``, so any sharding of users or
    items reproduces identical draws).  ``drift_period == 0`` pins
    phase 0: one static catalog, the pure scale scenario.
    """

    theta: jnp.ndarray             # [n_users, d] hidden user preferences
    region_centroids: jnp.ndarray  # [n_phases, n_regions, d] unit rows
    item_region: jnp.ndarray       # [n_items] i32
    item_noise: jnp.ndarray        # [n_items, d]
    drift_period: int
    n_candidates: int

    @property
    def n_users(self) -> int:
        return self.theta.shape[0]

    @property
    def d(self) -> int:
        return self.theta.shape[1]

    @property
    def n_items(self) -> int:
        return self.item_region.shape[0]

    @property
    def n_phases(self) -> int:
        return self.region_centroids.shape[0]


def make_catalog_env(
    key: jax.Array,
    n_users: int,
    d: int,
    n_clusters: int,
    n_items: int,
    n_regions: int | None = None,
    n_candidates: int = 20,
    drift_period: int = 0,
    n_phases: int = 1,
    within_cluster_noise: float = 0.05,
    item_noise_scale: float = 0.05,
) -> tuple[CatalogEnv, jnp.ndarray]:
    """Planted users + region-structured item catalog; returns
    ``(env, true_user_labels)``."""
    if n_regions is None:
        n_regions = n_clusters
    k_u, k_rc, k_ir, k_in = jax.random.split(key, 4)
    user_env, labels = make_synthetic_env(
        k_u, n_users, d, n_clusters, n_candidates=n_candidates,
        within_cluster_noise=within_cluster_noise)
    centroids = jax.random.normal(k_rc, (n_phases, n_regions, d))
    centroids /= jnp.linalg.norm(centroids, axis=-1, keepdims=True)
    region = jax.random.randint(k_ir, (n_items,), 0, n_regions)
    noise = item_noise_scale * jax.random.normal(k_in, (n_items, d))
    return CatalogEnv(
        theta=user_env.theta, region_centroids=centroids,
        item_region=region, item_noise=noise,
        drift_period=drift_period, n_candidates=n_candidates,
    ), labels


def catalog_embeddings(env: CatalogEnv, phase: int = 0) -> jnp.ndarray:
    """The full ``[n_items, d]`` unit-norm catalog at ``phase`` —
    materialize once into a ``core.catalog.Catalog`` for serving."""
    e = env.region_centroids[phase, env.item_region] + env.item_noise
    return e / jnp.linalg.norm(e, axis=-1, keepdims=True)


def sample_churn_items(env: CatalogEnv, key: jax.Array, m: int,
                       region: int | None = None, phase: int = 0,
                       noise_scale: float = 0.05
                       ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Draw ``m`` FRESH items consistent with the planted region
    structure — the churn-scenario generator: trending arrivals land in
    existing regions, so the retrieval engine's item-side structure
    stays learnable through churn.  ``region`` pins every arrival to one
    region (the flash-crowd scenario); None scatters them uniformly.
    Returns ``(emb [m, d] unit rows, regions [m] i32)``."""
    k_r, k_n = jax.random.split(key)
    if region is None:
        regions = jax.random.randint(k_r, (m,), 0,
                                     env.region_centroids.shape[1])
    else:
        regions = jnp.full((m,), region, jnp.int32)
    e = (env.region_centroids[phase, regions]
         + noise_scale * jax.random.normal(k_n, (m, env.d)))
    return e / jnp.linalg.norm(e, axis=-1, keepdims=True), regions


def region_item_ids(env: CatalogEnv, region: int):
    """Host-side ids of the ORIGINAL catalog items planted in
    ``region`` — the mass-retirement scenario retires a whole region at
    once (variable length, so host numpy, not a traced op)."""
    import numpy as np
    return np.nonzero(np.asarray(env.item_region) == region)[0].astype(
        np.int32)


def catalog_phase(env: CatalogEnv, occ: jnp.ndarray) -> jnp.ndarray:
    """Per-user drift phase from the per-user interaction count."""
    if env.drift_period <= 0:
        return jnp.zeros(occ.shape, jnp.int32)
    return jnp.clip(occ // env.drift_period, 0, env.n_phases - 1)


def sample_contexts(key: jax.Array, shape_prefix, K: int, d: int) -> jnp.ndarray:
    """Unit-norm candidate features: [*shape_prefix, K, d]."""
    x = jax.random.normal(key, (*shape_prefix, K, d))
    return x / jnp.linalg.norm(x, axis=-1, keepdims=True)


def expected_reward(theta_u: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """p(click) in [0,1]; broadcasts over leading axes of x."""
    return 0.5 * (1.0 + jnp.einsum("...d,...d->...", x, theta_u))


def step_rewards(
    key: jax.Array,
    theta_u: jnp.ndarray,     # [..., d]
    contexts: jnp.ndarray,    # [..., K, d]
    choice: jnp.ndarray,      # [...] i32
):
    """Realized Bernoulli reward for the chosen item + regret terms.

    Returns (reward [...], expected [...], best_expected [...], rand_reward [...]).
    ``rand_reward`` is the expected reward of the paper's RAN baseline
    (uniform-random choice) = mean over the candidate set.
    """
    p_all = expected_reward(theta_u[..., None, :], contexts)      # [..., K]
    p_choice = jnp.take_along_axis(p_all, choice[..., None], axis=-1)[..., 0]
    best = jnp.max(p_all, axis=-1)
    rand = jnp.mean(p_all, axis=-1)
    u = jax.random.uniform(key, p_choice.shape)
    realized = (u < p_choice).astype(contexts.dtype)
    return realized, p_choice, best, rand
