"""Shared model layers (pure-functional, params as nested dicts).

Every ``init_*`` has a matching ``*_specs`` producing a PartitionSpec
pytree of the same structure; the dryrun/launcher zips them to build
NamedShardings.  Convention for spec names: "model" = tensor-parallel
axis, "data" = fsdp/zero axis; the mesh mapper in launch/mesh.py resolves
them to physical axes (and prepends "pod" where needed).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps)).astype(x.dtype) * scale


def init_rms_norm(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype)}


def rms_norm_specs():
    return {"scale": P()}


def layer_norm(x, scale, bias, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps)).astype(x.dtype) * scale + bias


def init_layer_norm(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layer_norm_specs():
    return {"scale": P(), "bias": P()}


def dense_init(key, d_in: int, d_out: int, dtype=jnp.float32):
    scale = (2.0 / (d_in + d_out)) ** 0.5
    return jax.random.normal(key, (d_in, d_out), dtype) * scale


# --- rotary position embedding -------------------------------------------------

def rope_freqs(d_head: int, base: float = 10000.0):
    return 1.0 / (base ** (jnp.arange(0, d_head, 2, jnp.float32) / d_head))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, base: float = 10000.0):
    """x: [..., S, Dh]; positions: [S] (or broadcastable)."""
    d_head = x.shape[-1]
    freqs = rope_freqs(d_head, base)                        # [Dh/2]
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # [S, Dh/2]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x, 2, axis=-1)
    rot = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return rot.astype(x.dtype)


# --- MLPs ---------------------------------------------------------------------

def init_swiglu(key, d: int, f: int, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "gate": dense_init(k1, d, f, dtype),
        "up": dense_init(k2, d, f, dtype),
        "down": dense_init(k3, f, d, dtype),
    }


def swiglu_specs():
    return {
        "gate": P(None, "model"),
        "up": P(None, "model"),
        "down": P("model", None),
    }


def swiglu(params, x):
    h = jax.nn.silu(x @ params["gate"]) * (x @ params["up"])
    return h @ params["down"]


def init_mlp(key, d_in: int, hidden: tuple[int, ...], d_out: int | None = None,
             dtype=jnp.float32):
    """Plain relu MLP (recsys towers).  Layout: list of {w, b}."""
    dims = [d_in, *hidden] + ([d_out] if d_out is not None else [])
    keys = jax.random.split(key, len(dims) - 1)
    return [
        {"w": dense_init(k, a, b, dtype), "b": jnp.zeros((b,), dtype)}
        for k, a, b in zip(keys, dims[:-1], dims[1:])
    ]


def mlp_specs(n_layers: int):
    return [{"w": P(None, "model"), "b": P("model")} if i % 2 == 0
            else {"w": P("model", None), "b": P()}
            for i in range(n_layers)]


def mlp(params, x, final_act: bool = False):
    for i, lyr in enumerate(params):
        x = x @ lyr["w"] + lyr["b"]
        if i < len(params) - 1 or final_act:
            x = jax.nn.relu(x)
    return x
