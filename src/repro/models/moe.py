"""Mixture-of-Experts FFN (capacity dispatch via scatter, + shared experts).

Covers both assigned MoE archs:
  * llama4-maverick: 128 routed experts, top-1, 1 shared expert,
    MoE on alternating layers.
  * deepseek-moe-16b: 64 fine-grained routed experts, top-6, 2 shared
    experts, every layer (arXiv:2401.06066).

Dispatch: the classic GShard one-hot dispatch tensor is [T, E, C] — at the
assigned llama4 training shape (T = 1M tokens, E = 128, C = 10k) that is
10^12 elements, which no amount of sharding saves.  We instead compute each
(token, choice)'s slot = expert*C + position-in-expert-queue and
scatter-add tokens into a [E*C, d] buffer (drop beyond capacity, Switch
semantics), run the three stacked expert GEMMs on [E, C, d], and gather
back.  Buffer memory is E*C*d — independent of the dispatch blow-up — and
scatter/gather differentiate as gather/scatter-add.  Expert weights are
stacked [E, ...], sharded over "model" (EP) and over "data" on the d_ff
dim (ZeRO-3 style; pjit all-gathers them per layer).

A shared expert runs densely on every token (no routing).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..distributed import sharding
from . import layers


def init_moe(key, cfg, dtype=jnp.bfloat16):
    """cfg: d_model, d_ff_expert, n_experts, n_shared, top_k, capacity_factor."""
    k_r, k_e, k_s = jax.random.split(key, 3)
    d, f, E = cfg.d_model, cfg.d_ff_expert, cfg.n_experts
    ke = jax.random.split(k_e, 3)
    p = {
        "router": layers.dense_init(k_r, d, E, jnp.float32),
        "experts": {
            "gate": jax.vmap(
                lambda k: layers.dense_init(k, d, f, dtype)
            )(jax.random.split(ke[0], E)),
            "up": jax.vmap(
                lambda k: layers.dense_init(k, d, f, dtype)
            )(jax.random.split(ke[1], E)),
            "down": jax.vmap(
                lambda k: layers.dense_init(k, f, d, dtype)
            )(jax.random.split(ke[2], E)),
        },
    }
    if cfg.n_shared > 0:
        p["shared"] = layers.init_swiglu(k_s, d, f * cfg.n_shared, dtype)
    return p


def moe_specs(cfg):
    """Training layout: EP over "model" on the expert axis + ZeRO-3 over
    "data" on d_ff.  §Perf iteration 1 (REFUTED hypothesis, kept for the
    record): replicating experts across "data" to avoid the per-microbatch
    weight gathers needs 48 GiB/device at llama4 scale (386B expert params
    / 16 model shards x bf16) — ZeRO-3 expert sharding is load-bearing on
    16 GiB chips, and the per-microbatch gather volume is instead tuned via
    the microbatch count (EXPERIMENTS.md §Perf)."""
    p = {
        "router": P(),
        "experts": {
            "gate": P("model", None, "data"),
            "up": P("model", None, "data"),
            "down": P("model", "data", None),
        },
    }
    if cfg.n_shared > 0:
        p["shared"] = layers.swiglu_specs()
    return p


def moe_fwd(params, cfg, x: jnp.ndarray):
    """x: [B, S, d] -> ([B, S, d], aux_loss)."""
    B, S, d = x.shape
    T = B * S
    E, k = cfg.n_experts, cfg.top_k
    xt = x.reshape(T, d)

    logits = xt.astype(jnp.float32) @ params["router"]        # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)             # [T, k]
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9
    )

    # load-balancing auxiliary loss (Switch eq. 4)
    me = jnp.mean(probs, axis=0)
    ce = jnp.zeros((E,), jnp.float32).at[gate_idx.reshape(-1)].add(
        1.0 / (T * k)
    )
    aux = E * jnp.sum(me * ce)

    C = int(max(1, round(T * k / E * cfg.capacity_factor)))

    # queue position of each (token, choice) within its expert
    flat_e = gate_idx.reshape(T * k)                          # [T*k]
    onehot = (flat_e[:, None] == jnp.arange(E)[None, :]).astype(jnp.int32)
    pos = (
        jnp.take_along_axis(jnp.cumsum(onehot, axis=0), flat_e[:, None], 1)
        [:, 0] - 1
    )                                                          # [T*k]
    keep = (pos < C).astype(xt.dtype)                          # [T*k]
    slot = flat_e * C + jnp.minimum(pos, C - 1)                # [T*k]

    x_rep = jnp.repeat(xt, k, axis=0)                          # [T*k, d]
    buf = jnp.zeros((E * C, d), xt.dtype).at[slot].add(
        x_rep * keep[:, None]
    )
    # pin expert-parallel layouts: buffers shard over "model" on E so the
    # scatter lowers to a reduce into EP shards instead of replicating
    ex_in = sharding.hint(buf.reshape(E, C, d), "model", None, None)

    we = params["experts"]
    h = jax.nn.silu(
        jnp.einsum("ecd,edf->ecf", ex_in, we["gate"])
    ) * jnp.einsum("ecd,edf->ecf", ex_in, we["up"])
    h = sharding.hint(h, "model", None, None)
    ex_out = jnp.einsum("ecf,efd->ecd", h, we["down"])
    ex_out = sharding.hint(ex_out, "model", None, None).reshape(E * C, d)

    back = ex_out[slot]                                        # [T*k, d]
    back = back * (keep * gate_vals.reshape(T * k).astype(xt.dtype))[:, None]
    out = jnp.sum(back.reshape(T, k, d), axis=1)

    if cfg.n_shared > 0:
        out = out + layers.swiglu(params["shared"], xt)
    return out.reshape(B, S, d), aux
