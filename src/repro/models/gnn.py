"""GAT (arXiv:1710.10903) via segment ops — the JAX-native message-passing path.

JAX sparse is BCOO-only, so (per the assignment) message passing is built
from first principles: SDDMM-style edge scores -> per-destination segment
softmax (segment_max / segment_sum) -> SpMM-style weighted scatter.  All
four assigned shapes flow through the same forward:

  full_graph_sm / ogb_products : full-batch edge list
  minibatch_lg                 : fixed-fanout sampled blocks (see
                                 ``NeighborSampler``; host-side, per the
                                 production pattern of feeding fixed-shape
                                 device batches)
  molecule                     : batched small graphs = one disjoint union
                                 (edge ids offset per graph)

Sharding: nodes (and per-node features/labels) are row-sharded over the
flattened mesh; the edge list is sharded by destination block so the
segment reductions stay shard-local; source-feature fetches are global
takes that GSPMD lowers to gather collectives.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from . import layers


@dataclasses.dataclass(frozen=True)
class GNNConfig:
    name: str = "gat"
    n_layers: int = 2
    d_hidden: int = 8
    n_heads: int = 8
    d_feat: int = 1433
    n_classes: int = 7
    dtype: Any = jnp.float32
    # §Perf iteration (ogb_products cell): the per-layer node-feature
    # all_gather dominates (collective-bound); int8 gathers with per-row
    # scales halve the bf16 gather bytes (straight-through gradients; the
    # backward reduce-scatter stays f32).  Off by default — enabled by the
    # large full-graph cell config.
    quantized_gather: bool = False


def init_gat(key, cfg: GNNConfig):
    params = []
    d_in = cfg.d_feat
    for i in range(cfg.n_layers):
        last = i == cfg.n_layers - 1
        dh = cfg.n_classes if last else cfg.d_hidden
        k1, k2, k3, key = jax.random.split(key, 4)
        params.append({
            "W": layers.dense_init(k1, d_in, cfg.n_heads * dh, cfg.dtype),
            "a_src": jax.random.normal(k2, (cfg.n_heads, dh), cfg.dtype) * 0.1,
            "a_dst": jax.random.normal(k3, (cfg.n_heads, dh), cfg.dtype) * 0.1,
        })
        d_in = cfg.n_heads * dh if not last else cfg.n_classes
    return params


def gat_specs(cfg: GNNConfig):
    # GAT params are tiny (~100k); replicate them and let nodes/edges carry
    # all the parallelism (head counts like 8 don't divide a 16-way axis).
    return [
        {"W": P(), "a_src": P(), "a_dst": P()}
        for _ in range(cfg.n_layers)
    ]


def _gat_layer(p, x, src, dst, n_nodes, n_heads, dh, *, last: bool):
    h = (x @ p["W"]).reshape(-1, n_heads, dh)             # [N, H, dh]
    alpha_src = jnp.sum(h * p["a_src"], axis=-1)           # [N, H]
    alpha_dst = jnp.sum(h * p["a_dst"], axis=-1)
    e = jax.nn.leaky_relu(alpha_src[src] + alpha_dst[dst], 0.2)  # [E, H]
    # per-destination segment softmax
    m = jax.ops.segment_max(e, dst, num_segments=n_nodes)
    m = jnp.where(jnp.isfinite(m), m, 0.0)
    pexp = jnp.exp(e - m[dst])
    z = jax.ops.segment_sum(pexp, dst, num_segments=n_nodes)
    att = pexp / jnp.maximum(z[dst], 1e-9)                 # [E, H]
    msg = att[..., None] * h[src]                          # [E, H, dh]
    out = jax.ops.segment_sum(msg, dst, num_segments=n_nodes)
    if last:
        return jnp.mean(out, axis=1)                       # [N, n_classes]
    return jax.nn.elu(out.reshape(n_nodes, n_heads * dh))


def gat_fwd(params, cfg: GNNConfig, feats, src, dst):
    """feats [N, F], src/dst [E] i32 -> logits [N, n_classes]."""
    n = feats.shape[0]
    x = feats
    for i, p in enumerate(params):
        last = i == cfg.n_layers - 1
        dh = cfg.n_classes if last else cfg.d_hidden
        x = _gat_layer(p, x, src, dst, n, cfg.n_heads, dh, last=last)
    return x


def gat_loss(params, cfg: GNNConfig, feats, src, dst, labels, mask):
    logits = gat_fwd(params, cfg, feats, src, dst).astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    m = mask.astype(jnp.float32)
    return jnp.sum((lse - ll) * m) / jnp.maximum(jnp.sum(m), 1.0)


# --- sharded message passing ---------------------------------------------------
#
# GSPMD's scatter handling replicates edge tensors (28 GiB/device for
# ogbn-products in the dry-run) — so the distributed path is an explicit
# shard_map with the production-GNN layout contract: edges are partitioned
# by DESTINATION block (each device's edge shard has dst inside its node
# shard), making every segment reduction shard-local.  The only collective
# is one all_gather of the (small) node embeddings per layer so edge
# sources can read remote rows.


def _gat_layer_local(p, h_all, src, dst_global, dst_local, n_local, n_heads,
                     dh, *, last):
    """h_all: gathered [N, H*dh_in] node features; src/dst_global: global
    ids; dst_local in [0, n_local).  Returns [n_local, ...]."""
    h = h_all.reshape(h_all.shape[0], n_heads, dh)
    alpha_src = jnp.sum(h * p["a_src"], axis=-1)            # [N, H]
    alpha_dst = jnp.sum(h * p["a_dst"], axis=-1)
    e = jax.nn.leaky_relu(alpha_src[src] + alpha_dst[dst_global], 0.2)
    m = jax.ops.segment_max(e, dst_local, num_segments=n_local)
    m = jnp.where(jnp.isfinite(m), m, 0.0)
    pexp = jnp.exp(e - m[dst_local])
    z = jax.ops.segment_sum(pexp, dst_local, num_segments=n_local)
    att = pexp / jnp.maximum(z[dst_local], 1e-9)
    msg = att[..., None] * h[src]
    out = jax.ops.segment_sum(msg, dst_local, num_segments=n_local)
    if last:
        return jnp.mean(out, axis=1)
    return jax.nn.elu(out.reshape(n_local, n_heads * dh))


def gat_loss_local(params, cfg: GNNConfig, feats, src, dst, labels, mask,
                   axes):
    """Per-shard GAT loss body (runs inside shard_map).

    feats/labels/mask: this shard's node rows; src/dst: this shard's edges
    (dst guaranteed local by the dst-block partitioning contract); ids are
    global — dst is localized with the shard's row offset.
    """
    n_local = feats.shape[0]
    idx = jax.lax.axis_index(axes)
    row0 = (idx * n_local).astype(dst.dtype)
    dst_local = jnp.clip(dst - row0, 0, n_local - 1)

    def make_gather():
        """all_gather of node features; int8 per-row-scale quantized when
        cfg.quantized_gather (custom_vjp: the backward is the exact
        reduce-scatter of the cotangents — quantization only touches the
        forward traffic)."""
        if not cfg.quantized_gather:
            return lambda h: jax.lax.all_gather(
                h.astype(jnp.bfloat16), axes, tiled=True
            ).astype(jnp.float32)

        @jax.custom_vjp
        def qg(h):
            scale = jnp.maximum(
                jnp.max(jnp.abs(h), axis=-1, keepdims=True) / 127.0, 1e-9)
            q = jnp.clip(jnp.round(h / scale), -127, 127).astype(jnp.int8)
            q_all = jax.lax.all_gather(q, axes, tiled=True)
            s_all = jax.lax.all_gather(scale.astype(jnp.bfloat16), axes,
                                       tiled=True)
            return q_all.astype(jnp.float32) * s_all.astype(jnp.float32)

        def fwd(h):
            return qg(h), None

        def bwd(_, ct):
            return (jax.lax.psum_scatter(ct, axes, scatter_dimension=0,
                                         tiled=True),)

        qg.defvjp(fwd, bwd)
        return qg

    gather_features = make_gather()

    x_local = feats
    for i, p in enumerate(params):
        last = i == cfg.n_layers - 1
        dh = cfg.n_classes if last else cfg.d_hidden
        h_local = x_local @ p["W"]
        h_all = gather_features(h_local)                     # [N, H*dh]
        x_local = _gat_layer_local(
            p, h_all, src, dst, dst_local, n_local, cfg.n_heads, dh,
            last=last)

    logits = x_local.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    mf = mask.astype(jnp.float32)
    num = jax.lax.psum(jnp.sum((lse - ll) * mf), axes)
    den = jax.lax.psum(jnp.sum(mf), axes)
    return num / jnp.maximum(den, 1.0)


# --- neighbor sampler (host side) ------------------------------------------------


class NeighborSampler:
    """Fixed-fanout k-hop sampler over a CSR adjacency (numpy, host side).

    Produces fixed-shape padded blocks — the device graph never changes
    shape, which is what keeps the sampled-training path jit/pjit friendly
    (and straggler-free: every round is the same amount of work).
    """

    def __init__(self, n_nodes: int, src: np.ndarray, dst: np.ndarray):
        order = np.argsort(dst, kind="stable")
        self.nbr = src[order]
        counts = np.bincount(dst, minlength=n_nodes)
        self.offsets = np.concatenate([[0], np.cumsum(counts)])
        self.n_nodes = n_nodes

    def sample(self, rng: np.random.Generator, seeds: np.ndarray,
               fanouts: tuple[int, ...]):
        """Sample a fixed-fanout union subgraph around ``seeds``.

        Returns (nodes [N_tot] global ids, src [E], dst [E] local indices
        into ``nodes``).  Shapes depend only on (len(seeds), fanouts):
        N_tot = seeds * (1 + f1 + f1*f2 + ...), E = seeds * (f1 + f1*f2 + ...).
        Missing neighbors pad with self-loops (the standard self-edge
        convention), keeping every round identically shaped.
        """
        frontier = seeds
        nodes = [seeds]
        srcs, dsts = [], []
        base = 0
        for f in fanouts:
            lo = self.offsets[frontier]
            hi = self.offsets[frontier + 1]
            deg = hi - lo
            r = rng.integers(0, np.maximum(deg, 1)[:, None],
                             (len(frontier), f))
            idx = lo[:, None] + r
            picked = np.where(
                deg[:, None] > 0, self.nbr[np.minimum(idx, len(self.nbr) - 1)],
                frontier[:, None],   # isolated node -> self loop
            )
            new = picked.reshape(-1)
            srcs.append(base + len(frontier) + np.arange(len(new), dtype=np.int64))
            dsts.append(base + np.repeat(np.arange(len(frontier), dtype=np.int64), f))
            base += len(frontier)
            nodes.append(new)
            frontier = new
        return (
            np.concatenate(nodes),
            np.concatenate(srcs).astype(np.int32),
            np.concatenate(dsts).astype(np.int32),
        )
