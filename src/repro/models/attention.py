"""GQA attention with RoPE, optional qk-norm, KV cache, chunked softmax.

Two execution paths with identical semantics:

* ``repro.kernels.flash`` Pallas kernel — the TPU target.
* ``chunked_attention`` below — an XLA-level flash equivalent (lax.scan
  over KV chunks with online softmax).  The [Sq, Skv] score matrix never
  materializes, so compiled memory/cost reflect the real algorithm.  This
  is what the CPU dry-run lowers (Mosaic kernels don't compile on the CPU
  backend) and is also the long-context fallback on TPU.

The KV cache is laid out [B, Hkv, S_max, Dh] per layer (stacked to
[L, ...] by the scan-over-layers transformer); decode writes one position
and attends to the first ``pos+1`` entries via masking.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..kernels.flash import ops as flash_ops
from . import layers


def chunked_attention(
    q: jnp.ndarray,        # [B, Hq, Sq, Dh]
    k: jnp.ndarray,        # [B, Hkv, Skv, Dh]
    v: jnp.ndarray,        # [B, Hkv, Skv, Dh]
    *,
    causal: bool,
    q_offset: jnp.ndarray | int = 0,
    kv_len: jnp.ndarray | None = None,   # valid cache length (decode)
    chunk: int = 1024,
) -> jnp.ndarray:
    """Online-softmax attention, scanning KV in chunks."""
    B, Hq, Sq, Dh = q.shape
    _, Hkv, Skv, _ = k.shape
    group = Hq // Hkv
    scale = Dh ** -0.5
    chunk = min(chunk, Skv)
    assert Skv % chunk == 0, (Skv, chunk)
    n_chunks = Skv // chunk

    # fold q heads onto kv heads: [B, Hkv, group, Sq, Dh]
    qg = q.reshape(B, Hkv, group, Sq, Dh)
    kc = k.reshape(B, Hkv, n_chunks, chunk, Dh)
    vc = v.reshape(B, Hkv, n_chunks, chunk, Dh)
    kc = jnp.moveaxis(kc, 2, 0)       # [n_chunks, B, Hkv, chunk, Dh]
    vc = jnp.moveaxis(vc, 2, 0)

    qpos = jnp.arange(Sq) + q_offset                    # [Sq]

    def step(carry, inp):
        acc, m, l = carry
        kj, vj, j = inp
        s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, kj) * scale
        kpos = j * chunk + jnp.arange(chunk)            # [chunk]
        mask = jnp.ones((Sq, chunk), bool)
        if causal:
            mask &= qpos[:, None] >= kpos[None, :]
        if kv_len is not None:
            mask &= kpos[None, :] < kv_len
        s = jnp.where(mask[None, None, None], s, -jnp.inf)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        # guard fully-masked rows (m_new = -inf)
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(s - m_safe[..., None])
        p = jnp.where(mask[None, None, None], p, 0.0)
        corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
        l = corr * l + jnp.sum(p, axis=-1)
        acc = corr[..., None] * acc + jnp.einsum(
            "bhgqk,bhkd->bhgqd", p.astype(vj.dtype), vj
        ).astype(jnp.float32)
        return (acc, m_new, l), None

    acc0 = jnp.zeros((B, Hkv, group, Sq, Dh), jnp.float32)
    m0 = jnp.full((B, Hkv, group, Sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, Hkv, group, Sq), jnp.float32)
    (acc, m, l), _ = jax.lax.scan(
        step, (acc0, m0, l0), (kc, vc, jnp.arange(n_chunks))
    )
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.reshape(B, Hq, Sq, Dh).astype(q.dtype)


# --- full attention block -------------------------------------------------------


def init_attention(key, cfg, dtype=jnp.bfloat16):
    """cfg needs: d_model, n_heads, n_kv_heads, d_head, qk_norm."""
    k1, k2, k3, k4 = jax.random.split(key, 4)
    d, H, Hkv, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    p = {
        "wq": layers.dense_init(k1, d, H * Dh, dtype),
        "wk": layers.dense_init(k2, d, Hkv * Dh, dtype),
        "wv": layers.dense_init(k3, d, Hkv * Dh, dtype),
        "wo": layers.dense_init(k4, H * Dh, d, dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = layers.init_rms_norm(Dh, jnp.float32)
        p["k_norm"] = layers.init_rms_norm(Dh, jnp.float32)
    return p


def attention_specs(cfg):
    p = {
        "wq": P(None, "model"),
        "wk": P(None, "model"),
        "wv": P(None, "model"),
        "wo": P("model", None),
    }
    if cfg.qk_norm:
        p["q_norm"] = layers.rms_norm_specs()
        p["k_norm"] = layers.rms_norm_specs()
    return p


def attention_fwd(
    params, cfg, x: jnp.ndarray,
    *,
    positions: jnp.ndarray,          # [S] absolute positions of x tokens
    cache: tuple | None = None,      # (k_cache, v_cache) [B,Hkv,Smax,Dh]
    cache_pos: jnp.ndarray | int = 0,  # write offset into the cache
    causal: bool = True,
    attn_chunk: int = 1024,
):
    """Returns (out [B,S,d], new_cache)."""
    B, S, d = x.shape
    H, Hkv, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head

    q = (x @ params["wq"]).reshape(B, S, H, Dh)
    k = (x @ params["wk"]).reshape(B, S, Hkv, Dh)
    v = (x @ params["wv"]).reshape(B, S, Hkv, Dh)
    if cfg.qk_norm:
        q = layers.rms_norm(q, params["q_norm"]["scale"]).astype(q.dtype)
        k = layers.rms_norm(k, params["k_norm"]["scale"]).astype(k.dtype)
    q = layers.apply_rope(q.swapaxes(1, 2), positions, cfg.rope_base)
    k = layers.apply_rope(k.swapaxes(1, 2), positions, cfg.rope_base)
    v = v.swapaxes(1, 2)

    if cache is None:
        out = chunked_attention(
            q, k, v, causal=causal, q_offset=0, chunk=attn_chunk
        )
        new_cache = None
    else:
        kc, vc = cache
        kc = jax.lax.dynamic_update_slice_in_dim(kc, k, cache_pos, axis=2)
        vc = jax.lax.dynamic_update_slice_in_dim(vc, v, cache_pos, axis=2)
        kv_len = cache_pos + S
        out = chunked_attention(
            q, kc, vc, causal=causal, q_offset=cache_pos, kv_len=kv_len,
            chunk=attn_chunk,
        )
        new_cache = (kc, vc)

    out = out.swapaxes(1, 2).reshape(B, S, H * Dh)
    return out @ params["wo"], new_cache
