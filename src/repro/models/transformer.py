"""Decoder-only transformer LM (dense + MoE), scan-over-layers, KV-cache decode.

Design points for the multi-pod target:
  * ``lax.scan`` over the layer stack — one layer's HLO regardless of depth
    (compile time, uniform remat) with params stacked on a leading L dim.
  * remat on the layer body ("nothing saved but layer inputs") so train
    activations are O(L * B * S * d) instead of O(L * B * S * (d + f + scores)).
  * alternating dense/MoE supported via ``moe_every`` (llama4 = 2): the scan
    body is a *block* of ``moe_every`` layers (dense layers then one MoE).
  * logits stay vocab-sharded ("model" axis); the loss uses a logsumexp
    that pjit reduces across the vocab shards — the full [B,S,V] logits
    never assemble on one device.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from . import attention, layers, moe


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str = "lm"
    n_layers: int = 4
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    d_head: int = 64
    d_ff: int = 1024
    vocab: int = 1024
    qk_norm: bool = False
    rope_base: float = 10000.0
    # MoE (n_experts=0 -> dense)
    n_experts: int = 0
    top_k: int = 1
    n_shared: int = 0
    d_ff_expert: int = 0
    capacity_factor: float = 1.25
    moe_every: int = 1          # MoE on every k-th layer (llama4: 2)
    dtype: Any = jnp.bfloat16
    attn_chunk: int = 1024
    remat: bool = True
    microbatches: int = 1       # grad-accumulation splits of the global batch

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def block_layers(self) -> int:
        """Layers per scan step (dense layers + optional trailing MoE)."""
        return self.moe_every if self.is_moe else 1

    @property
    def n_blocks(self) -> int:
        assert self.n_layers % self.block_layers == 0
        return self.n_layers // self.block_layers

    def param_count(self) -> int:
        """Analytic parameter count (for roofline MODEL_FLOPS)."""
        d, Dh = self.d_model, self.d_head
        attn = d * (self.n_heads + 2 * self.n_kv_heads) * Dh \
            + self.n_heads * Dh * d
        dense_ffn = 3 * d * self.d_ff
        n_moe = self.n_layers // self.moe_every if self.is_moe else 0
        n_dense = self.n_layers - n_moe
        moe_ffn = n_moe * (
            self.n_experts * 3 * d * self.d_ff_expert
            + self.n_shared * 3 * d * self.d_ff_expert
            + d * self.n_experts
        )
        return (
            self.vocab * d * 2
            + self.n_layers * attn
            + n_dense * dense_ffn
            + moe_ffn
        )

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k + shared experts only)."""
        if not self.is_moe:
            return self.param_count()
        d = self.d_model
        n_moe = self.n_layers // self.moe_every
        full = self.param_count()
        all_experts = n_moe * self.n_experts * 3 * d * self.d_ff_expert
        active = n_moe * (self.top_k + self.n_shared) * 3 * d * self.d_ff_expert
        return full - all_experts + active


# --- single layer ----------------------------------------------------------------


def _init_layer(key, cfg: LMConfig, is_moe_layer: bool):
    k1, k2 = jax.random.split(key)
    p = {
        "ln1": layers.init_rms_norm(cfg.d_model),
        "attn": attention.init_attention(k1, cfg, cfg.dtype),
        "ln2": layers.init_rms_norm(cfg.d_model),
    }
    if is_moe_layer:
        p["moe"] = moe.init_moe(k2, cfg, cfg.dtype)
    else:
        p["ffn"] = layers.init_swiglu(k2, cfg.d_model, cfg.d_ff, cfg.dtype)
    return p


def _layer_specs(cfg: LMConfig, is_moe_layer: bool):
    p = {
        "ln1": layers.rms_norm_specs(),
        "attn": attention.attention_specs(cfg),
        "ln2": layers.rms_norm_specs(),
    }
    if is_moe_layer:
        p["moe"] = moe.moe_specs(cfg)
    else:
        p["ffn"] = layers.swiglu_specs()
    return p


def _layer_fwd(p, cfg: LMConfig, x, *, positions, cache=None, cache_pos=0,
               is_moe_layer=False):
    h, new_cache = attention.attention_fwd(
        p["attn"], cfg, layers.rms_norm(x, p["ln1"]["scale"]).astype(x.dtype),
        positions=positions, cache=cache, cache_pos=cache_pos,
        attn_chunk=cfg.attn_chunk,
    )
    x = x + h
    z = layers.rms_norm(x, p["ln2"]["scale"]).astype(x.dtype)
    if is_moe_layer:
        h, aux = moe.moe_fwd(p["moe"], cfg, z)
    else:
        h, aux = layers.swiglu(p["ffn"], z), jnp.float32(0)
    return x + h, new_cache, aux


# --- full model ------------------------------------------------------------------


def init_lm(key, cfg: LMConfig):
    """Params with per-block stacking: block = [dense]*(k-1) + [moe or dense]."""
    k_e, k_l, k_h = jax.random.split(key, 3)
    bl = cfg.block_layers

    def init_block(k):
        ks = jax.random.split(k, bl)
        return {
            f"l{i}": _init_layer(ks[i], cfg, is_moe_layer=(cfg.is_moe and i == bl - 1))
            for i in range(bl)
        }

    blocks = jax.vmap(init_block)(jax.random.split(k_l, cfg.n_blocks))
    return {
        "embed": jax.random.normal(
            k_e, (cfg.vocab, cfg.d_model), cfg.dtype) * 0.02,
        "blocks": blocks,
        "final_norm": layers.init_rms_norm(cfg.d_model),
        "lm_head": layers.dense_init(k_h, cfg.d_model, cfg.vocab, cfg.dtype),
    }


def lm_specs(cfg: LMConfig):
    bl = cfg.block_layers

    def add_layer_dim(spec_tree):
        return jax.tree.map(
            lambda s: P(None, *s), spec_tree,
            is_leaf=lambda x: isinstance(x, P),
        )

    blocks = {
        f"l{i}": add_layer_dim(
            _layer_specs(cfg, is_moe_layer=(cfg.is_moe and i == bl - 1))
        )
        for i in range(bl)
    }
    return {
        "embed": P("model", None),
        "blocks": blocks,
        "final_norm": layers.rms_norm_specs(),
        "lm_head": P(None, "model"),
    }


def lm_fwd(params, cfg: LMConfig, tokens: jnp.ndarray):
    """tokens [B, S] -> vocab-sharded logits [B, S, V] (bf16), aux loss."""
    B, S = tokens.shape
    x = params["embed"][tokens]                  # gather over sharded vocab
    positions = jnp.arange(S)
    bl = cfg.block_layers

    def block(x, bp):
        aux_tot = jnp.float32(0)
        for i in range(bl):
            x, _, aux = _layer_fwd(
                bp[f"l{i}"], cfg, x, positions=positions,
                is_moe_layer=(cfg.is_moe and i == bl - 1),
            )
            aux_tot += aux
        return x, aux_tot

    if cfg.remat:
        block = jax.checkpoint(block)
    x, aux = jax.lax.scan(lambda c, bp: block(c, bp), x, params["blocks"])
    x = layers.rms_norm(x, params["final_norm"]["scale"]).astype(x.dtype)
    logits = x @ params["lm_head"]               # [B, S, V] vocab-sharded
    return logits, jnp.sum(aux)


def lm_loss(params, cfg: LMConfig, tokens, labels):
    logits, aux = lm_fwd(params, cfg, tokens)
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - ll) + 0.01 * aux


def lm_prefill(params, cfg: LMConfig, tokens: jnp.ndarray):
    """Prompt pass that also builds the KV cache.

    tokens [B, S] -> (last-position vocab-sharded logits [B, V],
    cache ([nb, bl, B, Hkv, S, Dh] k, same v)).
    """
    B, S = tokens.shape
    x = params["embed"][tokens]
    positions = jnp.arange(S)
    bl = cfg.block_layers
    zero_cache = (
        jnp.zeros((B, cfg.n_kv_heads, S, cfg.d_head), cfg.dtype),
        jnp.zeros((B, cfg.n_kv_heads, S, cfg.d_head), cfg.dtype),
    )

    def block(x, bp):
        ks, vs = [], []
        for i in range(bl):
            x, (k, v), _ = _layer_fwd(
                bp[f"l{i}"], cfg, x, positions=positions,
                cache=zero_cache, cache_pos=0,
                is_moe_layer=(cfg.is_moe and i == bl - 1),
            )
            ks.append(k)
            vs.append(v)
        return x, (jnp.stack(ks), jnp.stack(vs))

    if cfg.remat:
        block = jax.checkpoint(block)
    x, (kc, vc) = jax.lax.scan(block, x, params["blocks"])
    x = layers.rms_norm(x[:, -1:], params["final_norm"]["scale"]).astype(x.dtype)
    logits = (x @ params["lm_head"])[:, 0]
    return logits, (kc, vc)


def init_cache(cfg: LMConfig, batch: int, max_len: int):
    shape = (cfg.n_blocks, cfg.block_layers, batch, cfg.n_kv_heads,
             max_len, cfg.d_head)
    return (jnp.zeros(shape, cfg.dtype), jnp.zeros(shape, cfg.dtype))


def cache_specs(cfg: LMConfig):
    # [blocks, bl, B, Hkv, S, Dh]: batch over data, kv heads over model
    s = P(None, None, ("pod", "data"), "model", None, None)
    return (s, s)


def lm_decode_step(params, cfg: LMConfig, token: jnp.ndarray,
                   cache, pos: jnp.ndarray):
    """One decode step.  token [B], cache as init_cache, pos [] i32.

    Returns (vocab-sharded logits [B, V], new cache).
    """
    B = token.shape[0]
    x = params["embed"][token][:, None, :]       # [B, 1, d]
    positions = jnp.arange(1) + pos

    kc, vc = cache
    bl = cfg.block_layers

    def block(x, inp):
        bp, kcb, vcb = inp                        # kcb: [bl, B, Hkv, S, Dh]
        new_k, new_v = [], []
        for i in range(bl):
            x, (nk, nv), _ = _layer_fwd(
                bp[f"l{i}"], cfg, x, positions=positions,
                cache=(kcb[i], vcb[i]), cache_pos=pos,
                is_moe_layer=(cfg.is_moe and i == bl - 1),
            )
            new_k.append(nk)
            new_v.append(nv)
        return x, (jnp.stack(new_k), jnp.stack(new_v))

    x, (kc, vc) = jax.lax.scan(block, x, (params["blocks"], kc, vc))
    x = layers.rms_norm(x, params["final_norm"]["scale"]).astype(x.dtype)
    logits = (x @ params["lm_head"])[:, 0]        # [B, V]
    return logits, (kc, vc)
