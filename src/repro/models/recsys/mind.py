"""MIND (arXiv:1904.08030): multi-interest extraction via capsule routing.

User history -> behavior capsules -> ``n_interests`` interest capsules via
B2I dynamic routing (squash nonlinearity, ``capsule_iters`` routing
iterations with *fixed* (untrained) coupling updates, per the paper) ->
label-aware attention picks the interest for scoring.

Routing is a fixed-iteration ``lax.fori_loop``-free scan (3 iters) so the
HLO stays static; the routing logits are stop-gradiented like the paper's
dynamic routing (gradients flow through the final weighted sum only).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .. import layers
from . import embedding


@dataclasses.dataclass(frozen=True)
class MINDConfig:
    name: str = "mind"
    n_items: int = 1 << 20
    embed_dim: int = 64
    n_interests: int = 4
    capsule_iters: int = 3
    seq_len: int = 50
    n_negatives: int = 127
    pow_p: float = 2.0          # label-aware attention sharpness
    dtype: Any = jnp.float32


def init_mind(key, cfg: MINDConfig):
    k_e, k_s = jax.random.split(key)
    return {
        "item_embed": embedding.init_table(
            k_e, cfg.n_items, cfg.embed_dim, cfg.dtype),
        # shared bilinear routing map S (B2I routing, paper eq. 5)
        "S": layers.dense_init(k_s, cfg.embed_dim, cfg.embed_dim, cfg.dtype),
    }


def mind_specs(cfg: MINDConfig):
    return {"item_embed": embedding.table_specs(), "S": P()}


def _squash(v, axis=-1):
    n2 = jnp.sum(jnp.square(v), axis=axis, keepdims=True)
    return (n2 / (1.0 + n2)) * v / jnp.sqrt(n2 + 1e-9)


def interest_capsules(params, cfg: MINDConfig, hist_ids, key=None):
    """hist_ids [B, L] -> interests [B, K, d] via dynamic routing."""
    e = embedding.lookup(params["item_embed"], hist_ids)      # [B, L, d]
    u = e @ params["S"]                                        # [B, L, d]
    B, L, d = u.shape
    K = cfg.n_interests
    mask = (hist_ids > 0).astype(jnp.float32)                  # [B, L]

    # fixed random-ish init of routing logits (paper: random init; we use a
    # deterministic hash of positions so serving is reproducible)
    b0 = jnp.sin(
        jnp.arange(L)[:, None] * (1.0 + jnp.arange(K)[None, :])
    ) * 0.1
    blog = jnp.broadcast_to(b0, (B, L, K))

    def routing_iter(blog, _):
        w = jax.nn.softmax(blog, axis=-1) * mask[..., None]    # [B, L, K]
        z = jnp.einsum("blk,bld->bkd", w, jax.lax.stop_gradient(u))
        cap = _squash(z)                                       # [B, K, d]
        blog = blog + jnp.einsum("bld,bkd->blk",
                                 jax.lax.stop_gradient(u), cap)
        return blog, cap

    blog, caps = jax.lax.scan(
        routing_iter, blog, None, length=cfg.capsule_iters
    )
    cap = caps[-1]
    # final pass with gradient flowing through u
    w = jax.nn.softmax(blog, axis=-1) * mask[..., None]
    return _squash(jnp.einsum("blk,bld->bkd", w, u))           # [B, K, d]


def label_aware_scores(interests, item_e, pow_p):
    """interests [B, K, d], item_e [B, T, d] -> scores [B, T]."""
    sims = jnp.einsum("bkd,btd->btk", interests, item_e)       # [B, T, K]
    att = jax.nn.softmax(jnp.power(jnp.abs(sims), pow_p)
                         * jnp.sign(sims), axis=-1)
    chosen = jnp.einsum("btk,bkd->btd", att, interests)
    return jnp.sum(chosen * item_e, axis=-1)


def mind_loss(params, cfg: MINDConfig, hist_ids, target_ids, key):
    """Sampled-softmax loss: hist [B, L], target [B]."""
    interests = interest_capsules(params, cfg, hist_ids)       # [B, K, d]
    neg = jax.random.randint(key, (cfg.n_negatives,), 0, cfg.n_items)
    pos_e = embedding.lookup(params["item_embed"], target_ids)  # [B, d]
    neg_e = embedding.lookup(params["item_embed"], neg)         # [N, d]
    cand = jnp.concatenate(
        [pos_e[:, None, :],
         jnp.broadcast_to(neg_e, (hist_ids.shape[0],) + neg_e.shape)], axis=1
    )                                                           # [B, 1+N, d]
    logits = label_aware_scores(interests, cand, cfg.pow_p).astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    return jnp.mean(lse - logits[:, 0])


def mind_serve(params, cfg: MINDConfig, hist_ids, cand_ids):
    """hist [B, L], cand [B, C] -> scores [B, C] (max over interests)."""
    interests = interest_capsules(params, cfg, hist_ids)
    ce = embedding.lookup(params["item_embed"], cand_ids)       # [B, C, d]
    sims = jnp.einsum("bkd,bcd->bck", interests, ce)
    return jnp.max(sims, axis=-1)


def mind_retrieval(params, cfg: MINDConfig, hist_ids, cand_ids):
    """One user against a candidate slab: hist [1, L], cand [N] -> [N]."""
    interests = interest_capsules(params, cfg, hist_ids)[0]     # [K, d]
    ce = embedding.lookup(params["item_embed"], cand_ids)       # [N, d]
    return jnp.max(ce @ interests.T, axis=-1).astype(jnp.float32)
