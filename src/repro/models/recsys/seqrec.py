"""Sequential recommenders: SASRec (arXiv:1808.09781) and BERT4Rec
(arXiv:1904.06690) share one transformer-over-item-history backbone.

Differences (both faithful to their papers):
  * SASRec: causal self-attention, next-item objective, learned absolute
    positions, scores via tied item embeddings.
  * BERT4Rec: bidirectional self-attention, masked-item (cloze) objective.

Training uses sampled softmax (1 positive + ``n_negatives`` shared uniform
negatives) — at the production catalog size (2^20 items) full-softmax
logits at batch 65536 x seq are not a sane baseline on any hardware, and
sampled softmax is what both papers' follow-ups deploy.

Serving entry points per the assigned shapes:
  * ``score_candidates``  (serve_p99 / serve_bulk): user state . candidate embeds
  * ``retrieval_scores``  (retrieval_cand): one user against the whole
    catalog slab — a [1, d] x [d, N_cand] matmul, candidates sharded.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .. import layers
from ..attention import chunked_attention
from . import embedding


@dataclasses.dataclass(frozen=True)
class SeqRecConfig:
    name: str = "sasrec"
    n_items: int = 1 << 20
    embed_dim: int = 50
    n_blocks: int = 2
    n_heads: int = 1
    seq_len: int = 50
    causal: bool = True          # False -> BERT4Rec
    n_negatives: int = 127
    dtype: Any = jnp.float32

    @property
    def d_head(self) -> int:
        return self.embed_dim // self.n_heads


def init_seqrec(key, cfg: SeqRecConfig):
    k_i, k_p, k_b = jax.random.split(key, 3)
    d = cfg.embed_dim

    def init_block(k):
        k1, k2, k3, k4 = jax.random.split(k, 4)
        return {
            "ln1": layers.init_layer_norm(d),
            "wqkv": layers.dense_init(k1, d, 3 * d, cfg.dtype),
            "wo": layers.dense_init(k2, d, d, cfg.dtype),
            "ln2": layers.init_layer_norm(d),
            "ffn": [
                {"w": layers.dense_init(k3, d, 4 * d, cfg.dtype),
                 "b": jnp.zeros((4 * d,), cfg.dtype)},
                {"w": layers.dense_init(k4, 4 * d, d, cfg.dtype),
                 "b": jnp.zeros((d,), cfg.dtype)},
            ],
        }

    return {
        "item_embed": embedding.init_table(k_i, cfg.n_items, d, cfg.dtype),
        "pos_embed": jax.random.normal(k_p, (cfg.seq_len, d), cfg.dtype) * 0.02,
        "blocks": jax.vmap(init_block)(jax.random.split(k_b, cfg.n_blocks)),
        "final_ln": layers.init_layer_norm(d),
    }


def seqrec_specs(cfg: SeqRecConfig):
    # The transformer tower is tiny (embed_dim 50-64; dims not divisible by
    # a 16-way model axis) — replicate it.  The 2^20-row item table is the
    # memory and is row-sharded; all tower compute is data-parallel.
    block = {
        "ln1": layers.layer_norm_specs(),
        "wqkv": P(),
        "wo": P(),
        "ln2": layers.layer_norm_specs(),
        "ffn": [{"w": P(), "b": P()}, {"w": P(), "b": P()}],
    }
    return {
        "item_embed": embedding.table_specs(),
        "pos_embed": P(),
        "blocks": block,
        "final_ln": layers.layer_norm_specs(),
    }


def _block_fwd(p, cfg: SeqRecConfig, x):
    B, S, d = x.shape
    H, Dh = cfg.n_heads, cfg.d_head
    z = layers.layer_norm(x, p["ln1"]["scale"], p["ln1"]["bias"]).astype(x.dtype)
    qkv = (z @ p["wqkv"]).reshape(B, S, 3, H, Dh)
    q, k, v = (qkv[:, :, i].swapaxes(1, 2) for i in range(3))
    o = chunked_attention(q, k, v, causal=cfg.causal,
                          chunk=min(1024, S)).swapaxes(1, 2)
    x = x + o.reshape(B, S, d) @ p["wo"]
    z = layers.layer_norm(x, p["ln2"]["scale"], p["ln2"]["bias"]).astype(x.dtype)
    return x + layers.mlp(p["ffn"], z)


def user_states(params, cfg: SeqRecConfig, item_ids: jnp.ndarray):
    """item_ids [B, S] -> per-position user states [B, S, d]."""
    x = embedding.lookup(params["item_embed"], item_ids) + params["pos_embed"]

    def step(x, bp):
        return _block_fwd(bp, cfg, x), None

    x, _ = jax.lax.scan(step, x, params["blocks"])
    return layers.layer_norm(
        x, params["final_ln"]["scale"], params["final_ln"]["bias"]
    ).astype(x.dtype)


def sampled_softmax_loss(params, cfg: SeqRecConfig, item_ids, targets, key):
    """Next-item (causal) or cloze (bidir) loss with shared uniform negatives.

    item_ids, targets: [B, S] (targets = inputs shifted for SASRec; masked
    positions for BERT4Rec with pad target 0 skipped via weighting).
    """
    h = user_states(params, cfg, item_ids)                     # [B, S, d]
    neg = jax.random.randint(
        key, (cfg.n_negatives,), 0, cfg.n_items
    )
    pos_e = embedding.lookup(params["item_embed"], targets)    # [B, S, d]
    neg_e = embedding.lookup(params["item_embed"], neg)        # [N, d]
    pos_logit = jnp.sum(h * pos_e, axis=-1, keepdims=True)     # [B, S, 1]
    neg_logit = jnp.einsum("bsd,nd->bsn", h, neg_e)            # [B, S, N]
    logits = jnp.concatenate([pos_logit, neg_logit], axis=-1).astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    weight = (targets > 0).astype(jnp.float32)
    loss = (lse - logits[..., 0]) * weight
    return jnp.sum(loss) / jnp.maximum(jnp.sum(weight), 1.0)


def score_candidates(params, cfg: SeqRecConfig, item_ids, cand_ids):
    """item_ids [B, S], cand_ids [B, C] -> scores [B, C] (online serving)."""
    h = user_states(params, cfg, item_ids)[:, -1]              # [B, d]
    ce = embedding.lookup(params["item_embed"], cand_ids)      # [B, C, d]
    return jnp.einsum("bd,bcd->bc", h, ce)


def retrieval_scores(params, cfg: SeqRecConfig, item_ids, cand_ids):
    """One user against a candidate slab: [1, S] x [N] -> [N] scores."""
    h = user_states(params, cfg, item_ids)[:, -1]              # [1, d]
    ce = embedding.lookup(params["item_embed"], cand_ids)      # [N, d]
    return (ce @ h[0]).astype(jnp.float32)
