"""DCN-v2 (arXiv:2008.13535): cross network + deep tower over Criteo-style
features (13 dense + 26 categorical fields).

The cross layers use the fused Pallas kernel (repro.kernels.cross) on TPU
and its oracle elsewhere.  Embedding tables are row-sharded ("model" axis);
the batch is data-parallel.  Structure: stacked cross (x_{l+1} = x0 *
(W x_l + b) + x_l) in parallel with a deep MLP, concat -> logit (the
paper's best "parallel" variant).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .. import layers
from ...kernels.cross import ops as cross_ops
from . import embedding


@dataclasses.dataclass(frozen=True)
class DCNConfig:
    name: str = "dcn-v2"
    n_dense: int = 13
    n_sparse: int = 26
    vocab_per_field: int = 1 << 20
    embed_dim: int = 16
    n_cross_layers: int = 3
    mlp_dims: tuple[int, ...] = (1024, 1024, 512)
    dtype: Any = jnp.float32

    @property
    def d_interact(self) -> int:
        return self.n_dense + self.n_sparse * self.embed_dim


def init_dcn(key, cfg: DCNConfig):
    k_e, k_c, k_m, k_f = jax.random.split(key, 4)
    d = cfg.d_interact
    tables = jax.vmap(
        lambda k: embedding.init_table(k, cfg.vocab_per_field, cfg.embed_dim,
                                       cfg.dtype)
    )(jax.random.split(k_e, cfg.n_sparse))
    kcs = jax.random.split(k_c, cfg.n_cross_layers)
    cross = [
        {"W": layers.dense_init(k, d, d, cfg.dtype),
         "b": jnp.zeros((d,), cfg.dtype)}
        for k in kcs
    ]
    deep = layers.init_mlp(k_m, d, cfg.mlp_dims, dtype=cfg.dtype)
    final = layers.dense_init(k_f, d + cfg.mlp_dims[-1], 1, cfg.dtype)
    return {"tables": tables, "cross": cross, "deep": deep, "final": final}


def dcn_specs(cfg: DCNConfig):
    # cross W is [429, 429] (not 16-divisible) — replicated; the deep tower
    # dims (1024/512) shard over "model"; tables row-shard per field.
    return {
        "tables": P(None, "model", None),     # [field, vocab, dim]
        "cross": [{"W": P(), "b": P()} for _ in range(cfg.n_cross_layers)],
        "deep": layers.mlp_specs(len(cfg.mlp_dims)),
        "final": P(),
    }


def dcn_fwd(params, cfg: DCNConfig, dense_feats, sparse_ids,
            *, use_pallas=None):
    """dense_feats [B, 13] f32, sparse_ids [B, 26] i32 -> logits [B]."""
    B = dense_feats.shape[0]
    # per-field gathers from the stacked [F, V, D] tables
    emb = jax.vmap(
        lambda table, ids: embedding.lookup(table, ids),
        in_axes=(0, 1), out_axes=1,
    )(params["tables"], sparse_ids)                       # [B, F, D]
    x0 = jnp.concatenate(
        [dense_feats.astype(cfg.dtype), emb.reshape(B, -1)], axis=-1
    )                                                      # [B, d]
    xl = x0
    for lyr in params["cross"]:
        xl = cross_ops.cross_layer(x0, xl, lyr["W"], lyr["b"],
                                   use_pallas=use_pallas)
    deep = layers.mlp(params["deep"], x0, final_act=True)
    both = jnp.concatenate([xl, deep], axis=-1)
    return (both @ params["final"])[:, 0]


def dcn_loss(params, cfg: DCNConfig, dense_feats, sparse_ids, labels):
    logits = dcn_fwd(params, cfg, dense_feats, sparse_ids).astype(jnp.float32)
    return jnp.mean(
        jnp.maximum(logits, 0) - logits * labels + jnp.log1p(
            jnp.exp(-jnp.abs(logits))
        )
    )
