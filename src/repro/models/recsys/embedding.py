"""Sharded embedding tables + EmbeddingBag for the recsys archs.

JAX has no native EmbeddingBag / CSR sparse — built here (per assignment)
from take + segment_sum, with the Pallas scalar-prefetch kernel
(``repro.kernels.embag``) as the TPU hot path.  Tables are row-sharded over
the "model" axis (table-wise + row-wise parallel — the standard production
layout for 10^6..10^9-row tables); lookups over sharded rows lower to
gather collectives under GSPMD.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ...kernels.embag import ops as embag_ops


def init_table(key, vocab: int, dim: int, dtype=jnp.float32):
    return jax.random.normal(key, (vocab, dim), dtype) * (dim ** -0.5)


def table_specs():
    return P("model", None)


def lookup(table: jnp.ndarray, ids: jnp.ndarray) -> jnp.ndarray:
    """Plain row gather: ids [...], table [V, D] -> [..., D]."""
    return table[ids]


def bag_lookup(table, ids, weights=None, *, use_pallas=None):
    """Multi-hot bag sum: ids [B, L] -> [B, D] (0-weight = pad)."""
    return embag_ops.embedding_bag(table, ids, weights, use_pallas=use_pallas)
