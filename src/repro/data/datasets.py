"""Paper-dataset clones + synthetic stress set (paper Table 1).

Real MovieLens/LastFM/Delicious/Yahoo downloads are network-gated in this
container, so each benchmark dataset is generated as a *stat-matched
clone*: identical user counts, feature dims and interaction counts, with a
planted cluster structure over user preference vectors and binary rewards
(all the paper's datasets have 0/1 rewards).  The evaluation protocol
follows Li et al. 2014 as the paper does: every interaction presents a
candidate set of items and the learner is rewarded iff the user "clicks"
its pick (Bernoulli in the item-user affinity).

``make_env`` is explicit about the protocol driving the clone:

  kind="synthetic"  the simulator — fresh candidate sets sampled per
                    interaction against the planted preference vectors.
  kind="replay"     actual logged tables (item catalog + per-user queues
                    of logged slates with affinity-derived CTRs),
                    materialized via ``repro.data.replay`` and served
                    through ``replay_ops`` — the paper's offline protocol.
  kind="drift"      the non-stationary scenario: cluster centroids
                    re-draw periodically ("content popularity can change
                    rapidly"), via ``drift_ops``.
  kind="catalog"    the item-side scale scenario: slates drawn from a
                    PERSISTENT region-structured item catalog (the same
                    population the retrieval engine serves two-stage),
                    via ``catalog_ops``; pass ``drift_period`` for
                    centroid re-draw over the catalog regions.

Every kind returns a shard-aware ``EnvOps``, so all scenarios run under
both the single-host and the ``shard_map`` runtimes.

Cluster counts follow the CLUB evaluation convention (10 underlying
clusters for the web datasets; the synthetic stress set uses 100).
"""
from __future__ import annotations

import dataclasses
import math

import jax

from ..core import env as core_env
from ..core.env_ops import EnvOps, catalog_ops, drift_ops, synthetic_ops


@dataclasses.dataclass(frozen=True)
class DatasetSpec:
    name: str
    n_interactions: int
    n_users: int
    d: int                 # item feature dim (paper Table 1)
    n_clusters: int
    n_candidates: int = 20


# paper Table 1 (Yahoo's d is listed as 1 — a degenerate linear model; the
# CLUB preprocessing it cites uses d=5-dim reduced features, which we adopt
# so clustering is meaningful)
PAPER_DATASETS = {
    "movielens": DatasetSpec("movielens", 80_000, 943, 19, 10),
    "lastfm": DatasetSpec("lastfm", 10_000, 1_888, 25, 10),
    "delicious": DatasetSpec("delicious", 10_000, 1_816, 25, 10),
    "yahoo": DatasetSpec("yahoo", 50_000, 5_045, 5, 10),
    "synthetic": DatasetSpec("synthetic", 4_000_000, 20_000, 25, 100),
    # reduced synthetic for CI-scale runs
    "synthetic-small": DatasetSpec("synthetic-small", 64_000, 2_000, 25, 50),
}

# replay queues are bounded: [n_users, max_t, K] logged tables must stay
# materializable (the synthetic stress set would need max_t=200 -> 320 MB);
# past the bound a user's queue clamps to its last logged slate, exactly
# the ``min(occ, max_t - 1)`` cursor semantics of ``replay_ops``.
_REPLAY_MAX_T = 128

# default persistent-catalog size for kind="catalog" offline runs — big
# enough that per-round slates rarely repeat, small enough that the
# [n_phases, n_regions, d] + [n_items, d] tables stay trivial; serving
# benchmarks build catalogs up to 2**20 items via make_catalog_env
_CATALOG_ITEMS = 4096


def make_env(spec: DatasetSpec, seed: int = 0, kind: str = "synthetic",
             drift_period: int | None = None,
             n_items: int | None = None) -> tuple[EnvOps, jax.Array]:
    """(EnvOps, true_labels) for a stat-matched clone of ``spec``.

    ``kind`` selects the protocol (see module docstring): "synthetic"
    simulates, "replay" materializes and serves actual logged tables,
    "drift" re-draws the planted centroids every ``drift_period``
    interactions (default: 4 phases across the spec's per-user budget),
    and "catalog" draws slates from a persistent ``n_items`` catalog
    (default ``_CATALOG_ITEMS``; ``drift_period`` re-draws its region
    centroids).  Catalog-kind serving sessions materialize the same
    catalog via ``core.env.make_catalog_env``/``catalog_embeddings``.
    """
    if kind == "synthetic":
        env, labels = core_env.make_synthetic_env(
            jax.random.PRNGKey(seed),
            n_users=spec.n_users,
            d=spec.d,
            n_clusters=spec.n_clusters,
            n_candidates=spec.n_candidates,
            within_cluster_noise=0.05,
        )
        return synthetic_ops(env), labels
    if kind == "replay":
        from .replay import make_replay_env
        max_t = min(_REPLAY_MAX_T,
                    max(1, math.ceil(spec.n_interactions / spec.n_users)))
        return make_replay_env(spec, max_t=max_t, seed=seed)
    if kind == "drift":
        per_user = max(1, spec.n_interactions // spec.n_users)
        period = drift_period or max(1, per_user // 4)
        env, labels = core_env.make_drift_env(
            jax.random.PRNGKey(seed),
            n_users=spec.n_users,
            d=spec.d,
            n_clusters=spec.n_clusters,
            n_candidates=spec.n_candidates,
            drift_period=period,
            n_phases=4,
            within_cluster_noise=0.05,
        )
        return drift_ops(env), labels
    if kind == "catalog":
        period = drift_period or 0        # no drift unless asked (static
        #                                   catalog is the scale scenario)
        env, labels = core_env.make_catalog_env(
            jax.random.PRNGKey(seed),
            n_users=spec.n_users,
            d=spec.d,
            n_clusters=spec.n_clusters,
            n_items=n_items or _CATALOG_ITEMS,
            n_candidates=spec.n_candidates,
            drift_period=period,
            n_phases=4 if period else 1,
            within_cluster_noise=0.05,
        )
        return catalog_ops(env), labels
    raise ValueError(
        f"unknown env kind {kind!r}; want synthetic|replay|drift|catalog")


def epochs_for(spec: DatasetSpec, hyper) -> int:
    """Number of 4-stage epochs so total interactions ~= the dataset's
    logged interaction count.

    Per-user budget accounting (see ``runtime.stages.stage4_rebalance``):
    rebalancing conserves the SUM ``u_rounds + c_rounds = 2 * sigma`` per
    user, but each budget is clipped to ``[0, max_rounds]`` — the static
    scan length — so one epoch processes at most
    ``n_users * 2 * min(sigma, max_rounds)`` interactions.  Using the
    clamped figure keeps the epoch count honest when
    ``max_rounds < sigma``.
    """
    per_user = 2 * min(hyper.sigma, hyper.max_rounds)
    per_epoch = spec.n_users * per_user
    return max(1, spec.n_interactions // per_epoch)
