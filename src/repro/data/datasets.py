"""Paper-dataset clones + synthetic stress set (paper Table 1).

Real MovieLens/LastFM/Delicious/Yahoo downloads are network-gated in this
container, so each benchmark dataset is generated as a *stat-matched
clone*: identical user counts, feature dims and interaction counts, with a
planted cluster structure over user preference vectors and binary rewards
(all the paper's datasets have 0/1 rewards).  The evaluation protocol
follows Li et al. 2014 as the paper does: every interaction presents a
candidate set of items and the learner is rewarded iff the user "clicks"
its pick (Bernoulli in the item-user affinity).

Cluster counts follow the CLUB evaluation convention (10 underlying
clusters for the web datasets; the synthetic stress set uses 100).
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np

from ..core import env as core_env
from ..core.env_ops import EnvOps, synthetic_ops


@dataclasses.dataclass(frozen=True)
class DatasetSpec:
    name: str
    n_interactions: int
    n_users: int
    d: int                 # item feature dim (paper Table 1)
    n_clusters: int
    n_candidates: int = 20


# paper Table 1 (Yahoo's d is listed as 1 — a degenerate linear model; the
# CLUB preprocessing it cites uses d=5-dim reduced features, which we adopt
# so clustering is meaningful)
PAPER_DATASETS = {
    "movielens": DatasetSpec("movielens", 80_000, 943, 19, 10),
    "lastfm": DatasetSpec("lastfm", 10_000, 1_888, 25, 10),
    "delicious": DatasetSpec("delicious", 10_000, 1_816, 25, 10),
    "yahoo": DatasetSpec("yahoo", 50_000, 5_045, 5, 10),
    "synthetic": DatasetSpec("synthetic", 4_000_000, 20_000, 25, 100),
    # reduced synthetic for CI-scale runs
    "synthetic-small": DatasetSpec("synthetic-small", 64_000, 2_000, 25, 50),
}


def make_env(spec: DatasetSpec, seed: int = 0):
    """(EnvOps, true_labels) for a stat-matched clone of ``spec``."""
    env, labels = core_env.make_synthetic_env(
        jax.random.PRNGKey(seed),
        n_users=spec.n_users,
        d=spec.d,
        n_clusters=spec.n_clusters,
        n_candidates=spec.n_candidates,
        within_cluster_noise=0.05,
    )
    return synthetic_ops(env), labels


def epochs_for(spec: DatasetSpec, hyper) -> int:
    """Number of 4-stage epochs so total interactions ~= the dataset's
    logged interaction count (each epoch processes ~n_users * (uR + cR))."""
    per_epoch = spec.n_users * 2 * hyper.sigma
    return max(1, spec.n_interactions // per_epoch)
