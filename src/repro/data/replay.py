"""Logged-interaction (replay) datasets.

The paper's real datasets are *logs*: each interaction has a user, a
candidate set drawn from a finite item catalog, and a click.  This module
materializes such logs from the stat-matched clones so the algorithms can
be driven by the exact replay protocol (per-user queues preserve each
user's interaction order under batched rounds — DESIGN.md §2), and so the
offline-evaluation counterfactual (reward only on matching pick) can be
studied alongside the simulator.  ``data.datasets.make_env(spec,
kind="replay")`` is the front door; the resulting ``EnvOps`` is
shard-aware (tables sliced per shard via ``row0``), so replay-backed
clones run under ``shard_map`` as well as single-host.

    item_feats  [n_items, d]        catalog features (unit rows)
    cand_ids    [n_users, max_t, K] per-user queue of logged slates
    click_probs [n_users, max_t, K] affinity-derived click probabilities
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..core import env as core_env
from ..core.env_ops import EnvOps, replay_ops
from .datasets import DatasetSpec


def make_replay_env(spec: DatasetSpec, *, n_items: int = 2048,
                    max_t: int = 64, seed: int = 0):
    """Materialize a replay log for ``spec``.  Returns (EnvOps, labels)."""
    key = jax.random.PRNGKey(seed)
    k_env, k_items, k_cands = jax.random.split(key, 3)
    env, labels = core_env.make_synthetic_env(
        k_env, n_users=spec.n_users, d=spec.d, n_clusters=spec.n_clusters,
        n_candidates=spec.n_candidates, within_cluster_noise=0.05)

    item_feats = jax.random.normal(k_items, (n_items, spec.d))
    item_feats = item_feats / jnp.linalg.norm(item_feats, axis=-1,
                                              keepdims=True)
    cand_ids = jax.random.randint(
        k_cands, (spec.n_users, max_t, spec.n_candidates), 1, n_items)
    # affinity-derived CTRs for every logged slate position
    cand_feats = item_feats[cand_ids]                    # [n, t, K, d]
    click_probs = core_env.expected_reward(
        env.theta[:, None, None, :], cand_feats)
    return replay_ops(item_feats, cand_ids, click_probs), labels
