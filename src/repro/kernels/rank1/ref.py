"""Pure-jnp oracle for the fused masked rank-1 bandit-state update."""
from __future__ import annotations

import jax.numpy as jnp


def rank1_update_ref(
    M: jnp.ndarray,       # [n, d, d]
    Minv: jnp.ndarray,    # [n, d, d]
    b: jnp.ndarray,       # [n, d]
    x: jnp.ndarray,       # [n, d] chosen contexts
    r: jnp.ndarray,       # [n]    realized rewards
    mask: jnp.ndarray,    # [n] bool
):
    """Returns (M', Minv', b') after one masked interaction per user.

    Minv' is the exact Sherman-Morrison inverse of M' = M + mask x x^T.
    A masked-out user is an identity update (x -> 0 path is exact).
    M stays f32 always; Minv may be stored bf16 (see rank1_update_inv_ref).
    """
    dt = Minv.dtype
    Minv32 = Minv.astype(jnp.float32)
    m = mask.astype(x.dtype)
    xm = x * m[:, None]
    Mx = jnp.einsum("nij,nj->ni", Minv32, xm)
    denom = 1.0 + jnp.einsum("ni,ni->n", xm, Mx)
    Minv_new = Minv32 - jnp.einsum("ni,nj->nij", Mx, Mx) / denom[:, None,
                                                                 None]
    M_new = M + jnp.einsum("ni,nj->nij", xm, xm)
    b_new = b + (r * m)[:, None] * x
    return M_new, Minv_new.astype(dt), b_new


def rank1_update_inv_ref(
    Minv: jnp.ndarray,    # [n, d, d]
    b: jnp.ndarray,       # [n, d]
    x: jnp.ndarray,       # [n, d]
    r: jnp.ndarray,       # [n]
    mask: jnp.ndarray,    # [n] bool
):
    """M-free oracle: (Minv', b') only (the sharded runtime's state).

    ``Minv`` may be stored bf16 (``Precision``): the S-M math runs in f32
    and the result is written back in the storage dtype.  For f32 both
    astypes are trace-time no-ops — bit-identical to the historical path.
    """
    dt = Minv.dtype
    Minv32 = Minv.astype(jnp.float32)
    m = mask.astype(x.dtype)
    xm = x * m[:, None]
    Mx = jnp.einsum("nij,nj->ni", Minv32, xm)
    denom = 1.0 + jnp.einsum("ni,ni->n", xm, Mx)
    Minv_new = Minv32 - jnp.einsum("ni,nj->nij", Mx, Mx) / denom[:, None,
                                                                 None]
    b_new = b + (r * m)[:, None] * x
    return Minv_new.astype(dt), b_new
