"""Fused masked Sherman-Morrison rank-1 update Pallas kernel.

Per interaction the bandit touches, per user: M (+= x x^T), Minv (S-M
downdate) and b (+= r x).  Doing these as three separate XLA ops streams
the [n,d,d] state through HBM three times; the fused kernel reads each
user's state once into VMEM, applies all three updates, and writes once —
the update is memory-bound, so this is a ~3x HBM-traffic cut on the state
arrays (the §Perf hillclimb for the bandit cell measures exactly this).

Grid: one step per block of users.  All compute is batched elementwise /
dot_general over the user block, so the VPU/MXU stay on the fast path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rank1_kernel(m_ref, minv_ref, b_ref, x_ref, r_ref, mask_ref,
                  m_out, minv_out, b_out):
    M = m_ref[...]             # [Bu, d, d] (always f32)
    # Minv may arrive bf16 (Precision state_dtype): upcast once in VMEM so
    # the S-M math runs f32; for f32 inputs the astype is a no-op.
    Minv = minv_ref[...].astype(jnp.float32)   # [Bu, d, d]
    b = b_ref[...]             # [Bu, d]
    x = x_ref[...]             # [Bu, d]
    r = r_ref[...]             # [Bu]
    msk = mask_ref[...]        # [Bu] (f32 0/1)

    xm = x * msk[:, None]
    Mx = jax.lax.dot_general(
        Minv, xm,
        dimension_numbers=(((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    )                                                  # [Bu, d]
    denom = 1.0 + jnp.sum(xm * Mx, axis=-1)            # [Bu]
    outer_inv = Mx[:, :, None] * Mx[:, None, :]        # [Bu, d, d]
    minv_out[...] = (Minv - outer_inv / denom[:, None, None]).astype(
        minv_out.dtype)
    m_out[...] = M + xm[:, :, None] * xm[:, None, :]
    b_out[...] = b + (r * msk)[:, None] * x


def _rank1_inv_kernel(minv_ref, b_ref, x_ref, r_ref, mask_ref,
                      minv_out, b_out):
    """M-free variant: the sharded runtime drops the Gram matrix entirely
    (stage-2 recovers it by inversion), so its hot loop only touches Minv
    and b — 2 state passes instead of 4."""
    Minv = minv_ref[...].astype(jnp.float32)   # [Bu, d, d] (may be bf16)
    b = b_ref[...]             # [Bu, d]
    x = x_ref[...]             # [Bu, d]
    r = r_ref[...]             # [Bu]
    msk = mask_ref[...]        # [Bu] (f32 0/1)

    xm = x * msk[:, None]
    Mx = jax.lax.dot_general(
        Minv, xm,
        dimension_numbers=(((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    )                                                  # [Bu, d]
    denom = 1.0 + jnp.sum(xm * Mx, axis=-1)            # [Bu]
    outer_inv = Mx[:, :, None] * Mx[:, None, :]        # [Bu, d, d]
    minv_out[...] = (Minv - outer_inv / denom[:, None, None]).astype(
        minv_out.dtype)
    b_out[...] = b + (r * msk)[:, None] * x


@functools.partial(jax.jit, static_argnames=("block_users", "interpret"))
def rank1_update_inv_pallas(
    Minv: jnp.ndarray,   # [n, d, d]
    b: jnp.ndarray,      # [n, d]
    x: jnp.ndarray,      # [n, d]
    r: jnp.ndarray,      # [n]
    mask: jnp.ndarray,   # [n] f32 (0/1)
    *,
    block_users: int = 256,
    interpret: bool = False,
):
    n, d = b.shape
    assert n % block_users == 0
    grid = (n // block_users,)
    bs2 = pl.BlockSpec((block_users, d, d), lambda i: (i, 0, 0))
    bs1 = pl.BlockSpec((block_users, d), lambda i: (i, 0))
    bs0 = pl.BlockSpec((block_users,), lambda i: (i,))
    return pl.pallas_call(
        _rank1_inv_kernel,
        grid=grid,
        in_specs=[bs2, bs1, bs1, bs0, bs0],
        out_specs=[bs2, bs1],
        out_shape=[
            jax.ShapeDtypeStruct((n, d, d), Minv.dtype),
            jax.ShapeDtypeStruct((n, d), jnp.float32),
        ],
        interpret=interpret,
    )(Minv, b, x, r, mask)


@functools.partial(jax.jit, static_argnames=("block_users", "interpret"))
def rank1_update_pallas(
    M: jnp.ndarray,      # [n, d, d]
    Minv: jnp.ndarray,   # [n, d, d]
    b: jnp.ndarray,      # [n, d]
    x: jnp.ndarray,      # [n, d]
    r: jnp.ndarray,      # [n]
    mask: jnp.ndarray,   # [n] f32 (0/1)
    *,
    block_users: int = 256,
    interpret: bool = False,
):
    n, d = b.shape
    assert n % block_users == 0
    grid = (n // block_users,)
    bs2 = pl.BlockSpec((block_users, d, d), lambda i: (i, 0, 0))
    bs1 = pl.BlockSpec((block_users, d), lambda i: (i, 0))
    bs0 = pl.BlockSpec((block_users,), lambda i: (i,))
    return pl.pallas_call(
        _rank1_kernel,
        grid=grid,
        in_specs=[bs2, bs2, bs1, bs1, bs0, bs0],
        out_specs=[bs2, bs2, bs1],
        out_shape=[
            jax.ShapeDtypeStruct((n, d, d), jnp.float32),
            jax.ShapeDtypeStruct((n, d, d), Minv.dtype),
            jax.ShapeDtypeStruct((n, d), jnp.float32),
        ],
        interpret=interpret,
    )(M, Minv, b, x, r, mask)
