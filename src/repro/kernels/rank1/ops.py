"""Public entry point for the fused rank-1 bandit-state update."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..pad import SUB, round_up, user_block
from .rank1 import rank1_update_inv_pallas, rank1_update_pallas
from .ref import rank1_update_inv_ref, rank1_update_ref


def _dims(n: int, d: int, block_users: int):
    np_, bu = user_block(n, block_users)
    return np_, round_up(d, SUB), bu


def rank1_update(
    M, Minv, b, x, r, mask,
    *,
    use_pallas: bool | None = None,
    block_users: int = 256,
    interpret: bool | None = None,
):
    """(M', Minv', b') — fused masked Sherman-Morrison update.

    Zero-padding users is exact (mask=0 rows are identity updates).  When
    the inputs are already block/sublane aligned (the backend engine pads
    state once per stage) no pad copies are issued.
    """
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if not use_pallas:
        return rank1_update_ref(M, Minv, b, x, r, mask)

    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    n, d = b.shape
    np_, dp, bu = _dims(n, d, block_users)

    if (n, d) == (np_, dp):
        Mo, Minvo, bo = rank1_update_pallas(
            M, Minv, b, x, r, mask.astype(jnp.float32),
            block_users=bu, interpret=interpret,
        )
        return Mo, Minvo, bo

    def pad2(a):
        out = jnp.zeros((np_, dp, dp), a.dtype).at[:n, :d, :d].set(a)
        # keep padded diagonal at 1 so Minv stays well-conditioned
        i = jnp.arange(d, dp)
        return out.at[:, i, i].set(jnp.ones((), a.dtype))

    Mp, Minvp = pad2(M), pad2(Minv)
    bp = jnp.zeros((np_, dp), jnp.float32).at[:n, :d].set(b)
    xp = jnp.zeros((np_, dp), jnp.float32).at[:n, :d].set(x)
    rp = jnp.zeros((np_,), jnp.float32).at[:n].set(r)
    mp = jnp.zeros((np_,), jnp.float32).at[:n].set(mask.astype(jnp.float32))

    Mo, Minvo, bo = rank1_update_pallas(
        Mp, Minvp, bp, xp, rp, mp, block_users=bu, interpret=interpret
    )
    return Mo[:n, :d, :d], Minvo[:n, :d, :d], bo[:n, :d]


def rank1_update_inv(
    Minv, b, x, r, mask,
    *,
    use_pallas: bool | None = None,
    block_users: int = 256,
    interpret: bool | None = None,
):
    """(Minv', b') — M-free fused update for the sharded runtime."""
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if not use_pallas:
        return rank1_update_inv_ref(Minv, b, x, r, mask)

    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    n, d = b.shape
    np_, dp, bu = _dims(n, d, block_users)

    if (n, d) == (np_, dp):
        return rank1_update_inv_pallas(
            Minv, b, x, r, mask.astype(jnp.float32),
            block_users=bu, interpret=interpret,
        )

    Minvp = jnp.zeros((np_, dp, dp), Minv.dtype).at[:n, :d, :d].set(Minv)
    i = jnp.arange(d, dp)
    Minvp = Minvp.at[:, i, i].set(jnp.ones((), Minv.dtype))
    bp = jnp.zeros((np_, dp), jnp.float32).at[:n, :d].set(b)
    xp = jnp.zeros((np_, dp), jnp.float32).at[:n, :d].set(x)
    rp = jnp.zeros((np_,), jnp.float32).at[:n].set(r)
    mp = jnp.zeros((np_,), jnp.float32).at[:n].set(mask.astype(jnp.float32))

    Minvo, bo = rank1_update_inv_pallas(
        Minvp, bp, xp, rp, mp, block_users=bu, interpret=interpret
    )
    return Minvo[:n, :d, :d], bo[:n, :d]
