"""Public entry point for the fused rank-1 bandit-state update."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .rank1 import rank1_update_pallas
from .ref import rank1_update_ref

_SUB = 8


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def rank1_update(
    M, Minv, b, x, r, mask,
    *,
    use_pallas: bool | None = None,
    block_users: int = 256,
    interpret: bool | None = None,
):
    """(M', Minv', b') — fused masked Sherman-Morrison update.

    Zero-padding users is exact (mask=0 rows are identity updates).
    """
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if not use_pallas:
        return rank1_update_ref(M, Minv, b, x, r, mask)

    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    n, d = b.shape
    dp = _round_up(d, _SUB)
    bu = min(block_users, _round_up(n, _SUB))
    np_ = _round_up(n, bu)

    def pad2(a):
        out = jnp.zeros((np_, dp, dp), jnp.float32).at[:n, :d, :d].set(a)
        # keep padded diagonal at 1 so Minv stays well-conditioned
        i = jnp.arange(d, dp)
        return out.at[:, i, i].set(1.0)

    Mp, Minvp = pad2(M), pad2(Minv)
    bp = jnp.zeros((np_, dp), jnp.float32).at[:n, :d].set(b)
    xp = jnp.zeros((np_, dp), jnp.float32).at[:n, :d].set(x)
    rp = jnp.zeros((np_,), jnp.float32).at[:n].set(r)
    mp = jnp.zeros((np_,), jnp.float32).at[:n].set(mask.astype(jnp.float32))

    Mo, Minvo, bo = rank1_update_pallas(
        Mp, Minvp, bp, xp, rp, mp, block_users=bu, interpret=interpret
    )
    return Mo[:n, :d, :d], Minvo[:n, :d, :d], bo[:n, :d]
