"""Public entry point for attention: flash kernel on TPU, oracle elsewhere."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .flash import flash_attention_pallas
from .ref import mha_ref


def attention(
    q, k, v,
    *,
    causal: bool = True,
    q_offset: int = 0,
    use_pallas: bool | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool | None = None,
):
    """[B,Hq,Sq,Dh] x [B,Hkv,Skv,Dh]^2 -> [B,Hq,Sq,Dh] (GQA softmax attn)."""
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if not use_pallas:
        return mha_ref(q, k, v, causal=causal, q_offset=q_offset)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    Sq, Skv = q.shape[2], k.shape[2]
    bq = min(block_q, Sq)
    bk = min(block_k, Skv)
    return flash_attention_pallas(
        q, k, v, causal=causal, q_offset=q_offset,
        block_q=bq, block_k=bk, interpret=interpret,
    )
