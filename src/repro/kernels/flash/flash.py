"""Blocked causal flash-attention Pallas kernel (GQA-aware).

FlashAttention's insight (arXiv:2205.14135) re-thought for TPU VMEM: tile
Q into (block_q, Dh) tiles resident in VMEM, stream K/V in (block_k, Dh)
tiles, and maintain the online-softmax running max/denominator in VMEM
scratch so the [Sq, Skv] score matrix never exists in HBM.  On the MXU the
two GEMMs per (q, k) tile are (block_q x Dh) @ (Dh x block_k) and
(block_q x block_k) @ (block_k x Dh) — block sizes default to 128 so every
matmul dim is systolic-array aligned.

Grid: (batch*heads, Sq/block_q, Skv/block_k), kv innermost so the scratch
carries across kv steps of one q tile.  GQA: the kv BlockSpec index_map
folds the q-head -> kv-head mapping (h // group), so no repeated KV is ever
materialized (that repeat is exactly what makes the XLA fallback
memory-bound at GQA shapes).

Causal handling: tiles entirely above the diagonal contribute nothing; the
kernel masks per-element with absolute positions (q_offset supports decode
where Sq << Skv) and `pl.when` skips the GEMMs for fully-masked tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, out_ref, acc_ref, m_ref, l_ref,
                  *, scale, causal, q_offset, block_q, block_k, n_kv_blocks):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = qi * block_q + q_offset
    k_start = ki * block_k

    # tile fully above the diagonal? (first q row < first k row)
    run = (not causal) or (q_start + block_q - 1 >= k_start)

    @pl.when(run)
    def _compute():
        q = q_ref[0]                                   # [bq, Dh]
        k = k_ref[0]                                   # [bk, Dh]
        v = v_ref[0]                                   # [bk, Dh]
        s = jax.lax.dot_general(
            q, k, dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale                                      # [bq, bk]
        if causal:
            qpos = q_start + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            kpos = k_start + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            s = jnp.where(qpos >= kpos, s, _NEG_INF)

        m_prev = m_ref[...]                            # [bq, 1]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)                         # [bq, bk]
        corr = jnp.exp(m_prev - m_new)                 # [bq, 1]
        l_ref[...] = corr * l_ref[...] + jnp.sum(p, axis=-1, keepdims=True)
        pv = jax.lax.dot_general(
            p, v, dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        acc_ref[...] = corr * acc_ref[...] + pv
        m_ref[...] = m_new

    @pl.when(ki == n_kv_blocks - 1)
    def _flush():
        out_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(
            out_ref.dtype
        )


@functools.partial(
    jax.jit,
    static_argnames=("causal", "q_offset", "block_q", "block_k", "interpret"),
)
def flash_attention_pallas(
    q: jnp.ndarray,   # [B, Hq, Sq, Dh]
    k: jnp.ndarray,   # [B, Hkv, Skv, Dh]
    v: jnp.ndarray,   # [B, Hkv, Skv, Dh]
    *,
    causal: bool = True,
    q_offset: int = 0,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    B, Hq, Sq, Dh = q.shape
    _, Hkv, Skv, _ = k.shape
    assert Sq % block_q == 0 and Skv % block_k == 0, (Sq, Skv)
    group = Hq // Hkv
    grid = (B * Hq, Sq // block_q, Skv // block_k)

    def q_map(bh, qi, ki):
        return (bh, qi, 0)

    def kv_map(bh, qi, ki):
        h = bh % Hq
        b = bh // Hq
        return (b * Hkv + h // group, ki, 0)

    kernel = functools.partial(
        _flash_kernel,
        scale=Dh ** -0.5, causal=causal, q_offset=q_offset,
        block_q=block_q, block_k=block_k, n_kv_blocks=Skv // block_k,
    )
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, Dh), q_map),
            pl.BlockSpec((1, block_k, Dh), kv_map),
            pl.BlockSpec((1, block_k, Dh), kv_map),
        ],
        out_specs=pl.BlockSpec((1, block_q, Dh), q_map),
        out_shape=jax.ShapeDtypeStruct((B * Hq, Sq, Dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, Dh), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
        ],
        interpret=interpret,
    )(
        q.reshape(B * Hq, Sq, Dh),
        k.reshape(B * Hkv, Skv, Dh),
        v.reshape(B * Hkv, Skv, Dh),
    )
    return out.reshape(B, Hq, Sq, Dh)
