"""Pure-jnp oracle for blocked causal (GQA) attention."""
from __future__ import annotations

import jax.numpy as jnp


def mha_ref(
    q: jnp.ndarray,   # [B, Hq, Sq, Dh]
    k: jnp.ndarray,   # [B, Hkv, Skv, Dh]
    v: jnp.ndarray,   # [B, Hkv, Skv, Dh]
    *,
    causal: bool = True,
    q_offset: int = 0,
) -> jnp.ndarray:
    """Softmax attention with GQA head sharing.

    ``q_offset`` positions the query block inside the kv sequence (decode:
    Sq=1, q_offset=cache_len-1).  Causal masking uses absolute positions.
    """
    B, Hq, Sq, Dh = q.shape
    Hkv = k.shape[1]
    group = Hq // Hkv
    kq = jnp.repeat(k, group, axis=1)
    vq = jnp.repeat(v, group, axis=1)
    scale = Dh ** -0.5
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, kq) * scale
    if causal:
        Skv = k.shape[2]
        qpos = jnp.arange(Sq)[:, None] + q_offset
        kpos = jnp.arange(Skv)[None, :]
        mask = qpos >= kpos
        logits = jnp.where(mask[None, None], logits, -jnp.inf)
    p = jnp.exp(logits - jnp.max(logits, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vq)
