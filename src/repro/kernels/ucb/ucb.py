"""Fused UCB scoring Pallas kernel — DistCLUB's serving hot path.

One grid step scores a *block of users* against their candidate sets:

    est[u,k]   = ctx[u,k,:] . w[u,:]
    quad[u,k]  = ctx[u,k,:] . Minv[u] . ctx[u,k,:]
    score[u,k] = est + alpha * sqrt(quad) * sqrt(log1p(occ[u]))

TPU mapping (this is the hardware-adaptation story from DESIGN.md §2): the
paper's d is tiny (19-25), far below the 128x128 MXU, so a per-user matvec
would waste >80% of the systolic array.  We instead make *users* the
parallel axis: a block of ``block_users`` users lives in VMEM at once and
the contraction over d runs as batched dot_generals whose batch dim fills
the MXU pipeline.  d and K are zero-padded to lane multiples by ``ops.py``;
zero columns contribute nothing to either the estimate or the quadratic
form, so padding is exact (not approximate).

VMEM budget per grid step (f32 words):
    ctx     block_users * K * d
    Minv    block_users * d * d
    w,occ   block_users * (d + 1)
    out     block_users * K
With the default block_users=256, K=128, d=32: ~1.3 MiB << 16 MiB VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ucb_kernel(w_ref, minv_ref, ctx_ref, occ_ref, alpha_ref, out_ref):
    ctx = ctx_ref[...]          # [Bu, K, d]
    minv = minv_ref[...]        # [Bu, d, d]
    w = w_ref[...]              # [Bu, d]
    occ = occ_ref[...]          # [Bu]
    alpha = alpha_ref[0]

    est = jax.lax.dot_general(
        ctx, w,
        dimension_numbers=(((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    )                           # [Bu, K]
    t = jax.lax.dot_general(
        ctx, minv,
        dimension_numbers=(((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    )                           # [Bu, K, d]
    quad = jnp.sum(t * ctx, axis=-1)                   # [Bu, K]
    bonus = alpha * jnp.sqrt(jnp.maximum(quad, 0.0)) * jnp.sqrt(
        jnp.log1p(occ.astype(jnp.float32))
    )[:, None]
    out_ref[...] = est + bonus


@functools.partial(jax.jit, static_argnames=("block_users", "interpret"))
def ucb_scores_pallas(
    w: jnp.ndarray,          # [n, d]   (n % block_users == 0; pad in ops.py)
    Minv: jnp.ndarray,       # [n, d, d]
    contexts: jnp.ndarray,   # [n, K, d]
    occ: jnp.ndarray,        # [n] i32
    alpha: float,
    *,
    block_users: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    n, K, d = contexts.shape
    assert n % block_users == 0, (n, block_users)
    grid = (n // block_users,)
    alpha_arr = jnp.full((1,), alpha, jnp.float32)

    return pl.pallas_call(
        _ucb_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_users, d), lambda i: (i, 0)),
            pl.BlockSpec((block_users, d, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((block_users, K, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((block_users,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_users, K), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, K), jnp.float32),
        interpret=interpret,
    )(w, Minv, contexts, occ, alpha_arr)
