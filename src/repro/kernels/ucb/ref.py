"""Pure-jnp oracle for the fused UCB scoring kernel."""
from __future__ import annotations

import jax.numpy as jnp


def ucb_scores_ref(
    w: jnp.ndarray,         # [n, d]
    Minv: jnp.ndarray,      # [n, d, d]
    contexts: jnp.ndarray,  # [n, K, d]
    occ: jnp.ndarray,       # [n] i32
    alpha: float,
) -> jnp.ndarray:
    """scores[n, K] = contexts.w + alpha sqrt(ctx Minv ctx) sqrt(log1p(occ))."""
    est = jnp.einsum("nkd,nd->nk", contexts, w)
    quad = jnp.einsum("nkd,nde,nke->nk", contexts, Minv, contexts)
    bonus = alpha * jnp.sqrt(jnp.maximum(quad, 0.0)) * jnp.sqrt(
        jnp.log1p(occ.astype(contexts.dtype))
    )[:, None]
    return est + bonus
