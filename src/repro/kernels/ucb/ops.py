"""Public entry point for fused UCB scoring: pads, dispatches, unpads."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..pad import padded_dims
from .ref import ucb_scores_ref
from .ucb import ucb_scores_pallas


def ucb_scores(
    w: jnp.ndarray,
    Minv: jnp.ndarray,
    contexts: jnp.ndarray,
    occ: jnp.ndarray,
    alpha: float,
    *,
    use_pallas: bool | None = None,
    block_users: int = 256,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """[n,K] UCB scores.  Pallas on TPU, jnp oracle elsewhere (or forced).

    Padding is exact: zero-padded feature columns contribute 0 to both the
    estimate and the quadratic form; padded users/candidates are sliced off.
    """
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if not use_pallas:
        return ucb_scores_ref(w, Minv, contexts, occ, alpha)

    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    n, K, d = contexts.shape
    np_, dp, Kp, bu = padded_dims(n, d, K, block_users)

    if (n, K, d) == (np_, Kp, dp):       # already aligned: no pad copies
        wp, Mp, cp, op = w, Minv, contexts, occ
    else:
        wp = jnp.zeros((np_, dp), jnp.float32).at[:n, :d].set(w)
        Mp = jnp.zeros((np_, dp, dp), jnp.float32).at[:n, :d, :d].set(Minv)
        cp = jnp.zeros((np_, Kp, dp), jnp.float32).at[:n, :K, :d].set(contexts)
        op = jnp.zeros((np_,), occ.dtype).at[:n].set(occ)

    out = ucb_scores_pallas(
        wp, Mp, cp, op, alpha, block_users=bu, interpret=interpret
    )
    return out[:n, :K]
