"""Shared TPU padding policy for the bandit kernels.

Single source of truth for the alignment the ucb / rank1 / interact kernels
assume: f32 sublane multiple for the feature dim, lane multiple for the
candidate dim, and a user-block multiple for the batch dim.  The ops
wrappers and ``core.backend`` all derive their padded shapes here, so the
aligned-shape short-circuits can never drift out of agreement with the
kernels' block asserts.
"""
from __future__ import annotations

LANE = 128     # TPU lane width
SUB = 8        # f32 sublane multiple


def round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def user_block(n: int, block_users: int = 256) -> tuple[int, int]:
    """(n_pad, block) — users rounded up to a whole number of blocks."""
    bu = min(block_users, round_up(n, SUB))
    return round_up(n, bu), bu


def padded_dims(n: int, d: int, K: int,
                block_users: int = 256) -> tuple[int, int, int, int]:
    """(n_pad, d_pad, K_pad, block) the fused kernels run at."""
    n_pad, bu = user_block(n, block_users)
    return n_pad, round_up(d, SUB), round_up(K, LANE), bu
