"""Pure-jnp oracle for the fused choose kernel.

Kept in terms of ``linucb.choose_batch`` semantics: score, first-index
argmax, gather.  This is also the CPU/GPU execution path when the Pallas
backend is off.
"""
from __future__ import annotations

import jax.numpy as jnp

from ..ucb.ref import ucb_scores_ref


def choose_ref(
    w: jnp.ndarray,          # [n, d]
    Minv: jnp.ndarray,       # [n, d, d]
    contexts: jnp.ndarray,   # [n, K, d]
    occ: jnp.ndarray,        # [n] i32
    alpha: float,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (choice [n] i32, x [n, d])."""
    # Minv may be stored bf16 (Precision); score in f32 like the kernel.
    scores = ucb_scores_ref(w, Minv.astype(jnp.float32), contexts, occ,
                            alpha)
    choice = jnp.argmax(scores, axis=-1).astype(jnp.int32)
    x = jnp.take_along_axis(contexts, choice[:, None, None], axis=1)[:, 0]
    return choice, x
