"""Fused choose Pallas kernel — the bandit interaction hot path.

One grid step serves a *block of users* end to end:

    score[u,k]  = ctx[u,k,:].w[u] + alpha sqrt(ctx Minv ctx) sqrt(log1p(occ[u]))
    choice[u]   = argmax_k score[u,k]          (first index on ties)
    x[u,:]      = ctx[u, choice[u], :]         (one-hot MXU gather)

This is the fusion of ``kernels/ucb`` scoring with the argmax and the
chosen-context gather that the reference drivers run as three separate XLA
ops.  The payoff is HBM traffic, not flops: the ``[n, K]`` score tensor and
the ``[n, K, d]`` scored-context intermediate live and die in VMEM — the
kernel reads each user's (w, Minv, ctx, occ) exactly once and writes only
``choice`` ([n] i32) and the chosen ``x`` ([n, d]).  The reference path
writes + re-reads scores and re-reads ctx for the gather, ~4 K d extra words
per user per round (see README "Backends & HBM accounting").

Padded candidates (K rounded up to the lane multiple by ``ops.py``) are
masked to -inf *inside* the kernel so a zero-padded candidate (score 0) can
never beat a real candidate with a negative score; padded feature columns
are exact by the same zero-column argument as ``kernels/ucb``.

VMEM budget per grid step (f32 words) matches the ucb kernel plus the
one-hot gather: ctx (Bu K d) + Minv (Bu d d) + scores/onehot (2 Bu K)
+ w/x (2 Bu d).  Defaults (Bu=256, K=128, d=32): ~1.5 MiB << 16 MiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _choose_kernel(w_ref, minv_ref, ctx_ref, occ_ref, scal_ref,
                   choice_ref, x_ref):
    ctx = ctx_ref[...]          # [Bu, K, d]
    # Minv may be stored bf16 (Precision state_dtype); upcast in VMEM so
    # the MXU contraction runs f32 (no-op for f32 inputs).
    minv = minv_ref[...].astype(jnp.float32)   # [Bu, d, d]
    w = w_ref[...]              # [Bu, d]
    occ = occ_ref[...]          # [Bu]
    alpha = scal_ref[0]
    k_live = scal_ref[1]        # number of real (non-padded) candidates

    est = jax.lax.dot_general(
        ctx, w,
        dimension_numbers=(((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    )                                                   # [Bu, K]
    t = jax.lax.dot_general(
        ctx, minv,
        dimension_numbers=(((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    )                                                   # [Bu, K, d]
    quad = jnp.sum(t * ctx, axis=-1)                    # [Bu, K]
    bonus = alpha * jnp.sqrt(jnp.maximum(quad, 0.0)) * jnp.sqrt(
        jnp.log1p(occ.astype(jnp.float32))
    )[:, None]

    bu, K = est.shape
    kidx = jax.lax.broadcasted_iota(jnp.int32, (bu, K), 1)
    live = kidx.astype(jnp.float32) < k_live
    scores = jnp.where(live, est + bonus, -jnp.inf)

    choice = jnp.argmax(scores, axis=-1).astype(jnp.int32)   # [Bu]
    onehot = (kidx == choice[:, None]).astype(jnp.float32)   # [Bu, K]
    x = jax.lax.dot_general(
        onehot, ctx,
        dimension_numbers=(((1,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    )                                                        # [Bu, d]
    choice_ref[...] = choice
    x_ref[...] = x


@functools.partial(jax.jit,
                   static_argnames=("k_live", "block_users", "interpret"))
def choose_pallas(
    w: jnp.ndarray,          # [n, d]   (n % block_users == 0; pad in ops.py)
    Minv: jnp.ndarray,       # [n, d, d]
    contexts: jnp.ndarray,   # [n, K, d]
    occ: jnp.ndarray,        # [n] i32
    alpha: float,
    k_live: int,             # candidates beyond this index are padding
    *,
    block_users: int = 256,
    interpret: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (choice [n] i32, x [n, d]) — scores never touch HBM."""
    n, K, d = contexts.shape
    assert n % block_users == 0, (n, block_users)
    grid = (n // block_users,)
    scal = jnp.array([alpha, float(k_live)], jnp.float32)

    return pl.pallas_call(
        _choose_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_users, d), lambda i: (i, 0)),
            pl.BlockSpec((block_users, d, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((block_users, K, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((block_users,), lambda i: (i,)),
            pl.BlockSpec((2,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((block_users,), lambda i: (i,)),
            pl.BlockSpec((block_users, d), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((n, d), jnp.float32),
        ],
        interpret=interpret,
    )(w, Minv, contexts, occ, scal)
