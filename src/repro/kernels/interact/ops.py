"""Public entry point for the fused choose: pads, dispatches, unpads.

Padding policy matches ``kernels/ucb``: d to the f32 sublane multiple, K to
the lane multiple, users to the block multiple.  When the caller already
holds padded arrays (the backend engine pads state once per stage), every
pad here is a trace-time no-op — no copies are issued.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..pad import padded_dims
from .interact import choose_pallas
from .ref import choose_ref


def choose(
    w: jnp.ndarray,          # [n, d]
    Minv: jnp.ndarray,       # [n, d, d]
    contexts: jnp.ndarray,   # [n, K, d]
    occ: jnp.ndarray,        # [n] i32
    alpha: float,
    *,
    use_pallas: bool | None = None,
    block_users: int = 256,
    interpret: bool | None = None,
    k_live: int | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """(choice [n] i32, x [n, d]).  Pallas on TPU, jnp oracle elsewhere.

    Padded candidates are masked to -inf inside the kernel; padded feature
    columns are exact (zero contribution); padded users are sliced off.
    ``k_live`` tells the kernel how many candidates are real when the caller
    hands in pre-padded contexts (defaults to the context K axis).
    """
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if not use_pallas:
        return choose_ref(w, Minv, contexts, occ, alpha)

    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    n, K, d = contexts.shape
    if k_live is None:
        k_live = K
    np_, dp, Kp, bu = padded_dims(n, d, K, block_users)

    if (n, K, d) == (np_, Kp, dp):
        wp, Mp, cp, op = w, Minv, contexts, occ     # already aligned
    else:
        wp = jnp.zeros((np_, dp), jnp.float32).at[:n, :d].set(w)
        Mp = jnp.zeros((np_, dp, dp), Minv.dtype).at[:n, :d, :d].set(Minv)
        cp = jnp.zeros((np_, Kp, dp), jnp.float32).at[:n, :K, :d].set(contexts)
        op = jnp.zeros((np_,), occ.dtype).at[:n].set(occ)

    choice, x = choose_pallas(
        wp, Mp, cp, op, alpha, k_live, block_users=bu, interpret=interpret
    )
    return choice[:n], x[:n, :d]
