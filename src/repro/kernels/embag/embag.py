"""EmbeddingBag Pallas kernel (scalar-prefetched gather + accumulate).

The recsys hot path: sparse-feature bags gather rows from a huge HBM table
and reduce them.  The TPU-native structure is *scalar prefetch*: bag
indices land in SMEM ahead of the grid so each grid step's BlockSpec
``index_map`` can select which table row the next DMA fetches — the gather
is expressed as data-dependent block indexing, and Mosaic double-buffers
the row DMAs against the accumulate.  (This is the standard TPU embedding
pattern; contrast a GPU implementation which would use per-thread gathers.)

Grid = (B, L): bag-major, so the output block (one bag row) stays resident
in VMEM across the L accumulation steps and is flushed once.

VMEM per step: one table row (D f32) + one out row — trivially small; the
win is entirely in DMA scheduling, as the op is pure memory traffic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _embag_kernel(idx_ref, wt_ref, row_ref, out_ref):
    b = pl.program_id(0)
    l = pl.program_id(1)

    @pl.when(l == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += wt_ref[b, l] * row_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def embedding_bag_pallas(
    table: jnp.ndarray,   # [V, D]
    idx: jnp.ndarray,     # [B, L] i32
    wt: jnp.ndarray,      # [B, L] f32
    *,
    interpret: bool = False,
) -> jnp.ndarray:
    B, L = idx.shape
    V, D = table.shape

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,           # idx, wt live in SMEM
        grid=(B, L),
        in_specs=[
            pl.BlockSpec((1, D), lambda b, l, idx_ref, wt_ref: (idx_ref[b, l], 0)),
        ],
        out_specs=pl.BlockSpec((1, D), lambda b, l, idx_ref, wt_ref: (b, 0)),
    )
    return pl.pallas_call(
        _embag_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, D), jnp.float32),
        interpret=interpret,
    )(idx, wt, table)
