"""Pure-jnp oracle for EmbeddingBag (gather + weighted segment reduce).

JAX has no native ``nn.EmbeddingBag``; per the taxonomy (§B.6 / §B.11) we
build it from take + reduction.  This reference is also the production CPU
path used by the recsys models.
"""
from __future__ import annotations

import jax.numpy as jnp


def embedding_bag_ref(
    table: jnp.ndarray,   # [V, D]
    idx: jnp.ndarray,     # [B, L] i32 (pad slots may point anywhere)
    wt: jnp.ndarray,      # [B, L] f32 (0 for pad slots)
) -> jnp.ndarray:
    """out[B, D] = sum_l wt[b,l] * table[idx[b,l]]."""
    rows = table[idx]                       # [B, L, D]
    return jnp.einsum("bld,bl->bd", rows, wt)
