"""Public entry point for EmbeddingBag."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .embag import embedding_bag_pallas
from .ref import embedding_bag_ref


def embedding_bag(
    table: jnp.ndarray,
    idx: jnp.ndarray,
    wt: jnp.ndarray | None = None,
    *,
    use_pallas: bool | None = None,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Weighted bag-sum over embedding rows: out[b] = sum_l wt[b,l] table[idx[b,l]].

    ``wt=None`` means plain sum (all-ones weights); use 0-weights for pads.
    """
    if wt is None:
        wt = jnp.ones(idx.shape, jnp.float32)
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if not use_pallas:
        return embedding_bag_ref(table, idx, wt)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return embedding_bag_pallas(table, idx, wt, interpret=interpret)
