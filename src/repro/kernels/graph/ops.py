"""Public entry points for the stage-2 graph engine: pad, dispatch, unpad.

Callers hold the packed adjacency at its *logical* shape
``[n_rows, ceil(n_cols/32)]`` (backend-independent, so reference and pallas
runs carry bit-identical state).  The pallas path pads rows to the row-block
multiple and words to the column-block multiple per call — stage 2 runs once
per epoch, so this is one O(n^2/8) copy per refresh, dwarfed by the sweep
itself.  All padding is exact: padded adjacency bits are 0 (AND-monotone,
never re-set), padded column labels are ``BIG_LABEL`` (never the min), and
padded rows are sliced off.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..pad import SUB, round_up
from .graph import cc_hop_packed_pallas, prune_packed_pallas
from .ref import (BIG_LABEL, cc_hop_packed_ref, init_packed_adj, pack_bits,
                  packed_words, pad_rows, prune_packed_ref, unpack_bits)

__all__ = [
    "BIG_LABEL", "init_packed_adj", "pack_bits", "packed_words",
    "unpack_bits", "prune_packed", "cc_hop_packed", "graph_blocks",
]


def graph_blocks(n_rows: int, n_cols: int, block_i: int = 256,
                 block_j: int = 4096) -> tuple[int, int, int, int]:
    """(rows_pad, cols_pad, bi, bj) the tiled kernels run at.

    Blocks clamp to the (sublane/word-aligned) problem size so small graphs
    run a single tile; at scale the defaults give a ``[256, 128]`` u32
    packed tile — exactly lane width.
    """
    bi = min(block_i, round_up(n_rows, SUB))
    bj = min(block_j, round_up(n_cols, 32))
    return round_up(n_rows, bi), round_up(n_cols, bj), bi, bj


def _pad_packed(packed, rows_pad, cols_pad):
    wp = cols_pad // 32
    out = pad_rows(packed, rows_pad)
    if out.shape[1] != wp:
        out = jnp.pad(out, ((0, 0), (0, wp - out.shape[1])))
    return out


def prune_packed(
    packed: jnp.ndarray,   # [R, W] uint32
    v_i: jnp.ndarray,      # [R, d]
    cb_i: jnp.ndarray,     # [R] f32 confidence widths
    v_j: jnp.ndarray,      # [C, d]
    cb_j: jnp.ndarray,     # [C] f32
    gamma: float,
    *,
    use_pallas: bool | None = None,
    block_i: int = 256,
    block_j: int = 4096,
    interpret: bool | None = None,
    row_block: int = 256,
) -> jnp.ndarray:
    """packed & (dist(v_i, v_j) < gamma (cb_i + cb_j)) — tiled on TPU."""
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if not use_pallas:
        return prune_packed_ref(packed, v_i, cb_i, v_j, cb_j, gamma,
                                row_block=row_block)

    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    R, W = packed.shape
    C, d = v_j.shape
    rows_pad, cols_pad, bi, bj = graph_blocks(R, W * 32, block_i, block_j)
    dp = round_up(d, SUB)

    def padv(v, n):
        out = pad_rows(v.astype(jnp.float32), n)
        if dp != d:
            out = jnp.pad(out, ((0, 0), (0, dp - d)))
        return out

    out = prune_packed_pallas(
        _pad_packed(packed, rows_pad, cols_pad),
        padv(v_i, rows_pad), pad_rows(cb_i.astype(jnp.float32), rows_pad),
        padv(v_j, cols_pad), pad_rows(cb_j.astype(jnp.float32), cols_pad),
        gamma, block_i=bi, block_j=bj, interpret=interpret,
    )
    return out[:R, :W]


def cc_hop_packed(
    packed: jnp.ndarray,        # [R, W] uint32
    labels_self: jnp.ndarray,   # [R] i32
    labels_j: jnp.ndarray,      # [C] i32
    *,
    use_pallas: bool | None = None,
    block_i: int = 256,
    block_j: int = 4096,
    interpret: bool | None = None,
    row_block: int = 256,
) -> jnp.ndarray:
    """min(labels_self, neighbour-min of labels_j over set bits) — [R] i32."""
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if not use_pallas:
        return cc_hop_packed_ref(packed, labels_self, labels_j,
                                 row_block=row_block)

    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    R, W = packed.shape
    rows_pad, cols_pad, bi, bj = graph_blocks(R, W * 32, block_i, block_j)
    out = cc_hop_packed_pallas(
        _pad_packed(packed, rows_pad, cols_pad),
        pad_rows(labels_self.astype(jnp.int32), rows_pad, fill=BIG_LABEL),
        pad_rows(labels_j.astype(jnp.int32), cols_pad, fill=BIG_LABEL),
        block_i=bi, block_j=bj, interpret=interpret,
    )
    return out[:R]
