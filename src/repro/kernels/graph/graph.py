"""Tiled Pallas kernels for the stage-2 graph engine.

Two kernels over the bit-packed adjacency (layout in ``ref.py``):

``prune``  grid (R/Bi, C/Bj).  Each step streams a ``[Bi, d] x [Bj, d]``
           pair of user-vector tiles into VMEM, forms the ``[Bi, Bj]``
           pairwise-distance tile and the CLUB threshold on the VPU/MXU,
           packs the keep-mask to ``[Bi, Bj/32]`` uint32 in registers
           (shift + sum — every bit is a distinct power of two, so sum is
           OR) and ANDs it into the adjacency tile.  The ``[n, n]`` f32
           distance matrix never reaches HBM: HBM traffic is the packed
           adjacency (n^2/8 bytes read + write) plus the streamed vector
           tiles, vs ``8 n^2 + 2 n^2`` bytes for the dense op-level path.

``cc_hop`` grid (R/Bi, C/Bj), output revisited across j.  Each step
           unpacks an adjacency tile via shift/mask in registers, takes
           the neighbour-min of the column labels, and folds it into the
           per-row running min (initialized with the row's own label at
           j == 0).  One pointer-doubling hop therefore reads n^2/8 bytes
           of adjacency instead of n^2 bool, plus O(n) label vectors.
           The label-chase ``min(l, l[l])`` stays outside (an O(n) gather).

Both kernels are shape-polymorphic over rows vs columns, so the sharded
runtime reuses them unchanged on ``[n_local, n]`` row shards inside
``shard_map``.  Defaults (Bi=256, Bj=4096) make the packed tile
``[256, 128]`` — exactly lane-width — and cost ~4.5 MiB VMEM at d=32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import BIG_LABEL


def _prune_kernel(vi_ref, vj_ref, cbi_ref, cbj_ref, scal_ref,
                  adj_ref, out_ref):
    vi = vi_ref[...]            # [Bi, d]
    vj = vj_ref[...]            # [Bj, d]
    gamma = scal_ref[0]
    d2 = (jnp.sum(vi * vi, axis=-1)[:, None]
          + jnp.sum(vj * vj, axis=-1)[None, :]
          - 2.0 * jax.lax.dot_general(
              vi, vj,
              dimension_numbers=(((1,), (1,)), ((), ())),
              preferred_element_type=jnp.float32,
          ))                                              # [Bi, Bj]
    dist = jnp.sqrt(jnp.maximum(d2, 0.0))
    thresh = gamma * (cbi_ref[...][:, None] + cbj_ref[...][None, :])
    keep = dist < thresh                                  # [Bi, Bj]

    bi, bj = keep.shape
    wb = bj // 32
    shifts = jax.lax.broadcasted_iota(jnp.uint32, (bi, wb, 32), 2)
    words = jnp.sum(keep.reshape(bi, wb, 32).astype(jnp.uint32) << shifts,
                    axis=-1, dtype=jnp.uint32)            # [Bi, Wb]
    out_ref[...] = adj_ref[...] & words


@functools.partial(jax.jit,
                   static_argnames=("block_i", "block_j", "interpret"))
def prune_packed_pallas(
    packed: jnp.ndarray,   # [R, Wp] u32, R % block_i == 0, Wp*32 % block_j == 0
    v_i: jnp.ndarray,      # [R, d]
    cb_i: jnp.ndarray,     # [R] f32
    v_j: jnp.ndarray,      # [C, d], C == Wp*32
    cb_j: jnp.ndarray,     # [C] f32
    gamma: float,
    *,
    block_i: int = 256,
    block_j: int = 4096,
    interpret: bool = False,
) -> jnp.ndarray:
    R, Wp = packed.shape
    C, d = v_j.shape
    assert R % block_i == 0, (R, block_i)
    assert C == Wp * 32 and C % block_j == 0, (C, Wp, block_j)
    wb = block_j // 32
    grid = (R // block_i, C // block_j)
    scal = jnp.array([gamma], jnp.float32)

    return pl.pallas_call(
        _prune_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_i, d), lambda i, j: (i, 0)),
            pl.BlockSpec((block_j, d), lambda i, j: (j, 0)),
            pl.BlockSpec((block_i,), lambda i, j: (i,)),
            pl.BlockSpec((block_j,), lambda i, j: (j,)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
            pl.BlockSpec((block_i, wb), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((block_i, wb), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((R, Wp), jnp.uint32),
        interpret=interpret,
    )(v_i, v_j, cb_i, cb_j, scal, packed)


def _cc_hop_kernel(adj_ref, lself_ref, lj_ref, out_ref):
    j = pl.program_id(1)
    adj = adj_ref[...]                # [Bi, Wb] u32
    bi, wb = adj.shape
    shifts = jax.lax.broadcasted_iota(jnp.uint32, (bi, wb, 32), 2)
    bits = ((adj[:, :, None] >> shifts) & jnp.uint32(1)) > 0
    # label of column 32*w + b sits at lj[w, b] after the row-major reshape
    neigh = jnp.where(bits, lj_ref[...].reshape(1, wb, 32), BIG_LABEL)
    m = jnp.min(jnp.min(neigh, axis=2), axis=1)          # [Bi]

    @pl.when(j == 0)
    def _():
        out_ref[...] = jnp.minimum(lself_ref[...], m)

    @pl.when(j > 0)
    def _():
        out_ref[...] = jnp.minimum(out_ref[...], m)


@functools.partial(jax.jit,
                   static_argnames=("block_i", "block_j", "interpret"))
def cc_hop_packed_pallas(
    packed: jnp.ndarray,        # [R, Wp] u32, aligned as in prune
    labels_self: jnp.ndarray,   # [R] i32
    labels_j: jnp.ndarray,      # [C] i32, C == Wp*32 (padding = BIG_LABEL)
    *,
    block_i: int = 256,
    block_j: int = 4096,
    interpret: bool = False,
) -> jnp.ndarray:
    R, Wp = packed.shape
    C = labels_j.shape[0]
    assert R % block_i == 0, (R, block_i)
    assert C == Wp * 32 and C % block_j == 0, (C, Wp, block_j)
    wb = block_j // 32
    grid = (R // block_i, C // block_j)

    return pl.pallas_call(
        _cc_hop_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_i, wb), lambda i, j: (i, j)),
            pl.BlockSpec((block_i,), lambda i, j: (i,)),
            pl.BlockSpec((block_j,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((block_i,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((R,), jnp.int32),
        interpret=interpret,
    )(packed, labels_self, labels_j)
