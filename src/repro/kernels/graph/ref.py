"""Pure-jnp oracle for the stage-2 graph engine — bit-packed adjacency.

Layout (single source of truth for every consumer): adjacency row ``i`` is
``W = ceil(n_cols / 32)`` uint32 words, LSB-first within a word, so

    edge (i, j)  <->  bit ``j % 32`` of ``packed[i, j // 32]``.

Bits at columns ``>= n_cols`` are always 0 (no edge) — pruning only ever
ANDs bits away, so the zero padding is an invariant, not a convention.

The reference prune / CC-hop below are *row-blocked* (``lax.map`` over row
tiles): numerically identical to the one-shot dense math — the only
contracted axis is the feature dim ``d``, so tiling over (i, j) cannot
change any per-element contraction order — but peak memory is
``O(row_block * n_cols)`` instead of ``O(n^2)``.  That is what lets the
n=65536 graph bench run on a CPU host where the dense ``[n, n]`` f32
distance matrix (17 GB) cannot be materialized alongside the rest of the
run.  These are the ``REPRO_BACKEND=reference`` execution path and the
numerical oracle for the Pallas kernels in ``graph.py``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..pad import round_up

# Label sentinel for "no neighbour in this word": larger than any user id
# (labels live in user-id space) yet far from int32 overflow under min().
# A plain int so Pallas kernels can use it without capturing an array.
BIG_LABEL = 2**30


def packed_words(n_cols: int) -> int:
    """Number of uint32 words per adjacency row."""
    return (n_cols + 31) // 32


def pack_bits(dense: jnp.ndarray, n_words: int | None = None) -> jnp.ndarray:
    """[..., C] bool -> [..., W] uint32 (LSB-first; W >= ceil(C/32))."""
    C = dense.shape[-1]
    W = packed_words(C) if n_words is None else n_words
    pad = W * 32 - C
    if pad:
        dense = jnp.pad(dense, [(0, 0)] * (dense.ndim - 1) + [(0, pad)])
    r = dense.reshape(*dense.shape[:-1], W, 32).astype(jnp.uint32)
    shifts = jnp.arange(32, dtype=jnp.uint32)
    # each bit position contributes a distinct power of two, so sum == OR
    return jnp.sum(r << shifts, axis=-1, dtype=jnp.uint32)


def unpack_bits(packed: jnp.ndarray, n_cols: int) -> jnp.ndarray:
    """[..., W] uint32 -> [..., n_cols] bool (inverse of ``pack_bits``)."""
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = (packed[..., :, None] >> shifts) & jnp.uint32(1)
    flat = bits.reshape(*packed.shape[:-1], packed.shape[-1] * 32)
    return flat[..., :n_cols].astype(bool)


def init_packed_adj(n_rows: int, n_cols: int, n_words: int | None = None,
                    row_offset: int = 0) -> jnp.ndarray:
    """Fully-connected packed adjacency minus self edges, [n_rows, W] u32.

    Built arithmetically (no [n, n] bool intermediate): full words below
    ``n_cols`` are 0xFFFFFFFF, the boundary word keeps its low
    ``n_cols % 32`` bits, and row ``i`` clears bit ``row_offset + i`` (its
    own column in the sharded row layout).
    """
    W = packed_words(n_cols) if n_words is None else n_words
    wi = jnp.arange(W, dtype=jnp.int32)
    rem = jnp.clip(n_cols - wi * 32, 0, 32)
    full = jnp.uint32(0xFFFFFFFF)
    partial = (jnp.uint32(1) << jnp.minimum(rem, 31).astype(jnp.uint32)
               ) - jnp.uint32(1)
    word = jnp.where(rem >= 32, full, partial)
    adj = jnp.broadcast_to(word, (n_rows, W))
    i = jnp.arange(n_rows, dtype=jnp.int32) + row_offset
    dw, db = i // 32, (i % 32).astype(jnp.uint32)
    rows = jnp.arange(n_rows)
    return adj.at[rows, dw].set(adj[rows, dw] & ~(jnp.uint32(1) << db))


def pad_rows(a: jnp.ndarray, n_pad: int, fill=0) -> jnp.ndarray:
    """Pad the leading axis to ``n_pad`` with ``fill`` (no-op if aligned)."""
    if a.shape[0] == n_pad:
        return a
    pad = [(0, n_pad - a.shape[0])] + [(0, 0)] * (a.ndim - 1)
    return jnp.pad(a, pad, constant_values=fill)


def prune_packed_ref(
    packed: jnp.ndarray,   # [R, W] uint32
    v_i: jnp.ndarray,      # [R, d] row-side user vectors
    cb_i: jnp.ndarray,     # [R] f32 confidence widths (cb_width(occ_i))
    v_j: jnp.ndarray,      # [C, d] column-side user vectors (C <= W*32)
    cb_j: jnp.ndarray,     # [C] f32
    gamma: float,
    *,
    row_block: int = 256,
) -> jnp.ndarray:
    """AND the CLUB keep-mask ``dist < gamma (cb_i + cb_j)`` into ``packed``.

    Row-blocked: each ``lax.map`` step computes a ``[rb, W*32]`` distance
    slab, packs it, and ANDs — the full distance matrix never exists.
    Padded columns (bits >= C) compare against zero vectors but their
    adjacency bits are 0, so the AND keeps them 0.
    """
    R, W = packed.shape
    C = W * 32
    d = v_i.shape[1]
    v_j = pad_rows(v_j.astype(jnp.float32), C)
    cb_j = pad_rows(cb_j.astype(jnp.float32), C)
    sq_j = jnp.sum(v_j * v_j, axis=-1)

    rb = min(row_block, R)
    Rp = round_up(R, rb)
    packed_p = pad_rows(packed, Rp)
    v_p = pad_rows(v_i.astype(jnp.float32), Rp)
    cb_p = pad_rows(cb_i.astype(jnp.float32), Rp)

    def blk(args):
        p, vb, cbb = args
        d2 = (jnp.sum(vb * vb, axis=-1)[:, None] + sq_j[None, :]
              - 2.0 * vb @ v_j.T)
        dist = jnp.sqrt(jnp.maximum(d2, 0.0))
        keep = dist < gamma * (cbb[:, None] + cb_j[None, :])
        return p & pack_bits(keep, W)

    out = jax.lax.map(blk, (packed_p.reshape(-1, rb, W),
                            v_p.reshape(-1, rb, d),
                            cb_p.reshape(-1, rb)))
    return out.reshape(Rp, W)[:R]


def cc_hop_packed_ref(
    packed: jnp.ndarray,        # [R, W] uint32
    labels_self: jnp.ndarray,   # [R] i32 current labels of the rows
    labels_j: jnp.ndarray,      # [C] i32 current labels of the columns
    *,
    row_block: int = 256,
) -> jnp.ndarray:
    """One min-label hop: ``min(labels_self, min over set bits of labels_j)``.

    The pointer-doubling shortcut (``l[l]``) stays with the caller — it is
    an O(n) gather on the label vector, not a graph sweep.
    """
    R, W = packed.shape
    C = W * 32
    lj = pad_rows(labels_j.astype(jnp.int32), C, fill=BIG_LABEL)

    rb = min(row_block, R)
    Rp = round_up(R, rb)
    packed_p = pad_rows(packed, Rp)
    ls_p = pad_rows(labels_self.astype(jnp.int32), Rp, fill=BIG_LABEL)

    def blk(args):
        p, ls = args
        bits = unpack_bits(p, C)
        neigh = jnp.where(bits, lj[None, :], BIG_LABEL)
        return jnp.minimum(ls, jnp.min(neigh, axis=1))

    out = jax.lax.map(blk, (packed_p.reshape(-1, rb, W),
                            ls_p.reshape(-1, rb)))
    return out.reshape(Rp)[:R]
