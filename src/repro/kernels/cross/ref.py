"""Pure-jnp oracle for the DCN-v2 cross layer."""
from __future__ import annotations

import jax.numpy as jnp


def cross_layer_ref(
    x0: jnp.ndarray,   # [B, d] base features
    xl: jnp.ndarray,   # [B, d] current layer input
    W: jnp.ndarray,    # [d, d]
    bias: jnp.ndarray,  # [d]
) -> jnp.ndarray:
    """x_{l+1} = x0 * (W xl + bias) + xl   (DCN-v2, arXiv:2008.13535)."""
    return x0 * (xl @ W.T + bias) + xl
