"""Public entry point for the DCN-v2 cross layer."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .cross import cross_layer_pallas
from .ref import cross_layer_ref


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def cross_layer(
    x0, xl, W, bias,
    *, use_pallas: bool | None = None, block_b: int = 256,
    interpret: bool | None = None,
):
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if not use_pallas:
        return cross_layer_ref(x0, xl, W, bias)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, d = x0.shape
    bb = min(block_b, _round_up(B, 8))
    Bp = _round_up(B, bb)
    if Bp != B:
        x0 = jnp.zeros((Bp, d), x0.dtype).at[:B].set(x0)
        xl = jnp.zeros((Bp, d), xl.dtype).at[:B].set(xl)
    out = cross_layer_pallas(x0, xl, W, bias, block_b=bb, interpret=interpret)
    return out[:B]
