"""Fused DCN-v2 cross-layer Pallas kernel.

x_{l+1} = x0 ⊙ (W x_l + b) + x_l

Unfused XLA emits a GEMM plus two elementwise passes over [B, d]; at recsys
batch sizes (65k-262k rows) those passes are pure HBM traffic.  The kernel
tiles B and keeps the GEMM epilogue (bias, Hadamard with x0, residual) in
VMEM: one read of x0/xl, one write of the output, W resident across steps.

Tiling: (block_b, d) x (d, d) GEMM per step — d is 512-2048 after the
embedding concat, so the MXU K/N dims are naturally 128-aligned; block_b
defaults to 256 sublanes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _cross_kernel(x0_ref, xl_ref, w_ref, bias_ref, out_ref):
    x0 = x0_ref[...]      # [Bb, d]
    xl = xl_ref[...]      # [Bb, d]
    W = w_ref[...]        # [d, d]
    bias = bias_ref[...]  # [1, d]
    wx = jax.lax.dot_general(
        xl, W,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    out_ref[...] = x0 * (wx + bias) + xl


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def cross_layer_pallas(
    x0: jnp.ndarray, xl: jnp.ndarray, W: jnp.ndarray, bias: jnp.ndarray,
    *, block_b: int = 256, interpret: bool = False,
) -> jnp.ndarray:
    B, d = x0.shape
    assert B % block_b == 0, (B, block_b)
    bias2 = bias.reshape(1, d)
    return pl.pallas_call(
        _cross_kernel,
        grid=(B // block_b,),
        in_specs=[
            pl.BlockSpec((block_b, d), lambda i: (i, 0)),
            pl.BlockSpec((block_b, d), lambda i: (i, 0)),
            pl.BlockSpec((d, d), lambda i: (0, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, d), jnp.float32),
        interpret=interpret,
    )(x0, xl, W, bias2)
