"""Row-blocked jnp oracle for the streaming UCB top-K retrieval kernel.

Semantics (shared with the Pallas kernel via :func:`select_topk`):

    score[u, i] = x_i . w_u + alpha sqrt(x_i' Minv_u x_i) sqrt(log1p(occ_u))
    shortlist_u = the ``k_short`` items with the largest scores, ordered by
                  (score desc, item id asc); dead items (``live == 0``)
                  score -inf and can only fill an underfull shortlist.

This is the same UCB the fused choose kernel computes over a per-round
slate — retrieval is "choose" with the catalog as the slate — so a
two-stage recommend (shortlist -> choose) degenerates to the direct-slate
path when the catalog fits in one slate.

The oracle never materializes the ``[n, N_items]`` score matrix either:
users are processed in ``row_block`` groups via ``lax.map`` and items in
``item_block`` tiles via ``lax.scan``, carrying a running
``[row_block, k_short]`` shortlist — ``N_items = 2**20`` runs on one CPU
core in a few seconds (see ``benchmarks/bench_retrieval.py``).

Tiling invariance (load-bearing for every parity claim): each item's
score contracts only over the feature dim, so its bits do not depend on
the tile partition, and :func:`select_topk` selects by *value*
``(score, id)`` — therefore reference/pallas, any block sizes, and the
per-shard + merge path of the item-sharded catalog all produce the
identical shortlist.

The quadratic form is computed as ``vec(Minv) . vec(x x')`` — one
``[rows, d^2] x [d^2, tile]`` contraction — matching the Pallas kernel's
MXU formulation bit for bit in interpret mode.

Cluster-pruned variant (:func:`topk_ref_pruned`): the item stream is the
cluster-SORTED catalog (``core.itemclub`` permutes slots so each tile
holds one cluster's items) and every (user, tile) pair carries a
precomputed upper bound ``tb`` (:func:`tile_bounds`).  A tile is skipped
for a whole user row-block iff STRICTLY ``tb < floor`` for every user in
the block, where ``floor`` is each user's running k-th shortlist score —
any item in such a tile scores ``<= tb < floor``, i.e. strictly below k
items already found, so it cannot enter the final shortlist even under
(score, id) tie-breaks.  ``tb == floor`` must NOT skip (an equal-score
item with a smaller id could still displace the floor entry), which is
why the comparison is strict.  Because per-item score bits are
tile-partition-invariant and :func:`select_topk` folds by value, the
pruned shortlist is BIT-EQUAL to the unpruned one — ties, churn and all.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

INT_MAX = jnp.iinfo(jnp.int32).max
NEG_INF = -jnp.inf


def select_topk(buf_s: jnp.ndarray, buf_i: jnp.ndarray, k: int):
    """Top-``k`` of each row of ``(buf_s [n, W], buf_i [n, W])`` by
    (score desc, id asc) — repeated (max score, min id) selection, so the
    result depends only on the (score, id) value multiset, never on the
    buffer order.  Returns ``(scores [n, k], ids [n, k])`` sorted the
    same way.  Shared verbatim by the oracle and the Pallas kernel."""
    n = buf_s.shape[0]
    cols = jax.lax.broadcasted_iota(jnp.int32, (n, k), 1)

    def step(j, carry):
        buf, out_s, out_i = carry
        m = jnp.max(buf, axis=1)                           # [n]
        tied = buf == m[:, None]
        sel = jnp.min(jnp.where(tied, buf_i, INT_MAX), axis=1)
        buf = jnp.where(tied & (buf_i == sel[:, None]), NEG_INF, buf)
        put = cols == j
        out_s = jnp.where(put, m[:, None], out_s)
        out_i = jnp.where(put, sel[:, None], out_i)
        return buf, out_s, out_i

    init = (buf_s,
            jnp.full((n, k), NEG_INF, jnp.float32),
            jnp.full((n, k), -1, jnp.int32))
    _, out_s, out_i = jax.lax.fori_loop(0, k, step, init)
    return out_s, out_i


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@functools.partial(jax.jit, static_argnames=("k_short", "row_block",
                                             "item_block"))
def topk_ref(
    w: jnp.ndarray,        # [n, d] user score vectors
    Minv: jnp.ndarray,     # [n, d, d]
    occ: jnp.ndarray,      # [n] i32
    items: jnp.ndarray,    # [N, d] catalog embeddings
    live: jnp.ndarray,     # [N] f32/bool liveness (0 = retired)
    alpha: float,
    k_short: int,
    *,
    row_block: int = 8,
    item_block: int = 4096,
    scales: jnp.ndarray | None = None,   # [N] f32 per-slot dequant scales
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """(scores [n, k_short], ids [n, k_short] i32; dead/pad entries keep
    score -inf — the caller maps them to id -1).

    ``items`` may be stored bf16 or int8 (``Precision``): scoring always
    runs on the f32 dequantized stream ``items.astype(f32) * scales`` —
    for int8 the caller passes the catalog's per-slot scales; bf16/f32
    pass ``scales=None`` and the astype upcast is the whole dequant (a
    no-op for f32, keeping the default policy bit-identical).  ``Minv``
    may be bf16 and is upcast the same way."""
    n, d = w.shape
    N = items.shape[0]
    ib = min(item_block, _round_up(N, 8))
    Np = _round_up(N, ib)
    rb = min(row_block, n)
    npad = _round_up(n, rb)

    items_f = items.astype(jnp.float32)
    if scales is not None:
        items_f = items_f * scales.astype(jnp.float32)[:, None]
    items_p = jnp.pad(items_f, ((0, Np - N), (0, 0)))
    live_p = jnp.pad(live.astype(jnp.float32), (0, Np - N))
    mf = jnp.pad(Minv.astype(jnp.float32).reshape(n, d * d),
                 ((0, npad - n), (0, 0)))
    w_p = jnp.pad(w, ((0, npad - n), (0, 0)))
    widen = jnp.pad(jnp.sqrt(jnp.log1p(occ.astype(jnp.float32))),
                    (0, npad - n))
    tiles = Np // ib

    def block_fn(blk):
        w_b, mf_b, f_b = blk                     # [rb, d], [rb, d^2], [rb]

        def tile_step(carry, t):
            run_s, run_i = carry
            x = jax.lax.dynamic_slice_in_dim(items_p, t * ib, ib)
            lv = jax.lax.dynamic_slice_in_dim(live_p, t * ib, ib)
            G = (x[:, None, :] * x[:, :, None]).reshape(ib, d * d)
            est = w_b @ x.T                                     # [rb, ib]
            quad = mf_b @ G.T                                   # [rb, ib]
            s = est + alpha * jnp.sqrt(jnp.maximum(quad, 0.0)) * f_b[:, None]
            s = jnp.where(lv[None, :] > 0, s, NEG_INF)
            ids = t * ib + jnp.arange(ib, dtype=jnp.int32)
            buf_s = jnp.concatenate([run_s, s], axis=1)
            buf_i = jnp.concatenate(
                [run_i, jnp.broadcast_to(ids[None], (rb, ib))], axis=1)
            return select_topk(buf_s, buf_i, k_short), None

        init = (jnp.full((rb, k_short), NEG_INF, jnp.float32),
                jnp.full((rb, k_short), -1, jnp.int32))
        (out_s, out_i), _ = jax.lax.scan(
            tile_step, init, jnp.arange(tiles, dtype=jnp.int32))
        return out_s, out_i

    blocks = (w_p.reshape(npad // rb, rb, d),
              mf.reshape(npad // rb, rb, d * d),
              widen.reshape(npad // rb, rb))
    out_s, out_i = jax.lax.map(block_fn, blocks)
    return (out_s.reshape(npad, k_short)[:n],
            out_i.reshape(npad, k_short)[:n])


# ---------------------------------------------------------------------------
# cluster-pruned streaming: per-tile UCB upper bounds + tile skipping
# ---------------------------------------------------------------------------

# absolute safety margin added to every tile bound: the bound math and the
# per-item score use different f32 op orders, so without slack a rounding
# wiggle of ~1e-6 could nudge a true bound below a real score and break
# exactness.  1e-4 dwarfs any accumulation error at serving magnitudes
# (scores are O(1)) while costing essentially no pruning.
BOUND_SLACK = 1e-4


@jax.jit
def tile_bounds(
    w: jnp.ndarray,        # [n, d] user score vectors
    Minv: jnp.ndarray,     # [n, d, d] SPD
    occ: jnp.ndarray,      # [n] i32
    alpha: float | jnp.ndarray,
    tile_mu: jnp.ndarray,  # [T, d] live-item tile centroids
    tile_r: jnp.ndarray,   # [T] max live |x - mu| per tile
    tile_xn: jnp.ndarray,  # [T] max live |x| per tile
    tile_n: jnp.ndarray,   # [T] i32 live items per tile
) -> jnp.ndarray:
    """[n, T] f32 — a TRUE upper bound on every live item score per tile:

        w.x                 <= w.mu + |w| r          (Cauchy-Schwarz)
        |x|_Minv            <= min(|mu|_Minv + sqrt(lmax) r, sqrt(lmax) xn)
                               (seminorm triangle ineq.; |v|_A <= sqrt(lmax)|v|)

    so  tb = w.mu + |w| r + alpha sqrt(log1p(occ)) min(...) + BOUND_SLACK
    dominates ``score[u, i]`` for every live ``i`` in the tile.  ``mu``
    is just a reference point — the bound holds for the STORED centroid
    whatever rounding produced it, as long as ``r >= max |x - mu|``.
    Zero-live tiles bound to -inf (skippable as soon as any floor
    exists).  The min keeps the bound tight both when a cluster is
    compact (centroid term) and when Minv is diffuse (max-norm term)."""
    n, d = w.shape
    T = tile_mu.shape[0]
    Minv = Minv.astype(jnp.float32)     # bf16 state: eigvalsh wants f32
    lmax = jnp.linalg.eigvalsh(Minv)[:, -1]            # [n] largest eig
    sl = jnp.sqrt(jnp.maximum(lmax, 0.0))
    est = w @ tile_mu.T + jnp.linalg.norm(w, axis=1)[:, None] * tile_r[None]
    G = (tile_mu[:, None, :] * tile_mu[:, :, None]).reshape(T, d * d)
    qmu = jnp.sqrt(jnp.maximum(Minv.reshape(n, d * d) @ G.T, 0.0))
    conf = jnp.minimum(qmu + sl[:, None] * tile_r[None],
                       sl[:, None] * tile_xn[None])
    widen = jnp.sqrt(jnp.log1p(occ.astype(jnp.float32)))
    tb = est + alpha * conf * widen[:, None] + BOUND_SLACK
    return jnp.where(tile_n[None] > 0, tb, NEG_INF)


@functools.partial(jax.jit, static_argnames=("k_short", "row_block"))
def topk_ref_pruned(
    w: jnp.ndarray,        # [n, d]
    Minv: jnp.ndarray,     # [n, d, d]
    occ: jnp.ndarray,      # [n] i32
    items: jnp.ndarray,    # [N, d] cluster-SORTED catalog embeddings
    live: jnp.ndarray,     # [N] f32/bool liveness in sorted order
    ids: jnp.ndarray,      # [N] i32 GLOBAL slot id of each sorted row
    alpha: float,
    k_short: int,
    tb: jnp.ndarray,       # [n, T] tile upper bounds (tile = N // T)
    *,
    row_block: int = 8,
    scales: jnp.ndarray | None = None,   # [N] f32 per-slot dequant scales
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """(scores [n, k_short], ids [n, k_short] — BIT-EQUAL to the
    unpruned shortlist over the unsorted catalog — plus
    (tiles_skipped [], tile_visits_total []) i32 skip telemetry).

    Selection buffers carry the ORIGINAL slot ids, so tie-breaks are by
    slot id exactly as in the unpruned stream — the (score, id) multiset
    is identical and :func:`select_topk` is value-based, hence
    bit-equality.  Two orderings make skipping actually fire: users are
    grouped into row blocks by their best-bound tile (per-user results
    are independent, so permuting and un-permuting rows is exact), and
    each block visits tiles in descending block-max bound order so the
    shortlist floor is high before doubtful tiles are tested.  The skip
    branch is a real ``lax.cond`` — a skipped tile's scoring work is
    never executed, which is the wall-clock (and modeled-HBM) win."""
    n, d = w.shape
    N = items.shape[0]
    T = tb.shape[1]
    assert N % T == 0, (N, T)
    ib = N // T
    rb = min(row_block, n)
    npad = _round_up(n, rb)

    # group users whose best tile coincides: a row block only skips a
    # tile when ALL of its users agree, so coherence is the lever
    order = jnp.argsort(jnp.argmax(tb, axis=1), stable=True).astype(jnp.int32)
    inv = jnp.argsort(order).astype(jnp.int32)
    pad_u = npad - n
    w_p = jnp.pad(w[order], ((0, pad_u), (0, 0)))
    mf = jnp.pad(Minv.astype(jnp.float32).reshape(n, d * d)[order],
                 ((0, pad_u), (0, 0)))
    widen = jnp.pad(jnp.sqrt(jnp.log1p(occ.astype(jnp.float32)))[order],
                    (0, pad_u))
    # padded users bound every tile at -inf: they vote "skip" as soon as
    # their (all-zero-statistics) floor leaves -inf, so they never keep a
    # tile alive that the real users would prune
    tb_p = jnp.pad(tb[order], ((0, pad_u), (0, 0)),
                   constant_values=NEG_INF)
    items_f = items.astype(jnp.float32)
    if scales is not None:
        items_f = items_f * scales.astype(jnp.float32)[:, None]
    live_f = live.astype(jnp.float32)
    ids_i = ids.astype(jnp.int32)

    def block_fn(blk):
        w_b, mf_b, f_b, tb_b = blk        # [rb,d] [rb,d^2] [rb] [rb,T]
        # likeliest tiles first: the floor saturates within the first
        # visited tiles, then everything that cannot beat it skips
        tile_order = jnp.argsort(-jnp.max(tb_b, axis=0)).astype(jnp.int32)

        def tile_step(carry, j):
            run_s, run_i, skipped = carry
            t = tile_order[j]
            floor = run_s[:, k_short - 1]
            skip = jnp.all(tb_b[:, t] < floor)     # STRICT: ties rescore

            def do_skip(c):
                rs, ri, sk = c
                return rs, ri, sk + 1

            def do_score(c):
                rs, ri, sk = c
                x = jax.lax.dynamic_slice_in_dim(items_f, t * ib, ib)
                lv = jax.lax.dynamic_slice_in_dim(live_f, t * ib, ib)
                iv = jax.lax.dynamic_slice_in_dim(ids_i, t * ib, ib)
                G = (x[:, None, :] * x[:, :, None]).reshape(ib, d * d)
                est = w_b @ x.T
                quad = mf_b @ G.T
                s = est + alpha * jnp.sqrt(
                    jnp.maximum(quad, 0.0)) * f_b[:, None]
                s = jnp.where(lv[None, :] > 0, s, NEG_INF)
                buf_s = jnp.concatenate([rs, s], axis=1)
                buf_i = jnp.concatenate(
                    [ri, jnp.broadcast_to(iv[None], (rb, ib))], axis=1)
                out_s, out_i = select_topk(buf_s, buf_i, k_short)
                return out_s, out_i, sk

            return jax.lax.cond(skip, do_skip, do_score,
                                (run_s, run_i, skipped)), None

        init = (jnp.full((rb, k_short), NEG_INF, jnp.float32),
                jnp.full((rb, k_short), -1, jnp.int32),
                jnp.zeros((), jnp.int32))
        (out_s, out_i, sk), _ = jax.lax.scan(
            tile_step, init, jnp.arange(T, dtype=jnp.int32))
        return out_s, out_i, sk

    blocks = (w_p.reshape(npad // rb, rb, d),
              mf.reshape(npad // rb, rb, d * d),
              widen.reshape(npad // rb, rb),
              tb_p.reshape(npad // rb, rb, T))
    out_s, out_i, sk = jax.lax.map(block_fn, blocks)
    total = jnp.asarray(T * (npad // rb), jnp.int32)
    return (out_s.reshape(npad, k_short)[:n][inv],
            out_i.reshape(npad, k_short)[:n][inv],
            jnp.sum(sk).astype(jnp.int32), total)
