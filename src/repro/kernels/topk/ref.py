"""Row-blocked jnp oracle for the streaming UCB top-K retrieval kernel.

Semantics (shared with the Pallas kernel via :func:`select_topk`):

    score[u, i] = x_i . w_u + alpha sqrt(x_i' Minv_u x_i) sqrt(log1p(occ_u))
    shortlist_u = the ``k_short`` items with the largest scores, ordered by
                  (score desc, item id asc); dead items (``live == 0``)
                  score -inf and can only fill an underfull shortlist.

This is the same UCB the fused choose kernel computes over a per-round
slate — retrieval is "choose" with the catalog as the slate — so a
two-stage recommend (shortlist -> choose) degenerates to the direct-slate
path when the catalog fits in one slate.

The oracle never materializes the ``[n, N_items]`` score matrix either:
users are processed in ``row_block`` groups via ``lax.map`` and items in
``item_block`` tiles via ``lax.scan``, carrying a running
``[row_block, k_short]`` shortlist — ``N_items = 2**20`` runs on one CPU
core in a few seconds (see ``benchmarks/bench_retrieval.py``).

Tiling invariance (load-bearing for every parity claim): each item's
score contracts only over the feature dim, so its bits do not depend on
the tile partition, and :func:`select_topk` selects by *value*
``(score, id)`` — therefore reference/pallas, any block sizes, and the
per-shard + merge path of the item-sharded catalog all produce the
identical shortlist.

The quadratic form is computed as ``vec(Minv) . vec(x x')`` — one
``[rows, d^2] x [d^2, tile]`` contraction — matching the Pallas kernel's
MXU formulation bit for bit in interpret mode.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

INT_MAX = jnp.iinfo(jnp.int32).max
NEG_INF = -jnp.inf


def select_topk(buf_s: jnp.ndarray, buf_i: jnp.ndarray, k: int):
    """Top-``k`` of each row of ``(buf_s [n, W], buf_i [n, W])`` by
    (score desc, id asc) — repeated (max score, min id) selection, so the
    result depends only on the (score, id) value multiset, never on the
    buffer order.  Returns ``(scores [n, k], ids [n, k])`` sorted the
    same way.  Shared verbatim by the oracle and the Pallas kernel."""
    n = buf_s.shape[0]
    cols = jax.lax.broadcasted_iota(jnp.int32, (n, k), 1)

    def step(j, carry):
        buf, out_s, out_i = carry
        m = jnp.max(buf, axis=1)                           # [n]
        tied = buf == m[:, None]
        sel = jnp.min(jnp.where(tied, buf_i, INT_MAX), axis=1)
        buf = jnp.where(tied & (buf_i == sel[:, None]), NEG_INF, buf)
        put = cols == j
        out_s = jnp.where(put, m[:, None], out_s)
        out_i = jnp.where(put, sel[:, None], out_i)
        return buf, out_s, out_i

    init = (buf_s,
            jnp.full((n, k), NEG_INF, jnp.float32),
            jnp.full((n, k), -1, jnp.int32))
    _, out_s, out_i = jax.lax.fori_loop(0, k, step, init)
    return out_s, out_i


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@functools.partial(jax.jit, static_argnames=("k_short", "row_block",
                                             "item_block"))
def topk_ref(
    w: jnp.ndarray,        # [n, d] user score vectors
    Minv: jnp.ndarray,     # [n, d, d]
    occ: jnp.ndarray,      # [n] i32
    items: jnp.ndarray,    # [N, d] catalog embeddings
    live: jnp.ndarray,     # [N] f32/bool liveness (0 = retired)
    alpha: float,
    k_short: int,
    *,
    row_block: int = 8,
    item_block: int = 4096,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """(scores [n, k_short], ids [n, k_short] i32; dead/pad entries keep
    score -inf — the caller maps them to id -1)."""
    n, d = w.shape
    N = items.shape[0]
    ib = min(item_block, _round_up(N, 8))
    Np = _round_up(N, ib)
    rb = min(row_block, n)
    npad = _round_up(n, rb)

    items_p = jnp.pad(items.astype(jnp.float32), ((0, Np - N), (0, 0)))
    live_p = jnp.pad(live.astype(jnp.float32), (0, Np - N))
    mf = jnp.pad(Minv.reshape(n, d * d), ((0, npad - n), (0, 0)))
    w_p = jnp.pad(w, ((0, npad - n), (0, 0)))
    widen = jnp.pad(jnp.sqrt(jnp.log1p(occ.astype(jnp.float32))),
                    (0, npad - n))
    tiles = Np // ib

    def block_fn(blk):
        w_b, mf_b, f_b = blk                     # [rb, d], [rb, d^2], [rb]

        def tile_step(carry, t):
            run_s, run_i = carry
            x = jax.lax.dynamic_slice_in_dim(items_p, t * ib, ib)
            lv = jax.lax.dynamic_slice_in_dim(live_p, t * ib, ib)
            G = (x[:, None, :] * x[:, :, None]).reshape(ib, d * d)
            est = w_b @ x.T                                     # [rb, ib]
            quad = mf_b @ G.T                                   # [rb, ib]
            s = est + alpha * jnp.sqrt(jnp.maximum(quad, 0.0)) * f_b[:, None]
            s = jnp.where(lv[None, :] > 0, s, NEG_INF)
            ids = t * ib + jnp.arange(ib, dtype=jnp.int32)
            buf_s = jnp.concatenate([run_s, s], axis=1)
            buf_i = jnp.concatenate(
                [run_i, jnp.broadcast_to(ids[None], (rb, ib))], axis=1)
            return select_topk(buf_s, buf_i, k_short), None

        init = (jnp.full((rb, k_short), NEG_INF, jnp.float32),
                jnp.full((rb, k_short), -1, jnp.int32))
        (out_s, out_i), _ = jax.lax.scan(
            tile_step, init, jnp.arange(tiles, dtype=jnp.int32))
        return out_s, out_i

    blocks = (w_p.reshape(npad // rb, rb, d),
              mf.reshape(npad // rb, rb, d * d),
              widen.reshape(npad // rb, rb))
    out_s, out_i = jax.lax.map(block_fn, blocks)
    return (out_s.reshape(npad, k_short)[:n],
            out_i.reshape(npad, k_short)[:n])
