"""Streaming UCB top-K Pallas kernel — catalog-scale retrieval.

One grid column serves a *block of users* against the whole item catalog:
the grid is ``(n / Bu, N / Bt)`` with the item axis innermost, so each
step streams one ``[Bt, d]`` catalog tile into VMEM, scores it for the
user block, and folds it into a running ``[Bu, k_short]`` shortlist held
in the (revisited) output blocks — exactly the ``cc_hop`` revisit pattern
of the graph engine.  The payoff is the whole point of the retrieval
engine: the ``[n, N_items]`` score matrix is never formed anywhere — not
in HBM, not even in VMEM — so serving against ``N_items ~ 2**20`` costs
the catalog stream (amortized over the user block) plus ``O(k_short)``
words of output per user instead of ``O(N_items)``.

Per tile the kernel computes

    est  = w @ x'                     [Bu, Bt]   (MXU)
    quad = vec(Minv) @ vec(x x')'     [Bu, Bt]   (MXU, d^2 contraction)
    s    = est + alpha sqrt(max(quad, 0)) sqrt(log1p(occ))   (VPU)

— the identical UCB the fused choose kernel scores a slate with, so the
two-stage recommend path re-ranks the shortlist with the same statistics
it was selected by.  Dead items (``live == 0``) and tile padding score
-inf.  The running shortlist is merged with the tile by
``ref.select_topk`` — repeated (max score, min id) selection, value-based
and therefore invariant to tile order/size — which the jnp oracle uses
verbatim; see ``ref.py`` for why that makes reference/pallas/sharded
shortlists identical.

VMEM per step (f32 words, defaults Bu=128, Bt=512, d<=32): Gram tile
``Bt d^2`` (2 MiB at d=32) + ``Minv`` ``Bu d^2`` (0.5 MiB) + score/merge
buffers ``~4 Bu (k_short + Bt)`` (~1.2 MiB at k_short=64) — well under
the 16 MiB budget.  The d^2 contraction is the LinUCB confidence width's
inherent cost; there is no [Bu, d, Bt] intermediate.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import NEG_INF, select_topk

# The pruned variant (`topk_pruned_pallas`) streams the cluster-SORTED
# catalog with a [Bu, T] tile-bound table resident per user block: before
# a tile's compute fires, the bound column is compared against the
# running shortlist floor and `pl.when` predicates the whole
# score+merge step off when no user in the block can be improved —
# the tile's MXU work is skipped and a revisited [1, 1] counter block
# accumulates how many tiles were.  Tiles arrive in natural order (the
# reference path's bound-descending visit order needs data-dependent
# index maps — `pltpu.PrefetchScalarGridSpec`, future TPU work), so the
# skip ratio trails the reference oracle's; exactness does not: per-item
# score bits and the value-based `select_topk` fold are identical, and
# the selection buffers carry ORIGINAL slot ids, so ties break exactly
# as in the unpruned stream.


def _topk_kernel(*refs, k_short: int, has_scales: bool):
    # With has_scales (int8 catalog) a per-slot scale block rides along
    # after `live`; f32/bf16 programs are EXACTLY the historical ones —
    # no extra input, and the astype upcasts are trace-time no-ops at f32.
    if has_scales:
        (w_ref, minv_ref, occ_ref, items_ref, live_ref, scale_ref,
         scal_ref, sc_ref, id_ref) = refs
    else:
        (w_ref, minv_ref, occ_ref, items_ref, live_ref,
         scal_ref, sc_ref, id_ref) = refs
        scale_ref = None
    t = pl.program_id(1)
    w = w_ref[...]                     # [Bu, d]
    minv = minv_ref[...].astype(jnp.float32)   # [Bu, d, d] (may be bf16)
    occ = occ_ref[...]                 # [Bu]
    x = items_ref[...].astype(jnp.float32)     # [Bt, d] (bf16/int8 ok)
    if scale_ref is not None:
        x = x * scale_ref[...][:, None]        # int8 dequant in VMEM
    live = live_ref[...]               # [Bt]
    alpha = scal_ref[0]
    bu, d = w.shape
    bt = x.shape[0]

    est = jax.lax.dot_general(
        w, x,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                                   # [Bu, Bt]
    G = (x[:, None, :] * x[:, :, None]).reshape(bt, d * d)
    quad = jax.lax.dot_general(
        minv.reshape(bu, d * d), G,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                                   # [Bu, Bt]
    widen = jnp.sqrt(jnp.log1p(occ.astype(jnp.float32)))
    s = est + alpha * jnp.sqrt(jnp.maximum(quad, 0.0)) * widen[:, None]
    s = jnp.where(live[None, :] > 0, s, NEG_INF)
    ids = t * bt + jax.lax.broadcasted_iota(jnp.int32, (bu, bt), 1)

    @pl.when(t == 0)
    def _():
        sc_ref[...] = jnp.full((bu, k_short), NEG_INF, jnp.float32)
        id_ref[...] = jnp.full((bu, k_short), -1, jnp.int32)

    buf_s = jnp.concatenate([sc_ref[...], s], axis=1)
    buf_i = jnp.concatenate([id_ref[...], ids], axis=1)
    out_s, out_i = select_topk(buf_s, buf_i, k_short)
    sc_ref[...] = out_s
    id_ref[...] = out_i


@functools.partial(jax.jit,
                   static_argnames=("k_short", "block_users", "block_items",
                                    "interpret"))
def topk_pallas(
    w: jnp.ndarray,        # [n, d]    (n % block_users == 0; pad in ops.py)
    Minv: jnp.ndarray,     # [n, d, d]
    occ: jnp.ndarray,      # [n] i32
    items: jnp.ndarray,    # [N, d]    (N % block_items == 0)
    live: jnp.ndarray,     # [N] f32   (0 = retired/padding -> -inf)
    alpha: float,
    k_short: int,
    *,
    block_users: int = 128,
    block_items: int = 512,
    interpret: bool = False,
    scales: jnp.ndarray | None = None,   # [N] f32 int8 dequant scales
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """(scores [n, k_short], ids [n, k_short] i32) — the [n, N] score
    matrix never exists; the running shortlist lives in revisited output
    blocks across the item-tile grid axis."""
    n, d = w.shape
    N = items.shape[0]
    assert n % block_users == 0, (n, block_users)
    assert N % block_items == 0, (N, block_items)
    grid = (n // block_users, N // block_items)
    scal = jnp.array([alpha], jnp.float32)

    in_specs = [
        pl.BlockSpec((block_users, d), lambda i, t: (i, 0)),
        pl.BlockSpec((block_users, d, d), lambda i, t: (i, 0, 0)),
        pl.BlockSpec((block_users,), lambda i, t: (i,)),
        pl.BlockSpec((block_items, d), lambda i, t: (t, 0)),
        pl.BlockSpec((block_items,), lambda i, t: (t,)),
    ]
    operands = [w, Minv, occ, items, live]
    if scales is not None:
        in_specs.append(pl.BlockSpec((block_items,), lambda i, t: (t,)))
        operands.append(scales)
    in_specs.append(pl.BlockSpec((1,), lambda i, t: (0,)))
    operands.append(scal)

    return pl.pallas_call(
        functools.partial(_topk_kernel, k_short=k_short,
                          has_scales=scales is not None),
        grid=grid,
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((block_users, k_short), lambda i, t: (i, 0)),
            pl.BlockSpec((block_users, k_short), lambda i, t: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, k_short), jnp.float32),
            jax.ShapeDtypeStruct((n, k_short), jnp.int32),
        ],
        interpret=interpret,
    )(*operands)


def _topk_pruned_kernel(*refs, k_short: int, has_scales: bool):
    if has_scales:
        (w_ref, minv_ref, occ_ref, items_ref, live_ref, ids_ref, tb_ref,
         scale_ref, scal_ref, sc_ref, id_ref, sk_ref) = refs
    else:
        (w_ref, minv_ref, occ_ref, items_ref, live_ref, ids_ref, tb_ref,
         scal_ref, sc_ref, id_ref, sk_ref) = refs
        scale_ref = None
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _():
        bu = w_ref.shape[0]
        sc_ref[...] = jnp.full((bu, k_short), NEG_INF, jnp.float32)
        id_ref[...] = jnp.full((bu, k_short), -1, jnp.int32)
        sk_ref[...] = jnp.zeros((1, 1), jnp.int32)

    floor = sc_ref[:, k_short - 1]
    # STRICT <: a bound equal to the floor may hold an equal-score item
    # with a smaller id, which would displace the floor entry
    skip = jnp.all(tb_ref[:, t] < floor)
    sk_ref[...] = sk_ref[...] + skip.astype(jnp.int32)

    @pl.when(~skip)
    def _():
        w = w_ref[...]                     # [Bu, d]
        minv = minv_ref[...].astype(jnp.float32)   # [Bu, d, d] (bf16 ok)
        occ = occ_ref[...]                 # [Bu]
        x = items_ref[...].astype(jnp.float32)     # [Bt, d] (bf16/int8 ok)
        if scale_ref is not None:
            x = x * scale_ref[...][:, None]        # int8 dequant in VMEM
        live = live_ref[...]               # [Bt]
        alpha = scal_ref[0]
        bu, d = w.shape
        bt = x.shape[0]
        est = jax.lax.dot_general(
            w, x,
            dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        G = (x[:, None, :] * x[:, :, None]).reshape(bt, d * d)
        quad = jax.lax.dot_general(
            minv.reshape(bu, d * d), G,
            dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        widen = jnp.sqrt(jnp.log1p(occ.astype(jnp.float32)))
        s = est + alpha * jnp.sqrt(jnp.maximum(quad, 0.0)) * widen[:, None]
        s = jnp.where(live[None, :] > 0, s, NEG_INF)
        ids = jnp.broadcast_to(ids_ref[...][None], (bu, bt))
        buf_s = jnp.concatenate([sc_ref[...], s], axis=1)
        buf_i = jnp.concatenate([id_ref[...], ids], axis=1)
        out_s, out_i = select_topk(buf_s, buf_i, k_short)
        sc_ref[...] = out_s
        id_ref[...] = out_i


@functools.partial(jax.jit,
                   static_argnames=("k_short", "block_users", "block_items",
                                    "interpret"))
def topk_pruned_pallas(
    w: jnp.ndarray,        # [n, d]        (n % block_users == 0)
    Minv: jnp.ndarray,     # [n, d, d]
    occ: jnp.ndarray,      # [n] i32
    items: jnp.ndarray,    # [N, d] cluster-sorted (N % block_items == 0)
    live: jnp.ndarray,     # [N] f32
    ids: jnp.ndarray,      # [N] i32 global slot ids of the sorted rows
    tb: jnp.ndarray,       # [n, T] tile bounds, T == N // block_items
    alpha: float,
    k_short: int,
    *,
    block_users: int = 128,
    block_items: int = 512,
    interpret: bool = False,
    scales: jnp.ndarray | None = None,   # [N] f32, sorted order
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """(scores [n, k_short], ids [n, k_short] i32,
    skipped [n // block_users, 1] i32 — tiles skipped per user block)."""
    n, d = w.shape
    N = items.shape[0]
    assert n % block_users == 0, (n, block_users)
    assert N % block_items == 0, (N, block_items)
    T = N // block_items
    assert tb.shape == (n, T), (tb.shape, n, T)
    grid = (n // block_users, T)
    scal = jnp.array([alpha], jnp.float32)

    in_specs = [
        pl.BlockSpec((block_users, d), lambda i, t: (i, 0)),
        pl.BlockSpec((block_users, d, d), lambda i, t: (i, 0, 0)),
        pl.BlockSpec((block_users,), lambda i, t: (i,)),
        pl.BlockSpec((block_items, d), lambda i, t: (t, 0)),
        pl.BlockSpec((block_items,), lambda i, t: (t,)),
        pl.BlockSpec((block_items,), lambda i, t: (t,)),
        pl.BlockSpec((block_users, T), lambda i, t: (i, 0)),
    ]
    operands = [w, Minv, occ, items, live, ids, tb]
    if scales is not None:
        in_specs.append(pl.BlockSpec((block_items,), lambda i, t: (t,)))
        operands.append(scales)
    in_specs.append(pl.BlockSpec((1,), lambda i, t: (0,)))
    operands.append(scal)

    return pl.pallas_call(
        functools.partial(_topk_pruned_kernel, k_short=k_short,
                          has_scales=scales is not None),
        grid=grid,
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((block_users, k_short), lambda i, t: (i, 0)),
            pl.BlockSpec((block_users, k_short), lambda i, t: (i, 0)),
            pl.BlockSpec((1, 1), lambda i, t: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, k_short), jnp.float32),
            jax.ShapeDtypeStruct((n, k_short), jnp.int32),
            jax.ShapeDtypeStruct((n // block_users, 1), jnp.int32),
        ],
        interpret=interpret,
    )(*operands)
