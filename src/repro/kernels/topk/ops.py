"""Public entry point for the streaming top-K: pads, dispatches, unpads.

Padding policy follows ``kernels/pad``: users to the block multiple, the
feature dim to the f32 sublane multiple (zero columns — exact for both
the estimate and the quadratic form), and the catalog to the item-tile
multiple with ``live = 0`` so padded rows score -inf and behave exactly
like retired items.  Padded *users* get zero statistics and are sliced
off.  Item padding cannot perturb real rows' shortlists: selection is by
(score, id) value (``ref.select_topk``), and a -inf pad entry only ever
fills a slot no live item claims.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..pad import SUB, round_up, user_block
from .ref import topk_ref, topk_ref_pruned
from .topk import topk_pallas, topk_pruned_pallas


def topk(
    w: jnp.ndarray,        # [n, d]
    Minv: jnp.ndarray,     # [n, d, d]
    occ: jnp.ndarray,      # [n] i32
    items: jnp.ndarray,    # [N, d]
    live: jnp.ndarray,     # [N] f32/bool
    alpha: float,
    k_short: int,
    *,
    use_pallas: bool | None = None,
    block_users: int = 128,
    block_items: int = 512,
    interpret: bool | None = None,
    scales: jnp.ndarray | None = None,   # [N] f32 per-slot dequant scales
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """(scores [n, k_short], ids [n, k_short]).  Pallas on TPU, jnp
    oracle elsewhere; ids of dead/underfull entries are whatever the
    selection produced — callers wanting a sentinel mask on
    ``isfinite(scores)`` (``core.backend.RetrievalBackend`` does).

    ``items``/``Minv`` may be reduced-precision (``Precision``): padding
    preserves the storage dtype and the kernels dequantize in VMEM —
    ``scales`` carries the int8 catalog's per-slot scales (None for
    f32/bf16).  Padded slots keep ``live = 0``, so their scale is
    irrelevant (zero-padded here)."""
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if not use_pallas:
        return topk_ref(w, Minv, occ, items, live, alpha, k_short,
                        scales=scales)

    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    n, d = w.shape
    N = items.shape[0]
    n_pad, bu = user_block(n, block_users)
    d_pad = round_up(d, SUB)
    bt = min(block_items, round_up(N, SUB))
    N_pad = round_up(N, bt)

    if (n, d, N) == (n_pad, d_pad, N_pad):
        wp, Mp, op = w, Minv, occ
        ip, lp, sp = items, live.astype(jnp.float32), scales
    else:
        wp = jnp.zeros((n_pad, d_pad), jnp.float32).at[:n, :d].set(w)
        Mp = jnp.zeros((n_pad, d_pad, d_pad), Minv.dtype
                       ).at[:n, :d, :d].set(Minv)
        op = jnp.zeros((n_pad,), occ.dtype).at[:n].set(occ)
        ip = jnp.zeros((N_pad, d_pad), items.dtype).at[:N, :d].set(items)
        lp = jnp.zeros((N_pad,), jnp.float32
                       ).at[:N].set(live.astype(jnp.float32))
        sp = (None if scales is None
              else jnp.zeros((N_pad,), jnp.float32).at[:N].set(scales))

    scores, ids = topk_pallas(
        wp, Mp, op, ip, lp, alpha, k_short,
        block_users=bu, block_items=bt, interpret=interpret, scales=sp,
    )
    return scores[:n], ids[:n]


def topk_pruned(
    w: jnp.ndarray,        # [n, d]
    Minv: jnp.ndarray,     # [n, d, d]
    occ: jnp.ndarray,      # [n] i32
    items: jnp.ndarray,    # [N, d] cluster-sorted catalog
    live: jnp.ndarray,     # [N] f32/bool in sorted order
    ids: jnp.ndarray,      # [N] i32 global slot ids of the sorted rows
    alpha: float,
    k_short: int,
    tb: jnp.ndarray,       # [n, T] tile bounds; tile size = N // T
    *,
    use_pallas: bool | None = None,
    block_users: int = 128,
    row_block: int = 8,
    interpret: bool | None = None,
    scales: jnp.ndarray | None = None,   # [N] f32, sorted order
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Cluster-pruned top-K: (scores [n, k_short], ids [n, k_short],
    tiles_skipped [], tile_visits_total []) — shortlist bit-equal to
    :func:`topk`'s over the unsorted catalog (see ``ref.py``).

    The item tile size is dictated by the bound table (``N // T``), not
    a free block parameter: a tile is the pruning granule.  ``N`` must
    be a tile multiple (``core.itemclub`` lays the sorted catalog out
    that way); only users and the feature dim are padded here.  Padded
    users carry ``tb = -inf`` so they always vote to skip."""
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    n, d = w.shape
    N = items.shape[0]
    T = tb.shape[1]
    assert N % T == 0, (N, T)
    if not use_pallas:
        return topk_ref_pruned(w, Minv, occ, items, live, ids, alpha,
                               k_short, tb, row_block=row_block,
                               scales=scales)

    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    n_pad, bu = user_block(n, block_users)
    d_pad = round_up(d, SUB)
    bt = N // T

    if (n, d) == (n_pad, d_pad):
        wp, Mp, op, tbp = w, Minv, occ, tb
        ip = items
    else:
        wp = jnp.zeros((n_pad, d_pad), jnp.float32).at[:n, :d].set(w)
        Mp = jnp.zeros((n_pad, d_pad, d_pad), Minv.dtype
                       ).at[:n, :d, :d].set(Minv)
        op = jnp.zeros((n_pad,), occ.dtype).at[:n].set(occ)
        tbp = jnp.full((n_pad, T), -jnp.inf, jnp.float32).at[:n].set(tb)
        ip = jnp.zeros((N, d_pad), items.dtype).at[:, :d].set(items)
    scores, out_ids, sk = topk_pruned_pallas(
        wp, Mp, op, ip, live.astype(jnp.float32), ids.astype(jnp.int32),
        tbp, alpha, k_short,
        block_users=bu, block_items=bt, interpret=interpret, scales=scales,
    )
    total = jnp.asarray(T * (n_pad // bu), jnp.int32)
    return scores[:n], out_ids[:n], jnp.sum(sk).astype(jnp.int32), total
