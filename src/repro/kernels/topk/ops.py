"""Public entry point for the streaming top-K: pads, dispatches, unpads.

Padding policy follows ``kernels/pad``: users to the block multiple, the
feature dim to the f32 sublane multiple (zero columns — exact for both
the estimate and the quadratic form), and the catalog to the item-tile
multiple with ``live = 0`` so padded rows score -inf and behave exactly
like retired items.  Padded *users* get zero statistics and are sliced
off.  Item padding cannot perturb real rows' shortlists: selection is by
(score, id) value (``ref.select_topk``), and a -inf pad entry only ever
fills a slot no live item claims.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..pad import SUB, round_up, user_block
from .ref import topk_ref
from .topk import topk_pallas


def topk(
    w: jnp.ndarray,        # [n, d]
    Minv: jnp.ndarray,     # [n, d, d]
    occ: jnp.ndarray,      # [n] i32
    items: jnp.ndarray,    # [N, d]
    live: jnp.ndarray,     # [N] f32/bool
    alpha: float,
    k_short: int,
    *,
    use_pallas: bool | None = None,
    block_users: int = 128,
    block_items: int = 512,
    interpret: bool | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """(scores [n, k_short], ids [n, k_short]).  Pallas on TPU, jnp
    oracle elsewhere; ids of dead/underfull entries are whatever the
    selection produced — callers wanting a sentinel mask on
    ``isfinite(scores)`` (``core.backend.RetrievalBackend`` does)."""
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if not use_pallas:
        return topk_ref(w, Minv, occ, items, live, alpha, k_short)

    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    n, d = w.shape
    N = items.shape[0]
    n_pad, bu = user_block(n, block_users)
    d_pad = round_up(d, SUB)
    bt = min(block_items, round_up(N, SUB))
    N_pad = round_up(N, bt)

    if (n, d, N) == (n_pad, d_pad, N_pad):
        wp, Mp, op = w, Minv, occ
        ip, lp = items, live.astype(jnp.float32)
    else:
        wp = jnp.zeros((n_pad, d_pad), jnp.float32).at[:n, :d].set(w)
        Mp = jnp.zeros((n_pad, d_pad, d_pad), jnp.float32
                       ).at[:n, :d, :d].set(Minv)
        op = jnp.zeros((n_pad,), occ.dtype).at[:n].set(occ)
        ip = jnp.zeros((N_pad, d_pad), jnp.float32).at[:N, :d].set(items)
        lp = jnp.zeros((N_pad,), jnp.float32
                       ).at[:N].set(live.astype(jnp.float32))

    scores, ids = topk_pallas(
        wp, Mp, op, ip, lp, alpha, k_short,
        block_users=bu, block_items=bt, interpret=interpret,
    )
    return scores[:n], ids[:n]
