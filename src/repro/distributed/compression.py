"""int8 gradient compression with error feedback (1-bit-Adam-family trick).

For cross-pod data parallelism the gradient all-reduce is the only traffic
on the (slower) pod-to-pod links; int8 quantization with per-tensor block
scales cuts it 4x vs f32 / 2x vs bf16.  Error feedback (Seide et al. 2014;
Karimireddy et al. 2019) keeps the *accumulated* quantization error in a
local buffer and folds it into the next step, preserving convergence
(the compressed SGD iterates track the exact ones to O(eta^2)).

Usage (wired as an option in the train step):

    comp, err = compress(g + err)          # quantize what we can't send
    g_hat     = decompress(comp)           # what the all-reduce actually moved
    err       = (g + err) - g_hat          # feedback for next step
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

BLOCK = 256


class Compressed(NamedTuple):
    q: jnp.ndarray        # int8 payload, padded flat [ceil(n/B)*B]
    scale: jnp.ndarray    # f32 per-block scales [ceil(n/B)]
    n: int                # true element count (static)


def compress(x: jnp.ndarray) -> Compressed:
    flat = x.astype(jnp.float32).reshape(-1)
    n = flat.shape[0]
    pad = (-n) % BLOCK
    flat = jnp.pad(flat, (0, pad)).reshape(-1, BLOCK)
    scale = jnp.maximum(jnp.max(jnp.abs(flat), axis=1) / 127.0, 1e-12)
    q = jnp.clip(jnp.round(flat / scale[:, None]), -127, 127).astype(jnp.int8)
    return Compressed(q=q.reshape(-1), scale=scale, n=n)


def decompress(c: Compressed, shape, dtype=jnp.float32) -> jnp.ndarray:
    deq = (c.q.reshape(-1, BLOCK).astype(jnp.float32)
           * c.scale[:, None]).reshape(-1)[: c.n]
    return deq.reshape(shape).astype(dtype)


def compressed_ratio(shape, dtype=jnp.float32) -> float:
    """bytes(compressed) / bytes(raw) for reporting."""
    import numpy as np

    n = int(np.prod(shape))
    nb = -(-n // BLOCK)
    raw = n * jnp.dtype(dtype).itemsize
    return (n + 4 * nb) / raw


def ef_step(grads, err):
    """One error-feedback round over a pytree.

    Returns (g_hat pytree — what a compressed all-reduce transports,
    new_err pytree).  The caller all-reduces g_hat (or, on hardware,
    all-reduces the int8 payloads and rescales).
    """
    def one(g, e):
        tot = g.astype(jnp.float32) + e
        c = compress(tot)
        g_hat = decompress(c, g.shape)
        return g_hat.astype(g.dtype), tot - g_hat

    out = jax.tree.map(one, grads, err)
    g_hat = jax.tree.map(lambda o: o[0], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_err = jax.tree.map(lambda o: o[1], out,
                           is_leaf=lambda x: isinstance(x, tuple))
    return g_hat, new_err


def init_error(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
