"""Distributed decode: tensor-parallel projections + flash-decoding attention.

Why shard_map and not pjit auto-sharding: decode against a 32k-524k KV
cache is dominated by reading the cache (B x Hkv x S x Dh x 2 per layer).
The only layout that scales it to 256-512 chips shards BOTH the batch
(over "pod","data") and the cache *sequence* (over "model").  The combine
across sequence shards is the flash-decoding split-K pattern — each shard
computes a partial online-softmax (m, l, acc) over its S/16 slice and the
shards merge with one tiny all_gather — which GSPMD cannot discover from a
scanned softmax, so we write the collectives ourselves.

Layout summary (single step, one token per sequence):
  activations x        [B_loc, d]      replicated over "model"
  wq/wk/wv             cols sharded over "model"  (TP)
  q/k after projection all_gather over "model" (tiny: B x H x Dh)
  KV cache             [nb, bl, B_loc, Hkv, S_loc, Dh], S over "model"
  attention            local partial flash -> all_gather(m, l, acc) -> merge
  wo / mlp down        rows sharded -> partial matmul -> psum (TP)
  MoE experts          E sharded over "model", replicated over "data"
                       (decode replicas don't ZeRO-shard weights; see
                       ``decode_param_specs``)
  lm_head              cols sharded -> logits stay vocab-sharded

Cross-pod ("pod" axis): pure DP — no collective in this step touches it,
so all gathers/psums stay on intra-pod ICI.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models import layers
from ..models.transformer import LMConfig


def decode_param_specs(cfg: LMConfig):
    """Training specs with ZeRO ("data") sharding stripped: serving replicas
    hold full (model-sharded) weights."""
    from ..models.transformer import lm_specs

    def strip(s: P):
        return P(*[None if e == "data" else e for e in s])

    return jax.tree.map(strip, lm_specs(cfg),
                        is_leaf=lambda x: isinstance(x, P))


def lm_specs_fshard(cfg: LMConfig):
    """Serving layout for llama4-class archs (weights/16 > HBM): expert d_ff
    additionally shards over "data" so per-device weights fit.  (Training
    uses the replicated-expert ZeRO-1 layout in ``moe.moe_specs``.)"""
    from ..models.transformer import lm_specs

    specs = lm_specs(cfg)

    def fshard_moe(block):
        if "moe" in block:
            e = block["moe"]["experts"]
            e["gate"] = P(None, "model", None, "data")
            e["up"] = P(None, "model", None, "data")
            e["down"] = P(None, "model", "data", None)
        return block

    for name, block in specs["blocks"].items():
        specs["blocks"][name] = fshard_moe(block)
    return specs


def cache_spec(ba):
    return P(None, None, ba, None, "model", None)


def _psum_lookup(table_loc, ids, lo, axis):
    """Row lookup from a dim0-sharded table: mask + psum."""
    v_loc = table_loc.shape[0]
    local = ids - lo
    ok = (local >= 0) & (local < v_loc)
    rows = table_loc[jnp.clip(local, 0, v_loc - 1)]
    rows = jnp.where(ok[..., None], rows, 0)
    return jax.lax.psum(rows, axis)


def _flash_decode_attn(q, k_loc, v_loc, pos, s_lo, axis,
                       k_scale=None, v_scale=None):
    """q [B,H,Dh]; k/v_loc [B,Hkv,S_loc,Dh] (this shard's S slice).

    int8 KV mode (k/v_scale [B,Hkv,S_loc] given): scores/values are
    rescaled per cache position instead of dequantizing the cache — the
    dominant decode cost is *reading* the cache, so int8 halves the
    memory-bound term (EXPERIMENTS.md §Perf, LM decode iteration).

    Returns merged attention output [B, H, Dh] (replicated over ``axis``).
    """
    B, H, Dh = q.shape
    Hkv, S_loc = k_loc.shape[1], k_loc.shape[2]
    g = H // Hkv
    qg = q.reshape(B, Hkv, g, Dh)
    s = jnp.einsum("bhgd,bhsd->bhgs", qg,
                   k_loc.astype(qg.dtype)) * (Dh ** -0.5)
    if k_scale is not None:
        s = s * k_scale[:, :, None, :]
    kpos = s_lo + jnp.arange(S_loc)
    valid = kpos <= pos
    s = jnp.where(valid[None, None, None, :], s.astype(jnp.float32), -jnp.inf)
    m = jnp.max(s, axis=-1)                                   # [B,Hkv,g]
    m_safe = jnp.where(jnp.isfinite(m), m, 0.0)
    p = jnp.exp(s - m_safe[..., None])
    p = jnp.where(valid[None, None, None, :], p, 0.0)
    l = jnp.sum(p, axis=-1)                                   # [B,Hkv,g]
    if v_scale is not None:
        pv = (p * v_scale[:, :, None, :]).astype(jnp.float32)
        acc = jnp.einsum("bhgs,bhsd->bhgd", pv,
                         v_loc.astype(jnp.float32))
    else:
        acc = jnp.einsum("bhgs,bhsd->bhgd", p.astype(v_loc.dtype), v_loc
                         ).astype(jnp.float32)

    # flash-decoding merge across sequence shards
    m_all = jax.lax.all_gather(m, axis)                       # [W,B,Hkv,g]
    l_all = jax.lax.all_gather(l, axis)
    acc_all = jax.lax.all_gather(acc, axis)                   # [W,B,Hkv,g,Dh]
    m_star = jnp.max(m_all, axis=0)
    w = jnp.exp(m_all - m_star[None])                         # [W,B,Hkv,g]
    l_star = jnp.sum(l_all * w, axis=0)
    out = jnp.sum(acc_all * w[..., None], axis=0) / jnp.maximum(
        l_star[..., None], 1e-30)
    return out.reshape(B, H, Dh)


def build_decode_step(mesh: Mesh, cfg: LMConfig, batch: int, s_max: int,
                      kv_quant: bool = False):
    """Returns (jit'd step, param_shardings, cache_shardings).

    step(params, token [B], (k_cache, v_cache), pos) ->
        (vocab-sharded logits [B, V], new cache)

    Three layouts by shape/size:
      * standard: batch over ("pod","data"), cache seq over "model",
        TP weights (model-sharded, ZeRO stripped).
      * tiny batch (long_500k, B=1): batch replicated, cache seq over
        EVERY axis (524288/512 = 1024 rows/chip), merge over the mesh.
      * f-sharded (llama4-class, weights/16 > HBM): expert d_ff stays
        sharded over "data" as in training, batch over "pod" only, cache
        seq over ("data","model"); MoE partial products psum over both.
    """
    tp = mesh.shape["model"]
    fshard = cfg.param_count() * 2 / tp > 8e9
    if fshard:
        ba = ("pod",) if ("pod" in mesh.axis_names
                          and batch % mesh.shape["pod"] == 0) else ()
        seq_ax = ("data", "model")
        p_specs = lm_specs_fshard(cfg)
    else:
        ba = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
        n_b = 1
        for a in ba:
            n_b *= mesh.shape[a]
        if batch % n_b != 0:
            ba = ()                                 # replicate batch
            seq_ax = tuple(mesh.axis_names)         # seq over all axes
        else:
            seq_ax = ("model",)
        p_specs = decode_param_specs(cfg)
    n_seq = 1
    for a in seq_ax:
        n_seq *= mesh.shape[a]
    assert s_max % n_seq == 0, (s_max, n_seq)
    c_spec = P(None, None, (ba or None), None, seq_ax, None)
    H, Hkv, Dh, d = cfg.n_heads, cfg.n_kv_heads, cfg.d_head, cfg.d_model

    def step(params, token, caches, pos):
        widx = jax.lax.axis_index("model")
        seq_idx = jax.lax.axis_index(seq_ax)
        s_loc = s_max // n_seq
        s_lo = seq_idx * s_loc
        v_loc = cfg.vocab // tp
        v_lo = widx * v_loc

        x = _psum_lookup(params["embed"], token, v_lo, "model")  # [B,d] repl.

        def attn_block(p, x, kc, vc, ks, vs):
            """x [B,d]; kc/vc [B,Hkv,S_loc,Dh] local (int8 when kv_quant,
            with ks/vs [B,Hkv,S_loc] scales). Returns (x', caches...)."""
            z = layers.rms_norm(x, p["ln1"]["scale"]).astype(x.dtype)
            # TP projections: local cols, gather heads
            q = jax.lax.all_gather(z @ p["attn"]["wq"], "model",
                                   axis=1, tiled=True).reshape(-1, H, Dh)
            k = jax.lax.all_gather(z @ p["attn"]["wk"], "model",
                                   axis=1, tiled=True).reshape(-1, Hkv, Dh)
            v = jax.lax.all_gather(z @ p["attn"]["wv"], "model",
                                   axis=1, tiled=True).reshape(-1, Hkv, Dh)
            if cfg.qk_norm:
                q = layers.rms_norm(q, p["attn"]["q_norm"]["scale"]).astype(q.dtype)
                k = layers.rms_norm(k, p["attn"]["k_norm"]["scale"]).astype(k.dtype)
            posv = jnp.full((1,), pos)
            # [B, H, Dh] -> [B, H, 1, Dh] so RoPE sees a length-1 sequence
            q = layers.apply_rope(q[:, :, None, :], posv, cfg.rope_base)[:, :, 0]
            k = layers.apply_rope(k[:, :, None, :], posv, cfg.rope_base)[:, :, 0]

            # masked cache write: only the owner of `pos` writes
            rel = pos - s_lo
            own = (rel >= 0) & (rel < s_loc)
            rel_c = jnp.clip(rel, 0, s_loc - 1)
            if kv_quant:
                def quant(a):
                    sc = jnp.maximum(jnp.max(jnp.abs(a), -1) / 127.0, 1e-8)
                    qv = jnp.clip(jnp.round(a / sc[..., None]),
                                  -127, 127).astype(jnp.int8)
                    return qv, sc.astype(jnp.float32)
                k_w, ks_w = quant(k.astype(jnp.float32))
                v_w, vs_w = quant(v.astype(jnp.float32))
                ks_ins = jax.lax.dynamic_update_slice_in_dim(
                    ks, ks_w[:, :, None], rel_c, axis=2)
                ks = jnp.where(own, ks_ins, ks)
                vs_ins = jax.lax.dynamic_update_slice_in_dim(
                    vs, vs_w[:, :, None], rel_c, axis=2)
                vs = jnp.where(own, vs_ins, vs)
            else:
                k_w, v_w = k, v
            k_ins = jax.lax.dynamic_update_slice_in_dim(
                kc, k_w[:, :, None, :], rel_c, axis=2)
            kc = jnp.where(own, k_ins, kc)
            v_ins = jax.lax.dynamic_update_slice_in_dim(
                vc, v_w[:, :, None, :], rel_c, axis=2)
            vc = jnp.where(own, v_ins, vc)

            o = _flash_decode_attn(
                q, kc, vc, pos, s_lo, seq_ax,
                k_scale=ks if kv_quant else None,
                v_scale=vs if kv_quant else None)
            o = o.astype(x.dtype).reshape(x.shape[0], H * Dh)
            # TP out-projection: slice my head rows, partial matmul, psum
            rows = H * Dh // tp
            o_loc = jax.lax.dynamic_slice_in_dim(o, widx * rows, rows, axis=1)
            attn_out = jax.lax.psum(o_loc @ p["attn"]["wo"], "model")
            return x + attn_out, kc, vc, ks, vs

        def mlp_block(p, x):
            z = layers.rms_norm(x, p["ln2"]["scale"]).astype(x.dtype)
            if "moe" in p:
                return x + _moe_decode(p["moe"], z)
            h = jax.nn.silu(z @ p["ffn"]["gate"]) * (z @ p["ffn"]["up"])
            return x + jax.lax.psum(h @ p["ffn"]["down"], "model")

        def _moe_decode(mp, z):
            B = z.shape[0]
            E, k_top = cfg.n_experts, cfg.top_k
            e_loc = E // tp
            e_lo = widx * e_loc
            logits = z.astype(jnp.float32) @ mp["router"]
            gate, idx = jax.lax.top_k(jax.nn.softmax(logits, -1), k_top)
            gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)
            we = mp["experts"]

            # decode batch is tiny: evaluate each *local* expert on all
            # tokens, weight by routing indicator, psum across shards.
            def one_expert(e):
                h = jax.nn.silu(z @ we["gate"][e]) * (z @ we["up"][e])
                out_e = h @ we["down"][e]
                w = jnp.sum(
                    jnp.where(idx == (e_lo + e), gate, 0.0), axis=-1
                ).astype(z.dtype)
                return out_e * w[:, None]

            out = jnp.sum(
                jax.vmap(one_expert)(jnp.arange(e_loc)), axis=0
            )
            # fshard: expert d_ff is data-sharded, so the down-projection
            # partials reduce over BOTH axes (EP over model + f over data)
            out = jax.lax.psum(out, ("data", "model") if fshard else "model")
            if cfg.n_shared > 0:
                sh = jax.nn.silu(z @ mp["shared"]["gate"]) * (
                    z @ mp["shared"]["up"])
                out = out + jax.lax.psum(sh @ mp["shared"]["down"], "model")
            return out

        if kv_quant:
            kc_all, vc_all, ks_all, vs_all = caches
        else:
            kc_all, vc_all = caches
            dummy = jnp.zeros(kc_all.shape[:-1], jnp.float32)
            ks_all = vs_all = dummy
        bl = cfg.block_layers

        def block(x, inp):
            bp, kcb, vcb, ksb, vsb = inp
            new_k, new_v, new_ks, new_vs = [], [], [], []
            for i in range(bl):
                lp = bp[f"l{i}"]
                x, kci, vci, ksi, vsi = attn_block(
                    lp, x, kcb[i], vcb[i], ksb[i], vsb[i])
                x = mlp_block(lp, x)
                new_k.append(kci)
                new_v.append(vci)
                new_ks.append(ksi)
                new_vs.append(vsi)
            return x, (jnp.stack(new_k), jnp.stack(new_v),
                       jnp.stack(new_ks), jnp.stack(new_vs))

        x, (kc_all, vc_all, ks_all, vs_all) = jax.lax.scan(
            block, x, (params["blocks"], kc_all, vc_all, ks_all, vs_all)
        )
        x = layers.rms_norm(x, params["final_norm"]["scale"]).astype(x.dtype)
        logits = x @ params["lm_head"]            # [B_loc, V/tp]
        if kv_quant:
            return logits, (kc_all, vc_all, ks_all, vs_all)
        return logits, (kc_all, vc_all)

    tok_spec = P(ba or None)
    out_spec = P(ba or None, "model")
    s_spec = P(None, None, (ba or None), None, seq_ax)   # scale arrays
    cache_specs_t = ((c_spec, c_spec, s_spec, s_spec) if kv_quant
                     else (c_spec, c_spec))
    sharded = shard_map(
        step, mesh=mesh,
        in_specs=(p_specs, tok_spec, cache_specs_t, P()),
        out_specs=(out_spec, cache_specs_t),
        check_rep=False,
    )
    param_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), p_specs,
                            is_leaf=lambda x: isinstance(x, P))
    cache_sh = tuple(NamedSharding(mesh, s) for s in cache_specs_t)
    step_jit = jax.jit(
        sharded,
        in_shardings=(param_sh, NamedSharding(mesh, tok_spec),
                      cache_sh, NamedSharding(mesh, P())),
        out_shardings=(NamedSharding(mesh, out_spec), cache_sh),
        donate_argnums=(2,),
    )
    return step_jit, param_sh, cache_sh
