"""Sharding helpers shared by the bandit runtime and the model zoo."""
from __future__ import annotations

import contextlib
import threading

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_TLS = threading.local()


@contextlib.contextmanager
def hint_mesh(mesh: Mesh):
    """Ambient mesh for ``hint()`` constraints inside model code.

    Model forward functions are mesh-agnostic; the launcher installs the
    mesh around tracing so deep intermediates (MoE dispatch buffers, etc.)
    can pin their layouts without threading a mesh argument everywhere.
    """
    prev = getattr(_TLS, "mesh", None)
    _TLS.mesh = mesh
    try:
        yield
    finally:
        _TLS.mesh = prev


def hint(x, *spec_entries):
    """with_sharding_constraint(x, P(*entries)) if a hint mesh is active."""
    mesh = getattr(_TLS, "mesh", None)
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*spec_entries)))


def flat_axis_size(mesh: Mesh, axes: tuple[str, ...]) -> int:
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    return size


def user_sharding(mesh: Mesh, axes: tuple[str, ...]) -> NamedSharding:
    """Shard dim 0 (users / batch) over the given mesh axes jointly."""
    return NamedSharding(mesh, P(axes))


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


def local_slice(n: int, axis_index: jnp.ndarray, n_shards: int):
    """(start, size) of this shard's slice of an n-length axis (n % shards == 0)."""
    per = n // n_shards
    return axis_index * per, per


def zero_specs(spec_tree, abstract_tree, data_size: int):
    """ZeRO-shard a spec tree: add "data" on the largest still-replicated,
    divisible dim of every leaf that doesn't already use it.

    Used for optimizer moments and gradient accumulators — they carry no
    compute, so fully sharding them costs one reduce-scatter/all-gather pair
    per step and divides their HBM footprint by the data-axis size.
    """
    def one(spec: P, ab):
        entries = list(spec) + [None] * (ab.ndim - len(spec))
        flat = []
        for e in entries:
            flat.extend(e if isinstance(e, tuple) else (e,))
        if "data" in flat:
            return P(*entries)
        best, best_dim = 0, -1
        for i, e in enumerate(entries):
            if e is None and ab.shape[i] % data_size == 0 and ab.shape[i] > best:
                best, best_dim = ab.shape[i], i
        if best_dim >= 0:
            entries[best_dim] = "data"
        return P(*entries)

    return jax.tree.map(one, spec_tree, abstract_tree,
                        is_leaf=lambda x: isinstance(x, P))
