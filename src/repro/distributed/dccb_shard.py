"""Distributed DCCB baseline under shard_map — gossip via collective_permute.

The paper's scaling argument (Fig 6) needs DCCB runnable on the same mesh
as DistCLUB.  Users are sharded as in ``distclub_shard``; the per-epoch
structure is L lockstep interaction steps followed by one gossip round.

The interaction steps route through the SAME shared round protocol as the
DistCLUB stages (``runtime.stages.interaction_rounds``): DCCB supplies a
lagged-Gram ``score_fn`` and a FIFO-buffer ``update_fn``, and the
environment is any shard-aware ``EnvOps`` (synthetic / drift / replay) —
the old runtime inlined the synthetic generator and carried ``theta``.
Per-user PRNG keying means a sharded DCCB run draws the same
contexts/rewards as the single-host ``repro.core.dccb`` driver.

Gossip mapping: the paper pairs each user with a random connected peer.
On a mesh, cross-shard random pairing is an all-to-all; the standard
hardware-shaped equivalent is a *permuted-neighbor* exchange — each shard
sends its users' (buffer, current) payloads to the next shard over the
ring (``collective_permute``, exactly one ICI hop) and pairs its users
with the arrivals.  Information still spreads one hop per round (the same
rate as the paper's random gossip in expectation); the per-round traffic
IS the paper's Table-4 objection: (L+1)(d^2+d) floats per user, which this
implementation ships for real.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..core import clustering, linucb
from ..core.backend import BackendConfig, InteractBackend
from ..core.env_ops import EnvOps, default_synthetic_ops
from ..core.types import BanditHyper, Metrics
from ..runtime import stages
from ..runtime.collectives import lax_collectives


class ShardedDCCB(NamedTuple):
    Mw: jnp.ndarray       # [n, d, d] current (lagged) Gram
    bw: jnp.ndarray       # [n, d]
    xbuf: jnp.ndarray     # [n, L, d]   FIFO of pending update contexts
    rbuf: jnp.ndarray     # [n, L]      ... and rewards
    occ: jnp.ndarray      # [n] i32
    comm_bytes: jnp.ndarray  # [] f32


def state_specs(axes) -> ShardedDCCB:
    s = P(axes)
    return ShardedDCCB(Mw=s, bw=s, xbuf=s, rbuf=s, occ=s, comm_bytes=P())


def init_state(n, d, L) -> ShardedDCCB:
    eye = jnp.eye(d, dtype=jnp.float32) + jnp.zeros((n, d, d), jnp.float32)
    return ShardedDCCB(
        Mw=eye, bw=jnp.zeros((n, d), jnp.float32),
        xbuf=jnp.zeros((n, L, d), jnp.float32),
        rbuf=jnp.zeros((n, L), jnp.float32),
        occ=jnp.zeros((n,), jnp.int32),
        comm_bytes=jnp.zeros((), jnp.float32),
    )


def build_epoch_fn(mesh: Mesh, axes, n: int, d: int, L: int,
                   hyper: BanditHyper,
                   ops: EnvOps | None = None,
                   backend: InteractBackend | None = None):
    col = lax_collectives(mesh, axes)
    n_shards = col.n_shards
    assert n % n_shards == 0
    n_local = n // n_shards
    be = backend or BackendConfig.create().interact(n_local, d,
                                                    hyper.n_candidates)
    env = ops or default_synthetic_ops(n, d, hyper.n_candidates)

    def epoch(state: ShardedDCCB, key: jax.Array):
        # same key schedule as the single-host driver (dccb._run splits
        # each epoch key into interaction/gossip halves), so both drivers
        # draw identical per-user env streams from one epoch key; the ring
        # gossip here is deterministic, so its key half goes unused.
        k_int, _ = jax.random.split(key)
        row0 = col.axis_index() * n_local

        # ---- L lockstep interactions via the shared round protocol ------
        def score_lagged(carry):
            Mw, bw, *_ = carry
            Minv = jnp.linalg.inv(Mw)
            return linucb.user_vector(Minv, bw), Minv

        def update_buffered(carry, slot, x, realized, mask):
            del mask                            # lockstep: all users live
            Mw, bw, xbuf, rbuf, occ = carry
            # pop oldest into current; push the new update
            x_old = xbuf[:, slot]
            r_old = rbuf[:, slot]
            Mw = Mw + jnp.einsum("ni,nj->nij", x_old, x_old)
            bw = bw + r_old[:, None] * x_old
            xbuf = xbuf.at[:, slot].set(x)
            rbuf = rbuf.at[:, slot].set(realized)
            return (Mw, bw, xbuf, rbuf, occ + 1)

        carry0 = (state.Mw, state.bw, state.xbuf, state.rbuf, state.occ)
        (Mw, bw, xbuf, rbuf, occ), metrics = stages.interaction_rounds(
            be, env, hyper, k_int, carry0, row0=row0, n_steps=L,
            occ_of=lambda c: c[4], score_fn=score_lagged,
            update_fn=update_buffered, budget=None,
        )
        metrics = jax.tree.map(lambda v: col.psum(v), metrics)

        # ---- gossip: one-hop ring exchange of (buffer + current) --------
        perm = [(i, (i + 1) % n_shards) for i in range(n_shards)]

        def ring(a):
            return jax.lax.ppermute(a, axes, perm)

        pM, pb = ring(Mw), ring(bw)
        pxb, prb = ring(xbuf), ring(rbuf)
        pocc = ring(occ)

        # paper's update: compare local vs peer estimates; average when
        # neighborhoods agree (here: always merge-average — the ring pairs
        # each user with one peer, the complete-graph early phase)
        M_loc = Mw + jnp.einsum("nld,nle->nde", xbuf, xbuf)
        b_loc = bw + jnp.einsum("nl,nld->nd", rbuf, xbuf)
        Mp_loc = pM + jnp.einsum("nld,nle->nde", pxb, pxb)
        bp_loc = pb + jnp.einsum("nl,nld->nd", prb, pxb)
        w = jnp.linalg.solve(M_loc, b_loc[..., None])[..., 0]
        v = jnp.linalg.solve(Mp_loc, bp_loc[..., None])[..., 0]
        dist = jnp.linalg.norm(w - v, axis=-1)
        width = clustering.cb_width(occ) + clustering.cb_width(pocc)
        similar = dist < hyper.gamma * width

        def mix(a, pa):
            sim = similar.reshape((-1,) + (1,) * (a.ndim - 1))
            return jnp.where(sim, 0.5 * (a + pa), a)

        Mw = mix(Mw, pM)
        bw = mix(bw, pb)
        xbuf = mix(xbuf, pxb)
        rbuf = mix(rbuf, prb)

        per_user = (L + 1) * (d * d + d) * 4.0
        comm = state.comm_bytes + jnp.float32(n) * per_user
        return ShardedDCCB(Mw, bw, xbuf, rbuf, occ, comm), metrics

    specs = state_specs(axes)
    return shard_map(
        epoch, mesh=mesh,
        in_specs=(specs, P()),
        out_specs=(specs, Metrics(P(), P(), P(), P())),
        check_rep=False,
    )


def make_runtime(mesh: Mesh, axes, n: int, d: int, L: int,
                 hyper: BanditHyper, ops: EnvOps | None = None):
    """(init_fn, jit'd epoch_fn); ``init_fn(key)`` ignores its key (the
    environment's randomness lives in ``ops``).  ``metrics`` out of the
    epoch is per-step ``[L]`` rows, like the single-host driver."""
    epoch = build_epoch_fn(mesh, axes, n, d, L, hyper, ops)
    shardings = jax.tree.map(
        lambda s: NamedSharding(mesh, s), state_specs(axes),
        is_leaf=lambda x: isinstance(x, P))

    def init_fn(key):
        del key
        return jax.device_put(init_state(n, d, L), shardings)

    epoch_jit = jax.jit(
        epoch,
        in_shardings=(shardings, NamedSharding(mesh, P())),
        out_shardings=(shardings, jax.tree.map(
            lambda _: NamedSharding(mesh, P()), Metrics(0, 0, 0, 0))),
        donate_argnums=(0,),
    )
    return init_fn, epoch_jit
