"""Distributed DCCB baseline under shard_map — gossip via collective_permute.

The paper's scaling argument (Fig 6) needs DCCB runnable on the same mesh
as DistCLUB.  Users are sharded as in ``distclub_shard``; the per-epoch
structure is L lockstep interaction steps followed by one gossip round.

Gossip mapping: the paper pairs each user with a random connected peer.
On a mesh, cross-shard random pairing is an all-to-all; the standard
hardware-shaped equivalent is a *permuted-neighbor* exchange — each shard
sends its users' (buffer, current) payloads to the next shard over the
ring (``collective_permute``, exactly one ICI hop) and pairs its users
with the arrivals.  Information still spreads one hop per round (the same
rate as the paper's random gossip in expectation); the per-round traffic
IS the paper's Table-4 objection: (L+1)(d^2+d) floats per user, which this
implementation ships for real.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..core import clustering
from ..core.env import expected_reward, sample_contexts
from ..core.types import BanditHyper, Metrics


class ShardedDCCB(NamedTuple):
    Mw: jnp.ndarray       # [n, d, d] current (lagged) Gram
    bw: jnp.ndarray       # [n, d]
    xbuf: jnp.ndarray     # [n, L, d]   FIFO of pending update contexts
    rbuf: jnp.ndarray     # [n, L]      ... and rewards
    occ: jnp.ndarray      # [n] i32
    theta: jnp.ndarray    # [n, d]
    comm_bytes: jnp.ndarray  # [] f32


def state_specs(axes) -> ShardedDCCB:
    s = P(axes)
    return ShardedDCCB(Mw=s, bw=s, xbuf=s, rbuf=s, occ=s, theta=s,
                       comm_bytes=P())


def init_state(n, d, L, theta) -> ShardedDCCB:
    eye = jnp.eye(d, dtype=jnp.float32) + jnp.zeros((n, d, d), jnp.float32)
    return ShardedDCCB(
        Mw=eye, bw=jnp.zeros((n, d), jnp.float32),
        xbuf=jnp.zeros((n, L, d), jnp.float32),
        rbuf=jnp.zeros((n, L), jnp.float32),
        occ=jnp.zeros((n,), jnp.int32), theta=theta,
        comm_bytes=jnp.zeros((), jnp.float32),
    )


def build_epoch_fn(mesh: Mesh, axes, n: int, d: int, L: int,
                   hyper: BanditHyper):
    n_shards = 1
    for a in axes:
        n_shards *= mesh.shape[a]
    assert n % n_shards == 0

    def epoch(state: ShardedDCCB, key: jax.Array):
        idx = jax.lax.axis_index(axes)
        key = jax.random.fold_in(key, idx)
        K = hyper.n_candidates

        # ---- L lockstep interactions (buffer turns over once) ----------
        def step(carry, inp):
            Mw, bw, xbuf, rbuf, occ = carry
            slot, k = inp
            k_ctx, k_rew = jax.random.split(k)
            contexts = sample_contexts(k_ctx, (Mw.shape[0],), K, d)
            w = jnp.linalg.solve(Mw, bw[..., None])[..., 0]
            Z = jnp.linalg.solve(Mw, jnp.swapaxes(contexts, -1, -2))
            quad = jnp.einsum("nkd,ndk->nk", contexts, Z)
            est = jnp.einsum("nkd,nd->nk", contexts, w)
            bonus = hyper.alpha * jnp.sqrt(jnp.maximum(quad, 0.0)) * jnp.sqrt(
                jnp.log1p(occ.astype(jnp.float32)))[:, None]
            choice = jnp.argmax(est + bonus, axis=-1)
            x = jnp.take_along_axis(contexts, choice[:, None, None], 1)[:, 0]
            p_all = expected_reward(state.theta[:, None, :], contexts)
            p_c = jnp.take_along_axis(p_all, choice[:, None], 1)[:, 0]
            r = (jax.random.uniform(k_rew, p_c.shape) < p_c).astype(
                jnp.float32)

            # pop oldest into current; push the new update
            x_old = xbuf[:, slot]
            r_old = rbuf[:, slot]
            Mw = Mw + jnp.einsum("ni,nj->nij", x_old, x_old)
            bw = bw + r_old[:, None] * x_old
            xbuf = xbuf.at[:, slot].set(x)
            rbuf = rbuf.at[:, slot].set(r)
            m = Metrics(
                reward=jnp.sum(r),
                regret=jnp.sum(jnp.max(p_all, -1) - p_c),
                rand_reward=jnp.sum(jnp.mean(p_all, -1)),
                interactions=jnp.int32(r.shape[0]),
            )
            return (Mw, bw, xbuf, rbuf, occ + 1), m

        keys = jax.random.split(key, L)
        (Mw, bw, xbuf, rbuf, occ), metrics = jax.lax.scan(
            step, (state.Mw, state.bw, state.xbuf, state.rbuf, state.occ),
            (jnp.arange(L), keys))
        metrics = jax.tree.map(lambda v: jnp.sum(v, 0), metrics)
        metrics = jax.tree.map(lambda v: jax.lax.psum(v, axes), metrics)

        # ---- gossip: one-hop ring exchange of (buffer + current) --------
        perm = [(i, (i + 1) % n_shards) for i in range(n_shards)]

        def ring(a):
            return jax.lax.ppermute(a, axes, perm)

        pM, pb = ring(Mw), ring(bw)
        pxb, prb = ring(xbuf), ring(rbuf)
        pocc = ring(occ)

        # paper's update: compare local vs peer estimates; average when
        # neighborhoods agree (here: always merge-average — the ring pairs
        # each user with one peer, the complete-graph early phase)
        M_loc = Mw + jnp.einsum("nld,nle->nde", xbuf, xbuf)
        b_loc = bw + jnp.einsum("nl,nld->nd", rbuf, xbuf)
        Mp_loc = pM + jnp.einsum("nld,nle->nde", pxb, pxb)
        bp_loc = pb + jnp.einsum("nl,nld->nd", prb, pxb)
        w = jnp.linalg.solve(M_loc, b_loc[..., None])[..., 0]
        v = jnp.linalg.solve(Mp_loc, bp_loc[..., None])[..., 0]
        dist = jnp.linalg.norm(w - v, axis=-1)
        width = clustering.cb_width(occ) + clustering.cb_width(pocc)
        similar = dist < hyper.gamma * width

        def mix(a, pa):
            sim = similar.reshape((-1,) + (1,) * (a.ndim - 1))
            return jnp.where(sim, 0.5 * (a + pa), a)

        Mw = mix(Mw, pM)
        bw = mix(bw, pb)
        xbuf = mix(xbuf, pxb)
        rbuf = mix(rbuf, prb)

        per_user = (L + 1) * (d * d + d) * 4.0
        comm = state.comm_bytes + jnp.float32(n) * per_user
        return ShardedDCCB(Mw, bw, xbuf, rbuf, occ, state.theta, comm), metrics

    specs = state_specs(axes)
    return shard_map(
        epoch, mesh=mesh,
        in_specs=(specs, P()),
        out_specs=(specs, Metrics(P(), P(), P(), P())),
        check_rep=False,
    )


def make_runtime(mesh: Mesh, axes, n: int, d: int, L: int,
                 hyper: BanditHyper):
    epoch = build_epoch_fn(mesh, axes, n, d, L, hyper)
    shardings = jax.tree.map(
        lambda s: NamedSharding(mesh, s), state_specs(axes),
        is_leaf=lambda x: isinstance(x, P))

    def init_fn(key):
        theta = jax.random.normal(key, (n, d))
        theta = theta / jnp.linalg.norm(theta, axis=-1, keepdims=True)
        return jax.device_put(init_state(n, d, L, theta), shardings)

    epoch_jit = jax.jit(
        epoch,
        in_shardings=(shardings, NamedSharding(mesh, P())),
        out_shardings=(shardings, jax.tree.map(
            lambda _: NamedSharding(mesh, P()), Metrics(0, 0, 0, 0))),
        donate_argnums=(0,),
    )
    return init_fn, epoch_jit
