"""Distributed DistCLUB: the paper's four stages under ``shard_map``.

Layout (users = the distribution axis, sharded over every mesh axis
flattened — the bandit equivalent of pure data parallelism):

  Mu, Minv, bu, occ, budgets : sharded on dim 0   -> [n_local, ...]
  adj (bit-packed uint32)    : sharded rows       -> [n_local, ceil(n/32)]
  labels                     : replicated [n]     (refreshed by all_gather)
  cluster stats              : replicated [n,...] (produced by psum — the
                               paper's treeReduce on the ICI all-reduce tree)

Stage 1/3 are purely local (zero communication — the paper's
"embarrassingly parallel" claim is literal here).  Stage 2 is the only
communicating stage and its traffic is exactly the paper's model: one
all-gather of the n x d user vectors + occ for edge pruning, label hops
during connected components, and one psum of the (n,d,d)+(n,d) aggregates.
The adjacency never crosses the network — each shard prunes and hops its
own packed rows through the graph engine (``repro.kernels.graph`` inside
``shard_map``): the [n_local, n] f32 distance slab stays in VMEM tiles and
each CC hop reads n_local*n/8 bytes of packed bits instead of n_local*n
bool (32x less resident graph, 8x less HBM sweep than dense bool).

The environment inside the sharded runtime is the synthetic generator
(per-device PRNG folded with the shard index); replay datasets use the
single-host driver in ``repro.core``.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..core import linucb
from ..core.backend import (GraphBackend, InteractBackend, get_backend,
                            get_graph_backend)
from ..core.env import expected_reward, sample_contexts
from ..core.types import BanditHyper, Metrics
from ..kernels.graph import ops as graph_ops


class ShardedDistCLUB(NamedTuple):
    """State as seen *outside* shard_map (global shapes).

    §Perf iteration (bandit cell): the Gram matrix M is NOT carried — only
    its inverse is needed per interaction (UCB + Sherman-Morrison), and
    stage-2's cluster aggregation recovers M = inv(Minv) locally once per
    epoch.  Dropping M cuts the per-round state traffic by ~1/3 on the
    memory-bound bandit cell (EXPERIMENTS.md §Perf)."""

    Minv: jnp.ndarray     # [n, d, d]   sharded dim0
    b: jnp.ndarray        # [n, d]      sharded dim0
    occ: jnp.ndarray      # [n]         sharded dim0
    adj: jnp.ndarray      # [n, ceil(n/32)] uint32 bit-packed, sharded rows
    labels: jnp.ndarray   # [n]         replicated (n i32 — cheap)
    uMcinv: jnp.ndarray   # [n, d, d]   sharded: per-user copy of its
                          #             cluster's inverse Gram (stage-2 snap)
    ubc: jnp.ndarray      # [n, d]      sharded: per-user cluster bias
    umean_occ: jnp.ndarray  # [n] f32   sharded: cluster mean occ snapshot
    u_rounds: jnp.ndarray  # [n] i32    sharded dim0
    c_rounds: jnp.ndarray  # [n] i32    sharded dim0
    theta: jnp.ndarray    # [n, d]      sharded dim0 (synthetic env truth)

    # §Perf iteration 2 (bandit cell): the label-indexed cluster tables
    # (Mc/Mcinv/bc, 3 x [n,d,d] REPLICATED) dominated per-device HBM
    # traffic (cost_analysis: ~790 MB/device/epoch, mostly these).  They
    # are now transients inside stage-2; the carried state holds only
    # per-user sharded snapshots (n_loc x d x d).  The within-stage-3
    # update of the seen-counter is deferred to the next stage-2 (the
    # paper's own lazy-update argument).


def state_specs(axes: tuple[str, ...]) -> ShardedDistCLUB:
    s = P(axes)          # dim-0 sharded
    r = P()              # replicated
    return ShardedDistCLUB(
        Minv=s, b=s, occ=s, adj=s, labels=r,
        uMcinv=s, ubc=s, umean_occ=s,
        u_rounds=s, c_rounds=s, theta=s,
    )


def init_state(n: int, d: int, hyper: BanditHyper, theta: jnp.ndarray) -> ShardedDistCLUB:
    def eye():
        # distinct buffers: the jit'd epoch donates its inputs and XLA
        # rejects the same buffer appearing in two donated slots.
        return jnp.eye(d, dtype=jnp.float32) + jnp.zeros((n, d, d), jnp.float32)

    return ShardedDistCLUB(
        Minv=eye(),
        b=jnp.zeros((n, d), jnp.float32),
        occ=jnp.zeros((n,), jnp.int32),
        adj=graph_ops.init_packed_adj(n, n),
        labels=jnp.zeros((n,), jnp.int32),
        uMcinv=eye(),
        ubc=jnp.zeros((n, d), jnp.float32),
        umean_occ=jnp.zeros((n,), jnp.float32),
        u_rounds=jnp.full((n,), hyper.sigma, jnp.int32),
        c_rounds=jnp.full((n,), hyper.sigma, jnp.int32),
        theta=theta,
    )


def _local_round(lin_Minv, lin_b, occ, theta_true, budget, key, hyper,
                 score_fn, be: InteractBackend):
    """Shared stage-1/3 inner loop over a local user shard. Zero comms.

    Runs through the fused interaction engine: the local (Minv, b, occ)
    shard is padded to the kernel block shape ONCE before the scan and the
    scan carries the padded state; per step only the fresh context tensor
    is padded.  ``score_fn`` receives and returns padded-width arrays.
    The M-free fused update applies here — the sharded state carries no
    Gram matrix, so the state traffic per round is one read + one write of
    Minv (plus the choose read) instead of the reference path's separate
    score-read / Sherman-Morrison read / subtract-and-write sweeps.
    """
    K = hyper.n_candidates
    d = lin_b.shape[-1]
    n_loc = lin_b.shape[0]

    Minv0 = be.pad_gram(lin_Minv)                 # pad once per stage
    b0 = be.pad_vec(lin_b)
    occ0 = be.pad_users(occ)
    budget_p = be.pad_users(budget)               # padded users: budget 0

    def step(carry, inp):
        Minv, b, occ = carry
        step_idx, k = inp
        k_ctx, k_rew = jax.random.split(k)
        mask = step_idx < budget_p
        contexts = sample_contexts(k_ctx, (n_loc,), K, d)
        w, minv_eff = score_fn(Minv, b, occ)
        x, choice = be.choose(w, minv_eff, contexts, occ, hyper.alpha)
        choice_log = be.unpad_users(choice)

        p_all = expected_reward(theta_true[:, None, :], contexts)
        p_choice = jnp.take_along_axis(p_all, choice_log[:, None],
                                       axis=1)[:, 0]
        realized = (jax.random.uniform(k_rew, p_choice.shape) < p_choice
                    ).astype(jnp.float32)

        Minv, b = be.update_inv(Minv, b, x, be.pad_users(realized), mask)
        occ = occ + mask.astype(jnp.int32)
        m = be.unpad_users(mask).astype(jnp.float32)
        metrics = Metrics(
            reward=jnp.sum(realized * m),
            regret=jnp.sum((jnp.max(p_all, axis=-1) - p_choice) * m),
            rand_reward=jnp.sum(jnp.mean(p_all, axis=-1) * m),
            interactions=jnp.sum(m.astype(jnp.int32)),
        )
        return (Minv, b, occ), metrics

    steps = jnp.arange(hyper.max_rounds)
    keys = jax.random.split(key, hyper.max_rounds)
    (Minv, b, occ), metrics = jax.lax.scan(
        step, (Minv0, b0, occ0), (steps, keys)
    )
    # fold per-step metric sums into one per-round Metrics row
    metrics = jax.tree.map(lambda v: jnp.sum(v, axis=0), metrics)
    return (be.unpad_gram(Minv), be.unpad_vec(b), be.unpad_users(occ),
            metrics)


def build_epoch_fn(mesh: Mesh, axes: tuple[str, ...], n: int, d: int,
                   hyper: BanditHyper,
                   backend: InteractBackend | None = None,
                   graph: GraphBackend | None = None):
    """Returns jit-able epoch(state, key) -> (state, metrics, n_clusters)."""
    n_shards = 1
    for a in axes:
        n_shards *= mesh.shape[a]
    if n % n_shards:
        raise ValueError(f"n_users={n} must divide the {n_shards}-way mesh")
    n_local = n // n_shards
    # the engines operate on the LOCAL shard inside shard_map (the graph
    # engine on [n_local, n] packed rows)
    be = backend or get_backend(n_local, d, hyper.n_candidates)
    gb = graph or get_graph_backend(n_local, n, kind=be.kind,
                                    interpret=be.interpret)

    def epoch(state: ShardedDistCLUB, key: jax.Array):
        idx = jax.lax.axis_index(axes)
        key = jax.random.fold_in(key, idx)
        k1, k3 = jax.random.split(key)
        row0 = idx * n_local
        local_ids = row0 + jnp.arange(n_local, dtype=jnp.int32)

        # ---- stage 1: personalized rounds (local only) --------------------
        def score_own(Minv, b, occ):
            return linucb.user_vector(Minv, b), Minv

        Minv, b, occ, m1 = _local_round(
            state.Minv, state.b, state.occ, state.theta,
            state.u_rounds, k1, hyper, score_own, be,
        )

        # ---- stage 2: the communication stage ------------------------------
        v_local = linucb.user_vector(Minv, b)                     # [n_loc, d]
        v_all = jax.lax.all_gather(v_local, axes, tiled=True)     # [n, d]
        occ_all = jax.lax.all_gather(occ, axes, tiled=True)       # [n]

        # prune the shard's packed adjacency rows: the graph engine tiles
        # the [n_local, n] distance slab through VMEM and ANDs the CLUB
        # keep-mask into the bits — no dense distance matrix, no bool graph.
        adj = gb.prune_rows(state.adj, v_local, occ, v_all, occ_all,
                            hyper.gamma)

        # connected components: min-label propagation with gathered labels
        init = jnp.arange(n, dtype=jnp.int32)

        def cc_cond(carry):
            _, changed, it = carry
            return changed & (it < n)

        def cc_body(carry):
            labels, _, it = carry
            # fused neighbour-min over the packed rows (n_local*n/8 bytes)
            new_local = gb.cc_hop(adj, labels[row0 + jnp.arange(n_local)],
                                  labels)
            new = jax.lax.all_gather(new_local, axes, tiled=True)
            # pointer-doubling on the replicated labels (free of comms):
            # chase label->label links so convergence needs O(log n) hops
            # instead of O(diameter).
            new = jnp.minimum(new, new[new])
            changed = jnp.any(new != labels)
            return new, changed, it + 1

        labels, _, _ = jax.lax.while_loop(
            cc_cond, cc_body, (init, jnp.array(True), 0)
        )

        # cluster stats: local segment_sum -> psum (the treeReduce).
        # M is recovered from Minv once per epoch (batched d x d inverse)
        # instead of being carried through every round, and the replicated
        # [n,d,d] tables are TRANSIENT — only per-user sharded snapshots
        # survive the stage.
        eye = jnp.eye(d, dtype=jnp.float32)
        M = jnp.linalg.inv(Minv)
        local_labels = labels[row0 + jnp.arange(n_local)]
        Mc = jax.ops.segment_sum(M - eye, local_labels, num_segments=n)
        bc = jax.ops.segment_sum(b, local_labels, num_segments=n)
        csize = jax.ops.segment_sum(jnp.ones_like(local_labels), local_labels,
                                    num_segments=n)
        cseen = jax.ops.segment_sum(occ, local_labels, num_segments=n)
        Mc = jax.lax.psum(Mc, axes) + eye
        bc = jax.lax.psum(bc, axes)
        csize = jax.lax.psum(csize, axes)
        cseen = jax.lax.psum(cseen, axes)
        lab_local = labels[local_ids]
        uMcinv = jnp.linalg.inv(Mc[lab_local])           # [n_loc, d, d]
        ubc = bc[lab_local]
        umean_occ = (cseen[lab_local].astype(jnp.float32)
                     / jnp.maximum(csize[lab_local], 1))
        n_clusters = jnp.sum(labels == init)

        # ---- stage 3: cluster-based rounds (local only; stats frozen) ------
        # cluster snapshots are frozen for the whole stage: pad them and
        # compute the cluster user-vector once, outside the scan.
        uMcinv_p = be.pad_gram(uMcinv)
        ubc_p = be.pad_vec(ubc)
        v_clu = linucb.user_vector(uMcinv_p, ubc_p)
        umean_p = be.pad_users(umean_occ)

        def score_cluster(Minv_, b_, occ_):
            use_own = occ_.astype(jnp.float32) >= hyper.beta * umean_p
            v_own = linucb.user_vector(Minv_, b_)
            w = jnp.where(use_own[:, None], v_own, v_clu)
            minv_eff = jnp.where(use_own[:, None, None], Minv_, uMcinv_p)
            return w, minv_eff

        Minv, b, occ, m3 = _local_round(
            Minv, b, occ, state.theta, state.c_rounds, k3, hyper,
            score_cluster, be,
        )

        # ---- stage 4: budget rebalancing (local) ----------------------------
        lab = labels[local_ids]
        mean_occ = cseen[lab].astype(jnp.float32) / jnp.maximum(csize[lab], 1)
        delta = ((occ.astype(jnp.float32) - mean_occ) / 2.0).astype(jnp.int32)
        u_rounds = jnp.clip(state.u_rounds + delta, 0, hyper.max_rounds)
        c_rounds = jnp.clip(state.c_rounds - delta, 0, hyper.max_rounds)

        metrics = jax.tree.map(lambda a_, b_: a_ + b_, m1, m3)
        metrics = jax.tree.map(lambda v: jax.lax.psum(v, axes), metrics)

        new_state = ShardedDistCLUB(
            Minv=Minv, b=b, occ=occ, adj=adj, labels=labels,
            uMcinv=uMcinv, ubc=ubc, umean_occ=umean_occ,
            u_rounds=u_rounds, c_rounds=c_rounds, theta=state.theta,
        )
        return new_state, metrics, n_clusters

    specs = state_specs(axes)
    sharded = shard_map(
        epoch, mesh=mesh,
        in_specs=(specs, P()),
        out_specs=(specs, Metrics(P(), P(), P(), P()), P()),
        check_rep=False,
    )
    return sharded


def make_runtime(mesh: Mesh, axes: tuple[str, ...], n: int, d: int,
                 hyper: BanditHyper,
                 backend: InteractBackend | None = None,
                 graph: GraphBackend | None = None):
    """(init_fn, jit'd epoch_fn) pair with global-array in/out shardings."""
    epoch = build_epoch_fn(mesh, axes, n, d, hyper, backend, graph)
    specs = state_specs(axes)
    shardings = jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                             is_leaf=lambda x: isinstance(x, P))

    def init_fn(key):
        theta = jax.random.normal(key, (n, d))
        theta = theta / jnp.linalg.norm(theta, axis=-1, keepdims=True)
        state = init_state(n, d, hyper, theta)
        return jax.device_put(state, shardings)

    epoch_jit = jax.jit(
        epoch,
        in_shardings=(shardings, NamedSharding(mesh, P())),
        out_shardings=(
            shardings,
            jax.tree.map(lambda _: NamedSharding(mesh, P()),
                         Metrics(0, 0, 0, 0)),
            NamedSharding(mesh, P()),
        ),
        donate_argnums=(0,),
    )
    return init_fn, epoch_jit
