"""Distributed DistCLUB: the shared stage engine under ``shard_map``.

This module contains NO stage logic — the four stage bodies live once in
``repro.runtime.stages`` and are bound here to ``LaxCollectives`` over the
mesh axes (the single-host driver binds the same functions to
``NullCollectives``).  What remains here is pure plumbing: the sharded
state record, its partition specs, and the jit/donation wiring.

Layout (users = the distribution axis, sharded over every mesh axis
flattened — the bandit equivalent of pure data parallelism):

  Minv, b, occ, budgets      : sharded on dim 0   -> [n_local, ...]
  adj (bit-packed uint32)    : sharded rows       -> [n_local, ceil(n/32)]
  labels                     : replicated [n]     (refreshed by all_gather)
  comm_bytes                 : replicated scalar  (modeled stage-2 bytes)

Stage 1/3 are purely local (zero communication — the paper's
"embarrassingly parallel" claim is literal here).  Stage 2 is the only
communicating stage and its traffic is exactly the paper's model: one
all-gather of the n x d user vectors + occ for edge pruning, label hops
during connected components, and one psum of the (n,d,d)+(n,d) aggregates.
The adjacency never crosses the network — each shard prunes and hops its
own packed rows through the graph engine.

Environments: ANY ``EnvOps`` (synthetic / drift / logged replay) runs
here — environment tables are closed over (replicated per device; small
next to the sharded state) and sliced per shard via ``row0``, and every
random draw is keyed by GLOBAL user id, so a sharded run reproduces the
single-host run up to fp contraction order.  The env no longer lives in
the carried state (the old runtime hard-coded the synthetic generator and
carried ``theta``); the per-user cluster snapshots are likewise no longer
carried — they are epoch transients of stage 2.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..core.backend import BackendConfig, GraphBackend, InteractBackend
from ..core.env_ops import EnvOps, default_synthetic_ops
from ..core.types import BanditHyper, Metrics
from ..kernels.graph import ops as graph_ops
from ..runtime import stages
from ..runtime.collectives import lax_collectives


class ShardedDistCLUB(NamedTuple):
    """State as seen *outside* shard_map (global shapes).

    §Perf iteration (bandit cell): the Gram matrix M is NOT carried — only
    its inverse is needed per interaction (UCB + Sherman-Morrison), and
    stage-2's cluster aggregation recovers M = inv(Minv) locally once per
    epoch.  §Perf iteration 2: the label-indexed cluster tables are
    stage-2 transients, not carried state.  §Unification: the environment
    (previously a carried ``theta`` + inlined synthetic sampling) moved
    into the shard-aware ``EnvOps`` closure, and the per-user cluster
    snapshots became stage-2 transients too — the carried state is now
    exactly the single-host ``DistCLUBState`` minus the recoverable
    Gram/cluster tables."""

    Minv: jnp.ndarray     # [n, d, d]   sharded dim0
    b: jnp.ndarray        # [n, d]      sharded dim0
    occ: jnp.ndarray      # [n]         sharded dim0
    adj: jnp.ndarray      # [n, ceil(n/32)] uint32 bit-packed, sharded rows
    labels: jnp.ndarray   # [n]         replicated (n i32 — cheap)
    u_rounds: jnp.ndarray  # [n] i32    sharded dim0
    c_rounds: jnp.ndarray  # [n] i32    sharded dim0
    comm_bytes: jnp.ndarray  # [] f32   replicated modeled-bytes counter


def named_shardings(mesh: Mesh, specs):
    """PartitionSpec pytree -> NamedSharding pytree over ``mesh``.  Shared
    by this runtime and the sharded serving sessions (``repro.serve``)."""
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def state_specs(axes: tuple[str, ...]) -> ShardedDistCLUB:
    s = P(axes)          # dim-0 sharded
    r = P()              # replicated
    return ShardedDistCLUB(
        Minv=s, b=s, occ=s, adj=s, labels=r,
        u_rounds=s, c_rounds=s, comm_bytes=r,
    )


def init_state(n: int, d: int, hyper: BanditHyper) -> ShardedDistCLUB:
    eye = jnp.eye(d, dtype=jnp.float32) + jnp.zeros((n, d, d), jnp.float32)
    return ShardedDistCLUB(
        Minv=eye,
        b=jnp.zeros((n, d), jnp.float32),
        occ=jnp.zeros((n,), jnp.int32),
        adj=graph_ops.init_packed_adj(n, n),
        labels=jnp.zeros((n,), jnp.int32),
        u_rounds=jnp.full((n,), hyper.sigma, jnp.int32),
        c_rounds=jnp.full((n,), hyper.sigma, jnp.int32),
        comm_bytes=jnp.zeros((), jnp.float32),
    )


def build_epoch_fn(mesh: Mesh, axes: tuple[str, ...], n: int, d: int,
                   hyper: BanditHyper,
                   backend: InteractBackend | None = None,
                   graph: GraphBackend | None = None,
                   ops: EnvOps | None = None):
    """Returns jit-able epoch(state, key) -> (state, metrics, n_clusters).

    ``metrics`` is per-scan-step ``[2 * max_rounds]`` rows (stage-1 steps
    then stage-3 steps, psum'd over shards) — the same layout one epoch of
    the single-host driver emits, so parity checks are slice-for-slice.
    ``ops`` defaults to a planted synthetic environment
    (``env_ops.default_synthetic_ops``); pass replay/drift ops to run
    those scenarios sharded.
    """
    col = lax_collectives(mesh, axes)
    if n % col.n_shards:
        raise ValueError(f"n_users={n} must divide the {col.n_shards}-way mesh")
    n_local = n // col.n_shards
    # the engines operate on the LOCAL shard inside shard_map (the graph
    # engine on [n_local, n] packed rows)
    be = backend or BackendConfig.create().interact(n_local, d,
                                                    hyper.n_candidates)
    gb = graph or BackendConfig(
        kind=be.kind, precision=be.precision,
    ).graph(n_local, n, interpret=be.interpret)
    env = ops or default_synthetic_ops(n, d, hyper.n_candidates)

    def epoch(state: ShardedDistCLUB, key: jax.Array):
        k1, k3 = jax.random.split(key)
        row0 = col.axis_index() * n_local

        # ---- stage 1: personalized rounds (local only) --------------------
        Minv, b, occ, m1 = stages.personalized_rounds(
            be, env, hyper, state.Minv, state.b, state.occ,
            state.u_rounds, k1, row0,
        )

        # ---- stage 2: the communication stage -----------------------------
        res = stages.stage2_refresh(col, gb, hyper, d, Minv, b, occ,
                                    state.adj)

        # ---- stage 3: cluster-based rounds (local; stats frozen) ----------
        Minv, b, occ, m3 = stages.cluster_rounds(
            be, env, hyper, Minv, b, occ, state.c_rounds, k3, row0,
            res.uMcinv, res.ubc, res.umean_occ,
        )

        # ---- stage 4: budget rebalancing (local; stage-2 snapshot) --------
        u_rounds, c_rounds = stages.stage4_rebalance(
            hyper, occ, res.umean_occ, state.u_rounds, state.c_rounds)

        metrics = jax.tree.map(lambda a, b_: jnp.concatenate([a, b_]),
                               m1, m3)
        metrics = jax.tree.map(lambda v: col.psum(v), metrics)

        new_state = ShardedDistCLUB(
            Minv=Minv, b=b, occ=occ, adj=res.adj, labels=res.labels,
            u_rounds=u_rounds, c_rounds=c_rounds,
            comm_bytes=state.comm_bytes + res.comm_bytes,
        )
        return new_state, metrics, res.n_clusters

    specs = state_specs(axes)
    sharded = shard_map(
        epoch, mesh=mesh,
        in_specs=(specs, P()),
        out_specs=(specs, Metrics(P(), P(), P(), P()), P()),
        check_rep=False,
    )
    return sharded


def make_runtime(mesh: Mesh, axes: tuple[str, ...], n: int, d: int,
                 hyper: BanditHyper,
                 backend: InteractBackend | None = None,
                 graph: GraphBackend | None = None,
                 ops: EnvOps | None = None):
    """(init_fn, jit'd epoch_fn) pair with global-array in/out shardings.

    ``init_fn(key)`` ignores its key (kept for API stability): the initial
    bandit state is deterministic and the environment's randomness lives
    in ``ops``.
    """
    epoch = build_epoch_fn(mesh, axes, n, d, hyper, backend, graph, ops)
    shardings = named_shardings(mesh, state_specs(axes))

    def init_fn(key):
        del key
        return jax.device_put(init_state(n, d, hyper), shardings)

    epoch_jit = jax.jit(
        epoch,
        in_shardings=(shardings, NamedSharding(mesh, P())),
        out_shardings=(
            shardings,
            jax.tree.map(lambda _: NamedSharding(mesh, P()),
                         Metrics(0, 0, 0, 0)),
            NamedSharding(mesh, P()),
        ),
        donate_argnums=(0,),
    )
    return init_fn, epoch_jit
