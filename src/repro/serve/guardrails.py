"""Streaming serving guardrails with checkpoint auto-rollback.

A production bandit can be poisoned silently: corrupted rewards bend the
LinUCB statistics, a stalled shard serves a stale shortlist, a wedged
feedback pipeline fills the pending ring — and (as CLUB's authors warn)
bad statistics propagate through the cluster graph at the next stage-2.
The guardrail layer watches cheap streaming signals, declares a breach
when one crosses its configured bound, and ROLLS BACK to the last
healthy :class:`~repro.train.checkpoint.CheckpointManager` snapshot —
after which the session resumes bit-identical pre-breach behaviour
(choices are a pure function of policy state + inputs).

Monitors (all EMA-smoothed, host-side Python floats):

  ctr          realized reward per interaction — floor `ctr_floor`,
               armed after `warmup` interactions
  recall       shortlist recall vs the direct-slate oracle
               (:func:`shortlist_recall`; healthy two-stage serving
               saturates at 1.0, so a drop means a stale/stalled shard
               or corrupted retrieval state) — floor `recall_floor`
  occupancy    pending-ring in-flight fraction — ceiling
               `occupancy_ceiling` (a wedged feedback path fills the
               ring; decisions start expiring/evicting)
  latency      per-transaction wall-clock seconds — ceiling
               `latency_ceiling_s`
  churn        fraction of catalog capacity changed per publish —
               ceiling `churn_ceiling` (a runaway ingest pipeline or a
               bad mass retirement swaps out the catalog faster than
               in-flight decisions can tolerate); unlike the others the
               BREACH tests the raw per-publish sample — a single
               oversized swap is the hazard, so it must not hide under
               EMA smoothing — while ``ema_churn`` stays as telemetry

State machine:  HEALTHY --breach--> ROLLBACK (restore latest snapshot,
pending ring cleared with the id counter kept monotone, monitors reset)
--cooldown txs--> HEALTHY.  While healthy, a snapshot is taken every
`snapshot_every` transactions; the snapshot cadence bounds how much
healthy progress a rollback can lose — and, like any monitored system,
how much *undetected* corruption can leak into a snapshot before the
EMA crosses its floor (tune `ema`/`snapshot_every` jointly).

Epoch-consistent rollback: a wrapper created with ``catalog=`` TRACKS
the serving catalog — every snapshot captures the (state, catalog,
epoch) triple (the epoch lives inside the catalog) and a rollback
restores all of it, so the restored statistics never resume against a
catalog they have not seen.  Catalog churn flows through the wrapper's
``stage_churn``/``publish``, which also feed the churn monitor.

Everything is functional: :class:`Guarded` methods return a new wrapper;
`events` is an append-only tuple of ``("snapshot", tx, step)`` /
``("rollback", tx, breaches, restored_step)`` records.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Any, NamedTuple

import jax.numpy as jnp

from . import session as session_mod


class GuardrailConfig(NamedTuple):
    """Bounds + smoothing for the streaming monitors.  The defaults
    disarm every monitor (infinite bounds) — set only what you watch."""

    ctr_floor: float = -math.inf
    recall_floor: float = -math.inf
    occupancy_ceiling: float = math.inf
    latency_ceiling_s: float = math.inf
    churn_ceiling: float = math.inf   # capacity fraction per publish
    warmup: int = 64            # interactions before ctr/recall arm
    ema: float = 0.9            # per-sample EMA decay
    snapshot_every: int = 4     # healthy transactions between snapshots
    cooldown: int = 2           # transactions disarmed after a rollback


@dataclasses.dataclass(frozen=True)
class GuardrailState:
    """EMA values + arming counters.  ``breaches`` names the monitors
    that crossed their bound on the LAST admitted sample."""

    ema_ctr: float | None = None
    ema_recall: float | None = None
    ema_occupancy: float | None = None
    ema_latency_s: float | None = None
    ema_churn: float | None = None
    ema_tiles_skipped: float | None = None
    interactions: int = 0
    cooldown_left: int = 0
    breaches: tuple = ()
    rollbacks: int = 0


def _ema(old: float | None, new: float, decay: float) -> float:
    return float(new) if old is None else decay * old + (1 - decay) * new


def update(cfg: GuardrailConfig, gs: GuardrailState, *,
           ctr: float | None = None, recall: float | None = None,
           occupancy: float | None = None,
           latency_s: float | None = None,
           churn: float | None = None,
           tiles_skipped: float | None = None,
           interactions: int = 0) -> GuardrailState:
    """Fold one transaction's samples and re-evaluate every monitor.
    Rate monitors (ctr/recall) arm after ``warmup`` interactions;
    resource monitors (occupancy/latency/churn) arm immediately;
    everything is disarmed during a rollback cooldown.
    ``tiles_skipped`` (pruned-retrieval skip ratio) is TELEMETRY only —
    pruning is exact, so a low ratio costs latency, never correctness;
    the latency ceiling is the monitor that bites when it collapses."""
    ema_ctr = gs.ema_ctr if ctr is None else _ema(gs.ema_ctr, ctr, cfg.ema)
    ema_recall = (gs.ema_recall if recall is None
                  else _ema(gs.ema_recall, recall, cfg.ema))
    ema_occ = (gs.ema_occupancy if occupancy is None
               else _ema(gs.ema_occupancy, occupancy, cfg.ema))
    ema_lat = (gs.ema_latency_s if latency_s is None
               else _ema(gs.ema_latency_s, latency_s, cfg.ema))
    ema_churn = (gs.ema_churn if churn is None
                 else _ema(gs.ema_churn, churn, cfg.ema))
    ema_tiles = (gs.ema_tiles_skipped if tiles_skipped is None
                 else _ema(gs.ema_tiles_skipped, tiles_skipped, cfg.ema))
    seen = gs.interactions + int(interactions)
    cooldown_left = max(0, gs.cooldown_left - 1)

    breaches = []
    if cooldown_left == 0:
        if seen >= cfg.warmup:
            if ema_ctr is not None and ema_ctr < cfg.ctr_floor:
                breaches.append("ctr_floor")
            if ema_recall is not None and ema_recall < cfg.recall_floor:
                breaches.append("recall_floor")
        if ema_occ is not None and ema_occ > cfg.occupancy_ceiling:
            breaches.append("occupancy_ceiling")
        if ema_lat is not None and ema_lat > cfg.latency_ceiling_s:
            breaches.append("latency_ceiling")
        # churn breaches on the RAW per-publish sample: one oversized
        # swap is the hazard, and an EMA would smooth it under the bar
        if churn is not None and churn > cfg.churn_ceiling:
            breaches.append("churn_ceiling")
    return dataclasses.replace(
        gs, ema_ctr=ema_ctr, ema_recall=ema_recall, ema_occupancy=ema_occ,
        ema_latency_s=ema_lat, ema_churn=ema_churn,
        ema_tiles_skipped=ema_tiles, interactions=seen,
        cooldown_left=cooldown_left, breaches=tuple(breaches))


def post_rollback_state(cfg: GuardrailConfig,
                        gs: GuardrailState) -> GuardrailState:
    """The monitor state after a breach-triggered rollback: EMAs reset
    (the rolled-back session's telemetry is void), lifetime interaction
    and rollback counters carried forward, cooldown armed so the fresh
    EMAs can re-warm before they can trip again.  Shared by the
    ``Guarded`` wrapper and per-arm disabling in ``serve.experiments``."""
    return dataclasses.replace(
        GuardrailState(), interactions=gs.interactions,
        cooldown_left=cfg.cooldown, rollbacks=gs.rollbacks + 1)


def shortlist_recall(session, catalog, user_ids, served_items, *,
                     k_short: int = 64) -> float:
    """Fraction of valid users whose SERVED item sits in a freshly
    computed direct oracle shortlist over the full catalog.

    ``session`` must be the state the choice was made FROM (the
    pre-transaction session — folding the feedback first moves the UCB
    scores and the probe stops being an invariant).  Healthy two-stage
    serving is exact, so this saturates at 1.0: any drop means the
    serving path diverged from its own statistics (stale shortlist from
    a stalled shard, corrupted retrieval state, catalog skew between
    replicas).  Subsumes the old ``k_short`` recall-telemetry item.
    Eager host call — run it on probe batches, not the hot path.
    """
    policy = session.policy
    cfg = policy.cfg
    rb = session_mod._retrieval_engine(session, k_short)
    valid = (user_ids >= 0) & (user_ids < cfg.n_users)
    idx = jnp.clip(user_ids, 0, cfg.n_users - 1)
    w, minv_eff, occ = policy.gather_score(session.state, idx)
    bank = catalog.serving
    _, oracle_ids = rb.shortlist(w, minv_eff, occ, bank.emb,
                                 bank.live, cfg.hyper.alpha)
    hit = jnp.any(oracle_ids == served_items[:, None], axis=1)
    n_valid = jnp.maximum(jnp.sum(valid.astype(jnp.int32)), 1)
    return float(jnp.sum((hit & valid).astype(jnp.float32)) / n_valid)


# ---------------------------------------------------------------------------
# the guarded session wrapper
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Guarded:
    """An `OnlineBandit` plus its monitors and rollback anchor.

    Every serving call admits its samples; a breach restores the latest
    snapshot from ``ckpt`` (and clears the pending ring) before the next
    call runs.  Immutable like the session it wraps.

    ``catalog`` (optional) makes the wrapper the catalog's owner for
    EPOCH-CONSISTENT rollback: snapshots save the (state, catalog)
    pair — the epoch travels inside the catalog — and a breach restores
    both, so the rolled-back statistics resume against exactly the
    catalog they were trained on.  Churn goes through ``stage_churn`` /
    ``publish`` (which feeds the churn monitor); the catalog-serving
    calls then default to the tracked catalog."""

    session: Any
    ckpt: Any
    cfg: GuardrailConfig
    gs: GuardrailState = GuardrailState()
    tx: int = 0
    last_snapshot: int = 0
    events: tuple = ()
    catalog: Any = None

    @classmethod
    def create(cls, session, ckpt, cfg: GuardrailConfig,
               catalog=None) -> "Guarded":
        """Wrap ``session``, anchoring snapshot 0 immediately so a
        rollback target always exists.  Pass ``catalog`` to snapshot the
        (state, catalog, epoch) triple and roll it back as one unit."""
        g = cls(session=session, ckpt=ckpt, cfg=cfg, catalog=catalog)
        g._save_snapshot(session, catalog, 0)
        return dataclasses.replace(g, events=(("snapshot", 0, 0),))

    # -- (state, catalog) snapshot plumbing --------------------------------
    def _save_snapshot(self, session, catalog, step):
        if catalog is None:
            session.save(self.ckpt, step)
        else:
            self.ckpt.save({"state": session.state, "catalog": catalog},
                           step)

    def _snapshot_shardings(self, session, catalog):
        if session.mesh is None:
            return None
        from ..core import catalog as catalog_mod
        from ..distributed.distclub_shard import named_shardings
        return {"state": session._shardings(),
                "catalog": named_shardings(session.mesh,
                                           catalog_mod.specs(session.axes))}

    def _rollback(self, session, catalog):
        """(session, catalog, step) restored from the latest loadable
        snapshot — state-only, or the epoch-consistent pair."""
        if catalog is None:
            restored, step = session.restore(self.ckpt)
            return restored, None, step
        like = {"state": session.state, "catalog": catalog}
        payload, step = self.ckpt.restore_latest(
            like, self._snapshot_shardings(session, catalog))
        if payload is None:     # empty directory: keep what we have
            return session, catalog, None
        return (dataclasses.replace(session, state=payload["state"]),
                payload["catalog"], step)

    # -- admission ---------------------------------------------------------
    def _admit(self, session, **sample) -> "Guarded":
        gs = update(self.cfg, self.gs, **sample)
        tx = self.tx + 1
        if gs.breaches:
            restored, cat, step = self._rollback(session, self.catalog)
            restored = session_mod.reset_pending(restored)
            fresh = post_rollback_state(self.cfg, gs)
            return dataclasses.replace(
                self, session=restored, catalog=cat, gs=fresh, tx=tx,
                events=self.events
                + (("rollback", tx, gs.breaches, step),))
        g = dataclasses.replace(self, session=session, gs=gs, tx=tx)
        # never snapshot during cooldown — a just-rolled-back session may
        # have re-folded bad samples before the fresh EMA can trip again
        if (gs.cooldown_left == 0
                and tx - g.last_snapshot >= self.cfg.snapshot_every):
            self._save_snapshot(session, g.catalog, tx)
            g = dataclasses.replace(
                g, last_snapshot=tx,
                events=g.events + (("snapshot", tx, tx),))
        return g

    @property
    def tripped(self) -> bool:
        return bool(self.gs.breaches)

    # -- guarded transactions ----------------------------------------------
    def step(self, key, user_ids, contexts, reward_fn):
        t0 = time.perf_counter()
        sess, choices, m = session_mod.step(self.session, key, user_ids,
                                            contexts, reward_fn)
        dt = time.perf_counter() - t0
        n = max(1, int(m.interactions))
        g = self._admit(sess, ctr=float(m.reward) / n, latency_s=dt,
                        occupancy=_occupancy(sess),
                        interactions=int(m.interactions))
        return g, choices, m

    def _catalog_or_tracked(self, catalog):
        cat = catalog if catalog is not None else self.catalog
        if cat is None:
            raise ValueError("no catalog: pass one explicitly or create "
                             "the Guarded wrapper with catalog=")
        return cat

    def step_catalog(self, key, user_ids, catalog=None, reward_fn=None, *,
                     k_short: int = 64, probe_recall: bool = False,
                     clusters=None):
        """``clusters`` routes the transaction through cluster-pruned
        retrieval (`serve.step_catalog`); the skip ratio feeds the
        ``ema_tiles_skipped`` telemetry and the return gains the
        ``RetrievalMetrics``.  ``probe_recall`` keeps comparing the
        SERVED items against the fresh UNPRUNED oracle shortlist — on the
        pruned path that is precisely the exactness invariant, so the
        recall-floor monitor guards the pruning machinery itself."""
        cat = self._catalog_or_tracked(catalog)
        t0 = time.perf_counter()
        if clusters is None:
            sess, items, m = session_mod.step_catalog(
                self.session, key, user_ids, cat, reward_fn,
                k_short=k_short)
            rmet = None
        else:
            sess, items, m, rmet = session_mod.step_catalog(
                self.session, key, user_ids, cat, reward_fn,
                k_short=k_short, clusters=clusters)
        dt = time.perf_counter() - t0
        n = max(1, int(m.interactions))
        # probe against the PRE-transaction state — the invariant is
        # "served item in the shortlist of the state it was chosen from"
        recall = (shortlist_recall(self.session, cat, user_ids, items,
                                   k_short=k_short)
                  if probe_recall else None)
        g = self if self.catalog is None else dataclasses.replace(
            self, catalog=cat)
        g = g._admit(sess, ctr=float(m.reward) / n, latency_s=dt,
                     occupancy=_occupancy(sess), recall=recall,
                     tiles_skipped=(None if rmet is None
                                    else rmet.skip_ratio()),
                     interactions=int(m.interactions))
        if clusters is None:
            return g, items, m
        return g, items, m, rmet

    def recommend(self, user_ids, contexts):
        """Issue on a buffer-enabled session (monitors latency and ring
        occupancy; CTR arrives with the delayed feedback)."""
        t0 = time.perf_counter()
        sess, choices, ids = session_mod.recommend(self.session, user_ids,
                                                   contexts)
        dt = time.perf_counter() - t0
        g = self._admit(sess, latency_s=dt, occupancy=_occupancy(sess))
        return g, choices, ids

    def recommend_catalog(self, user_ids, catalog=None, *,
                          k_short: int = 64, clusters=None):
        """Issue against the (tracked) catalog on a buffer-enabled
        session: returns ``(guarded, item_ids, decision_ids, slots,
        ctx)`` — plus a trailing ``RetrievalMetrics`` when ``clusters``
        routes it through pruned retrieval."""
        cat = self._catalog_or_tracked(catalog)
        t0 = time.perf_counter()
        if clusters is None:
            sess, items, ids, slots, ctx = session_mod.recommend_catalog(
                self.session, user_ids, cat, k_short=k_short)
            rmet = None
        else:
            (sess, items, ids, slots, ctx,
             rmet) = session_mod.recommend_catalog(
                self.session, user_ids, cat, k_short=k_short,
                clusters=clusters)
        dt = time.perf_counter() - t0
        g = self if self.catalog is None else dataclasses.replace(
            self, catalog=cat)
        g = g._admit(sess, latency_s=dt, occupancy=_occupancy(sess),
                     tiles_skipped=(None if rmet is None
                                    else rmet.skip_ratio()))
        if clusters is None:
            return g, items, ids, slots, ctx
        return g, items, ids, slots, ctx, rmet

    def observe_delayed(self, decision_ids, rewards, key=None):
        """Delayed-feedback fold; with a tracked catalog the fold
        quarantines churned-item feedback against the CURRENT epoch."""
        sess = session_mod.observe_delayed(self.session, decision_ids,
                                           rewards, key=key,
                                           catalog=self.catalog)
        delivered = jnp.sum((decision_ids >= 0).astype(jnp.int32))
        n = max(1, int(delivered))
        ctr = float(jnp.sum(jnp.where(decision_ids >= 0, rewards, 0.0))) / n
        g = self._admit(sess, ctr=ctr, occupancy=_occupancy(sess),
                        interactions=int(delivered))
        return g

    def observe_recall(self, recall: float) -> "Guarded":
        """Feed an externally computed recall probe (e.g. a shadow
        replica comparing served items against its own oracle)."""
        return self._admit(self.session, recall=recall)

    # -- guarded catalog churn ---------------------------------------------
    def stage_churn(self, *, add=None, retire=None):
        """Stage churn into the tracked catalog's shadow bank — serving
        is untouched until :meth:`publish`.  ``retire`` [m] item ids,
        ``add`` [m, d] embeddings.  Returns ``(guarded, slot_ids)``
        (``slot_ids`` is None without ``add``)."""
        from ..core import catalog as catalog_mod
        cat = self._catalog_or_tracked(None)
        slots = None
        if retire is not None:
            cat, _ = catalog_mod.retire_items(cat, retire)
        if add is not None:
            cat, slots, _ = catalog_mod.add_items(cat, add)
        return dataclasses.replace(self, catalog=cat), slots

    def publish(self, keep_mask=None) -> "Guarded":
        """Atomically publish the staged catalog epoch and admit the
        churn-rate sample (fraction of capacity changed) — a
        ``churn_ceiling`` breach rolls BOTH state and catalog back to
        the last snapshot.  ``keep_mask`` is fault injection only: a
        torn publish via ``core.catalog.torn_publish``."""
        from ..core import catalog as catalog_mod
        cat = self._catalog_or_tracked(None)
        churn = float(catalog_mod.staged_churn(cat)) / cat.capacity
        if keep_mask is None:
            cat = catalog_mod.publish(cat)
        else:
            cat = catalog_mod.torn_publish(cat, keep_mask)
        g = dataclasses.replace(self, catalog=cat)
        return g._admit(g.session, churn=churn)


def _occupancy(session) -> float | None:
    if session.pending is None:
        return None
    return float(jnp.mean((session.pending.uid >= 0).astype(jnp.float32)))
