"""Streaming serving guardrails with checkpoint auto-rollback.

A production bandit can be poisoned silently: corrupted rewards bend the
LinUCB statistics, a stalled shard serves a stale shortlist, a wedged
feedback pipeline fills the pending ring — and (as CLUB's authors warn)
bad statistics propagate through the cluster graph at the next stage-2.
The guardrail layer watches cheap streaming signals, declares a breach
when one crosses its configured bound, and ROLLS BACK to the last
healthy :class:`~repro.train.checkpoint.CheckpointManager` snapshot —
after which the session resumes bit-identical pre-breach behaviour
(choices are a pure function of policy state + inputs).

Monitors (all EMA-smoothed, host-side Python floats):

  ctr          realized reward per interaction — floor `ctr_floor`,
               armed after `warmup` interactions
  recall       shortlist recall vs the direct-slate oracle
               (:func:`shortlist_recall`; healthy two-stage serving
               saturates at 1.0, so a drop means a stale/stalled shard
               or corrupted retrieval state) — floor `recall_floor`
  occupancy    pending-ring in-flight fraction — ceiling
               `occupancy_ceiling` (a wedged feedback path fills the
               ring; decisions start expiring/evicting)
  latency      per-transaction wall-clock seconds — ceiling
               `latency_ceiling_s`

State machine:  HEALTHY --breach--> ROLLBACK (restore latest snapshot,
pending ring cleared with the id counter kept monotone, monitors reset)
--cooldown txs--> HEALTHY.  While healthy, a snapshot is taken every
`snapshot_every` transactions; the snapshot cadence bounds how much
healthy progress a rollback can lose — and, like any monitored system,
how much *undetected* corruption can leak into a snapshot before the
EMA crosses its floor (tune `ema`/`snapshot_every` jointly).

Everything is functional: :class:`Guarded` methods return a new wrapper;
`events` is an append-only tuple of ``("snapshot", tx, step)`` /
``("rollback", tx, breaches, restored_step)`` records.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Any, NamedTuple

import jax.numpy as jnp

from . import session as session_mod


class GuardrailConfig(NamedTuple):
    """Bounds + smoothing for the streaming monitors.  The defaults
    disarm every monitor (infinite bounds) — set only what you watch."""

    ctr_floor: float = -math.inf
    recall_floor: float = -math.inf
    occupancy_ceiling: float = math.inf
    latency_ceiling_s: float = math.inf
    warmup: int = 64            # interactions before ctr/recall arm
    ema: float = 0.9            # per-sample EMA decay
    snapshot_every: int = 4     # healthy transactions between snapshots
    cooldown: int = 2           # transactions disarmed after a rollback


@dataclasses.dataclass(frozen=True)
class GuardrailState:
    """EMA values + arming counters.  ``breaches`` names the monitors
    that crossed their bound on the LAST admitted sample."""

    ema_ctr: float | None = None
    ema_recall: float | None = None
    ema_occupancy: float | None = None
    ema_latency_s: float | None = None
    interactions: int = 0
    cooldown_left: int = 0
    breaches: tuple = ()
    rollbacks: int = 0


def _ema(old: float | None, new: float, decay: float) -> float:
    return float(new) if old is None else decay * old + (1 - decay) * new


def update(cfg: GuardrailConfig, gs: GuardrailState, *,
           ctr: float | None = None, recall: float | None = None,
           occupancy: float | None = None,
           latency_s: float | None = None,
           interactions: int = 0) -> GuardrailState:
    """Fold one transaction's samples and re-evaluate every monitor.
    Rate monitors (ctr/recall) arm after ``warmup`` interactions;
    resource monitors (occupancy/latency) arm immediately; everything is
    disarmed during a rollback cooldown."""
    ema_ctr = gs.ema_ctr if ctr is None else _ema(gs.ema_ctr, ctr, cfg.ema)
    ema_recall = (gs.ema_recall if recall is None
                  else _ema(gs.ema_recall, recall, cfg.ema))
    ema_occ = (gs.ema_occupancy if occupancy is None
               else _ema(gs.ema_occupancy, occupancy, cfg.ema))
    ema_lat = (gs.ema_latency_s if latency_s is None
               else _ema(gs.ema_latency_s, latency_s, cfg.ema))
    seen = gs.interactions + int(interactions)
    cooldown_left = max(0, gs.cooldown_left - 1)

    breaches = []
    if cooldown_left == 0:
        if seen >= cfg.warmup:
            if ema_ctr is not None and ema_ctr < cfg.ctr_floor:
                breaches.append("ctr_floor")
            if ema_recall is not None and ema_recall < cfg.recall_floor:
                breaches.append("recall_floor")
        if ema_occ is not None and ema_occ > cfg.occupancy_ceiling:
            breaches.append("occupancy_ceiling")
        if ema_lat is not None and ema_lat > cfg.latency_ceiling_s:
            breaches.append("latency_ceiling")
    return dataclasses.replace(
        gs, ema_ctr=ema_ctr, ema_recall=ema_recall, ema_occupancy=ema_occ,
        ema_latency_s=ema_lat, interactions=seen,
        cooldown_left=cooldown_left, breaches=tuple(breaches))


def shortlist_recall(session, catalog, user_ids, served_items, *,
                     k_short: int = 64) -> float:
    """Fraction of valid users whose SERVED item sits in a freshly
    computed direct oracle shortlist over the full catalog.

    ``session`` must be the state the choice was made FROM (the
    pre-transaction session — folding the feedback first moves the UCB
    scores and the probe stops being an invariant).  Healthy two-stage
    serving is exact, so this saturates at 1.0: any drop means the
    serving path diverged from its own statistics (stale shortlist from
    a stalled shard, corrupted retrieval state, catalog skew between
    replicas).  Subsumes the old ``k_short`` recall-telemetry item.
    Eager host call — run it on probe batches, not the hot path.
    """
    policy = session.policy
    cfg = policy.cfg
    rb = session_mod._retrieval_engine(session, k_short)
    valid = (user_ids >= 0) & (user_ids < cfg.n_users)
    idx = jnp.clip(user_ids, 0, cfg.n_users - 1)
    w, minv_eff, occ = policy.gather_score(session.state, idx)
    _, oracle_ids = rb.shortlist(w, minv_eff, occ, catalog.emb,
                                 catalog.live, cfg.hyper.alpha)
    hit = jnp.any(oracle_ids == served_items[:, None], axis=1)
    n_valid = jnp.maximum(jnp.sum(valid.astype(jnp.int32)), 1)
    return float(jnp.sum((hit & valid).astype(jnp.float32)) / n_valid)


# ---------------------------------------------------------------------------
# the guarded session wrapper
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Guarded:
    """An `OnlineBandit` plus its monitors and rollback anchor.

    Every serving call admits its samples; a breach restores the latest
    snapshot from ``ckpt`` (and clears the pending ring) before the next
    call runs.  Immutable like the session it wraps."""

    session: Any
    ckpt: Any
    cfg: GuardrailConfig
    gs: GuardrailState = GuardrailState()
    tx: int = 0
    last_snapshot: int = 0
    events: tuple = ()

    @classmethod
    def create(cls, session, ckpt, cfg: GuardrailConfig) -> "Guarded":
        """Wrap ``session``, anchoring snapshot 0 immediately so a
        rollback target always exists."""
        session.save(ckpt, 0)
        return cls(session=session, ckpt=ckpt, cfg=cfg,
                   events=(("snapshot", 0, 0),))

    # -- admission ---------------------------------------------------------
    def _admit(self, session, **sample) -> "Guarded":
        gs = update(self.cfg, self.gs, **sample)
        tx = self.tx + 1
        if gs.breaches:
            restored, step = session.restore(self.ckpt)
            restored = session_mod.reset_pending(restored)
            fresh = dataclasses.replace(
                GuardrailState(), interactions=gs.interactions,
                cooldown_left=self.cfg.cooldown,
                rollbacks=gs.rollbacks + 1)
            return dataclasses.replace(
                self, session=restored, gs=fresh, tx=tx,
                events=self.events
                + (("rollback", tx, gs.breaches, step),))
        g = dataclasses.replace(self, session=session, gs=gs, tx=tx)
        # never snapshot during cooldown — a just-rolled-back session may
        # have re-folded bad samples before the fresh EMA can trip again
        if (gs.cooldown_left == 0
                and tx - g.last_snapshot >= self.cfg.snapshot_every):
            session.save(self.ckpt, tx)
            g = dataclasses.replace(
                g, last_snapshot=tx,
                events=g.events + (("snapshot", tx, tx),))
        return g

    @property
    def tripped(self) -> bool:
        return bool(self.gs.breaches)

    # -- guarded transactions ----------------------------------------------
    def step(self, key, user_ids, contexts, reward_fn):
        t0 = time.perf_counter()
        sess, choices, m = session_mod.step(self.session, key, user_ids,
                                            contexts, reward_fn)
        dt = time.perf_counter() - t0
        n = max(1, int(m.interactions))
        g = self._admit(sess, ctr=float(m.reward) / n, latency_s=dt,
                        occupancy=_occupancy(sess),
                        interactions=int(m.interactions))
        return g, choices, m

    def step_catalog(self, key, user_ids, catalog, reward_fn, *,
                     k_short: int = 64, probe_recall: bool = False):
        t0 = time.perf_counter()
        sess, items, m = session_mod.step_catalog(
            self.session, key, user_ids, catalog, reward_fn,
            k_short=k_short)
        dt = time.perf_counter() - t0
        n = max(1, int(m.interactions))
        # probe against the PRE-transaction state — the invariant is
        # "served item in the shortlist of the state it was chosen from"
        recall = (shortlist_recall(self.session, catalog, user_ids, items,
                                   k_short=k_short)
                  if probe_recall else None)
        g = self._admit(sess, ctr=float(m.reward) / n, latency_s=dt,
                        occupancy=_occupancy(sess), recall=recall,
                        interactions=int(m.interactions))
        return g, items, m

    def recommend(self, user_ids, contexts):
        """Issue on a buffer-enabled session (monitors latency and ring
        occupancy; CTR arrives with the delayed feedback)."""
        t0 = time.perf_counter()
        sess, choices, ids = session_mod.recommend(self.session, user_ids,
                                                   contexts)
        dt = time.perf_counter() - t0
        g = self._admit(sess, latency_s=dt, occupancy=_occupancy(sess))
        return g, choices, ids

    def observe_delayed(self, decision_ids, rewards, key=None):
        sess = session_mod.observe_delayed(self.session, decision_ids,
                                           rewards, key=key)
        delivered = jnp.sum((decision_ids >= 0).astype(jnp.int32))
        n = max(1, int(delivered))
        ctr = float(jnp.sum(jnp.where(decision_ids >= 0, rewards, 0.0))) / n
        g = self._admit(sess, ctr=ctr, occupancy=_occupancy(sess),
                        interactions=int(delivered))
        return g

    def observe_recall(self, recall: float) -> "Guarded":
        """Feed an externally computed recall probe (e.g. a shadow
        replica comparing served items against its own oracle)."""
        return self._admit(self.session, recall=recall)


def _occupancy(session) -> float | None:
    if session.pending is None:
        return None
    return float(jnp.mean((session.pending.uid >= 0).astype(jnp.float32)))
