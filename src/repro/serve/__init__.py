"""First-class online serving: policy-pluggable `OnlineBandit` sessions
bound to the stage engine.

    from repro import serve

    session = serve.OnlineBandit.create(n_users, d, hyper,
                                        policy="distclub",
                                        refresh_every=n_users * 4)
    session, choices, metrics = serve.step(session, key, user_ids,
                                           contexts, reward_fn)

or, for real request/feedback splits:

    choices = serve.recommend(session, user_ids, contexts)
    ...  # show items, collect clicks
    session = serve.observe(session, user_ids, contexts, choices, rewards)

Policies: ``distclub`` | ``dccb`` | ``club`` | ``linucb`` — one protocol,
four bandits, head-to-head on the identical serving surface (see
``serve.policies``).  ``OnlineBandit.sharded(mesh, ...)`` runs the same
transaction over a device mesh; ``session.save``/``session.restore``
round-trip through ``train.checkpoint.CheckpointManager``.

Catalog-scale retrieval (README "Catalog-scale retrieval"): when the
item side outgrows caller-supplied slates, serve against a persistent
``Catalog`` — the streaming top-K engine shortlists each user's
``k_short`` highest-UCB items per item shard and the fused choose ranks
the shortlist, never materializing ``[B, N_items]`` scores::

    cat = serve.make_catalog(item_embeddings)        # or random_catalog
    session, item_ids, metrics = serve.step_catalog(
        session, key, user_ids, cat, reward_fn, k_short=64)
    item_ids, slots, ctx = serve.recommend_catalog(session, user_ids, cat)

Cluster-pruned retrieval (README "Cluster-pruned retrieval"): learn the
catalog's item-side cluster structure online and let the top-K stream
skip whole tiles — EXACTLY (served items bit-identical to unpruned)::

    clusters = serve.build_clusters(cat, stats)      # stage-2 cadence
    session, item_ids, metrics, rmet = serve.step_catalog(
        session, key, user_ids, cat, reward_fn, clusters=clusters)
    # rmet.skip_ratio() -> fraction of catalog tiles never streamed;
    # after serve.publish the table is stale -> automatic unpruned
    # fallback until serve.refresh_clusters rebuilds it

Fault-tolerant feedback (README "Fault tolerance & guardrails"): create
the session with ``pending_capacity > 0`` and the request half ISSUES —
``recommend`` returns ``(session, choices, decision_ids)``, enqueuing
each decision into a device-resident ring — while
``observe_delayed(session, decision_ids, rewards)`` folds feedback
whenever it arrives: exact under out-of-order/duplicate/lossy delivery,
TTL-dropping the rest, bit-identical to the synchronous ``step`` at zero
delay.  ``serve.guardrails`` layers streaming breach monitors with
checkpoint auto-rollback on top; ``serve.faults`` is the seeded
fault-injection harness that drives the whole stack
(``python -m repro.launch.faultrun``).

Online experimentation (README "Online experimentation"):
``serve.experiments`` runs N arm sessions — any policy mix — behind one
request stream with deterministic sticky uid-hash traffic splitting, an
optional Thompson-sampling meta-selector re-weighting fractions at epoch
boundaries, per-arm guardrail auto-disable, whole-experiment
checkpoint/restore, and seeded A/B through the fault harness
(``python -m repro.launch.abrun``)::

    from repro.serve import experiments
    exp = experiments.create([sess_a, sess_b, sess_c],
                             selector=experiments.make_selector(3))
    exp, choices, ids = experiments.recommend(exp, user_ids, contexts)
    exp = experiments.observe_delayed(exp, ids, rewards)

The old ``serve.bandit_service`` NamedTuple API was removed in PR 9
(deprecated since PR 4); importing it raises with a pointer here
(README "Online serving API" has the migration notes).
"""
from ..core.catalog import (Bank, Catalog, add_items, make_catalog,
                            publish, random_catalog, retire_items,
                            staged_churn, torn_publish)
from ..core.itemclub import (ItemClusters, ItemStats, RetrievalMetrics,
                             build_clusters, init_stats, observe_served,
                             refresh_clusters, reset_new_slots)
from . import experiments
from .experiments import (Experiment, ExperimentReport, TSSelector,
                          assign_arms, make_selector, run_experiment)
from .faults import (FaultReport, FaultSpec, TrafficStream, run_faulted,
                     run_faulted_catalog)
from .guardrails import (Guarded, GuardrailConfig, GuardrailState,
                         post_rollback_state, shortlist_recall)
from .pending import PendingBuffer
from .policies import (POLICIES, ClusteredPolicy, ClusteredState,
                       DCCBPolicy, DCCBServeState, LinUCBPolicy,
                       LinUCBServeState, ServeCfg, from_distclub_state,
                       get_policy, make_cfg, to_distclub_state)
from .session import (OnlineBandit, embed_candidates, observe,
                      observe_delayed, pending_stats, recommend,
                      recommend_catalog, refresh, reset_pending, step,
                      step_catalog)

__all__ = [
    "Bank", "Catalog", "POLICIES", "ClusteredPolicy", "ClusteredState",
    "DCCBPolicy", "DCCBServeState", "Experiment", "ExperimentReport",
    "FaultReport", "FaultSpec",
    "Guarded", "GuardrailConfig", "GuardrailState", "ItemClusters",
    "ItemStats", "LinUCBPolicy", "LinUCBServeState", "OnlineBandit",
    "PendingBuffer", "RetrievalMetrics", "ServeCfg", "TSSelector",
    "TrafficStream",
    "add_items", "assign_arms", "build_clusters", "embed_candidates",
    "experiments", "from_distclub_state", "get_policy", "init_stats",
    "make_catalog", "make_cfg", "make_selector", "observe",
    "observe_delayed", "observe_served", "pending_stats",
    "post_rollback_state", "publish", "random_catalog",
    "recommend", "recommend_catalog", "refresh", "refresh_clusters",
    "reset_new_slots", "reset_pending", "retire_items",
    "run_experiment", "run_faulted", "run_faulted_catalog",
    "shortlist_recall", "staged_churn", "step", "step_catalog",
    "to_distclub_state", "torn_publish",
]
