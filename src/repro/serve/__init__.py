"""First-class online serving: policy-pluggable `OnlineBandit` sessions
bound to the stage engine.

    from repro import serve

    session = serve.OnlineBandit.create(n_users, d, hyper,
                                        policy="distclub",
                                        refresh_every=n_users * 4)
    session, choices, metrics = serve.step(session, key, user_ids,
                                           contexts, reward_fn)

or, for real request/feedback splits:

    choices = serve.recommend(session, user_ids, contexts)
    ...  # show items, collect clicks
    session = serve.observe(session, user_ids, contexts, choices, rewards)

Policies: ``distclub`` | ``dccb`` | ``club`` | ``linucb`` — one protocol,
four bandits, head-to-head on the identical serving surface (see
``serve.policies``).  ``OnlineBandit.sharded(mesh, ...)`` runs the same
transaction over a device mesh; ``session.save``/``session.restore``
round-trip through ``train.checkpoint.CheckpointManager``.

The old ``serve.bandit_service`` NamedTuple API is deprecated; a shim
remains (README "Online serving API" has the migration notes).
"""
from .policies import (POLICIES, ClusteredPolicy, ClusteredState,
                       DCCBPolicy, DCCBServeState, LinUCBPolicy,
                       LinUCBServeState, ServeCfg, from_distclub_state,
                       get_policy, make_cfg, to_distclub_state)
from .session import (OnlineBandit, embed_candidates, observe, recommend,
                      refresh, step)

__all__ = [
    "POLICIES", "ClusteredPolicy", "ClusteredState", "DCCBPolicy",
    "DCCBServeState", "LinUCBPolicy", "LinUCBServeState", "OnlineBandit",
    "ServeCfg", "embed_candidates", "from_distclub_state", "get_policy",
    "make_cfg", "observe", "recommend", "refresh", "step",
    "to_distclub_state",
]
