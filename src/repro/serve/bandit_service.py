"""DEPRECATED migration shim over the `OnlineBandit` session API.

The ``BanditService`` NamedTuple + free functions were replaced by
``repro.serve``'s policy-pluggable sessions (README "Online serving
API").  This shim keeps the old call sites running on top of the new
engine-backed transaction; migrate to::

    session = serve.OnlineBandit.create(n, d, hyper, policy="distclub",
                                        refresh_every=every)
    session, choices, metrics = serve.step(session, key, users, ctx, rf)

Semantic changes the shim inherits from the redesign (deliberate):

  * duplicate-user batches are now EXACT (the old ``observe`` dropped all
    but the last occurrence via ``.at[ids].set``);
  * the cluster mean-occupancy the beta heuristic reads is the FROZEN
    stage-2 snapshot (the engine semantics) — the old service advanced
    ``clusters.seen`` live between refreshes;
  * scoring/updates run through the fused ``InteractBackend``
    (``REPRO_BACKEND`` dispatch) instead of raw ucb/rank1 ops, so the
    ``use_pallas=`` arguments are ignored.

``maybe_refresh`` keeps its host-synced check for compatibility; the new
API schedules refresh inside the jitted transaction (``refresh_every``).
"""
from __future__ import annotations

import warnings
from typing import NamedTuple

from ..core.types import BanditHyper, DistCLUBState
from . import policies, session as _session

embed_candidates = _session.embed_candidates


# emit the deprecation exactly once per process: the shim sits in
# request/feedback hot loops, so a per-call warning floods serving logs
# (and per-call `warnings` bookkeeping isn't free).  Tests reset this
# module-level guard to re-arm the warning.
_warned = False


def _deprecated(name: str):
    global _warned
    if _warned:
        return
    _warned = True
    warnings.warn(
        f"repro.serve.bandit_service.{name} is deprecated (first use; "
        "further uses won't warn): migrate to the repro.serve session "
        "API — serve.OnlineBandit.create / serve.step (README: Online "
        "serving API / migration notes)",
        DeprecationWarning, stacklevel=3,
    )


class BanditService(NamedTuple):
    """Compatibility wrapper: an `OnlineBandit` session behind the old
    record's attribute surface."""

    session: _session.OnlineBandit

    @property
    def state(self) -> DistCLUBState:
        """The old record, REBUILT on access (two [n, d, d] batched
        inversions + the label-table segment sums) — the session no
        longer carries the derived tables.  Hold the result in a local
        when reading repeatedly; new code reads ``session.state``."""
        cfg = self.session.policy.cfg
        return policies.to_distclub_state(self.session.state, cfg.hyper,
                                          cfg.d)

    @property
    def hyper(self) -> BanditHyper:
        return self.session.policy.cfg.hyper

    @property
    def d(self) -> int:
        return self.session.policy.cfg.d

    @property
    def interactions_since_refresh(self):
        return self.session.state.since_refresh


def create(n_users: int, d: int, hyper: BanditHyper) -> BanditService:
    _deprecated("create")
    return BanditService(session=_session.OnlineBandit.create(
        n_users, d, hyper, policy="distclub", refresh_every=0))


def recommend(svc: BanditService, user_ids, contexts, *,
              use_pallas: bool | None = None):
    """Pick one item per request.  user_ids [B], contexts [B, K, d] -> [B]."""
    _deprecated("recommend")
    del use_pallas                     # engine dispatch is session-level now
    return _session.recommend(svc.session, user_ids, contexts)


def observe(svc: BanditService, user_ids, contexts, choices, rewards, *,
            use_pallas: bool | None = None) -> BanditService:
    """Fold a feedback batch (duplicate-user batches are exact now)."""
    _deprecated("observe")
    del use_pallas
    return BanditService(session=_session.observe(
        svc.session, user_ids, contexts, choices, rewards))


def maybe_refresh(svc: BanditService, every: int) -> BanditService:
    """Stage-2 refresh when the budget elapsed.  Host-synced for
    compatibility — new code passes ``refresh_every`` at session creation
    and lets the jitted transaction schedule it."""
    _deprecated("maybe_refresh")
    if int(svc.session.state.since_refresh) < every:
        return svc
    return BanditService(session=_session.refresh(svc.session))
