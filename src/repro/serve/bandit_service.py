"""REMOVED — the old ``BanditService`` deprecation shim (deprecated in
PR 4) is retired.  Use ``repro.serve`` directly::

    from repro import serve
    session = serve.OnlineBandit.create(n_users, d, hyper)
    session, choices, metrics = serve.step(session, key, uids, ctx, rfn)

See the README "Migration from ``serve.bandit_service``" notes.
"""
raise ImportError(
    "repro.serve.bandit_service was removed — use repro.serve "
    "(OnlineBandit.create / step / recommend / observe_delayed); see the "
    "README migration notes")
