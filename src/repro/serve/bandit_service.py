"""DistCLUB as a first-class serving feature on top of the recsys models.

The recommendation loop the paper describes, with a real embedding model
supplying the context vectors:

  1. a recsys model (SASRec / BERT4Rec / MIND) embeds each user's candidate
     items -> the bandit's context set ``C_t`` (unit-normalized);
  2. the DistCLUB layer owns per-user LinUCB state and scores candidates
     with the fused UCB kernel (estimate + exploration bonus), choosing the
     item to show;
  3. observed rewards fold back with the rank-1 Sherman-Morrison kernel;
  4. periodically (stage-2) the user graph is re-clustered and cluster
     statistics are tree-reduced, after which cold users score with cluster
     statistics instead (the beta-heuristic decides per user).

State lives in the same ``DistCLUBState`` the offline driver uses, so the
checkpoint manager snapshots the full service (model params + bandit state)
and a restarted/rescaled replica resumes exactly.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from ..core import clustering, linucb
from ..core.types import BanditHyper, DistCLUBState
from ..core.distclub import init_state
from ..kernels.rank1 import ops as rank1_ops
from ..kernels.ucb import ops as ucb_ops


class BanditService(NamedTuple):
    state: DistCLUBState
    hyper: BanditHyper
    d: int
    interactions_since_refresh: jnp.ndarray


def create(n_users: int, d: int, hyper: BanditHyper) -> BanditService:
    return BanditService(
        state=init_state(n_users, d, hyper),
        hyper=hyper, d=d,
        interactions_since_refresh=jnp.zeros((), jnp.int32),
    )


def embed_candidates(item_embed: jnp.ndarray, cand_ids: jnp.ndarray):
    """Model item embeddings -> unit-norm bandit contexts [B, K, d]."""
    e = item_embed[cand_ids]
    return e / jnp.maximum(jnp.linalg.norm(e, axis=-1, keepdims=True), 1e-9)


def recommend(svc: BanditService, user_ids: jnp.ndarray,
              contexts: jnp.ndarray, *, use_pallas: bool | None = None):
    """Pick one item per request.  user_ids [B], contexts [B, K, d] -> [B]."""
    st = svc.state
    lin = st.lin
    labels = st.graph.labels[user_ids]
    stats = st.clusters

    size = jnp.maximum(stats.size[labels], 1)
    mean_occ = stats.seen[labels].astype(jnp.float32) / size
    use_own = lin.occ[user_ids].astype(jnp.float32) >= svc.hyper.beta * mean_occ

    v_own = linucb.user_vector(lin.Minv[user_ids], lin.b[user_ids])
    v_clu = linucb.user_vector(stats.Mcinv[labels], stats.bc[labels])
    w = jnp.where(use_own[:, None], v_own, v_clu)
    minv = jnp.where(use_own[:, None, None], lin.Minv[user_ids],
                     stats.Mcinv[labels])
    scores = ucb_ops.ucb_scores(w, minv, contexts, lin.occ[user_ids],
                                svc.hyper.alpha, use_pallas=use_pallas)
    return jnp.argmax(scores, axis=-1)


def observe(svc: BanditService, user_ids: jnp.ndarray, contexts: jnp.ndarray,
            choices: jnp.ndarray, rewards: jnp.ndarray,
            *, use_pallas: bool | None = None) -> BanditService:
    """Fold a batch of (distinct-user) feedback into the bandit state.

    Note the deliberate semantic difference from the offline 4-stage
    driver: serving advances ``clusters.seen`` LIVE between stage-2
    refreshes so the beta heuristic reacts to traffic immediately, while
    the epoch drivers (single-host and sharded, via
    ``runtime.stages``) freeze ``seen`` at the stage-2 snapshot for the
    whole epoch — the paper's lazy semantics.  Both converge to the same
    value at each refresh, which rebuilds ``seen`` from ``occ``."""
    st = svc.state
    x = jnp.take_along_axis(contexts, choices[:, None, None], axis=1)[:, 0]
    M_u, Minv_u, b_u = (st.lin.M[user_ids], st.lin.Minv[user_ids],
                        st.lin.b[user_ids])
    mask = jnp.ones(user_ids.shape, bool)
    M2, Minv2, b2 = rank1_ops.rank1_update(
        M_u, Minv_u, b_u, x, rewards, mask, use_pallas=use_pallas)
    lin = st.lin._replace(
        M=st.lin.M.at[user_ids].set(M2),
        Minv=st.lin.Minv.at[user_ids].set(Minv2),
        b=st.lin.b.at[user_ids].set(b2),
        occ=st.lin.occ.at[user_ids].add(1),
    )
    seen = st.clusters.seen.at[st.graph.labels[user_ids]].add(1)
    return svc._replace(
        state=st._replace(lin=lin, clusters=st.clusters._replace(seen=seen)),
        interactions_since_refresh=svc.interactions_since_refresh
        + user_ids.shape[0],
    )


def maybe_refresh(svc: BanditService, every: int) -> BanditService:
    """Stage-2: re-cluster + tree-reduce stats when the budget elapses."""
    if int(svc.interactions_since_refresh) < every:
        return svc
    from ..core import distclub

    state = distclub.stage2(svc.state, svc.hyper, svc.d)
    return svc._replace(state=state,
                        interactions_since_refresh=jnp.zeros((), jnp.int32))
