"""`OnlineBandit`: policy-pluggable online serving sessions on the stage
engine.

The hot path is ONE jit-compiled transaction per request batch —

    session, choices, metrics = serve.step(
        session, key, user_ids, contexts, reward_fn)

— score (policy-mixed statistics), fused choose (`InteractBackend`, the
`[B, K]` score tensor never hits HBM on the pallas engine), reward,
duplicate-safe feedback fold, and a trace-friendly refresh (`lax.cond` on
the interaction budget; the old host-synced `int(...)` check is gone).
For real request/feedback splits the transaction decomposes into the two
halves `recommend` (pure, no state change) and `observe` (feedback fold +
refresh schedule).

Fault-tolerant feedback (README "Fault tolerance & guardrails"): a
session created with ``pending_capacity > 0`` carries a persistent
device-resident ring of in-flight decisions (`serve.pending`).  On such
a session `recommend`/`recommend_catalog` ISSUE: they return
``(session, choices, decision_ids)`` (catalog:
``(session, item_ids, decision_ids, slots, ctx)``), enqueuing one
decision per valid request, and `observe_delayed(session, decision_ids,
rewards)` folds feedback matched by decision id whenever it arrives —
exact under out-of-order, duplicated, and lossy delivery, dropping on
TTL with counted `expired`, all inside the jit transaction.  With zero
delay the pair is bit-identical to the synchronous `step` (the buffer
stores the exact psum-combined chosen context the fold needs), on
single-host and sharded sessions alike (the buffer is replicated).
Under live catalog churn (README "Live catalog churn") every catalog
decision records its issue epoch, and `observe_delayed(...,
catalog=current_catalog)` quarantines feedback whose item churned since
issue — counted `stale`, extending the conservation identity to
issued == matched + in_flight + expired + dropped + stale.

Duplicate-user batches are EXACT.  A batch is decomposed by occurrence
rank (item i's rank = how many earlier items carry the same user id) and
folded rank-by-rank with `lax.fori_loop`: within one pass every live row
is a distinct user, so a single fused masked rank-1 sweep per pass equals
the sequential per-interaction fold.  Distinct-user batches take exactly
one pass — the common fast path costs one fused update, and matches the
offline `runtime.stages.interaction_rounds` update bit for bit.

Catalog-scale retrieval: `step_catalog`/`recommend_catalog` serve the
same transaction against a persistent `core.catalog.Catalog` instead of
a caller-supplied slate — the streaming top-K engine
(`core.backend.RetrievalBackend`, `kernels/topk`) shortlists each user's
`k_short` highest-UCB live items (per item shard on a sharded session,
merged by (score desc, id asc) — bit-equal to a single-host shortlist)
and the fused choose ranks the shortlist.  The `[B, N_items]` score
matrix never exists; comm on a sharded session is O(B k_short shards).

Sharding: `OnlineBandit.sharded(mesh, ...)` binds the SAME step body to
`LaxCollectives` under `shard_map` — per-user state rows are sharded over
the mesh, the request batch is replicated, each shard scores/updates the
users it owns and the per-request results are combined with one `psum`
(non-owner shards contribute zeros).  Refresh runs `stages.stage2_refresh`
with the mesh collectives, i.e. the identical code path as
`distributed.distclub_shard`.  A serving replica set is the offline
sharded runtime plus a request front-end.

Fault tolerance: `session.save(ckpt, step)` / `session.restore(ckpt)`
round-trip the policy state through `train.checkpoint.CheckpointManager`
(re-sharded onto whatever mesh the restoring session has) — a restarted
replica resumes with bit-identical subsequent choices
(`tests/test_serve.py::test_checkpoint_restore_resumes_bit_identical`).

Caching note: compiled transactions are memoized per (policy, reward_fn,
mesh) — pass a *stable* `reward_fn` (a module-level function or one
closure built once), not a fresh lambda per call, or every call retraces.

Padding contract (load-bearing for `serve.experiments`): rows with
``uid < 0`` or ``uid >= n_users`` flow through every transaction as
no-ops — choice 0 / item -1, no state change, decision id -1.  The
experiment router exploits this to partition one batch across N arm
sessions by masking non-assigned rows to uid -1, which keeps a
single-arm experiment bit-identical to a plain session.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from ..core import catalog as catalog_mod
from ..core import itemclub as itemclub_mod
from ..core.backend import BackendConfig
from ..core.types import BanditHyper, Metrics
from ..kernels.topk.ref import select_topk
from ..runtime.collectives import NullCollectives, lax_collectives
from . import pending as pending_mod
from . import policies as pol

_NULL = NullCollectives()

# the Precision policy is checkpointed as a small i32 tag (dtype codes +
# scale block) so restore can refuse a snapshot written under another one
_PREC_NAMES = ("f32", "bf16", "int8")


def _precision_tag(prec):
    return jnp.array([_PREC_NAMES.index(prec.state_dtype),
                      _PREC_NAMES.index(prec.catalog_dtype),
                      _PREC_NAMES.index(prec.accum_dtype),
                      prec.scale_block], jnp.int32)


def _decode_precision_tag(codes):
    def name(c):
        return _PREC_NAMES[c] if 0 <= c < len(_PREC_NAMES) else f"?{c}"

    return (f"Precision(state={name(codes[0])}, catalog={name(codes[1])}, "
            f"accum={name(codes[2])}, scale_block={codes[3]})")


def embed_candidates(item_embed: jnp.ndarray, cand_ids: jnp.ndarray):
    """Model item embeddings -> unit-norm bandit contexts [B, K, d]."""
    e = item_embed[cand_ids]
    return e / jnp.maximum(jnp.linalg.norm(e, axis=-1, keepdims=True), 1e-9)


# ---------------------------------------------------------------------------
# the transaction body (shared single-host / sharded)
# ---------------------------------------------------------------------------


def _occurrence_ranks(user_ids: jnp.ndarray) -> jnp.ndarray:
    """rank[i] = number of earlier batch items with the same user id.
    O(B^2) bools — negligible next to the [B, d, d] row gathers at
    serving batch sizes."""
    eq = user_ids[:, None] == user_ids[None, :]
    earlier = jnp.tril(eq, k=-1)
    return jnp.sum(earlier, axis=1).astype(jnp.int32)


def _normalize_rewards(out):
    """Accept `realized [B]` or the full env 4-tuple
    `(realized, expected, best, rand)`; missing regret/baseline terms
    metric as zero."""
    if isinstance(out, (tuple, list)):
        realized, expected, best, rand = out
    else:
        realized = out
        expected = best = rand = jnp.zeros_like(realized)
    return realized, expected, best, rand


def _request_masks(policy, col, state, user_ids):
    """(idx, own, valid, be): local row index per request, ownership mask
    for this shard, global validity, and the batch-width engine (the
    session's run-level dispatch re-fit to this traced batch width)."""
    cfg = policy.cfg
    n_local = policy.occ_of(state).shape[0]
    row0 = col.axis_index() * n_local
    valid = (user_ids >= 0) & (user_ids < cfg.n_users)
    local = user_ids - row0
    own = valid & (local >= 0) & (local < n_local)
    idx = jnp.clip(local, 0, n_local - 1)
    return idx, own, valid, cfg.engine.with_users(user_ids.shape[0])


def _choose(policy, col, state, user_ids, contexts):
    """Score + fused choose; combine per-request results across shards."""
    idx, own, valid, be = _request_masks(policy, col, state, user_ids)
    w, minv_eff, occ_rows = policy.gather_score(state, idx)
    x, choice = be.choose(w, minv_eff, contexts, occ_rows,
                          policy.cfg.hyper.alpha)
    choice = col.psum(jnp.where(own, choice, 0))
    x = col.psum(jnp.where(own[:, None], x, jnp.zeros_like(x)))
    return choice, x, (idx, own, valid, be)

def _fold_feedback(policy, state, idx, own, valid, be, user_ids, x,
                   realized):
    """Duplicate-safe feedback fold: one fused masked pass per occurrence
    rank (live rows of a pass are distinct users -> the pass is exact;
    distinct-user batches take exactly one pass)."""
    ranks = _occurrence_ranks(user_ids)
    n_passes = jnp.max(jnp.where(valid, ranks, -1)) + 1

    def one_pass(k, st):
        live = own & (ranks == k)
        return policy.apply_pass(st, idx, x, realized, live, be)

    return jax.lax.fori_loop(0, n_passes, one_pass, state)


def _schedule_refresh(policy, col, state, n_new, key):
    """Trace-friendly refresh: `lax.cond` on the interaction budget.

    The refresh key mixes the state's lifetime interaction count into the
    caller's key, so a randomized refresh (dccb gossip's peer draw) still
    varies round to round even when the caller reuses a key — e.g. the
    `observe` half's default.  The count is part of the checkpointed
    state, so a restored replica replays the identical schedule."""
    since = state.since_refresh + n_new
    state = state._replace(since_refresh=since)
    every = policy.cfg.refresh_every
    if not policy.has_refresh or every <= 0:
        return state
    k_ref = jax.random.fold_in(jax.random.fold_in(key, 1),
                               col.psum(jnp.sum(policy.occ_of(state))))

    def fire(st):
        st = policy.refresh(col, st, k_ref)
        return st._replace(since_refresh=jnp.zeros((), jnp.int32))

    return jax.lax.cond(since >= every, fire, lambda st: st, state)


def _apply_feedback(policy, col, state, key, idx, own, valid, be,
                    user_ids, x, rewards):
    """The shared transaction tail of both step bodies: fold the reward
    4-tuple, run the refresh schedule, reduce the batch metrics."""
    realized, expected, best, rand = rewards
    state = _fold_feedback(policy, state, idx, own, valid, be, user_ids,
                           x, realized)
    n_new = jnp.sum(valid.astype(jnp.int32))
    state = _schedule_refresh(policy, col, state, n_new, key)
    vm = valid.astype(realized.dtype)
    metrics = Metrics(
        reward=jnp.sum(realized * vm),
        regret=jnp.sum((best - expected) * vm),
        rand_reward=jnp.sum(rand * vm),
        interactions=n_new,
    )
    return state, metrics


def _step_body(policy, reward_fn, col, state, key, user_ids, contexts):
    choice, x, (idx, own, valid, be) = _choose(policy, col, state,
                                               user_ids, contexts)
    rewards = _normalize_rewards(reward_fn(key, user_ids, contexts, choice))
    state, metrics = _apply_feedback(policy, col, state, key, idx, own,
                                     valid, be, user_ids, x, rewards)
    return state, choice, metrics


def _observe_body(policy, col, state, key, user_ids, contexts, choices,
                  rewards):
    idx, own, valid, be = _request_masks(policy, col, state, user_ids)
    x = jnp.take_along_axis(contexts, choices[:, None, None], axis=1)[:, 0]
    state = _fold_feedback(policy, state, idx, own, valid, be, user_ids,
                           x, rewards)
    n_new = jnp.sum(valid.astype(jnp.int32))
    return _schedule_refresh(policy, col, state, n_new, key)


# ---------------------------------------------------------------------------
# catalog-scale retrieval: shortlist -> merge -> fused choose
# ---------------------------------------------------------------------------


def _catalog_choose(policy, rb, col, state, user_ids, catalog,
                    clusters=None):
    """Two-stage choose against a persistent (item-sharded) catalog.

    Stage 1 (shortlist): the request users' statistics are psum-replicated
    to every shard, each shard runs the streaming top-K engine over its
    LOCAL catalog slice, and the per-shard ``[B, K_short]`` (score, id)
    lists are all-gathered and merged by (score desc, id asc) — the exact
    order the kernel itself selects in, so the merged list is bit-equal
    to a single-host shortlist over the whole catalog (comm:
    ``O(B K_short shards)`` words, never ``O(B N_items)``).

    With ``clusters`` (a replicated ``core.itemclub.ItemClusters``) stage
    1 runs CLUSTER-PRUNED: each shard streams its position range of the
    cluster-sorted catalog and skips tiles whose UCB upper bound cannot
    beat the running shortlist floor — EXACT (the shortlist is bit-equal
    to the unpruned one; ``kernels/topk/ref.py``), and since the sorted
    stream carries global slot ids, the per-shard merge is too.  The
    churn-safety rule is enforced HERE, inside the jit transaction: if
    the cluster table's epoch does not match the catalog's (a `publish`
    landed after the last rebuild), the whole batch falls back to the
    unpruned stream — stale bounds are never trusted.  The last returned
    value is then a ``RetrievalMetrics`` (psum-combined tile skip counts
    + whether pruning was active); None when no clusters were given.

    Stage 2 (choose): shortlist embeddings are assembled by a one-hot
    psum (each shard contributes the rows it owns) and ranked by the
    session's fused ``InteractBackend.choose`` re-fit to ``K_short``
    candidates.  Underfull slots (score -inf) are filled with the user's
    top entry, so the filler can never outrank a real candidate and maps
    back to a valid item id.  For ``N_items <= K_short`` the shortlist is
    the whole catalog in (score desc, id asc) order and the chosen item
    is bit-identical to scoring the catalog as one direct slate.
    """
    cfg = policy.cfg
    idx, own, valid, be = _request_masks(policy, col, state, user_ids)
    w, minv_eff, occ_rows = policy.gather_score(state, idx)
    # replicate the request rows: exactly one shard owns each valid user
    w = col.psum(jnp.where(own[:, None], w, 0.0))
    minv_eff = col.psum(jnp.where(own[:, None, None], minv_eff, 0.0))
    occ_rows = col.psum(jnp.where(own, occ_rows, 0))

    bank = catalog.serving            # the ACTIVE double-buffer bank
    n_local_items = bank.live.shape[0]
    row0_items = col.axis_index() * n_local_items
    # int8 banks ship their per-slot dequant scales into the kernels;
    # f32/bf16 banks upcast in VMEM without scales (trace-time branch)
    scales = bank.scale if bank.emb.dtype == jnp.int8 else None
    if clusters is None:
        sc, ids = rb.shortlist(w, minv_eff, occ_rows, bank.emb, bank.live,
                               cfg.hyper.alpha, row0_items=row0_items,
                               scales=scales)
        rmet = None
    else:
        shard_tabs = itemclub_mod.shard_slice(clusters, col.axis_index(),
                                              n_local_items)
        fresh = clusters.epoch == catalog.epoch

        def _pruned(_):
            (emb_s, live_s, ids_s, scale_s,
             t_mu, t_r, t_xn, t_n) = shard_tabs
            ss = scale_s if emb_s.dtype == jnp.int8 else None
            return rb.shortlist_pruned(w, minv_eff, occ_rows, emb_s,
                                       live_s, ids_s, t_mu, t_r, t_xn,
                                       t_n, cfg.hyper.alpha,
                                       scales_sorted=ss)

        def _unpruned(_):
            s, i = rb.shortlist(w, minv_eff, occ_rows, bank.emb,
                                bank.live, cfg.hyper.alpha,
                                row0_items=row0_items, scales=scales)
            z = jnp.zeros((), jnp.int32)
            return s, i, z, z

        sc, ids, skipped, total = jax.lax.cond(fresh, _pruned, _unpruned,
                                               None)
        rmet = itemclub_mod.RetrievalMetrics(
            tiles_skipped=col.psum(skipped),
            tiles_total=col.psum(total),
            pruned_active=fresh.astype(jnp.int32),
        )
    sc_all = col.all_gather(sc[None])           # [S, B, K_short]
    id_all = col.all_gather(ids[None])
    B = user_ids.shape[0]
    sc_flat = jnp.moveaxis(sc_all, 0, 1).reshape(B, -1)
    id_flat = jnp.moveaxis(id_all, 0, 1).reshape(B, -1)
    # merge with the kernel's OWN selection routine, so the merged order
    # is the kernel's order by construction (not a re-implementation
    # that could diverge on e.g. signed-zero ties)
    top_s, top_i = select_topk(sc_flat, id_flat, rb.K_short)
    top_i = jnp.where(jnp.isfinite(top_s), top_i, top_i[:, :1])

    loc = top_i - row0_items
    ok = (loc >= 0) & (loc < n_local_items)
    g = jnp.clip(loc, 0, n_local_items - 1)
    # dequantize the gathered shortlist rows before the f32 psum — the
    # slate the fused choose (and the reward_fn) sees is always f32
    rows = bank.emb[g].astype(jnp.float32)
    if scales is not None:
        rows = rows * bank.scale[g][..., None]
    ctx = col.psum(jnp.where(ok[..., None], rows, 0.0))   # [B, K_short, d]

    be_s = be.with_candidates(rb.K_short)
    x, slot = be_s.choose(w, minv_eff, ctx, occ_rows, cfg.hyper.alpha)
    item = jnp.take_along_axis(top_i, slot[:, None], axis=1)[:, 0]
    item = jnp.where(valid, item, -1)
    return item, slot, ctx, x, (idx, own, valid, be), rmet


def _catalog_step_body(policy, rb, reward_fn, col, state, key, user_ids,
                       catalog, clusters=None):
    item, slot, ctx, x, (idx, own, valid, be), rmet = _catalog_choose(
        policy, rb, col, state, user_ids, catalog, clusters)
    rewards = _normalize_rewards(reward_fn(key, user_ids, ctx, slot))
    state, metrics = _apply_feedback(policy, col, state, key, idx, own,
                                     valid, be, user_ids, x, rewards)
    if clusters is None:
        return state, item, metrics
    return state, item, metrics, rmet


# ---------------------------------------------------------------------------
# the pending-decision feedback loop: issue now, fold when feedback lands
# ---------------------------------------------------------------------------


def _issue_body(policy, ttl, col, state, pend, user_ids, contexts):
    """The request half on a buffer-enabled session: choose (identical
    math to `_step_body`) and enqueue one pending decision per valid
    request.  The policy state is read, never written."""
    choice, x, (idx, own, valid, be) = _choose(policy, col, state,
                                               user_ids, contexts)
    pend, ids = pending_mod.issue(pend, user_ids, choice, x, valid, ttl)
    return pend, choice, ids


def _catalog_issue_body(policy, rb, ttl, col, state, pend, user_ids,
                        catalog, clusters=None):
    item, slot, ctx, x, (idx, own, valid, be), rmet = _catalog_choose(
        policy, rb, col, state, user_ids, catalog, clusters)
    pend, ids = pending_mod.issue(pend, user_ids, item, x, valid, ttl,
                                  epoch=catalog.epoch)
    if clusters is None:
        return pend, item, ids, slot, ctx
    return pend, item, ids, slot, ctx, rmet


def _observe_delayed_body(policy, col, state, pend, key, decision_ids,
                          rewards, stale=None):
    """Fold feedback matched by decision id: the matched slots supply the
    exact (uid, chosen-context) pair the synchronous fold would have
    used, so the delayed fold is bit-identical; unmatched entries
    (expired / already folded / in-batch duplicates / id -1 padding)
    surface as uid -1 and fold as padding, and ``stale``-masked entries
    are quarantined by the match (freed + counted, never folded)."""
    pend, uids, x = pending_mod.match(pend, decision_ids, stale=stale)
    idx, own, valid, be = _request_masks(policy, col, state, uids)
    state = _fold_feedback(policy, state, idx, own, valid, be, uids, x,
                           rewards)
    n_new = jnp.sum(valid.astype(jnp.int32))
    state = _schedule_refresh(policy, col, state, n_new, key)
    return state, pend


def _stale_mask(col, pend, decision_ids, catalog):
    """Per-delivery staleness against the CURRENT catalog: feedback for a
    decision issued at epoch ``e`` folds iff the published epoch is at
    most ``e + 1`` (the one-stale-epoch bound) AND its item is still
    live in the active bank with ``born <= e`` (a retired-then-reclaimed
    slot fails the born check even though it is live again).  Item
    liveness is resolved per item shard and psum-combined, mirroring the
    shortlist-row assembly.  Values at non-resident slots are garbage —
    harmless, since ``match`` only applies the mask to hits."""
    C = pend.uid.shape[0]
    slot = jnp.mod(jnp.where(decision_ids >= 0, decision_ids, 0), C)
    item = pend.choice[slot]
    e_issue = pend.epoch[slot]
    bank = catalog.serving
    n_local = bank.live.shape[0]
    row0 = col.axis_index() * n_local
    loc = item - row0
    in_range = (loc >= 0) & (loc < n_local)
    li = jnp.clip(loc, 0, n_local - 1)
    ok_here = in_range & (bank.live[li] > 0) & (bank.born[li] <= e_issue)
    item_ok = col.psum(ok_here.astype(jnp.int32)) > 0
    fresh = (catalog.epoch - e_issue) <= 1
    return ~(item_ok & fresh)


def _observe_delayed_catalog_body(policy, col, state, pend, key,
                                  decision_ids, rewards, catalog):
    stale = _stale_mask(col, pend, decision_ids, catalog)
    return _observe_delayed_body(policy, col, state, pend, key,
                                 decision_ids, rewards, stale=stale)


def _refresh_body(policy, col, state, key):
    k_ref = jax.random.fold_in(key,
                               col.psum(jnp.sum(policy.occ_of(state))))
    state = policy.refresh(col, state, k_ref)
    return state._replace(since_refresh=jnp.zeros((), jnp.int32))


# ---------------------------------------------------------------------------
# compiled-transaction cache (per policy / reward_fn / mesh)
# ---------------------------------------------------------------------------


def _bind_tx(policy, body, mesh, axes, out_extra=(), out_override=None):
    """jit `body(col, state, *args)` — single-host with NullCollectives,
    or shard_map'd over `mesh` with the policy's state specs (request
    args and scalar/choice outputs replicated)."""
    if mesh is None:
        return jax.jit(functools.partial(body, _NULL))
    col = lax_collectives(mesh, axes)
    specs = policy.state_specs(axes)
    bound = functools.partial(body, col)
    if out_override is not None:
        out_specs = out_override
    elif out_extra:
        out_specs = (specs,) + tuple(out_extra)
    else:
        out_specs = specs

    def wrap(state, *args):
        mapped = shard_map(
            bound, mesh=mesh,
            in_specs=(specs,) + tuple(P() for _ in args),
            out_specs=out_specs,
            check_rep=False,
        )
        return mapped(state, *args)

    return jax.jit(wrap)


@functools.lru_cache(maxsize=64)
def _step_fn(policy, reward_fn, mesh, axes):
    body = functools.partial(_step_body, policy, reward_fn)
    return _bind_tx(policy, body, mesh, axes,
                    out_extra=(P(), Metrics(P(), P(), P(), P())))


@functools.lru_cache(maxsize=64)
def _recommend_fn(policy, mesh, axes):
    def body(col, state, user_ids, contexts):
        choice, _, _ = _choose(policy, col, state, user_ids, contexts)
        return choice
    return _bind_tx(policy, body, mesh, axes, out_override=P())


@functools.lru_cache(maxsize=64)
def _observe_fn(policy, mesh, axes):
    def body(col, state, key, user_ids, contexts, choices, rewards):
        return _observe_body(policy, col, state, key, user_ids, contexts,
                             choices, rewards)
    return _bind_tx(policy, body, mesh, axes)


def _bind_catalog_tx(policy, body, mesh, axes, n_plain, out_specs,
                     tail_specs=()):
    """Like ``_bind_tx`` but the trailing arguments after the ``n_plain``
    replicated request inputs are a Catalog sharded on the ITEM axis over
    the same mesh axes the user state shards on, then any ``tail_specs``
    extras (e.g. a replicated ``ItemClusters`` on the pruned path)."""
    if mesh is None:
        return jax.jit(functools.partial(body, _NULL))
    col = lax_collectives(mesh, axes)
    bound = functools.partial(body, col)
    in_specs = ((policy.state_specs(axes),)
                + tuple(P() for _ in range(n_plain))
                + (catalog_mod.specs(axes),) + tuple(tail_specs))

    def wrap(state, *args):
        mapped = shard_map(
            bound, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_rep=False,
        )
        return mapped(state, *args)

    return jax.jit(wrap)


_RMET_SPECS = itemclub_mod.RetrievalMetrics(P(), P(), P())


@functools.lru_cache(maxsize=64)
def _catalog_step_fn(policy, rb, reward_fn, mesh, axes, pruned=False):
    body = functools.partial(_catalog_step_body, policy, rb, reward_fn)
    out = ((policy.state_specs(axes) if mesh is not None else None),
           P(), Metrics(P(), P(), P(), P()))
    if pruned:
        out = out + (_RMET_SPECS,)
    return _bind_catalog_tx(policy, body, mesh, axes, n_plain=2,
                            out_specs=out,
                            tail_specs=((itemclub_mod.specs(),)
                                        if pruned else ()))


@functools.lru_cache(maxsize=64)
def _catalog_recommend_fn(policy, rb, mesh, axes, pruned=False):
    def body(col, state, user_ids, catalog, clusters=None):
        item, slot, ctx, _, _, rmet = _catalog_choose(
            policy, rb, col, state, user_ids, catalog, clusters)
        if clusters is None:
            return item, slot, ctx
        return item, slot, ctx, rmet
    out = (P(), P(), P()) + ((_RMET_SPECS,) if pruned else ())
    return _bind_catalog_tx(policy, body, mesh, axes, n_plain=1,
                            out_specs=out,
                            tail_specs=((itemclub_mod.specs(),)
                                        if pruned else ()))


def _bind_pending_tx(policy, body, mesh, axes, n_plain, out_specs, *,
                     catalog=False, tail_specs=()):
    """Like ``_bind_tx`` for bodies over ``(state, pending, *args)`` —
    the pending buffer is replicated; with ``catalog`` the LAST plain
    arg is instead an item-sharded Catalog, and ``tail_specs`` extras
    (replicated cluster tables) follow it."""
    if mesh is None:
        return jax.jit(functools.partial(body, _NULL))
    col = lax_collectives(mesh, axes)
    bound = functools.partial(body, col)
    plain = [P() for _ in range(n_plain)]
    if catalog:
        plain[-1] = catalog_mod.specs(axes)
    in_specs = ((policy.state_specs(axes), pending_mod.specs())
                + tuple(plain) + tuple(tail_specs))

    def wrap(state, *args):
        mapped = shard_map(
            bound, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_rep=False,
        )
        return mapped(state, *args)

    return jax.jit(wrap)


@functools.lru_cache(maxsize=64)
def _issue_fn(policy, ttl, mesh, axes):
    body = functools.partial(_issue_body, policy, ttl)
    return _bind_pending_tx(policy, body, mesh, axes, n_plain=2,
                            out_specs=(pending_mod.specs(), P(), P()))


@functools.lru_cache(maxsize=64)
def _catalog_issue_fn(policy, rb, ttl, mesh, axes, pruned=False):
    body = functools.partial(_catalog_issue_body, policy, rb, ttl)
    out = (pending_mod.specs(), P(), P(), P(), P())
    if pruned:
        out = out + (_RMET_SPECS,)
    return _bind_pending_tx(
        policy, body, mesh, axes, n_plain=2, out_specs=out,
        catalog=True,
        tail_specs=(itemclub_mod.specs(),) if pruned else ())


@functools.lru_cache(maxsize=64)
def _observe_delayed_fn(policy, mesh, axes):
    def body(col, state, pend, key, decision_ids, rewards):
        return _observe_delayed_body(policy, col, state, pend, key,
                                     decision_ids, rewards)
    out = (policy.state_specs(axes) if mesh is not None else None,
           pending_mod.specs())
    return _bind_pending_tx(policy, body, mesh, axes, n_plain=3,
                            out_specs=out)


@functools.lru_cache(maxsize=64)
def _observe_delayed_catalog_fn(policy, mesh, axes):
    def body(col, state, pend, key, decision_ids, rewards, catalog):
        return _observe_delayed_catalog_body(policy, col, state, pend,
                                             key, decision_ids, rewards,
                                             catalog)
    out = (policy.state_specs(axes) if mesh is not None else None,
           pending_mod.specs())
    return _bind_pending_tx(policy, body, mesh, axes, n_plain=4,
                            out_specs=out, catalog=True)


@functools.lru_cache(maxsize=64)
def _force_refresh_fn(policy, mesh, axes):
    def body(col, state, key):
        return _refresh_body(policy, col, state, key)
    return _bind_tx(policy, body, mesh, axes)


# ---------------------------------------------------------------------------
# the session object + functional API
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class OnlineBandit:
    """One serving session: a hashable policy (static) + its state
    (pytree) + optional mesh binding.  Immutable — `step`/`observe`
    return a new session wrapping the new state."""

    policy: Any
    state: Any
    mesh: Any = None
    axes: tuple = ()
    pending: Any = None     # PendingBuffer, or None = synchronous-only
    ttl: int = 0            # pending TTL in issue transactions (static)

    # -- construction ------------------------------------------------------
    @classmethod
    def create(cls, n_users: int, d: int, hyper: BanditHyper, *,
               policy: str = "distclub", refresh_every: int = 0,
               backend: str | None = None, interpret: bool | None = None,
               block_users: int = 256, pending_capacity: int = 0,
               pending_ttl: int = 64, precision=None) -> "OnlineBandit":
        """Single-host session.  `refresh_every` is the interaction budget
        between refreshes (stage-2 / gossip); <= 0 disables scheduling
        (use `serve.refresh` to fire one manually).  `pending_capacity`
        > 0 enables the fault-tolerant feedback loop: `recommend`
        issues + enqueues and `observe_delayed` folds feedback by
        decision id; `pending_ttl` is how many SUBSEQUENT recommend
        transactions a decision survives before its feedback is dropped
        as expired.  `precision` (a `core.backend.Precision`, a preset
        name, or None = `REPRO_PRECISION` / f32) picks the reduced-
        precision state policy; checkpoints record it and refuse to
        restore under a different one."""
        cfg = pol.make_cfg(n_users, d, hyper, refresh_every=refresh_every,
                           backend=backend, interpret=interpret,
                           block_users=block_users, precision=precision)
        p = pol.get_policy(policy, cfg)
        pend = (pending_mod.init(pending_capacity, d)
                if pending_capacity > 0 else None)
        return cls(policy=p, state=p.init(), pending=pend,
                   ttl=int(pending_ttl))

    @classmethod
    def sharded(cls, mesh, n_users: int, d: int, hyper: BanditHyper, *,
                axes: tuple[str, ...] | None = None,
                policy: str = "distclub", refresh_every: int = 0,
                backend: str | None = None, interpret: bool | None = None,
                block_users: int = 256, pending_capacity: int = 0,
                pending_ttl: int = 64, precision=None) -> "OnlineBandit":
        """Serving replica set: per-user state sharded over `mesh` (users
        on the flattened `axes`), request batches replicated, refresh on
        the mesh collectives — the identical stage-2 code path as
        `distributed.distclub_shard`."""
        from ..distributed.distclub_shard import named_shardings

        axes = tuple(axes) if axes is not None else tuple(mesh.axis_names)
        cfg = pol.make_cfg(n_users, d, hyper, refresh_every=refresh_every,
                           backend=backend, interpret=interpret,
                           block_users=block_users, precision=precision)
        p = pol.get_policy(policy, cfg)
        shards = 1
        for a in axes:
            shards *= mesh.shape[a]
        if n_users % shards:
            raise ValueError(
                f"the {shards}-way mesh must evenly divide n_users={n_users}")
        state = jax.device_put(
            p.init(), named_shardings(mesh, p.state_specs(axes)))
        pend = (pending_mod.init(pending_capacity, d)
                if pending_capacity > 0 else None)
        return cls(policy=p, state=state, mesh=mesh, axes=axes,
                   pending=pend, ttl=int(pending_ttl))

    @classmethod
    def from_offline(cls, state, hyper: BanditHyper, *,
                     refresh_every: int = 0, backend: str | None = None,
                     interpret: bool | None = None,
                     precision=None) -> "OnlineBandit":
        """Warm-start a distclub serving session from an offline
        `distclub.run` final state (f32 — downcast into the session's
        precision state dtype here, a no-op under f32)."""
        n, d = state.lin.b.shape
        cfg = pol.make_cfg(n, d, hyper, refresh_every=refresh_every,
                           backend=backend, interpret=interpret,
                           precision=precision)
        p = pol.get_policy("distclub", cfg)
        st = pol.from_distclub_state(state)
        sdt = cfg.engine.precision.jnp_state
        st = st._replace(Minv=st.Minv.astype(sdt),
                         uMcinv=st.uMcinv.astype(sdt))
        return cls(policy=p, state=st)

    # -- checkpointing -----------------------------------------------------
    def _shardings(self):
        if self.mesh is None:
            return None
        from ..distributed.distclub_shard import named_shardings
        return named_shardings(self.mesh,
                               self.policy.state_specs(self.axes))

    def _precision_tag(self):
        return _precision_tag(self.policy.cfg.engine.precision)

    def _ckpt_shardings(self):
        sh = self._shardings()
        if sh is None:
            return None
        from jax.sharding import NamedSharding
        return {"prec": NamedSharding(self.mesh, P()), "state": sh}

    def save(self, ckpt, step: int):
        """Snapshot the policy state (atomic, keep-K — see
        `train.checkpoint`).  The session's `Precision` policy is
        recorded alongside the state: a reduced-precision snapshot is not
        silently reinterpretable, so `restore` refuses a mismatch."""
        payload = {"prec": self._precision_tag(), "state": self.state}
        return ckpt.save(payload, step)

    def restore(self, ckpt, step: int | None = None):
        """(session, step) restored from `ckpt` (latest when `step` is
        None; (self, None) when the directory is empty).  Re-shards onto
        this session's mesh — a replica restarted on a different mesh
        resumes from the same bytes.  Raises ``ValueError`` when the
        checkpoint was written under a different `Precision` policy —
        bytes saved as bf16/int8 state must not be silently upcast into
        an f32 session (or vice versa)."""
        like = {"prec": self._precision_tag(), "state": self.state}
        shardings = self._ckpt_shardings()
        if step is None:
            payload, step = ckpt.restore_latest(like, shardings)
            if payload is None:
                return self, None
        else:
            payload = ckpt.restore(step, like, shardings)
        got = [int(v) for v in jax.device_get(payload["prec"])]
        want = [int(v) for v in jax.device_get(self._precision_tag())]
        if got != want:
            raise ValueError(
                f"checkpoint precision mismatch: step {step} was saved "
                f"under {_decode_precision_tag(got)} but this session "
                f"runs {_decode_precision_tag(want)} — recreate the "
                "session with the matching precision= (or re-train)")
        return dataclasses.replace(self, state=payload["state"]), step

    # -- the transaction and its halves ------------------------------------
    def step(self, key, user_ids, contexts, reward_fn):
        return step(self, key, user_ids, contexts, reward_fn)

    def recommend(self, user_ids, contexts):
        return recommend(self, user_ids, contexts)

    def step_catalog(self, key, user_ids, catalog, reward_fn, *,
                     k_short: int = 64, clusters=None):
        return step_catalog(self, key, user_ids, catalog, reward_fn,
                            k_short=k_short, clusters=clusters)

    def recommend_catalog(self, user_ids, catalog, *, k_short: int = 64,
                          clusters=None):
        return recommend_catalog(self, user_ids, catalog, k_short=k_short,
                                 clusters=clusters)

    def observe(self, user_ids, contexts, choices, rewards, key=None):
        return observe(self, user_ids, contexts, choices, rewards, key=key)

    def observe_delayed(self, decision_ids, rewards, key=None,
                        catalog=None):
        return observe_delayed(self, decision_ids, rewards, key=key,
                               catalog=catalog)

    def reset_pending(self):
        return reset_pending(self)

    def refresh(self, key=None):
        return refresh(self, key=key)


def step(session: OnlineBandit, key, user_ids, contexts,
         reward_fn: Callable):
    """One jit-compiled serving transaction.

    `user_ids [B] i32` (ids < 0 or >= n_users are ignored — padding),
    `contexts [B, K, d]`, `reward_fn(key, user_ids, contexts, choices)`
    returning realized rewards `[B]` or the full environment 4-tuple
    `(realized, expected, best, rand)`.  Returns
    `(session, choices [B], metrics)` — `metrics` rows for terms the
    reward_fn didn't supply are zero.  `key` drives the reward draw
    as-given (and, folded, the dccb gossip refresh)."""
    fn = _step_fn(session.policy, reward_fn, session.mesh, session.axes)
    state, choices, metrics = fn(session.state, key, user_ids, contexts)
    return dataclasses.replace(session, state=state), choices, metrics


def _pending_guard(session: OnlineBandit, B: int):
    cap = session.pending.uid.shape[0]
    if B > cap:
        raise ValueError(
            f"pending capacity {cap} < batch width {B}: a batch of "
            "consecutive decision ids must land on distinct ring slots — "
            "create the session with pending_capacity >= the largest "
            "request batch")


def recommend(session: OnlineBandit, user_ids, contexts):
    """The request half: choices `[B]` for a batch.

    On a synchronous session (no pending buffer) this is pure — returns
    just `choices [B]`.  On a buffer-enabled session it ISSUES: returns
    `(session, choices [B], decision_ids [B])`, enqueuing one pending
    decision per valid request (padding requests get decision id -1);
    feed the ids to :func:`observe_delayed` when feedback arrives."""
    if session.pending is None:
        fn = _recommend_fn(session.policy, session.mesh, session.axes)
        return fn(session.state, user_ids, contexts)
    _pending_guard(session, user_ids.shape[0])
    fn = _issue_fn(session.policy, session.ttl, session.mesh, session.axes)
    pend, choices, ids = fn(session.state, session.pending, user_ids,
                            contexts)
    return dataclasses.replace(session, pending=pend), choices, ids


def observe(session: OnlineBandit, user_ids, contexts, choices, rewards,
            key=None):
    """The feedback half: fold a batch of (possibly duplicate-user)
    rewards and run the refresh schedule.  `key` is only consumed by the
    dccb gossip refresh (defaults to a fixed key)."""
    if key is None:
        key = jax.random.PRNGKey(0)
    fn = _observe_fn(session.policy, session.mesh, session.axes)
    state = fn(session.state, key, user_ids, contexts, choices, rewards)
    return dataclasses.replace(session, state=state)


def _retrieval_engine(session: OnlineBandit, k_short: int):
    """The session's retrieval backend: dispatch (kind/interpret) follows
    the run-level interact engine, resolved once per (session, k_short)."""
    eng = session.policy.cfg.engine
    return BackendConfig(kind=eng.kind, precision=eng.precision).retrieval(
        eng.d, k_short, interpret=eng.interpret)


def step_catalog(session: OnlineBandit, key, user_ids, catalog,
                 reward_fn: Callable, *, k_short: int = 64, clusters=None):
    """One serving transaction against a persistent catalog.

    Like :func:`step`, but the slate is not supplied by the caller — it
    is retrieved: each user's ``k_short`` highest-UCB live items are
    shortlisted by the streaming top-K engine (per item shard on a
    sharded session) and the fused choose ranks the shortlist.

    ``catalog`` is a ``core.catalog.Catalog``; on a sharded session it
    must be device_put item-sharded over the session mesh
    (``catalog.specs(axes)``) with ``capacity % shards == 0``.
    ``reward_fn(key, user_ids, contexts, choice)`` sees the
    ``[B, k_short, d]`` shortlist slate and the chosen SLOT — the same
    contract as :func:`step` — so regret terms are relative to the
    shortlist's best.  Returns ``(session, item_ids [B], metrics)`` with
    GLOBAL catalog ids (-1 for padded requests).

    ``clusters`` — a ``core.itemclub.ItemClusters`` built from this
    catalog enables CLUSTER-PRUNED retrieval: item tiles whose UCB upper
    bound cannot beat the running shortlist floor are skipped, with the
    chosen items BIT-IDENTICAL to the unpruned path.  A stale table
    (``clusters.epoch != catalog.epoch`` after a `publish`) falls back
    to the unpruned stream inside the transaction — rebuild on the
    stage-2 cadence with ``itemclub.refresh_clusters``.  The return
    gains a trailing ``RetrievalMetrics`` (tile skip counts +
    ``pruned_active``).  The cluster tables are replicated — pass them
    as-is on a sharded session (``capacity % (tile_items * shards)``
    must be 0).
    """
    rb = _retrieval_engine(session, k_short)
    fn = _catalog_step_fn(session.policy, rb, reward_fn, session.mesh,
                          session.axes, clusters is not None)
    if clusters is None:
        state, item_ids, metrics = fn(session.state, key, user_ids,
                                      catalog)
        return dataclasses.replace(session, state=state), item_ids, metrics
    state, item_ids, metrics, rmet = fn(session.state, key, user_ids,
                                        catalog, clusters)
    return (dataclasses.replace(session, state=state), item_ids, metrics,
            rmet)


def recommend_catalog(session: OnlineBandit, user_ids, catalog, *,
                      k_short: int = 64, clusters=None):
    """The request half against a catalog.

    On a synchronous session: no state change; returns
    ``(item_ids [B], slots [B], contexts [B, k_short, d])`` — feed
    ``(user_ids, contexts, slots, rewards)`` to :func:`observe` to fold
    the feedback, exactly as with a caller-supplied slate.

    On a buffer-enabled session it ISSUES: returns
    ``(session, item_ids [B], decision_ids [B], slots [B],
    contexts [B, k_short, d])`` — the buffer already holds the chosen
    context each decision needs, so only ``(decision_ids, rewards)`` go
    to :func:`observe_delayed`; slots/contexts are returned for reward
    models that score the served slate.

    ``clusters`` enables cluster-pruned retrieval exactly as in
    :func:`step_catalog` (same exactness + stale-epoch fallback) and
    appends a ``RetrievalMetrics`` to either return shape."""
    rb = _retrieval_engine(session, k_short)
    if session.pending is None:
        fn = _catalog_recommend_fn(session.policy, rb, session.mesh,
                                   session.axes, clusters is not None)
        if clusters is None:
            return fn(session.state, user_ids, catalog)
        return fn(session.state, user_ids, catalog, clusters)
    _pending_guard(session, user_ids.shape[0])
    fn = _catalog_issue_fn(session.policy, rb, session.ttl, session.mesh,
                           session.axes, clusters is not None)
    if clusters is None:
        pend, items, ids, slots, ctx = fn(session.state, session.pending,
                                          user_ids, catalog)
        return (dataclasses.replace(session, pending=pend), items, ids,
                slots, ctx)
    pend, items, ids, slots, ctx, rmet = fn(
        session.state, session.pending, user_ids, catalog, clusters)
    return (dataclasses.replace(session, pending=pend), items, ids, slots,
            ctx, rmet)


def observe_delayed(session: OnlineBandit, decision_ids, rewards,
                    key=None, catalog=None):
    """Fold a batch of delayed feedback matched by decision id.

    ``decision_ids [B] i32`` (id -1 = padding), ``rewards [B]`` realized
    rewards aligned with the ids.  Matching is exact under out-of-order
    and duplicate delivery: a folded decision's slot is freed, so
    re-delivery counts ``unmatched`` and never double-folds; feedback for
    TTL-expired decisions is dropped.  Runs the same refresh schedule as
    :func:`observe` (``key`` drives the dccb gossip draw).  Returns the
    updated session; read counters via :func:`pending_stats`.

    ``catalog`` — pass the CURRENT ``core.catalog.Catalog`` on a
    catalog-serving session and churned-item feedback is QUARANTINED:
    a matched decision folds only if its item survived in the active
    bank (live, ``born`` no later than issue) and the published epoch is
    at most one past its issue epoch; anything else frees the slot and
    counts ``stale`` instead.  Without it, feedback folds regardless of
    churn — correct for slate sessions, corrupt under catalog churn
    (the bug the quarantine formalizes).  At zero churn both paths are
    bit-identical."""
    if session.pending is None:
        raise ValueError(
            "observe_delayed needs a buffer-enabled session — create it "
            "with pending_capacity > 0")
    if key is None:
        key = jax.random.PRNGKey(0)
    if catalog is None:
        fn = _observe_delayed_fn(session.policy, session.mesh,
                                 session.axes)
        state, pend = fn(session.state, session.pending, key,
                         decision_ids, rewards)
    else:
        fn = _observe_delayed_catalog_fn(session.policy, session.mesh,
                                         session.axes)
        state, pend = fn(session.state, session.pending, key,
                         decision_ids, rewards, catalog)
    return dataclasses.replace(session, state=state, pending=pend)


def reset_pending(session: OnlineBandit) -> OnlineBandit:
    """Free every pending slot but keep the id counter monotone — used
    after a guardrail rollback so stale in-flight feedback can never
    alias a post-rollback decision."""
    if session.pending is None:
        return session
    return dataclasses.replace(session,
                               pending=pending_mod.clear(session.pending))


def pending_stats(session: OnlineBandit) -> dict[str, float]:
    """Host-side pending-buffer counters (occupancy, matched, unmatched,
    expired, dropped, ...); empty dict on a synchronous session."""
    if session.pending is None:
        return {}
    return pending_mod.stats(session.pending)


def refresh(session: OnlineBandit, key=None):
    """Force one refresh now (stage-2 for the clustered policies, a
    gossip round for dccb, a no-op for linucb) and reset the budget."""
    if key is None:
        key = jax.random.PRNGKey(0)
    fn = _force_refresh_fn(session.policy, session.mesh, session.axes)
    return dataclasses.replace(session, state=fn(session.state, key))
