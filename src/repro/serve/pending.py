"""The pending-decision ring buffer: recommend now, fold feedback later.

Real traffic never returns a reward inside the transaction that issued
the recommendation — feedback arrives late, out of order, duplicated, or
not at all.  The :class:`PendingBuffer` is the device-resident state that
bridges the two halves: ``serve.recommend`` on a buffer-enabled session
issues choices AND enqueues one decision per valid request —
``(uid, choice, x digest, decision id, deadline)`` — into a
fixed-capacity ring; ``serve.observe_delayed`` folds whatever feedback
has arrived, matched by decision id, whenever it arrives.

Layout and semantics
  * slot ``decision_id % capacity`` holds the decision — decision ids are
    a monotone i32 counter, so a batch of ``B <= capacity`` consecutive
    ids always lands on distinct slots (the session enforces the width).
  * ``x`` is the CHOSEN context row the feedback fold needs — the exact
    psum-combined ``[d]`` vector the synchronous ``step`` would fold —
    so a delayed fold is bit-identical to the synchronous one.
  * the ``clock`` ticks once per issue transaction; a decision issued at
    clock ``c`` with TTL ``t`` carries ``deadline = c + t`` and is
    dropped (slot freed, ``expired`` counted) at the first issue whose
    clock exceeds the deadline — i.e. it survives exactly ``t``
    subsequent ``recommend`` transactions.
  * capacity backpressure: enqueuing onto a slot that still holds an
    unmatched, unexpired decision evicts it (``dropped`` counted) — the
    ring never blocks the serving path.
  * duplicate delivery: a matched slot is cleared, so a second delivery
    of the same decision id finds no resident decision and is counted
    ``unmatched`` — never folded twice.  Duplicates INSIDE one feedback
    batch fold only their first occurrence.
  * catalog churn: every decision records the catalog ``epoch`` it was
    issued at.  When the serving layer passes a staleness mask to
    :func:`match` (``serve.observe_delayed(..., catalog=...)``), matched
    feedback whose item churned since issue — retired, slot re-claimed,
    or more than ONE epoch behind the published catalog — is QUARANTINED:
    the slot is freed and the entry counted ``stale``, never folded.

Conservation identity (asserted by the churn fault suite): every issued
decision resolves exactly once —

    issued == matched + in_flight + expired + dropped + stale

Every array is replicated on a sharded session (:func:`specs`): the
enqueue consumes the psum-combined choice/context, so all shards hold
byte-identical buffers and the fold re-derives ownership per shard
exactly like the synchronous path.  All counters are lifetime totals.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

try:  # PartitionSpec only needed for the sharded binding
    from jax.sharding import PartitionSpec as P
except ImportError:  # pragma: no cover
    P = None


class PendingBuffer(NamedTuple):
    uid: jnp.ndarray        # [C] i32 user id of the decision (-1 = free)
    choice: jnp.ndarray     # [C] i32 chosen slate slot / global item id
    x: jnp.ndarray          # [C, d] f32 chosen context (the fold digest)
    decision: jnp.ndarray   # [C] i32 resident decision id (-1 = free)
    deadline: jnp.ndarray   # [C] i32 last clock at which feedback folds
    epoch: jnp.ndarray      # [C] i32 catalog epoch the decision issued at
    next_id: jnp.ndarray    # [] i32 monotone decision-id counter
    clock: jnp.ndarray      # [] i32 issue-transaction counter
    issued: jnp.ndarray     # [] i32 VALID decisions enqueued (no padding)
    expired: jnp.ndarray    # [] i32 decisions dropped on TTL
    dropped: jnp.ndarray    # [] i32 decisions evicted by backpressure
    matched: jnp.ndarray    # [] i32 feedback entries folded
    unmatched: jnp.ndarray  # [] i32 feedback with no resident decision
    stale: jnp.ndarray      # [] i32 feedback quarantined (item churned)

    @property
    def capacity(self) -> int:
        return self.uid.shape[0]


def init(capacity: int, d: int) -> PendingBuffer:
    if capacity <= 0:
        raise ValueError(f"pending capacity must be positive, got {capacity}")
    z = jnp.zeros((), jnp.int32)
    return PendingBuffer(
        uid=jnp.full((capacity,), -1, jnp.int32),
        choice=jnp.full((capacity,), -1, jnp.int32),
        x=jnp.zeros((capacity, d), jnp.float32),
        decision=jnp.full((capacity,), -1, jnp.int32),
        deadline=jnp.zeros((capacity,), jnp.int32),
        epoch=jnp.zeros((capacity,), jnp.int32),
        next_id=z, clock=z, issued=z, expired=z, dropped=z, matched=z,
        unmatched=z, stale=z,
    )


def specs() -> PendingBuffer:
    """Replicated PartitionSpecs — the buffer is identical on every
    shard (it only ever consumes psum-combined values)."""
    return PendingBuffer(*(P() for _ in PendingBuffer._fields))


def clear(p: PendingBuffer) -> PendingBuffer:
    """Free every slot but KEEP ``next_id``/``clock``/counters — used by
    guardrail rollback, where in-flight feedback issued before the
    rollback must stay unmatchable (a reset id counter would let stale
    feedback alias fresh decisions)."""
    return p._replace(
        uid=jnp.full_like(p.uid, -1),
        decision=jnp.full_like(p.decision, -1),
    )


def in_flight(p: PendingBuffer) -> jnp.ndarray:
    return jnp.sum((p.uid >= 0).astype(jnp.int32))


def issue(p: PendingBuffer, uids: jnp.ndarray, choices: jnp.ndarray,
          x: jnp.ndarray, valid: jnp.ndarray, ttl: int,
          epoch: jnp.ndarray | None = None
          ) -> tuple[PendingBuffer, jnp.ndarray]:
    """Tick the clock, expire overdue decisions, enqueue the batch.

    Returns ``(buffer, decision_ids [B] i32)`` — padding requests
    (``valid`` False) consume an id but are not enqueued and return -1.
    ``ttl`` is static (part of the session's compiled-transaction key);
    ``epoch`` is the catalog epoch the batch was issued at (scalar i32;
    None — the slate path — records 0).
    """
    B = uids.shape[0]
    C = p.uid.shape[0]
    if epoch is None:
        epoch = jnp.zeros((), jnp.int32)
    clock = p.clock + 1
    overdue = (p.uid >= 0) & (p.deadline < clock)
    p = p._replace(
        uid=jnp.where(overdue, -1, p.uid),
        decision=jnp.where(overdue, -1, p.decision),
        clock=clock,
        expired=p.expired + jnp.sum(overdue.astype(jnp.int32)),
    )
    ids = p.next_id + jnp.arange(B, dtype=jnp.int32)
    slot = jnp.mod(ids, C)
    evict = valid & (p.uid[slot] >= 0)
    tgt = jnp.where(valid, slot, C)                  # drop padding writes
    return p._replace(
        uid=p.uid.at[tgt].set(uids, mode="drop"),
        choice=p.choice.at[tgt].set(choices, mode="drop"),
        x=p.x.at[tgt].set(x, mode="drop"),
        decision=p.decision.at[tgt].set(ids, mode="drop"),
        deadline=p.deadline.at[tgt].set(clock + ttl, mode="drop"),
        epoch=p.epoch.at[tgt].set(epoch, mode="drop"),
        next_id=p.next_id + B,
        issued=p.issued + jnp.sum(valid.astype(jnp.int32)),
        dropped=p.dropped + jnp.sum(evict.astype(jnp.int32)),
    ), jnp.where(valid, ids, -1)


def match(p: PendingBuffer, ids: jnp.ndarray,
          stale: jnp.ndarray | None = None
          ) -> tuple[PendingBuffer, jnp.ndarray, jnp.ndarray]:
    """Match a feedback batch by decision id and free the matched slots.

    Returns ``(buffer, uids [B] i32, x [B, d])`` ready for the session's
    duplicate-safe fold — entries that matched nothing (lost to TTL,
    already folded, duplicated inside the batch, or id -1 padding) come
    back with uid -1, which the fold treats as padding.

    ``stale [B]`` bool (from the serving layer's per-decision epoch/live
    check) QUARANTINES: a matched-but-stale entry frees its slot and
    counts ``stale`` instead of ``matched``, and surfaces as uid -1 so
    the fold never sees churned-item feedback.
    """
    C = p.uid.shape[0]
    if stale is None:
        stale = jnp.zeros(ids.shape, bool)
    slot = jnp.mod(jnp.where(ids >= 0, ids, 0), C)
    resident = (ids >= 0) & (p.decision[slot] == ids)
    # in-batch dedup: only the FIRST occurrence of a decision id folds
    eq = (ids[:, None] == ids[None, :]) & (ids >= 0)[:, None]
    first = jnp.sum(jnp.tril(eq, k=-1), axis=1) == 0
    hit = resident & first
    fold = hit & ~stale
    quarantined = hit & stale
    uids = jnp.where(fold, p.uid[slot], -1)
    x = p.x[slot]
    tgt = jnp.where(hit, slot, C)         # stale slots free too
    p = p._replace(
        uid=p.uid.at[tgt].set(-1, mode="drop"),
        decision=p.decision.at[tgt].set(-1, mode="drop"),
        matched=p.matched + jnp.sum(fold.astype(jnp.int32)),
        stale=p.stale + jnp.sum(quarantined.astype(jnp.int32)),
        unmatched=p.unmatched
        + jnp.sum(((ids >= 0) & ~hit).astype(jnp.int32)),
    )
    return p, uids, x


def conservation_gap(p: PendingBuffer) -> int:
    """issued - (matched + in_flight + expired + dropped + stale); zero
    iff every issued decision is accounted for exactly once.  The churn
    fault suite asserts this after every delivery."""
    resolved = p.matched + in_flight(p) + p.expired + p.dropped + p.stale
    return int(p.issued - resolved)


def stats(p: PendingBuffer) -> dict[str, float]:
    """Host-side counter snapshot (guardrails read ``occupancy``).
    ``issued`` counts VALID enqueued decisions (padding consumes an id
    but is never enqueued), so the conservation identity
    ``issued == matched + in_flight + expired + dropped + stale`` holds
    exactly on every buffer."""
    cap = p.capacity
    flight = int(in_flight(p))
    return {
        "capacity": cap,
        "in_flight": flight,
        "occupancy": flight / cap,
        "clock": int(p.clock),
        "issued": int(p.issued),
        "matched": int(p.matched),
        "unmatched": int(p.unmatched),
        "expired": int(p.expired),
        "dropped": int(p.dropped),
        "stale": int(p.stale),
    }
