"""Online experimentation: sticky traffic splitting over policy arms.

An :class:`Experiment` runs N arms — each a ``serve.OnlineBandit`` with
its OWN session state (any mix of distclub / dccb / club / linucb, or
the same policy under different hypers) — behind one request stream:

    arms = [serve.OnlineBandit.create(n, d, hyper, policy=p,
                                      pending_capacity=256)
            for p in ("distclub", "dccb", "linucb")]
    exp = experiments.create(arms, fractions=(0.34, 0.33, 0.33),
                             selector=experiments.make_selector(3),
                             guard_cfg=GuardrailConfig(ctr_floor=0.3))
    exp, choices, ids = experiments.recommend(exp, user_ids, contexts)
    ...
    exp = experiments.observe_delayed(exp, ids, rewards)

Sticky assignment: each user id hashes (salted, lowbias32) to a point on
the unit interval; arm a owns ``[cum_frac[a-1], cum_frac[a])``.  The
hash never changes, so assignment is DETERMINISTIC and STABLE under
fraction changes — shrinking an arm's share migrates exactly the users
whose hash falls in the surrendered sub-interval, and nobody else; a
user never silently migrates mid-experiment.  ``uid < 0`` padding maps
to arm -1 and flows through every arm as padding, exactly as in a plain
session.

Routing: the batch is partitioned by masking — arm a sees the SAME
full-width batch with non-assigned requests padded to uid -1 (the
serving transactions' existing padding convention), runs its own
unmodified compiled ``step`` / ``step_catalog`` / ``recommend`` /
``observe_delayed`` transaction, and the per-arm choices are merged back
in request order.  A single-arm experiment at fraction 1.0 is therefore
BIT-IDENTICAL to the plain session — same transaction, same inputs,
single-host and sharded (``tests/test_experiments.py``).

Decision ids are arm-encoded: ``global = local * n_arms + arm``, so
delayed feedback routes itself — ``observe_delayed`` decodes the arm and
folds each sub-batch through that arm's own pending ring.  With one arm
the encoding is the identity.

Thompson-sampling meta-selector (per CineaMate's BANDIT_SELECTOR.md): a
Beta(alpha, beta) posterior per (context bucket, arm) — success = click
(reward > 0), failure otherwise; optional cold_start / regular /
power_user buckets split by the user's lifetime interaction count.
Traffic fractions move ONLY at epoch boundaries (every
``epoch_rounds`` routing transactions): the win-probability of each arm
is estimated by Monte-Carlo argmax over posterior draws, floored at
``floor`` per enabled arm, renormalized.  Between boundaries assignment
is frozen — stickiness is the product surface, the posterior is the
learner.

Per-arm guardrails: pass ``guard_cfg`` and every arm runs its own
``serve.guardrails`` monitor chain (CTR floor, ring occupancy, latency).
A breaching arm is AUTO-DISABLED: its traffic re-routes to the surviving
arms (same hash, renormalized enabled fractions — survivors keep every
user they already had), its state rolls back to its last healthy
snapshot, and its pending ring is cleared; the experiment keeps serving.
The last enabled arm is never disabled.

Checkpoint/restore: :func:`save` / :func:`restore` round-trip arm states
+ pending rings + rollback snapshots + selector posteriors + the
assignment salt/fractions through ``train.checkpoint.CheckpointManager``
— a restored experiment resumes bit-identical routing and choices.

:func:`run_experiment` drives the whole stack over the SAME seeded
keyed traffic stream as ``serve.faults`` (one shared
``faults.TrafficStream``) with the same delivery-fault machinery, and
:func:`report` emits the :class:`ExperimentReport` — per-arm
reward/regret/matched ratios, traffic shares over time, and the
sequential z-statistic for the leading pair.  CLI:
``python -m repro.launch.abrun``.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core import env as bandit_env
from . import faults as faults_mod
from . import guardrails as guardrails_mod
from . import session as session_mod

# ---------------------------------------------------------------------------
# sticky assignment
# ---------------------------------------------------------------------------


def _hash01(user_ids: jnp.ndarray, salt) -> jnp.ndarray:
    """Deterministic uid -> [0, 1) point (lowbias32 integer mix; the top
    24 bits keep the value exact in f32).  Pure function of (uid, salt):
    the experiment's entire routing stability rests on this never
    depending on fractions, round, or arm count."""
    x = user_ids.astype(jnp.uint32) ^ jnp.uint32(salt)
    x = (x ^ (x >> 16)) * jnp.uint32(0x7FEB352D)
    x = (x ^ (x >> 15)) * jnp.uint32(0x846CA68B)
    x = x ^ (x >> 16)
    return (x >> 8).astype(jnp.float32) * jnp.float32(1.0 / (1 << 24))


@jax.jit
def _assign(user_ids, fractions, enabled, salt, scale):
    """arm[B] i32 (-1 = padding).  Primary assignment cuts the unit
    interval at the cumulative fractions; requests landing on a disabled
    arm fall through to the ENABLED-renormalized cut with the same hash
    point, so survivors keep every user they already had.

    ``scale[a] < 1`` is the PROBATION throttle: the arm accepts only the
    leading ``scale`` sub-interval of its own primary interval (measured
    by the same hash point — the accepted set is a stable prefix, and it
    grows back to the full interval when the arm is restored).  Rejected
    positions fall through to the secondary cut exactly like a disabled
    arm's users, and the secondary cut spans only FULL-scale enabled
    arms — so an arm entering or leaving probation never moves a single
    user owned by a healthy survivor."""
    h = _hash01(user_ids, salt)
    f = fractions.astype(jnp.float32)
    cumf = jnp.cumsum(f)
    cum = cumf.at[-1].set(jnp.inf)               # last arm absorbs rounding
    primary = jnp.searchsorted(cum, h, side="right").astype(jnp.int32)
    lo = cumf - f
    pos = (h - lo[primary]) / jnp.maximum(f[primary], 1e-9)
    take = enabled[primary] & ((scale[primary] >= 1.0)
                               | (pos < scale[primary]))
    full = enabled & (scale >= 1.0)
    # all enabled arms throttled (pathological): fall back to enabled set
    full = jnp.where(jnp.any(full), full, enabled)
    f2 = jnp.where(full, f, 0.0)
    f2 = f2 / jnp.maximum(jnp.sum(f2), 1e-9)
    cum2 = jnp.cumsum(f2).at[-1].set(jnp.inf)
    secondary = jnp.searchsorted(cum2, h, side="right").astype(jnp.int32)
    arm = jnp.where(take, primary, secondary)
    return jnp.where(user_ids >= 0, arm, -1)


def assign_arms(exp_or_uids, fractions=None, enabled=None, salt=0,
                scale=None):
    """Sticky arm per request: ``assign_arms(exp, user_ids)`` or the raw
    form ``assign_arms(user_ids, fractions, enabled, salt[, scale])``."""
    if isinstance(exp_or_uids, Experiment):
        exp, uids = exp_or_uids, fractions
        return _assign(jnp.asarray(uids),
                       jnp.asarray(exp.fractions, jnp.float32),
                       jnp.asarray(exp.enabled), jnp.uint32(exp.salt),
                       jnp.asarray(_arm_scales(exp), jnp.float32))
    n = len(fractions)
    sc = jnp.ones((n,), jnp.float32) if scale is None \
        else jnp.asarray(scale, jnp.float32)
    return _assign(jnp.asarray(exp_or_uids),
                   jnp.asarray(fractions, jnp.float32),
                   jnp.asarray(enabled), jnp.uint32(salt), sc)


# ---------------------------------------------------------------------------
# the Thompson-sampling meta-selector
# ---------------------------------------------------------------------------


class TSSelector(NamedTuple):
    """Beta posteriors per (context bucket, arm) + the re-weighting
    policy.  ``bucket_edges`` splits users by lifetime interaction count
    — ``(3, 21)`` gives the CineaMate cold_start (<3) / regular (3..20) /
    power_user (>20) buckets; ``()`` is one pooled bucket."""

    alpha: Any                  # np [n_buckets, n_arms]
    beta: Any                   # np [n_buckets, n_arms]
    floor: float = 0.05         # minimum enabled-arm traffic fraction
    epoch_rounds: int = 50      # routing transactions between re-weights
    bucket_edges: tuple = ()
    samples: int = 512          # MC draws for the win-probability


def make_selector(n_arms: int, *, floor: float = 0.05,
                  epoch_rounds: int = 50, bucket_edges: tuple = (),
                  samples: int = 512,
                  prior: tuple = (1.0, 1.0)) -> TSSelector:
    """Uniform Beta(1, 1) posteriors (CineaMate's prior) over
    ``len(bucket_edges) + 1`` context buckets."""
    nb = len(bucket_edges) + 1
    return TSSelector(
        alpha=np.full((nb, n_arms), float(prior[0])),
        beta=np.full((nb, n_arms), float(prior[1])),
        floor=float(floor), epoch_rounds=int(epoch_rounds),
        bucket_edges=tuple(bucket_edges), samples=int(samples))


def _buckets_of(sel: TSSelector, counts: np.ndarray) -> np.ndarray:
    if not sel.bucket_edges:
        return np.zeros_like(counts, dtype=np.int64)
    return np.searchsorted(np.asarray(sel.bucket_edges), counts,
                           side="right")


def _posterior_update(sel: TSSelector, buckets, arms, rewards, valid):
    """success = reward > 0 (a click), failure otherwise — corrupted
    (sign-flipped) deliveries therefore count as failures, which is what
    the serving system actually observed."""
    a2, b2 = sel.alpha.copy(), sel.beta.copy()
    succ = np.clip(np.asarray(rewards, np.float64), 0.0, 1.0)
    m = np.asarray(valid, bool)
    np.add.at(a2, (buckets[m], arms[m]), succ[m])
    np.add.at(b2, (buckets[m], arms[m]), 1.0 - succ[m])
    return sel._replace(alpha=a2, beta=b2)


def _reweight(sel: TSSelector, enabled, salt: int, epoch: int) -> tuple:
    """Epoch-boundary fractions: per bucket, P(arm is the argmax of one
    posterior draw) by Monte Carlo; buckets pooled by observation count;
    floored at ``sel.floor`` per enabled arm and renormalized.  Seeded by
    (salt, epoch) so a restored experiment replays the same schedule."""
    rng = np.random.default_rng([int(salt) & 0xFFFFFFFF, int(epoch),
                                 0x7E57])
    en = np.asarray(enabled, bool)
    nb, A = sel.alpha.shape
    wins = np.zeros(A)
    weights = 0.0
    for b in range(nb):
        draws = rng.beta(sel.alpha[b], sel.beta[b], size=(sel.samples, A))
        draws = np.where(en[None, :], draws, -np.inf)
        share = (np.bincount(np.argmax(draws, axis=1), minlength=A)
                 / sel.samples)
        w = float(np.sum(sel.alpha[b] + sel.beta[b])) + 1e-9
        wins += w * share
        weights += w
    p = wins / weights
    p = np.where(en, np.maximum(p, sel.floor), 0.0)
    p = p / p.sum()
    return tuple(float(x) for x in p)


# ---------------------------------------------------------------------------
# the experiment container
# ---------------------------------------------------------------------------


def _zero_totals(n_arms: int) -> dict:
    return {k: np.zeros(n_arms)
            for k in ("reward", "expected", "best", "rand", "interactions",
                      "delivered")}


@dataclasses.dataclass(frozen=True)
class Experiment:
    """N arm sessions + routing state.  Immutable like the sessions it
    wraps — every transaction returns a new Experiment."""

    arms: tuple                 # OnlineBandit per arm
    names: tuple
    fractions: tuple            # configured/selector split over ALL arms
    enabled: tuple              # per-arm bool; disabled = breached
    salt: int
    selector: Any = None        # TSSelector | None
    guard_cfg: Any = None       # guardrails.GuardrailConfig | None
    guards: tuple = ()          # guardrails.GuardrailState per arm
    snapshots: tuple = ()       # per-arm rollback anchor (state pytree)
    snapshot_every: int = 16    # routing txs between anchor refreshes
    steps: int = 0              # routing transactions so far
    epoch: int = 0              # selector epochs completed
    shares: tuple = ()          # ((step, fractions), ...) over time
    counts: Any = None          # np [n_users] lifetime interaction counts
    totals: Any = None          # per-arm accounting (np [n_arms] each)
    events: tuple = ()          # ("disable", step, name, breaches) etc.
    probation_tx: int = 0       # txs a breached arm sits out; 0 = forever
    probation_fraction: float = 0.25   # throttled share while on probation
    stages: tuple = ()          # per-arm: HEALTHY/BENCHED/PROBATION/PERMANENT
    stage_since: tuple = ()     # step the arm entered its current stage

    @property
    def n_arms(self) -> int:
        return len(self.arms)


# probation life-cycle stages (per arm)
HEALTHY = 0      # serving its full interval
BENCHED = 1      # breached; sitting out the probation window
PROBATION = 2    # re-enabled at probation_fraction of its own interval
PERMANENT = 3    # breached ON probation — never re-enabled


def _arm_scales(exp: "Experiment") -> np.ndarray:
    """Per-arm accepted share of its OWN primary interval (the probation
    throttle; 1.0 = full interval).  Disabled arms keep scale 0 so the
    raw-form assignment stays well-defined either way."""
    st = exp.stages or (HEALTHY,) * exp.n_arms
    return np.array(
        [0.0 if not en
         else (exp.probation_fraction if s == PROBATION else 1.0)
         for en, s in zip(exp.enabled, st)], np.float32)


def create(sessions, *, names=None, fractions=None, salt: int = 0,
           selector: TSSelector | None = None, guard_cfg=None,
           snapshot_every: int = 16, probation_tx: int = 0,
           probation_fraction: float = 0.25) -> Experiment:
    """Wrap ``sessions`` (each its own ``OnlineBandit``) as experiment
    arms.  All arms must serve the same user/context universe
    (equal ``n_users`` and ``d``).  ``fractions`` defaults to uniform.

    ``probation_tx > 0`` enables the probation window: a guardrail-
    disabled arm sits out ``probation_tx`` routing transactions, then
    re-enables THROTTLED to ``probation_fraction`` of its own sticky
    interval (survivors' users never move); a clean probation window of
    the same length restores it to full traffic, a second breach while
    on probation disables it permanently.  ``probation_tx = 0`` keeps
    the historical behavior — every disable is permanent."""
    arms = tuple(sessions)
    if not arms:
        raise ValueError("an experiment needs at least one arm")
    A = len(arms)
    cfg0 = arms[0].policy.cfg
    for s in arms[1:]:
        c = s.policy.cfg
        if (c.n_users, c.d) != (cfg0.n_users, cfg0.d):
            raise ValueError("every arm must share (n_users, d): "
                             f"{(c.n_users, c.d)} vs "
                             f"{(cfg0.n_users, cfg0.d)}")
    if names is None:
        names = []
        for i, s in enumerate(arms):
            n = s.policy.name
            names.append(n if n not in names else f"{n}#{i}")
    names = tuple(names)
    if fractions is None:
        fractions = (1.0 / A,) * A
    fractions = tuple(float(f) for f in fractions)
    if len(fractions) != A or any(f < 0 for f in fractions):
        raise ValueError(f"need {A} non-negative fractions")
    tot = sum(fractions)
    if tot <= 0:
        raise ValueError("fractions sum to zero")
    fractions = tuple(f / tot for f in fractions)
    if selector is not None and selector.alpha.shape[1] != A:
        raise ValueError(f"selector is over {selector.alpha.shape[1]} "
                         f"arms, experiment has {A}")
    if not 0.0 < float(probation_fraction) <= 1.0:
        raise ValueError("probation_fraction must be in (0, 1]")
    return Experiment(
        arms=arms, names=names, fractions=fractions, enabled=(True,) * A,
        salt=int(salt), selector=selector, guard_cfg=guard_cfg,
        guards=(guardrails_mod.GuardrailState(),) * A,
        snapshots=tuple(s.state for s in arms),
        snapshot_every=int(snapshot_every),
        counts=np.zeros(cfg0.n_users, np.int64),
        totals=_zero_totals(A), shares=((0, fractions),),
        probation_tx=int(probation_tx),
        probation_fraction=float(probation_fraction),
        stages=(HEALTHY,) * A, stage_since=(0,) * A)


# ---------------------------------------------------------------------------
# per-arm guardrails: admit -> maybe disable
# ---------------------------------------------------------------------------


def _disable_arm(exp: Experiment, a: int, breaches) -> Experiment:
    """Breached arm: roll its state back to its snapshot, clear its
    pending ring, and re-route its traffic (the assignment's
    enabled-fraction fallback).  The LAST enabled arm is never disabled
    — the breach is recorded and its monitors reset instead.

    With ``probation_tx > 0`` a first breach BENCHES the arm (eligible
    for a throttled comeback, see :func:`_advance`); a breach while ON
    probation disables it permanently."""
    guards = list(exp.guards)
    if sum(exp.enabled) <= 1:
        guards[a] = guardrails_mod.post_rollback_state(exp.guard_cfg,
                                                       guards[a])
        return dataclasses.replace(
            exp, guards=tuple(guards),
            events=exp.events + (("breach-last-arm", exp.steps,
                                  exp.names[a], breaches),))
    arms = list(exp.arms)
    sess = dataclasses.replace(arms[a], state=exp.snapshots[a])
    arms[a] = session_mod.reset_pending(sess)
    enabled = list(exp.enabled)
    enabled[a] = False
    guards[a] = guardrails_mod.post_rollback_state(exp.guard_cfg,
                                                   guards[a])
    stages = list(exp.stages or (HEALTHY,) * exp.n_arms)
    since = list(exp.stage_since or (0,) * exp.n_arms)
    on_probation = stages[a] == PROBATION
    stages[a] = (PERMANENT if on_probation or exp.probation_tx <= 0
                 else BENCHED)
    since[a] = exp.steps
    tag = "disable-permanent" if on_probation else "disable"
    return dataclasses.replace(
        exp, arms=tuple(arms), enabled=tuple(enabled), guards=tuple(guards),
        stages=tuple(stages), stage_since=tuple(since),
        events=exp.events + ((tag, exp.steps, exp.names[a], breaches),))


def _admit_arm(exp: Experiment, a: int, **sample) -> Experiment:
    if exp.guard_cfg is None:
        return exp
    gs = guardrails_mod.update(exp.guard_cfg, exp.guards[a], **sample)
    guards = list(exp.guards)
    guards[a] = gs
    exp = dataclasses.replace(exp, guards=tuple(guards))
    if gs.breaches:
        exp = _disable_arm(exp, a, gs.breaches)
    return exp


def _probation_tick(exp: Experiment, steps: int) -> Experiment:
    """Probation life-cycle transitions (no-op when ``probation_tx`` is
    0): a BENCHED arm that has sat out the window re-enables THROTTLED
    (``probation_fraction`` of its own sticky interval — the assignment's
    scale cut, so healthy survivors keep every user they own); an arm
    that stayed clean through a full probation window is restored."""
    if exp.probation_tx <= 0 or not exp.stages:
        return exp
    stages = list(exp.stages)
    since = list(exp.stage_since)
    enabled = list(exp.enabled)
    events = exp.events
    for a in range(exp.n_arms):
        waited = steps - since[a]
        if stages[a] == BENCHED and waited >= exp.probation_tx:
            enabled[a] = True
            stages[a] = PROBATION
            since[a] = steps
            events = events + (("probation", steps, exp.names[a]),)
        elif stages[a] == PROBATION and waited >= exp.probation_tx:
            stages[a] = HEALTHY
            since[a] = steps
            events = events + (("restore", steps, exp.names[a]),)
    return dataclasses.replace(
        exp, enabled=tuple(enabled), stages=tuple(stages),
        stage_since=tuple(since), events=events)


def _advance(exp: Experiment) -> Experiment:
    """Post-routing bookkeeping: refresh healthy rollback anchors, run
    the probation life-cycle, and at selector epoch boundaries re-weight
    the traffic fractions."""
    steps = exp.steps + 1
    exp = dataclasses.replace(exp, steps=steps)
    exp = _probation_tick(exp, steps)
    if (exp.guard_cfg is not None and exp.snapshot_every > 0
            and steps % exp.snapshot_every == 0):
        snaps = tuple(
            arm.state if en and not gs.cooldown_left else snap
            for arm, en, gs, snap in zip(exp.arms, exp.enabled, exp.guards,
                                         exp.snapshots))
        exp = dataclasses.replace(exp, snapshots=snaps)
    sel = exp.selector
    if sel is not None and steps % sel.epoch_rounds == 0:
        fr = _reweight(sel, exp.enabled, exp.salt, exp.epoch)
        exp = dataclasses.replace(
            exp, fractions=fr, epoch=exp.epoch + 1,
            shares=exp.shares + ((steps, fr),))
    return exp


def _note_counts(exp: Experiment, user_ids) -> Experiment:
    uids = np.asarray(user_ids)
    m = (uids >= 0) & (uids < exp.counts.shape[0])
    c = exp.counts.copy()
    np.add.at(c, uids[m], 1)
    return dataclasses.replace(exp, counts=c)


def _fold_totals(exp: Experiment, **per_arm) -> Experiment:
    t = {k: v.copy() for k, v in exp.totals.items()}
    for k, v in per_arm.items():
        t[k] = t[k] + np.asarray(v)
    return dataclasses.replace(exp, totals=t)


# ---------------------------------------------------------------------------
# the routing transactions
# ---------------------------------------------------------------------------


def step(exp: Experiment, key, user_ids, contexts, reward_fn):
    """One routed synchronous transaction: partition the batch by sticky
    arm, run each ENABLED arm's own compiled ``serve.step`` on the
    masked batch (non-assigned requests = uid -1 padding), merge choices
    in request order.  Returns ``(exp, choices [B], metrics)`` with
    ``metrics`` a per-arm tuple of ``Metrics``."""
    user_ids = jnp.asarray(user_ids)
    arm_of = assign_arms(exp, user_ids)
    arms = list(exp.arms)
    choices = jnp.zeros(user_ids.shape, jnp.int32)
    metrics = []
    samples = []
    for a in range(exp.n_arms):
        if not exp.enabled[a]:
            metrics.append(None)
            samples.append(None)
            continue
        uids_a = jnp.where(arm_of == a, user_ids, -1)
        t0 = time.perf_counter()
        arms[a], ch, m = session_mod.step(arms[a], key, uids_a, contexts,
                                          reward_fn)
        dt = time.perf_counter() - t0
        choices = jnp.where(arm_of == a, ch, choices)
        metrics.append(m)
        samples.append(dt)
    exp = dataclasses.replace(exp, arms=tuple(arms))
    exp = _note_counts(exp, jnp.where(arm_of >= 0, user_ids, -1))

    per_arm = {k: np.zeros(exp.n_arms) for k in
               ("reward", "expected", "best", "rand", "interactions")}
    sel = exp.selector
    for a, m in enumerate(metrics):
        if m is None:
            continue
        n = int(m.interactions)
        per_arm["reward"][a] = float(m.reward)
        per_arm["interactions"][a] = n
        if sel is not None and n > 0:
            # aggregate fold: the sync path has no per-request rewards
            # outside the jit, so successes pool into bucket 0
            a2, b2 = sel.alpha.copy(), sel.beta.copy()
            succ = min(max(float(m.reward), 0.0), float(n))
            a2[0, a] += succ
            b2[0, a] += n - succ
            sel = sel._replace(alpha=a2, beta=b2)
    exp = dataclasses.replace(exp, selector=sel)
    exp = _fold_totals(exp, **per_arm)
    if exp.guard_cfg is not None:
        for a, m in enumerate(metrics):
            if m is None:
                continue
            n = int(m.interactions)
            exp = _admit_arm(
                exp, a, ctr=(float(m.reward) / n if n > 0 else None),
                latency_s=samples[a],
                occupancy=guardrails_mod._occupancy(exp.arms[a]),
                interactions=n)
    return _advance(exp), choices, tuple(metrics)


def step_catalog(exp: Experiment, key, user_ids, catalog, reward_fn, *,
                 k_short: int = 64, clusters=None):
    """Routed catalog transaction: same partition/merge as :func:`step`
    over each arm's own ``serve.step_catalog``.  All arms serve the SAME
    catalog (read-only inside the transaction).  Returns
    ``(exp, item_ids [B], metrics)``; padded/unrouted rows get -1."""
    user_ids = jnp.asarray(user_ids)
    arm_of = assign_arms(exp, user_ids)
    arms = list(exp.arms)
    items = jnp.full(user_ids.shape, -1, jnp.int32)
    metrics = []
    samples = []
    for a in range(exp.n_arms):
        if not exp.enabled[a]:
            metrics.append(None)
            samples.append(None)
            continue
        uids_a = jnp.where(arm_of == a, user_ids, -1)
        t0 = time.perf_counter()
        out = session_mod.step_catalog(arms[a], key, uids_a, catalog,
                                       reward_fn, k_short=k_short,
                                       clusters=clusters)
        arms[a], it, m = out[0], out[1], out[2]
        dt = time.perf_counter() - t0
        items = jnp.where(arm_of == a, it, items)
        metrics.append(m)
        samples.append(dt)
    exp = dataclasses.replace(exp, arms=tuple(arms))
    exp = _note_counts(exp, jnp.where(arm_of >= 0, user_ids, -1))
    per_arm = {k: np.zeros(exp.n_arms) for k in ("reward", "interactions")}
    sel = exp.selector
    for a, m in enumerate(metrics):
        if m is None:
            continue
        n = int(m.interactions)
        per_arm["reward"][a] = float(m.reward)
        per_arm["interactions"][a] = n
        if sel is not None and n > 0:
            a2, b2 = sel.alpha.copy(), sel.beta.copy()
            succ = min(max(float(m.reward), 0.0), float(n))
            a2[0, a] += succ
            b2[0, a] += n - succ
            sel = sel._replace(alpha=a2, beta=b2)
    exp = dataclasses.replace(exp, selector=sel)
    exp = _fold_totals(exp, **per_arm)
    if exp.guard_cfg is not None:
        for a, m in enumerate(metrics):
            if m is None:
                continue
            n = int(m.interactions)
            exp = _admit_arm(
                exp, a, ctr=(float(m.reward) / n if n > 0 else None),
                latency_s=samples[a],
                occupancy=guardrails_mod._occupancy(exp.arms[a]),
                interactions=n)
    return _advance(exp), items, tuple(metrics)


def recommend(exp: Experiment, user_ids, contexts):
    """The routed request half on buffer-enabled arms: each enabled arm
    ISSUES on its masked sub-batch through its own pending ring.
    Returns ``(exp, choices [B], decision_ids [B])`` — ids are
    arm-encoded (``local * n_arms + arm``; -1 padding/unrouted), feed
    them back verbatim to :func:`observe_delayed`."""
    for s in exp.arms:
        if s.pending is None:
            raise ValueError("experiment recommend needs buffer-enabled "
                             "arms (create each with pending_capacity>0)")
    user_ids = jnp.asarray(user_ids)
    if exp.n_arms == 1 and exp.enabled[0]:
        # degenerate experiment: the sole arm owns every request, the
        # arm-encoding is the identity — skip the mask/merge entirely
        # (this is also what makes tx_vs_single_policy_ratio ~1)
        arms = list(exp.arms)
        t0 = time.perf_counter()
        arms[0], choices, ids = session_mod.recommend(arms[0], user_ids,
                                                      contexts)
        exp = dataclasses.replace(exp, arms=tuple(arms))
        if exp.guard_cfg is not None:
            exp = _admit_arm(
                exp, 0, latency_s=time.perf_counter() - t0,
                occupancy=guardrails_mod._occupancy(arms[0]))
        return _advance(exp), choices, ids
    arm_of = assign_arms(exp, user_ids)
    A = exp.n_arms
    arms = list(exp.arms)
    choices = jnp.zeros(user_ids.shape, jnp.int32)
    gids = jnp.full(user_ids.shape, -1, jnp.int32)
    for a in range(A):
        if not exp.enabled[a]:
            continue
        uids_a = jnp.where(arm_of == a, user_ids, -1)
        t0 = time.perf_counter()
        arms[a], ch, ids = session_mod.recommend(arms[a], uids_a, contexts)
        dt = time.perf_counter() - t0
        choices = jnp.where(arm_of == a, ch, choices)
        gids = jnp.where((arm_of == a) & (ids >= 0), ids * A + a, gids)
        if exp.guard_cfg is not None:   # guard samples cost a host sync
            exp = _admit_arm(exp, a, latency_s=dt,
                             occupancy=guardrails_mod._occupancy(arms[a]))
    exp = dataclasses.replace(exp, arms=tuple(arms))
    # lifetime counts advance in record_feedback (issue-time accounting)
    return _advance(exp), choices, gids


def observe_delayed(exp: Experiment, decision_ids, rewards, key=None):
    """Routed delayed-feedback fold: decode the arm from each decision id
    and fold the sub-batch through that arm's own
    ``serve.observe_delayed`` transaction.  Feedback for a disabled
    arm is dropped (its ring was cleared at disable time)."""
    if key is None:
        key = jax.random.PRNGKey(0)
    gids = jnp.asarray(decision_ids)
    A = exp.n_arms
    if A == 1 and exp.enabled[0]:
        arms = (session_mod.observe_delayed(exp.arms[0], gids, rewards,
                                            key=key),)
        n = int(np.asarray(gids >= 0).sum())
        if exp.guard_cfg is not None and n > 0:
            r = float(jnp.sum(jnp.where(gids >= 0, jnp.asarray(rewards),
                                        0.0)))
            exp = _admit_arm(
                exp, 0, ctr=r / n,
                occupancy=guardrails_mod._occupancy(arms[0]),
                interactions=n)
        exp = dataclasses.replace(exp, arms=arms)
        return _fold_totals(exp, delivered=np.asarray([n], np.float64))
    arm_of = jnp.where(gids >= 0, gids % A, -1)
    local = jnp.where(gids >= 0, gids // A, -1)
    arms = list(exp.arms)
    delivered = np.zeros(A)
    arm_np = np.asarray(arm_of)
    for a in range(A):
        if not exp.enabled[a]:
            continue
        if not bool((arm_np == a).any()):
            continue
        ids_a = jnp.where(arm_of == a, local, -1)
        arms[a] = session_mod.observe_delayed(arms[a], ids_a, rewards,
                                              key=key)
        n = int((arm_np == a).sum())
        delivered[a] = n
        if exp.guard_cfg is not None:   # guard samples cost a host sync
            r = float(jnp.sum(jnp.where(arm_of == a,
                                        jnp.asarray(rewards), 0.0)))
            exp = _admit_arm(
                exp, a, ctr=r / max(1, n),
                occupancy=guardrails_mod._occupancy(arms[a]),
                interactions=n)
    exp = dataclasses.replace(exp, arms=tuple(arms))
    return _fold_totals(exp, delivered=delivered)


def record_feedback(exp: Experiment, user_ids, arms, realized,
                    expected=None, best=None, rand=None,
                    learner_rewards=None) -> Experiment:
    """Issue-time accounting for a routed batch: fold per-request rewards
    into the per-arm totals and the selector posteriors (with TRUE
    context buckets — the uid is known here).  ``learner_rewards`` is
    what the system will actually deliver (possibly corrupted) and is
    what the posterior sees; it defaults to ``realized``."""
    arms = np.asarray(arms)
    valid = arms >= 0
    r = np.asarray(realized, np.float64)

    def tot(x):
        if x is None:
            return None
        return np.bincount(arms[valid],
                           weights=np.asarray(x, np.float64)[valid],
                           minlength=exp.n_arms)

    per_arm = {"reward": tot(r),
               "interactions": np.bincount(arms[valid],
                                           minlength=exp.n_arms)}
    for k, v in (("expected", expected), ("best", best), ("rand", rand)):
        t = tot(v)
        if t is not None:
            per_arm[k] = t
    exp = _fold_totals(exp, **per_arm)
    uids = np.asarray(user_ids)
    if exp.selector is not None:
        lr = r if learner_rewards is None else np.asarray(learner_rewards,
                                                          np.float64)
        cnt = np.where((uids >= 0) & (uids < exp.counts.shape[0]),
                       exp.counts[np.clip(uids, 0,
                                          exp.counts.shape[0] - 1)], 0)
        buckets = _buckets_of(exp.selector, cnt)
        exp = dataclasses.replace(
            exp, selector=_posterior_update(exp.selector, buckets, arms,
                                            lr, valid))
    # lifetime interaction counts (bucketing + drift envs) advance at
    # issue-time accounting — uids are already host-side here, so this
    # costs no extra device sync
    return _note_counts(exp, np.where(valid, uids, -1))


# ---------------------------------------------------------------------------
# checkpoint / restore
# ---------------------------------------------------------------------------


def _ckpt_payload(exp: Experiment) -> dict:
    arms = {}
    for i, s in enumerate(exp.arms):
        entry = {"state": s.state, "snap": exp.snapshots[i]}
        if s.pending is not None:
            entry["pending"] = s.pending
        arms[f"arm{i}"] = entry
    sel = ({} if exp.selector is None
           else {"alpha": exp.selector.alpha, "beta": exp.selector.beta})
    meta = {"fractions": np.asarray(exp.fractions, np.float64),
            "enabled": np.asarray(exp.enabled, np.int32),
            "salt": np.asarray(exp.salt, np.int64),
            "steps": np.asarray(exp.steps, np.int64),
            "epoch": np.asarray(exp.epoch, np.int64),
            "counts": exp.counts,
            "stages": np.asarray(exp.stages or (HEALTHY,) * exp.n_arms,
                                 np.int32),
            "stage_since": np.asarray(exp.stage_since or (0,) * exp.n_arms,
                                      np.int64),
            "totals": dict(exp.totals)}
    return {"arms": arms, "selector": sel, "meta": meta}


def _ckpt_shardings(exp: Experiment):
    if all(s.mesh is None for s in exp.arms):
        return None
    from jax.sharding import NamedSharding
    from jax.sharding import PartitionSpec as P

    def repl(mesh):
        return NamedSharding(mesh, P())

    payload = _ckpt_payload(exp)
    arms = {}
    for i, s in enumerate(exp.arms):
        st = (s._shardings() if s.mesh is not None
              else jax.tree_util.tree_map(lambda _: None, s.state))
        entry = {"state": st, "snap": st}
        if s.pending is not None:
            entry["pending"] = jax.tree_util.tree_map(
                lambda _: repl(s.mesh) if s.mesh is not None else None,
                s.pending)
        arms[f"arm{i}"] = entry
    rest = jax.tree_util.tree_map(lambda _: None,
                                  {"selector": payload["selector"],
                                   "meta": payload["meta"]})
    return {"arms": arms, **rest}


def save(exp: Experiment, ckpt, step: int):
    """Snapshot the WHOLE experiment — arm states + pending rings +
    rollback anchors + selector posteriors + assignment salt/fractions —
    as one atomic checkpoint entry."""
    return ckpt.save(_ckpt_payload(exp), step)


def restore(exp: Experiment, ckpt, step: int | None = None):
    """(experiment, step) restored from ``ckpt`` (latest when ``step`` is
    None; ``(exp, None)`` on an empty directory).  Routing — salt,
    fractions, enabled set, selector posteriors, epoch counters — and
    every arm's state/pending resume exactly, so subsequent assignment
    and choices are bit-identical to the uninterrupted run.  Guardrail
    EMAs restart fresh (monitors re-warm; the rollback anchors are
    restored)."""
    like = _ckpt_payload(exp)
    shardings = _ckpt_shardings(exp)
    if step is None:
        payload, step = ckpt.restore_latest(like, shardings)
        if payload is None:
            return exp, None
    else:
        payload = ckpt.restore(step, like, shardings)
    arms = []
    snaps = []
    for i, s in enumerate(exp.arms):
        entry = payload["arms"][f"arm{i}"]
        kw = {"state": entry["state"]}
        if s.pending is not None:
            kw["pending"] = entry["pending"]
        arms.append(dataclasses.replace(s, **kw))
        snaps.append(entry["snap"])
    sel = exp.selector
    if sel is not None:
        sel = sel._replace(alpha=np.asarray(payload["selector"]["alpha"]),
                           beta=np.asarray(payload["selector"]["beta"]))
    meta = payload["meta"]
    fractions = tuple(float(f) for f in np.asarray(meta["fractions"]))
    restored = dataclasses.replace(
        exp, arms=tuple(arms), snapshots=tuple(snaps), selector=sel,
        fractions=fractions,
        enabled=tuple(bool(e) for e in np.asarray(meta["enabled"])),
        salt=int(meta["salt"]), steps=int(meta["steps"]),
        epoch=int(meta["epoch"]), counts=np.asarray(meta["counts"]),
        stages=tuple(int(s) for s in np.asarray(meta["stages"])),
        stage_since=tuple(int(s) for s in np.asarray(meta["stage_since"])),
        totals={k: np.asarray(v) for k, v in meta["totals"].items()},
        guards=(guardrails_mod.GuardrailState(),) * exp.n_arms,
        shares=((int(meta["steps"]), fractions),))
    return restored, step


# ---------------------------------------------------------------------------
# the report
# ---------------------------------------------------------------------------


class ExperimentReport(NamedTuple):
    rounds: int
    names: tuple
    enabled: tuple
    fractions: tuple            # final traffic split
    reward: tuple               # per-arm realized reward (issue-time)
    expected: tuple
    best: tuple
    rand_reward: tuple
    regret: tuple               # per-arm best - expected
    interactions: tuple
    delivered: tuple
    matched_ratio: tuple        # per-arm pending matched / issued
    shares: tuple               # ((step, fractions), ...) over time
    leader: str                 # highest reward-rate enabled arm
    runner_up: str
    z_leading_pair: float       # sequential two-proportion z, leader pair
    tx_per_s: float
    events: tuple


def _z_stat(p1, n1, p2, n2) -> float:
    if min(n1, n2) <= 0:
        return 0.0
    pool = (p1 * n1 + p2 * n2) / (n1 + n2)
    var = pool * (1 - pool) * (1 / n1 + 1 / n2)
    if var <= 0:
        return 0.0
    return float((p1 - p2) / np.sqrt(var))


def report(exp: Experiment, *, rounds: int = 0,
           tx_per_s: float = 0.0) -> ExperimentReport:
    """Summarize the experiment so far.  The z-statistic compares the
    reward-rates of the two leading enabled arms over the SAME seeded
    traffic stream (a sequential look: |z| ~> 2-3 before trusting the
    winner, the usual always-valid caveats apply)."""
    t = exp.totals
    A = exp.n_arms
    n = np.maximum(t["interactions"], 1)
    rate = np.where(t["interactions"] > 0, t["reward"] / n, -np.inf)
    rate = np.where(np.asarray(exp.enabled, bool), rate, -np.inf)
    order = np.argsort(-rate)
    lead, run = int(order[0]), int(order[1]) if A > 1 else int(order[0])
    z = 0.0
    if A > 1 and np.isfinite(rate[run]):
        z = _z_stat(rate[lead], t["interactions"][lead],
                    rate[run], t["interactions"][run])
    matched = []
    for s in exp.arms:
        st = session_mod.pending_stats(s)
        matched.append(st["matched"] / max(1.0, st["issued"])
                       if st else 0.0)

    def tup(k):
        return tuple(float(x) for x in t[k])

    return ExperimentReport(
        rounds=rounds or exp.steps, names=exp.names, enabled=exp.enabled,
        fractions=exp.fractions, reward=tup("reward"),
        expected=tup("expected"), best=tup("best"),
        rand_reward=tup("rand"),
        regret=tuple(float(b - e) for b, e in zip(t["best"],
                                                  t["expected"])),
        interactions=tuple(int(x) for x in t["interactions"]),
        delivered=tuple(int(x) for x in t["delivered"]),
        matched_ratio=tuple(matched), shares=exp.shares,
        leader=exp.names[lead], runner_up=exp.names[run],
        z_leading_pair=z, tx_per_s=tx_per_s, events=exp.events)


# ---------------------------------------------------------------------------
# the seeded A/B harness (same traffic + fault machinery as serve.faults)
# ---------------------------------------------------------------------------


def run_experiment(exp: Experiment, theta, rounds: int, *,
                   spec: faults_mod.FaultSpec | None = None,
                   batch: int = 32, key: int = 0, drain: bool = True):
    """Drive the experiment over the SAME keyed traffic stream the fault
    harness uses (``faults.TrafficStream`` — byte-identical users,
    contexts, and reward keys to a ``run_faulted`` clean control with the
    same ``key``), with the same seeded delivery-fault machinery
    (delay/loss/dup/flip/stall) applied to the merged decision stream so
    every arm experiences the identical environment.  ``theta`` is the
    ``[n_users, d]`` preference matrix, or a callable
    ``theta(counts) -> [n_users, d]`` for drifting environments (counts =
    per-user lifetime interactions).  All arms must be buffer-enabled.
    Returns ``(exp, ExperimentReport)``."""
    spec = faults_mod.FaultSpec() if spec is None else spec
    cfg = exp.arms[0].policy.cfg
    stream = faults_mod.TrafficStream(key, batch, cfg.n_users,
                                      K=cfg.n_candidates, d=cfg.d)
    theta_fn = theta if callable(theta) else (lambda counts: theta)
    A = exp.n_arms
    rng = np.random.default_rng(spec.seed)
    queue: list[list] = []          # [due_round, global_id, reward]
    stalled_until = -1
    n_tx = 0

    def deliver(now, fb_key):
        nonlocal exp, queue, n_tx
        due = [e for e in queue if e[0] <= now]
        queue = [e for e in queue if e[0] > now]
        for c, lo in enumerate(range(0, len(due), batch)):
            chunk = due[lo:lo + batch]
            ids = np.full((batch,), -1, np.int32)
            rs = np.zeros((batch,), np.float32)
            ids[:len(chunk)] = [e[1] for e in chunk]
            rs[:len(chunk)] = [e[2] for e in chunk]
            exp = observe_delayed(exp, jnp.asarray(ids), jnp.asarray(rs),
                                  key=jax.random.fold_in(fb_key, c))
            n_tx += 1

    t0 = time.perf_counter()
    for i in range(rounds):
        users, ctx, kr, kf = stream.slate_batch(i)
        exp, choices, gids = recommend(exp, users, ctx)
        n_tx += 1
        th = jnp.asarray(theta_fn(exp.counts))
        realized, expected, best, rand = bandit_env.step_rewards(
            kr, th[users], ctx, choices)

        gids_np = np.asarray(gids)
        valid = gids_np >= 0
        arms_np = np.where(valid, gids_np % A, -1)
        r_np = np.asarray(realized, np.float32)

        # delivery fault draws — same NumPy stream layout as run_faulted
        B = batch
        flip = (i >= spec.flip_after) & (rng.random(B) < spec.p_flip)
        r_del = np.where(flip, -r_np, r_np)
        lost = rng.random(B) < spec.p_loss
        delayed = rng.random(B) < spec.p_delay
        lag = np.where(delayed, rng.integers(1, spec.max_delay + 1, B), 0)
        dup = rng.random(B) < spec.p_dup

        exp = record_feedback(exp, np.asarray(users), arms_np, r_np,
                              expected=np.asarray(expected),
                              best=np.asarray(best),
                              rand=np.asarray(rand),
                              learner_rewards=r_del)
        for b in np.nonzero(valid & ~lost)[0]:
            queue.append([i + int(lag[b]), int(gids_np[b]),
                          float(r_del[b])])
            if dup[b]:
                extra = int(rng.integers(0, spec.max_delay + 1))
                queue.append([i + int(lag[b]) + extra, int(gids_np[b]),
                              float(r_del[b])])

        if spec.stall_every and (i + 1) % spec.stall_every == 0:
            stalled_until = i + spec.stall_rounds
        if i >= stalled_until:
            deliver(i, kf)

    if drain and queue:
        deliver(max(e[0] for e in queue), stream.drain_key(rounds))
    dt = time.perf_counter() - t0
    return exp, report(exp, rounds=rounds, tx_per_s=n_tx / max(dt, 1e-9))
