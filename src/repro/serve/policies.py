"""The serving ``Policy`` protocol and its four implementations.

A policy is everything an :class:`~repro.serve.session.OnlineBandit`
session needs to turn a request batch into choices and fold feedback
back — four hooks over a policy-specific state pytree:

  init()                          -> state        (global shapes)
  gather_score(state, idx)        -> (w, minv_eff, occ) rows for the
                                     fused choose, gathered per request
  apply_pass(state, idx, x, r, live, be)
                                  -> state        one masked feedback
                                     pass; ``live`` rows have DISTINCT
                                     user ids (the session's duplicate
                                     decomposition guarantees it), so a
                                     single fused rank-1 sweep is exact
  refresh(col, state, key)        -> state        the periodic stage

Policies are hashable NamedTuples of Python scalars (like the backend
engines), so the session can close jit-compiled transactions over them.
None of the scoring / update / refresh math lives here: the clustered
policies call the stage bodies (``runtime.stages.beta_gate`` /
``mix_scores`` / ``stage2_refresh``), linucb is ``linucb.user_vector`` +
the fused engine, and dccb reuses ``core.dccb.lagged_score`` /
``buffered_push`` / ``gossip_round``.

| policy     | scores with                      | refresh                    |
|------------|----------------------------------|----------------------------|
| `distclub` | beta gate: own vs cluster stats  | stage-2 (prune+CC+reduce)  |
| `club`     | cluster stats always             | stage-2 (prune+CC+reduce)  |
| `linucb`   | own stats always                 | none                       |
| `dccb`     | lagged buffered stats            | one gossip round           |

``gather_score`` doubles as the CATALOG-RETRIEVAL statistics hook: the
``(w, minv_eff, occ)`` rows it returns are exactly what the streaming
top-K engine scores the item catalog with (``serve.step_catalog``), so
every policy serves two-stage against a ``core.catalog.Catalog`` with no
policy-specific retrieval code — the shortlist is ranked by the same
mixed statistics the fused choose would score a caller-supplied slate
with.

The clustered policies adopt the engine's FROZEN-snapshot semantics: the
per-user cluster statistics (``uMcinv``/``ubc``/``umean_occ``) are taken
at refresh time and held constant until the next refresh — exactly what
stages 3/4 of the offline drivers read.  (The pre-redesign serving layer
instead advanced ``clusters.seen`` live between refreshes; see the README
migration notes.)
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..core import dccb, distclub, linucb
from ..core.backend import BackendConfig, InteractBackend
from ..core.types import BanditHyper, ClusterStats, DistCLUBState, GraphState
from ..kernels.graph import ops as graph_ops
from ..runtime import stages

try:  # PartitionSpec only needed for the sharded binding
    from jax.sharding import PartitionSpec as P
except ImportError:  # pragma: no cover
    P = None

POLICIES = ("distclub", "dccb", "club", "linucb")


class ServeCfg(NamedTuple):
    """Static facts of one serving session (hashable -> jit-static).

    ``engine`` is the run-level `InteractBackend` — the dispatch decision
    (kind, interpret, padding policy) resolved ONCE at session creation
    and the single source of those facts (the graph engine for refresh
    follows ``engine.kind``/``engine.interpret``); the session derives
    the request-batch-width engine from it per traced batch shape via
    ``engine.with_users``."""

    n_users: int
    d: int
    n_candidates: int
    hyper: BanditHyper
    refresh_every: int      # interactions between refreshes; <= 0 = never
    engine: "InteractBackend"


def _scatter_rows(array, tgt, rows):
    """Masked row scatter: ``tgt`` >= n_local rows are dropped."""
    return array.at[tgt].set(rows, mode="drop")


def _rank1_pass(Minv, b, occ, idx, x, r, live, be):
    """One fused masked Sherman-Morrison pass over gathered rows,
    scattered back for the live (distinct-user) rows only — the shared
    feedback body of every LinUCB-statistics policy."""
    Minv2, b2 = be.update_inv(Minv[idx], b[idx], x, r, live)
    tgt = jnp.where(live, idx, occ.shape[0])
    return (_scatter_rows(Minv, tgt, Minv2), _scatter_rows(b, tgt, b2),
            occ.at[tgt].add(1, mode="drop"))


# ---------------------------------------------------------------------------
# distclub / club — the clustered policies (stage-engine refresh)
# ---------------------------------------------------------------------------


class ClusteredState(NamedTuple):
    """DistCLUB/CLUB serving state: LinUCB rows + packed graph + the
    frozen per-user stage-2 snapshots.  ``[n_local, ...]`` arrays are the
    sharded ones; ``labels`` and the scalars are replicated."""

    Minv: jnp.ndarray         # [n_local, d, d]
    b: jnp.ndarray            # [n_local, d]
    occ: jnp.ndarray          # [n_local] i32
    adj: jnp.ndarray          # [n_local, ceil(n/32)] uint32 packed rows
    labels: jnp.ndarray       # [n] i32 replicated
    uMcinv: jnp.ndarray       # [n_local, d, d]  frozen cluster snapshot
    ubc: jnp.ndarray          # [n_local, d]
    umean_occ: jnp.ndarray    # [n_local] f32
    since_refresh: jnp.ndarray  # [] i32
    comm_bytes: jnp.ndarray     # [] f32 modeled stage-2 traffic


class ClusteredPolicy(NamedTuple):
    cfg: ServeCfg
    use_beta: bool            # True = distclub (beta gate), False = club
    # NamedTuples compare as plain tuples, so policies of different
    # classes over the same cfg would otherwise collide in the session's
    # compiled-transaction cache — the kind tag keeps them distinct.
    kind: str = "clustered"

    @property
    def name(self) -> str:
        return "distclub" if self.use_beta else "club"

    @property
    def has_refresh(self) -> bool:
        return True

    def init(self) -> ClusteredState:
        n, d = self.cfg.n_users, self.cfg.d
        # HBM-dominant [n, d, d] state lives in the session's Precision
        # state dtype (f32 default -> these astype calls are no-ops)
        sdt = self.cfg.engine.precision.jnp_state
        eye = jnp.broadcast_to(jnp.eye(d, dtype=jnp.float32),
                               (n, d, d)).astype(sdt)
        return ClusteredState(
            Minv=eye,
            b=jnp.zeros((n, d), jnp.float32),
            occ=jnp.zeros((n,), jnp.int32),
            adj=graph_ops.init_packed_adj(n, n),
            labels=jnp.zeros((n,), jnp.int32),   # one big cluster initially
            uMcinv=eye,
            ubc=jnp.zeros((n, d), jnp.float32),
            umean_occ=jnp.zeros((n,), jnp.float32),
            since_refresh=jnp.zeros((), jnp.int32),
            comm_bytes=jnp.zeros((), jnp.float32),
        )

    def occ_of(self, state: ClusteredState):
        return state.occ

    def gather_score(self, state: ClusteredState, idx):
        # gather reduced-precision rows, then upcast once for the f32
        # user-vector solve and the fused choose (no-op under f32)
        Minv = state.Minv[idx].astype(jnp.float32)
        b, occ = state.b[idx], state.occ[idx]
        uMcinv = state.uMcinv[idx].astype(jnp.float32)
        ubc = state.ubc[idx]
        v_own = linucb.user_vector(Minv, b)
        v_clu = linucb.user_vector(uMcinv, ubc)
        if self.use_beta:
            use_own = stages.beta_gate(self.cfg.hyper, occ,
                                       state.umean_occ[idx])
        else:
            use_own = jnp.zeros(occ.shape, bool)     # CLUB: cluster always
        w, minv_eff = stages.mix_scores(use_own, v_own, v_clu, Minv, uMcinv)
        return w, minv_eff, occ

    def apply_pass(self, state: ClusteredState, idx, x, r, live, be):
        Minv, b, occ = _rank1_pass(state.Minv, state.b, state.occ,
                                   idx, x, r, live, be)
        return state._replace(Minv=Minv, b=b, occ=occ)

    def refresh(self, col, state: ClusteredState, key) -> ClusteredState:
        del key                                       # deterministic stage
        cfg = self.cfg
        n_local = state.occ.shape[0]
        gb = BackendConfig(kind=cfg.engine.kind,
                           precision=cfg.engine.precision
                           ).graph(n_local, cfg.n_users,
                                   interpret=cfg.engine.interpret)
        res = stages.stage2_refresh(col, gb, cfg.hyper, cfg.d,
                                    state.Minv, state.b, state.occ,
                                    state.adj)
        return state._replace(
            adj=res.adj, labels=res.labels,
            uMcinv=res.uMcinv.astype(state.uMcinv.dtype), ubc=res.ubc,
            umean_occ=res.umean_occ,
            comm_bytes=state.comm_bytes + res.comm_bytes,
        )

    def state_specs(self, axes) -> ClusteredState:
        s, r = P(axes), P()
        return ClusteredState(Minv=s, b=s, occ=s, adj=s, labels=r,
                              uMcinv=s, ubc=s, umean_occ=s,
                              since_refresh=r, comm_bytes=r)


# ---------------------------------------------------------------------------
# linucb — the per-user baseline (Li et al.; no clustering, no refresh)
# ---------------------------------------------------------------------------


class LinUCBServeState(NamedTuple):
    Minv: jnp.ndarray           # [n_local, d, d]
    b: jnp.ndarray              # [n_local, d]
    occ: jnp.ndarray            # [n_local] i32
    since_refresh: jnp.ndarray  # [] i32 (counted for parity; never fires)


class LinUCBPolicy(NamedTuple):
    cfg: ServeCfg
    kind: str = "linucb"      # cache-key discriminator (see ClusteredPolicy)

    @property
    def name(self) -> str:
        return "linucb"

    @property
    def has_refresh(self) -> bool:
        return False

    def init(self) -> LinUCBServeState:
        n, d = self.cfg.n_users, self.cfg.d
        sdt = self.cfg.engine.precision.jnp_state
        eye = jnp.broadcast_to(jnp.eye(d, dtype=jnp.float32),
                               (n, d, d)).astype(sdt)
        return LinUCBServeState(
            Minv=eye,
            b=jnp.zeros((n, d), jnp.float32),
            occ=jnp.zeros((n,), jnp.int32),
            since_refresh=jnp.zeros((), jnp.int32),
        )

    def occ_of(self, state: LinUCBServeState):
        return state.occ

    def gather_score(self, state: LinUCBServeState, idx):
        Minv = state.Minv[idx].astype(jnp.float32)
        b, occ = state.b[idx], state.occ[idx]
        return linucb.user_vector(Minv, b), Minv, occ

    def apply_pass(self, state: LinUCBServeState, idx, x, r, live, be):
        Minv, b, occ = _rank1_pass(state.Minv, state.b, state.occ,
                                   idx, x, r, live, be)
        return state._replace(Minv=Minv, b=b, occ=occ)

    def refresh(self, col, state, key):
        del col, key
        return state

    def state_specs(self, axes) -> LinUCBServeState:
        s, r = P(axes), P()
        return LinUCBServeState(Minv=s, b=s, occ=s, since_refresh=r)


# ---------------------------------------------------------------------------
# dccb — the buffered-gossip baseline (Korda et al.)
# ---------------------------------------------------------------------------


class DCCBServeState(NamedTuple):
    core: dccb.DCCBState        # full DCCB record (dense adj, buffers)
    since_refresh: jnp.ndarray  # [] i32


class DCCBPolicy(NamedTuple):
    """DCCB as a serving policy: lagged buffered scoring, refresh = one
    gossip round.  Request-driven adaptation of the lockstep driver: the
    ring-buffer cursor advances once per feedback pass, and inactive
    users keep their pending slot entries buffered until their next
    active pass pops them (strictly longer lag, never lost updates).
    Single-host only — gossip does per-edge scatter updates on the dense
    graph, which is deliberately not sharded (see ``core.dccb``)."""

    cfg: ServeCfg
    kind: str = "dccb"        # cache-key discriminator (see ClusteredPolicy)

    @property
    def name(self) -> str:
        return "dccb"

    @property
    def has_refresh(self) -> bool:
        return True

    @property
    def L(self) -> int:
        return self.cfg.hyper.buffer_size

    def init(self) -> DCCBServeState:
        return DCCBServeState(
            core=dccb.init_state(self.cfg.n_users, self.cfg.d, self.L),
            since_refresh=jnp.zeros((), jnp.int32),
        )

    def occ_of(self, state: DCCBServeState):
        return state.core.occ

    def gather_score(self, state: DCCBServeState, idx):
        w, Minv = dccb.lagged_score(state.core.Mw[idx], state.core.bw[idx])
        return w, Minv, state.core.occ[idx]

    def apply_pass(self, state: DCCBServeState, idx, x, r, live, be):
        del be                       # buffer pushes are plain adds, not S-M
        n_local = state.core.occ.shape[0]
        d = x.shape[1]
        tgt = jnp.where(live, idx, n_local)
        x_full = jnp.zeros((n_local, d), x.dtype).at[tgt].set(x, mode="drop")
        r_full = jnp.zeros((n_local,), x.dtype).at[tgt].set(r, mode="drop")
        m_full = jnp.zeros((n_local,), bool).at[tgt].set(live, mode="drop")
        core = dccb.buffered_push(state.core, x_full, r_full, m_full, self.L)
        return state._replace(core=core)

    def refresh(self, col, state: DCCBServeState, key) -> DCCBServeState:
        del col                                       # single-host only
        core = dccb.gossip_round(state.core, key, self.cfg.hyper, self.L,
                                 self.cfg.d)
        return state._replace(core=core)

    def state_specs(self, axes):
        raise NotImplementedError(
            "dccb serving is single-host only (dense gossip graph)")


# ---------------------------------------------------------------------------
# construction + offline interop
# ---------------------------------------------------------------------------


def make_cfg(n_users: int, d: int, hyper: BanditHyper, *,
             refresh_every: int = 0, backend: str | None = None,
             interpret: bool | None = None, block_users: int = 256,
             precision=None) -> ServeCfg:
    """Resolve the engine dispatch once per session: ``backend`` via
    ``REPRO_BACKEND`` / TPU-auto and ``precision`` (a ``Precision``, a
    preset name, or None) via ``REPRO_PRECISION`` — both through
    ``core.backend.BackendConfig.create``.  The resolved precision rides
    in ``cfg.engine.precision`` and is the single source for the state
    dtype, catalog kernels and checkpoint tagging."""
    engine = BackendConfig.create(backend, precision).interact(
        n_users, d, hyper.n_candidates, block_users=block_users,
        interpret=interpret)
    return ServeCfg(n_users=n_users, d=d, n_candidates=hyper.n_candidates,
                    hyper=hyper, refresh_every=refresh_every, engine=engine)


def get_policy(name: str, cfg: ServeCfg):
    if name == "distclub":
        return ClusteredPolicy(cfg, use_beta=True)
    if name == "club":
        return ClusteredPolicy(cfg, use_beta=False)
    if name == "linucb":
        return LinUCBPolicy(cfg)
    if name == "dccb":
        return DCCBPolicy(cfg)
    raise ValueError(f"unknown policy {name!r}; want one of {POLICIES}")


def from_distclub_state(state: DistCLUBState) -> ClusteredState:
    """Warm-start a serving session from an offline ``distclub.run``
    state: per-user snapshots are gathered exactly as stage 3 would."""
    uMcinv, ubc, umean_occ = distclub.serving_snapshot(state)
    return ClusteredState(
        Minv=state.lin.Minv, b=state.lin.b, occ=state.lin.occ,
        adj=state.graph.adj, labels=state.graph.labels,
        uMcinv=uMcinv, ubc=ubc, umean_occ=umean_occ,
        since_refresh=jnp.zeros((), jnp.int32),
        comm_bytes=state.comm_bytes,
    )


def to_distclub_state(state: ClusteredState, hyper: BanditHyper,
                      d: int) -> DistCLUBState:
    """The public offline record from a serving state (label tables are
    rebuilt from the per-user rows; M recovered from Minv)."""
    n = state.occ.shape[0]
    Minv = state.Minv.astype(jnp.float32)     # offline record is f32
    M = jnp.linalg.inv(Minv)
    lin = linucb.LinUCBState(M=M, Minv=Minv, b=state.b, occ=state.occ)
    eye = jnp.eye(d, dtype=jnp.float32)
    labels = state.labels
    Mc = jax.ops.segment_sum(M - eye, labels, num_segments=n) + eye
    bc = jax.ops.segment_sum(state.b, labels, num_segments=n)
    size = jax.ops.segment_sum(jnp.ones_like(labels), labels, num_segments=n)
    seen = jax.ops.segment_sum(state.occ, labels, num_segments=n)
    stats = ClusterStats(Mc=Mc, Mcinv=jnp.linalg.inv(Mc), bc=bc,
                         size=size, seen=seen)
    rounds = jnp.full((n,), hyper.sigma, jnp.int32)
    return DistCLUBState(
        lin=lin, graph=GraphState(adj=state.adj, labels=labels),
        clusters=stats, u_rounds=rounds, c_rounds=rounds,
        comm_bytes=state.comm_bytes,
    )
