"""Seeded fault-injection harness for the delayed-feedback loop.

Drives a buffer-enabled session through the request/feedback split under
controlled failure modes — the knobs a real feedback pipeline actually
breaks on:

  p_delay / max_delay   feedback arrives 1..max_delay rounds late
  p_loss                feedback never arrives (pending slot TTL-expires)
  p_dup                 feedback delivered twice (second copy must be a
                        counted no-op)
  p_flip / flip_after   reward sign-flip corruption from a given round —
                        the poisoning scenario the guardrails exist for
  stall_every / stall_rounds
                        every k-th round the (simulated) feedback shard
                        stalls: nothing is delivered for `stall_rounds`
                        rounds, then the backlog floods in

Two random streams, deliberately separate: JAX keys (folded per round
from ``key``) drive users/contexts/realized rewards, a NumPy
``default_rng(spec.seed)`` drives the fault draws — so a faulted run and
its clean control (``FaultSpec()``) see IDENTICAL traffic and coupled
reward draws, and any metric gap is attributable to the faults alone.

Issue-time regret accounting: ``expected``/``best``/``rand`` are scored
when the decision is made (what the user experienced), while the
*delivered* reward — possibly flipped — is what the learner folds.
``report.reward`` is therefore the true realized reward, not the
corrupted one.

    session = serve.OnlineBandit.create(..., pending_capacity=256)
    session, report = run_faulted(session, env.theta, rounds=50,
                                  spec=FaultSpec(p_delay=0.3, p_loss=0.1))

Pass a ``guardrails.Guarded`` wrapper instead of a bare session and the
harness routes every transaction through the monitors — the sign-flip
scenario then ends in an auto-rollback event instead of a poisoned
session.  ``python -m repro.launch.faultrun`` is the CLI.
"""
from __future__ import annotations

import time
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core import env as bandit_env
from . import guardrails as guardrails_mod
from . import session as session_mod


class FaultSpec(NamedTuple):
    seed: int = 0
    p_delay: float = 0.0
    max_delay: int = 4
    p_loss: float = 0.0
    p_dup: float = 0.0
    p_flip: float = 0.0
    flip_after: int = 0
    stall_every: int = 0
    stall_rounds: int = 2


class FaultReport(NamedTuple):
    rounds: int
    interactions: int       # valid decisions issued
    reward: float           # TRUE realized reward sum (pre-corruption)
    expected: float         # sum E[r | choice] at issue
    best: float             # sum max_k E[r | k] at issue
    rand_reward: float      # sum of the RAN baseline at issue
    regret: float           # best - expected, summed
    delivered: int          # feedback entries handed to observe_delayed
    tx_per_s: float         # recommend + observe transactions per second
    pending: dict           # final pending-buffer counters
    events: tuple           # guardrail events ((,) for a bare session)


def run_faulted(session, theta, rounds: int, spec: FaultSpec, *,
                batch: int = 32, key: int = 0, drain: bool = True):
    """Run ``rounds`` of issue -> fault-mangled delivery -> delayed fold.

    ``session`` is a buffer-enabled ``OnlineBandit`` or a
    ``guardrails.Guarded`` wrapping one; ``theta [n_users, d]`` defines
    the Bernoulli environment.  Returns ``(session, FaultReport)`` with
    the session in its final state (same type as passed in).
    """
    guarded = isinstance(session, guardrails_mod.Guarded)
    inner = session.session if guarded else session
    if inner.pending is None:
        raise ValueError("run_faulted needs a buffer-enabled session "
                         "(create with pending_capacity > 0)")
    cfg = inner.policy.cfg
    K, d = cfg.n_candidates, cfg.d
    theta = jnp.asarray(theta)

    rng = np.random.default_rng(spec.seed)
    base = jax.random.PRNGKey(key)
    queue: list[list] = []          # [due_round, decision_id, reward]
    stalled_until = -1
    tot = dict(interactions=0, reward=0.0, expected=0.0, best=0.0,
               rand=0.0, delivered=0)
    n_tx = 0

    def deliver(now, fb_key):
        nonlocal session, queue, n_tx
        due = [e for e in queue if e[0] <= now]
        queue = [e for e in queue if e[0] > now]
        for c, lo in enumerate(range(0, len(due), batch)):
            chunk = due[lo:lo + batch]
            ids = np.full((batch,), -1, np.int32)
            rs = np.zeros((batch,), np.float32)
            ids[:len(chunk)] = [e[1] for e in chunk]
            rs[:len(chunk)] = [e[2] for e in chunk]
            k = jax.random.fold_in(fb_key, c)
            if guarded:
                session = session.observe_delayed(jnp.asarray(ids),
                                                  jnp.asarray(rs), key=k)
            else:
                session = session_mod.observe_delayed(
                    session, jnp.asarray(ids), jnp.asarray(rs), key=k)
            n_tx += 1
            tot["delivered"] += len(chunk)

    t0 = time.perf_counter()
    for i in range(rounds):
        ku, kc, kr, kf = (jax.random.fold_in(base, 4 * i + j)
                          for j in range(4))
        users = jax.random.randint(ku, (batch,), 0, cfg.n_users)
        ctx = (jax.random.normal(kc, (batch, K, d), jnp.float32)
               / np.sqrt(d))
        if guarded:
            session, choices, ids = session.recommend(users, ctx)
        else:
            session, choices, ids = session_mod.recommend(session, users,
                                                          ctx)
        n_tx += 1
        realized, expected, best, rand = bandit_env.step_rewards(
            kr, theta[users], ctx, choices)

        ids_np = np.asarray(ids)
        r_np = np.asarray(realized, np.float32)
        valid = ids_np >= 0
        tot["interactions"] += int(valid.sum())
        tot["reward"] += float(np.where(valid, r_np, 0).sum())
        tot["expected"] += float(np.where(valid, np.asarray(expected), 0).sum())
        tot["best"] += float(np.where(valid, np.asarray(best), 0).sum())
        tot["rand"] += float(np.where(valid, np.asarray(rand), 0).sum())

        # fault draws — NumPy stream, invisible to the JAX traffic draws
        B = batch
        flip = (i >= spec.flip_after) & (rng.random(B) < spec.p_flip)
        r_del = np.where(flip, -r_np, r_np)
        lost = rng.random(B) < spec.p_loss
        delayed = rng.random(B) < spec.p_delay
        lag = np.where(delayed, rng.integers(1, spec.max_delay + 1, B), 0)
        dup = rng.random(B) < spec.p_dup
        for b in np.nonzero(valid & ~lost)[0]:
            queue.append([i + int(lag[b]), int(ids_np[b]), float(r_del[b])])
            if dup[b]:
                extra = int(rng.integers(0, spec.max_delay + 1))
                queue.append([i + int(lag[b]) + extra, int(ids_np[b]),
                              float(r_del[b])])

        if spec.stall_every and (i + 1) % spec.stall_every == 0:
            stalled_until = i + spec.stall_rounds
        if i >= stalled_until:
            deliver(i, kf)

    if drain and queue:             # flush the tail after traffic stops
        deliver(max(e[0] for e in queue),
                jax.random.fold_in(base, 4 * rounds))
    dt = time.perf_counter() - t0

    inner = session.session if guarded else session
    report = FaultReport(
        rounds=rounds, interactions=tot["interactions"],
        reward=tot["reward"], expected=tot["expected"], best=tot["best"],
        rand_reward=tot["rand"], regret=tot["best"] - tot["expected"],
        delivered=tot["delivered"], tx_per_s=n_tx / max(dt, 1e-9),
        pending=session_mod.pending_stats(inner),
        events=session.events if guarded else (),
    )
    return session, report
