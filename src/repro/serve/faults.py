"""Seeded fault-injection harness for the delayed-feedback loop.

Drives a buffer-enabled session through the request/feedback split under
controlled failure modes — the knobs a real feedback pipeline actually
breaks on:

  p_delay / max_delay   feedback arrives 1..max_delay rounds late
  p_loss                feedback never arrives (pending slot TTL-expires)
  p_dup                 feedback delivered twice (second copy must be a
                        counted no-op)
  p_flip / flip_after   reward sign-flip corruption from a given round —
                        the poisoning scenario the guardrails exist for
  stall_every / stall_rounds
                        every k-th round the (simulated) feedback shard
                        stalls: nothing is delivered for `stall_rounds`
                        rounds, then the backlog floods in

Catalog CHURN faults (:func:`run_faulted_catalog`, serving against a
double-buffered ``core.catalog.Catalog`` with the epoch/quarantine
machinery live):

  churn_every / churn_add / churn_retire
                        sustained churn: every k-th round stage
                        `churn_add` fresh items (drawn from the env's
                        region structure) + `churn_retire` random live
                        retirements, then publish a new epoch
  swap_stall_rounds     every publish lands late by this many rounds
                        (the swap-stall fault: staged churn accumulates
                        while serving continues on the old epoch)
  p_torn                P(a publish is torn): only a random half of the
                        staged slots land before the flip —
                        ``core.catalog.torn_publish``
  flash_crowd_at / flash_crowd_size
                        one burst of `size` arrivals in a single hot
                        region at the given round
  mass_retire_at        retire EVERY item of the hot region at the
                        given round (under load)

Two random streams, deliberately separate: JAX keys (folded per round
from ``key``) drive users/contexts/realized rewards, a NumPy
``default_rng(spec.seed)`` drives the fault draws (churn item CONTENT
comes from a third, spec-seeded JAX key so the env math stays in jax) —
so a faulted run and its clean control (``FaultSpec()``) see IDENTICAL
traffic and coupled reward draws, and any metric gap is attributable to
the faults alone.

Issue-time regret accounting: ``expected``/``best``/``rand`` are scored
when the decision is made (what the user experienced), while the
*delivered* reward — possibly flipped — is what the learner folds.
``report.reward`` is therefore the true realized reward, not the
corrupted one.

    session = serve.OnlineBandit.create(..., pending_capacity=256)
    session, report = run_faulted(session, env.theta, rounds=50,
                                  spec=FaultSpec(p_delay=0.3, p_loss=0.1))

Pass a ``guardrails.Guarded`` wrapper instead of a bare session and the
harness routes every transaction through the monitors — the sign-flip
scenario then ends in an auto-rollback event instead of a poisoned
session.  ``python -m repro.launch.faultrun`` is the CLI.
"""
from __future__ import annotations

import time
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core import catalog as catalog_mod
from ..core import env as bandit_env
from . import guardrails as guardrails_mod
from . import pending as pending_mod
from . import session as session_mod


class FaultSpec(NamedTuple):
    seed: int = 0
    p_delay: float = 0.0
    max_delay: int = 4
    p_loss: float = 0.0
    p_dup: float = 0.0
    p_flip: float = 0.0
    flip_after: int = 0
    stall_every: int = 0
    stall_rounds: int = 2
    # -- catalog churn faults (run_faulted_catalog only) --
    churn_every: int = 0        # publish cadence in rounds; 0 = no churn
    churn_add: int = 0          # fresh items staged per churn event
    churn_retire: int = 0       # random live retirements per churn event
    swap_stall_rounds: int = 0  # publishes land this many rounds late
    p_torn: float = 0.0         # P(publish is torn/partial)
    flash_crowd_at: int = -1    # round of a hot-region arrival burst
    flash_crowd_size: int = 0
    mass_retire_at: int = -1    # round the hot region retires wholesale


class FaultReport(NamedTuple):
    rounds: int
    interactions: int       # valid decisions issued
    reward: float           # TRUE realized reward sum (pre-corruption)
    expected: float         # sum E[r | choice] at issue
    best: float             # sum max_k E[r | k] at issue
    rand_reward: float      # sum of the RAN baseline at issue
    regret: float           # best - expected, summed
    delivered: int          # feedback entries handed to observe_delayed
    tx_per_s: float         # recommend + observe transactions per second
    pending: dict           # final pending-buffer counters (incl. stale)
    events: tuple           # guardrail events ((,) for a bare session)
    publishes: int = 0      # catalog epochs published (churn runs)
    items_added: int = 0    # items staged in across the run
    items_retired: int = 0  # items staged out across the run


class TrafficStream:
    """THE seeded keyed-traffic generator — the single source of the
    per-round key schedule consumed by clean controls, faulted runs, and
    every experiment arm (``serve.experiments``).  All consumers fold
    the same ``(key, round)`` lattice, so two runs constructed with the
    same ``key`` provably see byte-identical users, contexts, reward
    keys, and feedback keys; any metric gap is attributable to the
    policies/faults alone.

    Key layout (frozen — regression-tested byte-for-byte against the
    original inline schedule): round ``i`` owns the fold_in indices
    ``4*i .. 4*i+3`` as (users, contexts, rewards, feedback); catalog
    traffic draws no context key and uses ``4*i .. 4*i+2`` as (users,
    rewards, feedback) with the SAME stride; the post-run drain key is
    ``fold_in(base, 4*rounds)``.
    """

    def __init__(self, key, batch: int, n_users: int, *, K: int = None,
                 d: int = None):
        self.base = (jax.random.PRNGKey(key) if np.ndim(key) == 0
                     else key)
        self.batch = int(batch)
        self.n_users = int(n_users)
        self.K = K
        self.d = d

    def round_keys(self, i: int, n: int = 4) -> tuple:
        return tuple(jax.random.fold_in(self.base, 4 * i + j)
                     for j in range(n))

    def slate_batch(self, i: int):
        """(users [B], contexts [B,K,d], reward_key, feedback_key)."""
        ku, kc, kr, kf = self.round_keys(i, 4)
        users = jax.random.randint(ku, (self.batch,), 0, self.n_users)
        ctx = (jax.random.normal(kc, (self.batch, self.K, self.d),
                                 jnp.float32) / np.sqrt(self.d))
        return users, ctx, kr, kf

    def catalog_batch(self, i: int):
        """(users [B], reward_key, feedback_key) — contexts come from
        the served catalog shortlist, not the stream."""
        ku, kr, kf = self.round_keys(i, 3)
        users = jax.random.randint(ku, (self.batch,), 0, self.n_users)
        return users, kr, kf

    def drain_key(self, rounds: int):
        return jax.random.fold_in(self.base, 4 * rounds)


def run_faulted(session, theta, rounds: int, spec: FaultSpec, *,
                batch: int = 32, key: int = 0, drain: bool = True):
    """Run ``rounds`` of issue -> fault-mangled delivery -> delayed fold.

    ``session`` is a buffer-enabled ``OnlineBandit`` or a
    ``guardrails.Guarded`` wrapping one; ``theta [n_users, d]`` defines
    the Bernoulli environment.  Returns ``(session, FaultReport)`` with
    the session in its final state (same type as passed in).
    """
    guarded = isinstance(session, guardrails_mod.Guarded)
    inner = session.session if guarded else session
    if inner.pending is None:
        raise ValueError("run_faulted needs a buffer-enabled session "
                         "(create with pending_capacity > 0)")
    cfg = inner.policy.cfg
    theta = jnp.asarray(theta)
    stream = TrafficStream(key, batch, cfg.n_users, K=cfg.n_candidates,
                           d=cfg.d)

    rng = np.random.default_rng(spec.seed)
    queue: list[list] = []          # [due_round, decision_id, reward]
    stalled_until = -1
    tot = dict(interactions=0, reward=0.0, expected=0.0, best=0.0,
               rand=0.0, delivered=0)
    n_tx = 0

    def deliver(now, fb_key):
        nonlocal session, queue, n_tx
        due = [e for e in queue if e[0] <= now]
        queue = [e for e in queue if e[0] > now]
        for c, lo in enumerate(range(0, len(due), batch)):
            chunk = due[lo:lo + batch]
            ids = np.full((batch,), -1, np.int32)
            rs = np.zeros((batch,), np.float32)
            ids[:len(chunk)] = [e[1] for e in chunk]
            rs[:len(chunk)] = [e[2] for e in chunk]
            k = jax.random.fold_in(fb_key, c)
            if guarded:
                session = session.observe_delayed(jnp.asarray(ids),
                                                  jnp.asarray(rs), key=k)
            else:
                session = session_mod.observe_delayed(
                    session, jnp.asarray(ids), jnp.asarray(rs), key=k)
            n_tx += 1
            tot["delivered"] += len(chunk)

    t0 = time.perf_counter()
    for i in range(rounds):
        users, ctx, kr, kf = stream.slate_batch(i)
        if guarded:
            session, choices, ids = session.recommend(users, ctx)
        else:
            session, choices, ids = session_mod.recommend(session, users,
                                                          ctx)
        n_tx += 1
        realized, expected, best, rand = bandit_env.step_rewards(
            kr, theta[users], ctx, choices)

        ids_np = np.asarray(ids)
        r_np = np.asarray(realized, np.float32)
        valid = ids_np >= 0
        tot["interactions"] += int(valid.sum())
        tot["reward"] += float(np.where(valid, r_np, 0).sum())
        tot["expected"] += float(np.where(valid, np.asarray(expected), 0).sum())
        tot["best"] += float(np.where(valid, np.asarray(best), 0).sum())
        tot["rand"] += float(np.where(valid, np.asarray(rand), 0).sum())

        # fault draws — NumPy stream, invisible to the JAX traffic draws
        B = batch
        flip = (i >= spec.flip_after) & (rng.random(B) < spec.p_flip)
        r_del = np.where(flip, -r_np, r_np)
        lost = rng.random(B) < spec.p_loss
        delayed = rng.random(B) < spec.p_delay
        lag = np.where(delayed, rng.integers(1, spec.max_delay + 1, B), 0)
        dup = rng.random(B) < spec.p_dup
        for b in np.nonzero(valid & ~lost)[0]:
            queue.append([i + int(lag[b]), int(ids_np[b]), float(r_del[b])])
            if dup[b]:
                extra = int(rng.integers(0, spec.max_delay + 1))
                queue.append([i + int(lag[b]) + extra, int(ids_np[b]),
                              float(r_del[b])])

        if spec.stall_every and (i + 1) % spec.stall_every == 0:
            stalled_until = i + spec.stall_rounds
        if i >= stalled_until:
            deliver(i, kf)

    if drain and queue:             # flush the tail after traffic stops
        deliver(max(e[0] for e in queue), stream.drain_key(rounds))
    dt = time.perf_counter() - t0

    inner = session.session if guarded else session
    report = FaultReport(
        rounds=rounds, interactions=tot["interactions"],
        reward=tot["reward"], expected=tot["expected"], best=tot["best"],
        rand_reward=tot["rand"], regret=tot["best"] - tot["expected"],
        delivered=tot["delivered"], tx_per_s=n_tx / max(dt, 1e-9),
        pending=session_mod.pending_stats(inner),
        events=session.events if guarded else (),
    )
    return session, report


def run_faulted_catalog(session, env, rounds: int, spec: FaultSpec, *,
                        catalog=None, k_short: int = 16, batch: int = 32,
                        key: int = 0, drain: bool = True,
                        assert_conservation: bool = False):
    """Catalog serving under LIVE CHURN plus the delivery faults.

    ``session`` is a buffer-enabled ``OnlineBandit`` (pass ``catalog``)
    or a ``guardrails.Guarded`` created WITH a tracked catalog (so churn
    flows through its epoch-consistent snapshot/rollback path).  ``env``
    is a ``core.env.CatalogEnv`` — fresh churn items are drawn from its
    planted region structure, the flash crowd targets its hottest
    region, and rewards score the SERVED shortlist contexts, so churned
    items need no id-keyed reward table.  Delivery folds through
    ``observe_delayed(..., catalog=current)``: feedback for churned
    items is quarantined (``stale``), and with ``assert_conservation``
    the identity issued == matched + in_flight + expired + dropped +
    stale is checked after every delivery transaction.

    Returns ``(session, FaultReport)`` — ``report.pending["stale"]`` is
    the quarantine count, ``report.publishes`` the epochs flipped.
    """
    guarded = isinstance(session, guardrails_mod.Guarded)
    if guarded:
        if session.catalog is None:
            raise ValueError("run_faulted_catalog needs the Guarded "
                             "wrapper to track the catalog — create it "
                             "with Guarded.create(..., catalog=cat)")
        catalog = session.catalog
    elif catalog is None:
        raise ValueError("run_faulted_catalog needs a catalog")
    inner = session.session if guarded else session
    if inner.pending is None:
        raise ValueError("run_faulted_catalog needs a buffer-enabled "
                         "session (create with pending_capacity > 0)")
    cfg = inner.policy.cfg
    theta = jnp.asarray(env.theta)
    n_regions = env.region_centroids.shape[1]
    region_count = np.bincount(np.asarray(env.item_region),
                               minlength=n_regions)
    hot = int(region_count.argmax())

    rng = np.random.default_rng(spec.seed)
    stream = TrafficStream(key, batch, cfg.n_users)
    churn_base = jax.random.PRNGKey(spec.seed + 0x5EED)
    queue: list[list] = []          # [due_round, decision_id, reward]
    publish_due: list[int] = []     # rounds at which a publish lands
    stalled_until = -1
    tot = dict(interactions=0, reward=0.0, expected=0.0, best=0.0,
               rand=0.0, delivered=0)
    n_tx = 0
    n_pub = 0
    n_added = 0
    n_retired = 0

    def current_cat():
        return session.catalog if guarded else catalog

    def check_conservation():
        p = (session.session if guarded else session).pending
        gap = pending_mod.conservation_gap(p)
        if gap != 0:
            raise AssertionError(
                f"conservation identity violated: gap {gap} with "
                f"{pending_mod.stats(p)}")

    def stage(add=None, retire=None):
        nonlocal session, catalog, n_added, n_retired
        if guarded:
            session, _ = session.stage_churn(add=add, retire=retire)
        else:
            if retire is not None:
                catalog, _ = catalog_mod.retire_items(catalog, retire)
            if add is not None:
                catalog, _, _ = catalog_mod.add_items(catalog, add)
        if retire is not None:
            n_retired += int(retire.shape[0])
        if add is not None:
            n_added += int(add.shape[0])

    def do_publish():
        nonlocal session, catalog, n_pub
        cat = current_cat()
        torn = rng.random() < spec.p_torn
        keep = (jnp.asarray(rng.random(cat.capacity) < 0.5)
                if torn else None)
        if guarded:
            session = session.publish(keep_mask=keep)
        elif keep is None:
            catalog = catalog_mod.publish(catalog)
        else:
            catalog = catalog_mod.torn_publish(catalog, keep)
        n_pub += 1

    def deliver(now, fb_key):
        nonlocal session, queue, n_tx
        due = [e for e in queue if e[0] <= now]
        queue = [e for e in queue if e[0] > now]
        for c, lo in enumerate(range(0, len(due), batch)):
            chunk = due[lo:lo + batch]
            ids = np.full((batch,), -1, np.int32)
            rs = np.zeros((batch,), np.float32)
            ids[:len(chunk)] = [e[1] for e in chunk]
            rs[:len(chunk)] = [e[2] for e in chunk]
            k = jax.random.fold_in(fb_key, c)
            if guarded:
                session = session.observe_delayed(jnp.asarray(ids),
                                                  jnp.asarray(rs), key=k)
            else:
                session = session_mod.observe_delayed(
                    session, jnp.asarray(ids), jnp.asarray(rs), key=k,
                    catalog=current_cat())
            n_tx += 1
            tot["delivered"] += len(chunk)
            if assert_conservation:
                check_conservation()

    t0 = time.perf_counter()
    for i in range(rounds):
        users, kr, kf = stream.catalog_batch(i)
        if guarded:
            session, items, ids, slots, ctx = session.recommend_catalog(
                users, k_short=k_short)
        else:
            session, items, ids, slots, ctx = session_mod.recommend_catalog(
                session, users, current_cat(), k_short=k_short)
        n_tx += 1
        realized, expected, best, rand = bandit_env.step_rewards(
            kr, theta[users], ctx, slots)

        ids_np = np.asarray(ids)
        r_np = np.asarray(realized, np.float32)
        valid = ids_np >= 0
        tot["interactions"] += int(valid.sum())
        tot["reward"] += float(np.where(valid, r_np, 0).sum())
        tot["expected"] += float(np.where(valid, np.asarray(expected),
                                          0).sum())
        tot["best"] += float(np.where(valid, np.asarray(best), 0).sum())
        tot["rand"] += float(np.where(valid, np.asarray(rand), 0).sum())

        # delivery fault draws — NumPy stream, invisible to JAX traffic
        B = batch
        flip = (i >= spec.flip_after) & (rng.random(B) < spec.p_flip)
        r_del = np.where(flip, -r_np, r_np)
        lost = rng.random(B) < spec.p_loss
        delayed = rng.random(B) < spec.p_delay
        lag = np.where(delayed, rng.integers(1, spec.max_delay + 1, B), 0)
        dup = rng.random(B) < spec.p_dup
        for b in np.nonzero(valid & ~lost)[0]:
            queue.append([i + int(lag[b]), int(ids_np[b]),
                          float(r_del[b])])
            if dup[b]:
                extra = int(rng.integers(0, spec.max_delay + 1))
                queue.append([i + int(lag[b]) + extra, int(ids_np[b]),
                              float(r_del[b])])

        # churn events — staged into the shadow bank, published later
        staged = False
        if i == spec.flash_crowd_at and spec.flash_crowd_size > 0:
            k_fc = jax.random.fold_in(churn_base, 2 * i)
            emb, _ = bandit_env.sample_churn_items(
                env, k_fc, spec.flash_crowd_size, region=hot)
            stage(add=emb)
            staged = True
        if i == spec.mass_retire_at:
            stage(retire=jnp.asarray(
                bandit_env.region_item_ids(env, hot)))
            staged = True
        if spec.churn_every and (i + 1) % spec.churn_every == 0:
            if spec.churn_retire > 0:
                live_ids = np.nonzero(
                    np.asarray(current_cat().serving.live) > 0)[0]
                m = min(spec.churn_retire, len(live_ids))
                if m > 0:
                    stage(retire=jnp.asarray(rng.choice(
                        live_ids, size=m, replace=False).astype(np.int32)))
            if spec.churn_add > 0:
                k_ch = jax.random.fold_in(churn_base, 2 * i + 1)
                emb, _ = bandit_env.sample_churn_items(env, k_ch,
                                                       spec.churn_add)
                stage(add=emb)
            staged = True
        if staged:
            publish_due.append(i + spec.swap_stall_rounds)
        while publish_due and publish_due[0] <= i:
            publish_due.pop(0)
            do_publish()

        if spec.stall_every and (i + 1) % spec.stall_every == 0:
            stalled_until = i + spec.stall_rounds
        if i >= stalled_until:
            deliver(i, kf)

    while publish_due:                  # land stalled swaps before drain
        publish_due.pop(0)
        do_publish()
    if drain and queue:
        deliver(max(e[0] for e in queue), stream.drain_key(rounds))
    dt = time.perf_counter() - t0

    inner = session.session if guarded else session
    report = FaultReport(
        rounds=rounds, interactions=tot["interactions"],
        reward=tot["reward"], expected=tot["expected"], best=tot["best"],
        rand_reward=tot["rand"], regret=tot["best"] - tot["expected"],
        delivered=tot["delivered"], tx_per_s=n_tx / max(dt, 1e-9),
        pending=session_mod.pending_stats(inner),
        events=session.events if guarded else (),
        publishes=n_pub, items_added=n_added, items_retired=n_retired,
    )
    return session, report
