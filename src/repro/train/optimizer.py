"""Optimizers in pure JAX (no optax in this container).

AdamW with configurable moment dtype: ``f32`` for quality-critical runs,
``bf16`` for the multi-hundred-B MoE archs where 8 bytes/param of f32
moments cannot fit a v5e's HBM next to the weights (DESIGN.md §6 — this is
the "low-precision optimizer state" distributed-optimization knob; the
checkpoint round-trips the true dtype).  Adagrad is provided for the
embedding-table params of the recsys archs (the standard choice for sparse
features).

Optimizer states inherit the parameter PartitionSpecs (fully sharded —
ZeRO-style by construction, since our param specs already shard over both
"model" and "data" where the arch needs it).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: Any
    v: Any


# Leaves bigger than this get their elementwise update lax.map'd over dim 0
# (the stacked-layers dim): the update math needs f32 temporaries, and doing
# a 400B-model's worth of [L, ...] leaves in one shot materializes multi-GB
# f32 copies of every gradient at peak (seen directly in the dry-run buffer
# assignment).  Mapping over dim 0 caps the temp at one layer's slice.
_CHUNK_BYTES = 128 * 1024 * 1024


def _chunked(upd, n_out: int, *leaves):
    """Apply ``upd`` leafwise; lax.map over dim0 for huge stacked leaves."""
    p = leaves[-1]
    if p.ndim >= 3 and p.size * 4 > _CHUNK_BYTES and all(
        l.ndim >= 1 and l.shape[:1] == p.shape[:1] for l in leaves
    ):
        def body(xs):
            # barrier stops XLA hoisting the bf16->f32 converts out of the
            # loop (which would re-materialize the full-leaf f32 copies this
            # chunking exists to avoid)
            return upd(*jax.lax.optimization_barrier(xs))

        return jax.lax.map(body, leaves)
    return upd(*leaves)


def adamw_init(params, moment_dtype=jnp.float32) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, moment_dtype)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
    )


def adamw_update(
    grads, state: AdamWState, params,
    *, lr=1e-3, b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.01,
):
    step = state.step + 1
    t = step.astype(jnp.float32)
    c1 = 1.0 - b1 ** t
    c2 = 1.0 - b2 ** t

    def upd(g, m, v, p):
        gf = g.astype(jnp.float32)
        m_new = b1 * m.astype(jnp.float32) + (1 - b1) * gf
        v_new = b2 * v.astype(jnp.float32) + (1 - b2) * gf * gf
        delta = (m_new / c1) / (jnp.sqrt(v_new / c2) + eps)
        p_new = p.astype(jnp.float32) - lr * (
            delta + weight_decay * p.astype(jnp.float32)
        )
        return p_new.astype(p.dtype), m_new.astype(m.dtype), v_new.astype(v.dtype)

    out = jax.tree.map(lambda g, m, v, p: _chunked(upd, 3, g, m, v, p),
                       grads, state.m, state.v, params)
    params_new = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
    m_new = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    v_new = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return params_new, AdamWState(step=step, m=m_new, v=v_new)


class AdafactorState(NamedTuple):
    """Factored second moment (Shazeer & Stern, arXiv:1804.04235) + optional
    low-precision momentum — the standard optimizer-memory answer for the
    >100B archs, where even bf16 Adam moments overflow v5e HBM."""

    step: jnp.ndarray
    vr: Any      # row factors  (mean over last dim)
    vc: Any      # col factors  (mean over second-to-last dim)
    v: Any       # full second moment for rank<2 leaves
    m: Any       # momentum (bf16) or None-like zeros when disabled


def _factored(p) -> bool:
    return p.ndim >= 2


def adafactor_init(params, momentum_dtype=jnp.bfloat16) -> AdafactorState:
    def vr(p):
        return jnp.zeros(p.shape[:-1], jnp.float32) if _factored(p) else jnp.zeros((1,), jnp.float32)

    def vc(p):
        return (jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
                if _factored(p) else jnp.zeros((1,), jnp.float32))

    def v(p):
        return (jnp.zeros((1,), jnp.float32) if _factored(p)
                else jnp.zeros(p.shape, jnp.float32))

    return AdafactorState(
        step=jnp.zeros((), jnp.int32),
        vr=jax.tree.map(vr, params),
        vc=jax.tree.map(vc, params),
        v=jax.tree.map(v, params),
        m=jax.tree.map(lambda p: jnp.zeros(p.shape, momentum_dtype), params),
    )


def adafactor_update(
    grads, state: AdafactorState, params,
    *, lr=1e-3, decay=0.999, beta1=0.9, eps=1e-30, clip_rms=1.0,
):
    step = state.step + 1

    def upd_factored(g, vr, vc, m, p):
        gf = g.astype(jnp.float32)
        g2 = gf * gf + eps
        vr_n = decay * vr + (1 - decay) * jnp.mean(g2, axis=-1)
        vc_n = decay * vc + (1 - decay) * jnp.mean(g2, axis=-2)
        denom = jnp.maximum(jnp.mean(vr_n, axis=-1, keepdims=True), eps)
        vhat = (vr_n[..., None] * vc_n[..., None, :]) / denom[..., None]
        u = gf / jnp.sqrt(vhat + eps)
        rms = jnp.sqrt(jnp.mean(u * u) + eps)
        u = u / jnp.maximum(1.0, rms / clip_rms)
        m_n = beta1 * m.astype(jnp.float32) + (1 - beta1) * u
        p_n = p.astype(jnp.float32) - lr * m_n
        return p_n.astype(p.dtype), vr_n, vc_n, m_n.astype(m.dtype)

    def upd(g, vr, vc, v, m, p):
        if _factored(p):
            p_n, vr_n, vc_n, m_n = _chunked(upd_factored, 4, g, vr, vc, m, p)
            return p_n, vr_n, vc_n, v, m_n
        gf = g.astype(jnp.float32)
        g2 = gf * gf + eps
        v_n = decay * v + (1 - decay) * g2
        u = gf / jnp.sqrt(v_n + eps)
        rms = jnp.sqrt(jnp.mean(u * u) + eps)
        u = u / jnp.maximum(1.0, rms / clip_rms)
        m_n = beta1 * m.astype(jnp.float32) + (1 - beta1) * u
        p_n = p.astype(jnp.float32) - lr * m_n
        return p_n.astype(p.dtype), vr, vc, v_n, m_n.astype(m.dtype)

    out = jax.tree.map(upd, grads, state.vr, state.vc, state.v, state.m, params)
    pick = lambda i: jax.tree.map(lambda o: o[i], out,
                                  is_leaf=lambda x: isinstance(x, tuple))
    return pick(0), AdafactorState(step=step, vr=pick(1), vc=pick(2),
                                   v=pick(3), m=pick(4))


class AdagradState(NamedTuple):
    accum: Any


def adagrad_init(params) -> AdagradState:
    return AdagradState(
        accum=jax.tree.map(lambda p: jnp.full(p.shape, 0.1, jnp.float32), params)
    )


def adagrad_update(grads, state: AdagradState, params, *, lr=1e-2, eps=1e-10):
    def upd(g, a, p):
        gf = g.astype(jnp.float32)
        a_new = a + gf * gf
        p_new = p.astype(jnp.float32) - lr * gf / (jnp.sqrt(a_new) + eps)
        return p_new.astype(p.dtype), a_new

    out = jax.tree.map(upd, grads, state.accum, params)
    params_new = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
    accum_new = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    return params_new, AdagradState(accum=accum_new)
