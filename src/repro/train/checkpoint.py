"""Fault-tolerant checkpointing: atomic, keep-K, mesh-independent.

Design (DESIGN.md §6):
  * State is saved as host numpy arrays keyed by flattened pytree paths
    (npz) plus a msgpack-free JSON manifest (step, keys, shapes, dtypes).
    No mesh/sharding info is persisted — restore re-shards onto whatever
    mesh the new job has (**elastic**: scale from 256 to 512 chips or down
    to 1 CPU between runs; the bandit benchmarks round-trip through this).
  * Writes go to ``<dir>/tmp-<step>`` then ``os.replace`` into place —
    a crashed writer never corrupts the latest checkpoint (atomicity).
  * ``keep`` most-recent checkpoints are retained; ``latest_step`` scans
    the directory, so a restarted job just calls ``restore_latest``.
  * ``restore_latest`` is corruption-tolerant: a checkpoint that fails to
    load (truncated npz, malformed or wrong-magic manifest, missing keys
    — e.g. torn by a crash mid-copy on a non-atomic filesystem) is
    skipped with a warning and the next-newest good one is restored; it
    only raises if NO checkpoint in the directory loads.

This is deliberately dependency-free (no orbax in the container) but
API-compatible in spirit: save(state, step) / restore(step, like, mesh).
"""
from __future__ import annotations

import json
import os
import pathlib
import shutil
import warnings
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

_MAGIC = "repro-ckpt-v1"


def _flatten(tree) -> dict[str, Any]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return {jax.tree_util.keystr(path): leaf for path, leaf in flat}


class CheckpointManager:
    def __init__(self, directory: str | os.PathLike, keep: int = 3):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep

    # -- paths ------------------------------------------------------------
    def _step_dir(self, step: int) -> pathlib.Path:
        return self.dir / f"step-{step:010d}"

    def steps(self) -> list[int]:
        out = []
        for p in self.dir.glob("step-*"):
            if (p / "manifest.json").exists():
                out.append(int(p.name.split("-")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        s = self.steps()
        return s[-1] if s else None

    # -- save -------------------------------------------------------------
    def save(self, state, step: int) -> pathlib.Path:
        flat = _flatten(state)
        host = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
        tmp = self.dir / f"tmp-{step}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        # npz can't store ml_dtypes (bfloat16 &co) — persist their raw bits;
        # the manifest keeps the logical dtype for restore.
        np.savez(tmp / "arrays.npz", **{
            str(i): (v.view(np.uint16) if v.dtype.name == "bfloat16" else v)
            for i, v in enumerate(host.values())
        })
        manifest = {
            "magic": _MAGIC,
            "step": step,
            "keys": list(host.keys()),
            "shapes": [list(v.shape) for v in host.values()],
            "dtypes": [str(v.dtype) for v in host.values()],
        }
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        final = self._step_dir(step)
        if final.exists():
            shutil.rmtree(final)
        os.replace(tmp, final)                      # atomic publish
        self._gc()
        return final

    def _gc(self):
        steps = self.steps()
        for s in steps[: max(0, len(steps) - self.keep)]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # -- restore ----------------------------------------------------------
    def restore(self, step: int, like, shardings=None):
        """Rebuild ``like``-structured state; device_put with ``shardings``
        (a matching pytree or None for host arrays)."""
        import ml_dtypes

        d = self._step_dir(step)
        manifest = json.loads((d / "manifest.json").read_text())
        magic = manifest.get("magic", _MAGIC)   # pre-magic saves pass
        if magic != _MAGIC:
            raise ValueError(f"bad checkpoint magic {magic!r} in {d}")
        with np.load(d / "arrays.npz") as z:
            arrays = []
            for i, dt in enumerate(manifest["dtypes"]):
                a = z[str(i)]
                if dt == "bfloat16":
                    a = a.view(ml_dtypes.bfloat16)
                arrays.append(a)
        by_key = dict(zip(manifest["keys"], arrays))

        flat, treedef = jax.tree_util.tree_flatten_with_path(like)
        leaves = []
        for path, leaf in flat:
            key = jax.tree_util.keystr(path)
            if key not in by_key:
                raise KeyError(f"checkpoint missing {key}")
            a = by_key[key]
            want = np.dtype(jax.numpy.asarray(leaf).dtype
                            if not hasattr(leaf, "dtype") else leaf.dtype)
            if a.dtype != want:
                a = a.astype(want)
            leaves.append(a)
        state = jax.tree_util.tree_unflatten(treedef, leaves)
        if shardings is not None:
            state = jax.device_put(state, shardings)
        return state

    def restore_latest(self, like, shardings=None):
        """Restore the newest LOADABLE checkpoint, skipping corrupted or
        partial ones (truncated arrays, bad magic, missing keys) — a torn
        write must cost at most one snapshot of progress, never the whole
        directory.  Raises only when every candidate fails."""
        steps = self.steps()
        if not steps:
            return None, None
        errors = []
        for step in reversed(steps):
            try:
                return self.restore(step, like, shardings), step
            except Exception as e:  # corrupt entry: skip to next-newest
                errors.append((step, e))
                warnings.warn(
                    f"skipping corrupted checkpoint step {step}: {e!r}")
        raise RuntimeError(
            f"no loadable checkpoint in {self.dir}: "
            + "; ".join(f"step {s}: {e!r}" for s, e in errors))
