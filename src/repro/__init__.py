"""repro: DistCLUB (Fast Distributed Bandits for Online Recommendation
Systems) as a production-grade JAX/TPU framework."""
__version__ = "1.0.0"
