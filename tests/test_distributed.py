"""Distributed-runtime tests.

The production-mesh dry-run itself runs via ``python -m repro.launch.dryrun``
(512 host devices; results under results/dryrun).  Here we test:
  * the sharded DistCLUB runtime on a real multi-device mesh (subprocess
    with 8 host devices) agrees qualitatively with the single-host run,
  * the decode shard_map matches the single-host decode reference,
  * dry-run artifacts exist for every assigned cell on both meshes.
"""
import json
import os
import pathlib
import subprocess
import sys
import textwrap

import pytest

REPO = pathlib.Path(__file__).resolve().parents[1]
RESULTS = REPO / "results" / "dryrun"


def _run_with_devices(code: str, n: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    env["PYTHONPATH"] = str(REPO / "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_sharded_distclub_learns_on_8_devices():
    out = _run_with_devices("""
        import jax, jax.numpy as jnp
        from repro.distributed import distclub_shard
        from repro.core.types import BanditHyper

        mesh = jax.make_mesh((4, 2), ("data", "model"))
        hyper = BanditHyper(sigma=8, max_rounds=16, gamma=1.5, n_candidates=10)
        init_fn, epoch = distclub_shard.make_runtime(
            mesh, ("data", "model"), n=64, d=8, hyper=hyper)
        state = init_fn(jax.random.PRNGKey(0))
        tot_r = tot_rand = 0.0
        for i in range(5):
            state, m, nclu = epoch(state, jax.random.PRNGKey(i + 1))
            tot_r += float(m.reward.sum()); tot_rand += float(m.rand_reward.sum())
        print("REWARD", tot_r, "RAND", tot_rand, "CLU", int(nclu))
    """)
    parts = out.split()
    reward, rand = float(parts[1]), float(parts[3])
    assert reward > rand * 1.15, out


def test_decode_shard_map_matches_reference():
    out = _run_with_devices("""
        import numpy as np
        import jax, jax.numpy as jnp
        from jax.sharding import Mesh
        from repro.models import transformer as tr
        from repro.distributed import decode_shard

        cfg = tr.LMConfig(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                          d_head=16, d_ff=128, vocab=256, qk_norm=True,
                          dtype=jnp.float32, attn_chunk=32)
        params = tr.init_lm(jax.random.PRNGKey(0), cfg)
        B, S = 8, 32
        toks = jax.random.randint(jax.random.PRNGKey(1), (B, S + 1), 0, 256)
        _, cache = tr.lm_prefill(params, cfg, toks[:, :S])
        pad = 32
        kc = jnp.pad(cache[0], ((0,0),)*4 + ((0,pad),(0,0)))
        vc = jnp.pad(cache[1], ((0,0),)*4 + ((0,pad),(0,0)))
        ref, _ = tr.lm_decode_step(params, cfg, toks[:, S], (kc, vc),
                                   jnp.int32(S))

        mesh = jax.make_mesh((4, 2), ("data", "model"))
        step, p_sh, c_sh = decode_shard.build_decode_step(mesh, cfg, B, S + pad)
        params_d = jax.device_put(params, p_sh)
        kc_d = jax.device_put(kc, c_sh[0]); vc_d = jax.device_put(vc, c_sh[1])
        got, _ = step(params_d, toks[:, S], (kc_d, vc_d), jnp.int32(S))
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=2e-3, atol=2e-3)

        # int8 KV-cache variant (§Perf decode iteration): same step within
        # a few percent despite 2x less cache traffic
        stepq, p_shq, c_shq = decode_shard.build_decode_step(
            mesh, cfg, B, S + pad, kv_quant=True)
        def quant(a):
            sc = jnp.maximum(jnp.max(jnp.abs(a), -1) / 127.0, 1e-8)
            return (jnp.clip(jnp.round(a / sc[..., None]), -127, 127
                             ).astype(jnp.int8), sc.astype(jnp.float32))
        kq, ks = quant(kc.astype(jnp.float32))
        vq, vs = quant(vc.astype(jnp.float32))
        caches_q = tuple(jax.device_put(a, s) for a, s in
                         zip((kq, vq, ks, vs), c_shq))
        gotq, _ = stepq(jax.device_put(params, p_shq), toks[:, S], caches_q,
                        jnp.int32(S))
        ref_n = np.asarray(ref); got_n = np.asarray(gotq)
        denom = np.maximum(np.abs(ref_n).max(), 1e-6)
        assert np.max(np.abs(got_n - ref_n)) / denom < 0.08, "kv_quant drift"
        print("DECODE-OK")
    """)
    assert "DECODE-OK" in out


@pytest.mark.parametrize("tag", ["pod1", "pod2"])
def test_dryrun_artifacts_complete(tag):
    """Every assigned (arch x shape) compiled on both production meshes."""
    if not RESULTS.exists():
        pytest.skip("dry-run results not generated")
    sys.path.insert(0, str(REPO / "src"))
    from repro import configs

    missing = []
    for arch, shape in configs.all_cells():
        p = RESULTS / f"{arch}__{shape}__{tag}.json"
        if not p.exists():
            missing.append((arch, shape))
            continue
        rec = json.loads(p.read_text())
        assert rec["compile_s"] > 0
        assert rec["memory"]["temp_bytes"] is not None
    assert not missing, f"cells missing a {tag} dry-run: {missing}"


def test_dryrun_multi_pod_uses_pod_axis():
    """The multi-pod pass must actually shard over the 'pod' axis."""
    p = RESULTS / "llama3-8b__train_4k__pod2.json"
    if not p.exists():
        pytest.skip("dry-run results not generated")
    rec = json.loads(p.read_text())
    assert rec["mesh"] == [2, 16, 16]
    assert rec["axes"] == ["pod", "data", "model"]


def test_quantized_gather_matches_exact_loss():
    """int8 feature gathers (ogb_products §Perf iteration) must not change
    the loss materially (straight-through exactness is in the backward)."""
    out = _run_with_devices("""
        import dataclasses
        import jax, jax.numpy as jnp
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.models import gnn

        mesh = jax.make_mesh((8,), ("d",))
        n, e = 128, 512
        cfg = gnn.GNNConfig(d_feat=16, n_classes=5)
        params = gnn.init_gat(jax.random.PRNGKey(0), cfg)
        feats = jax.random.normal(jax.random.PRNGKey(1), (n, 16))
        # dst-partitioned edges: dst within each shard's 16-row block
        dst = jnp.concatenate([jax.random.randint(jax.random.PRNGKey(i), (e // 8,), i * 16, (i + 1) * 16) for i in range(8)])
        src = jax.random.randint(jax.random.PRNGKey(9), (e,), 0, n)
        labels = jax.random.randint(jax.random.PRNGKey(3), (n,), 0, 5)
        mask = jnp.ones((n,), bool)

        def loss_with(cfg):
            f = shard_map(
                lambda p, fe, s, d_, l, m: gnn.gat_loss_local(
                    p, cfg, fe, s, d_, l, m, ("d",)),
                mesh=mesh,
                in_specs=(P(), P("d", None), P("d"), P("d"), P("d"), P("d")),
                out_specs=P(), check_rep=False)
            return float(f(params, feats, src, dst, labels, mask))

        exact = loss_with(cfg)
        quant = loss_with(dataclasses.replace(cfg, quantized_gather=True))
        print("EXACT", exact, "QUANT", quant)
        assert abs(exact - quant) / abs(exact) < 0.05, (exact, quant)
        print("QGATHER-OK")
    """)
    assert "QGATHER-OK" in out
