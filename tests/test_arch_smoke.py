"""Per-architecture smoke tests: REDUCED config of the same family, one
forward/train step on CPU, asserting output shapes + no NaNs.

The full assigned configs are exercised abstractly by the dry-run only.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import gnn, transformer
from repro.models.recsys import dcn_v2, mind, seqrec

KEY = jax.random.PRNGKey(0)


def _finite(x):
    return bool(jnp.all(jnp.isfinite(x)))


# --- LM family: shrink every assigned config the same way ---------------------

LM_ARCHS = ["llama4-maverick-400b-a17b", "deepseek-moe-16b", "qwen3-4b",
            "llama3-8b", "yi-34b"]


def _reduced_lm(arch_id) -> transformer.LMConfig:
    cfg = configs.get(arch_id).cfg
    return dataclasses.replace(
        cfg,
        n_layers=2 * cfg.block_layers // cfg.block_layers * cfg.block_layers
        if cfg.block_layers > 1 else 2,
        d_model=64,
        n_heads=4, n_kv_heads=min(4, cfg.n_kv_heads), d_head=16,
        d_ff=128, vocab=512,
        n_experts=min(8, cfg.n_experts), d_ff_expert=64 if cfg.is_moe else 0,
        top_k=min(2, cfg.top_k),
        dtype=jnp.float32, attn_chunk=32, microbatches=1,
    )


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke_train_step(arch):
    cfg = _reduced_lm(arch)
    params = transformer.init_lm(KEY, cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0, cfg.vocab)
    loss, grads = jax.value_and_grad(transformer.lm_loss)(
        params, cfg, tokens, tokens)
    assert _finite(loss) and loss > 0
    assert all(_finite(g) for g in jax.tree.leaves(grads))


@pytest.mark.parametrize("arch", ["qwen3-4b", "deepseek-moe-16b"])
def test_lm_smoke_prefill_decode_consistency(arch):
    """Greedy decode after prefill == teacher-forced forward."""
    cfg = _reduced_lm(arch)
    params = transformer.init_lm(KEY, cfg)
    B, S = 2, 32
    tokens = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, cfg.vocab)
    logits_full, _ = transformer.lm_fwd(params, cfg, tokens)
    last_logits, cache = transformer.lm_prefill(params, cfg, tokens)
    np.testing.assert_allclose(
        np.asarray(last_logits), np.asarray(logits_full[:, -1]),
        rtol=2e-4, atol=2e-4)
    assert cache[0].shape == (cfg.n_blocks, cfg.block_layers, B,
                              cfg.n_kv_heads, S, cfg.d_head)


@pytest.mark.parametrize("arch", ["llama3-8b"])
def test_lm_smoke_decode_step_matches_fwd(arch):
    cfg = _reduced_lm(arch)
    params = transformer.init_lm(KEY, cfg)
    B, S = 2, 16
    tokens = jax.random.randint(jax.random.PRNGKey(3), (B, S + 1), 0, cfg.vocab)
    # build cache from the first S tokens, then decode token S
    _, cache = transformer.lm_prefill(params, cfg, tokens[:, :S])
    pad = 16
    kc = jnp.pad(cache[0], ((0, 0),) * 4 + ((0, pad), (0, 0)))
    vc = jnp.pad(cache[1], ((0, 0),) * 4 + ((0, pad), (0, 0)))
    logits, _ = transformer.lm_decode_step(
        params, cfg, tokens[:, S], (kc, vc), jnp.int32(S))
    full, _ = transformer.lm_fwd(params, cfg, tokens)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(full[:, -1]),
                               rtol=3e-3, atol=3e-3)


# --- GNN ------------------------------------------------------------------------


def test_gat_smoke_all_cells_reduced():
    spec = configs.get("gat-cora")
    for cell in spec.shapes:
        cfg = dataclasses.replace(spec.cell_cfg(cell), d_feat=12, n_classes=5)
        params = gnn.init_gat(KEY, cfg)
        n, e = 64, 256
        feats = jax.random.normal(KEY, (n, 12))
        src = jax.random.randint(KEY, (e,), 0, n)
        dst = jax.random.randint(jax.random.PRNGKey(1), (e,), 0, n)
        labels = jax.random.randint(KEY, (n,), 0, 5)
        loss = gnn.gat_loss(params, cfg, feats, src, dst, labels,
                            jnp.ones((n,), bool))
        assert _finite(loss)
        logits = gnn.gat_fwd(params, cfg, feats, src, dst)
        assert logits.shape == (n, 5)


def test_neighbor_sampler_shapes_fixed():
    rng = np.random.default_rng(0)
    n, e = 200, 2000
    src = rng.integers(0, n, e)
    dst = rng.integers(0, n, e)
    s = gnn.NeighborSampler(n, src, dst)
    for seed in range(3):
        nodes, ss, dd = s.sample(np.random.default_rng(seed),
                                 np.arange(16), (4, 3))
        assert nodes.shape == (16 * (1 + 4 + 12),)
        assert ss.shape == dd.shape == (16 * (4 + 12),)
        assert ss.max() < len(nodes) and dd.max() < len(nodes)


# --- recsys ---------------------------------------------------------------------


def test_sasrec_smoke():
    cfg = seqrec.SeqRecConfig(n_items=512, embed_dim=32, n_blocks=2,
                              n_heads=1, seq_len=16, n_negatives=7)
    p = seqrec.init_seqrec(KEY, cfg)
    ids = jax.random.randint(KEY, (4, 16), 1, 512)
    loss = seqrec.sampled_softmax_loss(p, cfg, ids, ids, KEY)
    assert _finite(loss)
    s = seqrec.score_candidates(p, cfg, ids, ids[:, :5])
    assert s.shape == (4, 5) and _finite(s)
    r = seqrec.retrieval_scores(p, cfg, ids[:1], jnp.arange(64))
    assert r.shape == (64,) and _finite(r)


def test_bert4rec_smoke_bidirectional():
    cfg = seqrec.SeqRecConfig(name="bert4rec", n_items=512, embed_dim=32,
                              n_blocks=2, n_heads=2, seq_len=16, causal=False)
    p = seqrec.init_seqrec(KEY, cfg)
    ids = jax.random.randint(KEY, (4, 16), 1, 512)
    h = seqrec.user_states(p, cfg, ids)
    assert h.shape == (4, 16, 32) and _finite(h)
    # bidirectionality: changing a LATER item changes an EARLIER state
    ids2 = ids.at[:, -1].set((ids[:, -1] + 1) % 512)
    h2 = seqrec.user_states(p, cfg, ids2)
    assert float(jnp.abs(h2[:, 0] - h[:, 0]).max()) > 0


def test_sasrec_is_causal():
    cfg = seqrec.SeqRecConfig(n_items=512, embed_dim=32, n_blocks=2,
                              n_heads=1, seq_len=16, causal=True)
    p = seqrec.init_seqrec(KEY, cfg)
    ids = jax.random.randint(KEY, (2, 16), 1, 512)
    h = seqrec.user_states(p, cfg, ids)
    ids2 = ids.at[:, -1].set((ids[:, -1] + 1) % 512)
    h2 = seqrec.user_states(p, cfg, ids2)
    np.testing.assert_allclose(np.asarray(h[:, :-1]), np.asarray(h2[:, :-1]),
                               atol=1e-5)


def test_dcn_smoke():
    cfg = dcn_v2.DCNConfig(vocab_per_field=256, embed_dim=8,
                           mlp_dims=(64, 32))
    p = dcn_v2.init_dcn(KEY, cfg)
    dense = jax.random.normal(KEY, (8, 13))
    sparse = jax.random.randint(KEY, (8, 26), 0, 256)
    logits = dcn_v2.dcn_fwd(p, cfg, dense, sparse)
    assert logits.shape == (8,) and _finite(logits)
    labels = jnp.ones((8,), jnp.float32)
    loss, grads = jax.value_and_grad(dcn_v2.dcn_loss)(p, cfg, dense, sparse,
                                                      labels)
    assert _finite(loss)
    assert all(_finite(g) for g in jax.tree.leaves(grads))


def test_mind_smoke_multi_interest():
    cfg = mind.MINDConfig(n_items=512, embed_dim=32, n_interests=4,
                          seq_len=16, n_negatives=7)
    p = mind.init_mind(KEY, cfg)
    hist = jax.random.randint(KEY, (4, 16), 1, 512)
    caps = mind.interest_capsules(p, cfg, hist)
    assert caps.shape == (4, 4, 32) and _finite(caps)
    # squash keeps capsule norms < 1
    assert float(jnp.linalg.norm(caps, axis=-1).max()) < 1.0
    tgt = jax.random.randint(KEY, (4,), 1, 512)
    loss = mind.mind_loss(p, cfg, hist, tgt, KEY)
    assert _finite(loss)
    s = mind.mind_serve(p, cfg, hist, hist[:, :6])
    assert s.shape == (4, 6)


def test_registry_covers_all_assigned_cells():
    cells = configs.all_cells()
    assert len(cells) == 41     # 40 assigned + paper's own
    archs = {a for a, _ in cells}
    assert len(archs) == 11
