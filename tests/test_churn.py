"""Epoch-consistent live catalog churn: double-buffered swaps,
stale-feedback quarantine, and churn fault injection.

Acceptance criteria covered here:
  * REGRESSION: feedback for an item retired between issue and delivery
    is QUARANTINED when the fold sees the current catalog — before the
    epoch/quarantine machinery it folded into learner state (the
    corrupt fold this file pins);
  * staleness bound: an in-flight shortlist tolerates exactly ONE stale
    epoch — issue-epoch feedback folds across a single publish, is
    quarantined from two publishes on, regardless of item liveness;
  * zero-churn serving is BIT-identical whether or not churn is staged:
    staging never perturbs the serving bank, single-host and on an
    8-device item-sharded mesh (subprocess);
  * the conservation identity
        issued == matched + in_flight + expired + dropped + stale
    holds EXACTLY after every delivery under sustained churn combined
    with delay / loss / duplication / torn swaps — single-host and
    8-device (subprocess), with identical seeded counters;
  * `Guarded` snapshots capture (state, catalog, epoch) as ONE unit: a
    churn-ceiling breach rolls all three back together.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import serve
from repro.core import catalog as catalog_mod, env
from repro.core.types import BanditHyper
from repro.serve import faults, guardrails, pending as pending_mod
from repro.train.checkpoint import CheckpointManager

from test_distributed import _run_with_devices

N, D, K, B = 32, 8, 10, 16
HYPER = BanditHyper(sigma=4, max_rounds=1, gamma=1.5, n_candidates=K)


def _session(capacity=128, ttl=16):
    return serve.OnlineBandit.create(
        N, D, HYPER, policy="distclub", refresh_every=N,
        pending_capacity=capacity, pending_ttl=ttl)


def _world(n_items=64, seed=3):
    e, _ = env.make_catalog_env(jax.random.PRNGKey(seed), N, D, 4,
                                n_items, n_candidates=K)
    return e, serve.make_catalog(env.catalog_embeddings(e))


def _reward_fn(theta):
    def reward_fn(key, uids, ctx, choice):
        return env.step_rewards(key, theta[uids], ctx, choice)
    return reward_fn


def _uids(i, n=B):
    return jax.random.randint(jax.random.PRNGKey(1000 + i), (n,), 0, N)


def _assert_states_equal(a, b):
    for la, lb in zip(jax.tree_util.tree_leaves(a),
                      jax.tree_util.tree_leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


# ---------------------------------------------------------------------------
# the regression this PR exists for: retired-item feedback must not fold
# ---------------------------------------------------------------------------


def test_retired_item_feedback_quarantined_not_folded():
    """Issue against epoch e, retire+publish every served item, deliver:
    with the catalog in the fold, every entry is quarantined (``stale``)
    and the learner state does not move.  Without it (the pre-epoch
    fold), the same delivery FOLDS — the corrupt behavior this test
    pins as the legacy path and the catalog-aware path must not share.
    """
    _, cat = _world()
    sess, items, ids, slots, ctx = serve.recommend_catalog(
        _session(), _uids(0), cat, k_short=8)
    served = jnp.unique(items)
    churned, _ = catalog_mod.retire_items(cat, served)
    churned = catalog_mod.publish(churned)

    before = sess.state
    quarantined = serve.observe_delayed(
        sess, ids, jnp.ones((B,), jnp.float32),
        key=jax.random.PRNGKey(0), catalog=churned)
    _assert_states_equal(before, quarantined.state)
    st = serve.pending_stats(quarantined)
    assert st["stale"] == B and st["matched"] == 0, st
    assert st["issued"] == (st["matched"] + st["in_flight"]
                           + st["expired"] + st["dropped"] + st["stale"])

    # the legacy catalog-blind fold DOES move state on the same input —
    # the corruption the quarantine exists to stop
    legacy = serve.observe_delayed(sess, ids, jnp.ones((B,), jnp.float32),
                                   key=jax.random.PRNGKey(0))
    assert serve.pending_stats(legacy)["matched"] == B
    occ_moved = np.asarray(legacy.state.occ) != np.asarray(before.occ)
    assert occ_moved.any()


def test_slot_reuse_after_retire_does_not_alias():
    """Retire a served item, publish, re-add a DIFFERENT item onto the
    freed slot, publish again: delivered feedback for the old decision
    must be quarantined even though the slot is live again — ``born``
    distinguishes the generations."""
    _, cat = _world()
    sess, items, ids, _, _ = serve.recommend_catalog(
        _session(), _uids(0), cat, k_short=8)
    victim = jnp.asarray([int(np.asarray(items)[0])], jnp.int32)
    c2, _ = catalog_mod.retire_items(cat, victim)
    c2 = catalog_mod.publish(c2)
    c2, slots2, _ = catalog_mod.add_items(
        c2, jnp.ones((1, D), jnp.float32) / np.sqrt(D))
    c2 = catalog_mod.publish(c2)
    assert np.asarray(slots2).tolist() == np.asarray(victim).tolist()
    assert int(c2.serving.live[int(victim[0])]) == 1

    sess = serve.observe_delayed(sess, ids, jnp.ones((B,), jnp.float32),
                                 key=jax.random.PRNGKey(0), catalog=c2)
    st = serve.pending_stats(sess)
    # every decision on the victim slot is stale (born > issue epoch);
    # note epoch lag is already 2 here, so the whole batch quarantines —
    # the aliasing hazard needs the batch to be un-foldable anyway
    assert st["stale"] == B and st["matched"] == 0, st


# ---------------------------------------------------------------------------
# the staleness bound: exactly one epoch of tolerated lag
# ---------------------------------------------------------------------------


def test_staleness_bound_exactly_one_epoch():
    """No-op publishes leave every item live, so liveness never blocks
    the fold: epoch lag alone draws the line.  lag 0 and lag 1 fold,
    lag 2 quarantines."""
    _, cat = _world()
    for lag, want_stale in [(0, 0), (1, 0), (2, B)]:
        sess, _, ids, _, _ = serve.recommend_catalog(
            _session(), _uids(0), cat, k_short=8)
        c = cat
        for _ in range(lag):
            c = catalog_mod.publish(c)      # nothing staged: item no-op
        sess = serve.observe_delayed(
            sess, ids, jnp.ones((B,), jnp.float32),
            key=jax.random.PRNGKey(1), catalog=c)
        st = serve.pending_stats(sess)
        assert st["stale"] == want_stale, (lag, st)
        assert st["matched"] == B - want_stale, (lag, st)


# ---------------------------------------------------------------------------
# zero-churn bit-parity: staging never touches serving
# ---------------------------------------------------------------------------


def test_staged_unpublished_churn_serves_bit_identical():
    """A session serving against a catalog with STAGED (unpublished)
    adds+retires makes bit-identical decisions and folds to
    bit-identical state vs the untouched catalog, with zero quarantine
    and epoch pinned at 0."""
    e, cat = _world()
    reward_fn = _reward_fn(e.theta)
    staged, _ = catalog_mod.retire_items(cat,
                                         jnp.array([1, 7, 30], jnp.int32))
    staged, _, _ = catalog_mod.add_items(
        staged, jnp.full((4, D), 0.5, jnp.float32))
    assert int(catalog_mod.staged_churn(staged)) > 0

    a, b = _session(), _session()
    for i in range(4):
        key = jax.random.PRNGKey(i)
        a, it_a, ids_a, slots_a, ctx_a = serve.recommend_catalog(
            a, _uids(i), cat, k_short=8)
        b, it_b, ids_b, slots_b, _ = serve.recommend_catalog(
            b, _uids(i), staged, k_short=8)
        np.testing.assert_array_equal(np.asarray(it_a), np.asarray(it_b))
        realized, _, _, _ = reward_fn(key, _uids(i), ctx_a, slots_a)
        a = serve.observe_delayed(a, ids_a, realized, key=key, catalog=cat)
        b = serve.observe_delayed(b, ids_b, realized, key=key,
                                  catalog=staged)
    _assert_states_equal(a.state, b.state)
    for s in (a, b):
        st = serve.pending_stats(s)
        assert st["stale"] == 0 and st["matched"] == 4 * B, st
    assert int(staged.epoch) == 0


def test_zero_churn_harness_identical_with_and_without_catalog_fold():
    """Churn-free traffic through the harness: passing the (never
    published) catalog to the fold changes nothing — same counters, same
    reward — i.e. the quarantine machinery is invisible until an epoch
    actually flips."""
    e, cat = _world(n_items=96)
    _, plain = faults.run_faulted_catalog(
        _session(capacity=256), e, 12, faults.FaultSpec(seed=2, p_delay=0.3,
                                                        p_loss=0.1),
        catalog=cat, k_short=8, batch=B, key=7, assert_conservation=True)
    assert plain.pending["stale"] == 0 and plain.publishes == 0
    assert plain.pending["issued"] == 12 * B


# ---------------------------------------------------------------------------
# conservation under churn x delivery faults (property-style grid)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("spec", [
    faults.FaultSpec(seed=1, p_delay=0.4, max_delay=4, p_loss=0.1,
                     p_dup=0.1, churn_every=2, churn_add=6,
                     churn_retire=6),
    faults.FaultSpec(seed=2, p_delay=0.3, max_delay=5, p_loss=0.05,
                     p_dup=0.05, churn_every=3, churn_add=8,
                     churn_retire=8, p_torn=0.5, swap_stall_rounds=1),
    faults.FaultSpec(seed=3, p_delay=0.5, max_delay=6, p_loss=0.2,
                     churn_every=2, churn_add=4, churn_retire=12,
                     flash_crowd_at=6, flash_crowd_size=16,
                     mass_retire_at=10),
], ids=["sustained", "torn_stalled", "flash_then_mass_retire"])
def test_conservation_identity_exact_under_churn_and_faults(spec):
    """issued == matched + in_flight + expired + dropped + stale after
    EVERY delivery transaction (asserted inside the harness), for churn
    crossed with delay/loss/dup/torn/stall — and some feedback really
    was quarantined, so the identity is exercised, not vacuous."""
    e, cat = _world(n_items=96, seed=spec.seed)
    sess, rep = faults.run_faulted_catalog(
        _session(capacity=256), e, 20, spec, catalog=cat, k_short=8,
        batch=B, key=spec.seed, assert_conservation=True)
    st = rep.pending
    assert st["issued"] == 20 * B
    assert st["issued"] == (st["matched"] + st["in_flight"]
                           + st["expired"] + st["dropped"] + st["stale"])
    assert st["stale"] > 0, st
    assert rep.publishes > 0
    assert int(pending_mod.conservation_gap(sess.pending)) == 0


def test_conservation_and_parity_8dev_item_sharded():
    """The same seeded churn+faults run on an 8-device item-sharded mesh:
    the conservation identity holds after every delivery AND every final
    counter matches the single-host run exactly (the per-shard stale
    mask combines to the same global verdicts)."""
    out = _run_with_devices("""
        import numpy as np
        import jax, jax.numpy as jnp
        from repro import serve
        from repro.core import catalog as catalog_mod, env
        from repro.core.types import BanditHyper
        from repro.distributed.distclub_shard import named_shardings
        from repro.serve import faults

        N, D, B = 64, 8, 16
        hyper = BanditHyper(sigma=4, max_rounds=1, gamma=1.5,
                            n_candidates=10)
        e, _ = env.make_catalog_env(jax.random.PRNGKey(0), N, D, 4, 128,
                                    n_candidates=10)
        cat = serve.make_catalog(env.catalog_embeddings(e))
        spec = faults.FaultSpec(seed=4, p_delay=0.4, max_delay=4,
                                p_loss=0.1, p_dup=0.05, churn_every=3,
                                churn_add=8, churn_retire=8, p_torn=0.5)

        def mk(sharded):
            if not sharded:
                return serve.OnlineBandit.create(
                    N, D, hyper, policy="distclub", refresh_every=2 * N,
                    pending_capacity=256, pending_ttl=16), cat
            mesh = jax.make_mesh((8,), ("users",))
            s = serve.OnlineBandit.sharded(
                mesh, N, D, hyper, policy="distclub",
                refresh_every=2 * N, pending_capacity=256,
                pending_ttl=16)
            c = jax.device_put(
                cat, named_shardings(mesh, catalog_mod.specs(("users",))))
            return s, c

        reports = []
        for sharded in (False, True):
            s, c = mk(sharded)
            _, rep = faults.run_faulted_catalog(
                s, e, 15, spec, catalog=c, k_short=16, batch=B, key=9,
                assert_conservation=True)
            reports.append(rep)
        r1, r8 = reports
        assert r1.pending == r8.pending, (r1.pending, r8.pending)
        assert r1.pending["stale"] > 0
        assert r1.pending["issued"] == (
            r1.pending["matched"] + r1.pending["in_flight"]
            + r1.pending["expired"] + r1.pending["dropped"]
            + r1.pending["stale"])
        assert (r1.publishes, r1.items_added, r1.items_retired) == \\
               (r8.publishes, r8.items_added, r8.items_retired)
        assert float(r1.reward) == float(r8.reward)
        print("CHURN-SHARD-CONSERVATION-OK")
    """)
    assert "CHURN-SHARD-CONSERVATION-OK" in out


# ---------------------------------------------------------------------------
# guardrails: (state, catalog, epoch) roll back as one unit
# ---------------------------------------------------------------------------


def test_guarded_snapshot_includes_catalog_and_epoch(tmp_path):
    """A churn-ceiling breach rolls back state AND catalog to the
    snapshot's epoch: the restored pair serves exactly what the
    snapshot-time pair served (the satellite fix: snapshots that
    captured only the state resumed against a future catalog)."""
    e, cat = _world(n_items=96)
    reward_fn = _reward_fn(e.theta)
    cfg = guardrails.GuardrailConfig(
        ctr_floor=0.0, churn_ceiling=0.25, warmup=10_000,
        snapshot_every=2, cooldown=2)
    g = guardrails.Guarded.create(
        _session(capacity=256), CheckpointManager(tmp_path / "gc", keep=4),
        cfg, catalog=cat)

    # healthy churn under traffic: small swaps stay below the ceiling
    for i in range(4):
        g, _, ids, slots, ctx = g.recommend_catalog(_uids(i), k_short=8)
        realized, _, _, _ = reward_fn(jax.random.PRNGKey(i), _uids(i),
                                      ctx, slots)
        g = g.observe_delayed(ids, realized, key=jax.random.PRNGKey(i))
        g, _ = g.stage_churn(add=jnp.full((2, D), 0.3, jnp.float32))
        g = g.publish()
    assert g.gs.rollbacks == 0
    epoch_before = int(g.catalog.epoch)
    snap_state, snap_cat = g.session.state, g.catalog
    assert epoch_before == 4

    # mass retirement blows through the ceiling -> epoch-consistent
    # rollback of the (state, catalog) pair
    live = np.nonzero(np.asarray(g.catalog.serving.live) > 0)[0]
    g, _ = g.stage_churn(retire=jnp.asarray(live[:60], dtype=jnp.int32))
    g = g.publish()
    assert g.gs.rollbacks == 1, g.events
    ev = [x for x in g.events if x[0] == "rollback"]
    assert ev[0][2] == ("churn_ceiling",)
    # catalog rolled back WITH the state: epoch and liveness match the
    # last healthy snapshot, not the poisoned publish
    assert int(g.catalog.epoch) == epoch_before
    _assert_states_equal(g.catalog, snap_cat)
    _assert_states_equal(g.session.state, snap_state)
    # ring cleared, id counter monotone: stale pre-rollback feedback
    # can never alias a post-rollback decision
    st = serve.pending_stats(g.session)
    assert st["in_flight"] == 0 and st["issued"] > 0


def test_checkpoint_roundtrip_state_and_catalog_pair(tmp_path):
    """The Guarded snapshot payload ({state, catalog}) restores through
    CheckpointManager.restore_latest as a pair, epochs included."""
    _, cat = _world()
    cat2, _ = catalog_mod.retire_items(cat, jnp.array([4, 9], jnp.int32))
    cat2 = catalog_mod.publish(cat2)
    sess = _session()
    ck = CheckpointManager(tmp_path / "pair", keep=2)
    ck.save({"state": sess.state, "catalog": cat2}, 7)
    like = {"state": _session().state,
            "catalog": serve.make_catalog(jnp.zeros((64, D), jnp.float32))}
    restored, step = ck.restore_latest(like)
    assert step == 7
    assert int(restored["catalog"].epoch) == 1
    _assert_states_equal(restored["catalog"], cat2)
    _assert_states_equal(restored["state"], sess.state)
