"""End-to-end behaviour of CLUB / DCCB / DistCLUB on planted environments."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import club, dccb, distclub, env, env_ops
from repro.core.types import BanditHyper

N, D, K, CLUSTERS = 64, 8, 10, 4


@pytest.fixture(scope="module")
def planted():
    e, labels = env.make_synthetic_env(
        jax.random.PRNGKey(0), n_users=N, d=D, n_clusters=CLUSTERS,
        n_candidates=K)
    return env_ops.synthetic_ops(e), labels


HYPER = BanditHyper(sigma=8, max_rounds=16, gamma=1.5, n_candidates=K)


def test_distclub_beats_random_and_learns(planted):
    ops, _ = planted
    state, m, nclu = distclub.run(ops, jax.random.PRNGKey(1), HYPER,
                                  n_epochs=6, d=D)
    T = int(m.interactions.sum())
    assert T == N * 2 * HYPER.sigma * 6
    reward = float(m.reward.sum())
    rand = float(m.rand_reward.sum())
    assert reward > rand * 1.2, (reward, rand)
    # later epochs beat earlier ones (learning)
    half = m.reward.shape[0] // 2
    r1 = float(m.reward[:half].sum()) / max(float(m.interactions[:half].sum()), 1)
    r2 = float(m.reward[half:].sum()) / max(float(m.interactions[half:].sum()), 1)
    assert r2 > r1


def test_distclub_discovers_clusters(planted):
    ops, _ = planted
    _, _, nclu = distclub.run(ops, jax.random.PRNGKey(1), HYPER,
                              n_epochs=6, d=D)
    assert int(nclu[0]) == 1          # starts connected
    assert int(nclu[-1]) > 1          # finds structure


def test_distclub_comm_model(planted):
    ops, _ = planted
    state, _, _ = distclub.run(ops, jax.random.PRNGKey(1), HYPER,
                               n_epochs=3, d=D)
    want = 3 * distclub.stage2_comm_bytes(N, D)   # 3 stage-2 rounds
    assert float(state.comm_bytes) == want
    # the tree-reduced (M, b) aggregates still dominate the model; the v
    # all-gather + CC label hops are additive, and the packed adjacency
    # contributes zero network bytes (it never leaves its shard).
    assert want < 3 * (2 * N * (D * D + D) + 2 * N * (D + 20)) * 4


def test_club_learns(planted):
    ops, _ = planted
    _, m = club.run(ops, jax.random.PRNGKey(2), HYPER, T=1024, d=D)
    assert float(m.reward.sum()) > float(m.rand_reward.sum()) * 1.1


def test_dccb_learns_and_comm_dominates_distclub(planted):
    ops, _ = planted
    L = 8
    st_d, m_d, _ = dccb.run(ops, jax.random.PRNGKey(3), HYPER,
                            n_epochs=16, d=D, L=L)
    # DCCB's buffer lag makes it barely better than random at this horizon
    # (the paper's accuracy complaint about it); it must still be above.
    assert float(m_d.reward.sum()) > float(m_d.rand_reward.sum()) * 1.01
    st_c, _, _ = distclub.run(ops, jax.random.PRNGKey(3), HYPER,
                              n_epochs=6, d=D)
    # paper Table 4: DCCB ships (L+1)(d^2+d) per user per round vs
    # DistCLUB's 2(d^2+d) per user per stage-2 -> DCCB >> DistCLUB
    # at matched interaction counts
    t_d = 16 * N * L
    t_c = int(6 * 2 * HYPER.sigma * N)
    per_i_d = float(st_d.comm_bytes) / t_d
    per_i_c = float(st_c.comm_bytes) / t_c
    assert per_i_d > 3 * per_i_c, (per_i_d, per_i_c)


def test_reward_ordering_matches_paper(planted):
    """Paper Table 5: DistCLUB reward >= DCCB reward (normalized)."""
    ops, _ = planted
    _, m_dc, _ = distclub.run(ops, jax.random.PRNGKey(5), HYPER,
                              n_epochs=4, d=D)
    _, m_db, _ = dccb.run(ops, jax.random.PRNGKey(5), HYPER,
                          n_epochs=8, d=D, L=8)
    r_dc = float(m_dc.reward.sum()) / float(m_dc.rand_reward.sum())
    r_db = float(m_db.reward.sum()) / float(m_db.rand_reward.sum())
    assert r_dc >= r_db * 0.98, (r_dc, r_db)


def test_stage4_rebalances_budgets(planted):
    """Users with above-cluster-mean history get MORE personalized rounds
    (paper stage 4); under uniform sampling deltas round to zero, so the
    mechanism is tested on a skewed state directly."""
    ops, _ = planted
    state = distclub.init_state(N, D, HYPER)
    skewed_occ = jnp.zeros((N,), jnp.int32).at[0].set(40)
    state = state._replace(
        lin=state.lin._replace(occ=skewed_occ),
        clusters=state.clusters._replace(
            seen=jax.ops.segment_sum(skewed_occ, state.graph.labels,
                                     num_segments=N)),
    )
    out = distclub.stage4(state, HYPER)
    assert int(out.u_rounds[0]) > HYPER.sigma          # heavy user: more S1
    assert int(out.c_rounds[0]) < HYPER.sigma          # ... fewer S3
    assert int(out.u_rounds[1]) <= HYPER.sigma         # light users: <= S1
    assert bool(jnp.all(out.u_rounds >= 0))
    assert bool(jnp.all(out.c_rounds <= HYPER.max_rounds))


def test_stage4_uses_stage2_snapshot(planted):
    """Regression for the unified lazy-snapshot semantics: stage 3 must NOT
    advance ``clusters.seen`` (it is the stage-2 snapshot), and stage 4's
    ``mean_occ`` must be computed from that snapshot — the single-host
    driver historically fed stage 4 a stage-3-updated counter while the
    sharded driver used the stage-2 value."""
    ops, _ = planted
    state = distclub.init_state(N, D, HYPER)
    state, _ = distclub.stage1(state, ops, jax.random.PRNGKey(11), HYPER)
    state = distclub.stage2(state, HYPER, D)
    seen_snapshot = np.asarray(state.clusters.seen).copy()

    state, _ = distclub.stage3(state, ops, jax.random.PRNGKey(12), HYPER)
    # stage 3 interacted (occ advanced) but the snapshot is frozen
    assert int(state.lin.occ.sum()) > int(seen_snapshot.sum())
    np.testing.assert_array_equal(np.asarray(state.clusters.seen),
                                  seen_snapshot)

    out = distclub.stage4(state, HYPER)
    # stage 4 deltas must come from the SNAPSHOT mean occ, i.e. match the
    # shared engine formula exactly
    labels = np.asarray(state.graph.labels)
    size = np.maximum(np.asarray(state.clusters.size)[labels], 1)
    mean_occ = seen_snapshot[labels].astype(np.float32) / size
    delta = ((np.asarray(state.lin.occ).astype(np.float32) - mean_occ)
             / 2.0).astype(np.int32)
    want_u = np.clip(np.asarray(state.u_rounds) + delta, 0, HYPER.max_rounds)
    want_c = np.clip(np.asarray(state.c_rounds) - delta, 0, HYPER.max_rounds)
    np.testing.assert_array_equal(np.asarray(out.u_rounds), want_u)
    np.testing.assert_array_equal(np.asarray(out.c_rounds), want_c)


def test_distclub_on_drift_env():
    """Non-stationary scenario: the learner beats random overall and the
    centroid re-draw is visible as a regret-rate spike at the phase
    boundary relative to the converged pre-drift rate."""
    from repro.core.env_ops import drift_ops

    denv, _ = env.make_drift_env(jax.random.PRNGKey(0), N, D, CLUSTERS, K,
                                 drift_period=64, n_phases=2)
    ops = drift_ops(denv)
    _, m, _ = distclub.run(ops, jax.random.PRNGKey(6), HYPER,
                           n_epochs=8, d=D)
    assert float(m.reward.sum()) > float(m.rand_reward.sum()) * 1.05
    # 16 interactions/user/epoch -> the re-draw at occ=64 lands in epoch 5
    per_epoch = m.regret.shape[0] // 8
    def rate(lo, hi):
        r = float(m.regret[lo * per_epoch:hi * per_epoch].sum())
        t = float(m.interactions[lo * per_epoch:hi * per_epoch].sum())
        return r / max(t, 1)
    converged = rate(3, 4)       # last pre-drift epoch
    post_drift = rate(4, 6)      # re-learning phase
    assert post_drift > converged, (post_drift, converged)


def test_regret_rate_decreases(planted):
    """Per-interaction regret should drop as estimates converge."""
    ops, _ = planted
    _, m, _ = distclub.run(ops, jax.random.PRNGKey(8), HYPER,
                           n_epochs=8, d=D)
    steps = m.regret.shape[0]
    q = steps // 4
    early = float(m.regret[:q].sum()) / max(float(m.interactions[:q].sum()), 1)
    late = float(m.regret[-q:].sum()) / max(float(m.interactions[-q:].sum()), 1)
    assert late < early


def test_movielens_replay_is_actual_logged_tables():
    """``make_env(kind="replay")`` materializes real logged tables for the
    paper-dataset clones (movielens here): contexts come from a fixed item
    catalog + per-user slate queues, so re-querying the same cursor with a
    different key returns the identical slate (the simulator would
    resample), and the learner still beats random on the log."""
    from repro.data.datasets import PAPER_DATASETS, make_env

    spec = PAPER_DATASETS["movielens"]
    ops, _ = make_env(spec, seed=1, kind="replay")
    assert (ops.n_users, ops.d, ops.n_candidates) == (943, 19, 20)
    occ = jnp.full((spec.n_users,), 3, jnp.int32)
    c1 = ops.contexts_fn(jax.random.PRNGKey(0), occ, 0)
    c2 = ops.contexts_fn(jax.random.PRNGKey(9), occ, 0)
    np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))
    # and the queues actually advance with the cursor
    c3 = ops.contexts_fn(jax.random.PRNGKey(0), occ + 1, 0)
    assert np.abs(np.asarray(c3) - np.asarray(c1)).max() > 0

    hyper = BanditHyper(sigma=8, max_rounds=16, gamma=1.5,
                        n_candidates=spec.n_candidates)
    _, m, _ = distclub.run(ops, jax.random.PRNGKey(4), hyper,
                           n_epochs=2, d=spec.d)
    assert int(m.interactions.sum()) == spec.n_users * 2 * 8 * 2
    # short-horizon replay: modest but reliable lift over random
    assert float(m.reward.sum()) > float(m.rand_reward.sum()) * 1.03


def test_distclub_on_replay_log():
    """Replay protocol: per-user queues of logged slates drive the rounds."""
    from repro.data.datasets import DatasetSpec
    from repro.data.replay import make_replay_env

    spec = DatasetSpec("tiny", 4096, 64, 8, 4, n_candidates=10)
    ops, _ = make_replay_env(spec, n_items=512, max_t=128, seed=3)
    state, m, nclu = distclub.run(ops, jax.random.PRNGKey(4), HYPER,
                                  n_epochs=3, d=8)
    assert int(m.interactions.sum()) == 64 * 2 * HYPER.sigma * 3
    assert float(m.reward.sum()) > float(m.rand_reward.sum()) * 1.05
