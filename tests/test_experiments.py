"""The online experimentation layer: sticky routing over policy arms.

Router invariants covered:
  * sticky assignment is deterministic and STABLE: shrinking one arm's
    fraction migrates exactly the users whose hash left the shrinking
    arm, and nobody else; unchanged fraction vectors migrate nobody;
  * a single-arm experiment at fraction 1.0 is BIT-identical to a plain
    `OnlineBandit` session — choices, decision ids, and state —
    single-host and on an 8-device mesh (subprocess);
  * a checkpoint round-trip through `CheckpointManager` resumes
    bit-identical routing and choices;
  * a sign-flip-poisoned arm breaches its per-arm guardrail, is
    auto-disabled (state rolled back, traffic re-routed to survivors —
    who keep every user they already had), and the experiment keeps
    serving; the LAST enabled arm is never disabled;
  * the Thompson-sampling meta-selector concentrates traffic on a
    planted-best arm, floors every enabled arm, and re-weights only at
    epoch boundaries.
"""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import serve
from repro.core import env
from repro.core.types import BanditHyper
from repro.serve import experiments, guardrails
from repro.train.checkpoint import CheckpointManager

from test_distributed import _run_with_devices

N, D, K, B = 32, 8, 10, 16
HYPER = BanditHyper(sigma=4, max_rounds=1, gamma=1.5, n_candidates=K)


def _session(policy="linucb", alpha=0.03, capacity=128, ttl=16):
    return serve.OnlineBandit.create(
        N, D, HYPER._replace(alpha=alpha), policy=policy, refresh_every=N,
        pending_capacity=capacity, pending_ttl=ttl)


@pytest.fixture(scope="module")
def world():
    e, _ = env.make_synthetic_env(jax.random.PRNGKey(0), N, D, 4, K)
    return e


def _uids(i, n=B):
    # includes negative padding rows
    return jax.random.randint(jax.random.PRNGKey(1000 + i), (n,), -2, N)


def _ctx(i, n=B):
    c = jax.random.normal(jax.random.PRNGKey(2000 + i), (n, K, D))
    return c / jnp.sqrt(jnp.float32(D))


def _assert_states_equal(a, b):
    for x, y in zip(jax.tree_util.tree_leaves(a),
                    jax.tree_util.tree_leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# sticky assignment
# ---------------------------------------------------------------------------


def test_sticky_assignment_deterministic_and_padded():
    uids = jnp.arange(-4, N)
    a1 = experiments.assign_arms(uids, (0.5, 0.5), (True, True), salt=9)
    a2 = experiments.assign_arms(uids, (0.5, 0.5), (True, True), salt=9)
    np.testing.assert_array_equal(np.asarray(a1), np.asarray(a2))
    assert (np.asarray(a1)[:4] == -1).all()          # uid<0 is padding
    assert set(np.asarray(a1)[4:]) <= {0, 1}
    # a different salt is a different (but still deterministic) split
    a3 = experiments.assign_arms(uids, (0.5, 0.5), (True, True), salt=10)
    assert (np.asarray(a1)[4:] != np.asarray(a3)[4:]).any()


def test_fraction_shrink_migrates_only_leavers():
    """0.5 -> 0.3 on arm 0: the only moves are OUT of the shrinking arm
    (hash in the surrendered [0.3, 0.5) band); growing/unchanged arms
    keep every user."""
    uids = jnp.arange(4 * N)
    before = np.asarray(experiments.assign_arms(
        uids, (0.5, 0.5), (True, True), salt=5))
    after = np.asarray(experiments.assign_arms(
        uids, (0.3, 0.7), (True, True), salt=5))
    moved = before != after
    assert moved.any()                       # the band is non-empty
    assert (before[moved] == 0).all() and (after[moved] == 1).all()


def test_unchanged_fractions_migrate_nobody():
    uids = jnp.arange(4 * N)
    f = (0.2, 0.5, 0.3)
    before = np.asarray(experiments.assign_arms(
        uids, f, (True,) * 3, salt=5))
    again = np.asarray(experiments.assign_arms(
        uids, f, (True,) * 3, salt=5))
    np.testing.assert_array_equal(before, again)


def test_disable_reroutes_without_migrating_survivors():
    uids = jnp.arange(4 * N)
    f = (0.4, 0.3, 0.3)
    before = np.asarray(experiments.assign_arms(
        uids, f, (True,) * 3, salt=2))
    after = np.asarray(experiments.assign_arms(
        uids, f, (True, False, True), salt=2))
    assert not (after == 1).any()            # nobody routes to the dead arm
    survivors = before != 1
    # every user of a surviving arm stays put
    np.testing.assert_array_equal(before[survivors], after[survivors])


# ---------------------------------------------------------------------------
# single-arm bit-parity with a plain session
# ---------------------------------------------------------------------------


def test_single_arm_parity_with_plain_session(world):
    """One arm at fraction 1.0 == a plain buffered session: choices,
    decision ids, and state bit-identical through issue/feedback rounds
    (the router masks to uid -1, which is the padding no-op)."""
    exp = experiments.create([_session()])
    plain = _session()
    for i in range(5):
        u, ctx = _uids(i), _ctx(i)
        exp, c_e, ids_e = experiments.recommend(exp, u, ctx)
        plain, c_p, ids_p = serve.recommend(plain, u, ctx)
        np.testing.assert_array_equal(np.asarray(c_e), np.asarray(c_p))
        np.testing.assert_array_equal(np.asarray(ids_e), np.asarray(ids_p))
        r, _, _, _ = env.step_rewards(jax.random.PRNGKey(3000 + i),
                                      world.theta[u], ctx, c_p)
        k = jax.random.PRNGKey(4000 + i)
        exp = experiments.observe_delayed(exp, ids_e, r, key=k)
        plain = serve.observe_delayed(plain, ids_p, r, key=k)
    _assert_states_equal(exp.arms[0].state, plain.state)
    _assert_states_equal(exp.arms[0].pending, plain.pending)


def test_single_arm_parity_sync_step(world):
    """The synchronous routed `step` has the same single-arm parity."""
    theta = world.theta

    def reward_fn(key, uids, ctx, choice):
        safe = jnp.clip(uids, 0, N - 1)
        return env.step_rewards(key, theta[safe], ctx, choice)

    exp = experiments.create([_session(capacity=0)])
    plain = _session(capacity=0)
    for i in range(4):
        u, ctx = _uids(i), _ctx(i)
        k = jax.random.PRNGKey(i)
        exp, c_e, _ = experiments.step(exp, k, u, ctx, reward_fn)
        plain, c_p, _ = serve.step(plain, k, u, ctx, reward_fn)
        np.testing.assert_array_equal(np.asarray(c_e), np.asarray(c_p))
    _assert_states_equal(exp.arms[0].state, plain.state)


def test_single_arm_parity_8dev_sharded():
    """Single-arm parity holds when the arm session is sharded over an
    8-device mesh — the router's masking composes with shard_map."""
    out = _run_with_devices("""
        import numpy as np
        import jax, jax.numpy as jnp
        from repro import serve
        from repro.serve import experiments
        from repro.core import env
        from repro.core.types import BanditHyper

        N, D, K, B = 64, 8, 10, 16
        hyper = BanditHyper(sigma=4, max_rounds=1, gamma=1.5,
                            n_candidates=K)
        e, _ = env.make_synthetic_env(jax.random.PRNGKey(0), N, D, 4, K)
        mesh = jax.make_mesh((8,), ("users",))
        mk = lambda: serve.OnlineBandit.sharded(
            mesh, N, D, hyper, policy="distclub", refresh_every=2 * N,
            pending_capacity=128, pending_ttl=16)
        exp = experiments.create([mk()])
        plain = mk()
        for i in range(4):
            u = jax.random.randint(jax.random.PRNGKey(100 + i), (B,),
                                   -2, N)
            ctx = jax.random.normal(jax.random.PRNGKey(200 + i),
                                    (B, K, D)) / np.sqrt(D)
            exp, c_e, ids_e = experiments.recommend(exp, u, ctx)
            plain, c_p, ids_p = serve.recommend(plain, u, ctx)
            np.testing.assert_array_equal(np.asarray(c_e), np.asarray(c_p))
            np.testing.assert_array_equal(np.asarray(ids_e),
                                          np.asarray(ids_p))
            r, _, _, _ = env.step_rewards(jax.random.PRNGKey(300 + i),
                                          e.theta[u], ctx, c_p)
            k = jax.random.PRNGKey(400 + i)
            exp = experiments.observe_delayed(exp, ids_e, r, key=k)
            plain = serve.observe_delayed(plain, ids_p, r, key=k)
        for x, y in zip(jax.tree_util.tree_leaves(exp.arms[0].state),
                        jax.tree_util.tree_leaves(plain.state)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
        print("EXP-SHARD-PARITY-OK")
    """)
    assert "EXP-SHARD-PARITY-OK" in out


def test_fraction_one_masked_path_parity(world):
    """fractions (1.0, 0.0): arm 0 owns all traffic THROUGH the masked
    multi-arm router (no single-arm fast path) and must still be
    bit-identical to the plain session — the mask-to-uid-(-1) no-op
    contract, exercised for real."""
    exp = experiments.create([_session(), _session(alpha=1.0)],
                             fractions=(1.0, 0.0), salt=6)
    plain = _session()
    for i in range(4):
        u, ctx = _uids(i), _ctx(i)
        exp, c_e, ids_e = experiments.recommend(exp, u, ctx)
        plain, c_p, ids_p = serve.recommend(plain, u, ctx)
        np.testing.assert_array_equal(np.asarray(c_e), np.asarray(c_p))
        # arm-encoded ids: local * 2 + 0
        np.testing.assert_array_equal(
            np.asarray(ids_e),
            np.where(np.asarray(ids_p) >= 0, np.asarray(ids_p) * 2, -1))
        r, _, _, _ = env.step_rewards(jax.random.PRNGKey(3000 + i),
                                      world.theta[u], ctx, c_p)
        k = jax.random.PRNGKey(4000 + i)
        exp = experiments.observe_delayed(exp, ids_e, r, key=k)
        plain = serve.observe_delayed(plain, ids_p, r, key=k)
    _assert_states_equal(exp.arms[0].state, plain.state)
    # the zero-fraction arm never saw a request
    _assert_states_equal(exp.arms[1].state,
                         _session(alpha=1.0).state)


# ---------------------------------------------------------------------------
# multi-arm routing
# ---------------------------------------------------------------------------


def test_multi_arm_routing_matches_masked_sub_sessions(world):
    """Each arm's state evolves exactly as a standalone session fed the
    masked sub-batches — routing is partition + merge, nothing more."""
    exp = experiments.create([_session(alpha=0.03), _session(alpha=1.0)],
                             salt=7)
    solo = [_session(alpha=0.03), _session(alpha=1.0)]
    for i in range(4):
        u, ctx = _uids(i), _ctx(i)
        arm_of = np.asarray(experiments.assign_arms(exp, u))
        exp, c_e, ids_e = experiments.recommend(exp, u, ctx)
        r, _, _, _ = env.step_rewards(jax.random.PRNGKey(3000 + i),
                                      world.theta[u], ctx, c_e)
        k = jax.random.PRNGKey(4000 + i)
        for a in range(2):
            u_a = jnp.where(jnp.asarray(arm_of) == a, u, -1)
            solo[a], c_s, ids_s = serve.recommend(solo[a], u_a, ctx)
            sel = arm_of == a
            np.testing.assert_array_equal(np.asarray(c_e)[sel],
                                          np.asarray(c_s)[sel])
            # decision ids are arm-encoded: local * n_arms + arm
            np.testing.assert_array_equal(
                np.asarray(ids_e)[sel],
                np.asarray(ids_s)[sel] * 2 + a)
            solo[a] = serve.observe_delayed(solo[a], ids_s, r, key=k)
        exp = experiments.observe_delayed(exp, ids_e, r, key=k)
    for a in range(2):
        _assert_states_equal(exp.arms[a].state, solo[a].state)


# ---------------------------------------------------------------------------
# checkpoint round-trip
# ---------------------------------------------------------------------------


def test_checkpoint_roundtrip_resumes_identical_routing(world, tmp_path):
    """save -> (new process) restore resumes bit-identical routing AND
    choices — salt, fractions, selector posteriors, arm states, pending
    rings all round-trip."""
    def mk():
        return experiments.create(
            [_session(alpha=0.03), _session(alpha=1.0)], salt=13,
            selector=experiments.make_selector(2, epoch_rounds=3))

    ck = CheckpointManager(tmp_path / "exp", keep=2)
    exp = mk()
    exp, _ = experiments.run_experiment(exp, world.theta, 7, batch=B,
                                        key=5)
    experiments.save(exp, ck, 7)
    cont, _ = experiments.run_experiment(exp, world.theta, 6, batch=B,
                                         key=99)

    fresh, step = experiments.restore(mk(), ck, 7)
    assert step == 7 and fresh.steps == exp.steps
    assert fresh.fractions == exp.fractions
    # routing is bit-identical after restore ...
    uids = jnp.arange(N)
    np.testing.assert_array_equal(
        np.asarray(experiments.assign_arms(exp, uids)),
        np.asarray(experiments.assign_arms(fresh, uids)))
    # ... and so is everything the resumed run produces
    cont2, _ = experiments.run_experiment(fresh, world.theta, 6, batch=B,
                                          key=99)
    for a in range(2):
        _assert_states_equal(cont.arms[a].state, cont2.arms[a].state)
    np.testing.assert_array_equal(cont.totals["reward"],
                                  cont2.totals["reward"])
    assert cont.fractions == cont2.fractions


def test_restore_empty_dir_is_noop(tmp_path):
    exp = experiments.create([_session()])
    same, step = experiments.restore(
        exp, CheckpointManager(tmp_path / "none"), None)
    assert step is None and same.steps == 0


# ---------------------------------------------------------------------------
# per-arm guardrails: auto-disable + re-route
# ---------------------------------------------------------------------------


def _poisoned_loop(exp, theta, rounds, flip_arm):
    """Drive the experiment with arm ``flip_arm``'s delivered rewards
    sign-flipped (the targeted poisoning fault) and everyone else
    healthy."""
    A = exp.n_arms
    for i in range(rounds):
        u = jax.random.randint(jax.random.PRNGKey(100 + i), (B,), 0, N)
        ctx = _ctx(i)
        exp, ch, ids = experiments.recommend(exp, u, ctx)
        r, _, _, _ = env.step_rewards(jax.random.PRNGKey(300 + i),
                                      theta[u], ctx, ch)
        arm_of = jnp.where(ids >= 0, ids % A, -1)
        r = jnp.where(arm_of == flip_arm, -r, r)
        exp = experiments.observe_delayed(exp, ids, r,
                                          key=jax.random.PRNGKey(400 + i))
    return exp


def test_poisoned_arm_auto_disabled_and_rerouted(world):
    cfg = guardrails.GuardrailConfig(ctr_floor=0.2, warmup=2 * B,
                                     ema=0.6, cooldown=2)
    exp = experiments.create(
        [_session(alpha=0.03), _session(alpha=0.03)], salt=3,
        guard_cfg=cfg, snapshot_every=2)
    healthy_anchor = exp.arms[1].state
    exp = _poisoned_loop(exp, world.theta, 12, flip_arm=1)
    assert exp.enabled == (True, False)
    kinds = [e[0] for e in exp.events]
    assert "disable" in kinds
    # all traffic now routes to the survivor; the survivor's users never
    # migrated (sticky fallback)
    uids = jnp.arange(N)
    arm = np.asarray(experiments.assign_arms(exp, uids))
    assert (arm == 0).all()
    # the poisoned arm's state was rolled back to a pre-breach snapshot
    # (its pending ring cleared), not left poisoned
    assert exp.arms[1].pending.uid.max() < 0
    disable_step = [e[1] for e in exp.events if e[0] == "disable"][0]
    assert disable_step <= 12
    # the rollback anchor is from before the breach tripped: folding the
    # flipped rewards for `disable_step` more rounds from the anchor
    # diverges, so the restored state must be older than the final
    # poisoned state would have been
    assert exp.guards[1].rollbacks == 1
    del healthy_anchor


def test_last_enabled_arm_is_never_disabled(world):
    cfg = guardrails.GuardrailConfig(ctr_floor=0.2, warmup=B, ema=0.6,
                                     cooldown=1)
    exp = experiments.create([_session(alpha=0.03)], guard_cfg=cfg)
    exp = _poisoned_loop(exp, world.theta, 8, flip_arm=0)
    assert exp.enabled == (True,)
    assert any(e[0] == "breach-last-arm" for e in exp.events)


# ---------------------------------------------------------------------------
# the probation window
# ---------------------------------------------------------------------------


def test_probation_survivor_intervals_untouched():
    """The ISSUE regression test: through disable -> throttled probation
    -> restore, a surviving arm's sticky hash interval never moves — not
    one user a healthy arm owns is reassigned at any stage."""
    uids = jnp.arange(4 * N)
    f = (0.4, 0.3, 0.3)
    full = np.asarray(experiments.assign_arms(uids, f, (True,) * 3,
                                              salt=2))
    dis = np.asarray(experiments.assign_arms(uids, f, (True, False, True),
                                             salt=2))
    prob = np.asarray(experiments.assign_arms(
        uids, f, (True,) * 3, salt=2, scale=(1.0, 0.25, 1.0)))
    survivors = full != 1
    np.testing.assert_array_equal(full[survivors], dis[survivors])
    np.testing.assert_array_equal(full[survivors], prob[survivors])
    # the probation arm takes back a non-empty strict SUBSET of its own
    # full interval...
    back = prob == 1
    assert back.any() and back.sum() < (full == 1).sum()
    assert (full[back] == 1).all()
    # ...and every user it does NOT take back stays exactly where the
    # disable-time fallback sent them
    fell = (full == 1) & ~back
    np.testing.assert_array_equal(prob[fell], dis[fell])
    # restore == the original full cut, bit for bit
    rest = np.asarray(experiments.assign_arms(
        uids, f, (True,) * 3, salt=2, scale=(1.0, 1.0, 1.0)))
    np.testing.assert_array_equal(rest, full)


def test_probation_lifecycle_reenable_throttled_then_restore(world):
    """A breached arm sits out `probation_tx` transactions, comes back
    throttled to `probation_fraction` of its own interval, and a clean
    probation window restores it to full traffic."""
    cfg = guardrails.GuardrailConfig(ctr_floor=0.2, warmup=2 * B, ema=0.6,
                                     cooldown=2)
    exp = experiments.create(
        [_session(alpha=0.03), _session(alpha=0.03)], salt=3,
        guard_cfg=cfg, snapshot_every=2, probation_tx=3,
        probation_fraction=0.25)
    rounds = 0
    while exp.enabled == (True, True) and rounds < 20:
        exp = _poisoned_loop(exp, world.theta, 1, flip_arm=1)
        rounds += 1
    assert exp.enabled == (True, False)
    assert exp.stages[1] == experiments.BENCHED
    uids = jnp.arange(N)
    full = np.asarray(experiments.assign_arms(
        uids, exp.fractions, (True, True), salt=3))
    # three clean routing transactions serve out the bench window
    exp = _poisoned_loop(exp, world.theta, 3, flip_arm=-1)
    assert exp.enabled == (True, True)
    assert exp.stages[1] == experiments.PROBATION
    assert any(e[0] == "probation" for e in exp.events)
    arm = np.asarray(experiments.assign_arms(exp, uids))
    back = arm == 1
    assert back.any() and back.sum() < (full == 1).sum()
    assert (full[back] == 1).all()
    np.testing.assert_array_equal(arm[full == 0], full[full == 0])
    # a clean probation window promotes the arm back to its full interval
    exp = _poisoned_loop(exp, world.theta, 3, flip_arm=-1)
    assert exp.stages[1] == experiments.HEALTHY
    assert any(e[0] == "restore" for e in exp.events)
    np.testing.assert_array_equal(
        np.asarray(experiments.assign_arms(exp, uids)), full)


def test_probation_second_breach_is_permanent(world):
    """An arm that breaches again WHILE ON probation is permanently
    disabled — no further probation windows."""
    cfg = guardrails.GuardrailConfig(ctr_floor=0.2, warmup=B, ema=0.6,
                                     cooldown=1)
    exp = experiments.create(
        [_session(alpha=0.03), _session(alpha=0.03)], salt=3,
        guard_cfg=cfg, snapshot_every=2, probation_tx=2,
        probation_fraction=0.5)
    exp = _poisoned_loop(exp, world.theta, 40, flip_arm=1)
    assert any(e[0] == "probation" for e in exp.events)
    assert any(e[0] == "disable-permanent" for e in exp.events)
    assert exp.stages[1] == experiments.PERMANENT
    assert exp.enabled == (True, False)
    # permanently out: more clean traffic never re-enables it
    exp = _poisoned_loop(exp, world.theta, 6, flip_arm=-1)
    assert exp.enabled == (True, False)
    assert exp.stages[1] == experiments.PERMANENT


# ---------------------------------------------------------------------------
# the meta-selector
# ---------------------------------------------------------------------------


def test_selector_concentrates_on_planted_best(world):
    """Two copycat arms with absurd exploration vs one tuned arm: the
    posterior routes the majority of traffic to the tuned arm, keeps the
    floor on the others, and only moves fractions at epoch boundaries."""
    arms = [_session(alpha=0.05), _session(alpha=50.0),
            _session(alpha=50.0)]
    exp = experiments.create(
        arms, names=("good", "noisy1", "noisy2"), salt=11,
        selector=experiments.make_selector(3, epoch_rounds=10, floor=0.05))
    exp, rep = experiments.run_experiment(exp, world.theta, 60, batch=B,
                                          key=5)
    assert rep.leader == "good"
    assert rep.fractions[0] >= 0.6
    assert all(f > 0 for f in rep.fractions)         # floored, not starved
    # fractions moved only at epoch boundaries (10 rounds apart)
    assert [s % 10 for s, _ in rep.shares] == [0] * len(rep.shares)
    assert len(rep.shares) == 7                      # t=0 + 6 epochs


def test_selector_bucketed_posteriors_update(world):
    sel = experiments.make_selector(2, epoch_rounds=5,
                                    bucket_edges=(3, 21))
    exp = experiments.create([_session(), _session(alpha=1.0)],
                             selector=sel, salt=1)
    exp, _ = experiments.run_experiment(exp, world.theta, 10, batch=B,
                                        key=2)
    sel = exp.selector
    # prior mass was 1+1 per cell; observed feedback landed somewhere
    assert float(sel.alpha.sum() + sel.beta.sum()) > 2.0 * sel.alpha.size
    assert sel.alpha.shape == (3, 2)


def test_report_fields(world):
    exp = experiments.create([_session(), _session(alpha=1.0)],
                             names=("a", "b"), salt=4)
    exp, rep = experiments.run_experiment(exp, world.theta, 6, batch=B,
                                          key=8)
    assert rep.rounds == 6 and rep.names == ("a", "b")
    assert len(rep.reward) == 2 and len(rep.matched_ratio) == 2
    assert sum(rep.interactions) > 0
    assert rep.regret == tuple(b - e for b, e in zip(rep.best,
                                                     rep.expected))
    assert np.isfinite(rep.z_leading_pair)
    assert rep.leader in rep.names and rep.runner_up in rep.names
