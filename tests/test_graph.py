"""Stage-2 graph engine: bit-packed adjacency, tiled prune, fused CC hop.

All Pallas runs use interpret=True (no TPU in this container) with small
block sizes so every test exercises a multi-tile grid; the same code path
compiles on TPU with interpret=False.  Parity against the dense oracle is
EXACT (bit/label equality): the feature dim is the only contracted axis,
so tiling over (i, j) cannot change any per-element contraction order.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import backend, clustering, distclub, env, env_ops
from repro.core.types import BanditHyper
from repro.kernels.graph import ops as graph_ops


def random_sym_adj(rng, n, p):
    a = rng.random((n, n)) < p
    a = np.triu(a, 1)
    return a | a.T


def chain_adj(n):
    """Path graph 0-1-...-n-1: one component, max-diameter — the
    pointer-doubling worst case."""
    a = np.zeros((n, n), bool)
    i = np.arange(n - 1)
    a[i, i + 1] = a[i + 1, i] = True
    return a


# ---- packing ---------------------------------------------------------------

@pytest.mark.parametrize("n", [1, 7, 32, 37, 100, 256])
def test_pack_unpack_roundtrip(n):
    rng = np.random.default_rng(n)
    dense = random_sym_adj(rng, n, 0.3)
    packed = graph_ops.pack_bits(jnp.asarray(dense))
    assert packed.shape == (n, (n + 31) // 32) and packed.dtype == jnp.uint32
    np.testing.assert_array_equal(
        np.asarray(graph_ops.unpack_bits(packed, n)), dense)


def test_pack_padding_bits_are_zero():
    """Bits at columns >= n must be 0 — the AND-monotone invariant."""
    n = 37
    dense = jnp.ones((n, n), bool)
    packed = graph_ops.pack_bits(dense)
    full = graph_ops.unpack_bits(packed, packed.shape[1] * 32)
    assert not bool(full[:, n:].any())


@pytest.mark.parametrize("n", [5, 33, 64, 100])
def test_init_packed_adj_matches_dense(n):
    got = graph_ops.unpack_bits(graph_ops.init_packed_adj(n, n), n)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(clustering.dense_adj(n)))


def test_init_packed_adj_row_offset():
    """Sharded rows clear their own global column, not the local index."""
    n, n_local, off = 64, 16, 16
    got = graph_ops.unpack_bits(
        graph_ops.init_packed_adj(n_local, n, row_offset=off), n)
    want = np.ones((n_local, n), bool)
    want[np.arange(n_local), np.arange(n_local) + off] = False
    np.testing.assert_array_equal(np.asarray(got), want)


# ---- prune -----------------------------------------------------------------

# Ragged on purpose: n not a multiple of 32 nor of the block sizes.
@pytest.mark.parametrize("n,d", [(37, 5), (70, 8), (130, 3)])
def test_prune_packed_matches_dense_oracle(n, d):
    rng = np.random.default_rng(n * 10 + d)
    v = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    occ = jnp.asarray(rng.integers(0, 100, n), jnp.int32)
    dense0 = random_sym_adj(rng, n, 0.7)
    want = clustering.prune_edges(jnp.asarray(dense0), v, occ, gamma=1.2)

    packed = graph_ops.pack_bits(jnp.asarray(dense0))
    cb = clustering.cb_width(occ)
    for kwargs in (
        dict(use_pallas=False, row_block=16),
        dict(use_pallas=True, interpret=True, block_i=16, block_j=32),
        dict(use_pallas=True, interpret=True, block_i=8, block_j=64),
    ):
        got = graph_ops.unpack_bits(
            graph_ops.prune_packed(packed, v, cb, v, cb, 1.2, **kwargs), n)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want),
                                      err_msg=str(kwargs))


def test_prune_is_and_monotone():
    """Pruning can only clear bits, never set them (packing invariant)."""
    n, d = 50, 4
    rng = np.random.default_rng(0)
    v = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    occ = jnp.full((n,), 1000, jnp.int32)
    dense0 = random_sym_adj(rng, n, 0.2)
    packed = graph_ops.pack_bits(jnp.asarray(dense0))
    cb = clustering.cb_width(occ)
    out = graph_ops.prune_packed(packed, v, cb, v, cb, 0.5, use_pallas=False)
    assert not bool((np.asarray(out) & ~np.asarray(packed)).any())


# ---- connected components --------------------------------------------------

@pytest.mark.parametrize("maker,n", [
    ("random_sparse", 60), ("random_sparse", 129), ("random_dense", 75),
    ("chain", 300), ("chain", 64), ("empty", 40),
])
def test_cc_packed_matches_dense(maker, n):
    rng = np.random.default_rng(n)
    dense = {"random_sparse": lambda: random_sym_adj(rng, n, 0.02),
             "random_dense": lambda: random_sym_adj(rng, n, 0.3),
             "chain": lambda: chain_adj(n),
             "empty": lambda: np.zeros((n, n), bool)}[maker]()
    want = clustering.connected_components(jnp.asarray(dense))
    packed = graph_ops.pack_bits(jnp.asarray(dense))
    gb_ref = backend.BackendConfig.create("reference").graph(n,
                                                             row_block=16)
    gb_pal = backend.BackendConfig.create("pallas").graph(
        n, interpret=True, block_i=16, block_j=64)
    np.testing.assert_array_equal(np.asarray(gb_ref.cc(packed)),
                                  np.asarray(want))
    np.testing.assert_array_equal(np.asarray(gb_pal.cc(packed)),
                                  np.asarray(want))


def test_cc_hop_bipartite_rows():
    """The sharded runtime runs the hop on a row shard against the full
    replicated label vector."""
    n, n_local, off = 96, 32, 32
    rng = np.random.default_rng(7)
    dense = random_sym_adj(rng, n, 0.05)
    labels = jnp.asarray(rng.permutation(n).astype(np.int32))
    rows = jnp.asarray(dense[off:off + n_local])
    want = jnp.minimum(
        labels[off:off + n_local],
        jnp.min(jnp.where(rows, labels[None, :], jnp.int32(n)), axis=1))

    packed_rows = graph_ops.pack_bits(rows)
    for kwargs in (dict(use_pallas=False, row_block=8),
                   dict(use_pallas=True, interpret=True,
                        block_i=8, block_j=32)):
        got = graph_ops.cc_hop_packed(
            packed_rows, labels[off:off + n_local], labels, **kwargs)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want),
                                      err_msg=str(kwargs))


# ---- backend dispatch ------------------------------------------------------

def test_graph_backend_dispatch_and_env_flag(monkeypatch):
    monkeypatch.delenv("REPRO_BACKEND", raising=False)
    gb = backend.BackendConfig.create().graph(100)   # auto on CPU -> ref
    assert gb.kind == "reference" and gb.words == 4

    monkeypatch.setenv("REPRO_BACKEND", "pallas")
    gb = backend.BackendConfig.create().graph(100)
    assert gb.kind == "pallas" and gb.interpret

    monkeypatch.setenv("REPRO_BACKEND", "bogus")
    with pytest.raises(ValueError):
        backend.BackendConfig.create().graph(100)


def test_graph_backend_pack_roundtrip_and_init():
    gb = backend.BackendConfig.create("reference").graph(45)
    dense = clustering.dense_adj(45)
    np.testing.assert_array_equal(np.asarray(gb.unpack(gb.pack(dense))),
                                  np.asarray(dense))
    np.testing.assert_array_equal(np.asarray(gb.unpack(gb.init_adj())),
                                  np.asarray(dense))


# ---- end-to-end ------------------------------------------------------------

def test_distclub_stage2_reference_vs_pallas_interpret():
    """Acceptance: end-to-end distclub agreement between the reference and
    pallas engines now COVERS stage 2 — identical pruned-edge bits,
    identical CC labels, identical cluster counts, and stage-1/3 state
    within PR 1's tolerances."""
    N, D, K = 24, 5, 10
    hyper = BanditHyper(sigma=4, max_rounds=8, gamma=1.5, n_candidates=K)
    e, _ = env.make_synthetic_env(jax.random.PRNGKey(0), N, D, 3, K)
    ops = env_ops.synthetic_ops(e)
    ref_i = backend.BackendConfig.create("reference").interact(N, D, K)
    pal_i = backend.BackendConfig.create("pallas").interact(
        N, D, K, interpret=True)
    ref_g = backend.BackendConfig.create("reference").graph(N)
    pal_g = backend.BackendConfig.create("pallas").graph(
        N, interpret=True, block_i=8, block_j=32)

    s_r, m_r, c_r = distclub.run(ops, jax.random.PRNGKey(1), hyper,
                                 n_epochs=2, d=D, backend=ref_i, graph=ref_g)
    s_p, m_p, c_p = distclub.run(ops, jax.random.PRNGKey(1), hyper,
                                 n_epochs=2, d=D, backend=pal_i, graph=pal_g)
    np.testing.assert_array_equal(np.asarray(s_p.graph.adj),
                                  np.asarray(s_r.graph.adj))
    np.testing.assert_array_equal(np.asarray(s_p.graph.labels),
                                  np.asarray(s_r.graph.labels))
    np.testing.assert_array_equal(np.asarray(c_p), np.asarray(c_r))
    np.testing.assert_allclose(s_p.lin.Minv, s_r.lin.Minv, atol=1e-5)
    np.testing.assert_allclose(s_p.lin.b, s_r.lin.b, atol=1e-5)
    np.testing.assert_allclose(m_p.reward, m_r.reward, atol=1e-6)


def test_distclub_state_carries_packed_graph():
    """The [n, n] bool graph is gone from the carried state."""
    N, D = 40, 4
    state = distclub.init_state(N, D, BanditHyper())
    assert state.graph.adj.shape == (N, (N + 31) // 32)
    assert state.graph.adj.dtype == jnp.uint32
