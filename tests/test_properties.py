"""Hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_shim import given, settings, st

from repro.models import moe, transformer
from repro.train import checkpoint, optimizer


@settings(max_examples=15, deadline=None)
@given(st.integers(2, 6), st.integers(1, 3), st.integers(0, 2**31 - 1),
       st.floats(0.25, 4.0))
def test_moe_dispatch_invariants(n_experts, top_k, seed, cf):
    """Capacity dispatch: unique slots among kept tokens; per-expert load
    <= capacity; combine weights of kept choices sum to <= 1 per token."""
    top_k = min(top_k, n_experts)
    cfg = transformer.LMConfig(
        d_model=16, n_experts=n_experts, top_k=top_k, n_shared=0,
        d_ff_expert=8, capacity_factor=cf, dtype=jnp.float32)
    T = 32
    key = jax.random.PRNGKey(seed)
    params = moe.init_moe(key, cfg, jnp.float32)
    xt = jax.random.normal(key, (T, 16))

    logits = xt @ params["router"]
    probs = jax.nn.softmax(logits, -1)
    gv, gi = jax.lax.top_k(probs, top_k)
    C = int(max(1, round(T * top_k / n_experts * cf)))
    flat_e = np.asarray(gi).reshape(-1)
    onehot = (flat_e[:, None] == np.arange(n_experts)).astype(np.int64)
    pos = np.take_along_axis(np.cumsum(onehot, 0), flat_e[:, None], 1)[:, 0] - 1
    keep = pos < C
    slots = flat_e[keep] * C + pos[keep]
    assert len(np.unique(slots)) == keep.sum()          # no slot collisions
    for e in range(n_experts):
        assert np.sum((flat_e == e) & keep) <= C        # capacity respected

    out, aux = moe.moe_fwd(params, cfg, xt[None])
    assert bool(jnp.all(jnp.isfinite(out)))
    assert float(aux) > 0.5                  # load-balance loss is O(1)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_checkpoint_roundtrip_arbitrary_pytrees(seed):
    import tempfile

    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 4)
    state = {
        "a": jax.random.normal(ks[0], (3, 5)),
        "nested": [{"b": jax.random.randint(ks[1], (7,), 0, 100)},
                   {"c": jax.random.normal(ks[2], ()).astype(jnp.bfloat16)}],
        "d": (jax.random.normal(ks[3], (2, 2, 2)),),
    }
    mgr = checkpoint.CheckpointManager(tempfile.mkdtemp(), keep=1)
    mgr.save(state, 1)
    restored, _ = mgr.restore_latest(jax.eval_shape(lambda: state))
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a).astype(np.float32),
                                      np.asarray(b).astype(np.float32))


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_adamw_invariant_to_chunking(seed):
    key = jax.random.PRNGKey(seed)
    p = {"w": jax.random.normal(key, (6, 64, 96))}
    g = {"w": jax.random.normal(jax.random.fold_in(key, 1), (6, 64, 96))}
    opt = optimizer.adamw_init(p)
    ref, _ = optimizer.adamw_update(g, opt, p)
    old = optimizer._CHUNK_BYTES
    try:
        optimizer._CHUNK_BYTES = 1
        got, _ = optimizer.adamw_update(g, opt, p)
    finally:
        optimizer._CHUNK_BYTES = old
    np.testing.assert_allclose(got["w"], ref["w"], rtol=1e-6, atol=1e-6)
