"""The unified `Precision` backend policy (README "Precision policies").

Covers the API redesign's acceptance criteria:
  * `Precision.f32` — the default — is bit-identical to the pre-policy
    behavior: explicit-f32 sessions match default sessions choice for
    choice and state bit for bit, across reference/pallas engines and
    single-host/8-device sharded serving;
  * bf16/int8 sessions bound the per-decision choice-flip rate vs the
    f32 oracle on seeded traffic (counterfactual probes on the oracle's
    own trajectory — occ/b stay exact, flips come only from the score
    contraction; see benchmarks/bench_precision.py for the full-size
    gated run);
  * int8 per-slot dequant scales survive catalog churn: staged
    retire/add, double-buffered publish, and slot reclaim keep every
    untouched slot's codes+scale bit-identical and give churn-added rows
    fresh per-row scales with the quantization error bound intact;
  * checkpoints record the precision policy and `restore` fails loudly
    on a mismatch — a reduced-precision snapshot is not silently
    reinterpretable;
  * cluster-pruned retrieval stays EXACT under quantized tile summaries
    (conservative dequantized bounds — `core.itemclub`);
  * the deprecated backend factories (`get_backend` & co.) still serve
    the same engines as the `BackendConfig` API that replaced them.
"""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import serve
from repro.core import catalog as catalog_mod
from repro.core import env
from repro.core.backend import (BackendConfig, Precision, get_backend,
                                get_graph_backend, get_retrieval_backend,
                                resolve_precision)
from repro.core.types import BanditHyper
from repro.train.checkpoint import CheckpointManager

from test_distributed import _run_with_devices

D, KS = 16, 16
N_USERS, N_ITEMS, B = 64, 512, 32
HYPER = BanditHyper(alpha=0.05, gamma=1.5, n_candidates=KS)


def _world(seed=7):
    k = jax.random.normal(jax.random.PRNGKey(seed), (N_ITEMS, D))
    emb = k / jnp.linalg.norm(k, axis=-1, keepdims=True)
    th = jax.random.normal(jax.random.PRNGKey(seed + 1), (N_USERS, D))
    theta = th / jnp.linalg.norm(th, axis=-1, keepdims=True)
    return emb, theta


def _session(precision=None, backend="reference", interpret=None):
    return serve.OnlineBandit.create(N_USERS, D, HYPER, policy="distclub",
                                     refresh_every=0, backend=backend,
                                     interpret=interpret,
                                     precision=precision)


def _uids(t):
    return jax.random.permutation(jax.random.PRNGKey(100 + t),
                                  N_USERS)[:B].astype(jnp.int32)


def _reward_fn(theta):
    def reward_fn(key, u, ctx, choice):
        return env.step_rewards(key, theta[u], ctx, choice)
    return reward_fn


# ---------------------------------------------------------------------------
# f32 bit-identity
# ---------------------------------------------------------------------------

def test_f32_policy_is_bit_identical_to_default():
    """Explicit `precision="f32"` is the default policy: same compiled
    transaction, bit-equal choices and state."""
    emb, theta = _world()
    rf = _reward_fn(theta)
    s_def, s_f32 = _session(None), _session("f32")
    cat = serve.make_catalog(emb)
    cat_f32 = serve.make_catalog(emb, precision="f32")
    assert s_f32.policy.cfg.engine.precision == Precision.f32
    assert s_f32.state.Minv.dtype == jnp.float32
    np.testing.assert_array_equal(np.asarray(cat.emb),
                                  np.asarray(cat_f32.emb))
    for t in range(3):
        k, u = jax.random.PRNGKey(1000 + t), _uids(t)
        s_def, c1, _ = serve.step_catalog(s_def, k, u, cat, rf, k_short=KS)
        s_f32, c2, _ = serve.step_catalog(s_f32, k, u, cat_f32, rf,
                                          k_short=KS)
        np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))
    np.testing.assert_array_equal(np.asarray(s_def.state.Minv),
                                  np.asarray(s_f32.state.Minv))
    np.testing.assert_array_equal(np.asarray(s_def.state.b),
                                  np.asarray(s_f32.state.b))


def test_f32_reference_vs_pallas_engines_identical():
    """The f32 policy through the pallas(-interpret) engine serves the
    reference engine's choices bit for bit."""
    emb, theta = _world()
    rf = _reward_fn(theta)
    sr = _session("f32", backend="reference")
    sp = _session("f32", backend="pallas", interpret=True)
    cat = serve.make_catalog(emb, precision="f32")
    for t in range(2):
        k, u = jax.random.PRNGKey(1000 + t), _uids(t)
        sr, cr, _ = serve.step_catalog(sr, k, u, cat, rf, k_short=KS)
        sp, cp, _ = serve.step_catalog(sp, k, u, cat, rf, k_short=KS)
        np.testing.assert_array_equal(np.asarray(cr), np.asarray(cp))
    np.testing.assert_allclose(np.asarray(sr.state.Minv),
                               np.asarray(sp.state.Minv), atol=1e-5)


def test_f32_sharded_8dev_matches_single_host():
    out = _run_with_devices("""
        import numpy as np
        import jax, jax.numpy as jnp
        from repro import serve
        from repro.core import env
        from repro.core.types import BanditHyper

        N, D, KS, B, NI = 64, 16, 16, 32, 512
        hyper = BanditHyper(alpha=0.05, gamma=1.5, n_candidates=KS)
        k = jax.random.normal(jax.random.PRNGKey(7), (NI, D))
        emb = k / jnp.linalg.norm(k, axis=-1, keepdims=True)
        th = jax.random.normal(jax.random.PRNGKey(8), (N, D))
        theta = th / jnp.linalg.norm(th, axis=-1, keepdims=True)

        def reward_fn(key, u, ctx, choice):
            return env.step_rewards(key, theta[u], ctx, choice)

        mesh = jax.make_mesh((8,), ("users",))
        s1 = serve.OnlineBandit.create(N, D, hyper, policy="distclub",
                                       refresh_every=0,
                                       backend="reference",
                                       precision="f32")
        s8 = serve.OnlineBandit.sharded(mesh, N, D, hyper,
                                        policy="distclub",
                                        refresh_every=0,
                                        backend="reference",
                                        precision="f32")
        cat = serve.make_catalog(emb, precision="f32")
        from repro.core import catalog as catalog_mod
        from repro.distributed.distclub_shard import named_shardings
        cat8 = jax.device_put(cat, named_shardings(
            mesh, catalog_mod.specs(("users",))))
        for t in range(3):
            key = jax.random.PRNGKey(1000 + t)
            u = jax.random.permutation(jax.random.PRNGKey(100 + t),
                                       N)[:B].astype(jnp.int32)
            s1, c1, _ = serve.step_catalog(s1, key, u, cat, reward_fn,
                                           k_short=KS)
            s8, c8, _ = serve.step_catalog(s8, key, u, cat8, reward_fn,
                                           k_short=KS)
            np.testing.assert_array_equal(np.asarray(c1), np.asarray(c8))
        np.testing.assert_array_equal(np.asarray(s1.state.occ),
                                      np.asarray(s8.state.occ))
        np.testing.assert_allclose(np.asarray(s1.state.Minv),
                                   np.asarray(s8.state.Minv), atol=1e-6)
        print("PRECISION-SHARD-OK")
    """)
    assert "PRECISION-SHARD-OK" in out


# ---------------------------------------------------------------------------
# reduced-precision flip-rate bound
# ---------------------------------------------------------------------------

def test_bf16_int8_choice_flip_rate_bounded():
    """Counterfactual per-decision probes on the f32 oracle's trajectory
    (the bench_precision harness at test scale): after the cold-start
    warmup, bf16/int8 flip at most 2% of choices — and the reduced
    sessions really store reduced state."""
    emb, theta = _world()
    rf = _reward_fn(theta)
    oracle = _session(None)
    cat = serve.make_catalog(emb)
    probes = {}
    for p in ("bf16", "int8"):
        rs = _session(p)
        assert rs.state.Minv.dtype == jnp.bfloat16
        probes[p] = (rs, serve.make_catalog(emb, precision=p))
    assert probes["int8"][1].serving.emb.dtype == jnp.int8
    warm, meas = 20, 8
    flips = {p: 0 for p in probes}
    total = 0
    for t in range(warm + meas):
        u = _uids(t)
        if t >= warm:
            idf, _, _ = serve.recommend_catalog(oracle, u, cat, k_short=KS)
            total += B
            for p, (rs, catp) in probes.items():
                sdt = rs.policy.cfg.engine.precision.jnp_state
                st = oracle.state._replace(
                    Minv=oracle.state.Minv.astype(sdt),
                    uMcinv=oracle.state.uMcinv.astype(sdt))
                idr, _, _ = serve.recommend_catalog(
                    dataclasses.replace(rs, state=st), u, catp, k_short=KS)
                flips[p] += int(jnp.sum(idf != idr))
        oracle, _, _ = serve.step_catalog(oracle,
                                          jax.random.PRNGKey(1000 + t), u,
                                          cat, rf, k_short=KS)
    for p, f in flips.items():
        assert f / total <= 0.02, (p, f, total)


# ---------------------------------------------------------------------------
# int8 scale round-trip through churn / publish / reclaim
# ---------------------------------------------------------------------------

def test_int8_scales_survive_churn_publish_and_reclaim():
    prec = Precision(state_dtype="bf16", catalog_dtype="int8",
                     scale_block=64)
    emb, _ = _world()
    cat = serve.make_catalog(emb, capacity=N_ITEMS + 32, precision=prec)
    assert cat.serving.emb.dtype == jnp.int8
    # initial quantization honors the error bound: one shared scale per
    # 64-slot block, |dequant - orig| <= scale/2 per component
    deq = np.asarray(catalog_mod.dequantize(cat.serving))
    orig = np.zeros_like(deq)
    orig[:N_ITEMS] = np.asarray(emb)
    sc = np.asarray(cat.serving.scale)
    assert np.all(np.abs(deq - orig) <= sc[:, None] / 2 + 1e-7)

    # stage churn: retire a block-straddling id range, add replacements
    retired = jnp.arange(10, 20, dtype=jnp.int32)
    cat1, n_ret = catalog_mod.retire_items(cat, retired)
    new_rows = 3.0 * jax.random.normal(jax.random.PRNGKey(5), (6, D))
    cat1, slots, n_add = catalog_mod.add_items(cat1, new_rows)
    assert int(n_ret) == 10 and int(n_add) == 6
    before = cat1.serving
    cat2 = catalog_mod.publish(cat1)
    after = cat2.serving

    # untouched slots: codes AND scales bit-identical across the swap
    touched = np.zeros(cat.capacity, bool)
    touched[np.asarray(retired)] = True
    touched[np.asarray(slots)] = True
    np.testing.assert_array_equal(np.asarray(before.emb)[~touched],
                                  np.asarray(after.emb)[~touched])
    np.testing.assert_array_equal(np.asarray(before.scale)[~touched],
                                  np.asarray(after.scale)[~touched])

    # churn-added rows got fresh PER-ROW scales (maxabs/127 — these rows
    # are far outside the initial blocks' range) and still dequantize
    # within the bound; the spare-capacity tail slots were claimed first
    got = np.asarray(slots)
    nr = np.asarray(new_rows)
    for i, s in enumerate(got):
        want_scale = max(np.abs(nr[i]).max(), 1e-8) / 127.0
        assert np.isclose(float(after.scale[s]), want_scale, rtol=1e-6)
        row = np.asarray(catalog_mod.dequantize(after))[s]
        assert np.all(np.abs(row - nr[i]) <= want_scale / 2 + 1e-6)

    # reclaim: a retired slot is reusable — the NEXT add claims it and
    # overwrites its scale with the new row's own
    cat3, slots2, _ = catalog_mod.add_items(
        cat2, 0.5 * jax.random.normal(jax.random.PRNGKey(6), (4, D)))
    assert set(np.asarray(slots2).tolist()) <= set(range(10, 20))
    cat3 = catalog_mod.publish(cat3)
    s0 = int(np.asarray(slots2)[0])
    assert float(cat3.serving.scale[s0]) != float(cat2.serving.scale[s0])
    # and a full no-churn publish round-trip is a bit-exact identity on
    # the serving bank
    cat4 = catalog_mod.publish(catalog_mod.publish(cat3))
    np.testing.assert_array_equal(np.asarray(cat3.serving.emb),
                                  np.asarray(cat4.serving.emb))
    np.testing.assert_array_equal(np.asarray(cat3.serving.scale),
                                  np.asarray(cat4.serving.scale))


# ---------------------------------------------------------------------------
# checkpoint precision tag
# ---------------------------------------------------------------------------

def test_checkpoint_precision_mismatch_raises(tmp_path):
    emb, theta = _world()
    rf = _reward_fn(theta)
    s16 = _session("bf16")
    cat = serve.make_catalog(emb, precision="bf16")
    s16, _, _ = serve.step_catalog(s16, jax.random.PRNGKey(0), _uids(0),
                                   cat, rf, k_short=KS)
    ck = CheckpointManager(tmp_path / "prec", keep=2)
    s16.save(ck, step=1)

    # same precision: round-trips bit-exactly, reduced dtypes intact
    s16b, got_step = _session("bf16").restore(ck, step=1)
    assert got_step == 1
    assert s16b.state.Minv.dtype == jnp.bfloat16
    np.testing.assert_array_equal(
        np.asarray(s16.state.Minv.astype(jnp.float32)),
        np.asarray(s16b.state.Minv.astype(jnp.float32)))

    # different precision: loud refusal, not silent reinterpretation
    with pytest.raises(ValueError, match="precision mismatch"):
        _session("f32").restore(ck, step=1)
    with pytest.raises(ValueError, match="precision mismatch"):
        _session("int8").restore(ck, step=1)


# ---------------------------------------------------------------------------
# pruned retrieval stays exact under quantized tile summaries
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("prec", ["bf16", "int8"])
def test_pruned_retrieval_exact_under_quantized_summaries(prec):
    """Cluster-pruned shortlists must never drop a true member: the
    quantized tile summaries widen conservatively (`core.itemclub`), so
    the pruned serve is bit-identical to unpruned — while still
    actually skipping tiles on a region-structured catalog."""
    e, _ = env.make_catalog_env(jax.random.PRNGKey(0), N_USERS, D, 4,
                                N_ITEMS, item_noise_scale=0.05)
    emb = env.catalog_embeddings(e)
    rf = _reward_fn(e.theta)
    sess = _session(prec)
    cat = serve.make_catalog(emb, precision=prec)
    for t in range(4):
        sess, _, _ = serve.step_catalog(sess, jax.random.PRNGKey(2000 + t),
                                        _uids(t), cat, rf, k_short=KS)
    cl = serve.build_clusters(cat, tile_items=64, n_anchors=64)
    u = jnp.arange(B, dtype=jnp.int32)
    ids_plain, _, _ = serve.recommend_catalog(sess, u, cat, k_short=KS)
    ids_pruned, _, _, rmet = serve.recommend_catalog(sess, u, cat,
                                                     k_short=KS,
                                                     clusters=cl)
    np.testing.assert_array_equal(np.asarray(ids_plain),
                                  np.asarray(ids_pruned))
    assert float(rmet.skip_ratio()) > 0.0


# ---------------------------------------------------------------------------
# deprecated factories still serve the BackendConfig engines
# ---------------------------------------------------------------------------

def test_deprecated_factories_match_backendconfig():
    """`get_backend`/`get_graph_backend`/`get_retrieval_backend` remain
    importable working aliases of the unified `BackendConfig` API (old
    call sites keep running while they migrate)."""
    eng_old = get_backend(N_USERS, D, KS, "reference")
    eng_new = BackendConfig(kind="reference",
                            precision=resolve_precision(None)).interact(
                                N_USERS, D, KS)
    assert eng_old == eng_new

    gb_old = get_graph_backend(N_USERS, kind="reference")
    gb_new = BackendConfig(kind="reference",
                           precision=resolve_precision(None)).graph(N_USERS)
    assert gb_old == gb_new

    rb_old = get_retrieval_backend(D, KS, "reference")
    rb_new = BackendConfig(kind="reference",
                           precision=resolve_precision(None)).retrieval(
                               D, KS)
    assert rb_old == rb_new
