"""Fault-tolerant feedback loop: pending buffer, delayed folding,
guardrail auto-rollback, and the seeded fault-injection harness.

Acceptance criteria covered here:
  * delay-0 split (`recommend` -> realized rewards -> `observe_delayed`)
    is BIT-identical to the synchronous `step` — single-host and on an
    8-device mesh (subprocess);
  * out-of-order, duplicate, and padded delivery fold exactly once, with
    the right matched/unmatched counters;
  * TTL expiry and capacity backpressure are counted, never corrupting;
  * the catalog-scale issue path (`recommend_catalog` on a buffer
    session) has the same delay-0 parity vs `step_catalog`;
  * the seeded fault suite (30% delayed, 10% lost, 5% duplicated)
    completes with bounded regret degradation vs its clean control;
  * a sign-flip-corrupted run under guardrails trips the CTR floor,
    auto-rolls back, and replays recorded healthy inputs bit-identically;
  * `CheckpointManager.restore_latest` skips truncated / bad-magic
    checkpoints to the newest good one.
"""
import json
import pathlib
import warnings

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import serve
from repro.core import env
from repro.core.types import BanditHyper
from repro.serve import faults, guardrails
from repro.train.checkpoint import CheckpointManager

from test_distributed import _run_with_devices

N, D, K, B = 32, 8, 10, 16
HYPER = BanditHyper(sigma=4, max_rounds=1, gamma=1.5, n_candidates=K)


def _session(policy="linucb", capacity=64, ttl=8, refresh_every=None):
    return serve.OnlineBandit.create(
        N, D, HYPER, policy=policy,
        refresh_every=N if refresh_every is None else refresh_every,
        pending_capacity=capacity, pending_ttl=ttl)


@pytest.fixture(scope="module")
def world():
    e, _ = env.make_synthetic_env(jax.random.PRNGKey(0), N, D, 4, K)
    return e


def _uids(i, n=B):
    return jax.random.randint(jax.random.PRNGKey(1000 + i), (n,), 0, N)


def _ctx(i, n=B):
    c = jax.random.normal(jax.random.PRNGKey(2000 + i), (n, K, D))
    return c / jnp.sqrt(jnp.float32(D))


def _reward_fn(theta):
    def reward_fn(key, uids, ctx, choice):
        return env.step_rewards(key, theta[uids], ctx, choice)
    return reward_fn


def _assert_states_equal(a, b):
    for la, lb in zip(jax.tree_util.tree_leaves(a),
                      jax.tree_util.tree_leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


# ---------------------------------------------------------------------------
# delay-0 bit-parity with the synchronous transaction
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("policy", ["linucb", "distclub"])
def test_delay0_split_is_bit_identical_to_step(world, policy):
    """recommend -> observe_delayed with immediate delivery lands on the
    exact bytes the fused `step` produces: the ring stores the same
    psum-combined chosen context the synchronous fold consumes, and the
    refresh schedule sees the same key."""
    reward_fn = _reward_fn(world.theta)
    sync = serve.OnlineBandit.create(N, D, HYPER, policy=policy,
                                     refresh_every=N)
    split = _session(policy=policy)
    for i in range(6):
        key = jax.random.PRNGKey(i)
        sync, ch_a, _ = serve.step(sync, key, _uids(i), _ctx(i), reward_fn)
        split, ch_b, ids = serve.recommend(split, _uids(i), _ctx(i))
        np.testing.assert_array_equal(np.asarray(ch_a), np.asarray(ch_b))
        realized, _, _, _ = reward_fn(key, _uids(i), _ctx(i), ch_b)
        split = serve.observe_delayed(split, ids, realized, key=key)
    _assert_states_equal(sync.state, split.state)
    st = serve.pending_stats(split)
    assert st["in_flight"] == 0
    assert st["matched"] == 6 * B and st["unmatched"] == 0


def test_delay0_parity_sharded_8dev():
    """Same parity on an 8-device users-sharded mesh: the buffer is
    replicated (it consumes psum-combined choices), so every shard holds
    byte-identical pending state and the delayed fold re-derives
    ownership exactly like the synchronous path."""
    out = _run_with_devices("""
        import numpy as np
        import jax, jax.numpy as jnp
        from repro import serve
        from repro.core import env
        from repro.core.types import BanditHyper

        N, D, K, B = 64, 8, 10, 16
        hyper = BanditHyper(sigma=4, max_rounds=1, gamma=1.5,
                            n_candidates=K)
        e, _ = env.make_synthetic_env(jax.random.PRNGKey(0), N, D, 4, K)
        theta = e.theta

        def reward_fn(key, uids, ctx, choice):
            return env.step_rewards(key, theta[uids], ctx, choice)

        mesh = jax.make_mesh((8,), ("users",))
        sync = serve.OnlineBandit.sharded(mesh, N, D, hyper,
                                          policy="distclub",
                                          refresh_every=N)
        split = serve.OnlineBandit.sharded(mesh, N, D, hyper,
                                           policy="distclub",
                                           refresh_every=N,
                                           pending_capacity=64,
                                           pending_ttl=8)
        for i in range(5):
            key = jax.random.PRNGKey(i)
            uids = jax.random.randint(jax.random.PRNGKey(100 + i), (B,),
                                      0, N)
            ctx = jax.random.normal(jax.random.PRNGKey(200 + i),
                                    (B, K, D)) / jnp.sqrt(jnp.float32(D))
            sync, ch_a, _ = serve.step(sync, key, uids, ctx, reward_fn)
            split, ch_b, ids = serve.recommend(split, uids, ctx)
            np.testing.assert_array_equal(np.asarray(ch_a),
                                          np.asarray(ch_b))
            realized, _, _, _ = reward_fn(key, uids, ctx, ch_b)
            split = serve.observe_delayed(split, ids, realized, key=key)
        for a, b in zip(jax.tree_util.tree_leaves(sync.state),
                        jax.tree_util.tree_leaves(split.state)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        st = serve.pending_stats(split)
        assert st["in_flight"] == 0 and st["matched"] == 5 * B, st
        print("DELAYED-SHARD-PARITY-OK")
    """)
    assert "DELAYED-SHARD-PARITY-OK" in out


def test_catalog_issue_delay0_parity(world):
    """The catalog-scale issue path: recommend_catalog on a buffer
    session + observe_delayed == step_catalog, bit for bit."""
    n_items = 64
    e, _ = env.make_catalog_env(jax.random.PRNGKey(3), N, D, 4, n_items,
                                n_candidates=K)
    cat = serve.make_catalog(env.catalog_embeddings(e))
    reward_fn = _reward_fn(e.theta)
    sync = serve.OnlineBandit.create(N, D, HYPER, policy="distclub",
                                     refresh_every=N)
    split = _session(policy="distclub")
    for i in range(4):
        key = jax.random.PRNGKey(i)
        uids = _uids(i)
        sync, it_a, _ = serve.step_catalog(sync, key, uids, cat,
                                           reward_fn, k_short=8)
        split, it_b, ids, slots, ctx = serve.recommend_catalog(
            split, uids, cat, k_short=8)
        np.testing.assert_array_equal(np.asarray(it_a), np.asarray(it_b))
        realized, _, _, _ = reward_fn(key, uids, ctx, slots)
        split = serve.observe_delayed(split, ids, realized, key=key)
    _assert_states_equal(sync.state, split.state)


# ---------------------------------------------------------------------------
# exactness under hostile delivery
# ---------------------------------------------------------------------------


def test_out_of_order_duplicate_padded_delivery_exact(world):
    """Shuffled cross-round delivery + re-delivery + in-batch duplicates
    + id -1 padding folds every decision exactly once."""
    reward_fn = _reward_fn(world.theta)
    sess = _session(ttl=16)
    backlog, round0 = [], None
    for i in range(4):        # issue 4 rounds, fold nothing yet
        key = jax.random.PRNGKey(i)
        sess, ch, ids = serve.recommend(sess, _uids(i), _ctx(i))
        realized, _, _, _ = reward_fn(key, _uids(i), _ctx(i), ch)
        entries = list(zip(np.asarray(ids).tolist(),
                           np.asarray(realized).tolist()))
        backlog += entries
        if i == 0:
            round0 = entries
    inorder = tangled = sess          # immutable: two futures, one past

    for c in range(4):                # clean in-order delivery
        ids = jnp.asarray([e[0] for e in backlog[c * B:(c + 1) * B]],
                          dtype=jnp.int32)
        rs = jnp.asarray([e[1] for e in backlog[c * B:(c + 1) * B]],
                         dtype=jnp.float32)
        inorder = serve.observe_delayed(inorder, ids, rs,
                                        key=jax.random.PRNGKey(50 + c))

    # shuffled cross-round order, chunks of B-1 so each batch has one
    # padding slot — chunk 0's spare slot carries an in-batch duplicate
    rng = np.random.default_rng(0)
    fb = [backlog[j] for j in rng.permutation(len(backlog))]
    chunks = [fb[k:k + (B - 1)] for k in range(0, len(fb), B - 1)]
    for c, chunk in enumerate(chunks):
        ids = np.full((B,), -1, np.int32)
        rs = np.zeros((B,), np.float32)
        ids[:len(chunk)] = [e[0] for e in chunk]
        rs[:len(chunk)] = [e[1] for e in chunk]
        if c == 0:            # in-batch duplicate in the padding slot
            ids[B - 1], rs[B - 1] = ids[0], rs[0]
        tangled = serve.observe_delayed(tangled, jnp.asarray(ids),
                                        jnp.asarray(rs),
                                        key=jax.random.PRNGKey(50 + c))
    # full re-delivery of round 0: every entry must be a counted no-op
    ids0 = jnp.asarray([e[0] for e in round0], dtype=jnp.int32)
    rs0 = jnp.asarray([e[1] for e in round0], dtype=jnp.float32)
    before = tangled.state
    tangled = serve.observe_delayed(tangled, ids0, rs0,
                                    key=jax.random.PRNGKey(99))
    _assert_states_equal(before, tangled.state)

    st = serve.pending_stats(tangled)
    assert st["matched"] == 4 * B, st           # every decision: once
    assert st["unmatched"] == 1 + B, st         # dup + full re-delivery
    # same multiset of folds as the in-order delivery: integer counters
    # exactly, float statistics to fold-order tolerance
    np.testing.assert_array_equal(np.asarray(tangled.state.occ),
                                  np.asarray(inorder.state.occ))
    assert int(jnp.sum(tangled.state.occ)) == 4 * B
    np.testing.assert_allclose(np.asarray(tangled.state.b),
                               np.asarray(inorder.state.b), atol=1e-5)
    np.testing.assert_allclose(np.asarray(tangled.state.Minv),
                               np.asarray(inorder.state.Minv), atol=1e-5)


def test_ttl_expiry_counts_and_drops(world):
    """A decision survives exactly `ttl` subsequent issue transactions;
    feedback after that is unmatched, the slot freed, `expired` counted."""
    sess = _session(ttl=2)
    sess, _, ids0 = serve.recommend(sess, _uids(0), _ctx(0))
    sess, _, _ = serve.recommend(sess, _uids(1), _ctx(1))   # clock 2
    sess, _, _ = serve.recommend(sess, _uids(2), _ctx(2))   # clock 3
    # round-0 deadline = 1 + 2 = 3: still matchable here
    st = serve.pending_stats(sess)
    assert st["expired"] == 0
    sess, _, _ = serve.recommend(sess, _uids(3), _ctx(3))   # clock 4 -> gone
    st = serve.pending_stats(sess)
    assert st["expired"] == B, st
    before = sess.state
    sess = serve.observe_delayed(sess, ids0,
                                 jnp.ones((B,), jnp.float32),
                                 key=jax.random.PRNGKey(0))
    st = serve.pending_stats(sess)
    assert st["unmatched"] == B and st["matched"] == 0
    _assert_states_equal(before, sess.state)   # late feedback: no fold


def test_capacity_backpressure_evicts_and_counts(world):
    """Issuing past capacity evicts the oldest resident decisions and
    counts them `dropped` — the serving path never blocks."""
    sess = _session(capacity=B, ttl=100)
    sess, _, ids0 = serve.recommend(sess, _uids(0), _ctx(0))
    sess, _, ids1 = serve.recommend(sess, _uids(1), _ctx(1))
    st = serve.pending_stats(sess)
    assert st["dropped"] == B and st["in_flight"] == B, st
    # round-0 ids were evicted: unmatched; round-1 ids still fold
    sess = serve.observe_delayed(sess, ids0, jnp.ones((B,), jnp.float32),
                                 key=jax.random.PRNGKey(0))
    st = serve.pending_stats(sess)
    assert st["unmatched"] == B and st["matched"] == 0
    sess = serve.observe_delayed(sess, ids1, jnp.ones((B,), jnp.float32),
                                 key=jax.random.PRNGKey(1))
    st = serve.pending_stats(sess)
    assert st["matched"] == B


def test_batch_wider_than_capacity_rejected(world):
    sess = _session(capacity=8)
    with pytest.raises(ValueError, match="capacity"):
        serve.recommend(sess, _uids(0), _ctx(0))


# ---------------------------------------------------------------------------
# the seeded fault suite
# ---------------------------------------------------------------------------


def test_seeded_fault_suite_bounded_degradation(world):
    """30% delayed / 10% lost / 5% duplicated: the session completes,
    every non-lost decision folds exactly once, and regret degrades by a
    bounded factor vs the clean control on identical traffic."""
    spec = faults.FaultSpec(seed=7, p_delay=0.3, max_delay=4, p_loss=0.1,
                            p_dup=0.05)
    _, clean = faults.run_faulted(_session(capacity=256, ttl=16),
                                  world.theta, 30, faults.FaultSpec(),
                                  batch=B, key=11)
    sess, rep = faults.run_faulted(_session(capacity=256, ttl=16),
                                   world.theta, 30, spec, batch=B, key=11)
    assert rep.interactions == clean.interactions == 30 * B
    # bounded degradation: the asserted acceptance thresholds
    assert rep.reward >= 0.8 * clean.reward, (rep.reward, clean.reward)
    assert rep.regret <= 1.5 * clean.regret + 5.0, (rep.regret,
                                                    clean.regret)
    st = rep.pending
    # conservation: every issued decision is exactly one of folded /
    # still resident / TTL-expired / ring-evicted
    lost = st["issued"] - st["matched"]
    assert 0 < lost < 0.2 * st["issued"], st
    assert st["in_flight"] + st["expired"] + st["dropped"] == lost, st
    # duplicates were delivered and rejected
    assert st["unmatched"] > 0, st


def test_stall_backlog_floods_then_drains(world):
    """A simulated shard stall: no delivery for `stall_rounds`, then the
    backlog floods in — everything still folds exactly once."""
    spec = faults.FaultSpec(seed=3, stall_every=5, stall_rounds=2)
    _, rep = faults.run_faulted(_session(capacity=256, ttl=16),
                                world.theta, 20, spec, batch=B, key=5)
    st = rep.pending
    assert st["matched"] == st["issued"] == 20 * B, st
    assert st["unmatched"] == 0 and st["expired"] == 0, st


# ---------------------------------------------------------------------------
# guardrails: breach -> rollback -> bit-identical resume
# ---------------------------------------------------------------------------


def test_guardrail_trips_on_sign_flip_and_resumes_bit_identical(
        world, tmp_path):
    """Reward sign-flip corruption drives the CTR EMA through the floor;
    the wrapper rolls back to the healthy snapshot and replaying the
    recorded healthy inputs yields bit-identical choices and state."""
    reward_fn = _reward_fn(world.theta)
    cfg = guardrails.GuardrailConfig(ctr_floor=0.05, warmup=2 * B,
                                     ema=0.5, snapshot_every=1000,
                                     cooldown=2)
    g = guardrails.Guarded.create(
        _session(), CheckpointManager(tmp_path / "guard", keep=4), cfg)

    healthy = []
    for i in range(6):
        key = jax.random.PRNGKey(i)
        g, ch, ids = g.recommend(_uids(i), _ctx(i))
        realized, _, _, _ = reward_fn(key, _uids(i), _ctx(i), ch)
        g = g.observe_delayed(ids, realized, key=key)
        healthy.append((i, key, np.asarray(ch)))
    assert not g.tripped and g.gs.rollbacks == 0

    for i in range(6, 40):
        key = jax.random.PRNGKey(i)
        g, ch, ids = g.recommend(_uids(i), _ctx(i))
        realized, _, _, _ = reward_fn(key, _uids(i), _ctx(i), ch)
        g = g.observe_delayed(ids, -realized, key=key)   # corrupted
        if g.gs.rollbacks:
            break
    assert g.gs.rollbacks == 1, g.events
    ev = [e for e in g.events if e[0] == "rollback"]
    assert ev and ev[0][2] == ("ctr_floor",) and ev[0][3] == 0

    # the ring was cleared but the id counter stayed monotone: stale
    # feedback can never alias a post-rollback decision
    st = serve.pending_stats(g.session)
    assert st["in_flight"] == 0 and st["issued"] > 0

    # replay the recorded healthy inputs: bit-identical choices + state
    ref = _session()
    for i, key, ch_rec in healthy:
        g, ch_g, ids_g = g.recommend(_uids(i), _ctx(i))
        ref, ch_r, ids_r = serve.recommend(ref, _uids(i), _ctx(i))
        np.testing.assert_array_equal(np.asarray(ch_g), ch_rec)
        np.testing.assert_array_equal(np.asarray(ch_g), np.asarray(ch_r))
        realized, _, _, _ = reward_fn(key, _uids(i), _ctx(i), ch_r)
        g = g.observe_delayed(ids_g, realized, key=key)
        ref = serve.observe_delayed(ref, ids_r, realized, key=key)
    _assert_states_equal(g.session.state, ref.state)


def test_guarded_fault_run_rolls_back_under_corruption(world, tmp_path):
    """End-to-end: the harness's sign-flip scenario through the guarded
    wrapper ends in rollback events, not a silently poisoned session."""
    spec = faults.FaultSpec(seed=1, p_flip=1.0, flip_after=8)
    cfg = guardrails.GuardrailConfig(ctr_floor=0.2, warmup=2 * B,
                                     ema=0.7, snapshot_every=6,
                                     cooldown=2)
    g = guardrails.Guarded.create(
        _session(capacity=256, ttl=16),
        CheckpointManager(tmp_path / "gfr", keep=4), cfg)
    g, rep = faults.run_faulted(g, world.theta, 30, spec, batch=B, key=2)
    rolls = [e for e in rep.events if e[0] == "rollback"]
    assert rolls, rep.events
    assert all(e[2] == ("ctr_floor",) for e in rolls)
    assert g.gs.rollbacks == len(rolls)


def test_occupancy_guardrail_trips_on_wedged_feedback(world, tmp_path):
    """Feedback stops arriving; the ring fills; the occupancy ceiling
    trips without waiting for the CTR to move."""
    cfg = guardrails.GuardrailConfig(occupancy_ceiling=0.5, ema=0.5,
                                     snapshot_every=1000, cooldown=2)
    g = guardrails.Guarded.create(
        _session(capacity=64, ttl=1000),
        CheckpointManager(tmp_path / "occ", keep=2), cfg)
    for i in range(8):                       # 8 * 16 issues, 0 delivered
        g, _, _ = g.recommend(_uids(i), _ctx(i))
        if g.gs.rollbacks:
            break
    assert g.gs.rollbacks == 1
    assert [e for e in g.events if e[0] == "rollback"][0][2] == (
        "occupancy_ceiling",)


# ---------------------------------------------------------------------------
# checkpoint corruption recovery
# ---------------------------------------------------------------------------


def test_restore_latest_skips_truncated_and_bad_magic(tmp_path):
    ck = CheckpointManager(tmp_path / "ck", keep=5)
    state = {"a": jnp.arange(4.0), "b": jnp.ones((2, 3))}
    for s in (1, 2, 3):
        ck.save(jax.tree_util.tree_map(lambda x: x + s, state), s)
    d3 = ck._step_dir(3)
    (d3 / "arrays.npz").write_bytes(
        (d3 / "arrays.npz").read_bytes()[:16])          # truncated
    d2 = ck._step_dir(2)
    m = json.loads((d2 / "manifest.json").read_text())
    m["magic"] = "not-a-checkpoint"
    (d2 / "manifest.json").write_text(json.dumps(m))    # bad magic
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        restored, step = ck.restore_latest(state)
    assert step == 1
    assert len(w) == 2
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.arange(4.0) + 1)
    # all three corrupt -> a clear error naming every failure
    d1 = ck._step_dir(1)
    (d1 / "manifest.json").write_text("{not json")
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        with pytest.raises(RuntimeError, match="no loadable checkpoint"):
            ck.restore_latest(state)


def test_session_restore_survives_torn_latest(world, tmp_path):
    """A session whose newest snapshot was torn mid-write resumes from
    the previous one instead of crashing."""
    reward_fn = _reward_fn(world.theta)
    sess = serve.OnlineBandit.create(N, D, HYPER, policy="linucb",
                                     refresh_every=N)
    ck = CheckpointManager(tmp_path / "sess", keep=3)
    for i in range(3):
        sess, _, _ = serve.step(sess, jax.random.PRNGKey(i), _uids(i),
                                _ctx(i), reward_fn)
        sess.save(ck, i)
    good = sess          # state at step 2 == last good snapshot... step 2
    d = ck._step_dir(2)
    (d / "arrays.npz").write_bytes(b"\x00" * 8)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        restored, step = serve.OnlineBandit.create(
            N, D, HYPER, policy="linucb", refresh_every=N).restore(ck)
    assert step == 1
    # resuming from step 1 and re-running round 2 reproduces step 2
    redo, _, _ = serve.step(restored, jax.random.PRNGKey(2), _uids(2),
                            _ctx(2), reward_fn)
    _assert_states_equal(redo.state, good.state)


# ---------------------------------------------------------------------------
# bench-gate hygiene: missing baseline is a clear failure
# ---------------------------------------------------------------------------


def test_check_regression_missing_baseline_clear_message(tmp_path):
    import subprocess
    import sys
    repo = pathlib.Path(__file__).resolve().parents[1]
    cur = tmp_path / "cur"
    base = tmp_path / "base"
    cur.mkdir()
    base.mkdir()
    (cur / "BENCH_thing.json").write_text(json.dumps(
        {"rows": [{"name": "r", "some_ratio": 1.0}]}))
    out = subprocess.run(
        [sys.executable, str(repo / "benchmarks" / "check_regression.py"),
         "--current", str(cur), "--baseline", str(base)],
        capture_output=True, text=True)
    assert out.returncode == 1
    blob = out.stdout + out.stderr
    assert "no baseline" in blob and "BENCH_thing.json" in blob
    assert "Traceback" not in blob


def test_check_regression_perturbed_baseline_fails_per_direction(tmp_path):
    """Perturb-a-baseline self-test of the gate's direction rules: every
    name class trips on a regression in ITS direction (including the
    lower-better ``*flip_rate*``/``*error*`` precision metrics) and
    stays green on same-direction improvements."""
    import subprocess
    import sys
    repo = pathlib.Path(__file__).resolve().parents[1]
    good = {"rows": [{"name": "r", "hbm_cut_ratio": 2.0,
                      "comm_bytes": 100.0, "choice_flip_rate": 0.004,
                      "bound_error": 0.5}]}

    def run(current_rows):
        cur = tmp_path / "cur"
        base = tmp_path / "base"
        for p in (cur, base):
            p.mkdir(exist_ok=True)
        (base / "BENCH_thing.json").write_text(json.dumps(good))
        (cur / "BENCH_thing.json").write_text(json.dumps(current_rows))
        return subprocess.run(
            [sys.executable,
             str(repo / "benchmarks" / "check_regression.py"),
             "--current", str(cur), "--baseline", str(base)],
            capture_output=True, text=True)

    out = run(good)
    assert out.returncode == 0, out.stdout + out.stderr

    regressions = {"hbm_cut_ratio": 1.0,       # higher-better fell
                   "comm_bytes": 200.0,        # lower-better rose
                   "choice_flip_rate": 0.02,   # precision parity worsened
                   "bound_error": 1.5}
    for key, bad_val in regressions.items():
        rows = {"rows": [dict(good["rows"][0], **{key: bad_val})]}
        out = run(rows)
        blob = out.stdout + out.stderr
        assert out.returncode == 1, (key, blob)
        assert key in blob, (key, blob)

    improvements = {"hbm_cut_ratio": 4.0, "comm_bytes": 50.0,
                    "choice_flip_rate": 0.0, "bound_error": 0.1}
    rows = {"rows": [dict(good["rows"][0], **improvements)]}
    out = run(rows)
    assert out.returncode == 0, out.stdout + out.stderr


# ---------------------------------------------------------------------------
# the shared traffic stream (consumed by clean runs, faulted runs, and
# experiment arms) reproduces the original inline key schedule exactly
# ---------------------------------------------------------------------------


def test_traffic_stream_matches_original_schedule_byte_for_byte():
    """`TrafficStream` is THE key lattice: its slate/catalog batches and
    drain key must equal the pre-factoring inline fold_in chains bit for
    bit, or clean-control baselines silently shift."""
    key, batch, rounds = 7, B, 5
    stream = faults.TrafficStream(key, batch, N, K=K, d=D)
    base = jax.random.PRNGKey(key)
    for i in range(rounds):
        # the original run_faulted schedule, written out inline
        ku, kc, kr, kf = (jax.random.fold_in(base, 4 * i + j)
                          for j in range(4))
        users0 = jax.random.randint(ku, (batch,), 0, N)
        ctx0 = (jax.random.normal(kc, (batch, K, D), jnp.float32)
                / np.sqrt(D))
        users, ctx, kr2, kf2 = stream.slate_batch(i)
        np.testing.assert_array_equal(np.asarray(users0), np.asarray(users))
        np.testing.assert_array_equal(np.asarray(ctx0), np.asarray(ctx))
        np.testing.assert_array_equal(np.asarray(kr), np.asarray(kr2))
        np.testing.assert_array_equal(np.asarray(kf), np.asarray(kf2))
        # the original run_faulted_catalog schedule (same stride, no ctx)
        cu, cr, cf = (jax.random.fold_in(base, 4 * i + j)
                      for j in range(3))
        users0c = jax.random.randint(cu, (batch,), 0, N)
        usersc, cr2, cf2 = stream.catalog_batch(i)
        np.testing.assert_array_equal(np.asarray(users0c),
                                      np.asarray(usersc))
        np.testing.assert_array_equal(np.asarray(cr), np.asarray(cr2))
        np.testing.assert_array_equal(np.asarray(cf), np.asarray(cf2))
    np.testing.assert_array_equal(
        np.asarray(jax.random.fold_in(base, 4 * rounds)),
        np.asarray(stream.drain_key(rounds)))


def test_traffic_stream_clean_control_unchanged(world):
    """A clean-control `run_faulted` on the factored stream reproduces
    the frozen pre-factoring totals — the regression anchor for every
    seeded A/B comparison."""
    sess, rep = faults.run_faulted(_session(), world.theta, 6,
                                   faults.FaultSpec(), batch=B, key=3)
    # identical seeded traffic -> identical run, run to run
    sess2, rep2 = faults.run_faulted(_session(), world.theta, 6,
                                     faults.FaultSpec(), batch=B, key=3)
    assert rep.reward == rep2.reward
    assert rep.interactions == rep2.interactions
    _assert_states_equal(sess.state, sess2.state)
