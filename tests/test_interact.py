"""Fused interaction engine: choose-kernel parity, backend dispatch, and
end-to-end reference-vs-pallas agreement of the DistCLUB drivers.

All Pallas runs use interpret=True (this container has no TPU); the same
code path compiles on TPU with interpret=False.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

jax.config.update("jax_default_matmul_precision", "highest")

from repro.core import backend, distclub, env, env_ops, linucb
from repro.core.types import BanditHyper
from repro.kernels.interact import ops as interact_ops
from repro.kernels.rank1 import ops as rank1_ops
from repro.kernels.rank1.ref import rank1_update_inv_ref


def spd(key, n, d, scale=0.1):
    A = jax.random.normal(key, (n, d, d)) * scale
    return jnp.eye(d) + jnp.einsum("nij,nkj->nik", A, A)


# Ragged shapes on purpose: n not a block/sublane multiple, d not a sublane
# multiple, K not a lane multiple — all exercise the padding path.
@pytest.mark.parametrize("n,K,d", [
    (8, 16, 8),        # aligned n/d, ragged K
    (37, 20, 25),      # everything ragged
    (64, 7, 19),       # tiny ragged K
    (128, 128, 32),    # fully lane/sublane aligned (short-circuit path)
])
def test_fused_choose_matches_choose_batch(n, K, d):
    key = jax.random.PRNGKey(n * 1000 + K)
    ks = jax.random.split(key, 4)
    w = jax.random.normal(ks[0], (n, d))
    Minv = spd(ks[1], n, d)
    ctx = jax.random.normal(ks[2], (n, K, d))
    occ = jax.random.randint(ks[3], (n,), 0, 1000)

    choice_ref = linucb.choose_batch(w, Minv, ctx, occ, 0.3)
    x_ref = jnp.take_along_axis(ctx, choice_ref[:, None, None], axis=1)[:, 0]
    choice, x = interact_ops.choose(w, Minv, ctx, occ, 0.3,
                                    use_pallas=True, interpret=True)
    np.testing.assert_array_equal(np.asarray(choice), np.asarray(choice_ref))
    np.testing.assert_allclose(x, x_ref, rtol=2e-5, atol=2e-5)


def test_fused_choose_tie_breaks_like_argmax():
    """Duplicate candidates produce exactly equal scores; both paths must
    take the first index (jnp.argmax semantics)."""
    n, K, d = 16, 12, 8
    ctx = jax.random.normal(jax.random.PRNGKey(0), (n, K, d))
    ctx = ctx.at[:, 5].set(ctx[:, 2])       # k=5 duplicates k=2
    ctx = ctx.at[:, 9].set(ctx[:, 2])       # and k=9
    w = jax.random.normal(jax.random.PRNGKey(1), (n, d))
    Minv = jnp.broadcast_to(jnp.eye(d), (n, d, d))
    occ = jnp.ones((n,), jnp.int32)

    choice_ref = linucb.choose_batch(w, Minv, ctx, occ, 0.3)
    choice, _ = interact_ops.choose(w, Minv, ctx, occ, 0.3,
                                    use_pallas=True, interpret=True)
    np.testing.assert_array_equal(np.asarray(choice), np.asarray(choice_ref))
    assert not np.any(np.asarray(choice) == 5)
    assert not np.any(np.asarray(choice) == 9)


def test_fused_choose_padded_candidates_never_win():
    """All real scores negative: a zero-padded candidate (score 0) would win
    if the kernel failed to mask K-padding to -inf."""
    n, K, d = 8, 5, 4                       # K pads 5 -> 128
    ctx = -jnp.abs(jax.random.normal(jax.random.PRNGKey(2), (n, K, d)))
    w = jnp.ones((n, d))                    # est = sum(ctx) < 0
    Minv = jnp.zeros((n, d, d))             # no bonus term
    occ = jnp.zeros((n,), jnp.int32)
    choice, x = interact_ops.choose(w, Minv, ctx, occ, 0.5,
                                    use_pallas=True, interpret=True)
    assert np.asarray(choice).max() < K
    x_ref = jnp.take_along_axis(
        ctx, jnp.asarray(choice)[:, None, None], axis=1)[:, 0]
    np.testing.assert_allclose(x, x_ref, atol=1e-6)


@pytest.mark.parametrize("n,d", [(37, 25), (64, 32)])
def test_rank1_inv_kernel(n, d):
    key = jax.random.PRNGKey(n + d)
    ks = jax.random.split(key, 5)
    Minv = jnp.linalg.inv(spd(ks[0], n, d))
    b = jax.random.normal(ks[1], (n, d))
    x = jax.random.normal(ks[2], (n, d))
    r = jax.random.uniform(ks[3], (n,))
    mask = jax.random.bernoulli(ks[4], 0.7, (n,))
    refs = rank1_update_inv_ref(Minv, b, x, r, mask)
    outs = rank1_ops.rank1_update_inv(Minv, b, x, r, mask,
                                      use_pallas=True, interpret=True)
    for out, ref in zip(outs, refs):
        np.testing.assert_allclose(out, ref, rtol=3e-5, atol=3e-5)


def test_backend_dispatch_and_env_flag(monkeypatch):
    monkeypatch.delenv("REPRO_BACKEND", raising=False)
    be = backend.BackendConfig.create().interact(24, 5, 10)  # auto -> ref
    assert be.kind == "reference"
    assert (be.n_pad, be.d_pad, be.K_pad) == (24, 5, 10)  # no padding

    monkeypatch.setenv("REPRO_BACKEND", "pallas")
    be = backend.BackendConfig.create().interact(24, 5, 10)
    assert be.kind == "pallas" and be.interpret
    assert be.n_pad % be.block_users == 0
    assert be.d_pad % 8 == 0 and be.K_pad % 128 == 0

    monkeypatch.setenv("REPRO_BACKEND", "bogus")
    with pytest.raises(ValueError):
        backend.BackendConfig.create().interact(24, 5, 10)


def test_backend_pad_helpers_are_exact():
    be = backend.BackendConfig.create("pallas").interact(24, 5, 10,
                                                         interpret=True)
    lin = linucb.init_linucb(24, 5)
    padded = be.pad_lin(lin)
    assert padded.Minv.shape == (be.n_pad, be.d_pad, be.d_pad)
    # padded Gram blocks are identity (well-conditioned), real block intact
    np.testing.assert_allclose(
        padded.Minv, jnp.broadcast_to(jnp.eye(be.d_pad),
                                      (be.n_pad, be.d_pad, be.d_pad)))
    back = be.unpad_lin(padded)
    for a, b_ in zip(back, lin):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b_))


def test_distclub_run_reference_vs_pallas_interpret():
    """Acceptance: fused path matches the reference path end to end —
    identical choices (hence identical realized rewards) and state arrays
    within atol 1e-5 — on a ragged shape that keeps padding live."""
    N, D, K = 24, 5, 10
    hyper = BanditHyper(sigma=4, max_rounds=8, gamma=1.5, n_candidates=K)
    e, _ = env.make_synthetic_env(jax.random.PRNGKey(0), N, D, 3, K)
    ops = env_ops.synthetic_ops(e)
    ref = backend.BackendConfig.create("reference").interact(N, D, K)
    pal = backend.BackendConfig.create("pallas").interact(N, D, K,
                                                          interpret=True)

    s_r, m_r, c_r = distclub.run(ops, jax.random.PRNGKey(1), hyper,
                                 n_epochs=2, d=D, backend=ref)
    s_p, m_p, c_p = distclub.run(ops, jax.random.PRNGKey(1), hyper,
                                 n_epochs=2, d=D, backend=pal)
    np.testing.assert_allclose(s_p.lin.M, s_r.lin.M, atol=1e-5)
    np.testing.assert_allclose(s_p.lin.Minv, s_r.lin.Minv, atol=1e-5)
    np.testing.assert_allclose(s_p.lin.b, s_r.lin.b, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(s_p.lin.occ),
                                  np.asarray(s_r.lin.occ))
    # same choices => same Bernoulli draws => identical realized rewards
    np.testing.assert_allclose(m_p.reward, m_r.reward, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(c_p), np.asarray(c_r))
