"""Clustering substrate: connected components, pruning, aggregates."""
import jax
import jax.numpy as jnp
import networkx as nx
import numpy as np
from _hypothesis_shim import given, settings, st

from repro.core import clustering


def test_cc_two_triangles():
    adj = np.zeros((6, 6), bool)
    for a, b in [(0, 1), (1, 2), (0, 2), (3, 4), (4, 5), (3, 5)]:
        adj[a, b] = adj[b, a] = True
    labels = clustering.connected_components(jnp.asarray(adj))
    assert labels.tolist() == [0, 0, 0, 3, 3, 3]
    assert int(clustering.num_clusters(labels)) == 2


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 30), st.floats(0.0, 0.3), st.integers(0, 2**31 - 1))
def test_cc_matches_networkx(n, p, seed):
    rng = np.random.default_rng(seed)
    adj = rng.random((n, n)) < p
    adj = np.triu(adj, 1)
    adj = adj | adj.T
    labels = np.asarray(clustering.connected_components(jnp.asarray(adj)))
    g = nx.from_numpy_array(adj)
    for comp in nx.connected_components(g):
        comp = sorted(comp)
        want = comp[0]
        for v in comp:
            assert labels[v] == want


def test_prune_edges_separates_far_users():
    n, d = 4, 3
    v = jnp.array([[1, 0, 0], [1, 0.01, 0], [-1, 0, 0], [-1, 0.01, 0]],
                  jnp.float32)
    occ = jnp.full((n,), 1000, jnp.int32)   # tight confidence balls
    adj = jnp.ones((n, n), bool) & ~jnp.eye(n, dtype=bool)
    pruned = clustering.prune_edges(adj, v, occ, gamma=1.0)
    assert bool(pruned[0, 1]) and bool(pruned[2, 3])
    assert not bool(pruned[0, 2]) and not bool(pruned[1, 3])


def test_cluster_stats_single_ridge_term():
    """Mc = I + sum (Mu - I): members' identities must not stack."""
    n, d = 3, 2
    labels = jnp.zeros((n,), jnp.int32)
    M = jnp.stack([jnp.eye(d) * (i + 1.0) for i in range(n)])
    b = jnp.arange(n * d, dtype=jnp.float32).reshape(n, d)
    stats = clustering.cluster_stats(labels, M, b, d)
    want_M = jnp.eye(d) + sum(M[i] - jnp.eye(d) for i in range(n))
    np.testing.assert_allclose(stats.Mc[0], want_M)
    np.testing.assert_allclose(stats.bc[0], b.sum(0))
    assert int(stats.size[0]) == 3
    np.testing.assert_allclose(
        stats.Mcinv[0] @ stats.Mc[0], np.eye(d), atol=1e-5)


def test_cb_width_decreasing():
    occ = jnp.array([0, 1, 10, 100, 10_000])
    w = clustering.cb_width(occ)
    assert bool(jnp.all(jnp.diff(w) < 0))
