"""The online serving API: `OnlineBandit` sessions on the stage engine.

Covers the redesign's acceptance criteria:
  * duplicate-user batches are exact (the old `observe` lost feedback
    via last-writer-wins scatter);
  * one `step` over a distinct-user batch matches the offline stage
    engine (`runtime.stages` via `distclub.stage3`) — bit-exact choices,
    state to 1e-5 (observed exact) — single-host and 8-device sharded;
  * the transaction runs jit-end-to-end with the refresh scheduled by
    `lax.cond` (no host sync), and through the pallas-interpret engine;
  * a kill/restore round-trip through `CheckpointManager` resumes with
    bit-identical subsequent choices;
  * all four policies serve through the one `Policy` protocol.
"""
import functools

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import serve
from repro.core import distclub, env, env_ops, linucb
from repro.core.backend import BackendConfig
from repro.core.types import BanditHyper
from repro.runtime import stages
from repro.train.checkpoint import CheckpointManager

from test_distributed import _run_with_devices

N, D, K = 32, 8, 10
HYPER = BanditHyper(sigma=4, max_rounds=1, gamma=1.5, n_candidates=K)


@pytest.fixture(scope="module")
def planted():
    e, _ = env.make_synthetic_env(jax.random.PRNGKey(0), N, D, 4, K)
    return env_ops.synthetic_ops(e)


@functools.lru_cache(maxsize=None)
def _reward_fn(ops):
    # cached per EnvOps: the session's compiled transactions are keyed on
    # reward_fn identity, so a fresh closure per call would retrace the
    # whole step each iteration
    def reward_fn(key, uids, contexts, choice):
        # env draws are keyed per global user id; occ is unused by the
        # synthetic generator beyond its shape
        return ops.rewards_fn(key, jnp.zeros_like(uids), contexts, choice, 0)
    return reward_fn


def _ctx(ops, i):
    k_ctx, k_rew = jax.random.split(jax.random.PRNGKey(i))
    return ops.contexts_fn(k_ctx, jnp.zeros((N,), jnp.int32), 0), k_rew


# ---------------------------------------------------------------------------
# duplicate-user feedback
# ---------------------------------------------------------------------------


def test_duplicate_user_batch_is_exact(planted):
    """A batch with the same user twice advances occ by 2 and folds both
    rewards — matching the sequential Sherman-Morrison fold exactly."""
    sess = serve.OnlineBandit.create(N, D, HYPER, policy="linucb")
    uids = jnp.array([3, 3, 5], jnp.int32)
    ctx = jax.random.normal(jax.random.PRNGKey(9), (3, K, D))
    ctx = ctx / jnp.linalg.norm(ctx, axis=-1, keepdims=True)
    rewards = jnp.array([1.0, 0.5, 0.25])

    def fixed_rewards(key, u, c, ch):
        return rewards

    sess2, ch, m = serve.step(sess, jax.random.PRNGKey(0), uids, ctx,
                              fixed_rewards)
    assert int(sess2.state.occ[3]) == 2
    assert int(sess2.state.occ[5]) == 1
    assert int(m.interactions) == 3

    x = jnp.take_along_axis(ctx, ch[:, None, None], axis=1)[:, 0]
    Minv = linucb.sherman_morrison(jnp.eye(D), x[0])
    Minv = linucb.sherman_morrison(Minv, x[1])
    np.testing.assert_allclose(np.asarray(sess2.state.Minv[3]),
                               np.asarray(Minv), atol=1e-6)
    np.testing.assert_allclose(
        np.asarray(sess2.state.b[3]),
        np.asarray(rewards[0] * x[0] + rewards[1] * x[1]), atol=1e-6)
    # the old API's last-writer-wins failure mode: occ would be 1 and
    # only x[1]'s update present
    assert not np.allclose(np.asarray(sess2.state.Minv[3]),
                           np.asarray(linucb.sherman_morrison(jnp.eye(D),
                                                              x[1])))


def test_padded_requests_are_ignored(planted):
    """user_id < 0 marks a padding slot: no state change, not counted."""
    ops = planted
    sess = serve.OnlineBandit.create(N, D, HYPER, policy="distclub")
    uids = jnp.array([2, -1, 7], jnp.int32)
    ctx, k_rew = _ctx(ops, 0)
    sess2, _, m = serve.step(sess, k_rew, uids, ctx[:3], _reward_fn(ops))
    assert int(m.interactions) == 2
    assert int(sess2.state.occ.sum()) == 2
    assert int(sess2.state.since_refresh) == 2


# ---------------------------------------------------------------------------
# serving-vs-offline parity (the stage engine is the oracle)
# ---------------------------------------------------------------------------


def test_step_matches_stage3_round(planted):
    """One full-batch serving step == one stage-3 round of the offline
    engine from the same stage-2 state: bit-exact choices, exact state."""
    ops = planted
    st0 = distclub.init_state(N, D, HYPER)
    st2 = distclub.stage2(st0, HYPER, D)
    stage_key = jax.random.PRNGKey(7)
    st3, m3 = distclub.stage3(st2, ops, stage_key, HYPER)

    sess = serve.refresh(
        serve.OnlineBandit.create(N, D, HYPER, policy="distclub"))
    # forced refresh == stage 2 on the init state
    np.testing.assert_array_equal(np.asarray(sess.state.labels),
                                  np.asarray(st2.graph.labels))
    np.testing.assert_array_equal(np.asarray(sess.state.adj),
                                  np.asarray(st2.graph.adj))

    # replicate the round's key schedule (scan step key -> ctx/reward)
    k0 = jax.random.split(stage_key, 1)[0]
    k_ctx, k_rew = jax.random.split(k0)
    ctx = ops.contexts_fn(k_ctx, st2.lin.occ, 0)
    sess2, choices, m = serve.step(
        sess, k_rew, jnp.arange(N, dtype=jnp.int32), ctx, _reward_fn(ops))

    # bit-exact choices vs the stage pipeline's own fused choose
    be = BackendConfig.create().interact(N, D, K)
    uMcinv, ubc, umean = distclub.serving_snapshot(st2)
    use_own = stages.beta_gate(HYPER, st2.lin.occ, umean)
    w, minv_eff = stages.mix_scores(
        use_own, linucb.user_vector(st2.lin.Minv, st2.lin.b),
        linucb.user_vector(uMcinv, ubc), st2.lin.Minv, uMcinv)
    _, c_ref = be.choose(w, minv_eff, ctx, st2.lin.occ, HYPER.alpha)
    np.testing.assert_array_equal(np.asarray(choices), np.asarray(c_ref))

    # state parity with the full stage-3 round (observed exact; the
    # acceptance tolerance is 1e-5)
    np.testing.assert_array_equal(np.asarray(sess2.state.occ),
                                  np.asarray(st3.lin.occ))
    np.testing.assert_allclose(np.asarray(sess2.state.Minv),
                               np.asarray(st3.lin.Minv), atol=1e-5)
    np.testing.assert_allclose(np.asarray(sess2.state.b),
                               np.asarray(st3.lin.b), atol=1e-5)
    assert float(m.reward) == float(np.asarray(m3.reward).sum())


def test_step_sharded_8dev_matches_single_host():
    """The sharded serving binding runs the identical transaction: choices
    bit-exact per step, state equal after refreshes fired inside jit."""
    out = _run_with_devices("""
        import numpy as np
        import jax, jax.numpy as jnp
        from repro import serve
        from repro.core import env, env_ops
        from repro.core.types import BanditHyper

        N, D, K = 64, 8, 10
        hyper = BanditHyper(sigma=4, max_rounds=1, gamma=1.5,
                            n_candidates=K)
        e, _ = env.make_synthetic_env(jax.random.PRNGKey(0), N, D, 4, K)
        ops = env_ops.synthetic_ops(e)

        def reward_fn(key, uids, ctx, choice):
            return ops.rewards_fn(key, jnp.zeros_like(uids), ctx, choice, 0)

        mesh = jax.make_mesh((8,), ("users",))
        s1 = serve.OnlineBandit.create(N, D, hyper, policy="distclub",
                                       refresh_every=2 * N)
        s8 = serve.OnlineBandit.sharded(mesh, N, D, hyper,
                                        policy="distclub",
                                        refresh_every=2 * N)
        for i in range(5):
            k_ctx, k_rew = jax.random.split(jax.random.PRNGKey(i))
            ctx = ops.contexts_fn(k_ctx, jnp.zeros((N,), jnp.int32), 0)
            uids = jax.random.permutation(
                jax.random.PRNGKey(100 + i), N).astype(jnp.int32)
            s1, c1, m1 = serve.step(s1, k_rew, uids, ctx, reward_fn)
            s8, c8, m8 = serve.step(s8, k_rew, uids, ctx, reward_fn)
            np.testing.assert_array_equal(np.asarray(c1), np.asarray(c8))
            assert float(m1.reward) == float(m8.reward)
        # two refreshes fired inside the jitted transaction by now
        assert int(s8.state.since_refresh) == N
        np.testing.assert_array_equal(np.asarray(s1.state.occ),
                                      np.asarray(s8.state.occ))
        np.testing.assert_array_equal(np.asarray(s1.state.labels),
                                      np.asarray(s8.state.labels))
        np.testing.assert_array_equal(np.asarray(s1.state.adj),
                                      np.asarray(s8.state.adj))
        np.testing.assert_allclose(np.asarray(s1.state.Minv),
                                   np.asarray(s8.state.Minv), atol=1e-6)
        print("SERVE-SHARD-PARITY-OK")
    """)
    assert "SERVE-SHARD-PARITY-OK" in out


def test_serving_through_pallas_interpret_engine(planted):
    """The fused engine path (pallas, interpret off-TPU) serves with
    identical choices and 1e-5-close state to the reference engine."""
    ops = planted
    mk = lambda kind, interp: serve.OnlineBandit.create(
        N, D, HYPER, policy="distclub", refresh_every=N,
        backend=kind, interpret=interp)
    sp, sr = mk("pallas", True), mk("reference", None)
    for i in range(2):
        ctx, k_rew = _ctx(ops, i)
        uids = jnp.arange(N, dtype=jnp.int32)
        sp, cp, _ = serve.step(sp, k_rew, uids, ctx, _reward_fn(ops))
        sr, cr, _ = serve.step(sr, k_rew, uids, ctx, _reward_fn(ops))
        np.testing.assert_array_equal(np.asarray(cp), np.asarray(cr))
    np.testing.assert_allclose(np.asarray(sp.state.Minv),
                               np.asarray(sr.state.Minv), atol=1e-5)


# ---------------------------------------------------------------------------
# refresh scheduling + checkpointing
# ---------------------------------------------------------------------------


def test_refresh_fires_inside_jit(planted):
    """The interaction-budget cond re-clusters without any host sync."""
    ops = planted
    sess = serve.OnlineBandit.create(N, D, HYPER, policy="distclub",
                                     refresh_every=2 * N)
    assert int(sess.state.comm_bytes) == 0
    for i in range(4):
        ctx, k_rew = _ctx(ops, i)
        sess, _, _ = serve.step(sess, k_rew, jnp.arange(N, dtype=jnp.int32),
                                ctx, _reward_fn(ops))
    # 4N interactions / budget 2N -> exactly two stage-2 refreshes
    assert float(sess.state.comm_bytes) == 2 * stages.stage2_comm_bytes(N, D)
    assert int(sess.state.since_refresh) == 0


def test_checkpoint_restore_resumes_bit_identical(planted, tmp_path):
    """Kill/restore through CheckpointManager: the restarted replica's
    subsequent choices are bit-identical to the uninterrupted run."""
    ops = planted
    ck = CheckpointManager(tmp_path / "svc", keep=2)
    sess = serve.OnlineBandit.create(N, D, HYPER, policy="distclub",
                                     refresh_every=N)
    uids = jnp.arange(N, dtype=jnp.int32)
    for i in range(3):
        ctx, k_rew = _ctx(ops, i)
        sess, _, _ = serve.step(sess, k_rew, uids, ctx, _reward_fn(ops))
    sess.save(ck, 3)

    cont_choices, cont = [], sess
    for i in range(3, 6):
        ctx, k_rew = _ctx(ops, i)
        cont, ch, _ = serve.step(cont, k_rew, uids, ctx, _reward_fn(ops))
        cont_choices.append(np.asarray(ch))

    # the "crashed replica": a fresh session restored from the checkpoint
    restored, step = serve.OnlineBandit.create(
        N, D, HYPER, policy="distclub", refresh_every=N).restore(ck)
    assert step == 3
    for i, want in zip(range(3, 6), cont_choices):
        ctx, k_rew = _ctx(ops, i)
        restored, ch, _ = serve.step(restored, k_rew, uids, ctx,
                                     _reward_fn(ops))
        np.testing.assert_array_equal(np.asarray(ch), want)
    np.testing.assert_array_equal(np.asarray(restored.state.occ),
                                  np.asarray(cont.state.occ))
    np.testing.assert_array_equal(np.asarray(restored.state.Minv),
                                  np.asarray(cont.state.Minv))


def test_restore_on_empty_directory(planted, tmp_path):
    ck = CheckpointManager(tmp_path / "empty")
    sess = serve.OnlineBandit.create(N, D, HYPER, policy="linucb")
    same, step = sess.restore(ck)
    assert step is None and same is sess


# ---------------------------------------------------------------------------
# the Policy protocol
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("policy", serve.POLICIES)
def test_every_policy_serves_and_beats_random(planted, policy):
    """All four bandits through the one protocol; the learners beat the
    RAN baseline on the planted environment.  DCCB gets a short buffer
    (its statistics lag by `buffer_size` interactions) and a long gossip
    period — at this tiny scale each gossip round cuts a wrong-cluster
    edge and RESETS both endpoints (the paper's protocol), so frequent
    gossip erases more than it shares."""
    ops = planted
    hyper = HYPER._replace(buffer_size=8)
    steps = 30 if policy == "dccb" else 25
    every = 8 * N if policy == "dccb" else 2 * N
    sess = serve.OnlineBandit.create(N, D, hyper, policy=policy,
                                     refresh_every=every)
    tot_r = tot_rand = 0.0
    for i in range(steps):
        ctx, k_rew = _ctx(ops, i)
        sess, _, m = serve.step(sess, k_rew, jnp.arange(N, dtype=jnp.int32),
                                ctx, _reward_fn(ops))
        tot_r += float(m.reward)
        tot_rand += float(m.rand_reward)
    assert tot_r > tot_rand * 1.05, (policy, tot_r, tot_rand)


def test_recommend_observe_halves_match_step(planted):
    """The split request/feedback halves land on the same state as the
    fused transaction when fed the realized rewards."""
    ops = planted
    sess_a = serve.OnlineBandit.create(N, D, HYPER, policy="distclub",
                                       refresh_every=2 * N)
    sess_b = sess_a
    uids = jnp.arange(N, dtype=jnp.int32)
    for i in range(3):
        ctx, k_rew = _ctx(ops, i)
        sess_a, ch_a, _ = serve.step(sess_a, k_rew, uids, ctx,
                                     _reward_fn(ops))
        ch_b = serve.recommend(sess_b, uids, ctx)
        np.testing.assert_array_equal(np.asarray(ch_a), np.asarray(ch_b))
        realized, _, _, _ = _reward_fn(ops)(k_rew, uids, ctx, ch_b)
        sess_b = serve.observe(sess_b, uids, ctx, ch_b, realized, key=k_rew)
    np.testing.assert_array_equal(np.asarray(sess_a.state.occ),
                                  np.asarray(sess_b.state.occ))
    np.testing.assert_allclose(np.asarray(sess_a.state.Minv),
                               np.asarray(sess_b.state.Minv), atol=1e-6)


def test_warm_start_from_offline_run(planted):
    """`from_offline` resumes serving from a `distclub.run` state with the
    stage-3 snapshot semantics."""
    ops = planted
    hyper = HYPER._replace(max_rounds=8)
    state, _, _ = distclub.run(ops, jax.random.PRNGKey(1), hyper,
                               n_epochs=2, d=D)
    sess = serve.OnlineBandit.from_offline(state, hyper)
    np.testing.assert_array_equal(np.asarray(sess.state.occ),
                                  np.asarray(state.lin.occ))
    ctx, k_rew = _ctx(ops, 0)
    sess, ch, m = serve.step(sess, k_rew, jnp.arange(N, dtype=jnp.int32),
                             ctx, _reward_fn(ops))
    assert int(m.interactions) == N
    # round-trip back to the offline record for checkpoint consumers
    cfg = sess.policy.cfg
    back = serve.to_distclub_state(sess.state, cfg.hyper, cfg.d)
    np.testing.assert_array_equal(np.asarray(back.lin.occ),
                                  np.asarray(sess.state.occ))


def test_bandit_service_removed_with_pointer():
    """The retired PR-4 shim fails fast with a migration pointer instead
    of silently serving the old API."""
    with pytest.raises(ImportError, match="repro.serve"):
        import repro.serve.bandit_service  # noqa: F401
