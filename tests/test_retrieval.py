"""Catalog-scale retrieval: streaming top-K engine + two-stage serving.

Covers the PR-5 acceptance criteria:
  * top-K kernel parity vs the reference oracle at ragged shapes
    (N_items not a tile multiple, d off the sublane multiple, retired
    items masked) — identical ids, identical scores;
  * deterministic (score desc, id asc) selection: all-tied fresh state
    shortlists the lowest live ids;
  * two-stage recommend == direct-slate choose BIT-IDENTICALLY when the
    catalog fits in one slate (N_items <= K);
  * 8-device item-sharded shortlist + serving transaction == single-host
    (subprocess mesh, the ``tests/test_parity.py`` pattern);
  * save/restore round-trip of a serving session together with its
    Catalog through ``CheckpointManager``;
  * the ``kind="catalog"`` offline environment: shard-invariant draws
    and a learnable planted signal.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import serve
from repro.core import catalog as catalog_mod
from repro.core import env, env_ops
from repro.core.backend import BackendConfig
from repro.core.types import BanditHyper
from repro.data import datasets
from repro.train.checkpoint import CheckpointManager

from test_distributed import _run_with_devices

D = 8
HYPER = BanditHyper(sigma=4, max_rounds=1, gamma=1.5, n_candidates=10)


def _spd_stats(key, n, d, scale=0.1):
    ks = jax.random.split(key, 3)
    w = jax.random.normal(ks[0], (n, d))
    A = scale * jax.random.normal(ks[1], (n, d, d))
    Minv = jnp.eye(d) + jnp.einsum("nab,ncb->nac", A, A)
    occ = jax.random.randint(ks[2], (n,), 0, 50)
    return w, Minv, occ


# ---------------------------------------------------------------------------
# kernel parity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n,d,N,Ks", [
    (10, 7, 70, 8),       # everything ragged: n, d, N off every multiple
    (16, 8, 64, 16),      # aligned
    (5, 12, 260, 4),      # N just over a tile at block_items=128
])
def test_topk_pallas_matches_reference_ragged(n, d, N, Ks):
    """Reference oracle vs interpret-mode Pallas kernel: identical ids
    AND scores at ragged shapes with retired items in the mix — tiling
    and padding cannot perturb the (score, id) selection."""
    w, Minv, occ = _spd_stats(jax.random.PRNGKey(0), n, d)
    ks = jax.random.split(jax.random.PRNGKey(1), 2)
    items = jax.random.normal(ks[0], (N, d))
    items = items / jnp.linalg.norm(items, axis=-1, keepdims=True)
    live = (jax.random.uniform(ks[1], (N,)) > 0.25).astype(jnp.float32)

    r_ref = BackendConfig.create("reference").retrieval(
        d, Ks, row_block=4, item_block=16)
    r_pal = BackendConfig.create("pallas").retrieval(
        d, Ks, block_users=8, block_items=32, interpret=True)
    s1, i1 = r_ref.shortlist(w, Minv, occ, items, live, 0.3)
    s2, i2 = r_pal.shortlist(w, Minv, occ, items, live, 0.3)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=1e-6)

    # matches a dense brute-force top-K with (score desc, id asc) order
    scores = (jnp.einsum("nd,Nd->nN", w, items)
              + 0.3 * jnp.sqrt(jnp.maximum(jnp.einsum(
                  "Na,nab,Nb->nN", items, Minv, items), 0.0))
              * jnp.sqrt(jnp.log1p(occ.astype(jnp.float32)))[:, None])
    scores = jnp.where(live[None, :] > 0, scores, -jnp.inf)
    order = jnp.lexsort((jnp.broadcast_to(jnp.arange(N)[None], (n, N)),
                         -scores), axis=-1)[:, :Ks]
    want = jnp.where(jnp.isfinite(
        jnp.take_along_axis(scores, order, axis=1)), order, -1)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(want))

    # retired items never surface
    dead = set(np.nonzero(np.asarray(live) == 0)[0].tolist())
    assert not (set(np.asarray(i1).ravel().tolist()) & dead)


def test_topk_all_tied_prefers_lowest_live_ids():
    """Fresh statistics score every item identically (w=0, occ=0 kills
    the bonus): the shortlist must be the lowest LIVE ids in order —
    the tie-break that makes two-stage == direct-slate exact."""
    n, d, N, Ks = 4, 8, 40, 6
    items = jax.random.normal(jax.random.PRNGKey(0), (N, d))
    items = items / jnp.linalg.norm(items, axis=-1, keepdims=True)
    live = jnp.ones((N,), jnp.float32).at[jnp.array([0, 2, 3])].set(0.0)
    for kind, kw in [("reference", dict(row_block=4, item_block=16)),
                     ("pallas", dict(block_users=8, block_items=16,
                                     interpret=True))]:
        rb = BackendConfig.create(kind).retrieval(d, Ks, **kw)
        _, ids = rb.shortlist(jnp.zeros((n, d)),
                              jnp.broadcast_to(jnp.eye(d), (n, d, d)),
                              jnp.zeros((n,), jnp.int32), items, live, 0.3)
        want = np.array([1, 4, 5, 6, 7, 8])
        np.testing.assert_array_equal(np.asarray(ids),
                                      np.broadcast_to(want, (n, Ks)))


def test_topk_underfull_catalog_pads_with_minus_one():
    """k_short > live items: the tail keeps score -inf / id -1."""
    n, d, N, Ks = 3, 4, 5, 8
    items = jnp.eye(N, d, dtype=jnp.float32)
    live = jnp.ones((N,), jnp.float32).at[4].set(0.0)
    rb = BackendConfig.create("reference").retrieval(d, Ks, row_block=2,
                                                     item_block=4)
    w, Minv, occ = _spd_stats(jax.random.PRNGKey(2), n, d)
    s, i = rb.shortlist(w, Minv, occ, items, live, 0.3)
    assert (np.asarray(i)[:, 4:] == -1).all()
    assert not np.isfinite(np.asarray(s)[:, 4:]).any()
    assert (np.asarray(i)[:, :4] >= 0).all()


def test_shortlist_row0_offsets_ids():
    """row0_items turns tile-local ids global (the item-sharded path)."""
    n, d, N, Ks = 4, 8, 32, 4
    w, Minv, occ = _spd_stats(jax.random.PRNGKey(3), n, d)
    items = jax.random.normal(jax.random.PRNGKey(4), (N, d))
    live = jnp.ones((N,), jnp.float32)
    rb = BackendConfig.create("reference").retrieval(d, Ks)
    _, i0 = rb.shortlist(w, Minv, occ, items, live, 0.3)
    _, i7 = rb.shortlist(w, Minv, occ, items, live, 0.3, row0_items=7 * N)
    np.testing.assert_array_equal(np.asarray(i7), np.asarray(i0) + 7 * N)


# ---------------------------------------------------------------------------
# catalog state
# ---------------------------------------------------------------------------


def test_catalog_add_retire_roundtrip():
    cat = catalog_mod.random_catalog(jax.random.PRNGKey(0), 6, D,
                                     capacity=10)
    assert int(cat.n_live()) == 6
    cat, n_ret = catalog_mod.retire_items(cat,
                                          jnp.array([1, 4, -1], jnp.int32))
    assert int(n_ret) == 2
    # STAGED only: serving is untouched until the epoch flip
    assert int(cat.n_live()) == 6 and int(cat.epoch) == 0
    cat = catalog_mod.publish(cat)
    assert int(cat.n_live()) == 4 and int(cat.epoch) == 1
    fresh = jnp.ones((3, D), jnp.float32)
    cat, slots, n_add = catalog_mod.add_items(cat, fresh)
    # lowest dead slots first: the two just-retired + the first spare
    np.testing.assert_array_equal(np.asarray(slots), [1, 4, 6])
    assert int(n_add) == 3
    assert int(cat.n_live()) == 4           # still the published view
    cat = catalog_mod.publish(cat)
    assert int(cat.n_live()) == 7 and int(cat.epoch) == 2
    np.testing.assert_array_equal(np.asarray(cat.serving.emb[slots]),
                                  np.asarray(fresh))
    # arrivals are stamped with the epoch their publish created
    np.testing.assert_array_equal(np.asarray(cat.serving.born[slots]),
                                  [2, 2, 2])


# ---------------------------------------------------------------------------
# two-stage serving
# ---------------------------------------------------------------------------


def _catalog_world(n_users=16, n_items=6, n_candidates=None, seed=0):
    e, _ = env.make_catalog_env(
        jax.random.PRNGKey(seed), n_users, D, 4, n_items,
        n_candidates=n_candidates or HYPER.n_candidates)
    return e, serve.make_catalog(env.catalog_embeddings(e))


def _theta_reward_fn(theta):
    def reward_fn(key, uids, ctx, choice):
        return env.step_rewards(key, theta[uids], ctx, choice)
    return reward_fn


def test_two_stage_equals_direct_slate_bit_identical():
    """N_items <= K: the shortlist is the whole catalog in (score desc,
    id asc) order, so shortlist -> fused choose returns the exact item
    the direct-slate path picks — fresh (all-tied) AND trained state."""
    n_users, n_items = 16, 6
    hyper = HYPER._replace(n_candidates=n_items)
    e, cat = _catalog_world(n_users, n_items, n_candidates=n_items)
    reward_fn = _theta_reward_fn(e.theta)
    uids = jnp.arange(n_users, dtype=jnp.int32)
    slate = jnp.broadcast_to(env.catalog_embeddings(e)[None],
                             (n_users, n_items, D))

    sess = serve.OnlineBandit.create(n_users, D, hyper, policy="distclub")
    for i in range(6):            # i=0 probes the all-tied fresh state
        direct = serve.recommend(sess, uids, slate)   # slate idx == item id
        two_stage, _, _ = serve.recommend_catalog(sess, uids, cat,
                                                  k_short=16)
        np.testing.assert_array_equal(np.asarray(direct),
                                      np.asarray(two_stage))
        sess, items, _ = serve.step_catalog(sess, jax.random.PRNGKey(i),
                                            uids, cat, reward_fn,
                                            k_short=16)
        np.testing.assert_array_equal(np.asarray(items),
                                      np.asarray(direct))


def test_step_catalog_folds_feedback_and_learns():
    """The full transaction learns the planted signal: realized reward
    beats uniform-random-over-the-CATALOG (the metrics' own rand_reward
    is random-over-the-shortlist — already top-UCB items, so the honest
    retrieval baseline is the full catalog), occ advances, retired items
    vanish."""
    n_users, n_items = 32, 128
    e, cat = _catalog_world(n_users, n_items)
    retired = jnp.array([5, 50, 77], jnp.int32)
    cat, _ = serve.retire_items(cat, retired)
    cat = serve.publish(cat)
    reward_fn = _theta_reward_fn(e.theta)
    uids = jnp.arange(n_users, dtype=jnp.int32)
    # a FIXED catalog needs real exploration pressure (fresh-slate tests
    # resample contexts every round; here the 128 arms never change, so
    # the paper's alpha=0.03 parks everyone on one early item)
    hyper = HYPER._replace(alpha=0.5)
    sess = serve.OnlineBandit.create(n_users, D, hyper, policy="distclub",
                                     refresh_every=2 * n_users)
    steps, tot_r = 25, 0.0
    seen_items = set()
    for i in range(steps):
        sess, items, m = serve.step_catalog(
            sess, jax.random.PRNGKey(i), uids, cat, reward_fn, k_short=8)
        tot_r += float(m.reward)
        seen_items |= set(np.asarray(items).tolist())
    assert int(sess.state.occ.sum()) == steps * n_users
    assert not seen_items & set(np.asarray(retired).tolist())
    # uniform-random catalog baseline: mean expected reward of a live item
    p = 0.5 * (1.0 + e.theta @ env.catalog_embeddings(e).T)   # [n, N]
    p_rand = jnp.sum(p * cat.serving.live[None, :n_items],
                     axis=1) / jnp.sum(cat.serving.live[:n_items])
    baseline = steps * float(jnp.sum(p_rand))
    assert tot_r > baseline * 1.1, (tot_r, baseline)


def test_recommend_catalog_observe_matches_step_catalog():
    """The split request/feedback halves land on the same state as the
    fused catalog transaction when fed the realized rewards."""
    n_users, n_items = 16, 64
    e, cat = _catalog_world(n_users, n_items)
    reward_fn = _theta_reward_fn(e.theta)
    uids = jnp.arange(n_users, dtype=jnp.int32)
    sess_a = sess_b = serve.OnlineBandit.create(n_users, D, HYPER,
                                                policy="distclub")
    for i in range(3):
        key = jax.random.PRNGKey(i)
        sess_a, items_a, _ = serve.step_catalog(sess_a, key, uids, cat,
                                                reward_fn, k_short=8)
        items_b, slots, ctx = serve.recommend_catalog(sess_b, uids, cat,
                                                      k_short=8)
        np.testing.assert_array_equal(np.asarray(items_a),
                                      np.asarray(items_b))
        realized, _, _, _ = reward_fn(key, uids, ctx, slots)
        sess_b = serve.observe(sess_b, uids, ctx, slots, realized, key=key)
    np.testing.assert_array_equal(np.asarray(sess_a.state.occ),
                                  np.asarray(sess_b.state.occ))
    np.testing.assert_allclose(np.asarray(sess_a.state.Minv),
                               np.asarray(sess_b.state.Minv), atol=1e-6)


def test_item_sharded_8dev_matches_single_host():
    """Item-sharded two-stage serving == single-host, bit for bit: the
    per-shard shortlists merge to the identical global shortlist, the
    replicated choose picks the identical item, the feedback fold lands
    on the identical state (subprocess 8-device mesh)."""
    out = _run_with_devices("""
        import numpy as np
        import jax, jax.numpy as jnp
        from repro import serve
        from repro.core import catalog as catalog_mod, env
        from repro.core.types import BanditHyper
        from repro.distributed.distclub_shard import named_shardings

        N_USERS, D, N_ITEMS, KS = 64, 8, 256, 16
        hyper = BanditHyper(sigma=4, max_rounds=1, gamma=1.5,
                            n_candidates=10)
        e, _ = env.make_catalog_env(jax.random.PRNGKey(0), N_USERS, D, 4,
                                    N_ITEMS, n_candidates=10)
        cat = serve.make_catalog(env.catalog_embeddings(e))
        cat, _ = serve.retire_items(cat, jnp.array([3, 17, 200], jnp.int32))
        cat = serve.publish(cat)
        theta = e.theta

        def reward_fn(key, uids, ctx, choice):
            return env.step_rewards(key, theta[uids], ctx, choice)

        mesh = jax.make_mesh((8,), ("users",))
        s1 = serve.OnlineBandit.create(N_USERS, D, hyper,
                                       policy="distclub",
                                       refresh_every=2 * N_USERS)
        s8 = serve.OnlineBandit.sharded(mesh, N_USERS, D, hyper,
                                        policy="distclub",
                                        refresh_every=2 * N_USERS)
        cat8 = jax.device_put(
            cat, named_shardings(mesh, catalog_mod.specs(("users",))))
        for i in range(5):
            k = jax.random.PRNGKey(i)
            uids = jax.random.permutation(
                jax.random.PRNGKey(100 + i), N_USERS).astype(jnp.int32)
            s1, i1, m1 = serve.step_catalog(s1, k, uids, cat, reward_fn,
                                            k_short=KS)
            s8, i8, m8 = serve.step_catalog(s8, k, uids, cat8, reward_fn,
                                            k_short=KS)
            np.testing.assert_array_equal(np.asarray(i1), np.asarray(i8))
            assert float(m1.reward) == float(m8.reward)
        assert not set(np.asarray(i1).tolist()) & {3, 17, 200}
        # a refresh fired inside the jitted transaction by now
        assert int(s1.state.since_refresh) == int(s8.state.since_refresh)
        np.testing.assert_array_equal(np.asarray(s1.state.occ),
                                      np.asarray(s8.state.occ))
        np.testing.assert_array_equal(np.asarray(s1.state.labels),
                                      np.asarray(s8.state.labels))
        np.testing.assert_allclose(np.asarray(s1.state.Minv),
                                   np.asarray(s8.state.Minv), atol=1e-6)
        print("ITEM-SHARD-PARITY-OK")
    """)
    assert "ITEM-SHARD-PARITY-OK" in out


def test_catalog_session_checkpoint_roundtrip(tmp_path):
    """A serving session WITH its catalog round-trips through
    CheckpointManager: the restored pair resumes with bit-identical
    recommendations (catalog liveness churn included)."""
    n_users, n_items = 16, 64
    e, cat = _catalog_world(n_users, n_items)
    cat, _ = serve.retire_items(cat, jnp.array([9, 30], jnp.int32))
    cat = serve.publish(cat)
    reward_fn = _theta_reward_fn(e.theta)
    uids = jnp.arange(n_users, dtype=jnp.int32)
    sess = serve.OnlineBandit.create(n_users, D, HYPER, policy="distclub",
                                     refresh_every=n_users)
    for i in range(3):
        sess, _, _ = serve.step_catalog(sess, jax.random.PRNGKey(i), uids,
                                        cat, reward_fn, k_short=8)
    ck = CheckpointManager(tmp_path / "cat-sess", keep=2)
    ck.save((sess.state, cat), 3)

    cont_items, cont = [], sess
    for i in range(3, 6):
        cont, items, _ = serve.step_catalog(cont, jax.random.PRNGKey(i),
                                            uids, cat, reward_fn,
                                            k_short=8)
        cont_items.append(np.asarray(items))

    fresh = serve.OnlineBandit.create(n_users, D, HYPER, policy="distclub",
                                      refresh_every=n_users)
    fresh_cat = serve.make_catalog(jnp.zeros((n_items, D), jnp.float32))
    (state, cat_r), step = ck.restore_latest((fresh.state, fresh_cat))
    assert step == 3
    restored = fresh.__class__(policy=fresh.policy, state=state)
    np.testing.assert_array_equal(np.asarray(cat_r.live),
                                  np.asarray(cat.live))
    for i, want in zip(range(3, 6), cont_items):
        restored, items, _ = serve.step_catalog(
            restored, jax.random.PRNGKey(i), uids, cat_r, reward_fn,
            k_short=8)
        np.testing.assert_array_equal(np.asarray(items), want)
    np.testing.assert_array_equal(np.asarray(restored.state.occ),
                                  np.asarray(cont.state.occ))


# ---------------------------------------------------------------------------
# the kind="catalog" offline environment
# ---------------------------------------------------------------------------


def test_catalog_env_ops_shard_invariant_draws():
    """Slates drawn from the persistent catalog are keyed per GLOBAL
    user id: a row0 slice sees exactly the full-range rows (the sharding
    parity contract every EnvOps obeys)."""
    spec = datasets.DatasetSpec("t", 1024, 16, D, 4, n_candidates=5)
    ops, _ = datasets.make_env(spec, kind="catalog", n_items=32)
    key = jax.random.PRNGKey(0)
    occ = jnp.zeros((16,), jnp.int32)
    full = ops.contexts_fn(key, occ, 0)
    half = ops.contexts_fn(key, occ[8:], 8)
    np.testing.assert_array_equal(np.asarray(full[8:]), np.asarray(half))
    r_full = ops.rewards_fn(key, occ, full, jnp.zeros((16,), jnp.int32), 0)
    r_half = ops.rewards_fn(key, occ[8:], half,
                            jnp.zeros((8,), jnp.int32), 8)
    np.testing.assert_array_equal(np.asarray(r_full[0][8:]),
                                  np.asarray(r_half[0]))


def test_catalog_env_drift_redraws_regions():
    """With drift_period set, crossing the phase boundary re-draws the
    region centroids: the same (user, key) slate changes; within a phase
    it is stable."""
    e, _ = env.make_catalog_env(jax.random.PRNGKey(0), 8, D, 2, 64,
                                n_candidates=4, drift_period=10,
                                n_phases=3)
    ops = env_ops.catalog_ops(e)
    key = jax.random.PRNGKey(5)
    occ0 = jnp.zeros((8,), jnp.int32)
    a = ops.contexts_fn(key, occ0, 0)
    b = ops.contexts_fn(key, occ0 + 5, 0)       # same phase
    c = ops.contexts_fn(key, occ0 + 10, 0)      # next phase
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert not np.allclose(np.asarray(a), np.asarray(c))
    # the phase-0 table equals the materialized serving catalog rows
    ids = jax.vmap(lambda k: jax.random.randint(k, (4,), 0, 64))(
        jax.vmap(jax.random.fold_in, in_axes=(None, 0))(
            key, jnp.arange(8, dtype=jnp.int32)))
    np.testing.assert_allclose(np.asarray(a),
                               np.asarray(env.catalog_embeddings(e)[ids]),
                               atol=1e-6)


# ---------------------------------------------------------------------------
# churn edge cases + degenerate serving batches (PR-6 regressions)
# ---------------------------------------------------------------------------


def test_add_items_partial_fill_beyond_capacity():
    """Adding past free capacity places what fits (ascending dead slots,
    input order) and returns slot -1 for the overflow — live embeddings
    are never overwritten."""
    cat = catalog_mod.random_catalog(jax.random.PRNGKey(1), 6, D,
                                     capacity=8)
    before = np.asarray(cat.serving.emb[:6]).copy()
    fresh = jnp.arange(5 * D, dtype=jnp.float32).reshape(5, D)
    cat2, slots, n_add = catalog_mod.add_items(cat, fresh)
    np.testing.assert_array_equal(np.asarray(slots), [6, 7, -1, -1, -1])
    assert int(n_add) == 2
    cat2 = catalog_mod.publish(cat2)
    assert int(cat2.n_live()) == 8
    np.testing.assert_array_equal(np.asarray(cat2.serving.emb[:6]), before)
    np.testing.assert_array_equal(np.asarray(cat2.serving.emb[6:]),
                                  np.asarray(fresh[:2]))
    # a full catalog accepts nothing, even a batch wider than capacity
    cat3, slots3, n3 = catalog_mod.add_items(
        cat2, jnp.ones((12, D), jnp.float32))
    assert int(n3) == 0
    assert np.all(np.asarray(slots3) == -1)
    np.testing.assert_array_equal(
        np.asarray(catalog_mod.publish(cat3).serving.emb),
        np.asarray(cat2.serving.emb))


def test_retire_items_dead_dup_out_of_range_are_noops():
    """Retiring dead slots, duplicates, negatives, and out-of-range ids
    is a counted no-op — only real live->dead transitions count."""
    cat = catalog_mod.random_catalog(jax.random.PRNGKey(2), 4, D,
                                     capacity=6)
    cat, n1 = catalog_mod.retire_items(
        cat, jnp.array([2, 2, 5, -3, 99], jnp.int32))
    assert int(n1) == 1                 # only slot 2 was live
    cat = catalog_mod.publish(cat)
    assert int(cat.n_live()) == 3
    cat, n2 = catalog_mod.retire_items(cat, jnp.array([2, 5], jnp.int32))
    assert int(n2) == 0                 # both already dead
    assert int(catalog_mod.publish(cat).n_live()) == 3
    # retire-then-readd stages back onto the freed slot (same shadow
    # bank, so the staged retirement and the add compose)
    cat, slots, n3 = catalog_mod.add_items(cat,
                                           jnp.ones((1, D), jnp.float32))
    assert int(n3) == 1 and np.asarray(slots).tolist() == [2]


def _degenerate_world(n_users=16, n_items=64):
    e, _ = env.make_catalog_env(jax.random.PRNGKey(4), n_users, D, 4,
                                n_items, n_candidates=HYPER.n_candidates)
    cat = serve.make_catalog(env.catalog_embeddings(e))

    def reward_fn(key, uids, ctx, choice):
        return env.step_rewards(key, e.theta[uids], ctx, choice)
    return e, cat, reward_fn


_DEGENERATE_REWARD_FNS = {}


def _degenerate_cached(n_users=16, n_items=64):
    # reward_fn identity keys the compiled transaction; cache per shape
    key = (n_users, n_items)
    if key not in _DEGENERATE_REWARD_FNS:
        _DEGENERATE_REWARD_FNS[key] = _degenerate_world(n_users, n_items)
    return _DEGENERATE_REWARD_FNS[key]


def test_step_catalog_all_padded_batch_is_noop():
    """Every uid < 0: no items served (-1), zero interactions, state
    byte-identical — the degenerate batch a sharded pipeline's tail
    produces."""
    _, cat, reward_fn = _degenerate_cached()
    sess = serve.OnlineBandit.create(16, D, HYPER, policy="distclub",
                                     refresh_every=64)
    uids = jnp.full((8,), -1, jnp.int32)
    sess2, items, m = serve.step_catalog(sess, jax.random.PRNGKey(0),
                                         uids, cat, reward_fn, k_short=8)
    assert np.all(np.asarray(items) == -1)
    assert int(m.interactions) == 0
    assert float(m.reward) == 0.0
    for a, b in zip(jax.tree_util.tree_leaves(sess.state),
                    jax.tree_util.tree_leaves(sess2.state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_step_catalog_underfull_shortlist_tiny_live_count():
    """k_short > live items: the shortlist pads with the user's top
    entry, served items stay within the live set, feedback folds."""
    _, cat, reward_fn = _degenerate_cached()
    keep = jnp.array([7, 21], jnp.int32)
    dead = jnp.array([i for i in range(64) if i not in (7, 21)],
                     jnp.int32)
    cat, n_ret = serve.retire_items(cat, dead)
    cat = serve.publish(cat)
    assert int(n_ret) == 62 and int(cat.n_live()) == 2
    sess = serve.OnlineBandit.create(16, D, HYPER, policy="distclub",
                                     refresh_every=64)
    uids = jnp.arange(8, dtype=jnp.int32)
    for i in range(3):
        sess, items, m = serve.step_catalog(sess, jax.random.PRNGKey(i),
                                            uids, cat, reward_fn,
                                            k_short=8)
        assert set(np.asarray(items).tolist()) <= set(
            np.asarray(keep).tolist()), items
        assert int(m.interactions) == 8
    assert int(jnp.sum(sess.state.occ)) == 24


def test_step_catalog_duplicate_uids_interleaved_with_padding():
    """[u, -1, u, -1, v]: both occurrences of u fold (occurrence-rank
    passes), padding contributes nothing."""
    _, cat, reward_fn = _degenerate_cached()
    sess = serve.OnlineBandit.create(16, D, HYPER, policy="distclub",
                                     refresh_every=1000)
    uids = jnp.array([3, -1, 3, -1, 5], jnp.int32)
    sess2, items, m = serve.step_catalog(sess, jax.random.PRNGKey(0),
                                         uids, cat, reward_fn, k_short=8)
    assert int(m.interactions) == 3
    assert int(sess2.state.occ[3]) == 2
    assert int(sess2.state.occ[5]) == 1
    it = np.asarray(items)
    assert it[1] == -1 and it[3] == -1
    assert it[0] >= 0 and it[2] >= 0 and it[4] >= 0
