"""Unit + property tests for the linear-bandit primitives."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_shim import given, settings, st

from repro.core import linucb
from repro.core.types import LinUCBState


def test_init_state_identity():
    st_ = linucb.init_linucb(5, 7)
    np.testing.assert_allclose(st_.M[3], np.eye(7))
    np.testing.assert_allclose(st_.Minv[0], np.eye(7))
    assert st_.occ.sum() == 0


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 12), st.integers(0, 2**31 - 1))
def test_sherman_morrison_matches_inverse(d, seed):
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    A = jax.random.normal(k1, (d, d)) * 0.3
    M = jnp.eye(d) + A @ A.T
    x = jax.random.normal(k2, (d,))
    got = linucb.sherman_morrison(jnp.linalg.inv(M), x)
    want = jnp.linalg.inv(M + jnp.outer(x, x))
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


def test_ucb_scores_formula():
    d, K = 4, 6
    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (d,))
    Minv = jnp.eye(d) * 0.5
    ctx = jax.random.normal(jax.random.PRNGKey(1), (K, d))
    occ = jnp.int32(7)
    scores = linucb.ucb_scores(w, Minv, ctx, occ, alpha=0.3)
    want = ctx @ w + 0.3 * jnp.sqrt(
        jnp.sum(ctx * (ctx @ (jnp.eye(d) * 0.5)), -1)
    ) * jnp.sqrt(jnp.log1p(7.0))
    np.testing.assert_allclose(scores, want, rtol=1e-5)


def test_bonus_shrinks_statistics_grow():
    """More observations of a direction -> smaller bonus along it."""
    d = 3
    x = jnp.array([1.0, 0.0, 0.0])
    state = linucb.init_linucb(1, d)
    s0 = linucb.ucb_scores(jnp.zeros(d), state.Minv[0], x[None], state.occ[0], 1.0)
    for _ in range(5):
        state = linucb.rank1_update(state, jnp.int32(0), x, jnp.float32(1.0))
    s1_bonus = linucb.ucb_scores(
        jnp.zeros(d), state.Minv[0], x[None], jnp.int32(0), 1.0)
    assert float(s1_bonus[0]) < float(s0[0]) + 1e-6


def test_masked_batch_update_is_identity_for_masked_out():
    n, d = 6, 4
    state = linucb.init_linucb(n, d)
    x = jax.random.normal(jax.random.PRNGKey(0), (n, d))
    r = jnp.ones((n,))
    mask = jnp.array([True, False, True, False, True, False])
    new = linucb.masked_batch_update(state, x, r, mask)
    for i in range(n):
        if mask[i]:
            assert float(jnp.abs(new.M[i] - state.M[i]).sum()) > 0
            assert new.occ[i] == 1
        else:
            np.testing.assert_array_equal(new.M[i], state.M[i])
            np.testing.assert_array_equal(new.b[i], state.b[i])
            assert new.occ[i] == 0


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 8), st.integers(2, 6), st.integers(0, 2**31 - 1))
def test_masked_update_keeps_minv_exact(n, d, seed):
    """Property: after arbitrary masked updates, Minv == inv(M)."""
    key = jax.random.PRNGKey(seed)
    state = linucb.init_linucb(n, d)
    for i in range(3):
        kx, km, kr, key = jax.random.split(key, 4)
        x = jax.random.normal(kx, (n, d))
        mask = jax.random.bernoulli(km, 0.6, (n,))
        r = jax.random.uniform(kr, (n,))
        state = linucb.masked_batch_update(state, x, r, mask)
    np.testing.assert_allclose(
        jnp.einsum("nij,njk->nik", state.M, state.Minv),
        jnp.broadcast_to(jnp.eye(d), (n, d, d)), atol=5e-2)
