"""Single-host vs sharded parity through the unified stage engine.

Both drivers bind the SAME stage bodies (``repro.runtime.stages``) — the
single-host driver to ``NullCollectives``, the sharded one to ``lax``
collectives on an 8-device host-platform mesh — and every environment
draw is keyed by GLOBAL user id, so from one seed the two runs must
agree:

  * exactly on everything integer-valued or per-user (interactions,
    realized rewards, occ, pruned adjacency bits, CC labels, cluster
    counts, Minv/b state): per-user math is identical elementwise and the
    graph engine is bit-exact across row shardings;
  * within fp-contraction tolerance on the float metric sums (the psum of
    per-shard partials reassociates the additions) — observed ~1e-6 at
    this scale, asserted at 1e-4.

The only cross-user float contraction feeding back into decisions is the
stage-2 psum of cluster aggregates; at test scale it has never flipped an
argmax (state equality below is exact), and if a future change makes that
flip legitimately possible the exact asserts are the tripwire.

Also here: the replay-backed and drift scenarios running under shard_map
(the unification's point — the old sharded runtime hard-coded the
synthetic generator), with per-stage drift parity.
"""
from test_distributed import _run_with_devices


def test_distclub_single_host_vs_sharded_parity():
    out = _run_with_devices("""
        import numpy as np
        import jax
        from repro.core import distclub, env, env_ops
        from repro.core.types import BanditHyper
        from repro.distributed import distclub_shard

        N, D, K, E = 64, 8, 10, 3
        hyper = BanditHyper(sigma=8, max_rounds=16, gamma=1.5,
                            n_candidates=K)
        e, _ = env.make_synthetic_env(jax.random.PRNGKey(0), N, D, 4, K)
        ops = env_ops.synthetic_ops(e)

        s1, m1, c1 = distclub.run(ops, jax.random.PRNGKey(1), hyper,
                                  n_epochs=E, d=D)
        R = 2 * hyper.max_rounds
        m1 = jax.tree.map(lambda v: np.asarray(v).reshape(E, R), m1)

        mesh = jax.make_mesh((8,), ("users",))
        init_fn, epoch = distclub_shard.make_runtime(
            mesh, ("users",), N, D, hyper, ops=ops)
        st = init_fn(jax.random.PRNGKey(0))
        # the single-host run splits its key once per epoch; feed the
        # sharded epochs the same schedule
        keys = jax.random.split(jax.random.PRNGKey(1), E)
        ms, nclus = [], []
        for k in keys:
            st, mm, nc = epoch(st, k)
            ms.append(jax.tree.map(np.asarray, mm))
            nclus.append(int(nc))
        ms = jax.tree.map(lambda *xs: np.stack(xs), *ms)

        # exact: integer metrics, realized rewards, cluster counts
        np.testing.assert_array_equal(ms.interactions, m1.interactions)
        np.testing.assert_array_equal(ms.reward, m1.reward)
        np.testing.assert_array_equal(np.asarray(c1), np.asarray(nclus))
        # fp-contraction tolerance: psum reassociates the float sums
        np.testing.assert_allclose(ms.regret, m1.regret, atol=1e-4)
        np.testing.assert_allclose(ms.rand_reward, m1.rand_reward,
                                   atol=1e-4)
        # exact: per-user state and the stage-2 graph
        np.testing.assert_array_equal(np.asarray(st.occ),
                                      np.asarray(s1.lin.occ))
        np.testing.assert_array_equal(np.asarray(st.labels),
                                      np.asarray(s1.graph.labels))
        np.testing.assert_array_equal(np.asarray(st.adj),
                                      np.asarray(s1.graph.adj))
        np.testing.assert_allclose(np.asarray(st.Minv),
                                   np.asarray(s1.lin.Minv), atol=1e-6)
        np.testing.assert_allclose(np.asarray(st.b),
                                   np.asarray(s1.lin.b), atol=1e-6)
        # the comm model is shared code, but assert the accounting wiring
        assert float(st.comm_bytes) == float(s1.comm_bytes)
        print("PARITY-OK")
    """)
    assert "PARITY-OK" in out


def test_drift_scenario_per_stage_parity_sharded():
    """The non-stationary scenario through both drivers: per-stage metric
    slices (stage-1 rows vs stage-3 rows of each epoch) agree between the
    single-host and 8-way sharded runs."""
    out = _run_with_devices("""
        import numpy as np
        import jax
        from repro.core import distclub, env, env_ops
        from repro.core.types import BanditHyper
        from repro.distributed import distclub_shard

        N, D, K, E = 64, 8, 10, 4
        hyper = BanditHyper(sigma=8, max_rounds=16, gamma=1.5,
                            n_candidates=K)
        denv, _ = env.make_drift_env(jax.random.PRNGKey(0), N, D, 4, K,
                                     drift_period=24, n_phases=3)
        ops = env_ops.drift_ops(denv)

        s1, m1, c1 = distclub.run(ops, jax.random.PRNGKey(2), hyper,
                                  n_epochs=E, d=D)
        R = hyper.max_rounds
        m1 = jax.tree.map(lambda v: np.asarray(v).reshape(E, 2 * R), m1)

        mesh = jax.make_mesh((8,), ("users",))
        init_fn, epoch = distclub_shard.make_runtime(
            mesh, ("users",), N, D, hyper, ops=ops)
        st = init_fn(jax.random.PRNGKey(0))
        keys = jax.random.split(jax.random.PRNGKey(2), E)
        ms = []
        for k in keys:
            st, mm, _ = epoch(st, k)
            ms.append(jax.tree.map(np.asarray, mm))
        ms = jax.tree.map(lambda *xs: np.stack(xs), *ms)

        for stage, sl in (("stage1", slice(0, R)), ("stage3", slice(R, None))):
            np.testing.assert_array_equal(
                ms.interactions[:, sl], m1.interactions[:, sl])
            np.testing.assert_array_equal(
                ms.reward[:, sl], m1.reward[:, sl])
            np.testing.assert_allclose(
                ms.regret[:, sl], m1.regret[:, sl], atol=1e-4)
        # the drift actually bites inside the horizon: some user crossed
        # a phase boundary (occ >= drift_period)
        assert int(np.asarray(st.occ).max()) >= 24
        print("DRIFT-PARITY-OK")
    """)
    assert "DRIFT-PARITY-OK" in out


def test_replay_scenario_runs_sharded():
    """Logged-replay EnvOps under shard_map: per-user queues sliced by
    row0, learner beats random, metrics match the single-host replay run
    exactly on integers."""
    out = _run_with_devices("""
        import numpy as np
        import jax
        from repro.core import distclub
        from repro.core.types import BanditHyper
        from repro.data.datasets import DatasetSpec, make_env
        from repro.distributed import distclub_shard

        spec = DatasetSpec("tiny", 4096, 64, 8, 4, n_candidates=10)
        ops, _ = make_env(spec, seed=3, kind="replay")
        hyper = BanditHyper(sigma=8, max_rounds=16, gamma=1.5,
                            n_candidates=10)
        E = 3
        s1, m1, _ = distclub.run(ops, jax.random.PRNGKey(4), hyper,
                                 n_epochs=E, d=8)

        mesh = jax.make_mesh((8,), ("users",))
        init_fn, epoch = distclub_shard.make_runtime(
            mesh, ("users",), 64, 8, hyper, ops=ops)
        st = init_fn(jax.random.PRNGKey(0))
        keys = jax.random.split(jax.random.PRNGKey(4), E)
        tot_r = tot_rand = tot_t = 0.0
        rew = []
        for k in keys:
            st, mm, _ = epoch(st, k)
            rew.append(np.asarray(mm.reward))
            tot_r += float(mm.reward.sum())
            tot_rand += float(mm.rand_reward.sum())
            tot_t += int(mm.interactions.sum())
        assert tot_t == 64 * 2 * hyper.sigma * E
        assert tot_r > tot_rand * 1.05, (tot_r, tot_rand)
        np.testing.assert_array_equal(
            np.concatenate(rew), np.asarray(m1.reward))
        print("REPLAY-SHARD-OK", tot_r / tot_rand)
    """)
    assert "REPLAY-SHARD-OK" in out
