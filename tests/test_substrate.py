"""Substrate tests: optimizer, checkpointing (fault tolerance), MoE dispatch."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import moe, transformer
from repro.train import checkpoint, optimizer

KEY = jax.random.PRNGKey(0)


# --- optimizer -------------------------------------------------------------------


def test_adamw_first_step_is_scaled_sign():
    params = {"w": jnp.ones((4,))}
    grads = {"w": jnp.array([1.0, -1.0, 2.0, 0.0])}
    opt = optimizer.adamw_init(params)
    new, opt = optimizer.adamw_update(grads, opt, params, lr=0.1,
                                      weight_decay=0.0)
    # first Adam step with bias correction = lr * sign(g) (approximately)
    np.testing.assert_allclose(new["w"][:3], 1.0 - 0.1 * jnp.sign(
        grads["w"][:3]), rtol=1e-4)
    np.testing.assert_allclose(new["w"][3], 1.0)
    assert int(opt.step) == 1


def test_adamw_chunked_matches_unchunked():
    big = {"w": jax.random.normal(KEY, (4, 256, 400))}   # > threshold? no
    grads = {"w": jax.random.normal(jax.random.PRNGKey(1), (4, 256, 400))}
    opt = optimizer.adamw_init(big)
    ref, _ = optimizer.adamw_update(grads, opt, big)
    old = optimizer._CHUNK_BYTES
    try:
        optimizer._CHUNK_BYTES = 1024       # force chunking
        got, _ = optimizer.adamw_update(grads, opt, big)
    finally:
        optimizer._CHUNK_BYTES = old
    np.testing.assert_allclose(got["w"], ref["w"], rtol=1e-6, atol=1e-6)


def test_adafactor_decreases_loss():
    w_true = jnp.array([[1.0, -2.0], [0.5, 3.0]])
    params = {"w": jnp.zeros((2, 2))}
    opt = optimizer.adafactor_init(params, momentum_dtype=jnp.float32)
    x = jax.random.normal(KEY, (64, 2))

    def loss_fn(p):
        return jnp.mean((x @ p["w"] - x @ w_true) ** 2)

    losses = []
    for _ in range(60):
        loss, g = jax.value_and_grad(loss_fn)(params)
        params, opt = optimizer.adafactor_update(g, opt, params, lr=0.05)
        losses.append(float(loss))
    assert losses[-1] < 0.2 * losses[0]


def test_adagrad_sparse_accumulates():
    params = {"e": jnp.ones((8, 2))}
    g = {"e": jnp.zeros((8, 2)).at[3].set(1.0)}
    opt = optimizer.adagrad_init(params)
    new, opt = optimizer.adagrad_update(g, opt, params, lr=0.1)
    assert float(new["e"][3, 0]) < 1.0
    np.testing.assert_allclose(new["e"][0], 1.0)


# --- checkpoint / fault tolerance ---------------------------------------------


def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "params": {"w": jax.random.normal(k, (8, 4)),
                   "ln": [jnp.ones((4,)), jnp.zeros((4,))]},
        "step": jnp.int32(7),
        "occ": jnp.arange(8, dtype=jnp.int32),
    }


def test_checkpoint_roundtrip(tmp_path):
    mgr = checkpoint.CheckpointManager(tmp_path, keep=2)
    state = _state()
    mgr.save(state, 100)
    restored, step = mgr.restore_latest(jax.eval_shape(lambda: state))
    assert step == 100
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_keep_k_and_latest(tmp_path):
    mgr = checkpoint.CheckpointManager(tmp_path, keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(_state(s), s)
    assert mgr.steps() == [3, 4]
    assert mgr.latest_step() == 4


def test_checkpoint_crash_leaves_no_corruption(tmp_path):
    """A tmp dir from a dead writer must not be visible as a checkpoint."""
    mgr = checkpoint.CheckpointManager(tmp_path, keep=3)
    mgr.save(_state(), 5)
    (tmp_path / "tmp-6").mkdir()                      # simulated dead writer
    (tmp_path / "tmp-6" / "arrays.npz").write_bytes(b"garbage")
    assert mgr.latest_step() == 5
    restored, step = mgr.restore_latest(jax.eval_shape(lambda: _state()))
    assert step == 5


def test_checkpoint_elastic_restore_changes_sharding(tmp_path):
    """Restore onto a different 'mesh' (1-device) — elastic scaling."""
    mesh = jax.make_mesh((1,), ("x",))
    from jax.sharding import NamedSharding, PartitionSpec as P

    mgr = checkpoint.CheckpointManager(tmp_path)
    state = _state()
    mgr.save(state, 1)
    sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), state)
    restored, _ = mgr.restore_latest(jax.eval_shape(lambda: state), sh)
    assert restored["params"]["w"].sharding == NamedSharding(mesh, P())


def test_checkpoint_resume_continues_training(tmp_path):
    """Simulated failure mid-run: resume reproduces the uninterrupted run."""
    from repro.core import distclub, env, env_ops
    from repro.core.types import BanditHyper

    e, _ = env.make_synthetic_env(KEY, 32, 8, 4, 10)
    ops = env_ops.synthetic_ops(e)
    hyper = BanditHyper(sigma=4, max_rounds=8, n_candidates=10)

    state = distclub.init_state(32, 8, hyper)
    keys = jax.random.split(jax.random.PRNGKey(9), 4)

    def epoch(state, k):
        k1, k3 = jax.random.split(k)
        state, _ = distclub.stage1(state, ops, k1, hyper)
        state = distclub.stage2(state, hyper, 8)
        state, _ = distclub.stage3(state, ops, k3, hyper)
        return distclub.stage4(state, hyper)

    # uninterrupted
    s_ref = state
    for k in keys:
        s_ref = epoch(s_ref, k)

    # interrupted after 2 epochs + restore
    mgr = checkpoint.CheckpointManager(tmp_path)
    s = state
    for k in keys[:2]:
        s = epoch(s, k)
    mgr.save(s, 2)
    restored, step = mgr.restore_latest(jax.eval_shape(lambda: s))
    assert step == 2
    for k in keys[2:]:
        restored = epoch(restored, k)

    np.testing.assert_allclose(np.asarray(s_ref.lin.b),
                               np.asarray(restored.lin.b), rtol=1e-5,
                               atol=1e-5)
    np.testing.assert_array_equal(np.asarray(s_ref.graph.labels),
                                  np.asarray(restored.graph.labels))


# --- MoE dispatch ---------------------------------------------------------------


def _moe_cfg(**kw):
    base = dict(n_layers=2, d_model=32, n_heads=2, n_kv_heads=2, d_head=16,
                d_ff=64, vocab=128, n_experts=4, top_k=2, n_shared=0,
                d_ff_expert=32, capacity_factor=4.0, dtype=jnp.float32)
    base.update(kw)
    return transformer.LMConfig(**base)


def test_moe_matches_dense_routing_at_high_capacity():
    """cf high enough -> no drops -> output == explicit per-token mixture."""
    cfg = _moe_cfg()
    params = moe.init_moe(KEY, cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 8, 32))
    out, aux = moe.moe_fwd(params, cfg, x)

    xt = x.reshape(-1, 32)
    logits = xt @ params["router"]
    probs = jax.nn.softmax(logits, -1)
    gv, gi = jax.lax.top_k(probs, cfg.top_k)
    gv = gv / gv.sum(-1, keepdims=True)
    we = params["experts"]

    def expert(e, z):
        h = jax.nn.silu(z @ we["gate"][e]) * (z @ we["up"][e])
        return h @ we["down"][e]

    want = jnp.zeros_like(xt)
    for t in range(xt.shape[0]):
        for j in range(cfg.top_k):
            want = want.at[t].add(gv[t, j] * expert(gi[t, j], xt[t]))
    np.testing.assert_allclose(out.reshape(-1, 32), want, rtol=2e-4,
                               atol=2e-4)
    assert float(aux) > 0


def test_moe_capacity_drops_tokens():
    cfg = _moe_cfg(capacity_factor=0.05, top_k=1)
    params = moe.init_moe(KEY, cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 16, 32))
    out, _ = moe.moe_fwd(params, cfg, x)
    # capacity 0.05 -> most tokens dropped -> many zero outputs
    zero_rows = jnp.sum(jnp.all(out.reshape(-1, 32) == 0, axis=-1))
    assert int(zero_rows) > 16


def test_moe_grads_flow_to_all_parts():
    cfg = _moe_cfg()
    params = moe.init_moe(KEY, cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 8, 32))

    def loss(p):
        out, aux = moe.moe_fwd(p, cfg, x)
        return jnp.sum(out ** 2) + 0.01 * aux

    g = jax.grad(loss)(params)
    assert float(jnp.abs(g["router"]).sum()) > 0
    assert float(jnp.abs(g["experts"]["gate"]).sum()) > 0
