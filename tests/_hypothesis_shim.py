"""Fallback for environments without ``hypothesis``.

When the real package is available it is re-exported untouched.  Otherwise
``@given`` degrades to running the test body over a deterministic
pseudo-random sample grid (seeded, so failures reproduce) — weaker than
real property testing but it keeps the whole suite collectable and the
invariants exercised on machines where ``hypothesis`` cannot be installed.

Only the surface this repo uses is shimmed: positional
``st.integers(lo, hi)`` / ``st.floats(lo, hi)`` and
``@settings(max_examples=..., deadline=...)``.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    import functools
    import random

    HAVE_HYPOTHESIS = False
    _FALLBACK_EXAMPLES = 5     # keep the no-hypothesis path fast

    class _Strategy:
        def __init__(self, sample):
            self.sample = sample

    class st:  # noqa: N801 - mimics `hypothesis.strategies` module
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def floats(min_value, max_value, **_kw):
            return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    def settings(*, max_examples=None, **_kw):
        def deco(fn):
            if max_examples is not None:
                fn._max_examples = min(max_examples, _FALLBACK_EXAMPLES)
            return fn
        return deco

    def given(*strategies):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper():
                rng = random.Random(1234)
                n = getattr(wrapper, "_max_examples", _FALLBACK_EXAMPLES)
                for _ in range(n):
                    fn(*(s.sample(rng) for s in strategies))

            # hypothesis consumes the strategy-bound params; hide the
            # original signature (set by functools.wraps) so pytest doesn't
            # look for fixtures named n/seed/...
            del wrapper.__wrapped__
            return wrapper
        return deco
