"""Tests: int8 gradient compression w/ error feedback + sharded DCCB gossip."""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_shim import given, settings, st

from repro.distributed import compression
from test_distributed import _run_with_devices


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 5000), st.integers(0, 2**31 - 1))
def test_compress_roundtrip_bounded_error(n, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (n,)) * 10
    c = compression.compress(x)
    y = compression.decompress(c, x.shape)
    # per-block max-scaled int8: error <= scale/2 = max|block|/254
    err = jnp.abs(y - x)
    assert float(err.max()) <= float(jnp.abs(x).max()) / 254 + 1e-6


def test_compression_ratio():
    r = compression.compressed_ratio((1024, 1024), jnp.float32)
    assert r < 0.27          # ~4x smaller than f32


def test_error_feedback_preserves_signal():
    """Sum of transported grads + final error == sum of true grads
    (error feedback never loses mass)."""
    key = jax.random.PRNGKey(0)
    params = {"w": jnp.zeros((257,))}       # non-multiple of block
    err = compression.init_error(params)
    total_true = jnp.zeros((257,))
    total_sent = jnp.zeros((257,))
    for i in range(5):
        g = {"w": jax.random.normal(jax.random.fold_in(key, i), (257,))}
        g_hat, err = compression.ef_step(g, err)
        total_true += g["w"]
        total_sent += g_hat["w"]
    np.testing.assert_allclose(
        np.asarray(total_sent + err["w"]), np.asarray(total_true),
        rtol=1e-5, atol=1e-5)


def test_sharded_dccb_runs_and_ships_buffers():
    out = _run_with_devices("""
        import jax
        from repro.distributed import dccb_shard
        from repro.core.types import BanditHyper

        mesh = jax.make_mesh((8,), ("users",))
        hyper = BanditHyper(alpha=0.05, gamma=1.5, n_candidates=10)
        n, d, L = 64, 8, 8
        init_fn, epoch = dccb_shard.make_runtime(
            mesh, ("users",), n, d, L, hyper)
        state = init_fn(jax.random.PRNGKey(0))
        tot_r = tot_rand = 0.0
        for i in range(6):
            state, m = epoch(state, jax.random.PRNGKey(i + 1))
            tot_r += float(m.reward.sum()); tot_rand += float(m.rand_reward.sum())
        comm = float(state.comm_bytes)
        want = 6 * n * (L + 1) * (d * d + d) * 4
        assert comm == want, (comm, want)
        assert tot_r > tot_rand * 0.98, (tot_r, tot_rand)
        print("DCCB-SHARD-OK", tot_r / tot_rand)
    """)
    assert "DCCB-SHARD-OK" in out
