"""Cluster-pruned retrieval: item-side CLUB clustering + exact tile
pruning.

Covers the PR acceptance criteria:
  * the per-(user, tile) UCB bound DOMINATES every member item's score
    (the soundness that makes pruning exact);
  * pruned shortlist == unpruned shortlist BIT-EQUAL — reference and
    interpret-mode Pallas, on random, adversarial near-tie (repeated
    embeddings) and region-structured catalogs;
  * region recovery: the anchor CLUB graph + nearest-anchor assignment
    rediscovers the planted item regions, and the reference/pallas graph
    engines build the identical clustering;
  * churn safety: a `publish` the cluster table has not seen makes the
    serving transaction FALL BACK to the unpruned stream (same items,
    ``pruned_active == 0``), and `refresh_clusters` re-arms it; sustained
    churn keeps the layout a permutation with exact live accounting;
  * single-host vs 8-device item-sharded pruned serving bit-identical
    (subprocess mesh, the ``tests/test_retrieval.py`` pattern);
  * `ItemStats` feedback fold: duplicate-safe scatter, padding dropped,
    reclaimed slots reset;
  * `Guarded` telemetry: the skip ratio lands in ``ema_tiles_skipped``
    and the recall probe (vs the unpruned oracle) stays 1.0.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import serve
from repro.core import catalog as catalog_mod
from repro.core import env, itemclub
from repro.core.backend import BackendConfig
from repro.core.types import BanditHyper
from repro.kernels.topk import ops as topk_ops
from repro.kernels.topk.ref import (BOUND_SLACK, tile_bounds, topk_ref,
                                    topk_ref_pruned)
from repro.train.checkpoint import CheckpointManager

from test_distributed import _run_with_devices

HYPER = BanditHyper(alpha=0.3, sigma=4, max_rounds=1, gamma=1.5,
                    n_candidates=10)


def _stats(key, n, d, scale=0.1):
    ks = jax.random.split(key, 3)
    w = jax.random.normal(ks[0], (n, d))
    A = scale * jax.random.normal(ks[1], (n, d, d))
    Minv = jnp.eye(d) + jnp.einsum("nab,ncb->nac", A, A)
    occ = jax.random.randint(ks[2], (n,), 0, 50)
    return w, Minv, occ


def _region_catalog(key, N, d, regions=4, noise=0.02):
    e, _ = env.make_catalog_env(key, n_users=16, d=d, n_clusters=regions,
                                n_items=N, n_candidates=10,
                                item_noise_scale=noise)
    return serve.make_catalog(env.catalog_embeddings(e)), e


# ---------------------------------------------------------------------------
# bound soundness + exact pruning
# ---------------------------------------------------------------------------


def test_tile_bounds_dominate_member_scores():
    """tb[u, t] >= score(u, i) for every live item i in tile t — with
    non-trivial Minv (anisotropic confidence) and mixed occupancies, so
    every term of the bound (estimate + radius + the min() of the two
    confidence majorants) is exercised."""
    key = jax.random.PRNGKey(0)
    n, d, N, tile = 12, 16, 1024, 128
    w, Minv, occ = _stats(key, n, d, scale=0.4)
    cat, _ = _region_catalog(jax.random.PRNGKey(1), N, d, noise=0.2)
    cl = itemclub.build_clusters(cat, tile_items=tile)
    tb = tile_bounds(w, Minv, occ, 0.3, cl.tile_mu, cl.tile_r, cl.tile_xn,
                     cl.tile_n)

    x = cl.emb_sorted
    est = w @ x.T
    quad = jnp.einsum("ua,uab,ib->ui", w * 0 + 1, Minv * 0 + jnp.eye(d), x)
    q = jnp.sqrt(jnp.maximum(
        jnp.einsum("ia,uab,ib->ui", x, Minv, x), 0.0))
    s = est + 0.3 * q * jnp.sqrt(jnp.log1p(occ.astype(jnp.float32)))[:, None]
    s = jnp.where(cl.live_sorted[None] > 0, s, -jnp.inf)
    per_tile_max = jnp.max(s.reshape(n, N // tile, tile), axis=2)
    assert np.all(np.asarray(tb) + 1e-6 >= np.asarray(per_tile_max))
    # and the slack is not doing the work: the margin is the real bound
    assert np.all(np.asarray(tb) - BOUND_SLACK + 1e-3
                  >= np.asarray(per_tile_max))


@pytest.mark.parametrize("catalog_kind", ["random", "ties", "regions"])
@pytest.mark.parametrize("engine", ["reference", "pallas"])
def test_pruned_equals_unpruned_bit_exact(catalog_kind, engine):
    """The acceptance criterion: pruned shortlist ids AND scores
    bit-equal to the unpruned stream — including under adversarial
    near-ties (the catalog is 64 embeddings repeated, so (score, id)
    tie-breaks decide every slot)."""
    key = jax.random.PRNGKey(7)
    n, d, N, tile, K = 24, 16, 2048, 256, 16
    if catalog_kind == "random":
        cat = serve.random_catalog(jax.random.PRNGKey(1), N, d)
    elif catalog_kind == "ties":
        base = jax.random.normal(jax.random.PRNGKey(2), (64, d))
        base /= jnp.linalg.norm(base, axis=-1, keepdims=True)
        cat = serve.make_catalog(jnp.tile(base, (N // 64, 1)))
    else:
        cat, _ = _region_catalog(jax.random.PRNGKey(3), N, d)
    # retired items in the mix: dead slots sort to the trailing tiles
    cat, _ = serve.retire_items(
        cat, jax.random.permutation(jax.random.PRNGKey(4), N)[:100])
    cat = serve.publish(cat)

    w, Minv, occ = _stats(key, n, d)
    cl = itemclub.build_clusters(cat, tile_items=tile, n_anchors=128)
    bank = cat.serving
    s0, i0 = topk_ref(w, Minv, occ, bank.emb, bank.live, 0.3, K)
    tb = tile_bounds(w, Minv, occ, 0.3, cl.tile_mu, cl.tile_r, cl.tile_xn,
                     cl.tile_n)
    if engine == "reference":
        s1, i1, sk, tot = topk_ref_pruned(
            w, Minv, occ, cl.emb_sorted, cl.live_sorted, cl.perm, 0.3, K,
            tb, row_block=4)
    else:
        s1, i1, sk, tot = topk_ops.topk_pruned(
            w, Minv, occ, cl.emb_sorted, cl.live_sorted, cl.perm, 0.3, K,
            tb, use_pallas=True, block_users=8, interpret=True)
    np.testing.assert_array_equal(np.asarray(s0), np.asarray(s1))
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
    assert 0 <= int(sk) <= int(tot)


def test_pruned_region_catalog_actually_skips():
    """On a well-separated region catalog with informative users the
    reference pruned path must skip a substantial share of tiles — the
    perf claim at test scale, not just exactness."""
    d, N, tile, K = 16, 4096, 256, 16
    cat, e = _region_catalog(jax.random.PRNGKey(5), N, d, regions=8,
                             noise=0.01)
    n = e.theta.shape[0]
    w = e.theta
    Minv = jnp.broadcast_to(jnp.eye(d), (n, d, d)).astype(jnp.float32)
    occ = jnp.full((n,), 50, jnp.int32)
    cl = itemclub.build_clusters(cat, tile_items=tile)
    tb = tile_bounds(w, Minv, occ, 0.3, cl.tile_mu, cl.tile_r, cl.tile_xn,
                     cl.tile_n)
    s1, i1, sk, tot = topk_ref_pruned(
        w, Minv, occ, cl.emb_sorted, cl.live_sorted, cl.perm, 0.3, K, tb,
        row_block=4)
    s0, i0 = topk_ref(w, Minv, occ, cat.serving.emb, cat.serving.live,
                      0.3, K)
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
    assert int(sk) / int(tot) > 0.3, (int(sk), int(tot))


# ---------------------------------------------------------------------------
# clustering structure
# ---------------------------------------------------------------------------


def test_build_clusters_recovers_planted_regions():
    """Items of the same planted region land in the same cluster, items
    of different regions in different clusters (low noise, so the CLUB
    threshold separates them cleanly), and the tile layout is coherent:
    every tile holds items of one region."""
    d, N = 16, 2048
    cat, e = _region_catalog(jax.random.PRNGKey(11), N, d, regions=4,
                             noise=0.01)
    cl = itemclub.build_clusters(cat, tile_items=128, n_anchors=128)
    assert int(cl.n_clusters) == 4
    labels = np.asarray(cl.labels)
    regions = np.asarray(e.item_region)
    # labels and regions agree up to relabeling: one label per region
    for r in range(4):
        assert len(set(labels[regions == r])) == 1
    assert len({labels[regions == r][0] for r in range(4)}) == 4


def test_build_clusters_reference_pallas_identical():
    """The anchor CLUB graph through the reference vs interpret-mode
    Pallas graph engines yields the identical clustering — labels, perm,
    tile tables, everything (the stage-2 parity guarantee carried to the
    item side)."""
    cat, _ = _region_catalog(jax.random.PRNGKey(13), 1024, 16, noise=0.05)
    stats = itemclub.init_stats(1024)
    # non-trivial learned rewards so the rhat feature participates
    stats = itemclub.observe_served(
        stats, jnp.arange(512, dtype=jnp.int32),
        jax.random.uniform(jax.random.PRNGKey(1), (512,)))
    a = itemclub.build_clusters(cat, stats, tile_items=128,
                                kind="reference")
    b = itemclub.build_clusters(cat, stats, tile_items=128, kind="pallas",
                                interpret=True)
    for fa, fb in zip(a, b):
        np.testing.assert_array_equal(np.asarray(fa), np.asarray(fb))


def test_reward_statistics_split_geometric_twins():
    """Two geometrically identical item groups with divergent LEARNED
    rewards separate into different clusters — the item-side CLUB
    insight: clustering is on (embedding, rhat), not embedding alone."""
    d, N = 8, 256
    base = jnp.ones((1, d)) / jnp.sqrt(d)
    emb = jnp.tile(base, (N, 1))
    cat = serve.make_catalog(emb)
    stats = itemclub.init_stats(N)
    ids = jnp.arange(N, dtype=jnp.int32)
    for _ in range(50):   # occ high enough that cb_width tightens
        stats = itemclub.observe_served(
            stats, ids, jnp.where(ids < N // 2, 1.0, 0.0))
    # n_anchors = N: every item is an anchor (the exact CLUB graph) —
    # the bounded-anchor default would take the FIRST live slots, which
    # here are all high-reward twins, leaving the low-reward group
    # without a representative
    cl = itemclub.build_clusters(cat, stats, tile_items=32, n_anchors=N,
                                 beta=1.0)
    labels = np.asarray(cl.labels)
    assert len(set(labels[: N // 2])) == 1
    assert len(set(labels[N // 2:])) == 1
    assert labels[0] != labels[-1]
    # and without the learned statistics they collapse to one cluster
    cl0 = itemclub.build_clusters(cat, tile_items=32, n_anchors=N)
    assert len(set(np.asarray(cl0.labels))) == 1


# ---------------------------------------------------------------------------
# feedback statistics
# ---------------------------------------------------------------------------


def test_observe_served_duplicates_padding_and_reset():
    st = itemclub.init_stats(8)
    st = itemclub.observe_served(st, jnp.array([3, 3, -1, 9, 7]),
                                 jnp.array([1.0, 0.5, 9.0, 9.0, 2.0]))
    assert int(st.occ[3]) == 2 and abs(float(st.rsum[3]) - 1.5) < 1e-6
    assert int(st.occ[7]) == 1 and float(st.rsum[7]) == 2.0
    assert int(jnp.sum(st.occ)) == 3          # padding + OOB dropped
    # valid mask quarantines (e.g. stale-feedback) entries
    st2 = itemclub.observe_served(st, jnp.array([7, 7]),
                                  jnp.array([1.0, 1.0]),
                                  valid=jnp.array([True, False]))
    assert int(st2.occ[7]) == 2

    # a reclaimed slot resets after the publish that re-seats it
    cat = serve.make_catalog(jnp.eye(8, 4), capacity=8)
    cat, _ = serve.retire_items(cat, jnp.array([3]))
    cat = serve.publish(cat)
    cat, slots, _ = serve.add_items(cat, jnp.ones((1, 4)))
    cat = serve.publish(cat)
    assert int(slots[0]) == 3                 # lowest dead slot reclaimed
    st3 = itemclub.reset_new_slots(st, cat)
    assert int(st3.occ[3]) == 0 and float(st3.rsum[3]) == 0.0
    assert int(st3.occ[7]) == int(st.occ[7])


# ---------------------------------------------------------------------------
# churn safety
# ---------------------------------------------------------------------------


def _mk_session(n_users, d):
    return serve.OnlineBandit.create(n_users, d, HYPER, policy="distclub")


def _reward_fn_for(theta):
    def reward_fn(key, uids, ctx, choice):
        return env.step_rewards(key, theta[uids], ctx, choice)
    return reward_fn


def test_stale_cluster_table_falls_back_to_unpruned():
    """Mass-retire + publish WITHOUT rebuilding: the pruned transaction
    must serve the identical items as the unpruned one off the NEW
    catalog (``pruned_active == 0``), never prune with stale bounds;
    a refresh re-arms pruning."""
    n_users, d, N = 32, 8, 512
    cat, e = _region_catalog(jax.random.PRNGKey(21), N, d)
    reward_fn = _reward_fn_for(e.theta[:n_users])
    cl = serve.build_clusters(cat, tile_items=64)
    sa, sb = _mk_session(n_users, d), _mk_session(n_users, d)
    uids = jnp.arange(32, dtype=jnp.int32)

    k = jax.random.PRNGKey(0)
    sa, ia, _ = serve.step_catalog(sa, k, uids, cat, reward_fn, k_short=16)
    sb, ib, _, rm = serve.step_catalog(sb, k, uids, cat, reward_fn,
                                       k_short=16, clusters=cl)
    np.testing.assert_array_equal(np.asarray(ia), np.asarray(ib))
    assert int(rm.pruned_active) == 1

    # mass retire half the catalog + fresh arrivals, publish — the swap
    # the cluster table has never seen
    cat, _ = serve.retire_items(cat, jnp.arange(0, N, 2, dtype=jnp.int32))
    fresh, _ = env.sample_churn_items(e, jax.random.PRNGKey(5), 64)
    cat, _, _ = serve.add_items(cat, fresh)
    cat = serve.publish(cat)
    assert int(cl.epoch) != int(cat.epoch)

    k = jax.random.PRNGKey(1)
    sa, ia, _ = serve.step_catalog(sa, k, uids, cat, reward_fn, k_short=16)
    sb, ib, _, rm = serve.step_catalog(sb, k, uids, cat, reward_fn,
                                       k_short=16, clusters=cl)
    np.testing.assert_array_equal(np.asarray(ia), np.asarray(ib))
    assert int(rm.pruned_active) == 0 and int(rm.tiles_total) == 0

    cl = serve.refresh_clusters(cl, cat)
    k = jax.random.PRNGKey(2)
    sa, ia, _ = serve.step_catalog(sa, k, uids, cat, reward_fn, k_short=16)
    sb, ib, _, rm = serve.step_catalog(sb, k, uids, cat, reward_fn,
                                       k_short=16, clusters=cl)
    np.testing.assert_array_equal(np.asarray(ia), np.asarray(ib))
    assert int(rm.pruned_active) == 1


def test_refresh_under_sustained_churn_stays_exact():
    """tests/test_churn.py-style sustained churn: every epoch retires a
    random slice, lands fresh arrivals, publishes, rebuilds — the layout
    must stay a true permutation with exact live accounting, and the
    pruned serving path must stay bit-equal to unpruned throughout."""
    n_users, d, N = 16, 8, 512
    cat, e = _region_catalog(jax.random.PRNGKey(31), N, d)
    reward_fn = _reward_fn_for(e.theta[:n_users])
    stats = serve.init_stats(N)
    cl = serve.build_clusters(cat, stats, tile_items=64)
    sa, sb = _mk_session(n_users, d), _mk_session(n_users, d)

    for t in range(6):
        k = jax.random.PRNGKey(100 + t)
        uids = jax.random.randint(jax.random.PRNGKey(200 + t), (16,), 0,
                                  n_users)
        sa, ia, ma = serve.step_catalog(sa, k, uids, cat, reward_fn,
                                        k_short=16)
        sb, ib, mb, rm = serve.step_catalog(sb, k, uids, cat, reward_fn,
                                            k_short=16, clusters=cl)
        np.testing.assert_array_equal(np.asarray(ia), np.asarray(ib))
        assert float(ma.reward) == float(mb.reward)
        stats = serve.observe_served(
            stats, ia, jnp.ones((ia.shape[0],), jnp.float32))

        live_ids = np.flatnonzero(np.asarray(cat.serving.live) > 0)
        kill = jax.random.choice(jax.random.PRNGKey(300 + t),
                                 jnp.asarray(live_ids), (40,),
                                 replace=False)
        cat, _ = serve.retire_items(cat, kill)
        fresh, _ = env.sample_churn_items(e, jax.random.PRNGKey(400 + t),
                                          30)
        cat, _, _ = serve.add_items(cat, fresh)
        cat = serve.publish(cat)
        stats = serve.reset_new_slots(stats, cat)
        cl = serve.refresh_clusters(cl, cat, stats)
        assert int(cl.epoch) == int(cat.epoch)
        perm = np.sort(np.asarray(cl.perm))
        np.testing.assert_array_equal(perm, np.arange(N))
        assert float(jnp.sum(cl.live_sorted)) == float(
            jnp.sum(cat.serving.live))
        assert int(jnp.sum(cl.tile_n)) == int(jnp.sum(cat.serving.live))


# ---------------------------------------------------------------------------
# sharded parity
# ---------------------------------------------------------------------------


def test_pruned_8dev_item_sharded_matches_single_host():
    """Pruned serving on an 8-device item-sharded mesh == single-host
    pruned == single-host unpruned, bit for bit: the replicated cluster
    tables slice into per-shard position ranges whose shortlists merge
    by (score, id) value to the identical global shortlist."""
    out = _run_with_devices("""
        import numpy as np
        import jax, jax.numpy as jnp
        from repro import serve
        from repro.core import catalog as catalog_mod, env
        from repro.core.types import BanditHyper
        from repro.distributed.distclub_shard import named_shardings

        N_USERS, D, N_ITEMS, KS = 64, 8, 1024, 16
        hyper = BanditHyper(alpha=0.3, sigma=4, max_rounds=1, gamma=1.5,
                            n_candidates=10)
        e, _ = env.make_catalog_env(jax.random.PRNGKey(0), N_USERS, D, 4,
                                    N_ITEMS, n_candidates=10,
                                    item_noise_scale=0.02)
        cat = serve.make_catalog(env.catalog_embeddings(e))
        cat, _ = serve.retire_items(cat, jnp.array([3, 17, 800], jnp.int32))
        cat = serve.publish(cat)
        # tile_items=16: 1024 / (16 * 8 shards) = 8 whole tiles per shard
        clusters = serve.build_clusters(cat, tile_items=16)
        theta = e.theta

        def reward_fn(key, uids, ctx, choice):
            return env.step_rewards(key, theta[uids], ctx, choice)

        mesh = jax.make_mesh((8,), ("users",))
        s1 = serve.OnlineBandit.create(N_USERS, D, hyper, policy="distclub")
        s8 = serve.OnlineBandit.sharded(mesh, N_USERS, D, hyper,
                                        policy="distclub")
        su = serve.OnlineBandit.create(N_USERS, D, hyper, policy="distclub")
        cat8 = jax.device_put(
            cat, named_shardings(mesh, catalog_mod.specs(("users",))))
        for i in range(4):
            k = jax.random.PRNGKey(i)
            uids = jax.random.permutation(
                jax.random.PRNGKey(100 + i), N_USERS).astype(jnp.int32)
            s1, i1, m1, r1 = serve.step_catalog(
                s1, k, uids, cat, reward_fn, k_short=KS, clusters=clusters)
            s8, i8, m8, r8 = serve.step_catalog(
                s8, k, uids, cat8, reward_fn, k_short=KS, clusters=clusters)
            su, iu, mu = serve.step_catalog(su, k, uids, cat, reward_fn,
                                            k_short=KS)
            np.testing.assert_array_equal(np.asarray(i1), np.asarray(i8))
            np.testing.assert_array_equal(np.asarray(i1), np.asarray(iu))
            assert float(m1.reward) == float(m8.reward) == float(mu.reward)
            assert int(r1.pruned_active) == int(r8.pruned_active) == 1
            assert int(r8.tiles_total) == int(r1.tiles_total)
        np.testing.assert_array_equal(np.asarray(s1.state.occ),
                                      np.asarray(s8.state.occ))
        np.testing.assert_allclose(np.asarray(s1.state.Minv),
                                   np.asarray(s8.state.Minv), atol=1e-6)
        print("PRUNED-SHARD-PARITY-OK", int(r1.tiles_skipped))
    """)
    assert "PRUNED-SHARD-PARITY-OK" in out


# ---------------------------------------------------------------------------
# guardrail telemetry
# ---------------------------------------------------------------------------


def test_guarded_pruned_telemetry_and_recall(tmp_path):
    n_users, d, N = 32, 8, 512
    cat, e = _region_catalog(jax.random.PRNGKey(41), N, d, noise=0.01)
    reward_fn = _reward_fn_for(e.theta[:n_users])
    cl = serve.build_clusters(cat, tile_items=64)
    sess = _mk_session(n_users, d)
    g = serve.Guarded.create(
        sess, CheckpointManager(tmp_path / "ck"),
        serve.GuardrailConfig(recall_floor=0.99, warmup=0), catalog=cat)
    uids = jnp.arange(32, dtype=jnp.int32)
    for t in range(3):
        g, items, m, rm = g.step_catalog(
            jax.random.PRNGKey(t), uids, reward_fn=reward_fn, k_short=16,
            probe_recall=True, clusters=cl)
    assert g.gs.ema_tiles_skipped is not None
    assert g.gs.ema_tiles_skipped == pytest.approx(rm.skip_ratio(),
                                                   abs=0.5)
    # pruning is exact, so the unpruned-oracle recall probe saturates
    assert g.gs.ema_recall == pytest.approx(1.0)
    assert not g.tripped
