"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs pure-jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

jax.config.update("jax_default_matmul_precision", "highest")

from repro.kernels.cross import ops as cross_ops
from repro.kernels.cross.ref import cross_layer_ref
from repro.kernels.embag import ops as embag_ops
from repro.kernels.embag.ref import embedding_bag_ref
from repro.kernels.flash import ops as flash_ops
from repro.kernels.flash.ref import mha_ref
from repro.kernels.rank1 import ops as rank1_ops
from repro.kernels.rank1.ref import rank1_update_ref
from repro.kernels.ucb import ops as ucb_ops
from repro.kernels.ucb.ref import ucb_scores_ref


def spd(key, n, d, scale=0.1):
    A = jax.random.normal(key, (n, d, d)) * scale
    return jnp.eye(d) + jnp.einsum("nij,nkj->nik", A, A)


@pytest.mark.parametrize("n,K,d", [(8, 16, 8), (37, 20, 25), (64, 7, 19), (128, 128, 32)])
@pytest.mark.parametrize("dtype", [jnp.float32])
def test_ucb_kernel(n, K, d, dtype):
    key = jax.random.PRNGKey(n * 1000 + K)
    ks = jax.random.split(key, 4)
    w = jax.random.normal(ks[0], (n, d), dtype)
    Minv = spd(ks[1], n, d).astype(dtype)
    ctx = jax.random.normal(ks[2], (n, K, d), dtype)
    occ = jax.random.randint(ks[3], (n,), 0, 1000)
    ref = ucb_scores_ref(w, Minv, ctx, occ, 0.3)
    out = ucb_ops.ucb_scores(w, Minv, ctx, occ, 0.3, use_pallas=True,
                             interpret=True)
    np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("n,d", [(8, 8), (37, 25), (100, 19), (256, 32)])
def test_rank1_kernel(n, d):
    key = jax.random.PRNGKey(n + d)
    ks = jax.random.split(key, 5)
    M = spd(ks[0], n, d)
    Minv = jnp.linalg.inv(M)
    b = jax.random.normal(ks[1], (n, d))
    x = jax.random.normal(ks[2], (n, d))
    r = jax.random.uniform(ks[3], (n,))
    mask = jax.random.bernoulli(ks[4], 0.7, (n,))
    refs = rank1_update_ref(M, Minv, b, x, r, mask)
    outs = rank1_ops.rank1_update(M, Minv, b, x, r, mask, use_pallas=True,
                                  interpret=True)
    for out, ref in zip(outs, refs):
        np.testing.assert_allclose(out, ref, rtol=3e-5, atol=3e-5)


def test_rank1_sherman_morrison_is_exact_inverse():
    key = jax.random.PRNGKey(7)
    n, d = 16, 12
    M = spd(key, n, d)
    Minv = jnp.linalg.inv(M)
    x = jax.random.normal(key, (n, d))
    r = jnp.ones((n,))
    mask = jnp.ones((n,), bool)
    M2, Minv2, _ = rank1_ops.rank1_update(
        M, Minv, jnp.zeros((n, d)), x, r, mask, use_pallas=True, interpret=True
    )
    np.testing.assert_allclose(
        jnp.einsum("nij,njk->nik", M2, Minv2),
        jnp.broadcast_to(jnp.eye(d), (n, d, d)), atol=1e-3,
    )


@pytest.mark.parametrize("V,D,B,L", [(50, 8, 4, 3), (1000, 64, 16, 10), (128, 128, 8, 1)])
def test_embag_kernel(V, D, B, L):
    key = jax.random.PRNGKey(V + B)
    ks = jax.random.split(key, 3)
    table = jax.random.normal(ks[0], (V, D))
    idx = jax.random.randint(ks[1], (B, L), 0, V)
    wt = jax.random.uniform(ks[2], (B, L))
    ref = embedding_bag_ref(table, idx, wt)
    out = embag_ops.embedding_bag(table, idx, wt, use_pallas=True,
                                  interpret=True)
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)


def test_embag_pad_slots_are_zero_weight():
    table = jnp.arange(20, dtype=jnp.float32).reshape(10, 2)
    idx = jnp.array([[1, 2, 0]])
    wt = jnp.array([[1.0, 1.0, 0.0]])   # pad slot points at row 0, weight 0
    out = embag_ops.embedding_bag(table, idx, wt, use_pallas=True,
                                  interpret=True)
    np.testing.assert_allclose(out[0], table[1] + table[2])


@pytest.mark.parametrize("B,d", [(16, 16), (37, 24), (100, 64)])
def test_cross_kernel(B, d):
    key = jax.random.PRNGKey(B + d)
    ks = jax.random.split(key, 4)
    x0 = jax.random.normal(ks[0], (B, d))
    xl = jax.random.normal(ks[1], (B, d))
    W = jax.random.normal(ks[2], (d, d)) / jnp.sqrt(d)
    bias = jax.random.normal(ks[3], (d,))
    np.testing.assert_allclose(
        cross_ops.cross_layer(x0, xl, W, bias, use_pallas=True, interpret=True),
        cross_layer_ref(x0, xl, W, bias), rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("B,Hq,Hkv,Sq,Skv,Dh,causal,off", [
    (1, 2, 2, 128, 128, 64, True, 0),      # MHA causal
    (2, 4, 2, 256, 256, 64, True, 0),      # GQA causal
    (1, 8, 1, 128, 128, 32, False, 0),     # MQA bidirectional
    (2, 4, 4, 64, 256, 64, True, 192),     # chunked decode tail
])
def test_flash_kernel(B, Hq, Hkv, Sq, Skv, Dh, causal, off):
    key = jax.random.PRNGKey(Sq + Skv)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, Hq, Sq, Dh))
    k = jax.random.normal(ks[1], (B, Hkv, Skv, Dh))
    v = jax.random.normal(ks[2], (B, Hkv, Skv, Dh))
    out = flash_ops.attention(q, k, v, causal=causal, q_offset=off,
                              use_pallas=True, block_q=64, block_k=64,
                              interpret=True)
    ref = mha_ref(q, k, v, causal=causal, q_offset=off)
    np.testing.assert_allclose(out, ref, rtol=2e-3, atol=2e-3)


def test_flash_bf16():
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 3)
    B, H, S, Dh = 1, 2, 128, 64
    q = jax.random.normal(ks[0], (B, H, S, Dh), jnp.bfloat16)
    k = jax.random.normal(ks[1], (B, H, S, Dh), jnp.bfloat16)
    v = jax.random.normal(ks[2], (B, H, S, Dh), jnp.bfloat16)
    out = flash_ops.attention(q, k, v, causal=True, use_pallas=True,
                              block_q=64, block_k=64, interpret=True)
    ref = mha_ref(q.astype(jnp.float32), k.astype(jnp.float32),
                  v.astype(jnp.float32), causal=True)
    np.testing.assert_allclose(out.astype(jnp.float32), ref, rtol=5e-2,
                               atol=5e-2)
