"""Train a ~small qwen3-family LM for a few hundred steps with
checkpoint/resume — the end-to-end training driver exercised on CPU.

    PYTHONPATH=src python examples/train_lm.py
"""
import subprocess
import sys

subprocess.run(
    [sys.executable, "-m", "repro.launch.train", "--arch", "qwen3-4b",
     "--reduce", "--steps", "30", "--batch", "4", "--seq", "64",
     "--ckpt-every", "10", "--log-every", "5"],
    check=True,
)
print("\n-- simulating failure + resume (same command continues) --")
subprocess.run(
    [sys.executable, "-m", "repro.launch.train", "--arch", "qwen3-4b",
     "--reduce", "--steps", "40", "--batch", "4", "--seq", "64",
     "--ckpt-every", "10", "--log-every", "5"],
    check=True,
)
