"""A 3-arm online experiment with one arm breaching and being disabled.

Three policies (distclub / dccb / linucb) serve ONE live request stream
behind sticky uid-hash traffic splitting, a Thompson-sampling
meta-selector shifts traffic toward the winner at epoch boundaries, and
per-arm guardrails watch every arm.  Mid-run the linucb arm's feedback
pipeline starts sign-flipping rewards (the targeted poisoning fault) —
its CTR monitor trips, the arm is AUTO-DISABLED: state rolled back to
its last healthy snapshot, its traffic re-routed to the survivors (who
keep every user they already had — the sticky hash never changes), and
the experiment keeps serving.

    PYTHONPATH=src python examples/ab_experiment.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro import serve
from repro.core import env as bandit_env
from repro.core.types import BanditHyper
from repro.serve import experiments, faults, guardrails

N_USERS, D, K, BATCH = 128, 8, 10, 32
ROUNDS, POISON_AFTER = 60, 20

# 1. a planted world and three arm sessions — one per policy, each with
#    its own state and pending ring
env, _ = bandit_env.make_synthetic_env(
    jax.random.PRNGKey(0), N_USERS, D, n_clusters=8, n_candidates=K)


def make_arm(policy):
    hyper = BanditHyper(alpha=0.05, gamma=2.4, n_candidates=K)
    return serve.OnlineBandit.create(
        N_USERS, D, hyper, policy=policy, refresh_every=N_USERS * 4,
        pending_capacity=512, pending_ttl=16)


# 2. the experiment: sticky split + TS meta-selector + per-arm guardrails
exp = experiments.create(
    [make_arm("distclub"), make_arm("dccb"), make_arm("linucb")],
    names=("distclub", "dccb", "linucb"), salt=7,
    selector=experiments.make_selector(3, epoch_rounds=15, floor=0.05),
    guard_cfg=guardrails.GuardrailConfig(ctr_floor=0.25, warmup=2 * BATCH,
                                         ema=0.7, cooldown=2),
    snapshot_every=4)

# 3. one seeded request stream for all arms (the same keyed traffic the
#    fault harness uses), with linucb's rewards sign-flipped after round
#    POISON_AFTER — the targeted poisoning fault
stream = faults.TrafficStream(3, BATCH, N_USERS, K=K, d=D)
A = exp.n_arms
for i in range(ROUNDS):
    users, ctx, kr, kf = stream.slate_batch(i)
    exp, choices, ids = experiments.recommend(exp, users, ctx)
    realized, expected, best, rand = bandit_env.step_rewards(
        kr, env.theta[users], ctx, choices)
    arm_of = np.where(np.asarray(ids) >= 0, np.asarray(ids) % A, -1)
    delivered = np.asarray(realized, np.float32)
    if i >= POISON_AFTER:                       # poison ONLY linucb's arm
        delivered = np.where(arm_of == 2, -delivered, delivered)
    exp = experiments.record_feedback(exp, np.asarray(users), arm_of,
                                      np.asarray(realized, np.float32),
                                      expected=np.asarray(expected),
                                      best=np.asarray(best),
                                      rand=np.asarray(rand),
                                      learner_rewards=delivered)
    exp = experiments.observe_delayed(exp, ids, jnp.asarray(delivered),
                                      key=kf)

rep = experiments.report(exp, rounds=ROUNDS)

# 4. what happened
print(f"{ROUNDS} rounds x {BATCH} requests, poison from round "
      f"{POISON_AFTER} on the linucb arm\n")
for i, name in enumerate(rep.names):
    n = max(1, rep.interactions[i])
    tag = "" if rep.enabled[i] else "   <- DISABLED"
    print(f"  {name:9s} reward/decision {rep.reward[i] / n:.3f}  "
          f"decisions {rep.interactions[i]:5d}  "
          f"final share {rep.fractions[i]:.2f}{tag}")
print(f"\n  leader: {rep.leader} (z = {rep.z_leading_pair:+.2f} vs "
      f"{rep.runner_up})")
print("  traffic shares over time:")
for step, fr in rep.shares:
    print(f"    round {step:3d}: "
          + "  ".join(f"{nm}={f:.2f}" for nm, f in zip(rep.names, fr)))
print("  guardrail events:")
for e in rep.events:
    print(f"    {e}")

assert not exp.enabled[2], "the poisoned arm should have been disabled"
# survivors kept every user they had before the disable (sticky fallback)
uids = jnp.arange(N_USERS)
arm_now = np.asarray(experiments.assign_arms(exp, uids))
assert not (arm_now == 2).any()
print("\nthe poisoned arm was disabled, its state rolled back, and its "
      "traffic re-routed to the surviving arms — experiment still live.")
