"""Quickstart: DistCLUB on a planted synthetic environment in ~30 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.core import distclub, env, env_ops
from repro.core.types import BanditHyper

# 1. a world with 128 users in 8 hidden preference clusters
environment, true_labels = env.make_synthetic_env(
    jax.random.PRNGKey(0), n_users=128, d=16, n_clusters=8, n_candidates=20)
ops = env_ops.synthetic_ops(environment)

# 2. paper hyper-parameters (Table 2), scaled round budgets
hyper = BanditHyper(alpha=0.03, beta=2.0, gamma=2.4, sigma=8, max_rounds=16,
                    n_candidates=20)

# 3. run 8 four-stage epochs (stage-1 personalized rounds -> stage-2
#    clustering -> stage-3 cluster-based rounds -> stage-4 rebalancing)
state, metrics, clusters_per_epoch = distclub.run(
    ops, jax.random.PRNGKey(1), hyper, n_epochs=8, d=16)

T = int(metrics.interactions.sum())
print(f"interactions processed : {T}")
print(f"cumulative reward      : {float(metrics.reward.sum()):.0f}")
print(f"random-policy reward   : {float(metrics.rand_reward.sum()):.0f}")
print(f"reward / random        : "
      f"{float(metrics.reward.sum()) / float(metrics.rand_reward.sum()):.3f}")
print(f"clusters discovered    : {clusters_per_epoch.tolist()}")
print(f"comm bytes (stage-2)   : {float(state.comm_bytes):.0f}")
