"""End-to-end online recommendation service: SASRec embeddings + DistCLUB.

The paper's deployment story with a real model in the loop: SASRec
supplies candidate item embeddings as bandit contexts, an `OnlineBandit`
session explores/exploits per user through one jit-compiled transaction
per batch (stage-2 re-clustering fires inside it on an interaction
budget), and `CheckpointManager` snapshots the service for fault
tolerance — demonstrated below by killing the session mid-run and
resuming from the latest checkpoint with bit-identical choices.

    PYTHONPATH=src python examples/serve_bandit.py
"""
import shutil

import jax
import jax.numpy as jnp
import numpy as np

from repro import serve
from repro.core import clustering
from repro.core import env as bandit_env
from repro.core.types import BanditHyper
from repro.models.recsys import seqrec
from repro.train.checkpoint import CheckpointManager

N_USERS, N_ITEMS, D, K = 256, 2048, 32, 20
BATCH = 128
CKPT_DIR = "/tmp/repro_bandit_service"

# --- the embedding model (would be trained offline; random here) -------------
cfg = seqrec.SeqRecConfig(n_items=N_ITEMS, embed_dim=D, n_blocks=2,
                          n_heads=2, seq_len=16)
model = seqrec.init_seqrec(jax.random.PRNGKey(0), cfg)

# --- hidden user preferences drive simulated clicks --------------------------
world, _ = bandit_env.make_synthetic_env(
    jax.random.PRNGKey(1), n_users=N_USERS, d=D, n_clusters=8,
    n_candidates=K)
theta = world.theta


def reward_fn(key, user_ids, contexts, choices):
    """User feedback: Bernoulli clicks in the hidden affinity."""
    return bandit_env.step_rewards(key, theta[user_ids], contexts, choices)


def request_batch(step):
    """One batch of requests: users + model-embedded candidate slates."""
    k_u, k_c = jax.random.split(jax.random.fold_in(jax.random.PRNGKey(2),
                                                   step))
    users = jax.random.permutation(k_u, N_USERS)[:BATCH]
    cand_ids = jax.random.randint(k_c, (BATCH, K), 0, N_ITEMS)
    contexts = serve.embed_candidates(model["item_embed"], cand_ids)
    return users, contexts


# --- the service --------------------------------------------------------------
hyper = BanditHyper(alpha=0.05, beta=2.0, gamma=2.4, n_candidates=K)
session = serve.OnlineBandit.create(N_USERS, D, hyper, policy="distclub",
                                    refresh_every=N_USERS * 4)
shutil.rmtree(CKPT_DIR, ignore_errors=True)   # clean slate for the demo,
ckpt = CheckpointManager(CKPT_DIR, keep=2)    # THEN create the manager once

total_reward = total_rand = 0.0
for step in range(120):
    users, contexts = request_batch(step)
    session, choices, m = serve.step(session, jax.random.PRNGKey(step),
                                     users, contexts, reward_fn)
    total_reward += float(m.reward)
    total_rand += float(m.rand_reward)
    if (step + 1) % 50 == 0:
        session.save(ckpt, step + 1)
        n_clu = int(clustering.num_clusters(session.state.labels))
        print(f"step {step + 1:3d}: reward/random = "
              f"{total_reward / total_rand:.3f}, clusters = {n_clu}, "
              f"checkpointed @ {ckpt.latest_step()}")

# --- kill the replica mid-run and resume from the latest checkpoint ----------
probe_users, probe_contexts = request_batch(120)
planned = serve.recommend(session, probe_users, probe_contexts)

del session                                    # the "crash"
session, resumed_at = serve.OnlineBandit.create(
    N_USERS, D, hyper, policy="distclub",
    refresh_every=N_USERS * 4).restore(ckpt)
print(f"\nreplica restarted from checkpoint @ step {resumed_at}")

# replay the traffic the checkpoint missed (steps 100..119; rewards were
# already tallied pre-crash, so only the state advances), then the
# restarted replica must plan the exact same slate as the dead one
for step in range(resumed_at, 120):
    users, contexts = request_batch(step)
    session, _, _ = serve.step(session, jax.random.PRNGKey(step),
                               users, contexts, reward_fn)
resumed = serve.recommend(session, probe_users, probe_contexts)
assert (np.asarray(planned) == np.asarray(resumed)).all()
print("restored replica reproduces the pre-crash choices bit-for-bit: OK")

for step in range(120, 200):
    users, contexts = request_batch(step)
    session, _, m = serve.step(session, jax.random.PRNGKey(step),
                               users, contexts, reward_fn)
    total_reward += float(m.reward)
    total_rand += float(m.rand_reward)

print(f"\nfinal reward vs random policy: {total_reward / total_rand:.3f}")
