"""End-to-end online recommendation service: SASRec embeddings + DistCLUB.

This is the paper's deployment story with a real model in the loop:
SASRec supplies candidate item embeddings as bandit contexts; DistCLUB
explores/exploits per user, discovers user clusters, and checkpoints the
whole service (model + bandit state) for fault tolerance.

    PYTHONPATH=src python examples/serve_bandit.py
"""
import shutil

import jax
import jax.numpy as jnp

from repro.core import env as bandit_env
from repro.core.types import BanditHyper
from repro.models.recsys import seqrec
from repro.serve import bandit_service
from repro.train.checkpoint import CheckpointManager

N_USERS, N_ITEMS, D, K = 256, 2048, 32, 20
BATCH = 128
key = jax.random.PRNGKey(0)

# --- the embedding model (would be trained offline; random here) -------------
cfg = seqrec.SeqRecConfig(n_items=N_ITEMS, embed_dim=D, n_blocks=2,
                          n_heads=2, seq_len=16)
model = seqrec.init_seqrec(key, cfg)

# --- hidden user preferences drive simulated clicks --------------------------
world, _ = bandit_env.make_synthetic_env(
    jax.random.PRNGKey(1), n_users=N_USERS, d=D, n_clusters=8,
    n_candidates=K)

# --- the service --------------------------------------------------------------
hyper = BanditHyper(alpha=0.05, beta=2.0, gamma=2.4, n_candidates=K)
svc = bandit_service.create(N_USERS, D, hyper)
ckpt = CheckpointManager("/tmp/repro_bandit_service", keep=2)
shutil.rmtree("/tmp/repro_bandit_service", ignore_errors=True)
ckpt = CheckpointManager("/tmp/repro_bandit_service", keep=2)

total_reward = total_rand = 0.0
for step in range(200):
    k_u, k_c, k_r, key = jax.random.split(key, 4)
    users = jax.random.permutation(k_u, N_USERS)[:BATCH]
    cand_ids = jax.random.randint(k_c, (BATCH, K), 0, N_ITEMS)

    # model -> contexts; bandit -> choice
    contexts = bandit_service.embed_candidates(model["item_embed"], cand_ids)
    choices = bandit_service.recommend(svc, users, contexts)

    # user feedback (Bernoulli in hidden affinity)
    realized, p_choice, best, rand = bandit_env.step_rewards(
        k_r, world.theta[users], contexts, choices)
    svc = bandit_service.observe(svc, users, contexts, choices, realized)
    svc = bandit_service.maybe_refresh(svc, every=N_USERS * 4)

    total_reward += float(realized.sum())
    total_rand += float(rand.sum())
    if (step + 1) % 50 == 0:
        ckpt.save(svc.state, step + 1)
        from repro.core import clustering
        n_clu = int(clustering.num_clusters(svc.state.graph.labels))
        print(f"step {step + 1:3d}: reward/random = "
              f"{total_reward / total_rand:.3f}, clusters = {n_clu}, "
              f"checkpointed @ {ckpt.latest_step()}")

print(f"\nfinal reward vs random policy: {total_reward / total_rand:.3f} "
      f"({total_reward:.0f} vs {total_rand:.0f})")
restored, step = ckpt.restore_latest(jax.eval_shape(lambda: svc.state))
print(f"service state restores from checkpoint at step {step}: OK")
