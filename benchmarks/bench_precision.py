"""Reduced-precision parity + HBM bench for the `Precision` backend API.

Two halves, both gated by check_regression:

  modeled   bytes moved for the banks the ``Precision`` policy actually
            shrinks — the per-user ``Minv`` d^2 state blocks the
            interaction engine streams every round, and the catalog
            embedding bank the top-K engine streams per user block.
            bf16 halves both (``*_hbm_cut_ratio`` = 2.0); int8 catalog
            tiles cut ``4d / (d + 4)`` (~3.6x at d=32 — the +4 is the
            per-slot f32 scale read).  Pure functions of shapes, so the
            gate catches any contract change, not runner noise.

  measured  per-decision choice parity vs the f32 oracle under seeded
            traffic.  The oracle session drives the ONE trajectory (all
            state updates are the oracle's own — exact-state metrics
            like occ stay exact, so flips come only from the score
            contraction, exactly the PR acceptance framing): each
            measured round, the oracle's full retrieval+choose decision
            (``recommend_catalog``, f32 state + f32 catalog) is compared
            against the counterfactual decision from the SAME state cast
            to the reduced dtypes against the quantized catalog.
            Compounding a live reduced-precision trajectory instead
            would measure butterfly divergence (one flipped near-tie
            reroutes every later reward draw), not quantization quality.

            The first ``WARMUP`` rounds are excluded: a cold LinUCB-form
            user scores every unit-norm item identically (w = 0, flat
            UCB width — any argmax is an equally good exploration pick),
            so ties sit at 1 ulp and ANY rounding flips them.  Flip rate
            only means something once margins are real; by round ~32
            every user has occupancy >= a handful and the measured rate
            settles near zero.  ``choice_flip_rate`` is gated <= 0.01
            (the acceptance ceiling; the run raises above it) and is
            deterministic given the seeds, so the checked-in baseline is
            exact — ANY drift means the quantization contract changed.

The ``pruned`` rows assert the cluster-pruned retrieval invariant
survives quantized tile summaries: per reduced precision, a short live
loop on a region-structured catalog, then the pruned
``recommend_catalog`` must serve the BIT-IDENTICAL items as the
unpruned run of the same state (conservative dequantized bounds — see
``core.itemclub``), so ``pruned_recall_ratio`` is exactly 1.0 or the
bench raises.

Wall-clock is deliberately not recorded: off-TPU the reduced banks
upcast in registers either way, so there is nothing honest to time —
the memory story is the modeled half, the accuracy story the measured
half.  Every row is mode-invariant (quick == full), so the quick-mode
baseline gates local full runs too.

Writes BENCH_precision.json at the repo root (tracked from this PR on).
"""
from __future__ import annotations

import dataclasses
import json
import pathlib

import jax
import jax.numpy as jnp

from repro import serve
from repro.core import env
from repro.core.backend import resolve_precision
from repro.core.types import BanditHyper

from .common import emit

ROOT = pathlib.Path(__file__).resolve().parents[1]

D, KSHORT = 32, 64
N_USERS, N_ITEMS, BATCH = 256, 4096, 64
TILE_ITEMS = 256
PARITY_PRECS = ("bf16", "int8")
FLIP_CEILING = 0.01
# identical in quick and full mode: every parity field is gated, and
# quick (the baseline / CI mode) must agree with a local full run
WARMUP, MEASURE = 32, 40
PRUNED_ROUNDS = 8


# ---- modeled HBM bytes for the precision-reduced banks ---------------------

_BYTES = {"f32": 4, "bf16": 2, "int8": 1}


def minv_bytes_per_user(d: int, state_dtype: str) -> int:
    """The per-user ``Minv`` d^2 block the interaction engine reads and
    scatters back every round (the dominant HBM-resident state; b/occ
    stay f32 and are O(d))."""
    return _BYTES[state_dtype] * d * d


def catalog_bytes_per_item(d: int, catalog_dtype: str) -> int:
    """Embedding-bank bytes the top-K stream moves per catalog slot;
    int8 adds the per-slot f32 scale read."""
    return _BYTES[catalog_dtype] * d + (4 if catalog_dtype == "int8" else 0)


def modeled_row(name: str) -> dict:
    prec = resolve_precision(name)
    mb, cb = (minv_bytes_per_user(D, prec.state_dtype),
              catalog_bytes_per_item(D, prec.catalog_dtype))
    rec = {
        "scenario": name, "d": D,
        "state_dtype": prec.state_dtype,
        "catalog_dtype": prec.catalog_dtype,
        "minv_bytes_per_user": mb,
        "catalog_bytes_per_item": cb,
        "interact_minv_hbm_cut_ratio": minv_bytes_per_user(D, "f32") / mb,
        "topk_catalog_hbm_cut_ratio": catalog_bytes_per_item(D, "f32") / cb,
    }
    emit(f"precision_model_{name}", 0.0,
         f"minv_cut={rec['interact_minv_hbm_cut_ratio']:.2f}x,"
         f"catalog_cut={rec['topk_catalog_hbm_cut_ratio']:.2f}x")
    return rec


# ---- measured per-decision parity vs the f32 oracle ------------------------

def _hyper():
    return BanditHyper(alpha=0.05, gamma=1.5, n_candidates=KSHORT)


def _session(precision):
    return serve.OnlineBandit.create(N_USERS, D, _hyper(),
                                     policy="distclub", refresh_every=0,
                                     backend="reference",
                                     precision=precision)


def _uids(t):
    return jax.random.permutation(jax.random.PRNGKey(100 + t),
                                  N_USERS)[:BATCH].astype(jnp.int32)


def parity_rows() -> list[dict]:
    # structureless random catalog: items are DISTINCT, so post-warmup
    # top-1 margins are real and a flip is a genuine ranking change (a
    # region-structured catalog is near-clones — flipping between two
    # copies of the same item tells nothing about quantization)
    k = jax.random.normal(jax.random.PRNGKey(7), (N_ITEMS, D))
    emb = k / jnp.linalg.norm(k, axis=-1, keepdims=True)
    theta = jax.random.normal(jax.random.PRNGKey(8), (N_USERS, D))
    theta = theta / jnp.linalg.norm(theta, axis=-1, keepdims=True)

    def reward_fn(key, u, ctx, choice):
        return env.step_rewards(key, theta[u], ctx, choice)

    oracle = _session(None)
    cat = serve.make_catalog(emb)
    probes = {p: (_session(p), serve.make_catalog(emb, precision=p))
              for p in PARITY_PRECS}
    flips = {p: 0 for p in PARITY_PRECS}
    total = 0
    for t in range(WARMUP + MEASURE):
        u = _uids(t)
        if t >= WARMUP:
            idf, _, _ = serve.recommend_catalog(oracle, u, cat,
                                                k_short=KSHORT)
            total += BATCH
            for p, (rs, catp) in probes.items():
                sdt = rs.policy.cfg.engine.precision.jnp_state
                st = oracle.state._replace(
                    Minv=oracle.state.Minv.astype(sdt),
                    uMcinv=oracle.state.uMcinv.astype(sdt))
                idr, _, _ = serve.recommend_catalog(
                    dataclasses.replace(rs, state=st), u, catp,
                    k_short=KSHORT)
                flips[p] += int(jnp.sum(idf != idr))
        oracle, _, _ = serve.step_catalog(oracle,
                                          jax.random.PRNGKey(1000 + t), u,
                                          cat, reward_fn, k_short=KSHORT)
    rows = []
    for p in PARITY_PRECS:
        rate = flips[p] / total
        if rate > FLIP_CEILING:
            raise RuntimeError(
                f"{p} choice_flip_rate {rate:.4f} > {FLIP_CEILING} "
                "acceptance ceiling vs the f32 oracle")
        rec = {
            "scenario": p, "n_users": N_USERS, "N_items": N_ITEMS,
            "batch": BATCH, "d": D, "K_short": KSHORT,
            "policy": "distclub",
            "warmup_rounds": WARMUP, "measured_rounds": MEASURE,
            "choices_compared": total, "choice_flips": flips[p],
            "choice_flip_rate": rate,
        }
        emit(f"precision_parity_{p}_N{N_ITEMS}_B{BATCH}", 0.0,
             f"flip_rate={rate:.4f} over {total} decisions")
        rows.append(rec)
    return rows


# ---- pruned retrieval exactness under quantized tile summaries -------------

def pruned_rows() -> list[dict]:
    e, _ = env.make_catalog_env(jax.random.PRNGKey(0), N_USERS, D, 8,
                                N_ITEMS, item_noise_scale=0.05)
    emb = env.catalog_embeddings(e)
    theta = e.theta

    def reward_fn(key, u, ctx, choice):
        return env.step_rewards(key, theta[u], ctx, choice)

    rows = []
    for p in PARITY_PRECS:
        sess = _session(p)
        cat = serve.make_catalog(emb, precision=p)
        for t in range(PRUNED_ROUNDS):
            sess, _, _ = serve.step_catalog(sess,
                                            jax.random.PRNGKey(2000 + t),
                                            _uids(t), cat, reward_fn,
                                            k_short=KSHORT)
        cl = serve.build_clusters(cat, tile_items=TILE_ITEMS,
                                  n_anchors=256)
        u = jnp.arange(BATCH, dtype=jnp.int32)
        ids_plain, _, _ = serve.recommend_catalog(sess, u, cat,
                                                  k_short=KSHORT)
        ids_pruned, _, _, rmet = serve.recommend_catalog(
            sess, u, cat, k_short=KSHORT, clusters=cl)
        recall = float(jnp.mean((ids_plain == ids_pruned)
                                .astype(jnp.float32)))
        skipped = float(rmet.skip_ratio())
        if recall != 1.0:
            raise RuntimeError(
                f"{p} pruned retrieval served different items than "
                f"unpruned (recall {recall:.4f}) — the conservative-"
                "bound invariant is broken for quantized summaries")
        rec = {
            "scenario": p, "N_items": N_ITEMS, "d": D,
            "K_short": KSHORT, "batch": BATCH,
            "tile_items": TILE_ITEMS,
            "pruned_recall_ratio": recall,
            "tiles_skipped_frac": skipped,
        }
        emit(f"precision_pruned_{p}_N{N_ITEMS}", 0.0,
             f"recall={recall:.2f},skipped={skipped:.2f}")
        rows.append(rec)
    return rows


def main(quick: bool = False):
    del quick                   # every row is mode-invariant (see WARMUP)
    modeled = [modeled_row(p) for p in ("bf16", "int8")]
    bf16 = next(r for r in modeled if r["scenario"] == "bf16")
    if (bf16["interact_minv_hbm_cut_ratio"] < 2.0
            or bf16["topk_catalog_hbm_cut_ratio"] < 2.0):
        raise RuntimeError("bf16 modeled HBM cut fell below the 2x "
                           "acceptance floor")
    parity = parity_rows()
    pruned = pruned_rows()
    payload = {
        "mode": "mode-invariant",
        "jax_backend": jax.default_backend(),
        "hbm_model_note": (
            "bytes per bank the Precision policy reduces: per-user Minv "
            "d^2 state blocks (interact) and catalog embedding slots "
            "(top-K stream, + per-slot f32 scale for int8); pure shape "
            "functions — see module docstring"),
        "parity_note": (
            "per-decision flips vs the f32 oracle's trajectory (state "
            "cast down, quantized catalog, same retrieval+choose), "
            "measured after the cold-start warmup; deterministic given "
            "the seeds, baseline is exact"),
        "modeled": modeled,
        "parity": parity,
        "pruned": pruned,
        # headline pinned scalars (like bench_retrieval's: the
        # acceptance-criteria numbers, addressable at a fixed path)
        "bf16_interact_hbm_cut_ratio": bf16["interact_minv_hbm_cut_ratio"],
        "bf16_topk_hbm_cut_ratio": bf16["topk_catalog_hbm_cut_ratio"],
        "max_choice_flip_rate": max(r["choice_flip_rate"] for r in parity),
        "min_pruned_recall_ratio": min(r["pruned_recall_ratio"]
                                       for r in pruned),
    }
    (ROOT / "BENCH_precision.json").write_text(json.dumps(payload, indent=1))
    return payload


if __name__ == "__main__":
    main()
