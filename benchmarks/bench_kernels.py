"""Kernel microbenchmarks: oracle path timings + interpret-mode validation.

Wall-clock here is the CPU oracle (the TPU kernel can't be timed in this
container); the derived column reports the analytic VMEM working set and
arithmetic intensity the BlockSpecs were sized for — the numbers that
matter for the TPU roofline placement of each kernel.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.cross.ref import cross_layer_ref
from repro.kernels.embag.ref import embedding_bag_ref
from repro.kernels.flash.ref import mha_ref
from repro.kernels.rank1.ref import rank1_update_ref
from repro.kernels.ucb.ref import ucb_scores_ref

from .common import emit, timed

KEY = jax.random.PRNGKey(0)


def bench_ucb():
    n, K, d = 4096, 128, 32
    ks = jax.random.split(KEY, 4)
    w = jax.random.normal(ks[0], (n, d))
    Minv = jnp.broadcast_to(jnp.eye(d), (n, d, d))
    ctx = jax.random.normal(ks[1], (n, K, d))
    occ = jnp.ones((n,), jnp.int32)
    f = jax.jit(lambda *a: ucb_scores_ref(*a, 0.3))
    f(w, Minv, ctx, occ)  # compile
    t, _ = timed(f, w, Minv, ctx, occ, repeats=3)
    vmem_kib = (256 * (K * d + d * d + d + K) * 4) / 1024
    flops = n * (2 * K * d + 2 * K * d * d)
    emit("kernel_ucb_fused", 1e6 * t,
         f"vmem_block={vmem_kib:.0f}KiB;ai={flops / (n * (K*d + d*d) * 4):.1f}")


def bench_rank1():
    n, d = 8192, 32
    ks = jax.random.split(KEY, 3)
    M = jnp.broadcast_to(jnp.eye(d), (n, d, d))
    b = jax.random.normal(ks[0], (n, d))
    x = jax.random.normal(ks[1], (n, d))
    r = jax.random.uniform(ks[2], (n,))
    mask = jnp.ones((n,), bool)
    f = jax.jit(rank1_update_ref)
    f(M, M, b, x, r, mask)
    t, _ = timed(f, M, M, b, x, r, mask, repeats=3)
    emit("kernel_rank1_sherman_morrison", 1e6 * t,
         "hbm_passes=1_vs_3_unfused")


def bench_embag():
    V, D, B, L = 100_000, 64, 8192, 32
    table = jax.random.normal(KEY, (V, D))
    idx = jax.random.randint(KEY, (B, L), 0, V)
    wt = jnp.ones((B, L))
    f = jax.jit(embedding_bag_ref)
    f(table, idx, wt)
    t, _ = timed(f, table, idx, wt, repeats=3)
    emit("kernel_embedding_bag", 1e6 * t,
         f"gather_bytes={B * L * D * 4 / 1e6:.0f}MB")


def bench_cross():
    B, d = 16384, 429
    ks = jax.random.split(KEY, 4)
    x0 = jax.random.normal(ks[0], (B, d))
    xl = jax.random.normal(ks[1], (B, d))
    W = jax.random.normal(ks[2], (d, d)) / jnp.sqrt(d)
    bias = jax.random.normal(ks[3], (d,))
    f = jax.jit(cross_layer_ref)
    f(x0, xl, W, bias)
    t, _ = timed(f, x0, xl, W, bias, repeats=3)
    emit("kernel_cross_dcnv2", 1e6 * t, "fused_epilogue=3_passes_to_1")


def bench_flash():
    B, H, S, Dh = 1, 8, 1024, 64
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, H, S, Dh))
    k = jax.random.normal(ks[1], (B, H, S, Dh))
    v = jax.random.normal(ks[2], (B, H, S, Dh))
    f = jax.jit(lambda q, k, v: mha_ref(q, k, v, causal=True))
    f(q, k, v)
    t, _ = timed(f, q, k, v, repeats=3)
    emit("kernel_flash_attention", 1e6 * t,
         f"score_matrix_avoided={B * H * S * S * 4 / 1e6:.0f}MB")


def main():
    bench_ucb()
    bench_rank1()
    bench_embag()
    bench_cross()
    bench_flash()


if __name__ == "__main__":
    main()
