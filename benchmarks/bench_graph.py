"""Stage-2 graph-engine benchmark: dense vs bit-packed adjacency.

Times the two stage-2 graph sweeps — the CLUB edge-prune and one
connected-components hop — at n in {1k, 4k, 16k, 64k}, and reports the
modeled HBM bytes of a full stage-2 refresh (prune + ceil(log2 n)+1
pointer-doubling hops) for both representations.

HBM model (op-level, matching bench_interact's accounting style —
"each XLA op streams its operands"; elementwise chains assumed fused):

  dense prune   8 n^2   [n, n] f32 distance matrix write + read
              + 2 n^2   bool adjacency read + write
              + 8 n d   user vectors
  dense hop     n^2     bool adjacency read
              + 8 n^2   [n, n] i32 neighbour-label intermediate w + r
              + 12 n    label read / pointer-double gather / write
  packed prune  2 n^2/8 packed adjacency read + write — the distance
                        tile lives and dies in VMEM
              + 4 n d (n/Bi + 1)  v_j tile re-streamed once per row block
  packed hop    n^2/8   packed adjacency read
              + 4 n (n/Bi)        column labels per row block
              + 12 n

The dense graph is additionally 32x larger *resident*: n^2 bool vs
n^2/8 packed bytes — at n=65536 the dense path needs a 4.3 GB adjacency
plus a 17 GB f32 distance matrix, so it is skipped above DENSE_N_CAP and
recorded as such; the packed path must (and does) complete on one CPU
host.  Wall-clock off-TPU runs the blocked reference engine — the same
row-tiled schedule the Pallas kernels execute per-grid-step on TPU.

Writes BENCH_graph.json at the repo root (tracked from PR 2 onward).
"""
from __future__ import annotations

import json
import math
import pathlib

import jax
import jax.numpy as jnp

from repro.core import backend as backend_mod
from repro.core import clustering

from .common import emit, timed

ROOT = pathlib.Path(__file__).resolve().parents[1]
KEY = jax.random.PRNGKey(0)

NS = [1024, 4096, 16384, 65536]
D = 16
BLOCK_I = 256
# dense needs ~n^2 * 9 transient bytes (adj + i32/f32 [n,n] intermediates):
# ~2.4 GB at 16384, ~39 GB at 65536 — cap it where the packed path keeps going.
DENSE_N_CAP = 16384
GAMMA = 0.9


# ---- analytic HBM model (bytes per stage-2 refresh) -------------------------

def cc_hops(n: int) -> int:
    """Static bound on pointer-doubling hops to convergence."""
    return max(1, math.ceil(math.log2(max(n, 2))) + 1)


def hbm_bytes_dense(n: int, d: int) -> int:
    prune = 8 * n * n + 2 * n * n + 8 * n * d
    hop = n * n + 8 * n * n + 12 * n
    return prune + cc_hops(n) * hop


def hbm_bytes_packed(n: int, d: int, block_i: int = BLOCK_I) -> int:
    row_blocks = -(-n // block_i)
    prune = 2 * (n * n // 8) + 4 * n * d * (row_blocks + 1)
    hop = n * n // 8 + 4 * n * row_blocks + 12 * n
    return prune + cc_hops(n) * hop


# ---- timed sweeps -----------------------------------------------------------

def _inputs(n, d):
    ks = jax.random.split(KEY, 3)
    v = jax.random.normal(ks[0], (n, d)) * 0.1
    occ = jax.random.randint(ks[1], (n,), 1, 200)
    labels = jnp.arange(n, dtype=jnp.int32)
    return v, occ, labels


def _dense_hop(adj, labels):
    """One dense min-label hop + pointer doubling (the seed CC body)."""
    n = adj.shape[0]
    neigh = jnp.where(adj, labels[None, :], jnp.int32(n))
    l1 = jnp.minimum(labels, jnp.min(neigh, axis=1))
    return jnp.minimum(l1, l1[l1])


def bench_dense(n, d, repeats):
    v, occ, labels = _inputs(n, d)
    adj = clustering.dense_adj(n)
    f_prune = jax.jit(lambda a, v, o: clustering.prune_edges(a, v, o, GAMMA))
    f_hop = jax.jit(_dense_hop)
    pruned = f_prune(adj, v, occ)                 # compile
    f_hop(pruned, labels)
    t_prune, _ = timed(f_prune, adj, v, occ, repeats=repeats)
    t_hop, _ = timed(f_hop, pruned, labels, repeats=repeats)
    return {"skipped": False, "prune_us": 1e6 * t_prune,
            "cc_hop_us": 1e6 * t_hop}


def _packed_hop(gb, adj, labels):
    """One packed min-label hop + pointer doubling."""
    l1 = gb.cc_hop(adj, labels, labels)
    return jnp.minimum(l1, l1[l1])


def bench_packed(n, d, repeats):
    v, occ, labels = _inputs(n, d)
    gb = backend_mod.BackendConfig.create().graph(n, block_i=BLOCK_I)
    adj = gb.init_adj()
    f_prune = jax.jit(lambda a, v, o: gb.prune(a, v, o, GAMMA))
    f_hop = jax.jit(lambda a, l: _packed_hop(gb, a, l))
    pruned = f_prune(adj, v, occ)                 # compile
    f_hop(pruned, labels)
    t_prune, _ = timed(f_prune, adj, v, occ, repeats=repeats)
    t_hop, _ = timed(f_hop, pruned, labels, repeats=repeats)
    rec = {"backend": gb.kind, "prune_us": 1e6 * t_prune,
           "cc_hop_us": 1e6 * t_hop,
           "adj_bytes": int(n * gb.words * 4)}
    if n <= 4096:
        # full CC to convergence is cheap enough to track at small n
        f_cc = jax.jit(gb.cc)
        f_cc(pruned)
        t_cc, _ = timed(f_cc, pruned, repeats=repeats)
        rec["cc_full_us"] = 1e6 * t_cc
    return rec


def bench_shape(n, d, repeats=2):
    repeats = 1 if n > 16384 else repeats
    model = {
        "dense_stage2_bytes": hbm_bytes_dense(n, d),
        "packed_stage2_bytes": hbm_bytes_packed(n, d),
        "cc_hops": cc_hops(n),
    }
    model["hbm_reduction"] = (model["dense_stage2_bytes"]
                              / model["packed_stage2_bytes"])
    if n <= DENSE_N_CAP:
        dense = bench_dense(n, d, repeats)
    else:
        dense = {"skipped": True,
                 "reason": f"dense graph needs ~{9 * n * n / 1e9:.0f} GB of "
                           "[n,n] intermediates (adjacency + f32 distance + "
                           "i32 neighbour labels); packed runs in "
                           f"{n * n // 8 / 1e9:.1f} GB"}
    packed = bench_packed(n, d, repeats)
    rec = {
        "n": n, "d": d,
        "graph_mem_dense_bytes": n * n,
        "graph_mem_packed_bytes": int(n * ((n + 31) // 32) * 4),
        "dense": dense, "packed": packed, "model": model,
    }
    emit(f"graph_prune_n{n}_packed", packed["prune_us"],
         f"hbm_reduction={model['hbm_reduction']:.1f}x")
    emit(f"graph_cc_hop_n{n}_packed", packed["cc_hop_us"],
         "dense=skipped" if dense.get("skipped")
         else f"dense_us={dense['cc_hop_us']:.1f}")
    return rec


def _interpret_parity(n=150, d=8):
    """In-run check: pallas-interpret prune + CC equal the reference engine
    (full parity matrix lives in tests/test_graph.py)."""
    import numpy as np

    v, occ, labels = _inputs(n, d)
    ref = backend_mod.BackendConfig.create("reference").graph(n)
    pal = backend_mod.BackendConfig.create("pallas").graph(
        n, interpret=True, block_i=64, block_j=64)
    adj0 = ref.init_adj()
    a_ref = ref.prune(adj0, v, occ, GAMMA)
    a_pal = pal.prune(adj0, v, occ, GAMMA)
    same_adj = bool((np.asarray(a_ref) == np.asarray(a_pal)).all())
    same_cc = bool((np.asarray(ref.cc(a_ref))
                    == np.asarray(pal.cc(a_pal))).all())
    return {"pruned_bits_identical": same_adj, "cc_labels_identical": same_cc}


def main(quick: bool = False):
    # the acceptance gates live at n=16384 (modeled >=8x) and n=65536
    # (packed completes where dense cannot), so --quick runs the full n
    # sweep; "quick" trims repeats, not coverage.
    records = [bench_shape(n, D, repeats=2 if quick else 3) for n in NS]
    by_n = {r["n"]: r for r in records}
    payload = {
        "mode": "quick" if quick else "full",
        "jax_backend": jax.default_backend(),
        "block_i": BLOCK_I,
        "records": records,
        "interpret_parity": _interpret_parity(),
        "hbm_reduction_at_16384": by_n[16384]["model"]["hbm_reduction"],
        "packed_completes_at_65536": 65536 in by_n
                                     and "prune_us" in by_n[65536]["packed"],
        "dense_at_65536": by_n[65536]["dense"],
    }
    (ROOT / "BENCH_graph.json").write_text(json.dumps(payload, indent=1))
    return payload


if __name__ == "__main__":
    main()
