"""Non-stationary (drift) scenario benchmark through the unified engine.

The abstract's motivating regime — "content popularity can change
rapidly" — as a tracked workload: DistCLUB runs on ``DriftEnv`` (cluster
centroids re-draw every ``drift_period`` per-user interactions) and we
record, per phase, the reward/random ratio plus the end-to-end epoch
timing.  A healthy learner shows the signature dip-and-recover: the
ratio drops right after each re-draw and climbs back within the phase.

Two scenario rows:

  single_host  the engine with null collectives (this process)
  sharded_8dev the SAME stage functions under shard_map on an 8-device
               host-platform mesh (subprocess; the drift EnvOps is
               shard-aware, so this is one ``ops=`` argument away)

Writes BENCH_drift.json at the repo root (tracked from PR 3 onward).
"""
from __future__ import annotations

import json
import os
import pathlib
import subprocess
import sys

import jax

from repro.core import distclub, env, env_ops
from repro.core.types import BanditHyper

from .common import timed, emit

ROOT = pathlib.Path(__file__).resolve().parents[1]

D, K = 16, 10
HYPER = BanditHyper(sigma=8, max_rounds=16, gamma=1.5, n_candidates=K)
N_PHASES = 3
# full: 3 epochs (of 2*sigma=16 interactions/user) per phase; quick halves
# the user count and runs 2 epochs/phase — same dip-and-recover signal,
# well under a minute on one core.
FULL = dict(n=256, clusters=8, drift_period=48, epochs=9)
QUICK = dict(n=128, clusters=8, drift_period=32, epochs=6)

_SHARDED_CODE = r"""
import time, jax
from repro.core import env, env_ops
from repro.core.types import BanditHyper
from repro.distributed import distclub_shard

N, D, K, CLUSTERS = {n}, 16, 10, {clusters}
EPOCHS = {epochs}
hyper = BanditHyper(sigma=8, max_rounds=16, gamma=1.5, n_candidates=K)
denv, _ = env.make_drift_env(jax.random.PRNGKey(0), N, D, CLUSTERS, K,
                             drift_period={drift_period}, n_phases=3)
ops = env_ops.drift_ops(denv)
mesh = jax.make_mesh((8,), ("users",))
init_fn, epoch = distclub_shard.make_runtime(mesh, ("users",), N, D, hyper,
                                             ops=ops)
state = init_fn(jax.random.PRNGKey(0))
keys = jax.random.split(jax.random.PRNGKey(1), EPOCHS)
state, m, _ = epoch(state, keys[0])          # compile + warm
jax.block_until_ready(state)
t0 = time.perf_counter()
tot_r = tot_rand = 0.0
for k in keys[1:]:
    state, m, _ = epoch(state, k)
    tot_r += float(m.reward.sum()); tot_rand += float(m.rand_reward.sum())
jax.block_until_ready(state)
print("SHARD_EPOCH_S", (time.perf_counter() - t0) / (EPOCHS - 1),
      "RATIO", tot_r / tot_rand)
"""


def _phase_ratios(metrics, epochs):
    """Reward/random ratio per drift phase (epoch-granular split)."""
    per_epoch = metrics.reward.shape[0] // epochs
    ratios = []
    for p in range(N_PHASES):
        lo = p * (epochs // N_PHASES) * per_epoch
        hi = (p + 1) * (epochs // N_PHASES) * per_epoch
        r = float(metrics.reward[lo:hi].sum())
        rnd = float(metrics.rand_reward[lo:hi].sum())
        ratios.append(r / max(rnd, 1e-9))
    return ratios


def main(quick: bool = False):
    cfg = QUICK if quick else FULL
    n, epochs = cfg["n"], cfg["epochs"]
    denv, _ = env.make_drift_env(jax.random.PRNGKey(0), n, D,
                                 cfg["clusters"], K,
                                 drift_period=cfg["drift_period"],
                                 n_phases=N_PHASES)
    ops = env_ops.drift_ops(denv)
    secs, (state, metrics, nclu) = timed(
        distclub.run, ops, jax.random.PRNGKey(1), HYPER, epochs, D)
    ratios = _phase_ratios(metrics, epochs)
    payload = {
        "scenario": {
            "n_users": n, "d": D, "n_clusters": cfg["clusters"],
            "drift_period": cfg["drift_period"], "n_phases": N_PHASES,
            "epochs": epochs, "quick": quick,
        },
        "single_host": {
            "total_s": secs,
            "epoch_s": secs / epochs,
            "reward_over_random_per_phase": ratios,
            "final_clusters": int(nclu[-1]),
        },
    }
    emit("drift_single_host_epoch", 1e6 * secs / epochs,
         f"reward/rand per phase {['%.3f' % r for r in ratios]}")

    envv = dict(os.environ)
    envv["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    envv["PYTHONPATH"] = str(ROOT / "src")
    code = _SHARDED_CODE.format(n=n, clusters=cfg["clusters"],
                                drift_period=cfg["drift_period"],
                                epochs=epochs)
    out = subprocess.run([sys.executable, "-c", code],
                         capture_output=True, text=True, env=envv,
                         timeout=900)
    if out.returncode == 0:
        parts = out.stdout.split()
        payload["sharded_8dev"] = {
            "epoch_s": float(parts[1]),
            "reward_over_random": float(parts[3]),
        }
        emit("drift_sharded_8dev_epoch", 1e6 * float(parts[1]),
             f"reward/rand {float(parts[3]):.3f}")
    else:
        payload["sharded_8dev"] = {"error": out.stderr[-800:]}

    (ROOT / "BENCH_drift.json").write_text(json.dumps(payload, indent=1))


if __name__ == "__main__":
    main()
