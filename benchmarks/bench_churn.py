"""Catalog-churn benchmark: live double-buffered swaps under traffic.

Runs the churn fault harness (`serve.faults.run_faulted_catalog`)
against a churn-free control on IDENTICAL traffic and delivery faults
(same JAX keys; churn content comes from its own key stream, fault
coins from a separate NumPy stream) and records, per churn scenario:

  matched_ratio            folded / issued decisions (gated; seeded)
  stale_ratio              quarantined / issued decisions — feedback for
                           items churned out between issue and delivery
                           (gated; seeded: any drift is a real change in
                           the epoch/quarantine semantics)
  reward_vs_nochurn_ratio  true realized reward vs the churn-free
                           control — the learning cost of catalog churn
                           (gated; seeded)
  tx_vs_nochurn_ratio      throughput vs the churn-free row — the
                           serving cost of the double-buffered swap
                           path (gated against a conservatively
                           hand-set baseline: wall-clock-derived, so
                           the baseline is NOT a measured value)
  tx_per_s                 wall clock — never gated

Every scenario (including the ``nochurn`` control) runs the same
delay/loss delivery faults, so the ratios isolate the churn itself.
A warmup run absorbs compilation before anything is timed, and the
sustained row hard-asserts ``tx_vs_nochurn_ratio >= 0.75`` — the
acceptance bound: a publish is one buffer flip, not a serving stall.

Writes BENCH_churn.json at the repo root.
"""
from __future__ import annotations

import json
import pathlib

import jax

from repro import serve
from repro.core import env
from repro.core.types import BanditHyper
from repro.serve import faults

from .common import emit

ROOT = pathlib.Path(__file__).resolve().parents[1]

N_USERS, D, BATCH = 128, 8, 32
N_ITEMS, CAPACITY_ITEMS, K_SHORT = 384, 512, 16
ROUNDS, CAPACITY, TTL = 60, 512, 16

# identical delivery faults on every row (churn-free control included)
# so the vs-nochurn ratios isolate the churn itself
_DELIVERY = dict(seed=5, p_delay=0.25, max_delay=3, p_loss=0.05)

# QUICK_SCENARIOS stays a subset of FULL_SCENARIOS (check_regression
# matches rows by identity and fails on vanished baseline rows)
FULL_SCENARIOS = [
    ("nochurn", faults.FaultSpec(**_DELIVERY)),
    ("sustained", faults.FaultSpec(**_DELIVERY, churn_every=3,
                                   churn_add=8, churn_retire=8)),
    ("flash_crowd", faults.FaultSpec(**_DELIVERY, churn_every=5,
                                     churn_add=8, churn_retire=8,
                                     flash_crowd_at=10,
                                     flash_crowd_size=24)),
    ("mass_retire", faults.FaultSpec(**_DELIVERY, churn_every=4,
                                     churn_add=8, mass_retire_at=15)),
    ("torn_swap", faults.FaultSpec(**_DELIVERY, churn_every=3,
                                   churn_add=8, churn_retire=8,
                                   p_torn=0.5, swap_stall_rounds=1)),
]
QUICK_SCENARIOS = FULL_SCENARIOS[:3]

TX_FLOOR = 0.75   # acceptance bound: churn costs < 25% throughput


def _session():
    hyper = BanditHyper(sigma=4, max_rounds=1, gamma=1.5, n_candidates=10)
    return serve.OnlineBandit.create(
        N_USERS, D, hyper, policy="distclub", refresh_every=N_USERS,
        pending_capacity=CAPACITY, pending_ttl=TTL)


def _run(e, cat, spec, rounds=ROUNDS):
    return faults.run_faulted_catalog(
        _session(), e, rounds, spec, catalog=cat, k_short=K_SHORT,
        batch=BATCH, key=11, assert_conservation=True)


def main(quick: bool = False):
    scenarios = QUICK_SCENARIOS if quick else FULL_SCENARIOS
    e, _ = env.make_catalog_env(jax.random.PRNGKey(0), N_USERS, D, 4,
                                N_ITEMS, n_candidates=10)
    cat = serve.make_catalog(env.catalog_embeddings(e),
                             capacity=CAPACITY_ITEMS)

    # warmup: compile every transaction path any scenario hits —
    # issue/fold, stage (sustained-add, flash-crowd, mass-retire id
    # shapes), clean and torn publish — before any timed run
    _run(e, cat, faults.FaultSpec(**_DELIVERY, churn_every=2, churn_add=8,
                                  churn_retire=8, p_torn=0.5,
                                  flash_crowd_at=2, flash_crowd_size=24,
                                  mass_retire_at=4, swap_stall_rounds=1),
         rounds=8)

    _, nochurn = _run(e, cat, FULL_SCENARIOS[0][1])
    rows = []
    for name, spec in scenarios:
        # the churn-free row IS the control — its vs-nochurn ratios are
        # exactly 1 by construction, not a rerun's wall-clock noise
        _, rep = (None, nochurn) if name == "nochurn" \
            else _run(e, cat, spec)
        st = rep.pending
        tx_ratio = rep.tx_per_s / max(nochurn.tx_per_s, 1e-9)
        row = {
            "scenario": name, "policy": "distclub",
            "n_users": N_USERS, "batch": BATCH, "d": D,
            "N_items": N_ITEMS, "item_capacity": CAPACITY_ITEMS,
            "K_short": K_SHORT, "rounds": ROUNDS,
            "capacity": CAPACITY, "ttl": TTL,
            "churn_every": spec.churn_every,
            "churn_add": spec.churn_add,
            "churn_retire": spec.churn_retire,
            "p_torn": spec.p_torn,
            "publishes": rep.publishes,
            "items_added": rep.items_added,
            "items_retired": rep.items_retired,
            "matched_ratio": st["matched"] / max(1, st["issued"]),
            "stale_ratio": st["stale"] / max(1, st["issued"]),
            "reward_vs_nochurn_ratio":
                rep.reward / max(nochurn.reward, 1e-9),
            "tx_vs_nochurn_ratio": tx_ratio,
            "conservation_gap": 0,   # asserted exact every delivery
            "tx_per_s": rep.tx_per_s,
        }
        rows.append(row)
        emit(f"churn_{name}", 1e6 / max(rep.tx_per_s, 1e-9),
             f"stale={row['stale_ratio']:.3f} "
             f"matched={row['matched_ratio']:.3f} "
             f"reward_vs_nochurn={row['reward_vs_nochurn_ratio']:.3f} "
             f"tx_vs_nochurn={tx_ratio:.2f} epochs={rep.publishes}")
        if name == "sustained" and tx_ratio < TX_FLOOR:
            raise AssertionError(
                f"sustained churn throughput {tx_ratio:.2f}x nochurn "
                f"< {TX_FLOOR} — publish is stalling the serving path")

    payload = {
        "mode": "quick" if quick else "full",
        "jax_backend": jax.default_backend(),
        "determinism_note": (
            "matched_ratio / stale_ratio / reward_vs_nochurn_ratio are "
            "fully seeded (JAX traffic + churn-content keys, NumPy "
            "fault stream) — gated; the conservation identity "
            "issued == matched + in_flight + expired + dropped + stale "
            "is hard-asserted after every delivery; "
            "tx_vs_nochurn_ratio is wall-clock-derived, gated against "
            "a hand-set conservative baseline, never refreshed from a "
            "measured run; tx_per_s is wall clock, never gated"),
        "scenarios": rows,
    }
    (ROOT / "BENCH_churn.json").write_text(json.dumps(payload, indent=1))
    return payload


if __name__ == "__main__":
    main()
