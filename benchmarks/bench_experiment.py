"""Online-experimentation benchmark: the routing layer's cost and the
meta-selector's learning.

Two scenarios, both fully seeded (JAX traffic keys + NumPy fault/selector
streams), written to BENCH_experiment.json:

  meta_selector      3 arms — one tuned (planted best) + two copycats
                     with absurd exploration — under the Thompson-
                     sampling meta-selector.  Gated:
                     ``meta_vs_best_fixed_reward_ratio`` (selector's
                     total realized reward vs the best FIXED single arm
                     on the identical traffic stream — the price of
                     having to learn which arm wins).  Recorded:
                     ``share_best_final`` (fraction of traffic on the
                     planted best by the end; the ≥0.6 acceptance bar is
                     asserted in-run), per-arm shares, the sequential z.

  routing_overhead   a 1-arm experiment vs the bare ``run_faulted`` loop
                     on identical traffic — the full router (sticky
                     assign, mask, merge, arm-encoded ids, accounting)
                     against the plain session harness.  Gated:
                     ``tx_vs_single_policy_ratio`` (experiment tx/s over
                     single-session tx/s, best-of-repeats; the baseline
                     is pinned so the CI floor sits at the 0.8x
                     acceptance bar).

Writes BENCH_experiment.json at the repo root.
"""
from __future__ import annotations

import json
import pathlib

import jax

from repro import serve
from repro.core import env
from repro.core.types import BanditHyper
from repro.serve import experiments, faults

from .common import emit

ROOT = pathlib.Path(__file__).resolve().parents[1]

N_USERS, D, K, BATCH = 64, 8, 10, 16
ROUNDS, CAPACITY, TTL = 60, 256, 16
EPOCH_ROUNDS, FLOOR = 10, 0.05
BEST_ALPHA, NOISY_ALPHA = 0.05, 50.0


def _arm(alpha: float):
    hyper = BanditHyper(alpha=alpha, sigma=4, max_rounds=1, gamma=1.5,
                        n_candidates=K)
    return serve.OnlineBandit.create(
        N_USERS, D, hyper, policy="linucb", refresh_every=N_USERS,
        pending_capacity=CAPACITY, pending_ttl=TTL)


def _meta_selector_row(theta):
    def fresh():
        return experiments.create(
            [_arm(BEST_ALPHA), _arm(NOISY_ALPHA), _arm(NOISY_ALPHA)],
            names=("best", "noisy1", "noisy2"), salt=11,
            selector=experiments.make_selector(
                3, epoch_rounds=EPOCH_ROUNDS, floor=FLOOR))

    exp, rep = experiments.run_experiment(fresh(), theta, ROUNDS,
                                          batch=BATCH, key=5)
    # the best FIXED arm on the identical stream: all traffic to `best`
    solo = experiments.create([_arm(BEST_ALPHA)], names=("best",))
    _, fixed = experiments.run_experiment(solo, theta, ROUNDS,
                                          batch=BATCH, key=5)
    share_best = rep.fractions[0]
    assert rep.leader == "best", rep.leader
    assert share_best >= 0.6, (
        f"meta-selector routed only {share_best:.2f} to the planted best")
    return {
        "scenario": "meta_selector", "policy": "linucb",
        "n_users": N_USERS, "batch": BATCH, "d": D, "K": K,
        "rounds": ROUNDS, "epoch_rounds": EPOCH_ROUNDS, "floor": FLOOR,
        "meta_vs_best_fixed_reward_ratio": round(
            sum(rep.reward) / max(sum(fixed.reward), 1e-9), 3),
        "share_best_final": round(share_best, 3),
        "share_noisy1_final": round(rep.fractions[1], 3),
        "share_noisy2_final": round(rep.fractions[2], 3),
        "z_leading_pair": round(rep.z_leading_pair, 2),
        "reward_per_decision_best": round(
            rep.reward[0] / max(1, rep.interactions[0]), 3),
        "epochs": len(rep.shares) - 1,
    }


def _routing_overhead_row(theta, repeats: int):
    def single_tx():
        sess, rep = faults.run_faulted(_arm(BEST_ALPHA), theta, ROUNDS,
                                       faults.FaultSpec(), batch=BATCH,
                                       key=11)
        return rep.tx_per_s

    def exp_tx():
        e = experiments.create([_arm(BEST_ALPHA)])
        _, rep = experiments.run_experiment(e, theta, ROUNDS, batch=BATCH,
                                            key=11)
        return rep.tx_per_s

    single_tx()                         # warm the compile caches
    exp_tx()
    single = max(single_tx() for _ in range(repeats))
    routed = max(exp_tx() for _ in range(repeats))
    return {
        "scenario": "routing_overhead", "policy": "linucb",
        "n_users": N_USERS, "batch": BATCH, "d": D, "K": K,
        "rounds": ROUNDS,
        "tx_vs_single_policy_ratio": round(routed / max(single, 1e-9), 3),
        "single_tx_per_s": round(single, 1),
        "experiment_tx_per_s": round(routed, 1),
    }


def main(quick: bool = False):
    e, _ = env.make_synthetic_env(jax.random.PRNGKey(0), N_USERS, D, 4, K)
    rows = [
        _meta_selector_row(e.theta),
        _routing_overhead_row(e.theta, repeats=2 if quick else 4),
    ]
    for row in rows:
        emit(f"experiment_{row['scenario']}", 0.0,
             " ".join(f"{k}={v}" for k, v in row.items()
                      if k.endswith("ratio") or k.startswith("share")))

    payload = {
        "mode": "quick" if quick else "full",
        "jax_backend": jax.default_backend(),
        "determinism_note": (
            "meta_vs_best_fixed_reward_ratio and the shares are fully "
            "seeded (JAX traffic keys + NumPy selector/fault streams) — "
            "any drift is a real routing/selector change; "
            "tx_vs_single_policy_ratio is wall clock of two identical-"
            "shape loops (best of repeats), gated with its baseline "
            "pinned so the CI floor is the 0.8x acceptance bar"),
        "scenarios": rows,
    }
    (ROOT / "BENCH_experiment.json").write_text(
        json.dumps(payload, indent=1))
    return payload


if __name__ == "__main__":
    main()
