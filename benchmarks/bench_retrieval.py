"""Catalog-scale retrieval benchmark + the streaming-vs-dense HBM model.

Times the streaming UCB top-K shortlist (``RetrievalBackend``) against a
persistent item catalog at serving shapes, and models the HBM traffic
both ways:

  dense       score the whole catalog as one ``[B, N]`` op chain
              (einsum -> [B, N, d] quad intermediate -> scores ->
              top_k), each XLA op streaming its operands.  Per user:
              ``N d / B`` (catalog stream amortized over the request
              block) + ``2 N d`` ([N, d] quad intermediate write+read)
              + ``2 N`` (scores write + top-k read) + ``d^2 + d`` state.
  streaming   the retrieval engine: the catalog streams through VMEM
              once per user block and ONLY the ``[B, K_short]`` shortlist
              is written — no [N, d] intermediate, no score matrix.
              Per user: ``N d / Bu`` + ``d^2 + d`` + ``4 K_short``.

The modeled cut (``hbm_cut_ratio``) is what the two-stage redesign buys
on the item axis — the CI regression gate tracks it (≥8x is the PR-5
acceptance floor at N=262144, d=32, K_short=64; the model gives ~250x).

Wall-clock columns: the reference engine rows are honest CPU numbers
(the row-blocked oracle is also the off-TPU serving path); the pallas
row is interpret-mode off-TPU — kernel-path validation, not a speed
claim (same convention as every other bench, flagged per record).
A ``N_items = 2**20`` reference row demonstrates catalog scale on one
CPU core, and an 8-device item-sharded serving row (subprocess mesh)
runs the full two-stage ``step_catalog`` transaction with the modeled
comm volume: ``O(B K_short S)`` merge words vs ``O(B N)`` for shipping
dense scores.

The ``pruned`` row exercises cluster-pruned retrieval (README
"Cluster-pruned retrieval") on a region-structured catalog: item-side
CLUB clusters + per-tile UCB upper bounds let the stream skip tiles
whose bound cannot beat the running shortlist floor.  Pruning is EXACT
(the row asserts the pruned shortlist bit-equal to unpruned), so the
gated metric is pure savings: ``tiles_skipped_ratio`` (fraction of tile
visits avoided, gate ≥ 0.5 at N=262144) and the modeled
``hbm_cut_vs_unpruned_ratio``.

Writes BENCH_retrieval.json at the repo root (tracked from PR 5 onward).
"""
from __future__ import annotations

import json
import os
import pathlib
import subprocess
import sys

import jax
import jax.numpy as jnp

from repro.core import catalog as catalog_mod
from repro.core.backend import BackendConfig

from .common import emit, timed

ROOT = pathlib.Path(__file__).resolve().parents[1]

D, KSHORT = 32, 64
BATCH = 64                     # request-batch users per shortlist call
# (N_items, dense wall-clock comparable) — dense at 2**18 would need a
# [B, N, d] f32 intermediate (2 GiB at B=64): modeled only, like
# bench_graph's dense_at_65536.  The gated 262144 row stays shapes[0] in
# both modes so check_regression's baseline paths line up.
FULL_SHAPES = [(262144, False), (16384, True)]
QUICK_SHAPES = [(262144, False)]
REFERENCE_1M = 1 << 20


# ---- analytic HBM-traffic model (f32 words per user per request) -----------

def hbm_words_dense(N: int, d: int, batch: int) -> float:
    """Dense [B, N] scoring, op-level accounting (see module docstring)."""
    return N * d / batch + 2 * N * d + 2 * N + d * d + d


def hbm_words_streaming(N: int, d: int, k_short: int, block_users: int
                        ) -> float:
    """Streaming engine: catalog once per user block, shortlist out."""
    return N * d / block_users + d * d + d + 4 * k_short


def hbm_words_pruned(N: int, d: int, k_short: int, block_users: int,
                     tiles: int, skip_ratio: float) -> float:
    """Cluster-pruned streaming: only ``(1 - skip_ratio)`` of the catalog
    streams; adds the ``[T, d+3]`` cluster-bound table (read once per
    user block) and the per-user ``[T]`` tile-bound row."""
    return ((1.0 - skip_ratio) * N * d / block_users
            + tiles * (d + 3) / block_users + tiles
            + d * d + d + 4 * k_short)


# ---- modeled sharded comm (f32 words per request batch) --------------------

def comm_words_sharded(batch: int, d: int, k_short: int, shards: int) -> int:
    """Two-stage merge traffic: psum-replicate the request users' stats
    (d^2 + d + 1 words each), all-gather the per-shard (score, id)
    shortlists, psum the one-hot shortlist-embedding assembly."""
    return (batch * (d * d + d + 1)
            + 2 * batch * k_short * shards
            + batch * k_short * d)


def comm_words_dense(batch: int, N: int) -> int:
    """The alternative: ship every shard's [B, N_local] scores to a
    merger — O(B N) words regardless of topology."""
    return batch * N


# ---- timed rows ------------------------------------------------------------

def _inputs(n, d, N, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    w = 0.1 * jax.random.normal(ks[0], (n, d))
    Minv = jnp.broadcast_to(jnp.eye(d, dtype=jnp.float32), (n, d, d))
    occ = jax.random.randint(ks[1], (n,), 1, 100)
    cat = catalog_mod.random_catalog(ks[2], N, d)
    return w, Minv, occ, cat


def _dense_topk(w, Minv, occ, items, alpha, k):
    est = jnp.einsum("nd,Nd->nN", w, items)
    t = jnp.einsum("nab,Nb->nNa", Minv, items)
    quad = jnp.einsum("nNa,Na->nN", t, items)
    s = est + alpha * jnp.sqrt(jnp.maximum(quad, 0.0)) * jnp.sqrt(
        jnp.log1p(occ.astype(jnp.float32)))[:, None]
    return jax.lax.top_k(s, k)


def bench_shape(N, dense_ok, repeats=2):
    w, Minv, occ, cat = _inputs(BATCH, D, N)
    rb = BackendConfig.create("reference").retrieval(D, KSHORT)
    f_stream = jax.jit(lambda w, M, o, e, lv: rb.shortlist(
        w, M, o, e, lv, 0.3))
    ids = f_stream(w, Minv, occ, cat.serving.emb, cat.serving.live)[1]
    jax.block_until_ready(ids)
    secs, _ = timed(f_stream, w, Minv, occ, cat.serving.emb, cat.serving.live,
                    repeats=repeats)

    rec = {
        "N_items": N, "batch": BATCH, "d": D, "K_short": KSHORT,
        "backend": "reference",
        "streaming_us": 1e6 * secs,
        "hbm_bytes_per_user_dense": 4 * hbm_words_dense(N, D, BATCH),
        "hbm_bytes_per_user_streaming": 4 * hbm_words_streaming(
            N, D, KSHORT, rb.block_users),
        "hbm_cut_ratio": hbm_words_dense(N, D, BATCH)
        / hbm_words_streaming(N, D, KSHORT, rb.block_users),
        "comm_bytes_sharded8_per_batch": 4 * comm_words_sharded(
            BATCH, D, KSHORT, 8),
        "comm_bytes_dense_gather_per_batch": 4 * comm_words_dense(BATCH, N),
        "comm_cut_ratio": comm_words_dense(BATCH, N)
        / comm_words_sharded(BATCH, D, KSHORT, 8),
    }
    if dense_ok:
        f_dense = jax.jit(lambda w, M, o, e: _dense_topk(
            w, M, o, e, 0.3, KSHORT))
        jax.block_until_ready(f_dense(w, Minv, occ, cat.serving.emb))
        dsecs, _ = timed(f_dense, w, Minv, occ, cat.serving.emb, repeats=repeats)
        rec["dense_us"] = 1e6 * dsecs
    else:
        rec["dense_skipped"] = (
            f"dense scoring needs a [B, N, d] f32 intermediate "
            f"({4 * BATCH * N * D / 2**30:.1f} GiB) — modeled only")
    emit(f"retrieval_topk_N{N}_B{BATCH}_streaming", rec["streaming_us"],
         f"hbm_cut={rec['hbm_cut_ratio']:.1f}x")
    return rec


def _reference_1m_row(repeats=1):
    """N_items = 2**20 on one CPU core: the row-blocked oracle at a
    small request batch — the catalog-scale acceptance row."""
    n = 8
    w, Minv, occ, cat = _inputs(n, D, REFERENCE_1M)
    rb = BackendConfig.create("reference").retrieval(D, KSHORT)
    f = jax.jit(lambda w, M, o, e, lv: rb.shortlist(w, M, o, e, lv, 0.3))
    out = f(w, Minv, occ, cat.serving.emb, cat.serving.live)
    jax.block_until_ready(out)
    secs, _ = timed(f, w, Minv, occ, cat.serving.emb, cat.serving.live, repeats=repeats)
    emit(f"retrieval_topk_N{REFERENCE_1M}_B{n}_reference", 1e6 * secs,
         "catalog=2**20")
    return {"N_items": REFERENCE_1M, "batch": n, "d": D, "K_short": KSHORT,
            "backend": "reference", "completes_on_cpu": True,
            "streaming_us": 1e6 * secs}


def _pruned_row(N=262144, tile_items=512, repeats=1):
    """Cluster-pruned vs plain streaming on a region-structured catalog
    (8 regions, tight item noise — the regime cluster pruning targets;
    a structureless catalog degrades to ~0 skips, never to wrong
    results).

    The exactness check runs the SAME compiled kernel twice — real tile
    bounds vs ``tb = +inf`` (skipping disabled) — and requires bit-equal
    (score, id) shortlists.  That isolates the pruning logic: two
    separately-compiled programs can differ in the last ulp from XLA
    reduction reassociation, which flips near-ties and is not a property
    of pruning (the serving path keeps both branches in one ``lax.cond``
    program for the same reason; see tests/test_itemclub.py).  The
    no-skip run doubles as the apples-to-apples unpruned wall-clock.
    Raises if pruning is inexact or the skip ratio misses the 0.5
    acceptance floor, so run.py's failure policy gates it."""
    import numpy as np

    from repro.core import env as env_mod
    from repro.core import itemclub
    from repro.kernels.topk.ops import topk_pruned
    from repro.kernels.topk.ref import tile_bounds

    e, _ = env_mod.make_catalog_env(jax.random.PRNGKey(0), BATCH, D, 8, N,
                                    item_noise_scale=0.01)
    cat = catalog_mod.make_catalog(env_mod.catalog_embeddings(e))
    w = e.theta                      # unit-ish user params: realistic floors
    Minv = jnp.broadcast_to(jnp.eye(D, dtype=jnp.float32), (BATCH, D, D))
    occ = jax.random.randint(jax.random.PRNGKey(1), (BATCH,), 1, 100)

    build_secs, cl = timed(itemclub.build_clusters, cat,
                           tile_items=tile_items, n_anchors=512)

    f = jax.jit(lambda w, M, o, c, tb: topk_pruned(
        w, M, o, c.emb_sorted, c.live_sorted, c.perm, 0.3, KSHORT, tb,
        use_pallas=False, row_block=4))
    tb = tile_bounds(w, Minv, occ, 0.3, cl.tile_mu, cl.tile_r,
                     cl.tile_xn, cl.tile_n)
    tb_off = jnp.full_like(tb, jnp.inf)

    jax.block_until_ready(f(w, Minv, occ, cl, tb))
    p_secs, (sp, ip, skipped, total) = timed(f, w, Minv, occ, cl, tb,
                                             repeats=repeats)
    jax.block_until_ready(f(w, Minv, occ, cl, tb_off))
    u_secs, (su, iu, _, _) = timed(f, w, Minv, occ, cl, tb_off,
                                   repeats=repeats)

    identical = bool(np.array_equal(np.asarray(iu), np.asarray(ip))
                     and np.array_equal(np.asarray(su), np.asarray(sp)))
    ratio = float(skipped) / float(total)
    if not identical:
        raise RuntimeError("pruned shortlist diverged from the no-skip "
                           "run of the same kernel — the exactness "
                           "invariant is broken")
    if ratio < 0.5:
        raise RuntimeError(
            f"tiles_skipped_ratio {ratio:.3f} < 0.5 acceptance floor")

    tiles = N // tile_items
    bu = 128                    # engine user-block (matches shapes rows)
    words_un = hbm_words_streaming(N, D, KSHORT, bu)
    words_pr = hbm_words_pruned(N, D, KSHORT, bu, tiles, ratio)
    rec = {
        "N_items": N, "batch": BATCH, "d": D, "K_short": KSHORT,
        "backend": "reference", "scenario": "regions8_noise0.01",
        "tile_items": tile_items,
        "tiles_skipped_ratio": ratio,
        "pruned_ids_identical": identical,
        "pruned_us": 1e6 * p_secs,
        "unpruned_us": 1e6 * u_secs,
        "cluster_build_us": 1e6 * build_secs,
        "hbm_bytes_per_user_pruned": 4 * words_pr,
        "hbm_cut_vs_unpruned_ratio": words_un / words_pr,
    }
    emit(f"retrieval_pruned_N{N}_B{BATCH}", rec["pruned_us"],
         f"skip={ratio:.2f},unpruned_us={rec['unpruned_us']:.0f}")
    return rec


def _interpret_parity(n=16, d=16, N=512, k=8):
    """In-run validation that the kernel path matches the oracle bit for
    bit (full coverage in tests/test_retrieval.py)."""
    import numpy as np

    w, Minv, occ, cat = _inputs(n, d, N, seed=3)
    live = cat.serving.live.at[jnp.arange(0, N, 7)].set(0.0)
    r_ref = BackendConfig.create("reference").retrieval(d, k)
    r_pal = BackendConfig.create("pallas").retrieval(
        d, k, block_users=8, block_items=128, interpret=True)
    s1, i1 = r_ref.shortlist(w, Minv, occ, cat.serving.emb, live, 0.3)
    s2, i2 = r_pal.shortlist(w, Minv, occ, cat.serving.emb, live, 0.3)
    return {
        "ids_identical": bool((np.asarray(i1) == np.asarray(i2)).all()),
        "scores_max_abs_err": float(jnp.max(jnp.abs(s1 - s2))),
        "pallas_backend": "pallas_interpret"
        if jax.default_backend() != "tpu" else "pallas",
    }


_SHARDED_CODE = r"""
import time, jax, jax.numpy as jnp
from repro import serve
from repro.core import catalog as catalog_mod, env
from repro.core.types import BanditHyper
from repro.distributed.distclub_shard import named_shardings

N_USERS, D, KS, B, N_ITEMS = 1024, {d}, {ks}, {batch}, {n_items}
hyper = BanditHyper(alpha=0.05, gamma=1.5, n_candidates=KS)
e, _ = env.make_catalog_env(jax.random.PRNGKey(0), N_USERS, D, 8, N_ITEMS)
cat = serve.make_catalog(env.catalog_embeddings(e))
theta = e.theta

def reward_fn(key, uids, ctx, choice):
    return env.step_rewards(key, theta[uids], ctx, choice)

mesh = jax.make_mesh((8,), ("users",))
session = serve.OnlineBandit.sharded(mesh, N_USERS, D, hyper,
                                     policy="distclub", refresh_every=0,
                                     backend="reference")
cat8 = jax.device_put(cat, named_shardings(mesh,
                                           catalog_mod.specs(("users",))))
uids = jax.random.permutation(jax.random.PRNGKey(2),
                              N_USERS)[:B].astype(jnp.int32)
session, ids, m = serve.step_catalog(session, jax.random.PRNGKey(3), uids,
                                     cat8, reward_fn, k_short=KS)
jax.block_until_ready(ids)
t0 = time.perf_counter()
REP = 3
for i in range(REP):
    session, ids, m = serve.step_catalog(session, jax.random.PRNGKey(4 + i),
                                         uids, cat8, reward_fn, k_short=KS)
jax.block_until_ready(ids)
print("SHARD_STEP_US", 1e6 * (time.perf_counter() - t0) / REP)
"""


def _sharded_row(n_items=65536, batch=32):
    """8-device item-sharded two-stage serving transaction (host-platform
    mesh; 8 shards on one CPU core, so wall-clock is a smoke number —
    the modeled comm cut is the tracked metric)."""
    envv = dict(os.environ)
    envv["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    envv["PYTHONPATH"] = str(ROOT / "src")
    out = subprocess.run(
        [sys.executable, "-c",
         _SHARDED_CODE.format(d=D, ks=KSHORT, batch=batch,
                              n_items=n_items)],
        capture_output=True, text=True, env=envv, timeout=900)
    if out.returncode != 0 or "SHARD_STEP_US" not in out.stdout:
        # raise, don't record-and-continue: run.py's failure policy makes
        # the quick-bench step a real gate, and the comm metrics this row
        # feeds are baseline-gated by check_regression
        raise RuntimeError("sharded retrieval row failed:\n"
                           + (out.stderr or out.stdout)[-800:])
    us = float(out.stdout.split("SHARD_STEP_US")[1].split()[0])
    emit(f"retrieval_step_sharded8_N{n_items}_B{batch}", us,
         f"comm_cut={comm_words_dense(batch, n_items) / comm_words_sharded(batch, D, KSHORT, 8):.1f}x")
    return {
        "N_items": n_items, "batch": batch, "d": D, "K_short": KSHORT,
        "step_us": us,
        "comm_bytes_merge_per_batch": 4 * comm_words_sharded(
            batch, D, KSHORT, 8),
        "comm_bytes_dense_gather_per_batch": 4 * comm_words_dense(
            batch, n_items),
        "comm_cut_ratio": comm_words_dense(batch, n_items)
        / comm_words_sharded(batch, D, KSHORT, 8),
    }


def main(quick: bool = False):
    shapes = QUICK_SHAPES if quick else FULL_SHAPES
    records = [bench_shape(N, dense_ok, repeats=1 if quick else 2)
               for (N, dense_ok) in shapes]
    gate = next(r for r in records if r["N_items"] == 262144)
    payload = {
        "mode": "quick" if quick else "full",
        "jax_backend": jax.default_backend(),
        "hbm_model_note": (
            "per-user f32 words; dense = [B,N] op chain with a [N,d] "
            "quad intermediate per user; streaming = catalog once per "
            "user block + d^2 state + the [K_short] shortlist (see "
            "module docstring / README 'Catalog-scale retrieval')"),
        "shapes": records,
        "reference_1M": _reference_1m_row(),
        "sharded_8dev": _sharded_row(),
        # own top-level dict: its identity keys overlap shapes[0]'s, and
        # check_regression paths must stay collision-free
        "pruned": _pruned_row(repeats=1 if quick else 2),
        "interpret_parity": _interpret_parity(),
        # the headline gated scalar is shape-PINNED (the acceptance row),
        # not a min over the mode-dependent shape list — quick and full
        # runs must agree on every gated value
        "hbm_cut_ratio_at_262144": gate["hbm_cut_ratio"],
    }
    (ROOT / "BENCH_retrieval.json").write_text(json.dumps(payload, indent=1))
    return payload


if __name__ == "__main__":
    main()
