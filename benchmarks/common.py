"""Shared benchmark plumbing.

Scaling note (recorded in EXPERIMENTS.md): the paper's Table 3 runs 80k-4M
interactions on 64 EC2 cores; this container is ONE CPU core, so each
dataset clone runs a proportionally reduced interaction budget at the
paper's user counts and feature dims.  All comparisons are at MATCHED
interaction counts across algorithms, so ratios (speedup, reward ratio,
comm volume) are the meaningful outputs, not absolute seconds.
"""
from __future__ import annotations

import json
import pathlib
import time

import jax

RESULTS = pathlib.Path(__file__).resolve().parents[1] / "results"
RESULTS.mkdir(parents=True, exist_ok=True)


def timed(fn, *args, repeats: int = 1, **kw):
    """(wall seconds of best repeat, result). Blocks on jax async."""
    best = float("inf")
    out = None
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    return best, out


def emit(name: str, us_per_call: float, derived: str = ""):
    print(f"{name},{us_per_call:.1f},{derived}")


def save_json(name: str, payload):
    (RESULTS / f"{name}.json").write_text(json.dumps(payload, indent=1))
