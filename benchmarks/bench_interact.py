"""Fused-vs-reference interaction-step microbenchmark + HBM accounting.

Times one full stage-1-style interaction step (score -> argmax -> gather ->
rank-1 state update) at paper-scale shapes two ways:

  reference   the seed per-op path: materialize [n,K] scores, separate
              take_along_axis gather, three separate state-update ops
              (exactly what ``core/distclub.py`` ran before the engine).
  fused       the interaction-engine path (``core/backend.py``): fused
              choose + fused rank-1 update contracts.

Off-TPU the "auto" backend resolves to the jnp reference engine, which
would make the fused column silently benchmark reference-vs-reference; the
fused column therefore *explicitly* constructs the interpret-mode Pallas
backend, so it always exercises the kernel path, and every record carries
``fused_backend`` + ``wallclock_comparable`` so a reader can tell whether
the fused_us column is a compiled kernel (TPU) or the interpreter (CPU —
orders of magnitude slower than both the kernel and the reference; only
the reference_us trend and the analytic HBM model are meaningful there).
The traffic model quantifies the TPU win: per user per round the fused
path eliminates the score-tensor write+read, the [n,K,d] scored-context
intermediate, the second context read of the gather, and two of the three
Gram-state sweeps of the unfused update.  See README.md "Backends & HBM
accounting" for the model's derivation.

Writes BENCH_interact.json at the repo root so the perf trajectory is
tracked from PR 1 onward.
"""
from __future__ import annotations

import json
import pathlib

import jax
import jax.numpy as jnp

from repro.core import backend as backend_mod
from repro.core import linucb
from repro.kernels.interact.ref import choose_ref

from .common import emit, timed

ROOT = pathlib.Path(__file__).resolve().parents[1]
KEY = jax.random.PRNGKey(0)

FULL_SHAPES = [(n, d, 128) for n in (1024, 4096, 16384) for d in (16, 32)]
QUICK_SHAPES = [(1024, 32, 128), (4096, 32, 128)]


# ---- analytic HBM-traffic model (f32 words per user per round) -------------

def hbm_words_reference(d: int, K: int, with_M: bool = True) -> int:
    """Seed path, op-level accounting (each XLA op streams its operands):

    score:  read ctx (Kd) + Minv (d^2) + w (d); write+read the [K,d]
            ctx@Minv intermediate (2Kd); write scores (K)
    argmax: read scores (K); write choice (1)
    gather: read ctx again (Kd) + choice; write x (d)
    update: M read+write (2d^2) [core drivers only], Minv read for the
            Sherman-Morrison matvec (d^2), Minv read+write for the
            subtract (2d^2), b read+write (2d)
    """
    gram = 3 * d * d + (2 * d * d if with_M else 0) + d * d
    ctx = 4 * K * d
    scores = 2 * K
    small = 4 * d + 2  # w, x, b r/w, choice
    return gram + ctx + scores + small


def hbm_words_fused(d: int, K: int, with_M: bool = True) -> int:
    """Engine path: choose reads (ctx, Minv, w) once and writes (choice, x)
    — scores and the scored-context intermediate stay in VMEM; the fused
    rank-1 kernel reads each state array once and writes once."""
    gram = d * d + (2 * d * d if with_M else 0) + 2 * d * d
    ctx = K * d
    small = 4 * d + 2
    return gram + ctx + small


# ---- timed steps -----------------------------------------------------------

def _make_inputs(n, d, K):
    ks = jax.random.split(KEY, 4)
    lin = linucb.init_linucb(n, d)
    w = jax.random.normal(ks[0], (n, d))
    ctx = jax.random.normal(ks[1], (n, K, d))
    ctx = ctx / jnp.linalg.norm(ctx, axis=-1, keepdims=True)
    r = jax.random.uniform(ks[2], (n,))
    mask = jnp.ones((n,), bool)
    return lin, w, ctx, r, mask


def _reference_step(lin, w, ctx, r, mask, alpha=0.3):
    """The seed per-op path, verbatim."""
    choice, x = choose_ref(w, lin.Minv, ctx, lin.occ, alpha)
    return linucb.masked_batch_update(lin, x, r, mask), choice


def _fused_step(be, lin, w, ctx, r, mask, alpha=0.3):
    x, choice = be.choose(w, lin.Minv, ctx, lin.occ, alpha)
    return be.update_lin(lin, x, r, mask), choice


def bench_shape(n, d, K, repeats=3):
    lin, w, ctx, r, mask = _make_inputs(n, d, K)
    on_tpu = jax.default_backend() == "tpu"
    # compiled Pallas kernels on TPU; elsewhere the fused column must NOT
    # fall back to the reference engine (that benchmarked
    # reference-vs-reference and reported fused_us ~ reference_us) — build
    # the interpret-mode kernel backend explicitly and flag it.
    if on_tpu:
        be = backend_mod.BackendConfig.create("pallas").interact(n, d, K)
        fused_backend = "pallas"
    else:
        be = backend_mod.BackendConfig.create("pallas").interact(
            n, d, K, interpret=True)
        fused_backend = "pallas_interpret"

    f_ref = jax.jit(_reference_step)
    f_fused = jax.jit(lambda lin, w, ctx, r, mask: _fused_step(
        be, lin, w, ctx, r, mask))
    f_ref(lin, w, ctx, r, mask)          # compile
    f_fused(lin, w, ctx, r, mask)
    t_ref, _ = timed(f_ref, lin, w, ctx, r, mask, repeats=repeats)
    # the interpreter is slow at large n; one repeat is plenty for a
    # column whose wall-clock is flagged non-comparable anyway
    t_fused, _ = timed(f_fused, lin, w, ctx, r, mask,
                       repeats=repeats if on_tpu else 1)

    words_ref = hbm_words_reference(d, K)
    words_fused = hbm_words_fused(d, K)
    rec = {
        "n": n, "d": d, "K": K,
        "fused_backend": fused_backend,
        "wallclock_comparable": on_tpu,
        "reference_us": 1e6 * t_ref,
        "fused_us": 1e6 * t_fused,
        "hbm_bytes_per_round_reference": 4 * n * words_ref,
        "hbm_bytes_per_round_fused": 4 * n * words_fused,
        "hbm_traffic_ratio": words_ref / words_fused,
        "hbm_traffic_ratio_sharded": (
            hbm_words_reference(d, K, with_M=False)
            / hbm_words_fused(d, K, with_M=False)),
    }
    emit(f"interact_step_n{n}_d{d}_K{K}_reference", rec["reference_us"],
         f"hbm_bytes={rec['hbm_bytes_per_round_reference']}")
    emit(f"interact_step_n{n}_d{d}_K{K}_fused", rec["fused_us"],
         f"hbm_bytes={rec['hbm_bytes_per_round_fused']}"
         f";ratio={rec['hbm_traffic_ratio']:.2f}x")
    return rec


def _interpret_parity(n=128, d=16, K=20):
    """Cheap in-run validation that the two paths agree (full parity lives
    in tests/test_interact.py)."""
    import numpy as np

    lin, w, ctx, r, mask = _make_inputs(n, d, K)
    be = backend_mod.BackendConfig.create("pallas").interact(
        n, d, K, interpret=True)
    (lin_r, c_r) = _reference_step(lin, w, ctx, r, mask)
    (lin_p, c_p) = _fused_step(be, lin, w, ctx, r, mask)
    lin_p = be.unpad_lin(lin_p)
    same_choice = bool((np.asarray(be.unpad_users(c_p))
                        == np.asarray(c_r)).all())
    max_err = max(
        float(jnp.max(jnp.abs(lin_p.Minv - lin_r.Minv))),
        float(jnp.max(jnp.abs(lin_p.b - lin_r.b))),
    )
    return {"choices_identical": same_choice, "state_max_abs_err": max_err}


def main(quick: bool = False):
    shapes = QUICK_SHAPES if quick else FULL_SHAPES
    records = [bench_shape(n, d, K, repeats=3)
               for (n, d, K) in shapes]
    payload = {
        "mode": "quick" if quick else "full",
        "jax_backend": jax.default_backend(),
        "fused_wallclock_note": (
            "fused_us is a compiled TPU kernel only where "
            "wallclock_comparable is true; on CPU runners it is the Pallas "
            "interpreter (kernel-path validation, not a speed claim)"),
        "shapes": records,
        "interpret_parity": _interpret_parity(),
        "min_traffic_ratio": min(r["hbm_traffic_ratio"] for r in records),
    }
    (ROOT / "BENCH_interact.json").write_text(json.dumps(payload, indent=1))
    return payload


if __name__ == "__main__":
    main()
